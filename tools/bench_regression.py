"""CI bench-regression gate: fresh smoke-suite results vs the committed
performance trajectory.

Re-runs the named benchmark suites in smoke mode (``REPRO_BENCH_SMOKE=1``,
in a subprocess — the bench modules read the env var at import time) with
the trajectory redirected to a scratch file, then diffs every tracked
P99 metric in the fresh ``<suite>@smoke`` cells against the committed
``BENCH_trajectory.json``.  The gate fails when

* the committed and fresh trajectory files disagree on
  ``schema_version`` (the diff would be apples-to-oranges), or
* any P99 latency metric regresses by more than ``--threshold``
  (default 15%) relative AND more than ``--floor`` (default 50 ms)
  absolute — the floor keeps sub-100 ms metrics from tripping the
  relative gate on noise.

Suites without a committed ``@smoke`` baseline cell are reported and
skipped (the first run that lands a baseline arms the gate).  Smoke
runs are fully seeded, so any drift the gate sees is a real behaviour
change, not sampling noise.

    PYTHONPATH=src python tools/bench_regression.py [--suites trace ...]
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import tempfile
from typing import Dict, List, Optional, Tuple

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
DEFAULT_SUITES = ["trace"]
DEFAULT_THRESHOLD = 0.15
DEFAULT_FLOOR_S = 0.05


def run_smoke_suites(suites: List[str], traj_path: str) -> None:
    """Run ``benchmarks.run`` for the suites in smoke mode, writing the
    trajectory to ``traj_path`` (never the committed file)."""
    env = dict(os.environ)
    env["REPRO_BENCH_SMOKE"] = "1"
    env["REPRO_TRAJECTORY"] = traj_path
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (os.path.join(REPO, "src"), env.get("PYTHONPATH")) if p
    )
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.run", *suites],
        cwd=REPO, env=env, capture_output=True, text=True,
    )
    if proc.returncode != 0:
        sys.stderr.write(proc.stdout[-2000:] + proc.stderr[-2000:])
        raise SystemExit(
            f"bench-regression: smoke run failed (exit {proc.returncode})"
        )


def p99_metrics(cell: Optional[dict]) -> Dict[str, float]:
    """The P99 latency rows of one trajectory cell."""
    if not cell:
        return {}
    return {
        name: float(v)
        for name, v in cell.get("metrics", {}).items()
        if "p99" in name.lower()
    }


def compare(
    baseline: dict,
    fresh: dict,
    suites: List[str],
    threshold: float,
    floor_s: float,
) -> Tuple[List[str], List[str]]:
    """Diff fresh ``@smoke`` cells against the committed ones; returns
    (regressions, notes)."""
    b_ver = baseline.get("schema_version")
    f_ver = fresh.get("schema_version")
    if b_ver != f_ver:
        return (
            [f"trajectory schema_version mismatch: committed {b_ver!r} "
             f"vs fresh {f_ver!r} — regenerate the committed baseline"],
            [],
        )
    regressions: List[str] = []
    notes: List[str] = []
    for suite in suites:
        key = f"{suite}@smoke"
        base = p99_metrics(baseline.get("suites", {}).get(key))
        new = p99_metrics(fresh.get("suites", {}).get(key))
        if not base:
            notes.append(f"{key}: no committed baseline cell — skipped "
                         f"(commit one to arm the gate)")
            continue
        if not new:
            regressions.append(f"{key}: smoke run produced no P99 metrics")
            continue
        for name in sorted(base):
            if name not in new:
                regressions.append(f"{name}: present in baseline, missing "
                                   f"from fresh run")
                continue
            old_v, new_v = base[name], new[name]
            delta = new_v - old_v
            rel = delta / old_v if old_v > 0 else float("inf")
            line = (f"{name}: {old_v:.4f}s -> {new_v:.4f}s "
                    f"({rel:+.1%}, {delta:+.4f}s)")
            if delta > floor_s and rel > threshold:
                regressions.append("REGRESSION " + line)
            else:
                notes.append("ok " + line)
    return regressions, notes


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--suites", nargs="+", default=DEFAULT_SUITES,
                    help="benchmark suites to gate (default: trace)")
    ap.add_argument("--threshold", type=float, default=DEFAULT_THRESHOLD,
                    help="relative P99 increase that fails the gate")
    ap.add_argument("--floor", type=float, default=DEFAULT_FLOOR_S,
                    help="absolute increase (s) below which the relative "
                         "gate never trips")
    ap.add_argument("--baseline",
                    default=os.path.join(REPO, "BENCH_trajectory.json"),
                    help="committed trajectory file to diff against")
    args = ap.parse_args(argv)

    try:
        with open(args.baseline) as f:
            baseline = json.load(f)
    except (OSError, ValueError) as exc:
        print(f"bench-regression: cannot read baseline "
              f"{args.baseline}: {exc}")
        return 1

    with tempfile.TemporaryDirectory() as tmp:
        traj_path = os.path.join(tmp, "trajectory.json")
        run_smoke_suites(args.suites, traj_path)
        with open(traj_path) as f:
            fresh = json.load(f)

    regressions, notes = compare(
        baseline, fresh, args.suites, args.threshold, args.floor
    )
    for line in notes:
        print(f"bench-regression: {line}")
    for line in regressions:
        print(f"bench-regression: {line}")
    if regressions:
        print(f"bench-regression: FAIL ({len(regressions)} problem(s), "
              f"threshold {args.threshold:.0%}, floor {args.floor}s)")
        return 1
    print("bench-regression: OK")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

"""CI bench-regression gate: fresh smoke-suite results vs the committed
performance trajectory.

Re-runs the named benchmark suites in smoke mode (``REPRO_BENCH_SMOKE=1``,
in a subprocess — the bench modules read the env var at import time) with
the trajectory redirected to a scratch file, then diffs every tracked
P99 metric in the fresh ``<suite>@smoke`` cells against the committed
``BENCH_trajectory.json``.  The gate fails when

* the committed and fresh trajectory files disagree on
  ``schema_version`` (the diff would be apples-to-oranges), or
* any P99 latency metric regresses by more than ``--threshold``
  (default 15%) relative AND more than ``--floor`` (default 50 ms)
  absolute — the floor keeps sub-100 ms metrics from tripping the
  relative gate on noise, or
* any per-event replay-cost metric (``per_event_us`` rows from the
  scalability suite) regresses by more than ``--event-threshold``
  (default 50%) relative AND more than ``--event-floor`` (default
  2 µs) absolute.  Per-event costs are wall-clock (machine-sensitive),
  so this gate is deliberately looser than the latency gate — it exists
  to catch the event core sliding back toward O(n) rescans, not 10%
  jitter.

Suites without a committed ``@smoke`` baseline cell are reported and
skipped (the first run that lands a baseline arms the gate).  Smoke
runs are fully seeded, so any drift the gate sees is a real behaviour
change, not sampling noise.

    PYTHONPATH=src python tools/bench_regression.py [--suites trace ...]
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import tempfile
from typing import Dict, List, Optional, Tuple

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
DEFAULT_SUITES = ["trace"]
DEFAULT_THRESHOLD = 0.15
DEFAULT_FLOOR_S = 0.05
DEFAULT_EVENT_THRESHOLD = 0.50
DEFAULT_EVENT_FLOOR_US = 2.0


def run_smoke_suites(suites: List[str], traj_path: str) -> None:
    """Run ``benchmarks.run`` for the suites in smoke mode, writing the
    trajectory to ``traj_path`` (never the committed file)."""
    env = dict(os.environ)
    env["REPRO_BENCH_SMOKE"] = "1"
    env["REPRO_TRAJECTORY"] = traj_path
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (os.path.join(REPO, "src"), env.get("PYTHONPATH")) if p
    )
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.run", *suites],
        cwd=REPO, env=env, capture_output=True, text=True,
    )
    if proc.returncode != 0:
        sys.stderr.write(proc.stdout[-2000:] + proc.stderr[-2000:])
        raise SystemExit(
            f"bench-regression: smoke run failed (exit {proc.returncode})"
        )


def p99_metrics(cell: Optional[dict]) -> Dict[str, float]:
    """The P99 latency rows of one trajectory cell."""
    if not cell:
        return {}
    return {
        name: float(v)
        for name, v in cell.get("metrics", {}).items()
        if "p99" in name.lower()
    }


def per_event_metrics(cell: Optional[dict]) -> Dict[str, float]:
    """The per-event replay-cost rows of one trajectory cell (µs)."""
    if not cell:
        return {}
    return {
        name: float(v)
        for name, v in cell.get("metrics", {}).items()
        if "per_event_us" in name.lower()
    }


def _diff_family(
    family: str,
    base: Dict[str, float],
    new: Dict[str, float],
    threshold: float,
    floor: float,
    unit: str,
    regressions: List[str],
    notes: List[str],
) -> None:
    """Diff one metric family; a regression needs BOTH the relative
    threshold and the absolute floor exceeded."""
    for name in sorted(base):
        if name not in new:
            regressions.append(f"{name}: present in baseline, missing "
                               f"from fresh run")
            continue
        old_v, new_v = base[name], new[name]
        delta = new_v - old_v
        rel = delta / old_v if old_v > 0 else float("inf")
        line = (f"{name}: {old_v:.4f}{unit} -> {new_v:.4f}{unit} "
                f"({rel:+.1%}, {delta:+.4f}{unit})")
        if delta > floor and rel > threshold:
            regressions.append(f"REGRESSION [{family}] " + line)
        else:
            notes.append("ok " + line)


def compare(
    baseline: dict,
    fresh: dict,
    suites: List[str],
    threshold: float,
    floor_s: float,
    event_threshold: float = DEFAULT_EVENT_THRESHOLD,
    event_floor_us: float = DEFAULT_EVENT_FLOOR_US,
) -> Tuple[List[str], List[str]]:
    """Diff fresh ``@smoke`` cells against the committed ones; returns
    (regressions, notes)."""
    b_ver = baseline.get("schema_version")
    f_ver = fresh.get("schema_version")
    if b_ver != f_ver:
        return (
            [f"trajectory schema_version mismatch: committed {b_ver!r} "
             f"vs fresh {f_ver!r} — regenerate the committed baseline"],
            [],
        )
    regressions: List[str] = []
    notes: List[str] = []
    for suite in suites:
        key = f"{suite}@smoke"
        base_cell = baseline.get("suites", {}).get(key)
        new_cell = fresh.get("suites", {}).get(key)
        base_p99 = p99_metrics(base_cell)
        base_ev = per_event_metrics(base_cell)
        if not base_p99 and not base_ev:
            notes.append(f"{key}: no committed baseline cell — skipped "
                         f"(commit one to arm the gate)")
            continue
        new_p99 = p99_metrics(new_cell)
        new_ev = per_event_metrics(new_cell)
        if base_p99 and not new_p99:
            regressions.append(f"{key}: smoke run produced no P99 metrics")
        elif base_p99:
            _diff_family("p99", base_p99, new_p99, threshold, floor_s,
                         "s", regressions, notes)
        if base_ev and not new_ev:
            regressions.append(f"{key}: smoke run produced no per-event "
                               f"metrics")
        elif base_ev:
            _diff_family("per-event", base_ev, new_ev, event_threshold,
                         event_floor_us, "us", regressions, notes)
    return regressions, notes


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--suites", nargs="+", default=DEFAULT_SUITES,
                    help="benchmark suites to gate (default: trace)")
    ap.add_argument("--threshold", type=float, default=DEFAULT_THRESHOLD,
                    help="relative P99 increase that fails the gate")
    ap.add_argument("--floor", type=float, default=DEFAULT_FLOOR_S,
                    help="absolute increase (s) below which the relative "
                         "gate never trips")
    ap.add_argument("--event-threshold", type=float,
                    default=DEFAULT_EVENT_THRESHOLD,
                    help="relative per-event-cost increase that fails "
                         "the gate")
    ap.add_argument("--event-floor", type=float,
                    default=DEFAULT_EVENT_FLOOR_US,
                    help="absolute per-event increase (µs) below which "
                         "the relative gate never trips")
    ap.add_argument("--baseline",
                    default=os.path.join(REPO, "BENCH_trajectory.json"),
                    help="committed trajectory file to diff against")
    args = ap.parse_args(argv)

    try:
        with open(args.baseline) as f:
            baseline = json.load(f)
    except (OSError, ValueError) as exc:
        print(f"bench-regression: cannot read baseline "
              f"{args.baseline}: {exc}")
        return 1

    with tempfile.TemporaryDirectory() as tmp:
        traj_path = os.path.join(tmp, "trajectory.json")
        run_smoke_suites(args.suites, traj_path)
        with open(traj_path) as f:
            fresh = json.load(f)

    regressions, notes = compare(
        baseline, fresh, args.suites, args.threshold, args.floor,
        event_threshold=args.event_threshold,
        event_floor_us=args.event_floor,
    )
    for line in notes:
        print(f"bench-regression: {line}")
    for line in regressions:
        print(f"bench-regression: {line}")
    if regressions:
        print(f"bench-regression: FAIL ({len(regressions)} problem(s), "
              f"threshold {args.threshold:.0%}, floor {args.floor}s)")
        return 1
    print("bench-regression: OK")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

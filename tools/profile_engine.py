"""cProfile harness for the replay hot loop (satellite of the indexed
event core).

Profiles one fleet-scale open-loop replay — trace synthesis, job load,
and initial scheduling are excluded so the report shows the event loop
alone.  Invoke directly or via ``python -m benchmarks.bench_scalability
--profile``:

    PYTHONPATH=src python tools/profile_engine.py --workers 100 \
        --tasks 50000 --engine indexed --top 25

Comparing ``--engine indexed`` against ``--engine reference`` shows
where the O(dirty) bookkeeping and packed batch placement moved the
time: the reference profile is dominated by per-row ``view()``
materialization and scalar per-candidate cost loops; the indexed profile
by the vectorized ``_td_model_vec`` / ``_eviction_penalty_vec`` kernels.
"""

from __future__ import annotations

import argparse
import cProfile
import os
import pstats
import sys
import tempfile
import time


def profile_replay(
    n_workers: int = 100,
    n_tasks: int = 50_000,
    rate_per_s: float = 40.0,
    scheduler: str = "navigator",
    engine: str = "indexed",
    seed: int = 5,
    top: int = 25,
    sort: str = "cumulative",
    stream=None,
) -> pstats.Stats:
    """Replay a synthesized trace under cProfile; print the top-N report."""
    from repro.core import ClusterSpec, ProfileRepository
    from repro.sim import Simulation
    from repro.sim.tracefile import load_jobs, synthesize_poisson_trace
    from repro.workflows import MODELS, paper_dfgs

    stream = stream or sys.stdout
    dfgs = paper_dfgs()
    with tempfile.TemporaryDirectory() as tmp:
        path = os.path.join(tmp, "profile.ctrc")
        synthesize_poisson_trace(path, dfgs, rate_per_s, n_tasks, seed=seed)
        jobs = load_jobs(path, {d.name: d for d in dfgs})
    cluster = ClusterSpec(n_workers=n_workers)
    profiles = ProfileRepository(cluster, MODELS)
    for d in dfgs:
        profiles.register(d)
    sim = Simulation(
        cluster, profiles, MODELS, scheduler=scheduler, seed=1, engine=engine
    )
    sim._schedule_initial(jobs)
    prof = cProfile.Profile()
    t0 = time.perf_counter()
    prof.enable()
    sim._event_loop()
    prof.disable()
    wall = time.perf_counter() - t0
    sim._assemble_result()
    print(
        f"engine={engine} scheduler={scheduler} workers={n_workers} "
        f"tasks={n_tasks}: {sim._events} events in {wall:.2f}s "
        f"({wall / sim._events * 1e6:.2f} us/event)",
        file=stream,
    )
    stats = pstats.Stats(prof, stream=stream)
    stats.sort_stats(sort).print_stats(top)
    return stats


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--workers", type=int, default=100)
    ap.add_argument("--tasks", type=int, default=50_000)
    ap.add_argument("--rate", type=float, default=40.0)
    ap.add_argument("--scheduler", default="navigator")
    ap.add_argument("--engine", default="indexed",
                    choices=["indexed", "reference"])
    ap.add_argument("--seed", type=int, default=5)
    ap.add_argument("--top", type=int, default=25)
    ap.add_argument("--sort", default="cumulative",
                    choices=["cumulative", "tottime", "ncalls"])
    args = ap.parse_args(argv)
    profile_replay(
        n_workers=args.workers, n_tasks=args.tasks, rate_per_s=args.rate,
        scheduler=args.scheduler, engine=args.engine, seed=args.seed,
        top=args.top, sort=args.sort,
    )
    return 0


if __name__ == "__main__":
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    raise SystemExit(main())

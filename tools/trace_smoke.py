"""CI trace-smoke: flight-recorder end-to-end guard.

Runs a small 5-worker paper-config sim with tracing ON and asserts

1. the Chrome-trace/Perfetto export validates against
   ``schemas/trace.schema.json`` and the metrics export against
   ``schemas/metrics.schema.json`` (dependency-free subset validator);
2. the JSONL export is byte-identical across two runs of the same
   seed + config (the determinism contract the chaos suite builds on);
3. placement provenance is recorded for every planned task and each
   decision's chosen worker is the candidate argmin;
4. every job's critical-path latency breakdown sums to its measured
   JCT within 1e-6;
5. the per-ring FIFO drop counters exported by the metrics registry
   (``trace.emitted`` / ``trace.dropped``) agree with the recorder's own
   ledger — zero drops at the default ring size, and a deliberately tiny
   ring shows its drops in the export without touching determinism;

and with tracing OFF that the hot event loop performs **zero**
allocations attributable to ``core/telemetry.py`` or
``core/healthplane.py`` (tracemalloc-filtered guard: the
zero-overhead-when-off claim, structurally enforced because
``Simulation._event_loop`` never calls into telemetry or the health
monitor when ``self._rec is None`` / ``self._health is None``).

    PYTHONPATH=src python tools/trace_smoke.py
"""

from __future__ import annotations

import json
import os
import sys
import tracemalloc

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import ClusterSpec, ProfileRepository, SimReport, validate_schema
from repro.core import healthplane as healthplane_mod
from repro.core import telemetry as telemetry_mod
from repro.core.telemetry import TraceConfig
from repro.sim import Simulation, bursty_trace_workload
from repro.workflows import MODELS, paper_dfgs

REPO = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..")
DURATION_S = 30.0


def build_sim(trace) -> Simulation:
    cluster = ClusterSpec(n_workers=5)
    profiles = ProfileRepository(cluster, MODELS)
    for d in paper_dfgs():
        profiles.register(d)
    return Simulation(
        cluster, profiles, MODELS, scheduler="navigator", seed=1, trace=trace
    )


def workload():
    return bursty_trace_workload(
        paper_dfgs(), base_rate_per_s=0.8, duration_s=DURATION_S, seed=3
    )


def load_schema(name: str):
    with open(os.path.join(REPO, "schemas", name)) as f:
        return json.load(f)


def check_traced() -> None:
    res = build_sim(trace=True).run(workload())
    assert res.trace is not None and res.trace.dropped == 0

    chrome = res.trace.to_chrome_trace()
    # Round-trip through JSON: validate what a consumer would parse.
    validate_schema(json.loads(json.dumps(chrome)), load_schema("trace.schema.json"))
    validate_schema(res.metrics.export(), load_schema("metrics.schema.json"))
    n_x = sum(1 for e in chrome["traceEvents"] if e["ph"] == "X")
    assert n_x > 0, "no duration events in the Chrome trace"
    print(f"trace-smoke: chrome trace valid "
          f"({len(chrome['traceEvents'])} events, {n_x} spans); "
          f"metrics export valid ({len(res.metrics.export()['metrics'])} rows)")

    jsonl = res.trace.to_jsonl()
    res2 = build_sim(trace=True).run(workload())
    assert res2.trace.to_jsonl() == jsonl, "JSONL trace is not deterministic"
    print(f"trace-smoke: JSONL deterministic ({jsonl.count(chr(10))} lines)")

    assert res.trace.placements, "no placement provenance recorded"
    for d in res.trace.placements:
        feasible = [c for c in d.candidates if c.total_s != float("inf")]
        assert feasible, f"decision for {d.task_id!r} has no feasible candidate"
        chosen = d.candidate(d.chosen)
        assert chosen is not None
        if not d.note:  # herd-sticky / hysteresis overrides leave a note
            best = min(c.total_s for c in feasible)
            assert chosen.total_s <= best + 1e-6, (
                f"{d.task_id!r}: chose w{d.chosen} ({chosen.total_s:.6f}) "
                f"over {best:.6f}"
            )
    print(f"trace-smoke: provenance argmin-consistent "
          f"({len(res.trace.placements)} decisions)")

    report = SimReport(res)
    worst = 0.0
    for r in res.records:
        bd = report.latency_breakdown(r.job_id)
        worst = max(worst, abs(bd.components_sum_s - bd.jct_s),
                    abs(bd.jct_s - r.latency))
    assert worst < 1e-6, f"breakdown residual {worst}"
    print(f"trace-smoke: {len(res.records)} breakdowns sum to JCT "
          f"(worst residual {worst:.2e})")


def check_drop_counters() -> None:
    """Satellite: per-ring FIFO drop counters in the metrics export must
    mirror the recorder's own ledger — zero at the default capacity, and
    visible (without breaking the run) when the ring is squeezed."""
    res = build_sim(trace=True).run(workload())
    stats = res.trace.ring_stats()
    assert sum(d for _, d in stats.values()) == res.trace.dropped == 0
    for ring, (emitted, dropped) in stats.items():
        assert int(res.metrics.value("trace.emitted", ring=ring)) == emitted
        assert int(res.metrics.value("trace.dropped", ring=ring)) == dropped

    squeezed = build_sim(trace=TraceConfig(ring_capacity=64)).run(workload())
    st2 = squeezed.trace.ring_stats()
    emitted2 = sum(e for e, _ in st2.values())
    dropped2 = sum(d for _, d in st2.values())
    assert dropped2 == squeezed.trace.dropped > 0, (
        f"64-event rings should overflow on this workload "
        f"(dropped={dropped2}, ledger={squeezed.trace.dropped})"
    )
    assert int(squeezed.metrics.sum_values("trace.dropped")) == dropped2
    assert int(squeezed.metrics.sum_values("trace.emitted")) == emitted2
    rate = dropped2 / emitted2
    assert 0.0 < rate < 1.0, f"drop rate {rate} out of range"
    assert len(squeezed.records) == len(res.records), (
        "ring overflow must not change the run itself"
    )
    print(f"trace-smoke: ring drop counters consistent "
          f"(default: 0 drops; 64-slot rings: {dropped2}/{emitted2} "
          f"= {rate:.1%} dropped, surfaced in metrics export)")


def check_zero_alloc_off() -> None:
    """Tracing OFF must add zero telemetry/health allocations to the
    event loop."""
    sim = build_sim(trace=False)
    jobs = workload()
    sim._schedule_initial(jobs)
    tel_file = telemetry_mod.__file__
    health_file = healthplane_mod.__file__
    tracemalloc.start(25)
    try:
        before = tracemalloc.take_snapshot()
        sim._event_loop()
        after = tracemalloc.take_snapshot()
    finally:
        tracemalloc.stop()
    flt = [tracemalloc.Filter(True, tel_file),
           tracemalloc.Filter(True, health_file)]
    stats = after.filter_traces(flt).compare_to(before.filter_traces(flt),
                                                "lineno")
    leaked = [s for s in stats if s.size_diff > 0 or s.count_diff > 0]
    assert not leaked, (
        "tracing-off event loop allocated in telemetry.py/healthplane.py:\n"
        + "\n".join(str(s) for s in leaked)
    )
    res = sim._assemble_result()
    assert res.trace is None and res.health is None and len(res.records) > 0
    print(f"trace-smoke: tracing-off event loop made 0 telemetry/health "
          f"allocations ({len(res.records)} jobs completed)")


def main() -> None:
    check_traced()
    check_drop_counters()
    check_zero_alloc_off()
    print("trace-smoke: OK")


if __name__ == "__main__":
    main()

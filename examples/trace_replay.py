"""Replay a bursty production-style trace through every scheduler
(the §6.4 experiment) and render completion-time timelines as ASCII.

    PYTHONPATH=src python examples/trace_replay.py
"""

from repro.core import ClusterSpec, ProfileRepository
from repro.sim import (
    Simulation,
    arrival_rate_timeline,
    bursty_trace_workload,
)
from repro.workflows import MODELS, paper_dfgs


def sparkline(values, width=72):
    if not values:
        return ""
    blocks = " ▁▂▃▄▅▆▇█"
    step = max(1, len(values) // width)
    vals = [max(values[i:i + step]) for i in range(0, len(values), step)]
    hi = max(vals) or 1.0
    return "".join(blocks[min(8, int(v / hi * 8))] for v in vals)


def main() -> None:
    cluster = ClusterSpec(n_workers=5)
    dfgs = paper_dfgs()
    jobs = bursty_trace_workload(dfgs, base_rate_per_s=0.8,
                                 duration_s=600.0, seed=3)
    rates = [r for _, r in arrival_rate_timeline(jobs, bin_s=10.0)]
    print(f"trace: {len(jobs)} requests over 600 s")
    print(f"arrival rate   {sparkline(rates)}")

    for name in ["navigator", "jit", "heft", "hash"]:
        profiles = ProfileRepository(cluster, MODELS)
        for d in dfgs:
            profiles.register(d)
        res = Simulation(cluster, profiles, MODELS, scheduler=name,
                         seed=1).run(jobs)
        lats = [0.0] * 61
        for r in res.records:
            lats[int(r.arrival // 10)] = max(
                lats[int(r.arrival // 10)], r.latency
            )
        print(f"{name:>10} lat {sparkline(lats)}  "
              f"p95={res.percentile_latency(0.95):6.2f}s "
              f"mean={res.mean_latency:5.2f}s")

    print("\nHash is least burst-tolerant; Navigator absorbs the spikes")
    print("by expanding the worker set only when it pays off (§6.4).")


if __name__ == "__main__":
    main()

"""Decentralized scheduling under metadata staleness, in 60 seconds.

Runs Navigator on the decentralized SST gossip plane — every worker plans
from its own, possibly stale, replica of the cluster state — and sweeps
the gossip period from near-fresh (50 ms) to very stale (4 s), on the
uniform paper fleet and on a heterogeneous mixed fleet (A10/L4/T4/edge).
Watch the P50/P99 job completion times degrade *gracefully* as views get
staler while the message volume collapses.

    PYTHONPATH=src python examples/staleness_demo.py
"""

from repro.core import ClusterSpec, GossipConfig, ProfileRepository, fleet
from repro.sim import Simulation, fleet_scaled_rate, fleet_workload
from repro.workflows import MODELS, paper_dfgs


def run(cluster, period_s, base_rate):
    dfgs = paper_dfgs()
    profiles = ProfileRepository(cluster, MODELS)
    for d in dfgs:
        profiles.register(d)
    jobs = fleet_workload(dfgs, cluster, base_rate, duration_s=150.0, seed=7)
    sim = Simulation(
        cluster, profiles, MODELS, scheduler="navigator",
        gossip=GossipConfig(period_s=period_s, fanout=2), seed=1,
    )
    return sim.run(jobs)


def main() -> None:
    for fleet_name in ("uniform", "mixed"):
        cluster = fleet(fleet_name)
        print(f"\n{fleet_name} fleet ({cluster.n_workers} workers, "
              f"aggregate speed {cluster.total_speed:.1f}x, "
              f"{fleet_scaled_rate(cluster, 2.0):.2f} req/s):")
        print(f"{'gossip period':>14} | {'P50 JCT':>8} | {'P99 JCT':>8} | "
              f"{'slowdown':>8} | {'messages':>8}")
        print("-" * 62)
        for period in (0.05, 0.2, 1.0, 4.0):
            res = run(cluster, period, 2.0)
            print(f"{period:13.2f}s | {res.percentile_latency(0.5):7.2f}s | "
                  f"{res.percentile_latency(0.99):7.2f}s | "
                  f"{res.mean_slowdown:8.2f} | {res.sst_pushes:8d}")

    print("\nEach worker planned from its own gossip replica; a 80x staler")
    print("view costs well under 2x latency — the decentralized plane")
    print("degrades gracefully instead of cliffing.")


if __name__ == "__main__":
    main()

"""Train a small dense LM for a few hundred steps on the synthetic
pipeline (deliverable b) — demonstrates the full training substrate:
data pipeline → sharded train_step (pjit) → AdamW → checkpointing.

Default config is CPU-sized (~8M params, 200 steps in a couple of
minutes); pass --steps/--d-model to scale.  Loss should drop well below
ln(vocab) as the model learns the synthetic repetition structure.

    PYTHONPATH=src python examples/train_small.py --steps 200
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import DataConfig, make_pipeline
from repro.launch.mesh import make_debug_mesh
from repro.models import ModelConfig, init_params
from repro.training import checkpoint, make_train_step, optimizer as opt


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--vocab", type=int, default=512)
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt/train_small")
    args = ap.parse_args()

    cfg = ModelConfig(
        name="tiny-dense",
        arch_type="dense",
        n_layers=args.layers,
        d_model=args.d_model,
        n_heads=4,
        n_kv_heads=2,
        d_ff=args.d_model * 4,
        vocab=args.vocab,
        dtype="float32",
    )
    print(f"model: {cfg.param_count()/1e6:.1f}M params")

    mesh = make_debug_mesh()
    ocfg = opt.AdamWConfig(lr=3e-3, warmup_steps=20, total_steps=args.steps)
    params = init_params(cfg, jax.random.key(0))
    state = opt.init(params)
    _, jit_factory = make_train_step(cfg, mesh, ocfg, remat=False)
    batch0 = {
        "tokens": jax.ShapeDtypeStruct((args.batch, args.seq), jnp.int32)
    }
    step = jit_factory(params, state, batch0)

    data = make_pipeline(
        DataConfig(vocab=args.vocab, seq_len=args.seq, global_batch=args.batch)
    )
    t0 = time.time()
    first = last = None
    for i in range(args.steps):
        batch = {"tokens": jnp.asarray(next(data)["tokens"])}
        params, state, metrics = step(params, state, batch)
        loss = float(metrics["loss"])
        first = first if first is not None else loss
        last = loss
        if i % 25 == 0 or i == args.steps - 1:
            print(
                f"step {i:4d}  loss {loss:6.3f}  "
                f"gnorm {float(metrics['grad_norm']):6.2f}  "
                f"lr {float(metrics['lr']):.2e}  "
                f"({(time.time()-t0)/(i+1):.2f}s/step)"
            )
    print(f"\nloss {first:.3f} → {last:.3f} (ln V = {np.log(args.vocab):.3f})")

    checkpoint.save(args.ckpt, {"params": params, "opt": state._asdict()},
                    metadata={"steps": args.steps, "loss": last})
    restored, meta = checkpoint.restore(
        args.ckpt, {"params": params, "opt": state._asdict()}
    )
    print(f"checkpoint round-trip OK (saved at step {meta['steps']})")


if __name__ == "__main__":
    main()

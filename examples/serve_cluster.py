"""End-to-end serving driver (deliverable b): batched pipeline requests
through REAL JAX models placed by the Navigator scheduler.

Three reduced-config zoo architectures (a dense GQA model, an MQA code
model, and an attention-free Mamba2) are hosted on a 3-worker cluster and
chained into a draft → verify → refine pipeline; a second
perceive → describe pipeline shares the verify model (cross-pipeline
model reuse, §3.3).  Requests execute real prefill+decode steps; the
scheduler's placements and the model-cache hit rate are reported, and
Navigator is compared with Hash placement on total virtual makespan.

    PYTHONPATH=src python examples/serve_cluster.py
"""

import numpy as np
import jax

from repro.configs import ARCHS
from repro.core import ClusterSpec, GB
from repro.core.types import DFG, MB, TaskSpec
from repro.models import init_params
from repro.serving import HostedModel, ServingCluster

DRAFT, VERIFY, REFINE = 0, 1, 2


def build_pipelines():
    speculative = DFG(
        "speculative_serving",
        tasks=[
            TaskSpec("draft", 0.08, model_id=DRAFT, output_bytes=0.01 * MB,
                     input_bytes=0.01 * MB),
            TaskSpec("verify", 0.20, model_id=VERIFY, output_bytes=0.01 * MB),
            TaskSpec("refine", 0.15, model_id=REFINE, output_bytes=0.01 * MB),
        ],
        edges=[("draft", "verify"), ("verify", "refine")],
    )
    summarize = DFG(
        "describe",
        tasks=[
            TaskSpec("perceive", 0.1, model_id=REFINE, output_bytes=0.01 * MB,
                     input_bytes=0.02 * MB),
            TaskSpec("describe", 0.2, model_id=VERIFY, output_bytes=0.01 * MB),
        ],
        edges=[("perceive", "describe")],
    )
    return speculative, summarize


def run(scheduler: str, requests, hosted_factory):
    cluster = ClusterSpec(n_workers=3, gpu_capacity_bytes=1 * GB)
    sc = ServingCluster(cluster, hosted_factory(), scheduler=scheduler,
                        decode_tokens=6)
    spec, summ = build_pipelines()
    sc.register_pipeline(spec)
    sc.register_pipeline(summ)
    for i, (kind, prompt) in enumerate(requests):
        dfg, entry = (spec, "draft") if kind == 0 else (summ, "perceive")
        sc.submit(dfg, {entry: prompt}, origin=i % 3)
    makespan = max(r.virtual_latency_s for r in sc.results)
    total_virtual = sum(r.virtual_latency_s for r in sc.results)
    return sc, total_virtual, makespan


def main() -> None:
    def hosted_factory():
        out = []
        for mid, arch in [
            (DRAFT, "mamba2-780m"),
            (VERIFY, "mistral-nemo-12b"),
            (REFINE, "granite-20b"),
        ]:
            cfg = ARCHS[arch].reduced(dtype="float32")
            out.append(HostedModel(mid, cfg, init_params(cfg, jax.random.key(mid))))
        return out

    rng = np.random.default_rng(0)
    requests = [
        (int(rng.integers(0, 2)),
         rng.integers(1, 64, size=(2, 12)).astype(np.int32))
        for _ in range(10)
    ]

    for sched in ["navigator", "hash"]:
        sc, total, makespan = run(sched, requests, hosted_factory)
        print(f"\n=== scheduler: {sched} ===")
        for r in sc.results[:3]:
            print(f"  {r.dfg_name:22s} virt={r.virtual_latency_s:6.3f}s "
                  f"assign={r.assignment}")
        print(f"  … {len(sc.results)} requests")
        print(f"  total virtual latency : {total:7.3f}s")
        print(f"  cache hit rate        : {sc.cache_hit_rate()*100:5.1f}%")
        print(f"  workers used          : {sc.workers_used()}")

    print("\nReal logits flowed through every pipeline stage; placement and")
    print("cache behaviour are Navigator's (§3-§4).")


if __name__ == "__main__":
    main()

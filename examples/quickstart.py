"""Quickstart: Navigator in 60 seconds.

Builds the paper's four ML workflows (Fig. 1), runs the decentralized
scheduler against the JIT/HEFT/Hash baselines on a simulated 5-worker
edge cluster at the paper's high-load setting, and prints the §6.2
comparison (slowdown factor, cache hit rate, GPU utilization).

    PYTHONPATH=src python examples/quickstart.py
"""

from repro.core import ClusterSpec, ProfileRepository
from repro.sim import Simulation, poisson_workload
from repro.workflows import MODELS, paper_dfgs


def main() -> None:
    cluster = ClusterSpec(n_workers=5)  # 5× 16 GB-GPU workers (§6)
    dfgs = paper_dfgs()

    print(f"{'scheduler':>10} | {'mean lat':>8} | {'slowdown':>8} | "
          f"{'hit rate':>8} | {'GPU util':>8}")
    print("-" * 56)
    for name in ["navigator", "jit", "heft", "hash"]:
        profiles = ProfileRepository(cluster, MODELS)
        for d in dfgs:
            profiles.register(d)
        jobs = poisson_workload(dfgs, rate_per_s=2.0, duration_s=300.0, seed=7)
        res = Simulation(
            cluster, profiles, MODELS, scheduler=name, seed=1
        ).run(jobs)
        print(
            f"{name:>10} | {res.mean_latency:7.2f}s | "
            f"{res.mean_slowdown:8.2f} | {res.cache_hit_rate*100:7.1f}% | "
            f"{res.gpu_utilization*100:7.1f}%"
        )

    print("\nNavigator schedules where the models already are; the cache")
    print("hit-rate column is the paper's Table-1 story in one number.")


if __name__ == "__main__":
    main()

"""Flight-recorder observability plane (DESIGN.md §3.4).

Compass's headline claims are latency *decompositions* — where a job's
completion time goes and why a placement won — so the repo needs more
than end-of-run aggregates.  This module is the instrument:

* :class:`FlightRecorder` — a structured event tracer.  Every simulator /
  serving-engine event (dispatch, fetch begin/complete/abort, prefetch
  intent/promotion, gossip exchange, transfer start/finish with link
  scope + contention share, churn/partition transitions, task state
  changes) lands as a typed record on a per-worker ring buffer.  The
  whole trace exports as Chrome-trace/Perfetto JSON and as a
  deterministic JSONL stream (same seed + config ⇒ byte-identical
  bytes — a far stronger regression oracle than aggregate counters).

* **Span model** — :func:`build_spans` stitches raw events into
  per-task-attempt spans and :class:`SimReport` walks the critical path
  of a job's DAG to produce a queue / input-transfer / model-fetch-wait
  / compute / output-ship latency breakdown whose components sum to the
  measured JCT exactly (telescoping differences, no estimation).

* **Placement provenance** — planners record the per-candidate Eq. 2
  cost vector (queue drain, data/path term, model term, intent
  discount, runtime, liveness penalty, staleness margin) for every
  decision, so ``explain(task_id)`` answers "why worker 3 and not
  worker 5" from the trace alone.

* :class:`MetricsRegistry` — named, labeled counters/gauges that absorb
  the engines' ad-hoc result counters into a stable, versioned export
  schema (``schemas/metrics.schema.json``).

Zero overhead when off: the engines guard every emission site with
``if self._rec is not None`` and never call into this module from the
hot event loop while tracing is disabled — the CI ``trace-smoke`` guard
benchmark asserts the tracing-off loop performs *zero* allocations
attributable to this file.
"""

from __future__ import annotations

import collections
import dataclasses
import gzip
import io
import json
from typing import (
    Any,
    Callable,
    Dict,
    Iterable,
    List,
    Mapping,
    Optional,
    Sequence,
    Tuple,
)

TRACE_SCHEMA_VERSION = 1
METRICS_SCHEMA_VERSION = 1

#: Cluster-scope events (churn, plan, job lifecycle) ride a dedicated
#: ring instead of a worker's.
GLOBAL = -1


# --------------------------------------------------------------------------
# Metrics registry
# --------------------------------------------------------------------------
class Counter:
    """Monotone named counter (int or float)."""

    __slots__ = ("name", "labels", "value")

    def __init__(self, name: str, labels: Tuple[Tuple[str, str], ...]) -> None:
        self.name = name
        self.labels = labels
        self.value: float = 0

    def inc(self, n: float = 1) -> None:
        self.value += n


class Gauge:
    """Last-write-wins named value."""

    __slots__ = ("name", "labels", "value")

    def __init__(self, name: str, labels: Tuple[Tuple[str, str], ...]) -> None:
        self.name = name
        self.labels = labels
        self.value: float = 0

    def set(self, v: float) -> None:
        self.value = v


class Histogram:
    """Fixed-bucket histogram (cumulative counts + sum/count/min/max)."""

    __slots__ = ("name", "labels", "bounds", "bucket_counts", "count", "sum",
                 "min", "max")

    def __init__(
        self,
        name: str,
        labels: Tuple[Tuple[str, str], ...],
        bounds: Sequence[float],
    ) -> None:
        self.name = name
        self.labels = labels
        self.bounds = tuple(bounds)
        self.bucket_counts = [0] * (len(self.bounds) + 1)
        self.count = 0
        self.sum = 0.0
        self.min = float("inf")
        self.max = float("-inf")

    def observe(self, v: float) -> None:
        self.count += 1
        self.sum += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v
        for i, b in enumerate(self.bounds):
            if v <= b:
                self.bucket_counts[i] += 1
                return
        self.bucket_counts[-1] += 1


def _label_key(labels: Mapping[str, str]) -> Tuple[Tuple[str, str], ...]:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


class MetricsRegistry:
    """Named, labeled counters/gauges/histograms with a stable export
    schema.  One registry per simulation/serving run; the engines'
    legacy result fields are derived views over it."""

    def __init__(self) -> None:
        self._metrics: Dict[Tuple[str, Tuple[Tuple[str, str], ...]], Any] = {}

    def _get(self, cls, name: str, labels: Mapping[str, str], *args):
        key = (name, _label_key(labels))
        m = self._metrics.get(key)
        if m is None:
            m = cls(name, key[1], *args)
            self._metrics[key] = m
        elif not isinstance(m, cls):
            raise TypeError(
                f"metric {name!r}{dict(key[1])} already registered as "
                f"{type(m).__name__}"
            )
        return m

    def counter(self, name: str, **labels: str) -> Counter:
        return self._get(Counter, name, labels)

    def gauge(self, name: str, **labels: str) -> Gauge:
        return self._get(Gauge, name, labels)

    def histogram(
        self, name: str, bounds: Sequence[float] = (), **labels: str
    ) -> Histogram:
        return self._get(Histogram, name, labels, bounds)

    def value(self, name: str, default: float = 0, **labels: str) -> float:
        """Current value of a counter/gauge (``default`` if absent)."""
        m = self._metrics.get((name, _label_key(labels)))
        return default if m is None else m.value

    def sum_values(self, name: str) -> float:
        """Sum of a counter family's values across all label sets."""
        return sum(
            m.value
            for (n, _), m in self._metrics.items()
            if n == name and isinstance(m, (Counter, Gauge))
        )

    def export(self) -> Dict[str, Any]:
        """Versioned, deterministic export (sorted by name + labels)."""
        out: List[Dict[str, Any]] = []
        for (name, labels), m in sorted(self._metrics.items()):
            rec: Dict[str, Any] = {
                "name": name,
                "type": type(m).__name__.lower(),
                "labels": dict(labels),
            }
            if isinstance(m, Histogram):
                rec["count"] = m.count
                rec["sum"] = m.sum
                rec["bounds"] = list(m.bounds)
                rec["bucket_counts"] = list(m.bucket_counts)
                if m.count:
                    rec["min"] = m.min
                    rec["max"] = m.max
            else:
                rec["value"] = m.value
            out.append(rec)
        return {"schema_version": METRICS_SCHEMA_VERSION, "metrics": out}


# --------------------------------------------------------------------------
# Placement provenance
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class CandidateCost:
    """Per-candidate Eq. 2 cost vector for one placement decision.

    All terms are seconds.  ``total_s`` is the selection cost the argmin
    ran over: ``max(queue_s, input_s) + model_s + runtime_s +
    liveness_s (+ staleness_margin_s where the decision applies one)``.
    ``intent_discount_s`` is how much the prefetch-intent lane shaved
    off the undiscounted model term (0 when inert).
    """

    worker: int
    queue_s: float            # published FT(w) queue-drain estimate (abs)
    input_s: float            # AT_allInputs / data-path term (abs arrival)
    model_s: float            # Eq. 2 TD_model actually charged
    intent_discount_s: float  # fetch seconds saved by the intent lane
    runtime_s: float          # R(t, w)
    liveness_s: float         # membership penalty (inf = DEAD in view)
    total_s: float            # selection cost (argmin input)
    staleness_margin_s: float = 0.0  # hysteresis margin applied (adjust)

    def as_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        return {k: (repr(v) if v in (float("inf"),) else v)
                for k, v in d.items()}


@dataclasses.dataclass(frozen=True)
class PlacementDecision:
    """One planner decision with its full candidate table."""

    t: float
    job_id: int
    task_id: str
    phase: str                # plan | jit | adjust | recovery
    scheduler: str
    reader: int               # worker whose SST replica was read
    chosen: int
    candidates: Tuple[CandidateCost, ...]
    note: str = ""            # e.g. herd-sticky override, hysteresis hold

    def candidate(self, worker: int) -> Optional[CandidateCost]:
        for c in self.candidates:
            if c.worker == worker:
                return c
        return None

    def explain(self) -> str:
        lines = [
            f"[{self.phase}] job {self.job_id} task {self.task_id!r} "
            f"@t={self.t:.6f}s  scheduler={self.scheduler}  "
            f"reader=w{self.reader}  chosen=w{self.chosen}"
            + (f"  ({self.note})" if self.note else "")
        ]
        lines.append(
            "  worker   queue_s   input_s   model_s  -intent_s runtime_s"
            "    live_s   total_s"
        )
        for c in sorted(self.candidates, key=lambda c: c.worker):
            mark = "→" if c.worker == self.chosen else " "
            lines.append(
                f" {mark}w{c.worker:<4d}"
                + "".join(
                    f"{v:>10.4f}" if v != float("inf") else f"{'inf':>10}"
                    for v in (
                        c.queue_s, c.input_s, c.model_s, c.intent_discount_s,
                        c.runtime_s, c.liveness_s, c.total_s,
                    )
                )
            )
        return "\n".join(lines)


# --------------------------------------------------------------------------
# Flight recorder
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class TraceConfig:
    """Flight-recorder tunables."""

    # Events retained per ring (one ring per worker + one cluster ring).
    # Old events are dropped FIFO once a ring fills; drops are counted
    # and surfaced by SimReport.
    ring_capacity: int = 1 << 20
    # Record per-candidate planner cost vectors (placement provenance).
    provenance: bool = True


class FlightRecorder:
    """Per-worker ring-buffer event tracer + provenance store.

    ``emit`` is the single hot entry point; the engines only call it
    behind an ``is not None`` guard, so a disabled recorder costs one
    attribute load + branch per site and zero allocations.
    """

    def __init__(
        self, n_workers: int, config: Optional[TraceConfig] = None
    ) -> None:
        self.config = config or TraceConfig()
        self.n_workers = n_workers
        cap = self.config.ring_capacity
        # rings[w] for workers, rings[n_workers] is the cluster ring.
        self._rings: List[collections.deque] = [
            collections.deque(maxlen=cap) for _ in range(n_workers + 1)
        ]
        self._emitted: List[int] = [0] * (n_workers + 1)
        self._seq = 0
        self.placements: List[PlacementDecision] = []
        self._placement_index: Dict[Tuple[int, str], List[int]] = {}

    # -- hot path ------------------------------------------------------------
    def emit(self, t: float, kind: str, worker: int = GLOBAL, **data) -> None:
        ring = self._rings[worker if 0 <= worker < self.n_workers
                           else self.n_workers]
        ring.append((self._seq, t, kind, worker, data))
        self._emitted[worker if 0 <= worker < self.n_workers
                      else self.n_workers] += 1
        self._seq += 1

    # -- provenance sink (planners call this) ---------------------------------
    def record_placement(self, decision: PlacementDecision) -> None:
        if not self.config.provenance:
            return
        self._placement_index.setdefault(
            (decision.job_id, decision.task_id), []
        ).append(len(self.placements))
        self.placements.append(decision)
        self.emit(
            decision.t,
            "sched.place",
            worker=decision.reader,
            job=decision.job_id,
            task=decision.task_id,
            phase=decision.phase,
            chosen=decision.chosen,
            n_candidates=len(decision.candidates),
        )

    def decisions(self, job_id: int, task_id: str) -> List[PlacementDecision]:
        return [
            self.placements[i]
            for i in self._placement_index.get((job_id, task_id), [])
        ]

    # -- inspection -----------------------------------------------------------
    @property
    def dropped(self) -> int:
        """Events lost to ring wrap-around (0 ⇒ the trace is complete)."""
        return sum(
            e - len(r) for e, r in zip(self._emitted, self._rings)
        )

    def ring_stats(self) -> Dict[str, Tuple[int, int]]:
        """Per-ring (emitted, dropped) counts keyed by ring name
        (``worker<N>`` / ``cluster``) — the engines fold these into the
        MetricsRegistry export (``trace.emitted`` / ``trace.dropped``)
        so a drop-rate alert needs no trace access."""
        out: Dict[str, Tuple[int, int]] = {}
        for i, (e, r) in enumerate(zip(self._emitted, self._rings)):
            name = f"worker{i}" if i < self.n_workers else "cluster"
            out[name] = (e, e - len(r))
        return out

    def events(self) -> List[Tuple[int, float, str, int, Dict[str, Any]]]:
        """All retained events in emission order (seq-sorted)."""
        out: List[Tuple[int, float, str, int, Dict[str, Any]]] = []
        for ring in self._rings:
            out.extend(ring)
        out.sort(key=lambda e: e[0])
        return out

    # -- exports --------------------------------------------------------------
    def to_jsonl(self) -> str:
        """Deterministic JSONL stream: one event per line, stable key
        order, seq-sorted.  Same seed + config ⇒ byte-identical output
        (the chaos suite asserts this)."""
        lines = []
        for seq, t, kind, worker, data in self.events():
            rec = {"seq": seq, "t": round(t, 9), "kind": kind,
                   "worker": worker}
            for k in sorted(data):
                v = data[k]
                if isinstance(v, float):
                    v = round(v, 9)
                rec[k] = v
            lines.append(json.dumps(rec, separators=(",", ":")))
        return "\n".join(lines) + ("\n" if lines else "")

    def to_chrome_trace(self) -> Dict[str, Any]:
        """Chrome-trace/Perfetto JSON (``chrome://tracing`` object
        format).  pid = worker, tids split execution / fetch-pipe /
        network lanes; instant events carry churn and scheduling
        markers."""
        US = 1e6
        tev: List[Dict[str, Any]] = []
        for w in range(self.n_workers):
            tev.append({"ph": "M", "name": "process_name", "pid": w,
                        "tid": 0, "args": {"name": f"worker{w}"}})
            for tid, nm in ((0, "exec"), (1, "fetch-pipe"), (2, "net-out")):
                tev.append({"ph": "M", "name": "thread_name", "pid": w,
                            "tid": tid, "args": {"name": nm}})
        tev.append({"ph": "M", "name": "process_name", "pid": self.n_workers,
                    "tid": 0, "args": {"name": "cluster"}})

        open_exec: Dict[Tuple[int, str], Tuple[float, Dict[str, Any]]] = {}
        open_fetch: Dict[int, Tuple[float, Dict[str, Any]]] = {}
        for seq, t, kind, worker, data in self.events():
            pid = worker if 0 <= worker < self.n_workers else self.n_workers
            if kind == "task.start":
                open_exec[(pid, f"{data['job']}:{data['task']}")] = (t, data)
            elif kind == "task.done":
                key = (pid, f"{data['job']}:{data['task']}")
                t0, d0 = open_exec.pop(key, (t, data))
                tev.append({
                    "ph": "X", "cat": "task", "name": key[1], "pid": pid,
                    "tid": 0, "ts": t0 * US, "dur": (t - t0) * US,
                    "args": {"gen": data.get("gen", 0),
                             "model": d0.get("model", -1),
                             "miss": d0.get("miss", False)},
                })
            elif kind == "fetch.start":
                open_fetch[pid] = (t, data)
            elif kind in ("fetch.done", "fetch.abort"):
                t0, d0 = open_fetch.pop(pid, (t, data))
                tev.append({
                    "ph": "X", "cat": "fetch",
                    "name": f"m{d0.get('model', data.get('model', -1))}"
                            f"/{d0.get('fetch_kind', '?')}",
                    "pid": pid, "tid": 1, "ts": t0 * US,
                    "dur": (t - t0) * US,
                    "args": {"outcome": kind.split(".")[1],
                             "bytes": d0.get("bytes", 0.0)},
                })
            elif kind == "net.xfer":
                tev.append({
                    "ph": "X", "cat": "net",
                    "name": f"→w{data.get('dst', -1)}"
                            f"/{data.get('scope', 'flat')}",
                    "pid": pid, "tid": 2, "ts": t * US,
                    "dur": data.get("dur", 0.0) * US,
                    "args": {"bytes": data.get("bytes", 0.0),
                             "scope": data.get("scope", "flat"),
                             "share": data.get("share", 1.0)},
                })
            elif kind in ("churn.crash", "churn.join", "churn.drain",
                          "churn.partition", "churn.heal", "job.arrive",
                          "job.done", "sched.adjust", "sched.place",
                          "task.bounce", "task.dead_letter",
                          "task.recover", "gossip.exchange",
                          "intent.admit", "intent.cancel",
                          "fetch.promote", "health.straggler",
                          "health.queue_buildup", "health.memory_thrash",
                          "health.spine_saturation"):
                tev.append({
                    "ph": "i", "s": "p" if pid < self.n_workers else "g",
                    "cat": kind.split(".")[0], "name": kind,
                    "pid": pid, "tid": 0, "ts": t * US,
                    "args": {k: v for k, v in sorted(data.items())
                             if isinstance(v, (int, float, str, bool))},
                })
        return {
            "schema_version": TRACE_SCHEMA_VERSION,
            "displayTimeUnit": "ms",
            "traceEvents": tev,
        }

    def export_jsonl(self, path: str, compress: bool = False) -> None:
        """Write the JSONL stream to ``path``; with ``compress=True`` the
        stream is gzipped (``mtime=0`` so the archive, like the
        uncompressed stream, is byte-deterministic across reruns — long
        open-loop traces shrink ~10×)."""
        payload = self.to_jsonl().encode("utf-8")
        if compress:
            raw = io.BytesIO()
            with gzip.GzipFile(fileobj=raw, mode="wb", mtime=0) as gz:
                gz.write(payload)
            with open(path, "wb") as f:
                f.write(raw.getvalue())
        else:
            with open(path, "wb") as f:
                f.write(payload)

    def write(self, jsonl_path: Optional[str] = None,
              chrome_path: Optional[str] = None) -> None:
        if jsonl_path:
            self.export_jsonl(jsonl_path)
        if chrome_path:
            with open(chrome_path, "w") as f:
                json.dump(self.to_chrome_trace(), f, indent=1,
                          sort_keys=True)


# --------------------------------------------------------------------------
# Span model: raw events → per-task-attempt spans → latency breakdown
# --------------------------------------------------------------------------
@dataclasses.dataclass
class TaskSpan:
    """One task attempt (generation), stitched from trace events."""

    job_id: int
    task_id: str
    gen: int
    worker: Optional[int] = None
    model: Optional[int] = None
    miss: bool = False
    # src -> (t_send, t_arrive, from_worker); "" is the entry payload.
    inputs: Dict[str, Tuple[float, float, Optional[int]]] = dataclasses.field(
        default_factory=dict
    )
    t_start: Optional[float] = None
    t_done: Optional[float] = None
    model_ready: Optional[float] = None  # fetch.done that unblocked it

    # -- derived components ---------------------------------------------------
    @property
    def t_send(self) -> float:
        """When this attempt's inputs left their producers (max over
        inputs — siblings ship together or the last sender gates)."""
        return max((s for s, _, _ in self.inputs.values()), default=0.0)

    @property
    def t_ready(self) -> float:
        """When the last input landed (dispatch-eligible)."""
        return max((a for _, a, _ in self.inputs.values()), default=0.0)

    @property
    def input_s(self) -> float:
        """Input/output shipping time on this attempt's critical input."""
        return max(0.0, self.t_ready - self.t_send)

    @property
    def fetch_s(self) -> float:
        """Model-fetch wait past input readiness (0 on a hit or when the
        fetch fully overlapped the input transfer)."""
        if self.model_ready is None or self.t_start is None:
            return 0.0
        return max(0.0, min(self.model_ready, self.t_start) - self.t_ready)

    @property
    def queue_s(self) -> float:
        """Dispatch wait after inputs + model were both available."""
        if self.t_start is None:
            return 0.0
        return max(0.0, self.t_start - self.t_ready - self.fetch_s)

    @property
    def compute_s(self) -> float:
        if self.t_start is None or self.t_done is None:
            return 0.0
        return self.t_done - self.t_start

    @property
    def total_s(self) -> float:
        """send → done; telescopes along the critical path."""
        if self.t_done is None:
            return 0.0
        return self.t_done - self.t_send


def build_spans(
    events: Iterable[Tuple[int, float, str, int, Dict[str, Any]]],
) -> Dict[Tuple[int, str, int], TaskSpan]:
    """Stitch raw events into per-(job, task, generation) spans.

    Re-shipments of the same (task, src, generation) overwrite earlier
    ones (the last posted copy is the one that landed — dead-letter
    failover re-ships under the same generation).  ``model_ready`` is
    the last fetch completion on the span's worker for its model at or
    before execution start.
    """
    spans: Dict[Tuple[int, str, int], TaskSpan] = {}
    # (worker, model) -> list of fetch.done times (ascending by seq).
    fetch_done: Dict[Tuple[int, int], List[float]] = {}

    def span(job: int, task: str, gen: int) -> TaskSpan:
        key = (job, task, gen)
        s = spans.get(key)
        if s is None:
            s = spans[key] = TaskSpan(job, task, gen)
        return s

    for seq, t, kind, worker, data in events:
        if kind == "task.input":
            s = span(data["job"], data["task"], data["gen"])
            s.inputs[data["src"]] = (t, data["arrive"], data.get("frm"))
            s.worker = data["to"]
        elif kind == "fetch.done":
            fetch_done.setdefault(
                (worker, data["model"]), []
            ).append(t)
        elif kind == "task.start":
            s = span(data["job"], data["task"], data["gen"])
            s.worker = worker
            s.t_start = t
            s.model = data.get("model")
            s.miss = bool(data.get("miss", False))
            if s.miss and s.model is not None:
                done = fetch_done.get((worker, s.model), ())
                ready = None
                for ft in done:
                    if ft <= t:
                        ready = ft
                s.model_ready = ready
        elif kind == "task.done":
            s = span(data["job"], data["task"], data["gen"])
            s.worker = worker
            s.t_done = t
    return spans


@dataclasses.dataclass
class JobBreakdown:
    """Critical-path latency decomposition of one job.

    ``queue_s + input_transfer_s + output_ship_s + fetch_wait_s +
    compute_s == jct_s`` exactly (telescoping differences; any
    recovery/re-staging stall is folded into ``queue_s``).
    """

    job_id: int
    arrival: float
    finish: float
    critical_path: List[Tuple[str, int]]  # (task_id, gen) exit → entry order
    queue_s: float = 0.0
    input_transfer_s: float = 0.0   # entry payload shipping
    output_ship_s: float = 0.0      # inter-task intermediate transfers
    fetch_wait_s: float = 0.0       # model-fetch wait past input readiness
    compute_s: float = 0.0

    @property
    def jct_s(self) -> float:
        return self.finish - self.arrival

    @property
    def components_sum_s(self) -> float:
        return (
            self.queue_s + self.input_transfer_s + self.output_ship_s
            + self.fetch_wait_s + self.compute_s
        )

    def as_dict(self) -> Dict[str, Any]:
        return {
            "job_id": self.job_id,
            "jct_s": self.jct_s,
            "queue_s": self.queue_s,
            "input_transfer_s": self.input_transfer_s,
            "output_ship_s": self.output_ship_s,
            "fetch_wait_s": self.fetch_wait_s,
            "compute_s": self.compute_s,
            "critical_path": [t for t, _ in self.critical_path],
        }


class SimReport:
    """Post-run analysis API over a traced simulation result.

    Construct from a ``SimResult`` whose ``trace`` is a
    :class:`FlightRecorder` (``Simulation(..., trace=True)``), or pass
    the recorder explicitly.
    """

    def __init__(self, result, recorder: Optional[FlightRecorder] = None):
        self.result = result
        rec = recorder if recorder is not None else getattr(
            result, "trace", None
        )
        if rec is None:
            raise ValueError(
                "SimReport needs a traced run: pass trace=True to the "
                "Simulation (result.trace is None)"
            )
        self.recorder: FlightRecorder = rec
        self._spans: Optional[Dict[Tuple[int, str, int], TaskSpan]] = None
        self._done_times: Optional[Dict[Tuple[int, str],
                                        List[Tuple[float, int]]]] = None

    # -- span access ----------------------------------------------------------
    @property
    def spans(self) -> Dict[Tuple[int, str, int], TaskSpan]:
        if self._spans is None:
            if self.recorder.dropped:
                raise ValueError(
                    f"trace dropped {self.recorder.dropped} events (ring "
                    f"too small for this horizon); raise "
                    f"TraceConfig.ring_capacity"
                )
            self._spans = build_spans(self.recorder.events())
        return self._spans

    def _completions(self) -> Dict[Tuple[int, str], List[Tuple[float, int]]]:
        """(job, task) -> [(t_done, gen)] ascending by completion time."""
        if self._done_times is None:
            out: Dict[Tuple[int, str], List[Tuple[float, int]]] = {}
            for (job, task, gen), s in self.spans.items():
                if s.t_done is not None:
                    out.setdefault((job, task), []).append((s.t_done, gen))
            for v in out.values():
                v.sort()
            self._done_times = out
        return self._done_times

    def final_span(self, job_id: int, task_id: str) -> TaskSpan:
        done = self._completions().get((job_id, task_id))
        if not done:
            raise KeyError(
                f"no completed attempt for job {job_id} task {task_id!r}"
            )
        return self.spans[(job_id, task_id, done[-1][1])]

    # -- critical path + breakdown --------------------------------------------
    def _record(self, job_id: int):
        for r in self.result.records:
            if r.job_id == job_id:
                return r
        raise KeyError(f"job {job_id} has no completion record")

    def critical_path(self, job_id: int) -> List[Tuple[str, int]]:
        """(task, gen) chain from the job's last-finishing task back to
        an entry task, following the time-binding dependency at each
        step: the predecessor whose completion gated this attempt's
        input shipment (exact match on ``t_send``), else the
        latest-arriving input's producer."""
        comps = self._completions()
        tasks = [(t, d) for (j, t), d in comps.items() if j == job_id]
        if not tasks:
            raise KeyError(f"job {job_id} not in trace")
        # Last-finishing attempt overall = the job's finishing task.
        task_id, done = max(tasks, key=lambda td: td[1][-1][0])
        gen = done[-1][1]
        path: List[Tuple[str, int]] = []
        seen = set()
        while True:
            path.append((task_id, gen))
            seen.add((task_id, gen))
            s = self.spans[(job_id, task_id, gen)]
            preds = [src for src in s.inputs if src != ""]
            if not preds:
                return path
            t_send = s.t_send
            nxt: Optional[Tuple[str, int]] = None
            # A predecessor completion exactly at t_send gated the send.
            for p in sorted(preds):
                for t_done, g in comps.get((job_id, p), ()):
                    if abs(t_done - t_send) < 1e-12:
                        nxt = (p, g)
                        break
                if nxt:
                    break
            if nxt is None:
                # Fall back to the latest-arriving input's producer, at
                # the generation that completed at/before the send.
                p = max(
                    sorted(preds), key=lambda p: s.inputs[p][1]
                )
                cand = [
                    (t_done, g)
                    for t_done, g in comps.get((job_id, p), ())
                    if t_done <= t_send + 1e-12
                ]
                pick = cand[-1] if cand else comps[(job_id, p)][-1]
                nxt = (p, pick[1])
            if nxt in seen:  # defensive: malformed trace
                return path
            task_id, gen = nxt

    def latency_breakdown(
        self, job_id: Optional[int] = None
    ) -> Any:
        """Per-job critical-path decomposition; with no ``job_id``, the
        aggregate over every completed job (component sums + shares)."""
        if job_id is None:
            return self._aggregate_breakdown()
        rec = self._record(job_id)
        path = self.critical_path(job_id)
        bd = JobBreakdown(
            job_id=job_id, arrival=rec.arrival, finish=rec.finish,
            critical_path=path,
        )
        for i, (task_id, gen) in enumerate(path):
            s = self.spans[(job_id, task_id, gen)]
            bd.compute_s += s.compute_s
            bd.fetch_wait_s += s.fetch_s
            bd.queue_s += s.queue_s
            entry = "" in s.inputs and i == len(path) - 1
            if entry:
                bd.input_transfer_s += s.input_s
                # Any gap between job arrival and the entry shipment
                # (client retry, recovery re-staging) is queueing.
                bd.queue_s += max(0.0, s.t_send - rec.arrival)
            else:
                bd.output_ship_s += s.input_s
                # Gap between the gating predecessor's completion and
                # this attempt's shipment (recovery stalls) is queueing.
                nxt = self.spans[(job_id,) + path[i + 1]]
                if nxt.t_done is not None:
                    bd.queue_s += max(0.0, s.t_send - nxt.t_done)
        return bd

    def _aggregate_breakdown(self) -> Dict[str, Any]:
        parts = ["queue_s", "input_transfer_s", "output_ship_s",
                 "fetch_wait_s", "compute_s"]
        agg = {p: 0.0 for p in parts}
        jct = 0.0
        n = 0
        for r in self.result.records:
            bd = self.latency_breakdown(r.job_id)
            for p in parts:
                agg[p] += getattr(bd, p)
            jct += bd.jct_s
            n += 1
        out: Dict[str, Any] = {"jobs": n, "jct_s": jct}
        out.update(agg)
        if jct > 0:
            out["shares"] = {p: agg[p] / jct for p in parts}
        return out

    # -- provenance -----------------------------------------------------------
    def explain(self, task_id: str, job_id: Optional[int] = None) -> str:
        """Human-readable account of every placement decision made for
        the task, each with its per-candidate Eq. 2 cost vector, plus
        the task's measured latency breakdown."""
        if job_id is None:
            jobs = sorted(
                j for (j, t) in self._placement_keys() if t == task_id
            )
            if not jobs:
                # No provenance (hash/heft read no state) — fall back to
                # any job with a completed span for the task.
                jobs = sorted(
                    j for (j, t) in self._completions() if t == task_id
                )
            if not jobs:
                raise KeyError(
                    f"no decisions or spans recorded for task {task_id!r}"
                )
            job_id = jobs[0]
        decisions = self.recorder.decisions(job_id, task_id)
        blocks = [d.explain() for d in decisions]
        try:
            s = self.final_span(job_id, task_id)
            blocks.append(
                f"measured: worker=w{s.worker} input={s.input_s:.4f}s "
                f"fetch={s.fetch_s:.4f}s queue={s.queue_s:.4f}s "
                f"compute={s.compute_s:.4f}s "
                f"(miss={s.miss}, gen={s.gen})"
            )
        except KeyError:
            pass
        if not blocks:
            blocks.append(
                f"no decisions or spans for job {job_id} task {task_id!r} "
                f"(hash/heft record no provenance: their placement reads "
                f"no state)"
            )
        return "\n".join(blocks)

    def _placement_keys(self):
        return self.recorder._placement_index.keys()

    # -- health plane (core/healthplane.py) -----------------------------------
    def health_summary(self) -> Dict[str, Any]:
        """Deterministic health report for the run (windowed series
        aggregates, fleet latency sketches, detector ledger); requires
        ``Simulation(..., health=True)``."""
        health = getattr(self.result, "health", None)
        if health is None:
            raise ValueError(
                "SimReport.health_summary needs a health-monitored run: "
                "pass health=True to the engine (result.health is None)"
            )
        return health.summary()

    def calibration(self):
        """Eq. 2 cost-model calibration: per-component residuals of this
        run's placement provenance against its measured spans (see
        ``core.healthplane.calibrate``)."""
        from repro.core.healthplane import calibrate

        return calibrate(self)


# --------------------------------------------------------------------------
# Minimal JSON-Schema validator (dependency-free)
# --------------------------------------------------------------------------
def validate_schema(obj: Any, schema: Mapping[str, Any], path: str = "$"):
    """Validate ``obj`` against the subset of JSON Schema the checked-in
    schemas use (type, required, properties, items, enum, minimum,
    additionalProperties: false).  Raises ``ValueError`` naming the
    offending path.  Dependency-free so CI and the container need no
    ``jsonschema`` install."""
    types = {
        "object": dict, "array": list, "string": str, "boolean": bool,
        "null": type(None),
    }
    t = schema.get("type")
    if t is not None:
        ts = t if isinstance(t, list) else [t]
        ok = False
        for name in ts:
            if name == "number":
                ok = ok or (isinstance(obj, (int, float))
                            and not isinstance(obj, bool))
            elif name == "integer":
                ok = ok or (isinstance(obj, int)
                            and not isinstance(obj, bool))
            else:
                ok = ok or isinstance(obj, types[name])
        if not ok:
            raise ValueError(
                f"{path}: expected {t}, got {type(obj).__name__}"
            )
    if "enum" in schema and obj not in schema["enum"]:
        raise ValueError(f"{path}: {obj!r} not in {schema['enum']}")
    if "minimum" in schema and isinstance(obj, (int, float)) \
            and not isinstance(obj, bool) and obj < schema["minimum"]:
        raise ValueError(f"{path}: {obj} < minimum {schema['minimum']}")
    if "const" in schema and obj != schema["const"]:
        raise ValueError(f"{path}: {obj!r} != const {schema['const']!r}")
    if isinstance(obj, dict):
        for req in schema.get("required", ()):
            if req not in obj:
                raise ValueError(f"{path}: missing required key {req!r}")
        props = schema.get("properties", {})
        for k, v in obj.items():
            if k in props:
                validate_schema(v, props[k], f"{path}.{k}")
            elif schema.get("additionalProperties") is False:
                raise ValueError(f"{path}: unexpected key {k!r}")
    if isinstance(obj, list) and "items" in schema:
        for i, v in enumerate(obj):
            validate_schema(v, schema["items"], f"{path}[{i}]")

"""GPU Memory Manager (§3.3, §5.3).

Manages the *Navigator cache*: ML model objects resident in GPU memory.
Fetching a model costs ``TD_model(m, w) = |m|/PCIe_bw + delta_PCIe`` (§4.1).
Two eviction policies are implemented exactly as described:

* **FIFO** (§5.3.1): evict non-in-use models in insertion order until the
  new model fits.
* **Queue-lookahead** (§5.3.2): inspect a fixed number of upcoming tasks on
  the worker's execution queue; models needed sooner get higher retention
  priority; models not needed in the window are evicted first (FIFO order
  among equals).

Models pinned by currently-executing tasks are never evicted.
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Dict, Iterable, List, Mapping, Optional, Sequence, Tuple

from repro.core import bitmaps
from repro.core.netmodel import AcceleratorLink
from repro.core.types import MLModel


@dataclasses.dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    evictions: int = 0
    bytes_fetched: float = 0.0

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 1.0


class GpuMemoryManager:
    """Per-worker model cache with scheduler-triggered management.

    The worker makes local fetch/evict decisions based on its assigned
    tasks (§3.3); the scheduler influences placement globally through the
    published cache bitmap.
    """

    FIFO = "fifo"
    LOOKAHEAD = "lookahead"

    def __init__(
        self,
        capacity_bytes: float,
        models: Mapping[int, MLModel],
        link: AcceleratorLink,
        policy: str = LOOKAHEAD,
        lookahead_depth: int = 8,
        compression_ratio: float = 0.6,
    ) -> None:
        if policy not in (self.FIFO, self.LOOKAHEAD):
            raise ValueError(f"unknown eviction policy {policy!r}")
        self.capacity_bytes = capacity_bytes
        self.models = dict(models)
        self.link = link
        self.policy = policy
        self.lookahead_depth = lookahead_depth
        # The Navigator cache holds models in *compressed* form; execution
        # memory holds a decompressed instance per currently-active task
        # (§3.3).  ``compression_ratio`` is compressed/decompressed bytes.
        self.compression_ratio = compression_ratio
        # Insertion-ordered contents: model_id -> cached (compressed) size.
        self._contents: "collections.OrderedDict[int, float]" = collections.OrderedDict()
        self._pinned: Dict[int, int] = {}  # model_id -> pin count
        # Decompressed execution-memory reservations: model_id -> count.
        self._executing: Dict[int, int] = {}
        self.stats = CacheStats()

    def cached_size(self, model_id: int) -> float:
        return self.models[model_id].size_bytes * self.compression_ratio

    # -- inspection ----------------------------------------------------------
    def has(self, model_id: int) -> bool:
        return model_id in self._contents

    @property
    def used_bytes(self) -> float:
        return sum(self._contents.values())

    @property
    def exec_reserved_bytes(self) -> float:
        """Execution memory: one decompressed instance per active task."""
        return sum(
            self.models[m].size_bytes * n for m, n in self._executing.items()
        )

    @property
    def free_bytes(self) -> float:
        """AVC(w) (§4.1): capacity minus cache minus execution memory."""
        return self.capacity_bytes - self.used_bytes - self.exec_reserved_bytes

    @property
    def bitmap(self) -> int:
        return bitmaps.pack(self._contents.keys())

    def resident_models(self) -> List[int]:
        return list(self._contents.keys())

    # -- pinning (models of running tasks are not evictable) -----------------
    def pin(self, model_id: int) -> None:
        self._pinned[model_id] = self._pinned.get(model_id, 0) + 1

    def unpin(self, model_id: int) -> None:
        n = self._pinned.get(model_id, 0) - 1
        if n <= 0:
            self._pinned.pop(model_id, None)
        else:
            self._pinned[model_id] = n

    def _evictable(self) -> List[int]:
        return [m for m in self._contents if m not in self._pinned]

    # -- eviction ------------------------------------------------------------
    def _eviction_order(self, upcoming_model_ids: Sequence[int]) -> List[int]:
        """Victims, most-evictable first."""
        candidates = self._evictable()
        if self.policy == self.FIFO:
            return candidates  # already insertion ordered
        # Queue-lookahead: next-use position within the lookahead window;
        # models not needed in the window sort first (use position = inf),
        # then by *latest* next use; FIFO breaks ties.
        window = list(upcoming_model_ids)[: self.lookahead_depth]
        next_use: Dict[int, int] = {}
        for pos, mid in enumerate(window):
            if mid is not None and mid not in next_use:
                next_use[mid] = pos
        fifo_pos = {mid: i for i, mid in enumerate(self._contents)}
        return sorted(
            candidates,
            key=lambda m: (-next_use.get(m, 10**9), fifo_pos[m]),
        )

    def would_evict(
        self, model_id: int, upcoming_model_ids: Sequence[int] = ()
    ) -> List[int]:
        """Which models eviction for ``model_id`` would remove (no mutation)."""
        size = self.cached_size(model_id)
        if self.has(model_id) or size <= self.free_bytes:
            return []
        victims: List[int] = []
        freed = self.free_bytes
        for victim in self._eviction_order(upcoming_model_ids):
            if freed >= size:
                break
            victims.append(victim)
            freed += self._contents[victim]
        if freed < size:
            return []  # cannot free enough right now (pins)
        return victims

    # -- fetch ---------------------------------------------------------------
    def fetch_seconds(self, model_id: int) -> float:
        """TD_model(m, w) for a cache miss."""
        return self.link.fetch_time(self.models[model_id].size_bytes)

    def ensure(
        self,
        model_id: int,
        upcoming_model_ids: Sequence[int] = (),
    ) -> Optional[Tuple[float, List[int]]]:
        """Make ``model_id`` resident.

        Returns ``(fetch_seconds, evicted_ids)``; ``fetch_seconds == 0.0``
        on a cache hit.  Returns ``None`` if the model cannot currently be
        made resident (pinned working set too large) — the task dispatcher
        then leaves the task on the queue and proceeds (§3.2).
        """
        if model_id not in self.models:
            raise KeyError(f"unknown model id {model_id}")
        if self.has(model_id):
            self.stats.hits += 1
            # refresh nothing: FIFO order is by insertion, not use (§5.3.1)
            return 0.0, []
        size = self.cached_size(model_id)
        if size + self.models[model_id].size_bytes > self.capacity_bytes:
            raise ValueError(
                f"model {model_id} cached+decompressed footprint exceeds GPU capacity"
            )
        victims = self.would_evict(model_id, upcoming_model_ids)
        if size > self.free_bytes and not victims:
            return None
        for v in victims:
            del self._contents[v]
            self.stats.evictions += 1
        self._contents[model_id] = size
        self.stats.misses += 1
        self.stats.bytes_fetched += size
        return self.fetch_seconds(model_id), victims

    # -- execution memory (§3.3) ----------------------------------------------
    def begin_execution(
        self, model_id: int, upcoming_model_ids: Sequence[int] = ()
    ) -> None:
        """Reserve execution memory for a decompressed instance of
        ``model_id``; evicts cached models (per policy) to make headroom.
        Pinned models are never evicted — if the pinned working set forces
        an overcommit we allow it (the real system stalls/uses host paging;
        this is rare and self-corrects when tasks finish)."""
        self._executing[model_id] = self._executing.get(model_id, 0) + 1
        self.pin(model_id)
        if self.free_bytes >= 0:
            return
        for victim in self._eviction_order(upcoming_model_ids):
            if self.free_bytes >= 0:
                break
            del self._contents[victim]
            self.stats.evictions += 1

    def end_execution(self, model_id: int) -> None:
        n = self._executing.get(model_id, 0) - 1
        if n <= 0:
            self._executing.pop(model_id, None)
        else:
            self._executing[model_id] = n
        self.unpin(model_id)

    def drop(self, model_id: int) -> None:
        self._contents.pop(model_id, None)

    def preload(self, model_ids: Iterable[int]) -> None:
        """Warm the cache without counting stats (test/benchmark setup)."""
        for mid in model_ids:
            size = self.cached_size(mid)
            if size > self.free_bytes:
                raise ValueError("preload exceeds capacity")
            self._contents[mid] = size

"""GPU Memory Manager (§3.3, §5.3).

Manages the *Navigator cache*: ML model objects resident in GPU memory.
Fetching a model costs ``TD_model(m, w) = |m|/PCIe_bw + delta_PCIe`` (§4.1).
Two eviction policies are implemented exactly as described:

* **FIFO** (§5.3.1): evict non-in-use models in insertion order until the
  new model fits.
* **Queue-lookahead** (§5.3.2): inspect a fixed number of upcoming tasks on
  the worker's execution queue; models needed sooner get higher retention
  priority; models not needed in the window are evicted first (FIFO order
  among equals).

Models pinned by currently-executing tasks are never evicted.
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Dict, Iterable, List, Mapping, Optional, Sequence, Tuple

from repro.core import bitmaps
from repro.core.netmodel import AcceleratorLink
from repro.core.types import MLModel


@dataclasses.dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    evictions: int = 0
    bytes_fetched: float = 0.0
    # Speculative (plan-driven) prefetch accounting.  ``bytes_fetched``
    # stays the total PCIe traffic (demand + prefetch); the fields below
    # split out the speculative share and its outcome.
    prefetch_fetches: int = 0
    prefetch_bytes: float = 0.0
    prefetch_useful: int = 0        # prefetched model later demanded
    prefetch_aborted: int = 0       # preempted/cancelled mid-flight
    prefetch_wasted: int = 0        # never demanded before leaving cache
    prefetch_wasted_bytes: float = 0.0
    # Fleet-churn accounting: PCIe bytes thrown away because the worker
    # died/drained mid-transfer or with speculative contents nobody used.
    churn_wasted_bytes: float = 0.0
    churn_resets: int = 0

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 1.0


class GpuMemoryManager:
    """Per-worker model cache with scheduler-triggered management.

    The worker makes local fetch/evict decisions based on its assigned
    tasks (§3.3); the scheduler influences placement globally through the
    published cache bitmap.
    """

    FIFO = "fifo"
    LOOKAHEAD = "lookahead"

    def __init__(
        self,
        capacity_bytes: float,
        models: Mapping[int, MLModel],
        link: AcceleratorLink,
        policy: str = LOOKAHEAD,
        lookahead_depth: int = 8,
        compression_ratio: float = 0.6,
    ) -> None:
        if policy not in (self.FIFO, self.LOOKAHEAD):
            raise ValueError(f"unknown eviction policy {policy!r}")
        self.capacity_bytes = capacity_bytes
        self.models = dict(models)
        self.link = link
        self.policy = policy
        self.lookahead_depth = lookahead_depth
        # The Navigator cache holds models in *compressed* form; execution
        # memory holds a decompressed instance per currently-active task
        # (§3.3).  ``compression_ratio`` is compressed/decompressed bytes.
        self.compression_ratio = compression_ratio
        # Insertion-ordered contents: model_id -> cached (compressed) size.
        self._contents: "collections.OrderedDict[int, float]" = collections.OrderedDict()
        self._pinned: Dict[int, int] = {}  # model_id -> pin count
        # Decompressed execution-memory reservations: model_id -> count.
        self._executing: Dict[int, int] = {}
        # Models brought in speculatively and not yet demanded; leaving
        # the cache while in this set counts as wasted prefetch.
        self._prefetched_unused: set = set()
        self.stats = CacheStats()

    def cached_size(self, model_id: int) -> float:
        return self.models[model_id].size_bytes * self.compression_ratio

    # -- inspection ----------------------------------------------------------
    def has(self, model_id: int) -> bool:
        return model_id in self._contents

    def can_host(self, model_id: int) -> bool:
        """Whether this GPU can *ever* execute the model: one compressed
        cache copy plus one decompressed execution instance must fit."""
        return (
            self.cached_size(model_id) + self.models[model_id].size_bytes
            <= self.capacity_bytes
        )

    @property
    def used_bytes(self) -> float:
        return sum(self._contents.values())

    @property
    def exec_reserved_bytes(self) -> float:
        """Execution memory: one decompressed instance per active task."""
        return sum(
            self.models[m].size_bytes * n for m, n in self._executing.items()
        )

    @property
    def free_bytes(self) -> float:
        """AVC(w) (§4.1): capacity minus cache minus execution memory."""
        return self.capacity_bytes - self.used_bytes - self.exec_reserved_bytes

    @property
    def available_bytes(self) -> float:
        """AVC(w) as *advertised* under the prefetch plane: speculative
        contents nobody has demanded yet are the cheapest victims, so the
        space they occupy is still 'available' to the placement cost —
        otherwise speculation would make workers look full and repel the
        very tasks it prefetched for."""
        return self.free_bytes + self.unused_prefetched_bytes()

    @property
    def bitmap(self) -> int:
        return bitmaps.pack(self._contents.keys())

    def resident_models(self) -> List[int]:
        return list(self._contents.keys())

    # -- pinning (models of running tasks are not evictable) -----------------
    def pin(self, model_id: int) -> None:
        self._pinned[model_id] = self._pinned.get(model_id, 0) + 1

    def unpin(self, model_id: int) -> None:
        n = self._pinned.get(model_id, 0) - 1
        if n <= 0:
            self._pinned.pop(model_id, None)
        else:
            self._pinned[model_id] = n

    def _evictable(self) -> List[int]:
        return [m for m in self._contents if m not in self._pinned]

    # -- prefetch bookkeeping -------------------------------------------------
    def _note_demand_use(self, model_id: int) -> None:
        """First demand touch of a speculatively fetched model."""
        if model_id in self._prefetched_unused:
            self._prefetched_unused.discard(model_id)
            self.stats.prefetch_useful += 1

    def _note_departure(self, model_id: int, bytes_lost: float) -> None:
        """A model left the cache; if it was prefetched and never
        demanded, its transfer was wasted."""
        if model_id in self._prefetched_unused:
            self._prefetched_unused.discard(model_id)
            self.stats.prefetch_wasted += 1
            self.stats.prefetch_wasted_bytes += bytes_lost

    def _evict(self, model_id: int) -> None:
        size = self._contents.pop(model_id)
        self.stats.evictions += 1
        self._note_departure(model_id, size)

    def unused_prefetched_bytes(self) -> float:
        """Resident bytes brought in speculatively and never demanded so
        far (end-of-run residual waste, reported by the benchmarks)."""
        return sum(
            self._contents[m]
            for m in self._prefetched_unused
            if m in self._contents
        )

    # -- eviction ------------------------------------------------------------
    def _eviction_order(self, upcoming_model_ids: Sequence[int]) -> List[int]:
        """Victims, most-evictable first."""
        candidates = self._evictable()
        if self.policy == self.FIFO:
            return candidates  # already insertion ordered
        # Queue-lookahead: next-use position within the lookahead window;
        # models not needed in the window sort first (use position = inf),
        # then by *latest* next use; FIFO breaks ties.  Speculative
        # contents nobody demanded yet are the cheapest victims of all —
        # evicting them merely un-speculates.
        window = list(upcoming_model_ids)[: self.lookahead_depth]
        next_use: Dict[int, int] = {}
        for pos, mid in enumerate(window):
            if mid is not None and mid not in next_use:
                next_use[mid] = pos
        fifo_pos = {mid: i for i, mid in enumerate(self._contents)}
        return sorted(
            candidates,
            key=lambda m: (
                m not in self._prefetched_unused or m in next_use,
                -next_use.get(m, 10**9),
                fifo_pos[m],
            ),
        )

    def would_evict(
        self, model_id: int, upcoming_model_ids: Sequence[int] = ()
    ) -> List[int]:
        """Which models eviction for ``model_id`` would remove (no mutation)."""
        size = self.cached_size(model_id)
        if self.has(model_id) or size <= self.free_bytes:
            return []
        victims: List[int] = []
        freed = self.free_bytes
        for victim in self._eviction_order(upcoming_model_ids):
            if freed >= size:
                break
            victims.append(victim)
            freed += self._contents[victim]
        if freed < size:
            return []  # cannot free enough right now (pins)
        return victims

    # -- fetch ---------------------------------------------------------------
    def fetch_seconds(self, model_id: int) -> float:
        """TD_model(m, w) for a cache miss."""
        return self.link.fetch_time(self.models[model_id].size_bytes)

    def ensure(
        self,
        model_id: int,
        upcoming_model_ids: Sequence[int] = (),
    ) -> Optional[Tuple[float, List[int]]]:
        """Make ``model_id`` resident.

        Returns ``(fetch_seconds, evicted_ids)``; ``fetch_seconds == 0.0``
        on a cache hit.  Returns ``None`` if the model cannot currently be
        made resident (pinned working set too large) — the task dispatcher
        then leaves the task on the queue and proceeds (§3.2).
        """
        if model_id not in self.models:
            raise KeyError(f"unknown model id {model_id}")
        if self.has(model_id):
            self.stats.hits += 1
            self._note_demand_use(model_id)
            # refresh nothing: FIFO order is by insertion, not use (§5.3.1)
            return 0.0, []
        size = self.cached_size(model_id)
        if size + self.models[model_id].size_bytes > self.capacity_bytes:
            raise ValueError(
                f"model {model_id} cached+decompressed footprint exceeds GPU capacity"
            )
        victims = self.would_evict(model_id, upcoming_model_ids)
        if size > self.free_bytes and not victims:
            return None
        for v in victims:
            self._evict(v)
        self._contents[model_id] = size
        self.stats.misses += 1
        self.stats.bytes_fetched += size
        return self.fetch_seconds(model_id), victims

    # -- speculative fetch (predictive prefetch plane) ------------------------
    def begin_prefetch(
        self,
        model_id: int,
        upcoming_model_ids: Sequence[int] = (),
        allow_evict: bool = False,
    ) -> Optional[Tuple[float, List[int]]]:
        """Start a speculative fetch of ``model_id`` on the fetch pipe.

        Like :meth:`ensure` but with speculative accounting (no demand
        miss is charged) and a fetch-pin held until
        :meth:`complete_prefetch` / :meth:`abort_prefetch` — an in-flight
        speculative model is never an eviction victim.  With
        ``allow_evict=False`` (the default) the fetch only proceeds into
        free memory: speculation must not displace resident models.
        Returns ``None`` when the model is already resident or cannot be
        staged right now.
        """
        if model_id not in self.models:
            raise KeyError(f"unknown model id {model_id}")
        if self.has(model_id):
            return None
        size = self.cached_size(model_id)
        if size + self.models[model_id].size_bytes > self.capacity_bytes:
            return None
        victims: List[int] = []
        if size > self.free_bytes:
            if not allow_evict:
                return None
            victims = self.would_evict(model_id, upcoming_model_ids)
            if not victims:
                return None
        for v in victims:
            self._evict(v)
        self._contents[model_id] = size
        self._prefetched_unused.add(model_id)
        self.pin(model_id)  # fetch-pin for the transfer duration
        self.stats.prefetch_fetches += 1
        self.stats.prefetch_bytes += size
        self.stats.bytes_fetched += size
        return self.fetch_seconds(model_id), victims

    def complete_prefetch(self, model_id: int) -> None:
        """The speculative transfer finished: release the fetch-pin (the
        model stays resident, evictable per policy)."""
        self.unpin(model_id)

    def abort_prefetch(self, model_id: int, fraction_done: float = 0.0) -> None:
        """A demand fetch preempted (or a cancellation killed) the
        speculative transfer.  The partial bytes moved so far are wasted;
        the un-transferred remainder never hit the pipe."""
        self.unpin(model_id)
        size = self._contents.pop(model_id, None)
        if size is None:
            return
        frac = min(1.0, max(0.0, fraction_done))
        undone = size * (1.0 - frac)
        self.stats.bytes_fetched -= undone
        self.stats.prefetch_bytes -= undone
        self.stats.prefetch_aborted += 1
        self._prefetched_unused.discard(model_id)
        self.stats.prefetch_wasted += 1
        self.stats.prefetch_wasted_bytes += size * frac

    def abort_fetch(self, model_id: int, fraction_done: float = 0.0) -> None:
        """Tear down an in-flight *demand* fetch whose owning task died or
        was re-routed off this worker (crash/drain): release the
        fetch-pin, drop the partial model, and account the bytes moved so
        far as churn waste (the un-transferred remainder never hit the
        pipe, so it comes back off ``bytes_fetched``)."""
        self.unpin(model_id)
        size = self._contents.pop(model_id, None)
        if size is None:
            return
        frac = min(1.0, max(0.0, fraction_done))
        self.stats.bytes_fetched -= size * (1.0 - frac)
        self.stats.churn_wasted_bytes += size * frac

    def reset(self, graceful: bool = False) -> float:
        """The worker left the fleet: every resident model, pin, and
        execution reservation is gone.  Speculative contents nobody
        demanded count as wasted prefetch; on a crash (``graceful=False``)
        the lost residency is also churn waste (a drain served its cache
        until the end, so only the unused speculation is charged).
        Returns the resident bytes dropped."""
        lost = self.used_bytes
        for mid in list(self._prefetched_unused):
            size = self._contents.get(mid, 0.0)
            self.stats.prefetch_wasted += 1
            self.stats.prefetch_wasted_bytes += size
            self.stats.churn_wasted_bytes += size
        if not graceful:
            self.stats.churn_wasted_bytes += lost - self.unused_prefetched_bytes()
        self._contents.clear()
        self._pinned.clear()
        self._executing.clear()
        self._prefetched_unused.clear()
        self.stats.churn_resets += 1
        return lost

    # -- execution memory (§3.3) ----------------------------------------------
    def begin_execution(
        self, model_id: int, upcoming_model_ids: Sequence[int] = ()
    ) -> None:
        """Reserve execution memory for a decompressed instance of
        ``model_id``; evicts cached models (per policy) to make headroom.
        Pinned models are never evicted — if the pinned working set forces
        an overcommit we allow it (the real system stalls/uses host paging;
        this is rare and self-corrects when tasks finish)."""
        self._executing[model_id] = self._executing.get(model_id, 0) + 1
        self.pin(model_id)
        self._note_demand_use(model_id)
        if self.free_bytes >= 0:
            return
        for victim in self._eviction_order(upcoming_model_ids):
            if self.free_bytes >= 0:
                break
            self._evict(victim)

    def end_execution(self, model_id: int) -> None:
        n = self._executing.get(model_id, 0) - 1
        if n <= 0:
            self._executing.pop(model_id, None)
        else:
            self._executing[model_id] = n
        self.unpin(model_id)

    def drop(self, model_id: int) -> None:
        size = self._contents.pop(model_id, None)
        if size is not None:
            self._note_departure(model_id, size)

    def preload(self, model_ids: Iterable[int]) -> None:
        """Warm the cache without counting stats (test/benchmark setup)."""
        for mid in model_ids:
            size = self.cached_size(mid)
            if size > self.free_bytes:
                raise ValueError("preload exceeds capacity")
            self._contents[mid] = size

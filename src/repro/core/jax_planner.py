"""JAX-vectorized Navigator planning (Alg. 1).

The Python planner loops over workers per task; here the worker dimension
is a jnp vector, so one jit-compiled call plans a whole job instance —
the per-task argmin (Alg. 1 lines 7–11) becomes a vector min over W.
Task order (upward ranks) is static per DFG, so the task loop unrolls at
trace time; W can be hundreds (the Fig. 10 regime) at negligible cost.

Equivalence with the reference Python planner is property-tested in
``tests/test_jax_planner.py`` — this is the planner the real serving
engine uses, and it lowers/shards cleanly (each worker can plan with its
replica of the SST under ``shard_map``; see ``core/sst_exchange.py``).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.packed import PackedViews
from repro.core.profiles import ProfileRepository
from repro.core.scheduler import NavigatorConfig
from repro.core.state import DEAD, SUSPECT
from repro.core.types import ADFG, DFG, Job

# (64,) shift vector for the vectorized bitmap → (W, 64) bool unpack.
_BIT_SHIFTS = np.arange(64, dtype=np.uint64)


@dataclasses.dataclass(frozen=True, eq=False)  # identity hash: cached per DFG
class StaticPlanInputs:
    """Per-DFG static arrays (built once per DFG, cached)."""

    order: Tuple[str, ...]                 # tasks in rank order
    runtimes: np.ndarray                   # (T,) base R(t)
    model_ids: np.ndarray                  # (T,) int, -1 = no model
    fetch_times: np.ndarray                # (T,) TD_model on miss
    cached_sizes: np.ndarray               # (T,) compressed model bytes
    full_sizes: np.ndarray                 # (T,) decompressed model bytes
    td_outputs: np.ndarray                 # (T,) TD_output(t)
    td_inputs: np.ndarray                  # (T,) TD_input(t) (entry tasks)
    output_bytes: np.ndarray               # (T,) raw output sizes (topology)
    input_bytes: np.ndarray                # (T,) raw input sizes (topology)
    preds: Tuple[Tuple[int, ...], ...]     # indices into `order`
    is_entry: np.ndarray                   # (T,) bool


def build_static_inputs(
    profiles: ProfileRepository, dfg: DFG
) -> StaticPlanInputs:
    order = tuple(profiles.rank_order(dfg))
    pos = {t: i for i, t in enumerate(order)}
    t_arr = [dfg.tasks[t] for t in order]
    preds = tuple(
        tuple(pos[p] for p in dfg.preds[t]) for t in order
    )
    return StaticPlanInputs(
        order=order,
        runtimes=np.array([t.runtime_s for t in t_arr], np.float32),
        model_ids=np.array(
            [-1 if t.model_id is None else t.model_id for t in t_arr], np.int32
        ),
        fetch_times=np.array(
            [profiles.td_model(t.model_id) for t in t_arr], np.float32
        ),
        cached_sizes=np.array(
            [profiles.cached_model_size(t.model_id) for t in t_arr], np.float32
        ),
        full_sizes=np.array(
            [profiles.model_size(t.model_id) for t in t_arr], np.float32
        ),
        td_outputs=np.array(
            [profiles.td_output(t) for t in t_arr], np.float32
        ),
        td_inputs=np.array(
            [profiles.td_input(t) for t in t_arr], np.float32
        ),
        output_bytes=np.array(
            [t.output_bytes for t in t_arr], np.float32
        ),
        input_bytes=np.array(
            [t.input_bytes for t in t_arr], np.float32
        ),
        preds=preds,
        is_entry=np.array([not p for p in preds], bool),
    )


@functools.partial(
    jax.jit,
    static_argnames=(
        "static", "config", "n_workers", "worker_speed",
        "return_components",
    ),
)
def plan_vectorized(
    static: StaticPlanInputs,
    config: NavigatorConfig,
    n_workers: int,
    ft0: jax.Array,          # (W,) worker_FT_map (absolute seconds)
    cache_bits: jax.Array,   # (W, 64) bool — SST cache bitmaps
    avc0: jax.Array,         # (W,) free cache bytes
    now: jax.Array,          # scalar
    origin_worker: jax.Array,  # scalar int
    worker_speed: Optional[Tuple[float, ...]] = None,
    intent_bits: Optional[jax.Array] = None,   # (W, 64) bool — intent bitmaps
    intent_fresh: Optional[jax.Array] = None,  # (W,) bool — row fresh enough
    gpu_capacity: Optional[jax.Array] = None,  # (W,) bytes; None = unbounded
    liveness_cost: Optional[jax.Array] = None,  # (W,) s; membership lane:
    # 0 = ALIVE, suspect_penalty_s = SUSPECT, +inf = DEAD/draining
    xfer_inv_bw: Optional[jax.Array] = None,   # (W, W) path 1/bandwidth;
    # None = flat all-pairs table (static.td_inputs / td_outputs)
    xfer_delta: Optional[jax.Array] = None,    # (W, W) path latency
    fetch_model: Optional[jax.Array] = None,   # (W,) in-flight fetch model
    # id per worker (−1 = none) — expected-completion intent lane
    fetch_eta: Optional[jax.Array] = None,     # (W,) absolute fetch ETA
    return_components: bool = False,  # also return stacked (T, W) Eq. 2
    # component arrays (queue, at, td_model, intent discount, runtime,
    # selection cost) for placement provenance — tracing-on path only
) -> Tuple[jax.Array, ...]:
    """Returns (assignment (T,) int32, planned_ft (T,) float32[, components])."""
    t_count = len(static.order)
    speed = (
        jnp.ones((n_workers,), jnp.float32)
        if worker_speed is None
        else jnp.asarray(worker_speed, jnp.float32)
    )
    ft = jnp.maximum(ft0, now)
    bits = cache_bits
    use_intents = intent_bits is not None and config.intent_confidence > 0.0
    if intent_bits is None:
        intent_bits = jnp.zeros_like(cache_bits)
    if intent_fresh is None:
        intent_fresh = jnp.zeros((n_workers,), bool)
    avc = avc0
    assign = []
    task_ft = []
    comps: List[Tuple[jax.Array, ...]] = []
    mean_speed_inv = jnp.mean(1.0 / speed)

    for ti in range(t_count):
        r_w = static.runtimes[ti] / speed                     # R(t, w)
        mid = int(static.model_ids[ti])
        # AT_allInputs (Eq. 3-4).
        if static.is_entry[ti]:
            if xfer_inv_bw is None:
                ship = static.td_inputs[ti]
            else:
                # Path cost of the client input: origin → each worker.
                ship = (
                    static.input_bytes[ti] * xfer_inv_bw[origin_worker]
                    + xfer_delta[origin_worker]
                )
            at = now + jnp.where(
                jnp.arange(n_workers) == origin_worker, 0.0, ship
            )
        else:
            at = jnp.zeros((n_workers,), jnp.float32)
            for pi in static.preds[ti]:
                ft_p = task_ft[pi]
                w_p = assign[pi]
                if xfer_inv_bw is None:
                    ship = static.td_outputs[pi]
                else:
                    ship = (
                        static.output_bytes[pi] * xfer_inv_bw[w_p]
                        + xfer_delta[w_p]
                    )
                arrival = ft_p + jnp.where(
                    jnp.arange(n_workers) == w_p, 0.0, ship
                )
                at = jnp.maximum(at, arrival)
        x = jnp.maximum(ft, at)                               # line 8
        hit = jnp.zeros((n_workers,), bool)
        intent_m = jnp.zeros((n_workers,), bool)
        undiscounted = None
        if mid < 0 or not config.use_model_locality:
            td_model = (
                jnp.zeros((n_workers,), jnp.float32)
                if mid < 0
                else jnp.full((n_workers,), static.fetch_times[ti])
            )
        else:
            hit = bits[:, mid]
            intent_m = intent_bits[:, mid] & intent_fresh
            fits = static.cached_sizes[ti] <= avc
            # Eq. 2 third case: mean refetch cost of resident models.
            if config.eviction_penalty_s is not None:
                penalty = config.eviction_penalty_s
            else:
                # approximate resident-model refetch with this model's own
                # fetch time (vector-friendly surrogate; exact per-worker
                # catalogue means are maintained by the Python planner).
                penalty = static.fetch_times[ti]
            miss_cost = static.fetch_times[ti] + jnp.where(fits, 0.0, penalty)
            undiscounted = miss_cost
            if use_intents:
                # Prefetch plane: intended models cost the undiscounted
                # remainder of the fetch (core/prefetch.py).
                miss_cost = jnp.where(
                    intent_m,
                    static.fetch_times[ti] * (1.0 - config.intent_confidence),
                    miss_cost,
                )
                if fetch_model is not None and fetch_eta is not None:
                    # Expected-completion lane: when the advertised
                    # *in-flight* fetch is this model, price the true
                    # remaining overlap past the task's earliest start —
                    # a nearly-done fetch costs ≈ 0, a just-started one
                    # ≈ the full fetch (mirrors Scheduler._td_model).
                    inflight = intent_m & (fetch_model == mid) & (fetch_eta > 0.0)
                    remaining = jnp.clip(
                        fetch_eta - x, 0.0, static.fetch_times[ti]
                    )
                    miss_cost = jnp.where(inflight, remaining, miss_cost)
            td_model = jnp.where(hit, 0.0, miss_cost)
        ftw = x + td_model + r_w                              # line 9
        if mid >= 0 and gpu_capacity is not None:
            # Static feasibility: cached + decompressed must fit the GPU
            # (mirrors ProfileRepository.model_fits).
            feasible = (
                static.cached_sizes[ti] + static.full_sizes[ti]
                <= gpu_capacity
            )
            ftw = jnp.where(feasible, ftw, jnp.inf)
        # Membership risk biases selection only; the recorded finish
        # estimates (planned_ft / ft_map) stay time-shaped.
        cost = ftw if liveness_cost is None else ftw + liveness_cost
        w_min = jnp.argmin(cost)                              # line 10
        if (
            mid >= 0
            and config.use_model_locality
            and config.intent_herd_margin > 0.0
        ):
            # Anti-herd stickiness (mirrors Scheduler._herd_sticky_choice):
            # move to the cheapest holder/intender unless the plain argmin
            # beats it by more than the margin.
            have = hit | intent_m
            cost_have = jnp.where(have, cost, jnp.inf)
            alt = jnp.argmin(cost_have)
            use_alt = (
                jnp.any(have)
                & ~have[w_min]
                & (cost_have[alt] <= cost[w_min] * (1.0 + config.intent_herd_margin))
            )
            w_min = jnp.where(use_alt, alt, w_min)
        ft_min = ftw[w_min]
        if return_components:
            base_td = (
                td_model if undiscounted is None
                else jnp.where(hit, 0.0, undiscounted)
            )
            comps.append((
                ft,                                    # queue (pre-update)
                jnp.broadcast_to(at, (n_workers,)),
                td_model,
                jnp.maximum(0.0, base_td - td_model),  # intent discount
                r_w,
                cost,
            ))
        assign.append(w_min)
        task_ft.append(ft_min)
        ft = ft.at[w_min].set(ft_min)                         # line 12
        if mid >= 0 and config.speculative_cache:
            newly = ~bits[w_min, mid]
            bits = bits.at[w_min, mid].set(True)
            avc = avc.at[w_min].add(
                -static.cached_sizes[ti] * newly
            )
            avc = jnp.maximum(avc, 0.0)
    if return_components:
        stacked = tuple(
            jnp.stack([c[k] for c in comps]) for k in range(6)
        )
        return (jnp.stack(assign), jnp.stack(task_ft)) + stacked
    return jnp.stack(assign), jnp.stack(task_ft)


class JaxNavigatorPlanner:
    """Drop-in planning-phase replacement built on ``plan_vectorized``."""

    def __init__(
        self,
        profiles: ProfileRepository,
        config: Optional[NavigatorConfig] = None,
    ) -> None:
        self.profiles = profiles
        self.config = config or NavigatorConfig()
        self._static: Dict[str, StaticPlanInputs] = {}
        # Flight-recorder hook (see Scheduler.recorder): when set, plan()
        # asks the kernel for the stacked Eq. 2 component arrays and
        # records one PlacementDecision per task.  The jit specialises on
        # ``return_components``, so the tracing-off kernel is unchanged.
        self.recorder = None
        # Topology path-cost matrices (uncontended planner view); None on
        # flat clusters so the kernel keeps the all-pairs-table code path.
        topo = profiles.cluster.topology
        self._xfer_inv_bw = self._xfer_delta = None
        if topo is not None:
            inv_bw, delta = topo.pair_matrices()
            self._xfer_inv_bw = jnp.asarray(inv_bw, jnp.float32)
            self._xfer_delta = jnp.asarray(delta, jnp.float32)
        n = profiles.cluster.n_workers
        # Static per-worker feasibility input, built once per planner.
        self._gpu_capacity = jnp.asarray(
            [profiles.cluster.gpu_capacity(w) for w in range(n)], jnp.float32
        )

    def _pack_feed(self, sst, now: float, n: int):
        """SST → kernel feed arrays.  A :class:`PackedViews` read (the
        indexed engine's columnar path) is a handful of vector ops; a
        scalar row list falls back to the per-row python unpack."""
        if isinstance(sst, PackedViews):
            one = np.uint64(1)
            bits = ((sst.bitmap[:, None] >> _BIT_SHIFTS) & one) != 0
            ibits = ((sst.intent[:, None] >> _BIT_SHIFTS) & one) != 0
            fresh = (
                np.maximum(0.0, now - sst.pushed_at)
                <= self.config.intent_fresh_s
            )
            live = np.where(
                sst.dead,
                np.inf,
                np.where(sst.suspect, self.config.suspect_penalty_s, 0.0),
            ).astype(np.float32)
            return (
                bits, ibits, fresh, live,
                sst.ft.astype(np.float32),
                sst.avc.astype(np.float32),
                sst.fetch_model.astype(np.int32),
                sst.fetch_eta.astype(np.float32),
            )
        bits = np.zeros((n, 64), bool)
        ibits = np.zeros((n, 64), bool)
        fresh = np.zeros((n,), bool)
        live = np.zeros((n,), np.float32)
        for w, row in enumerate(sst):
            for m in range(64):
                bits[w, m] = bool((row.cache_bitmap >> m) & 1)
                ibits[w, m] = bool((row.intent_bitmap >> m) & 1)
            fresh[w] = (
                max(0.0, now - row.pushed_at) <= self.config.intent_fresh_s
            )
            if row.liveness == DEAD:
                live[w] = np.inf
            elif row.liveness == SUSPECT:
                live[w] = self.config.suspect_penalty_s
        return (
            bits, ibits, fresh, live,
            np.asarray([r.ft_estimate_s for r in sst], np.float32),
            np.asarray([r.free_cache_bytes for r in sst], np.float32),
            np.asarray([r.fetch_model_id for r in sst], np.int32),
            np.asarray([r.fetch_eta_s for r in sst], np.float32),
        )

    def plan(self, job: Job, now: float, origin_worker: int, sst) -> ADFG:
        dfg = job.dfg
        if dfg.name not in self._static:
            self._static[dfg.name] = build_static_inputs(self.profiles, dfg)
        static = self._static[dfg.name]
        n = self.profiles.cluster.n_workers
        (bits, ibits, fresh, live, ft0, avc0,
         fetch_model, fetch_eta) = self._pack_feed(sst, now, n)
        out = plan_vectorized(
            static,
            self.config,
            n,
            jnp.asarray(ft0),
            jnp.asarray(bits),
            jnp.asarray(avc0),
            jnp.float32(now),
            jnp.int32(origin_worker),
            intent_bits=jnp.asarray(ibits),
            intent_fresh=jnp.asarray(fresh),
            gpu_capacity=self._gpu_capacity,
            liveness_cost=jnp.asarray(live),
            xfer_inv_bw=self._xfer_inv_bw,
            xfer_delta=self._xfer_delta,
            fetch_model=jnp.asarray(fetch_model),
            fetch_eta=jnp.asarray(fetch_eta),
            return_components=self.recorder is not None,
        )
        assign, task_ft = out[0], out[1]
        adfg = ADFG(job)
        for i, tid in enumerate(static.order):
            adfg[tid] = int(assign[i])
            adfg.planned_ft[tid] = float(task_ft[i])
        if self.recorder is not None:
            from repro.core.telemetry import CandidateCost, PlacementDecision

            queue, at, td, disc, rt, cost = (np.asarray(a) for a in out[2:])
            for i, tid in enumerate(static.order):
                self.recorder.record_placement(PlacementDecision(
                    t=now, job_id=job.job_id, task_id=tid, phase="plan",
                    scheduler="navigator-jax", reader=origin_worker,
                    chosen=int(assign[i]),
                    candidates=tuple(
                        CandidateCost(
                            worker=w,
                            queue_s=float(queue[i, w]),
                            input_s=float(at[i, w]),
                            model_s=float(td[i, w]),
                            intent_discount_s=float(disc[i, w]),
                            runtime_s=float(rt[i, w]),
                            liveness_s=float(live[w]),
                            total_s=float(cost[i, w]),
                        )
                        for w in range(n)
                    ),
                ))
        return adfg

"""Predictive prefetch plane: intent-driven GPU memory co-scheduling.

The paper's headline claim is *unified* co-scheduling of task placement
and GPU memory management, but a purely reactive memory layer only starts
a model fetch once a task is already enqueued with inputs present — the
fetch serializes behind the upstream computation instead of overlapping
it.  This subsystem closes the loop in the other direction: the planning
phase (Alg. 1) already knows, at job arrival, which models each worker
will need and roughly when.  We turn that plan into **memory intents**:

* When Navigator plans (or adjusts, Alg. 2) a job, every assigned worker
  receives :class:`PrefetchIntent` records for the models of its future
  tasks — ``(job, task, model, expected start)`` — capped per worker by
  ``lookahead_depth``.
* Each worker keeps a small intent queue.  Whenever its single fetch pipe
  (one PCIe transfer in flight per worker, §3.2) is idle and no *demand*
  fetch is pending, the earliest-needed intent issues a **speculative
  fetch**.  Demand always preempts prefetch: an in-flight speculative
  transfer for a different model is aborted (partial bytes are wasted and
  accounted) the moment a queued task needs the pipe.  A speculative
  fetch whose model a task demands mid-flight is *promoted* to a demand
  fetch and becomes non-preemptible.
* Workers advertise an **intent bitmap** — resident ∪ in-flight ∪
  queued-to-fetch — through both metadata planes (the ``SharedStateTable``
  row and ``GossipPlane`` diffs, lanes 6–7 of the wire row).  The
  planner's placement cost discounts ``TD_model`` for intended models by
  a confidence factor (``NavigatorConfig.intent_confidence``) when the
  advertisement is fresh.

Anti-herd hysteresis (two layers, both needed because every view is
stale):

1. **Planner-side stickiness** (``NavigatorConfig.intent_herd_margin``):
   when the cheapest worker for a model-bearing task does *not* hold or
   intend the model but some other worker does, the planner moves the
   task to the intending worker unless the cheapest worker wins by more
   than the margin — so concurrent planners converge on the worker that
   already committed to the fetch instead of each starting their own.
2. **Worker-side deferral** (``PrefetchConfig.herd_backoff_s``): a worker
   holds off issuing a *non-urgent* speculative fetch for a model some
   peer already advertises (resident or intended); Alg. 2 adjustment may
   well move the task there, making the local fetch redundant.  Once the
   expected start closes to within ``fetch + urgency_slack_s`` the fetch
   is issued regardless — by then the placement is as good as committed.

The plane is engine-agnostic, mirroring ``GossipPlane``: it holds no
clock and samples no randomness; the driving engine decides when intent
control messages arrive, when fetches start/finish, and feeds residency
predicates in.  ``sim/engine.py`` drives it with discrete events and
``serving/engine.py`` folds it into its virtual clock.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.core import bitmaps
from repro.core.types import ADFG, Job

#: Control-message payload per intent on the wire (job id, task id hash,
#: model id, expected start — comfortably one cache line with headers).
INTENT_WIRE_BYTES = 64.0


@dataclasses.dataclass(frozen=True)
class PrefetchConfig:
    """Worker-side tunables of the prefetch plane.

    Planner-side knobs (confidence discount, herd margin, freshness
    window) live on ``NavigatorConfig`` so the jitted vectorized planner
    can treat them as static arguments.
    """

    # How many future model-bearing tasks per worker one plan converts
    # into intents (deeper = more speculation, more potential waste).
    lookahead_depth: int = 4
    # Defer non-urgent speculative fetches for models a peer already
    # advertises by this much (anti-herd layer 2).  0 disables deferral.
    herd_backoff_s: float = 0.5
    # A prefetch is *urgent* (never deferred) once the expected task
    # start is within fetch_time + this slack.
    urgency_slack_s: float = 0.5
    # Intents older than this are dropped unissued: the plan that created
    # them has long since played out (or been adjusted away).
    intent_ttl_s: float = 30.0
    # Per-worker intent queue bound; beyond it the latest-needed intents
    # are dropped (the plan is re-derived on the next arrival anyway).
    max_queue: int = 16
    # Allow a speculative fetch to evict resident models (per the cache
    # policy; pinned and soon-needed models are protected, and unused
    # speculative contents are always the first victims).  On by default:
    # the multi-seed bursty-trace calibration (EXPERIMENTS.md) has it
    # strictly better on P50 and P99 than fill-free-memory-only.
    evict_for_prefetch: bool = True


# Intent lifecycle states.
QUEUED = "queued"
INFLIGHT = "inflight"
DONE = "done"
CANCELLED = "cancelled"


@dataclasses.dataclass
class PrefetchIntent:
    """One planned future model need on one worker."""

    job_id: int
    task_id: str
    model_id: int
    worker: int
    issued_at: float
    # Planner's estimate of when the task starts on the worker
    # (planned_ft − R(t, w)); orders the queue and gates urgency.
    expected_start_s: float
    state: str = QUEUED
    # Anti-herd deferral: not eligible for issue before this time.
    deferred_until: float = 0.0

    def key(self) -> Tuple[int, str]:
        return (self.job_id, self.task_id)


@dataclasses.dataclass
class PrefetchStats:
    intents_issued: int = 0      # admitted to a worker queue
    intents_cancelled: int = 0   # plan adjusted away / job done
    intents_migrated: int = 0    # moved to another worker by Alg. 2
    intents_consumed: int = 0    # demand reached the worker first
    intents_expired: int = 0     # TTL elapsed before issue
    intents_dropped: int = 0     # queue-bound overflow
    intents_orphaned: int = 0    # owning worker crashed/drained
    intents_rehomed: int = 0     # orphan re-issued on the task's heir worker
    already_resident: int = 0    # satisfied with no fetch needed
    prefetches_started: int = 0
    prefetches_completed: int = 0
    prefetches_promoted: int = 0  # demanded mid-flight → demand fetch
    prefetches_preempted: int = 0  # aborted for a demand fetch
    deferrals: int = 0           # anti-herd hold-offs
    stalls: int = 0              # chosen but no cache room; parked


class PrefetchPlane:
    """Cluster-wide book-keeping for per-worker prefetch queues.

    One instance per engine; state is strictly per-worker (a worker only
    ever reads/writes its own queue), so the centralized object is a
    modelling convenience, not a coordination point — exactly like
    ``GossipPlane`` holding every worker's replica.
    """

    def __init__(
        self,
        n_workers: int,
        config: Optional[PrefetchConfig] = None,
        fetch_time_fn: Optional[Callable[[int], float]] = None,
    ) -> None:
        self.n_workers = n_workers
        self.config = config or PrefetchConfig()
        # TD_model(m) estimator used for urgency; defaults to 0 (always
        # urgent) if the engine provides none.
        self._fetch_time = fetch_time_fn or (lambda mid: 0.0)
        # queues[w]: (job_id, task_id) -> intent, insertion-ordered;
        # scans sort by expected start (queues are ≤ max_queue long).
        self.queues: List[Dict[Tuple[int, str], PrefetchIntent]] = [
            {} for _ in range(n_workers)
        ]
        # The speculative fetch currently occupying w's fetch pipe.
        self.inflight: List[Optional[PrefetchIntent]] = [None] * n_workers
        self.stats = PrefetchStats()

    # -- intent derivation (planner side) -----------------------------------
    def plan_intents(
        self, job: Job, adfg: ADFG, profiles, now: float
    ) -> Dict[int, List[PrefetchIntent]]:
        """Turn a fresh ADFG into per-worker intents: for each worker the
        first ``lookahead_depth`` model-bearing tasks by expected start.
        The caller delivers each worker's list as a control message (with
        whatever transport delay its network model implies) and then
        calls :meth:`admit`."""
        per: Dict[int, List[PrefetchIntent]] = {}
        ordered = sorted(
            adfg.assignment, key=lambda t: adfg.planned_ft.get(t, now)
        )
        for tid in ordered:
            task = job.dfg.tasks[tid]
            if task.model_id is None:
                continue
            w = adfg[tid]
            lst = per.setdefault(w, [])
            if len(lst) >= self.config.lookahead_depth:
                continue
            est = adfg.planned_ft.get(tid, now) - profiles.runtime(task, w)
            lst.append(
                PrefetchIntent(
                    job_id=job.job_id,
                    task_id=tid,
                    model_id=task.model_id,
                    worker=w,
                    issued_at=now,
                    expected_start_s=max(now, est),
                )
            )
        return per

    def make_intent(
        self, job: Job, task_id: str, worker: int, now: float,
        expected_start_s: Optional[float] = None,
    ) -> Optional[PrefetchIntent]:
        """Single-task intent (Alg. 2 migration target)."""
        task = job.dfg.tasks[task_id]
        if task.model_id is None:
            return None
        return PrefetchIntent(
            job_id=job.job_id,
            task_id=task_id,
            model_id=task.model_id,
            worker=worker,
            issued_at=now,
            expected_start_s=(
                now if expected_start_s is None else expected_start_s
            ),
        )

    # -- intent queue maintenance (worker side) ------------------------------
    def admit(
        self, worker: int, intents: Sequence[PrefetchIntent], now: float
    ) -> None:
        """An intent control message arrived at ``worker``."""
        queue = self.queues[worker]
        for intent in intents:
            key = intent.key()
            prev = queue.get(key)
            if prev is not None:
                # Re-plan of the same task: keep the newer estimate.
                prev.expected_start_s = intent.expected_start_s
                prev.issued_at = intent.issued_at
                continue
            intent.worker = worker
            queue[key] = intent
            self.stats.intents_issued += 1
        # Bound the queue: drop the latest-needed surplus.
        over = len(queue) - self.config.max_queue
        if over > 0:
            by_need = sorted(
                queue.values(), key=lambda i: -i.expected_start_s
            )
            for victim in by_need[:over]:
                del queue[victim.key()]
                self.stats.intents_dropped += 1

    def cancel(
        self, worker: int, job_id: int, task_id: str, migrated: bool = False
    ) -> Optional[PrefetchIntent]:
        """Remove the intent for (job, task) on ``worker``.  Returns the
        in-flight intent if the cancellation hits a fetch the engine must
        abort (the caller owns the fetch pipe), else None."""
        key = (job_id, task_id)
        intent = self.queues[worker].pop(key, None)
        if intent is not None:
            intent.state = CANCELLED
            if migrated:
                self.stats.intents_migrated += 1
            else:
                self.stats.intents_cancelled += 1
            return None
        cur = self.inflight[worker]
        if cur is not None and cur.key() == key:
            # The speculative fetch belongs to a cancelled intent.  If
            # another queued intent wants the same model, transfer
            # ownership instead of wasting the transfer.
            heir = self._heir(worker, cur.model_id)
            if heir is not None:
                del self.queues[worker][heir.key()]
                heir.state = INFLIGHT
                self.inflight[worker] = heir
                if migrated:
                    self.stats.intents_migrated += 1
                else:
                    self.stats.intents_cancelled += 1
                return None
            cur.state = CANCELLED
            self.inflight[worker] = None
            if migrated:
                self.stats.intents_migrated += 1
            else:
                self.stats.intents_cancelled += 1
            return cur
        return None

    def _heir(self, worker: int, model_id: int) -> Optional[PrefetchIntent]:
        cands = [
            i for i in self.queues[worker].values() if i.model_id == model_id
        ]
        if not cands:
            return None
        return min(cands, key=lambda i: i.expected_start_s)

    def drop_worker(self, worker: int) -> List[PrefetchIntent]:
        """The worker left the fleet (crash or drain): its queued intents
        are orphaned and its in-flight speculative transfer is void (the
        engine owns the fetch pipe and the partial-bytes accounting).

        Returns the orphaned queue in expected-start order so the engine
        can re-home each intent on the heir worker its task is re-routed
        to (:meth:`rehome` stamps the move)."""
        orphans = sorted(
            self.queues[worker].values(), key=lambda i: i.expected_start_s
        )
        self.queues[worker] = {}
        cur = self.inflight[worker]
        if cur is not None:
            self.inflight[worker] = None
            cur.state = CANCELLED
            orphans.append(cur)
        for intent in orphans:
            intent.state = CANCELLED
            self.stats.intents_orphaned += 1
        return orphans

    def rehome(
        self, intent: PrefetchIntent, worker: int, now: float
    ) -> PrefetchIntent:
        """Mint the heir copy of an orphaned intent for the worker the
        engine's recovery re-routed its task to; the fresh issue time
        restarts the TTL (the old plan's clock died with the old worker).
        The caller delivers it like any other intent control message."""
        heir = PrefetchIntent(
            job_id=intent.job_id,
            task_id=intent.task_id,
            model_id=intent.model_id,
            worker=worker,
            issued_at=now,
            expected_start_s=max(now, intent.expected_start_s),
        )
        self.stats.intents_rehomed += 1
        return heir

    def consume(self, worker: int, job_id: int, task_id: str) -> None:
        """The task itself reached ``worker``'s execution queue — demand
        fetching takes over from here; the intent is spent."""
        intent = self.queues[worker].pop((job_id, task_id), None)
        if intent is not None:
            intent.state = DONE
            self.stats.intents_consumed += 1

    # -- fetch-pipe interface (engine side) ----------------------------------
    def next_intent(
        self,
        worker: int,
        now: float,
        is_resident: Callable[[int], bool],
        peer_bits: int = 0,
    ) -> Tuple[Optional[PrefetchIntent], Optional[float]]:
        """Pick the next intent to speculatively fetch on ``worker``.

        ``is_resident`` is the worker's local cache predicate (resident
        models need no fetch); ``peer_bits`` is the union of *other*
        workers' advertised cache∪intent bitmaps from this worker's own
        (possibly stale) SST view — the anti-herd evidence.

        Returns ``(intent, retry_at)``: ``intent`` is marked in-flight
        and removed from the queue when chosen; when every eligible
        intent is deferred, ``intent`` is None and ``retry_at`` is the
        earliest time a deferral expires (the engine may poke then).
        """
        queue = self.queues[worker]
        retry_at: Optional[float] = None
        for intent in sorted(queue.values(), key=lambda i: i.expected_start_s):
            if now - intent.issued_at > self.config.intent_ttl_s:
                del queue[intent.key()]
                intent.state = CANCELLED
                self.stats.intents_expired += 1
                continue
            if is_resident(intent.model_id):
                del queue[intent.key()]
                intent.state = DONE
                self.stats.already_resident += 1
                continue
            if now < intent.deferred_until:
                retry_at = (
                    intent.deferred_until
                    if retry_at is None
                    else min(retry_at, intent.deferred_until)
                )
                continue
            fetch_s = self._fetch_time(intent.model_id)
            urgent = (
                intent.expected_start_s - now
                <= fetch_s + self.config.urgency_slack_s
            )
            if (
                not urgent
                and self.config.herd_backoff_s > 0.0
                and bitmaps.contains(peer_bits, intent.model_id)
            ):
                # A peer already holds or intends this model and our need
                # is not imminent: hold off — adjustment may route the
                # task there and make this fetch pure waste.
                intent.deferred_until = now + self.config.herd_backoff_s
                self.stats.deferrals += 1
                retry_at = (
                    intent.deferred_until
                    if retry_at is None
                    else min(retry_at, intent.deferred_until)
                )
                continue
            del queue[intent.key()]
            intent.state = INFLIGHT
            self.inflight[worker] = intent
            self.stats.prefetches_started += 1
            return intent, None
        return None, retry_at

    def complete_inflight(self, worker: int) -> Optional[PrefetchIntent]:
        intent = self.inflight[worker]
        self.inflight[worker] = None
        if intent is not None:
            intent.state = DONE
            self.stats.prefetches_completed += 1
        return intent

    def promote_inflight(self, worker: int) -> None:
        """A queued task demanded the model mid-flight: the speculative
        fetch becomes a demand fetch (non-preemptible)."""
        if self.inflight[worker] is not None:
            self.stats.prefetches_promoted += 1

    def stall_inflight(self, worker: int, until: float) -> None:
        """The chosen intent could not be staged (no cache room without
        eviction): park it back on the queue, deferred until ``until``."""
        intent = self.inflight[worker]
        self.inflight[worker] = None
        if intent is None:
            return
        intent.state = QUEUED
        intent.deferred_until = until
        self.queues[worker][intent.key()] = intent
        self.stats.stalls += 1

    def preempt_inflight(self, worker: int, requeue: bool) -> Optional[PrefetchIntent]:
        """A demand fetch claimed the pipe.  ``requeue`` puts the intent
        back on the queue (the task still needs the model later)."""
        intent = self.inflight[worker]
        self.inflight[worker] = None
        if intent is None:
            return None
        self.stats.prefetches_preempted += 1
        if requeue:
            intent.state = QUEUED
            intent.deferred_until = 0.0
            self.queues[worker][intent.key()] = intent
        else:
            intent.state = CANCELLED
        return intent

    # -- advertisement --------------------------------------------------------
    def advertised_bits(self, worker: int) -> int:
        """Queued ∪ in-flight model bits for ``worker`` — the engine ORs
        these with the cache bitmap to form the advertised intent bitmap
        (resident ∪ in-flight ∪ queued-to-fetch)."""
        bits = 0
        for intent in self.queues[worker].values():
            bits = bitmaps.add(bits, intent.model_id)
        cur = self.inflight[worker]
        if cur is not None:
            bits = bitmaps.add(bits, cur.model_id)
        return bits

    def queue_depth(self, worker: int) -> int:
        return len(self.queues[worker])

"""Core datatypes for the Navigator scheduler.

The paper (§2.1) represents ML applications as acyclic dataflow graphs
(DFGs).  Vertices are ML computations annotated with the ML model object
they depend on (the "diamond box"), expected runtimes and input/output
object sizes.  A triggering event creates a *job instance*; the planning
phase produces an *Activated DFG* (ADFG): a map from task id to worker id
that is piggybacked from task to task and may be dynamically adjusted
(§3.2, §4.3).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Mapping, Optional, Sequence, Tuple

GB = 1024.0**3
MB = 1024.0**2

# The SST encoding uses a 64-bit integer bitmap for cache contents, hence
# model ids live in a small id space (paper §3.3 / §5.2: "currently 0..63").
MAX_MODEL_ID = 63


@dataclasses.dataclass(frozen=True)
class MLModel:
    """An ML model object: the cacheable unit managed by the GPU memory
    manager.  ``size_bytes`` is the decompressed in-GPU footprint used for
    cache accounting and fetch-time estimation (``TD_model``)."""

    model_id: int
    name: str
    size_bytes: float

    def __post_init__(self) -> None:
        if not (0 <= self.model_id <= MAX_MODEL_ID):
            raise ValueError(
                f"model_id {self.model_id} outside the 0..{MAX_MODEL_ID} "
                f"SST bitmap id space (paper §5.2)"
            )
        if self.size_bytes < 0:
            raise ValueError("model size must be non-negative")


@dataclasses.dataclass(frozen=True)
class TaskSpec:
    """A DFG vertex.

    ``runtime_s`` is the profiled expected execution time R(t) (§4.1); the
    per-worker R(t, w) is derived by the profile repository (workers may
    have a speed factor).  ``model_id`` is None for lightweight host-side
    tasks (e.g. the "aggregate translations" exit vertex) that need no GPU
    model object.
    """

    task_id: str
    runtime_s: float
    model_id: Optional[int] = None
    output_bytes: float = 1.0 * MB
    input_bytes: float = 1.0 * MB  # external input (entry tasks)

    def __post_init__(self) -> None:
        if self.runtime_s < 0:
            raise ValueError("runtime must be non-negative")


class DFG:
    """Directed acyclic dataflow graph G = (V, E).

    Edges are precedence constraints: output of the upstream task becomes
    input of the downstream task (§2.1).  The DFGs a deployment might see
    are small and static and available on all workers (§2.2), so rank
    computation (Eq. 1) is done once and cached in the profile repository.
    """

    def __init__(
        self,
        name: str,
        tasks: Sequence[TaskSpec],
        edges: Sequence[Tuple[str, str]],
    ) -> None:
        self.name = name
        self.tasks: Dict[str, TaskSpec] = {t.task_id: t for t in tasks}
        if len(self.tasks) != len(tasks):
            raise ValueError("duplicate task ids")
        self.edges: List[Tuple[str, str]] = list(edges)
        self.succs: Dict[str, List[str]] = {t: [] for t in self.tasks}
        self.preds: Dict[str, List[str]] = {t: [] for t in self.tasks}
        for u, v in self.edges:
            if u not in self.tasks or v not in self.tasks:
                raise ValueError(f"edge ({u},{v}) references unknown task")
            self.succs[u].append(v)
            self.preds[v].append(u)
        self._topo = self._toposort()

    # -- graph structure ---------------------------------------------------
    def _toposort(self) -> List[str]:
        indeg = {t: len(p) for t, p in self.preds.items()}
        frontier = sorted(t for t, d in indeg.items() if d == 0)
        order: List[str] = []
        while frontier:
            t = frontier.pop(0)
            order.append(t)
            for s in self.succs[t]:
                indeg[s] -= 1
                if indeg[s] == 0:
                    frontier.append(s)
            frontier.sort()
        if len(order) != len(self.tasks):
            raise ValueError(f"DFG {self.name!r} contains a cycle")
        return order

    @property
    def topo_order(self) -> List[str]:
        return list(self._topo)

    @property
    def entry_tasks(self) -> List[str]:
        return [t for t in self._topo if not self.preds[t]]

    @property
    def exit_tasks(self) -> List[str]:
        return [t for t in self._topo if not self.succs[t]]

    def is_join(self, task_id: str) -> bool:
        """Join tasks (>1 predecessor) cannot be moved during dynamic
        adjustment without coordination across predecessors (§4.3)."""
        return len(self.preds[task_id]) > 1

    def model_ids(self) -> List[int]:
        out = sorted(
            {t.model_id for t in self.tasks.values() if t.model_id is not None}
        )
        return out

    # -- lower bound (§6.1) --------------------------------------------------
    def lower_bound_latency(self) -> float:
        """Length of the critical path assuming maximum task parallelism,
        all models cached on GPU and zero data-transfer delay — the (possibly
        unachievable) latency lower bound used by the slowdown factor."""
        finish: Dict[str, float] = {}
        for t in self._topo:
            start = max((finish[p] for p in self.preds[t]), default=0.0)
            finish[t] = start + self.tasks[t].runtime_s
        return max(finish.values()) if finish else 0.0

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"DFG({self.name!r}, |V|={len(self.tasks)}, |E|={len(self.edges)})"


@dataclasses.dataclass
class Job:
    """A job instance: one triggering event for one DFG (§2.1)."""

    job_id: int
    dfg: DFG
    arrival_time: float
    # Actual (sampled) external input size; the profile holds the expected one.
    input_bytes: Optional[float] = None

    def lower_bound(self) -> float:
        return self.dfg.lower_bound_latency()


class ADFG:
    """Activated DFG: the per-job-instance task→worker assignment map
    produced by the planning phase and adjusted at runtime (§3.2)."""

    def __init__(self, job: Job) -> None:
        self.job = job
        self.assignment: Dict[str, int] = {}
        # Planner's estimated finish time per task (used for AT_input, Eq. 3).
        self.planned_ft: Dict[str, float] = {}

    def __getitem__(self, task_id: str) -> int:
        return self.assignment[task_id]

    def __setitem__(self, task_id: str, worker: int) -> None:
        self.assignment[task_id] = worker

    def __contains__(self, task_id: str) -> bool:
        return task_id in self.assignment

    def items(self) -> Iterable[Tuple[str, int]]:
        return self.assignment.items()

    def workers_used(self) -> List[int]:
        return sorted(set(self.assignment.values()))

    def copy(self) -> "ADFG":
        new = ADFG(self.job)
        new.assignment = dict(self.assignment)
        new.planned_ft = dict(self.planned_ft)
        return new


def models_from_specs(
    specs: Mapping[int, Tuple[str, float]]
) -> Dict[int, MLModel]:
    """Helper: {id: (name, size_bytes)} → {id: MLModel}."""
    return {
        mid: MLModel(model_id=mid, name=name, size_bytes=size)
        for mid, (name, size) in specs.items()
    }

"""Cache bitmap encoding (§5.2).

The SST row packs the GPU cache contents into a single 64-bit integer so
that one cache line holds a worker's whole row and RDMA pushes stay
cache-line atomic.  Model ids are 0..63; bit i set ⇔ model i resident.
"""

from __future__ import annotations

from typing import Iterable, List

from repro.core.types import MAX_MODEL_ID

_MASK64 = (1 << 64) - 1


def pack(model_ids: Iterable[int]) -> int:
    bm = 0
    for mid in model_ids:
        if not (0 <= mid <= MAX_MODEL_ID):
            raise ValueError(f"model id {mid} outside 0..{MAX_MODEL_ID}")
        bm |= 1 << mid
    return bm & _MASK64


def unpack(bitmap: int) -> List[int]:
    out = []
    mid = 0
    bm = bitmap & _MASK64
    while bm:
        if bm & 1:
            out.append(mid)
        bm >>= 1
        mid += 1
    return out


def contains(bitmap: int, model_id: int) -> bool:
    return bool((bitmap >> model_id) & 1)


def add(bitmap: int, model_id: int) -> int:
    return (bitmap | (1 << model_id)) & _MASK64


def remove(bitmap: int, model_id: int) -> int:
    return bitmap & ~(1 << model_id) & _MASK64


def popcount(bitmap: int) -> int:
    return bin(bitmap & _MASK64).count("1")

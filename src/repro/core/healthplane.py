"""Live cluster health plane (DESIGN.md §3.5).

PR 5's flight recorder answers questions *after* a run from a raw
trace; a production fleet needs to answer them *while* running.  This
module is the online half of the observability plane:

* :class:`QuantileSketch` — a deterministic, mergeable t-digest-style
  quantile sketch.  Centroid compression uses the standard arcsine
  scale function but every step (buffering, sorting, merging) is pure
  deterministic float arithmetic: the same observation sequence always
  yields the same centroids, and merging per-worker sketches in worker
  order composes latency distributions fleet-wide without collecting
  raw samples.  Property tests pin the rank error against exact
  quantiles.

* :class:`HealthMonitor` — streaming windowed time-series per worker
  (queue depth, GPU-memory occupancy, fetch-pipe utilization,
  per-uplink bytes in flight), sampled by the engines on the events
  they already process.  Windows are fixed-size and keyed to simulated
  time (``floor(t / window_s)``), so two runs of the same seed produce
  byte-identical series — the chaos suite's determinism oracle extends
  to the health plane.

* **Health digests** — a four-field summary (queue depth, memory
  occupancy, fetch utilization, local task-latency p99) refreshed onto
  the owner's SST row right before each publication/gossip round
  (``SSTRow`` wire lanes 12–15), so every worker holds a
  staleness-bounded view of fleet health with no oracle — the same
  metadata-plane discipline as load/cache/membership.

* **Online detectors** — straggler, queue-buildup, memory-thrash and
  spine-saturation detectors run inside the sampling hooks, emit typed
  ``health.*`` events into the flight recorder (when attached) and
  accumulate a per-kind ledger surfaced by
  ``SimReport.health_summary()``.

* **Cost-model calibration** — :func:`calibrate` joins each task's
  placement-provenance Eq. 2 cost vector (PR 5) against its measured
  span breakdown and maintains per-component residual statistics
  (queue, input-transfer, model-fetch, runtime), exported through the
  MetricsRegistry and surfaced by ``bench_trace.py --calibration``.

Zero overhead when off: like the flight recorder, the engines guard
every sampling site with ``if self._health is not None`` — the CI
``trace-smoke`` tracemalloc guard covers this file too.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Optional, Sequence, Tuple

HEALTH_SCHEMA_VERSION = 1

#: Typed detector event kinds (also the trace-event kinds they emit).
STRAGGLER = "health.straggler"
QUEUE_BUILDUP = "health.queue_buildup"
MEMORY_THRASH = "health.memory_thrash"
SPINE_SATURATION = "health.spine_saturation"

DETECTOR_KINDS = (STRAGGLER, QUEUE_BUILDUP, MEMORY_THRASH, SPINE_SATURATION)


# --------------------------------------------------------------------------
# Deterministic mergeable quantile sketch
# --------------------------------------------------------------------------
class QuantileSketch:
    """Merging t-digest with the arcsine scale function, deterministic by
    construction.

    Observations buffer until ``4 * compression`` points, then compress
    into weighted centroids whose width shrinks toward the tails
    (k(q) = c/2π · asin(2q−1)); ``merge`` feeds another sketch's
    centroids through the same pass.  All arithmetic is plain float ops
    over sorted sequences — no randomness, no hashing — so reruns and
    replicas agree bit-for-bit, and the chaos byte-diff can cover
    health summaries.  Rank error is O(1/compression) at the tails
    (property-tested against exact quantiles in
    ``tests/test_healthplane.py``).
    """

    __slots__ = ("compression", "_means", "_weights", "_buf",
                 "count", "sum", "min", "max")

    def __init__(self, compression: int = 100) -> None:
        if compression < 20:
            raise ValueError("compression < 20 gives useless accuracy")
        self.compression = compression
        self._means: List[float] = []
        self._weights: List[float] = []
        self._buf: List[Tuple[float, float]] = []
        self.count = 0.0
        self.sum = 0.0
        self.min = float("inf")
        self.max = float("-inf")

    # -- scale function -----------------------------------------------------
    def _k(self, q: float) -> float:
        q = min(1.0, max(0.0, q))
        return self.compression / (2.0 * math.pi) * math.asin(2.0 * q - 1.0)

    def _k_inv(self, k: float) -> float:
        return (math.sin(2.0 * math.pi * k / self.compression) + 1.0) / 2.0

    # -- ingestion ----------------------------------------------------------
    def add(self, x: float, w: float = 1.0) -> None:
        if w <= 0:
            return
        self._buf.append((float(x), float(w)))
        self.count += w
        self.sum += x * w
        if x < self.min:
            self.min = x
        if x > self.max:
            self.max = x
        if len(self._buf) >= 4 * self.compression:
            self._compress()

    def merge(self, other: "QuantileSketch") -> None:
        """Fold ``other`` into this sketch (deterministic given call
        order; per-worker sketches merged in worker order compose the
        fleet distribution)."""
        other._compress()
        for m, w in zip(other._means, other._weights):
            self._buf.append((m, w))
        self.count += other.count
        self.sum += other.sum
        if other.min < self.min:
            self.min = other.min
        if other.max > self.max:
            self.max = other.max
        self._compress()

    def _compress(self) -> None:
        if not self._buf:
            return
        pts = sorted(
            list(zip(self._means, self._weights)) + self._buf
        )
        self._buf = []
        total = sum(w for _, w in pts)
        means: List[float] = []
        weights: List[float] = []
        cur_m, cur_w = pts[0]
        w_done = 0.0  # weight fully emitted so far
        limit = self._k_inv(self._k(0.0) + 1.0) * total
        for m, w in pts[1:]:
            if w_done + cur_w + w <= limit:
                cur_m += (m - cur_m) * (w / (cur_w + w))
                cur_w += w
            else:
                means.append(cur_m)
                weights.append(cur_w)
                w_done += cur_w
                limit = self._k_inv(self._k(w_done / total) + 1.0) * total
                cur_m, cur_w = m, w
        means.append(cur_m)
        weights.append(cur_w)
        self._means = means
        self._weights = weights

    # -- queries -------------------------------------------------------------
    def quantile(self, q: float) -> float:
        """Estimate the ``q``-quantile by midpoint interpolation across
        centroids, anchored at the exact min/max."""
        self._compress()
        if not self._means:
            return 0.0
        if len(self._means) == 1:
            return self._means[0]
        q = min(1.0, max(0.0, q))
        target = q * self.count
        # Cumulative midpoints: centroid i covers rank cum + w_i/2.
        cum = 0.0
        prev_pos, prev_val = 0.0, self.min
        for m, w in zip(self._means, self._weights):
            pos = cum + w / 2.0
            if target <= pos:
                span = pos - prev_pos
                frac = 0.0 if span <= 0 else (target - prev_pos) / span
                return prev_val + (m - prev_val) * frac
            cum += w
            prev_pos, prev_val = pos, m
        span = self.count - prev_pos
        frac = 0.0 if span <= 0 else (target - prev_pos) / span
        return prev_val + (self.max - prev_val) * frac

    def centroids(self) -> Tuple[Tuple[float, float], ...]:
        """Flushed (mean, weight) pairs — the determinism fingerprint."""
        self._compress()
        return tuple(zip(self._means, self._weights))

    def as_dict(self) -> Dict[str, float]:
        if self.count <= 0:
            return {"count": 0, "min": 0.0, "max": 0.0,
                    "p50": 0.0, "p90": 0.0, "p99": 0.0}
        return {
            "count": int(self.count),
            "min": self.min,
            "max": self.max,
            "p50": self.quantile(0.5),
            "p90": self.quantile(0.9),
            "p99": self.quantile(0.99),
        }


# --------------------------------------------------------------------------
# Streaming windowed time-series
# --------------------------------------------------------------------------
@dataclasses.dataclass
class Window:
    """One fixed-size aggregation window (index = floor(t / window_s))."""

    index: int
    count: int = 0
    sum: float = 0.0
    min: float = float("inf")
    max: float = float("-inf")
    last: float = 0.0

    def observe(self, v: float) -> None:
        self.count += 1
        self.sum += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v
        self.last = v

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0


class WindowedSeries:
    """Bounded ring of :class:`Window` aggregates for one signal.

    Windows are keyed to simulated time, never wall clock, and samples
    arrive in non-decreasing ``t`` from a deterministic event loop —
    so the series is a pure function of the run."""

    __slots__ = ("window_s", "max_windows", "windows")

    def __init__(self, window_s: float, max_windows: int) -> None:
        self.window_s = window_s
        self.max_windows = max_windows
        self.windows: List[Window] = []

    def observe(self, t: float, v: float) -> Window:
        idx = int(t // self.window_s)
        if not self.windows or self.windows[-1].index != idx:
            self.windows.append(Window(idx))
            if len(self.windows) > self.max_windows:
                del self.windows[0]
        w = self.windows[-1]
        w.observe(v)
        return w

    @property
    def last(self) -> Optional[Window]:
        return self.windows[-1] if self.windows else None

    def overall_max(self) -> float:
        return max((w.max for w in self.windows), default=0.0)

    def overall_mean(self) -> float:
        n = sum(w.count for w in self.windows)
        return sum(w.sum for w in self.windows) / n if n else 0.0


class _PipeUtilization:
    """Busy-time integrator for the per-worker fetch pipe: state changes
    (busy/idle) split into per-window busy seconds."""

    __slots__ = ("window_s", "max_windows", "_busy", "_since", "_windows")

    def __init__(self, window_s: float, max_windows: int) -> None:
        self.window_s = window_s
        self.max_windows = max_windows
        self._busy = False
        self._since = 0.0
        self._windows: List[Tuple[int, float]] = []  # (index, busy seconds)

    def _credit(self, t0: float, t1: float) -> None:
        while t0 < t1 - 1e-12:
            idx = int(t0 // self.window_s)
            edge = min(t1, (idx + 1) * self.window_s)
            if self._windows and self._windows[-1][0] == idx:
                self._windows[-1] = (idx, self._windows[-1][1] + (edge - t0))
            else:
                self._windows.append((idx, edge - t0))
                if len(self._windows) > self.max_windows:
                    del self._windows[0]
            t0 = edge

    def update(self, t: float, busy: bool) -> None:
        if self._busy:
            self._credit(self._since, t)
        self._busy = busy
        self._since = t

    def utilization(self, now: float) -> float:
        """Mean busy fraction over the retained windows (open busy
        interval credited up to ``now``)."""
        if self._busy:
            self._credit(self._since, now)
            self._since = now
        if not self._windows:
            return 0.0
        horizon = len(self._windows) * self.window_s
        return min(1.0, sum(b for _, b in self._windows) / horizon)


# --------------------------------------------------------------------------
# Monitor: per-worker series + detectors + digests
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class HealthConfig:
    """Health-plane tunables.  Detector thresholds are deliberately
    conservative defaults; the scenario tests inject clear violations."""

    window_s: float = 1.0
    max_windows: int = 64
    sketch_compression: int = 100
    # Straggler: a task whose service time exceeds ``straggler_factor`` ×
    # its profiled expectation (and a floor, so micro-tasks never flag).
    straggler_factor: float = 3.0
    straggler_min_s: float = 0.05
    # Queue buildup: depth at/above threshold on N consecutive samples.
    queue_depth_threshold: int = 8
    queue_consecutive: int = 3
    # Memory thrash: eviction count within one window at/above threshold.
    thrash_evictions_per_window: int = 4
    # Spine saturation: fair share at/below threshold (~ >= 1/share
    # concurrent flows on the uplink) on N consecutive cross transfers.
    spine_share_threshold: float = 0.34
    spine_consecutive: int = 4
    # Events retained verbatim in the summary (counters are unbounded).
    max_events: int = 256


@dataclasses.dataclass(frozen=True)
class HealthEvent:
    """One detector firing."""

    t: float
    kind: str
    worker: int          # -1 for fleet-scope (spine) events
    value: float
    threshold: float
    detail: str = ""

    def as_dict(self) -> Dict[str, Any]:
        return {"t": round(self.t, 9), "kind": self.kind,
                "worker": self.worker, "value": round(self.value, 9),
                "threshold": self.threshold, "detail": self.detail}


@dataclasses.dataclass(frozen=True)
class HealthDigest:
    """The four-field per-worker summary gossiped on SSTRow lanes 12–15."""

    queue_depth: int
    mem_occupancy: float
    fetch_util: float
    p99_latency_s: float


class _WorkerHealth:
    __slots__ = ("queue_depth", "mem_occupancy", "uplink_bytes", "pipe",
                 "latency", "_evictions_seen", "_thrash_window",
                 "_thrash_count", "_queue_over", "_last_queue_depth")

    def __init__(self, cfg: HealthConfig) -> None:
        self.queue_depth = WindowedSeries(cfg.window_s, cfg.max_windows)
        self.mem_occupancy = WindowedSeries(cfg.window_s, cfg.max_windows)
        self.uplink_bytes = WindowedSeries(cfg.window_s, cfg.max_windows)
        self.pipe = _PipeUtilization(cfg.window_s, cfg.max_windows)
        self.latency = QuantileSketch(cfg.sketch_compression)
        self._evictions_seen = 0
        self._thrash_window = -1
        self._thrash_count = 0
        self._queue_over = 0
        self._last_queue_depth = 0


class HealthMonitor:
    """Streaming health state for one engine run.

    The engines call the ``sample_* / on_*`` hooks behind
    ``if self._health is not None`` guards (same zero-overhead-when-off
    contract as the flight recorder); ``recorder`` may be None — health
    events are then only kept in the monitor's own ledger."""

    def __init__(
        self,
        n_workers: int,
        config: Optional[HealthConfig] = None,
        recorder: Optional[Any] = None,
    ) -> None:
        self.config = config or HealthConfig()
        self.n_workers = n_workers
        self.recorder = recorder
        self.workers = [_WorkerHealth(self.config) for _ in range(n_workers)]
        # Per-uplink bytes-in-flight series, keyed by uplink label
        # ("flat" on a topology-less cluster, "rackN" spine uplinks).
        self.uplinks: Dict[str, WindowedSeries] = {}
        self.fleet_job_latency = QuantileSketch(self.config.sketch_compression)
        self.events: List[HealthEvent] = []
        self.counts: Dict[str, int] = {k: 0 for k in DETECTOR_KINDS}
        self._spine_low = 0
        self._now = 0.0

    # -- event plumbing ------------------------------------------------------
    def _fire(self, t: float, kind: str, worker: int, value: float,
              threshold: float, detail: str = "") -> None:
        self.counts[kind] += 1
        if len(self.events) < self.config.max_events:
            self.events.append(
                HealthEvent(t, kind, worker, value, threshold, detail)
            )
        if self.recorder is not None:
            self.recorder.emit(
                t, kind, worker=worker, value=round(value, 9),
                threshold=threshold, detail=detail,
            )

    # -- sampling hooks (engine call sites) ----------------------------------
    def sample_queue(self, worker: int, t: float, depth: int) -> None:
        self._now = t
        wh = self.workers[worker]
        wh.queue_depth.observe(t, float(depth))
        wh._last_queue_depth = depth
        cfg = self.config
        if depth >= cfg.queue_depth_threshold:
            wh._queue_over += 1
            if wh._queue_over == cfg.queue_consecutive:
                self._fire(
                    t, QUEUE_BUILDUP, worker, float(depth),
                    float(cfg.queue_depth_threshold),
                    f"{cfg.queue_consecutive} consecutive samples",
                )
        else:
            wh._queue_over = 0

    def sample_memory(self, worker: int, t: float, occupancy: float,
                      evictions_total: int) -> None:
        self._now = t
        wh = self.workers[worker]
        wh.mem_occupancy.observe(t, occupancy)
        cfg = self.config
        new = evictions_total - wh._evictions_seen
        wh._evictions_seen = evictions_total
        if new <= 0:
            return
        idx = int(t // cfg.window_s)
        win = wh.mem_occupancy.last
        # Count evictions into the current window via a side counter on
        # the occupancy series' window index.
        if wh._thrash_window != idx:
            wh._thrash_window = idx
            wh._thrash_count = new
        else:
            wh._thrash_count += new
        if (
            wh._thrash_count >= cfg.thrash_evictions_per_window
            and wh._thrash_count - new < cfg.thrash_evictions_per_window
        ):
            self._fire(
                t, MEMORY_THRASH, worker, float(wh._thrash_count),
                float(cfg.thrash_evictions_per_window),
                f"evictions in window {idx} (occupancy {win.last:.2f})"
                if win else "",
            )

    def fetch_state(self, worker: int, t: float, busy: bool) -> None:
        self._now = t
        self.workers[worker].pipe.update(t, busy)

    def on_transfer(self, t: float, uplink: str, nbytes: float,
                    share: float, cross: bool) -> None:
        self._now = t
        series = self.uplinks.get(uplink)
        if series is None:
            series = self.uplinks[uplink] = WindowedSeries(
                self.config.window_s, self.config.max_windows
            )
        series.observe(t, nbytes)
        cfg = self.config
        if cross:
            if share <= cfg.spine_share_threshold:
                self._spine_low += 1
                if self._spine_low == cfg.spine_consecutive:
                    self._fire(
                        t, SPINE_SATURATION, -1, share,
                        cfg.spine_share_threshold,
                        f"uplink {uplink}: {cfg.spine_consecutive} "
                        f"consecutive contended transfers",
                    )
            else:
                self._spine_low = 0

    def task_done(self, worker: int, t: float, service_s: float,
                  expected_s: float) -> None:
        self._now = t
        wh = self.workers[worker]
        wh.latency.add(service_s)
        cfg = self.config
        if (
            service_s >= cfg.straggler_min_s
            and expected_s > 0.0
            and service_s >= cfg.straggler_factor * expected_s
        ):
            self._fire(
                t, STRAGGLER, worker, service_s,
                cfg.straggler_factor * expected_s,
                f"expected {expected_s:.4f}s",
            )

    def job_done(self, t: float, latency_s: float) -> None:
        self._now = t
        self.fleet_job_latency.add(latency_s)

    # -- digests (SST lanes 12-15) -------------------------------------------
    def digest(self, worker: int, t: float) -> HealthDigest:
        """Current four-field digest for the owner's SST row; the engine
        refreshes it right before each publication/gossip round, so the
        replicated view's staleness is bounded by the dissemination
        period like every other lane."""
        wh = self.workers[worker]
        occ = wh.mem_occupancy.last
        return HealthDigest(
            queue_depth=wh._last_queue_depth,
            mem_occupancy=occ.last if occ else 0.0,
            fetch_util=wh.pipe.utilization(t),
            p99_latency_s=(
                wh.latency.quantile(0.99) if wh.latency.count else 0.0
            ),
        )

    # -- summary --------------------------------------------------------------
    def summary(self) -> Dict[str, Any]:
        """Deterministic, schema-versioned health report
        (``schemas/health.schema.json``); the payload behind
        ``SimReport.health_summary()``."""
        fleet = QuantileSketch(self.config.sketch_compression)
        per_worker = []
        for w, wh in enumerate(self.workers):
            if wh.latency.count:
                fleet.merge(wh.latency)
            occ = wh.mem_occupancy.last
            per_worker.append({
                "worker": w,
                "queue_depth_last": wh._last_queue_depth,
                "queue_depth_max": int(wh.queue_depth.overall_max()),
                "mem_occupancy_last": round(occ.last, 9) if occ else 0.0,
                "fetch_util": round(wh.pipe.utilization(self._now), 9),
                "task_latency": _round_dict(wh.latency.as_dict()),
            })
        return {
            "schema_version": HEALTH_SCHEMA_VERSION,
            "horizon_s": round(self._now, 9),
            "workers": per_worker,
            "fleet_task_latency": _round_dict(fleet.as_dict()),
            "fleet_job_latency": _round_dict(self.fleet_job_latency.as_dict()),
            "uplink_bytes": {
                name: round(s.overall_mean(), 9)
                for name, s in sorted(self.uplinks.items())
            },
            "detectors": {k: self.counts[k] for k in sorted(self.counts)},
            "events": [e.as_dict() for e in self.events],
        }


def _round_dict(d: Dict[str, float]) -> Dict[str, float]:
    return {k: (round(v, 9) if isinstance(v, float) else v)
            for k, v in d.items()}


# --------------------------------------------------------------------------
# Eq. 2 cost-model calibration: provenance vs measured spans
# --------------------------------------------------------------------------
#: Eq. 2 components joined against span measurements, in report order.
CALIBRATION_COMPONENTS = ("queue", "input_transfer", "model_fetch", "runtime")


@dataclasses.dataclass
class ComponentCalibration:
    """Residual statistics for one Eq. 2 component (residual =
    measured − predicted; positive means the planner was optimistic)."""

    component: str
    count: int = 0
    predicted_sum: float = 0.0
    measured_sum: float = 0.0
    residual_sum: float = 0.0
    residual_abs_sum: float = 0.0
    residuals: QuantileSketch = dataclasses.field(
        default_factory=lambda: QuantileSketch(100)
    )

    def observe(self, predicted: float, measured: float) -> None:
        r = measured - predicted
        self.count += 1
        self.predicted_sum += predicted
        self.measured_sum += measured
        self.residual_sum += r
        self.residual_abs_sum += abs(r)
        self.residuals.add(r)

    def as_dict(self) -> Dict[str, Any]:
        n = max(1, self.count)
        return {
            "component": self.component,
            "count": self.count,
            "predicted_mean_s": self.predicted_sum / n,
            "measured_mean_s": self.measured_sum / n,
            "residual_mean_s": self.residual_sum / n,
            "residual_abs_mean_s": self.residual_abs_sum / n,
            "residual_p50_s": self.residuals.quantile(0.5),
            "residual_p90_s": self.residuals.quantile(0.9),
            "residual_p99_s": self.residuals.quantile(0.99),
        }


@dataclasses.dataclass
class CalibrationReport:
    """Per-component Eq. 2 residuals for one traced run."""

    scheduler: str
    components: Dict[str, ComponentCalibration]
    joined: int = 0        # spans matched to a placement decision
    unmatched: int = 0     # completed spans with no usable decision

    def to_metrics(self, registry) -> None:
        """Export the residual statistics as gauges/counters on the
        run's MetricsRegistry (schema-compatible with
        ``schemas/metrics.schema.json``)."""
        registry.counter("calibration.joined",
                         scheduler=self.scheduler).inc(self.joined)
        registry.counter("calibration.unmatched",
                         scheduler=self.scheduler).inc(self.unmatched)
        for name in CALIBRATION_COMPONENTS:
            c = self.components[name]
            d = c.as_dict()
            labels = {"component": name, "scheduler": self.scheduler}
            registry.counter("calibration.samples", **labels).inc(c.count)
            for key in ("residual_mean_s", "residual_abs_mean_s",
                        "residual_p50_s", "residual_p90_s",
                        "residual_p99_s"):
                registry.gauge(f"calibration.{key}", **labels).set(d[key])

    def format_table(self) -> str:
        lines = [
            f"calibration[{self.scheduler}]: {self.joined} spans joined, "
            f"{self.unmatched} unmatched",
            f"{'component':>16} {'n':>6} {'pred_mean':>10} {'meas_mean':>10}"
            f" {'resid_mean':>11} {'|resid|':>9} {'p50':>9} {'p90':>9}"
            f" {'p99':>9}",
        ]
        for name in CALIBRATION_COMPONENTS:
            d = self.components[name].as_dict()
            lines.append(
                f"{name:>16} {d['count']:>6d}"
                f" {d['predicted_mean_s']:>10.4f}"
                f" {d['measured_mean_s']:>10.4f}"
                f" {d['residual_mean_s']:>11.4f}"
                f" {d['residual_abs_mean_s']:>9.4f}"
                f" {d['residual_p50_s']:>9.4f}"
                f" {d['residual_p90_s']:>9.4f}"
                f" {d['residual_p99_s']:>9.4f}"
            )
        return "\n".join(lines)

    def worst_component(self) -> str:
        return max(
            CALIBRATION_COMPONENTS,
            key=lambda n: abs(self.components[n].as_dict()["residual_mean_s"]),
        )

    def as_dict(self) -> Dict[str, Any]:
        return {
            "scheduler": self.scheduler,
            "joined": self.joined,
            "unmatched": self.unmatched,
            "components": {
                n: self.components[n].as_dict()
                for n in CALIBRATION_COMPONENTS
            },
        }


def calibrate(report) -> CalibrationReport:
    """Join placement provenance against measured spans.

    For every completed (job, task) with recorded decisions, take the
    *last* decision whose chosen worker is where the final attempt
    actually ran (plan→adjust chains re-place; the final decision is
    the one the execution realized) and compare its chosen candidate's
    Eq. 2 terms against the span's measured breakdown:

    ====================  =============================================
    predicted             measured (span component)
    ====================  =============================================
    queue   max(0, FT(w) − max(AT_inputs, t))   dispatch wait past readiness
    input   max(0, AT_inputs − t)               critical-input shipping
    model   TD_model charged (model_s)          fetch wait past readiness
    runtime R(t, w)                             compute time
    ====================  =============================================

    ``report`` is a ``core.telemetry.SimReport``.  Residuals are
    measured − predicted, so positive = planner optimistic.
    """
    rec = report.recorder
    out = CalibrationReport(
        scheduler=report.result.scheduler,
        components={
            n: ComponentCalibration(n) for n in CALIBRATION_COMPONENTS
        },
    )
    for (job_id, task_id) in sorted(rec._placement_index):
        decisions = rec.decisions(job_id, task_id)
        try:
            span = report.final_span(job_id, task_id)
        except KeyError:
            out.unmatched += 1
            continue
        decision = None
        for d in reversed(decisions):
            if d.chosen == span.worker:
                decision = d
                break
        if decision is None:
            out.unmatched += 1  # every decision was overtaken by recovery
            continue
        cand = decision.candidate(decision.chosen)
        if cand is None or cand.total_s == float("inf"):
            out.unmatched += 1
            continue
        t0 = decision.t
        pred_input = max(0.0, cand.input_s - t0)
        pred_queue = max(0.0, cand.queue_s - max(cand.input_s, t0))
        out.components["queue"].observe(pred_queue, span.queue_s)
        out.components["input_transfer"].observe(pred_input, span.input_s)
        out.components["model_fetch"].observe(cand.model_s, span.fetch_s)
        out.components["runtime"].observe(cand.runtime_s, span.compute_s)
        out.joined += 1
    return out

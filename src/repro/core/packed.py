"""Columnar (packed) SST views for fleet-scale planning.

The reference read path materializes a reader's view as ``List[SSTRow]``
— one python object copy per worker per plan/adjust call.  At 500
workers that alone dominates the event loop.  This module provides the
indexed alternative:

* :class:`ColumnStore` — a columnar mirror of the planner-relevant
  SSTRow lanes, maintained **O(1) per dirty row** by the metadata planes
  (``SharedStateTable`` mirrors its single published table on each push;
  ``GossipPlane`` mirrors each reader's replica on each ``_bump`` /
  ``deliver`` / ``join`` / ``push`` — exactly the rows those operations
  already touch, never a table scan).

* :class:`PackedViews` — what a reader actually hands the planners:
  parallel ``(W,)`` numpy arrays plus the reader's vectorized membership
  verdicts.  Building one is a handful of numpy column copies —
  microseconds at 500 workers — instead of W python row copies.

The planners' batched paths (``NavigatorScheduler._plan_packed`` etc.)
consume these arrays with float64 numpy arithmetic that replays the
scalar reference expressions element-for-element, so placement decisions
are bit-exact with the row-list path (pinned by chaos family 7 and
tests/test_engine_indexed.py).
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.state import ALIVE, DEAD, SUSPECT, LeaseConfig, SSTRow

# Shape of a ColumnStore: (W,) for a single table, (R, W) for per-reader
# replica sets.
Shape = Union[int, Tuple[int, int]]


class ColumnStore:
    """Columnar mirror of the planner-relevant SSTRow lanes.

    ``set_row(idx, row, version)`` copies one row in O(1); ``idx`` is a
    worker id for a ``(W,)`` store or a ``(reader, owner)`` pair for an
    ``(R, W)`` store.  Health lanes are deliberately absent — planners
    never read them, and the health plane keeps its own digests.
    """

    __slots__ = (
        "ft", "bitmap", "avc", "pushed_at", "intent",
        "fetch_model", "fetch_eta", "heartbeat", "draining", "version",
    )

    def __init__(self, shape: Shape) -> None:
        self.ft = np.zeros(shape)
        self.bitmap = np.zeros(shape, dtype=np.uint64)
        self.avc = np.zeros(shape)
        self.pushed_at = np.zeros(shape)
        self.intent = np.zeros(shape, dtype=np.uint64)
        self.fetch_model = np.full(shape, -1, dtype=np.int64)
        self.fetch_eta = np.zeros(shape)
        self.heartbeat = np.zeros(shape)
        self.draining = np.zeros(shape, dtype=bool)
        self.version = np.zeros(shape, dtype=np.int64)

    def set_row(self, idx, row: SSTRow, version: Optional[int] = None) -> None:
        self.ft[idx] = row.ft_estimate_s
        self.bitmap[idx] = row.cache_bitmap
        self.avc[idx] = row.free_cache_bytes
        self.pushed_at[idx] = row.pushed_at
        self.intent[idx] = row.intent_bitmap
        self.fetch_model[idx] = row.fetch_model_id
        self.fetch_eta[idx] = row.fetch_eta_s
        self.heartbeat[idx] = row.heartbeat_s
        self.draining[idx] = row.draining
        self.version[idx] = row.version if version is None else version

    def reset_reader(self, reader: int) -> None:
        """Blank one reader's replica slice (gossip rejoin): every lane
        back to the fresh-``SSTRow()`` defaults."""
        self.ft[reader] = 0.0
        self.bitmap[reader] = 0
        self.avc[reader] = 0.0
        self.pushed_at[reader] = 0.0
        self.intent[reader] = 0
        self.fetch_model[reader] = -1
        self.fetch_eta[reader] = 0.0
        self.heartbeat[reader] = 0.0
        self.draining[reader] = False
        self.version[reader] = 0


@dataclasses.dataclass
class PackedViews:
    """One reader's SST view as parallel ``(W,)`` columns.

    ``dead`` / ``suspect`` carry the reader's per-peer membership
    verdicts (mutually exclusive; neither set ⇒ ALIVE), computed with the
    same precedence as the scalar classifiers: draining ⇒ DEAD beats
    everything, self-evidence is never stale, a never-heard-from gossip
    peer (version 0) is SUSPECT, otherwise the lease classifies the
    replicated heartbeat age.
    """

    reader: int
    ft: np.ndarray           # float64 — FT(w) estimates
    bitmap: np.ndarray       # uint64  — cache bitmaps
    avc: np.ndarray          # float64 — free cache bytes (AVC)
    pushed_at: np.ndarray    # float64 — last-modification stamps
    intent: np.ndarray       # uint64  — prefetch intent bitmaps
    fetch_model: np.ndarray  # int64   — in-flight fetch model id (−1 none)
    fetch_eta: np.ndarray    # float64 — absolute in-flight fetch ETA
    dead: np.ndarray         # bool
    suspect: np.ndarray      # bool

    @property
    def n_workers(self) -> int:
        return int(self.ft.shape[0])

    def liveness(self, worker: int) -> str:
        if self.dead[worker]:
            return DEAD
        if self.suspect[worker]:
            return SUSPECT
        return ALIVE

    @classmethod
    def from_rows(cls, rows: Sequence[SSTRow], reader: int = 0) -> "PackedViews":
        """Pack an already-annotated row list (tests, external callers).
        Row ``liveness`` annotations are taken at face value."""
        n = len(rows)
        pv = cls(
            reader=reader,
            ft=np.array([r.ft_estimate_s for r in rows]),
            bitmap=np.array([r.cache_bitmap for r in rows], dtype=np.uint64),
            avc=np.array([r.free_cache_bytes for r in rows]),
            pushed_at=np.array([r.pushed_at for r in rows]),
            intent=np.array([r.intent_bitmap for r in rows], dtype=np.uint64),
            fetch_model=np.array([r.fetch_model_id for r in rows], dtype=np.int64),
            fetch_eta=np.array([r.fetch_eta_s for r in rows]),
            dead=np.array([r.liveness == DEAD for r in rows], dtype=bool),
            suspect=np.array([r.liveness == SUSPECT for r in rows], dtype=bool),
        )
        assert pv.ft.shape == (n,)
        return pv


def classify_columns(
    lease: Optional[LeaseConfig],
    now: float,
    reader: int,
    heartbeat: np.ndarray,
    draining: np.ndarray,
    version: Optional[np.ndarray] = None,
) -> Tuple[np.ndarray, np.ndarray]:
    """Vectorized membership verdicts for one reader — the columnar twin
    of ``SharedStateTable.view`` / ``GossipPlane._classify_row``.

    Returns ``(dead, suspect)`` bool arrays.  ``version`` enables the
    gossip plane's never-heard-from ⇒ SUSPECT rule; ``heartbeat`` must
    already include any partition clamp the caller applies.
    """
    n = heartbeat.shape[0]
    if lease is None:
        return np.zeros(n, dtype=bool), np.zeros(n, dtype=bool)
    age = np.maximum(0.0, now - heartbeat)
    dead = age > lease.dead_after_s
    suspect = (~dead) & (age > lease.suspect_after_s)
    if version is not None:
        never_heard = version == 0
        dead = np.where(never_heard, False, dead)
        suspect = np.where(never_heard, True, suspect)
    # Self-evidence is never stale ...
    dead[reader] = False
    suspect[reader] = False
    # ... but a draining row is DEAD for placement, even the reader's own.
    dead = dead | draining
    suspect = suspect & ~draining
    return dead, suspect


__all__ = ["ColumnStore", "PackedViews", "classify_columns"]

"""Navigator core: the paper's contribution.

DFG/ADFG types, profile repository + upward ranking (Eq. 1), the Navigator
two-phase scheduler (Alg. 1 planning, Alg. 2 dynamic adjustment, Eq. 2–4),
the GPU memory manager (FIFO + queue-lookahead eviction), the decentralized
shared state table, and the baseline schedulers (JIT / HEFT / Hash).
"""

from repro.core.healthplane import (
    CalibrationReport,
    HealthConfig,
    HealthEvent,
    HealthMonitor,
    QuantileSketch,
    calibrate,
)
from repro.core.memory import CacheStats, GpuMemoryManager
from repro.core.netmodel import (
    AcceleratorLink,
    ClusterSpec,
    LinkSpec,
    NetworkModel,
    NetworkState,
    Topology,
    TPU_V5E_CLUSTER,
)
from repro.core.prefetch import (
    PrefetchConfig,
    PrefetchIntent,
    PrefetchPlane,
    PrefetchStats,
)
from repro.core.profiles import (
    FLEETS,
    ProfileRepository,
    RACK_FLEETS,
    WorkerProfile,
    build_fleet,
    fleet,
    rack_topology,
)
from repro.core.scheduler import (
    HEFTScheduler,
    HashScheduler,
    JITScheduler,
    NavigatorConfig,
    NavigatorScheduler,
    SCHEDULERS,
    Scheduler,
    make_scheduler,
)
from repro.core.sst_exchange import GossipConfig, GossipPlane
from repro.core.telemetry import (
    CandidateCost,
    FlightRecorder,
    MetricsRegistry,
    PlacementDecision,
    SimReport,
    TraceConfig,
    validate_schema,
)
from repro.core.state import (
    ALIVE,
    DEAD,
    LeaseConfig,
    SharedStateTable,
    SSTRow,
    SUSPECT,
)
from repro.core.types import ADFG, DFG, GB, Job, MB, MLModel, TaskSpec

__all__ = [
    "ADFG",
    "ALIVE",
    "AcceleratorLink",
    "CacheStats",
    "CalibrationReport",
    "CandidateCost",
    "ClusterSpec",
    "DEAD",
    "DFG",
    "FLEETS",
    "FlightRecorder",
    "GB",
    "GossipConfig",
    "GossipPlane",
    "GpuMemoryManager",
    "HEFTScheduler",
    "HashScheduler",
    "HealthConfig",
    "HealthEvent",
    "HealthMonitor",
    "JITScheduler",
    "Job",
    "LeaseConfig",
    "LinkSpec",
    "MB",
    "MLModel",
    "MetricsRegistry",
    "NavigatorConfig",
    "NavigatorScheduler",
    "NetworkModel",
    "NetworkState",
    "PlacementDecision",
    "PrefetchConfig",
    "PrefetchIntent",
    "PrefetchPlane",
    "PrefetchStats",
    "ProfileRepository",
    "QuantileSketch",
    "RACK_FLEETS",
    "SCHEDULERS",
    "SSTRow",
    "SUSPECT",
    "Scheduler",
    "SharedStateTable",
    "SimReport",
    "TPU_V5E_CLUSTER",
    "TaskSpec",
    "Topology",
    "TraceConfig",
    "WorkerProfile",
    "build_fleet",
    "calibrate",
    "fleet",
    "make_scheduler",
    "rack_topology",
    "validate_schema",
]

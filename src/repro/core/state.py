"""Decentralized Global State Monitor — the Shared State Table (§3.4, §5.2).

Every worker holds a replica of a one-row-per-worker table:

    [ FT estimate | cache bitmap (u64) | free cache bytes | push timestamp ]

A worker updates *its own* row locally at any time, but replicas on peers
only see the value as of the worker's last *push*.  Pushes are rate-limited
by ``push_interval_s`` (paper default 200 ms = 5 pushes/s, §5.2/§6.3.2);
the staleness a reader observes is therefore bounded by the interval.

``SharedStateTable`` models exactly this: ``local`` rows are ground truth
for the owning worker, ``published`` rows are what remote schedulers see.
The simulator calls ``push(worker, now)`` on the dissemination schedule.
Separate intervals for the load field and the cache field support the
staleness sensitivity study (Fig. 8), which varies them independently.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional


@dataclasses.dataclass
class SSTRow:
    """One worker's row.  ``ft_estimate_s`` is FT(w): the absolute time at
    which the worker expects to have drained its execution queue (§4.1).
    ``cache_bitmap`` encodes Navigator-cache contents; ``free_cache_bytes``
    is AVC(w)."""

    ft_estimate_s: float = 0.0
    cache_bitmap: int = 0
    free_cache_bytes: float = 0.0
    pushed_at: float = 0.0
    # Monotonic per-owner version; the gossip plane (sst_exchange.py) uses
    # it to merge replicas newest-wins and to ship version-vector diffs.
    version: int = 0
    # Prefetch-plane advertisement: resident ∪ in-flight ∪ queued-to-fetch
    # models (core/prefetch.py).  Superset of ``cache_bitmap`` when the
    # plane is enabled; 0 (inert) otherwise.
    intent_bitmap: int = 0

    def copy(self) -> "SSTRow":
        return SSTRow(
            self.ft_estimate_s,
            self.cache_bitmap,
            self.free_cache_bytes,
            self.pushed_at,
            self.version,
            self.intent_bitmap,
        )


class SharedStateTable:
    """Replicated per-worker state with bounded-staleness publication.

    For simplicity we model a single published copy (all peers see the same
    snapshot age); per-peer divergence below one push interval does not
    change scheduling behaviour, which only depends on the staleness bound.
    Load and cache fields may be published on different cadences, matching
    the two axes of Fig. 8.
    """

    def __init__(
        self,
        n_workers: int,
        push_interval_s: float = 0.2,
        cache_push_interval_s: Optional[float] = None,
    ) -> None:
        self.n_workers = n_workers
        self.push_interval_s = push_interval_s
        self.cache_push_interval_s = (
            push_interval_s if cache_push_interval_s is None else cache_push_interval_s
        )
        self.local: List[SSTRow] = [SSTRow() for _ in range(n_workers)]
        self.published: List[SSTRow] = [SSTRow() for _ in range(n_workers)]
        self._pushes = 0

    # -- local updates (free, instantaneous) -------------------------------
    # ``now`` stamps the local row's modification time (the same signature
    # the gossip plane uses), so a reader substituting its own local row
    # sees a current ``pushed_at`` and staleness-aware consumers don't
    # mistake own ground truth for ancient data.
    def update_load(
        self, worker: int, ft_estimate_s: float, now: float = 0.0
    ) -> None:
        row = self.local[worker]
        row.ft_estimate_s = ft_estimate_s
        row.pushed_at = max(row.pushed_at, now)

    def update_cache(
        self,
        worker: int,
        cache_bitmap: int,
        free_cache_bytes: float,
        now: float = 0.0,
    ) -> None:
        row = self.local[worker]
        row.cache_bitmap = cache_bitmap
        row.free_cache_bytes = free_cache_bytes
        row.pushed_at = max(row.pushed_at, now)

    def update_intent(
        self, worker: int, intent_bitmap: int, now: float = 0.0
    ) -> None:
        """Prefetch-plane advertisement (resident ∪ in-flight ∪ queued);
        rides the cache-field publication cadence."""
        row = self.local[worker]
        row.intent_bitmap = intent_bitmap
        row.pushed_at = max(row.pushed_at, now)

    # -- publication --------------------------------------------------------
    def push_load(self, worker: int, now: float) -> None:
        self.published[worker].ft_estimate_s = self.local[worker].ft_estimate_s
        self.published[worker].pushed_at = now
        self._pushes += 1

    def push_cache(self, worker: int, now: float) -> None:
        self.published[worker].cache_bitmap = self.local[worker].cache_bitmap
        self.published[worker].free_cache_bytes = self.local[worker].free_cache_bytes
        self.published[worker].intent_bitmap = self.local[worker].intent_bitmap
        self.published[worker].pushed_at = now
        self._pushes += 1

    def push(self, worker: int, now: float) -> None:
        self.push_load(worker, now)
        self.push_cache(worker, now)

    @property
    def total_pushes(self) -> int:
        return self._pushes

    # -- reads ---------------------------------------------------------------
    def view(self, reader_worker: Optional[int] = None) -> List[SSTRow]:
        """Snapshot as a scheduler on ``reader_worker`` sees it: its own row
        is always fresh (local), remote rows are the last published values.
        ``reader_worker=None`` returns the pure published view (used by a
        hypothetical external observer)."""
        rows = [r.copy() for r in self.published]
        if reader_worker is not None:
            rows[reader_worker] = self.local[reader_worker].copy()
        return rows

"""Decentralized Global State Monitor — the Shared State Table (§3.4, §5.2).

Every worker holds a replica of a one-row-per-worker table:

    [ FT estimate | cache bitmap (u64) | free cache bytes | push timestamp ]

A worker updates *its own* row locally at any time, but replicas on peers
only see the value as of the worker's last *push*.  Pushes are rate-limited
by ``push_interval_s`` (paper default 200 ms = 5 pushes/s, §5.2/§6.3.2);
the staleness a reader observes is therefore bounded by the interval.

``SharedStateTable`` models exactly this: ``local`` rows are ground truth
for the owning worker, ``published`` rows are what remote schedulers see.
The simulator calls ``push(worker, now)`` on the dissemination schedule.
Separate intervals for the load field and the cache field support the
staleness sensitivity study (Fig. 8), which varies them independently.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

# Membership states a *reader* assigns to a peer's row from its own view.
# There is no oracle: two workers can (and under partitions/drops do)
# disagree about whether a third is alive.
ALIVE = "alive"
SUSPECT = "suspect"
DEAD = "dead"


@dataclasses.dataclass(frozen=True)
class LeaseConfig:
    """Heartbeat/lease tunables for the membership lane.

    Each worker stamps its own row with a heartbeat (``heartbeat_s``)
    every ``heartbeat_period_s``; the stamp disseminates like any other
    row mutation.  A reader classifies a peer from the *replicated* stamp
    age — ALIVE below ``suspect_after_s``, SUSPECT up to ``dead_after_s``,
    DEAD beyond — so detection latency includes dissemination lag, and
    every worker decides from its own, possibly stale, evidence.

    Defaults assume the paper's 200 ms gossip cadence: a lease survives a
    few dropped rounds (no flapping) but a crash is declared dead within
    ~4 s, well under typical re-execution costs.
    """

    heartbeat_period_s: float = 0.25
    suspect_after_s: float = 1.5
    dead_after_s: float = 4.0
    # RPC/connection timeout a dispatcher pays before concluding that a
    # worker it shipped work to is unreachable and failing over — the
    # per-contact price of acting on a stale ALIVE verdict.
    dead_letter_timeout_s: float = 1.0

    @property
    def detection_delay_s(self) -> float:
        """Time from a silent crash to the moment a peer's replicated
        heartbeat age crosses ``dead_after_s``: the lease bound plus the
        dissemination lag of the last heartbeat it did send."""
        return self.dead_after_s + 2.0 * self.heartbeat_period_s

    def classify(self, heartbeat_age_s: float) -> str:
        if heartbeat_age_s > self.dead_after_s:
            return DEAD
        if heartbeat_age_s > self.suspect_after_s:
            return SUSPECT
        return ALIVE


@dataclasses.dataclass
class SSTRow:
    """One worker's row.  ``ft_estimate_s`` is FT(w): the absolute time at
    which the worker expects to have drained its execution queue (§4.1).
    ``cache_bitmap`` encodes Navigator-cache contents; ``free_cache_bytes``
    is AVC(w)."""

    ft_estimate_s: float = 0.0
    cache_bitmap: int = 0
    free_cache_bytes: float = 0.0
    pushed_at: float = 0.0
    # Monotonic per-owner version; the gossip plane (sst_exchange.py) uses
    # it to merge replicas newest-wins and to ship version-vector diffs.
    version: int = 0
    # Prefetch-plane advertisement: resident ∪ in-flight ∪ queued-to-fetch
    # models (core/prefetch.py).  Superset of ``cache_bitmap`` when the
    # plane is enabled; 0 (inert) otherwise.
    intent_bitmap: int = 0
    # Membership lane: the owner's last self-stamped heartbeat time, its
    # incarnation (bumped on every rejoin so pre-crash rows can never
    # overwrite post-rejoin state), and a graceful-departure flag.
    heartbeat_s: float = 0.0
    epoch: int = 0
    draining: bool = False
    # Prefetch-plane expected-completion advertisement: the model id of
    # the owner's *in-flight* fetch (−1 = none) and its absolute expected
    # completion time.  Planners use the remaining transfer fraction to
    # scale the intent discount (a nearly-done fetch is nearly free).
    fetch_model_id: int = -1
    fetch_eta_s: float = 0.0
    # Health-digest lane (core/healthplane.py): the owner's four-field
    # health summary, refreshed right before each publication so every
    # reader holds a staleness-bounded view of fleet health with no
    # oracle — wire lanes 12–15 in sst_exchange.py.
    health_queue_depth: int = 0
    health_mem_occupancy: float = 0.0
    health_fetch_util: float = 0.0
    health_p99_latency_s: float = 0.0
    # Reader-side annotation (NOT wire state): the membership state the
    # reader that produced this view assigns the row.  Filled by
    # ``view(..., now=...)`` when a lease is configured; planners cost
    # SUSPECT rows with a penalty and DEAD rows at infinity.
    liveness: str = ALIVE

    def copy(self) -> "SSTRow":
        return SSTRow(
            self.ft_estimate_s,
            self.cache_bitmap,
            self.free_cache_bytes,
            self.pushed_at,
            self.version,
            self.intent_bitmap,
            self.heartbeat_s,
            self.epoch,
            self.draining,
            self.fetch_model_id,
            self.fetch_eta_s,
            self.health_queue_depth,
            self.health_mem_occupancy,
            self.health_fetch_util,
            self.health_p99_latency_s,
            self.liveness,
        )

    def merge_key(self) -> "tuple[int, int]":
        """Newest-wins merge order across crash boundaries: a rejoined
        worker restarts version at 1 but bumps epoch, so (epoch, version)
        keeps post-rejoin rows strictly newer than any pre-crash replica."""
        return (self.epoch, self.version)


class SharedStateTable:
    """Replicated per-worker state with bounded-staleness publication.

    For simplicity we model a single published copy (all peers see the same
    snapshot age); per-peer divergence below one push interval does not
    change scheduling behaviour, which only depends on the staleness bound.
    Load and cache fields may be published on different cadences, matching
    the two axes of Fig. 8.
    """

    def __init__(
        self,
        n_workers: int,
        push_interval_s: float = 0.2,
        cache_push_interval_s: Optional[float] = None,
        lease: Optional[LeaseConfig] = None,
    ) -> None:
        self.n_workers = n_workers
        self.push_interval_s = push_interval_s
        self.cache_push_interval_s = (
            push_interval_s if cache_push_interval_s is None else cache_push_interval_s
        )
        # Membership lane (None = static fleet, rows always ALIVE).
        self.lease = lease
        self.local: List[SSTRow] = [SSTRow() for _ in range(n_workers)]
        self.published: List[SSTRow] = [SSTRow() for _ in range(n_workers)]
        self._pushes = 0
        # Open network partition: (worker -> group id, cut start time), or
        # None when fully connected.  See ``set_partition``.
        self._partition: Optional[tuple] = None
        self._partition_groups: Optional[np.ndarray] = None
        # Columnar mirror of ``published`` for the packed read path
        # (``view_arrays``), kept in sync O(1) per push/join.  Deferred
        # import: packed.py imports this module for the row types.
        from repro.core.packed import ColumnStore

        self._cols = ColumnStore(n_workers)

    # -- local updates (free, instantaneous) -------------------------------
    # ``now`` stamps the local row's modification time (the same signature
    # the gossip plane uses), so a reader substituting its own local row
    # sees a current ``pushed_at`` and staleness-aware consumers don't
    # mistake own ground truth for ancient data.
    def update_load(
        self, worker: int, ft_estimate_s: float, now: float = 0.0
    ) -> None:
        row = self.local[worker]
        row.ft_estimate_s = ft_estimate_s
        row.pushed_at = max(row.pushed_at, now)

    def update_cache(
        self,
        worker: int,
        cache_bitmap: int,
        free_cache_bytes: float,
        now: float = 0.0,
        fetch_model_id: int = -1,
        fetch_eta_s: float = 0.0,
    ) -> None:
        row = self.local[worker]
        row.cache_bitmap = cache_bitmap
        row.free_cache_bytes = free_cache_bytes
        row.fetch_model_id = fetch_model_id
        row.fetch_eta_s = fetch_eta_s
        row.pushed_at = max(row.pushed_at, now)

    def update_intent(
        self, worker: int, intent_bitmap: int, now: float = 0.0
    ) -> None:
        """Prefetch-plane advertisement (resident ∪ in-flight ∪ queued);
        rides the cache-field publication cadence."""
        row = self.local[worker]
        row.intent_bitmap = intent_bitmap
        row.pushed_at = max(row.pushed_at, now)

    def update_health(
        self,
        worker: int,
        queue_depth: int,
        mem_occupancy: float,
        fetch_util: float,
        p99_latency_s: float,
        now: float = 0.0,
    ) -> None:
        """Health-digest lane (core/healthplane.py): the engine refreshes
        the owner's four-field digest right before each publication, so
        the replicated view's staleness is bounded by the push interval
        like every other lane."""
        row = self.local[worker]
        row.health_queue_depth = queue_depth
        row.health_mem_occupancy = mem_occupancy
        row.health_fetch_util = fetch_util
        row.health_p99_latency_s = p99_latency_s
        row.pushed_at = max(row.pushed_at, now)

    # -- membership (heartbeat/lease lane) -----------------------------------
    def heartbeat(self, worker: int, now: float) -> None:
        """Owner self-stamp; reaches peers on the next push (so lease age
        as observed includes publication lag, same as the gossip plane)."""
        row = self.local[worker]
        row.heartbeat_s = max(row.heartbeat_s, now)
        row.pushed_at = max(row.pushed_at, now)

    def set_draining(self, worker: int, draining: bool, now: float = 0.0) -> None:
        row = self.local[worker]
        row.draining = draining
        row.pushed_at = max(row.pushed_at, now)

    def join(self, worker: int, now: float) -> None:
        """A (re)joining worker: new incarnation, empty row.  The single
        published snapshot makes bootstrap trivial here; the gossip plane
        models the real anti-entropy full-sync path."""
        old = self.local[worker]
        fresh = SSTRow(heartbeat_s=now, pushed_at=now, epoch=old.epoch + 1)
        self.local[worker] = fresh
        self.published[worker] = fresh.copy()
        self._cols.set_row(worker, fresh)

    # -- publication --------------------------------------------------------
    def push_load(self, worker: int, now: float) -> None:
        self.published[worker].ft_estimate_s = self.local[worker].ft_estimate_s
        # The liveness lane rides every publication.
        self.published[worker].heartbeat_s = self.local[worker].heartbeat_s
        self.published[worker].draining = self.local[worker].draining
        self.published[worker].epoch = self.local[worker].epoch
        # The health-digest lane rides the load cadence (both describe
        # the owner's instantaneous busyness).
        self.published[worker].health_queue_depth = self.local[worker].health_queue_depth
        self.published[worker].health_mem_occupancy = self.local[worker].health_mem_occupancy
        self.published[worker].health_fetch_util = self.local[worker].health_fetch_util
        self.published[worker].health_p99_latency_s = self.local[worker].health_p99_latency_s
        self.published[worker].pushed_at = now
        pub, cols = self.published[worker], self._cols
        cols.ft[worker] = pub.ft_estimate_s
        cols.heartbeat[worker] = pub.heartbeat_s
        cols.draining[worker] = pub.draining
        cols.pushed_at[worker] = now
        self._pushes += 1

    def push_cache(self, worker: int, now: float) -> None:
        self.published[worker].cache_bitmap = self.local[worker].cache_bitmap
        self.published[worker].free_cache_bytes = self.local[worker].free_cache_bytes
        self.published[worker].intent_bitmap = self.local[worker].intent_bitmap
        self.published[worker].fetch_model_id = self.local[worker].fetch_model_id
        self.published[worker].fetch_eta_s = self.local[worker].fetch_eta_s
        self.published[worker].heartbeat_s = self.local[worker].heartbeat_s
        self.published[worker].draining = self.local[worker].draining
        self.published[worker].epoch = self.local[worker].epoch
        self.published[worker].pushed_at = now
        pub, cols = self.published[worker], self._cols
        cols.bitmap[worker] = pub.cache_bitmap
        cols.avc[worker] = pub.free_cache_bytes
        cols.intent[worker] = pub.intent_bitmap
        cols.fetch_model[worker] = pub.fetch_model_id
        cols.fetch_eta[worker] = pub.fetch_eta_s
        cols.heartbeat[worker] = pub.heartbeat_s
        cols.draining[worker] = pub.draining
        cols.pushed_at[worker] = now
        self._pushes += 1

    def push(self, worker: int, now: float) -> None:
        self.push_load(worker, now)
        self.push_cache(worker, now)

    @property
    def total_pushes(self) -> int:
        return self._pushes

    # -- partitions ----------------------------------------------------------
    def set_partition(
        self, group_of: Optional[List[int]], now: float = 0.0
    ) -> None:
        """Install (or with ``None`` heal) a network cut, as a worker ->
        group-id map.  The single published snapshot models a table
        replicated on every side of the cut: writes keep landing on the
        writer's own side, but a *reader* stops receiving heartbeats from
        workers across the cut, so ``view`` classifies those rows from the
        frozen pre-cut heartbeat — per-reader lease verdicts disagree
        across the cut while every same-side verdict stays fresh, matching
        the gossip plane's behaviour without per-reader row copies (the
        planner ignores the payload of SUSPECT/DEAD rows anyway)."""
        self._partition = None if group_of is None else (list(group_of), now)
        self._partition_groups = (
            None if group_of is None else np.asarray(group_of, dtype=np.int64)
        )

    # -- reads ---------------------------------------------------------------
    def view(
        self,
        reader_worker: Optional[int] = None,
        now: Optional[float] = None,
    ) -> List[SSTRow]:
        """Snapshot as a scheduler on ``reader_worker`` sees it: its own row
        is always fresh (local), remote rows are the last published values.
        ``reader_worker=None`` returns the pure published view (used by a
        hypothetical external observer).  With a lease configured and
        ``now`` given, each row is annotated with the membership state the
        reader derives from the replicated heartbeat age."""
        rows = [r.copy() for r in self.published]
        if reader_worker is not None:
            rows[reader_worker] = self.local[reader_worker].copy()
        if self.lease is not None and now is not None:
            for w, row in enumerate(rows):
                if row.draining:
                    row.liveness = DEAD  # graceful departure: no new work
                elif w == reader_worker:
                    row.liveness = ALIVE  # self-evidence is never stale
                else:
                    hb = row.heartbeat_s
                    if self._partition is not None and reader_worker is not None:
                        group_of, cut_start = self._partition
                        if group_of[reader_worker] != group_of[w]:
                            # The reader's last heartbeat from across the
                            # cut is the fresher of the owner's pre-cut
                            # stamp and the cut onset.
                            hb = min(hb, cut_start)
                    row.liveness = self.lease.classify(max(0.0, now - hb))
        return rows

    def view_arrays(self, reader_worker: int, now: float):
        """Columnar twin of :meth:`view` for the indexed engine: the same
        snapshot (own row fresh, peers last-published, per-reader lease
        verdicts incl. the partition heartbeat clamp) as packed ``(W,)``
        arrays.  A handful of numpy column copies instead of W python row
        copies — the values are bit-identical to the row-list path."""
        from repro.core.packed import PackedViews, classify_columns

        c = self._cols
        ft = c.ft.copy()
        bitmap = c.bitmap.copy()
        avc = c.avc.copy()
        pushed = c.pushed_at.copy()
        intent = c.intent.copy()
        fetch_model = c.fetch_model.copy()
        fetch_eta = c.fetch_eta.copy()
        hb = c.heartbeat.copy()
        draining = c.draining.copy()
        loc = self.local[reader_worker]
        ft[reader_worker] = loc.ft_estimate_s
        bitmap[reader_worker] = loc.cache_bitmap
        avc[reader_worker] = loc.free_cache_bytes
        pushed[reader_worker] = loc.pushed_at
        intent[reader_worker] = loc.intent_bitmap
        fetch_model[reader_worker] = loc.fetch_model_id
        fetch_eta[reader_worker] = loc.fetch_eta_s
        hb[reader_worker] = loc.heartbeat_s
        draining[reader_worker] = loc.draining
        if self._partition is not None and self.lease is not None:
            groups = self._partition_groups
            cut_start = self._partition[1]
            cross = groups != groups[reader_worker]
            hb = np.where(cross, np.minimum(hb, cut_start), hb)
        dead, suspect = classify_columns(
            self.lease, now, reader_worker, hb, draining
        )
        return PackedViews(
            reader=reader_worker, ft=ft, bitmap=bitmap, avc=avc,
            pushed_at=pushed, intent=intent, fetch_model=fetch_model,
            fetch_eta=fetch_eta, dead=dead, suspect=suspect,
        )

"""Transfer-time models (§4.1).

The paper estimates:
  TD_input(t)      = |input_t| / network transmission capacity + delta_network
  TD_model(m, w)   = |m| / PCIe transmission capacity_w + delta_PCIe(w)

Both are the "commonly accepted heuristic" linear size/bandwidth models.
The experimental cluster is RDMA/InfiniBand 100 Gbps with Tesla T4 GPUs
(16 GB, PCIe 3.0 x16); we keep those constants as defaults so the simulator
reproduces the paper, and expose a TPU-v5e flavoured profile used by the
real serving engine (HBM 819 GB/s, ICI ~50 GB/s/link).
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, List, Optional, Tuple

from repro.core.types import GB


@dataclasses.dataclass(frozen=True)
class NetworkModel:
    """Worker↔worker object transfer cost model (flat all-pairs table)."""

    bandwidth_bytes_per_s: float = 100e9 / 8.0  # 100 Gbps RDMA
    delta_s: float = 1e-3  # constant latency term (delta_network)

    def transfer_time(self, nbytes: float) -> float:
        if nbytes <= 0:
            return 0.0
        return nbytes / self.bandwidth_bytes_per_s + self.delta_s


@dataclasses.dataclass(frozen=True)
class LinkSpec:
    """One physical link class: capacity plus a constant per-hop latency."""

    bandwidth_bytes_per_s: float
    delta_s: float = 0.0


@dataclasses.dataclass(frozen=True)
class Topology:
    """Two-tier rack topology: workers sit on non-blocking rack-local
    links; each rack reaches the spine through one shared (and typically
    oversubscribed) uplink.

    Path model:

    * same worker          — zero cost.
    * same rack            — ``rack_link`` bandwidth + one hop latency
                             (the ToR is non-blocking, so rack-local
                             transfers never contend).
    * cross rack           — bottleneck of the rack link and *both* rack
                             uplinks, plus one rack hop and one spine
                             hop of latency.  Concurrent transfers that
                             share an uplink divide its capacity
                             (fair-share contention, see
                             :class:`NetworkState`).
    """

    rack_of: Tuple[int, ...]
    rack_link: LinkSpec = LinkSpec(100e9 / 8.0, 1e-3)
    uplink: LinkSpec = LinkSpec(100e9 / 8.0 / 4.0, 1e-3)

    def __post_init__(self) -> None:
        if not self.rack_of:
            raise ValueError("topology needs at least one worker")
        racks = set(self.rack_of)
        if racks != set(range(len(racks))):
            raise ValueError(
                f"rack ids must be contiguous from 0, got {sorted(racks)}"
            )

    @property
    def n_workers(self) -> int:
        return len(self.rack_of)

    @property
    def n_racks(self) -> int:
        return max(self.rack_of) + 1

    def rack(self, worker: int) -> int:
        return self.rack_of[worker]

    def path_uplinks(self, src: int, dst: int) -> Tuple[int, ...]:
        """Rack uplinks a ``src → dst`` transfer crosses (the contended
        resources); empty for worker- or rack-local paths."""
        rs, rd = self.rack_of[src], self.rack_of[dst]
        if rs == rd:
            return ()
        return (rs, rd)

    def transfer_time(
        self,
        nbytes: float,
        src: int,
        dst: int,
        uplink_shares: Optional[Tuple[float, ...]] = None,
    ) -> float:
        """Effective transfer time along the ``src → dst`` path.

        ``uplink_shares`` optionally scales each crossed uplink's
        capacity (fair-share fraction in ``(0, 1]``); omitted means the
        uncontended path cost the planners price with.
        """
        if nbytes <= 0 or src == dst:
            return 0.0
        rs, rd = self.rack_of[src], self.rack_of[dst]
        if rs == rd:
            return (
                nbytes / self.rack_link.bandwidth_bytes_per_s
                + self.rack_link.delta_s
            )
        bw = self.rack_link.bandwidth_bytes_per_s
        ups = (1.0, 1.0) if uplink_shares is None else uplink_shares
        for share in ups:
            bw = min(bw, self.uplink.bandwidth_bytes_per_s * share)
        return nbytes / bw + self.rack_link.delta_s + self.uplink.delta_s

    def pair_matrices(self) -> Tuple[List[List[float]], List[List[float]]]:
        """(inverse-bandwidth, latency) matrices over worker pairs for the
        vectorized planner: ``time(src→dst) = nbytes * inv_bw[src][dst]
        + delta[src][dst]`` (uncontended; diagonal is zero).

        Pure function of the (frozen) topology, so the O(W²) build is
        memoized on the instance — planners used to rebuild it on every
        plan call.  Callers must treat the returned matrices as
        read-only."""
        cached = getattr(self, "_pair_matrices_cache", None)
        if cached is not None:
            return cached
        n = self.n_workers
        inv_bw = [[0.0] * n for _ in range(n)]
        delta = [[0.0] * n for _ in range(n)]
        for s in range(n):
            for d in range(n):
                if s == d:
                    continue
                if self.rack_of[s] == self.rack_of[d]:
                    inv_bw[s][d] = 1.0 / self.rack_link.bandwidth_bytes_per_s
                    delta[s][d] = self.rack_link.delta_s
                else:
                    bw = min(
                        self.rack_link.bandwidth_bytes_per_s,
                        self.uplink.bandwidth_bytes_per_s,
                    )
                    inv_bw[s][d] = 1.0 / bw
                    delta[s][d] = self.rack_link.delta_s + self.uplink.delta_s
        # Frozen dataclass: stash the memo via object.__setattr__.
        object.__setattr__(self, "_pair_matrices_cache", (inv_bw, delta))
        return inv_bw, delta

    def mean_path_factors(self) -> Tuple[float, float]:
        """Mean (inverse bandwidth, latency) over distinct worker pairs —
        the topology analogue of the flat table for static ranks (Eq. 1),
        which price a representative transfer before placement is known.
        Memoized alongside :meth:`pair_matrices`."""
        cached = getattr(self, "_mean_factors_cache", None)
        if cached is not None:
            return cached
        inv_bw, delta = self.pair_matrices()
        n = self.n_workers
        if n < 2:
            return 1.0 / self.rack_link.bandwidth_bytes_per_s, \
                self.rack_link.delta_s
        pairs = [(s, d) for s in range(n) for d in range(n) if s != d]
        out = (
            sum(inv_bw[s][d] for s, d in pairs) / len(pairs),
            sum(delta[s][d] for s, d in pairs) / len(pairs),
        )
        object.__setattr__(self, "_mean_factors_cache", out)
        return out


class NetworkState:
    """Mutable fair-share contention tracker over a :class:`Topology`.

    Each rack uplink carries a lazily-expired heap of in-flight transfer
    end times.  A new bulk transfer sees each crossed uplink's capacity
    divided by ``active flows + 1`` (itself); in-flight transfers are
    never re-timed, so admitting a new flow can only slow the *new*
    transfer — contention is monotone by construction, and the whole
    tracker is deterministic under a fixed event order.
    """

    def __init__(self, topology: Topology) -> None:
        self.topology = topology
        self._flows: List[List[float]] = [[] for _ in range(topology.n_racks)]
        self.bulk_transfers = 0
        self.contended_transfers = 0
        # Fair-share fractions applied to the most recent transfer_time
        # call, one per crossed uplink (empty for local paths).  The
        # flight recorder reads this to tag each traced transfer with
        # the contention share it actually received.
        self.last_shares: Tuple[float, ...] = ()

    def active_flows(self, rack: int, now: float) -> int:
        heap = self._flows[rack]
        while heap and heap[0] <= now:
            heapq.heappop(heap)
        return len(heap)

    def transfer_time(self, nbytes: float, src: int, dst: int,
                      now: float) -> float:
        """Contention-aware path time if a transfer started at ``now``
        (does not register the flow)."""
        uplinks = self.topology.path_uplinks(src, dst)
        if not uplinks:
            self.last_shares = ()
            return self.topology.transfer_time(nbytes, src, dst)
        shares = tuple(
            1.0 / (self.active_flows(r, now) + 1) for r in uplinks
        )
        self.last_shares = shares
        return self.topology.transfer_time(nbytes, src, dst, shares)

    def start_transfer(self, nbytes: float, src: int, dst: int,
                       now: float) -> float:
        """Register a bulk transfer starting at ``now`` on every uplink
        along its path; returns its (contended) duration."""
        dur = self.transfer_time(nbytes, src, dst, now)
        uplinks = self.topology.path_uplinks(src, dst)
        if uplinks and nbytes > 0:
            self.bulk_transfers += 1
            if any(self.active_flows(r, now) for r in uplinks):
                self.contended_transfers += 1
            for r in uplinks:
                heapq.heappush(self._flows[r], now + dur)
        return dur


@dataclasses.dataclass(frozen=True)
class AcceleratorLink:
    """Host→accelerator model fetch cost model (PCIe on the paper's T4
    testbed; HBM load on TPU).

    The Navigator cache holds model objects *compressed*; making a model
    executable requires transfer + decompression + framework initialization
    (§3.3).  The effective bandwidth is therefore far below raw PCIe —
    2 GB/s effective makes a several-GB model a multi-second fetch, which
    matches the paper's premise that "it is costly to fetch large models at
    the last instant" against 1–3 s idle job completion times.
    """

    bandwidth_bytes_per_s: float = 2.0 * GB  # transfer+decompress+init
    delta_s: float = 0.1  # delta_PCIe: driver/alloc constant

    def fetch_time(self, nbytes: float) -> float:
        if nbytes <= 0:
            return 0.0
        return nbytes / self.bandwidth_bytes_per_s + self.delta_s


@dataclasses.dataclass(frozen=True)
class ClusterSpec:
    """Static description of the worker cluster.

    The paper's testbed (§6): 5 workers, Tesla T4 (16 GB) each, dual Xeon
    Gold 6242, 192 GB host DRAM, 100 Gbps InfiniBand.  ``worker_speed``
    allows heterogeneous workers (HEFT heritage); R(t, w) =
    runtime_s / worker_speed[w].
    """

    n_workers: int = 5
    gpu_capacity_bytes: float = 16.0 * GB
    network: NetworkModel = dataclasses.field(default_factory=NetworkModel)
    link: AcceleratorLink = dataclasses.field(default_factory=AcceleratorLink)
    worker_speed: Optional[Dict[int, float]] = None
    # Per-worker GPU memory overrides (heterogeneous fleets); workers not
    # listed fall back to ``gpu_capacity_bytes``.
    worker_gpu_capacity: Optional[Dict[int, float]] = None
    # Compressed/decompressed bytes ratio for Navigator-cache accounting
    # (§3.3: the cache holds models compressed; execution memory holds a
    # decompressed instance per active task).
    compression_ratio: float = 0.6
    # Energy proxy (Table 1): active vs idle GPU power draw.
    gpu_power_active_w: float = 70.0  # T4 TDP
    gpu_power_idle_w: float = 10.0
    # Optional rack topology.  ``None`` (the default) preserves the flat
    # all-pairs table exactly: every path cost delegates to ``network``.
    topology: Optional[Topology] = None

    def __post_init__(self) -> None:
        if (
            self.topology is not None
            and self.topology.n_workers != self.n_workers
        ):
            raise ValueError(
                f"topology covers {self.topology.n_workers} workers, "
                f"cluster has {self.n_workers}"
            )

    def speed(self, worker: int) -> float:
        if self.worker_speed is None:
            return 1.0
        return self.worker_speed.get(worker, 1.0)

    def gpu_capacity(self, worker: int) -> float:
        """GPU memory of ``worker`` (heterogeneous fleets override the
        uniform ``gpu_capacity_bytes`` per worker)."""
        if self.worker_gpu_capacity is None:
            return self.gpu_capacity_bytes
        return self.worker_gpu_capacity.get(worker, self.gpu_capacity_bytes)

    @property
    def total_speed(self) -> float:
        """Aggregate fleet throughput multiplier (used to hold offered
        load constant when sweeping fleet heterogeneity)."""
        return sum(self.speed(w) for w in self.workers())

    def runtime_on(self, base_runtime_s: float, worker: int) -> float:
        """R(t, w) from the profiled base runtime R(t)."""
        return base_runtime_s / self.speed(worker)

    def workers(self) -> range:
        return range(self.n_workers)

    def path_transfer_time(self, nbytes: float, src: int, dst: int) -> float:
        """Uncontended ``src → dst`` transfer time: the flat table when no
        topology is configured (bit-exact with the pre-topology model),
        the path cost otherwise."""
        if self.topology is None:
            return self.network.transfer_time(nbytes)
        return self.topology.transfer_time(nbytes, src, dst)


TPU_V5E_CLUSTER = ClusterSpec(
    n_workers=16,
    gpu_capacity_bytes=16.0 * GB,  # v5e HBM per chip
    network=NetworkModel(bandwidth_bytes_per_s=50.0 * GB, delta_s=2e-6),  # ICI
    link=AcceleratorLink(bandwidth_bytes_per_s=819.0 * GB, delta_s=1e-4),  # HBM
    gpu_power_active_w=200.0,
    gpu_power_idle_w=40.0,
)

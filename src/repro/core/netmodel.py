"""Transfer-time models (§4.1).

The paper estimates:
  TD_input(t)      = |input_t| / network transmission capacity + delta_network
  TD_model(m, w)   = |m| / PCIe transmission capacity_w + delta_PCIe(w)

Both are the "commonly accepted heuristic" linear size/bandwidth models.
The experimental cluster is RDMA/InfiniBand 100 Gbps with Tesla T4 GPUs
(16 GB, PCIe 3.0 x16); we keep those constants as defaults so the simulator
reproduces the paper, and expose a TPU-v5e flavoured profile used by the
real serving engine (HBM 819 GB/s, ICI ~50 GB/s/link).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.core.types import GB


@dataclasses.dataclass(frozen=True)
class NetworkModel:
    """Worker↔worker object transfer cost model."""

    bandwidth_bytes_per_s: float = 100e9 / 8.0  # 100 Gbps RDMA
    delta_s: float = 1e-3  # constant latency term (delta_network)

    def transfer_time(self, nbytes: float) -> float:
        if nbytes <= 0:
            return 0.0
        return nbytes / self.bandwidth_bytes_per_s + self.delta_s


@dataclasses.dataclass(frozen=True)
class AcceleratorLink:
    """Host→accelerator model fetch cost model (PCIe on the paper's T4
    testbed; HBM load on TPU).

    The Navigator cache holds model objects *compressed*; making a model
    executable requires transfer + decompression + framework initialization
    (§3.3).  The effective bandwidth is therefore far below raw PCIe —
    2 GB/s effective makes a several-GB model a multi-second fetch, which
    matches the paper's premise that "it is costly to fetch large models at
    the last instant" against 1–3 s idle job completion times.
    """

    bandwidth_bytes_per_s: float = 2.0 * GB  # transfer+decompress+init
    delta_s: float = 0.1  # delta_PCIe: driver/alloc constant

    def fetch_time(self, nbytes: float) -> float:
        if nbytes <= 0:
            return 0.0
        return nbytes / self.bandwidth_bytes_per_s + self.delta_s


@dataclasses.dataclass(frozen=True)
class ClusterSpec:
    """Static description of the worker cluster.

    The paper's testbed (§6): 5 workers, Tesla T4 (16 GB) each, dual Xeon
    Gold 6242, 192 GB host DRAM, 100 Gbps InfiniBand.  ``worker_speed``
    allows heterogeneous workers (HEFT heritage); R(t, w) =
    runtime_s / worker_speed[w].
    """

    n_workers: int = 5
    gpu_capacity_bytes: float = 16.0 * GB
    network: NetworkModel = dataclasses.field(default_factory=NetworkModel)
    link: AcceleratorLink = dataclasses.field(default_factory=AcceleratorLink)
    worker_speed: Optional[Dict[int, float]] = None
    # Per-worker GPU memory overrides (heterogeneous fleets); workers not
    # listed fall back to ``gpu_capacity_bytes``.
    worker_gpu_capacity: Optional[Dict[int, float]] = None
    # Compressed/decompressed bytes ratio for Navigator-cache accounting
    # (§3.3: the cache holds models compressed; execution memory holds a
    # decompressed instance per active task).
    compression_ratio: float = 0.6
    # Energy proxy (Table 1): active vs idle GPU power draw.
    gpu_power_active_w: float = 70.0  # T4 TDP
    gpu_power_idle_w: float = 10.0

    def speed(self, worker: int) -> float:
        if self.worker_speed is None:
            return 1.0
        return self.worker_speed.get(worker, 1.0)

    def gpu_capacity(self, worker: int) -> float:
        """GPU memory of ``worker`` (heterogeneous fleets override the
        uniform ``gpu_capacity_bytes`` per worker)."""
        if self.worker_gpu_capacity is None:
            return self.gpu_capacity_bytes
        return self.worker_gpu_capacity.get(worker, self.gpu_capacity_bytes)

    @property
    def total_speed(self) -> float:
        """Aggregate fleet throughput multiplier (used to hold offered
        load constant when sweeping fleet heterogeneity)."""
        return sum(self.speed(w) for w in self.workers())

    def runtime_on(self, base_runtime_s: float, worker: int) -> float:
        """R(t, w) from the profiled base runtime R(t)."""
        return base_runtime_s / self.speed(worker)

    def workers(self) -> range:
        return range(self.n_workers)


TPU_V5E_CLUSTER = ClusterSpec(
    n_workers=16,
    gpu_capacity_bytes=16.0 * GB,  # v5e HBM per chip
    network=NetworkModel(bandwidth_bytes_per_s=50.0 * GB, delta_s=2e-6),  # ICI
    link=AcceleratorLink(bandwidth_bytes_per_s=819.0 * GB, delta_s=1e-4),  # HBM
    gpu_power_active_w=200.0,
    gpu_power_idle_w=40.0,
)

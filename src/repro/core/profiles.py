"""Repository of Workflow Profiles (§3.1) and vertex ranking (§4.2.1).

Holds static DFG metadata: expected runtimes R(t), input/output object
sizes, model sizes — plus the statically computed upward ranks (Eq. 1):

    rank(t) = R(t) + max_{t ≺ t'} (TD_output(t) + rank(t'))

Ranks depend only on the DFG and the cluster's network model, so Navigator
computes them once when the DFG is loaded and caches them here (§4.2.1);
dynamic inputs merely update, not recompute, the static values.
"""

from __future__ import annotations

from typing import Dict, List, Mapping, Optional, Tuple

from repro.core.netmodel import ClusterSpec
from repro.core.types import DFG, MLModel, TaskSpec


class ProfileRepository:
    def __init__(self, cluster: ClusterSpec, models: Mapping[int, MLModel]) -> None:
        self.cluster = cluster
        self.models: Dict[int, MLModel] = dict(models)
        self._dfgs: Dict[str, DFG] = {}
        self._ranks: Dict[str, Dict[str, float]] = {}

    # -- registration ---------------------------------------------------------
    def register(self, dfg: DFG) -> None:
        for t in dfg.tasks.values():
            if t.model_id is not None and t.model_id not in self.models:
                raise KeyError(
                    f"DFG {dfg.name!r} task {t.task_id!r} references "
                    f"unknown model {t.model_id}"
                )
        self._dfgs[dfg.name] = dfg
        self._ranks[dfg.name] = self._compute_ranks(dfg)

    def dfg(self, name: str) -> DFG:
        return self._dfgs[name]

    def dfgs(self) -> List[DFG]:
        return list(self._dfgs.values())

    # -- parameters (§4.1) -----------------------------------------------------
    def runtime(self, task: TaskSpec, worker: int) -> float:
        """R(t, w)."""
        return self.cluster.runtime_on(task.runtime_s, worker)

    def mean_runtime(self, task: TaskSpec) -> float:
        """R(t): average of R(t, w) over the worker set (§4.2.1)."""
        speeds = [self.cluster.speed(w) for w in self.cluster.workers()]
        return task.runtime_s * sum(1.0 / s for s in speeds) / len(speeds)

    def td_output(self, task: TaskSpec) -> float:
        """TD_output(t): time to move the task's output between workers."""
        return self.cluster.network.transfer_time(task.output_bytes)

    def td_input(self, task: TaskSpec) -> float:
        """TD_input(t): time to move the task's (external) input."""
        return self.cluster.network.transfer_time(task.input_bytes)

    def td_model(self, model_id: Optional[int]) -> float:
        """TD_model(m, w) for a cache miss (uniform link assumed unless the
        cluster defines per-worker links)."""
        if model_id is None:
            return 0.0
        return self.cluster.link.fetch_time(self.models[model_id].size_bytes)

    def model_size(self, model_id: Optional[int]) -> float:
        if model_id is None:
            return 0.0
        return self.models[model_id].size_bytes

    def cached_model_size(self, model_id: Optional[int]) -> float:
        """Compressed in-cache footprint (§3.3)."""
        if model_id is None:
            return 0.0
        return self.models[model_id].size_bytes * self.cluster.compression_ratio

    # -- ranking (Eq. 1) ---------------------------------------------------------
    def _compute_ranks(self, dfg: DFG) -> Dict[str, float]:
        ranks: Dict[str, float] = {}
        for tid in reversed(dfg.topo_order):
            task = dfg.tasks[tid]
            succ_term = 0.0
            if dfg.succs[tid]:
                succ_term = max(
                    self.td_output(task) + ranks[s] for s in dfg.succs[tid]
                )
            ranks[tid] = self.mean_runtime(task) + succ_term
        return ranks

    def ranks(self, dfg: DFG) -> Dict[str, float]:
        if dfg.name not in self._ranks:
            self.register(dfg)
        return self._ranks[dfg.name]

    def rank_order(self, dfg: DFG) -> List[str]:
        """Tasks in descending rank; ties broken by topological position
        ("time of arrival determines the ranking" for identical ranks,
        §4.2.1 — topo position is the deterministic analogue within a job)."""
        ranks = self.ranks(dfg)
        topo_pos = {t: i for i, t in enumerate(dfg.topo_order)}
        return sorted(dfg.tasks, key=lambda t: (-ranks[t], topo_pos[t]))

"""Repository of Workflow Profiles (§3.1) and vertex ranking (§4.2.1),
plus heterogeneous worker-fleet profiles.

Holds static DFG metadata: expected runtimes R(t), input/output object
sizes, model sizes — plus the statically computed upward ranks (Eq. 1):

    rank(t) = R(t) + max_{t ≺ t'} (TD_output(t) + rank(t'))

Ranks depend only on the DFG and the cluster's network model, so Navigator
computes them once when the DFG is loaded and caches them here (§4.2.1);
dynamic inputs merely update, not recompute, the static values.

Fleet profiles: the paper's testbed is 5 identical T4 workers, but edge
clusters are rarely uniform.  ``WorkerProfile`` describes one GPU class
(FLOPS multiplier + memory) and ``build_fleet`` assembles a
``ClusterSpec`` from a mix; ``FLEETS`` names the presets the staleness /
heterogeneity sweeps use.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.core.netmodel import ClusterSpec, LinkSpec, Topology
from repro.core.types import DFG, GB, MLModel, TaskSpec


# --------------------------------------------------------------------------
# Heterogeneous fleet profiles
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class WorkerProfile:
    """One GPU class: ``speed`` multiplies task throughput
    (R(t, w) = R(t) / speed), ``gpu_capacity_bytes`` bounds the Navigator
    cache."""

    name: str
    speed: float = 1.0
    gpu_capacity_bytes: float = 16.0 * GB


# The paper's T4 is the 1.0x reference; the others are plausible edge/DC
# neighbours (relative serving throughput, not peak-FLOPS marketing).
T4 = WorkerProfile("t4", 1.0, 16.0 * GB)
L4 = WorkerProfile("l4", 1.6, 24.0 * GB)
A10 = WorkerProfile("a10", 2.0, 24.0 * GB)
EDGE = WorkerProfile("edge", 0.5, 8.0 * GB)

#: Named fleet mixes for the heterogeneity sweeps (bench_staleness.py).
FLEETS: Dict[str, Tuple[WorkerProfile, ...]] = {
    "uniform": (T4, T4, T4, T4, T4),
    "mixed": (A10, L4, T4, T4, EDGE),
    "edge_heavy": (L4, EDGE, EDGE, EDGE, EDGE),
}


def rack_topology(
    rack_sizes: Sequence[int],
    oversubscription: float = 4.0,
    rack_link: LinkSpec = LinkSpec(100e9 / 8.0, 1e-3),
    uplink_delta_s: float = 1e-3,
) -> Topology:
    """Two-tier topology over ``rack_sizes`` racks: rack-local links at
    ``rack_link`` capacity, each rack's shared spine uplink oversubscribed
    by ``oversubscription`` (uplink bw = rack bw / factor)."""
    if oversubscription <= 0:
        raise ValueError("oversubscription must be positive")
    rack_of: List[int] = []
    for rack, size in enumerate(rack_sizes):
        rack_of.extend([rack] * size)
    return Topology(
        rack_of=tuple(rack_of),
        rack_link=rack_link,
        uplink=LinkSpec(
            rack_link.bandwidth_bytes_per_s / oversubscription,
            uplink_delta_s,
        ),
    )


#: Rack-aware fleet presets: (worker profiles, topology).  ``rack2`` is
#: the paper's T4 class spread across two racks of four behind 4×
#: oversubscribed uplinks; ``rack2_mixed`` skews the fast GPUs into rack
#: 0 so rack-local placement and heterogeneity pull in different
#: directions.
RACK_FLEETS: Dict[str, Tuple[Tuple[WorkerProfile, ...], Topology]] = {
    "rack2": (
        (T4,) * 8,
        rack_topology((4, 4), oversubscription=4.0),
    ),
    "rack2_mixed": (
        (A10, A10, L4, T4, T4, T4, EDGE, EDGE),
        rack_topology((4, 4), oversubscription=4.0),
    ),
}


def build_fleet(
    profiles: Sequence[WorkerProfile], **cluster_kwargs
) -> ClusterSpec:
    """Assemble a ``ClusterSpec`` from a worker-profile mix.  Extra
    keyword arguments (network, link, …) pass through to the spec."""
    if not profiles:
        raise ValueError("fleet needs at least one worker profile")
    return ClusterSpec(
        n_workers=len(profiles),
        gpu_capacity_bytes=max(p.gpu_capacity_bytes for p in profiles),
        worker_speed={w: p.speed for w, p in enumerate(profiles)},
        worker_gpu_capacity={
            w: p.gpu_capacity_bytes for w, p in enumerate(profiles)
        },
        **cluster_kwargs,
    )


def fleet(name: str, **cluster_kwargs) -> ClusterSpec:
    """Named preset → ``ClusterSpec`` (see ``FLEETS`` / ``RACK_FLEETS``)."""
    if name in RACK_FLEETS:
        profiles, topo = RACK_FLEETS[name]
        cluster_kwargs.setdefault("topology", topo)
        return build_fleet(profiles, **cluster_kwargs)
    try:
        return build_fleet(FLEETS[name], **cluster_kwargs)
    except KeyError:
        raise ValueError(
            f"unknown fleet {name!r}; have "
            f"{sorted(FLEETS) + sorted(RACK_FLEETS)}"
        ) from None


class ProfileRepository:
    def __init__(self, cluster: ClusterSpec, models: Mapping[int, MLModel]) -> None:
        self.cluster = cluster
        self.models: Dict[int, MLModel] = dict(models)
        self._dfgs: Dict[str, DFG] = {}
        self._ranks: Dict[str, Dict[str, float]] = {}
        self._mean_factors: Optional[Tuple[float, float]] = None
        # Per-worker vector caches for the batched planners.  All derive
        # from the frozen ClusterSpec, so they never invalidate.
        n = cluster.n_workers
        self._speed_vec = np.array([cluster.speed(w) for w in range(n)])
        self._gpu_cap_vec = np.array([cluster.gpu_capacity(w) for w in range(n)])
        self._fits_vec: Dict[Optional[int], np.ndarray] = {}
        self._path_src_cache: Dict[int, Tuple[np.ndarray, np.ndarray]] = {}

    # -- registration ---------------------------------------------------------
    def register(self, dfg: DFG) -> None:
        for t in dfg.tasks.values():
            if t.model_id is not None and t.model_id not in self.models:
                raise KeyError(
                    f"DFG {dfg.name!r} task {t.task_id!r} references "
                    f"unknown model {t.model_id}"
                )
        self._dfgs[dfg.name] = dfg
        self._ranks[dfg.name] = self._compute_ranks(dfg)

    def dfg(self, name: str) -> DFG:
        return self._dfgs[name]

    def dfgs(self) -> List[DFG]:
        return list(self._dfgs.values())

    # -- parameters (§4.1) -----------------------------------------------------
    def runtime(self, task: TaskSpec, worker: int) -> float:
        """R(t, w)."""
        return self.cluster.runtime_on(task.runtime_s, worker)

    def mean_runtime(self, task: TaskSpec) -> float:
        """R(t): average of R(t, w) over the worker set (§4.2.1)."""
        speeds = [self.cluster.speed(w) for w in self.cluster.workers()]
        return task.runtime_s * sum(1.0 / s for s in speeds) / len(speeds)

    def _mean_transfer(self, nbytes: float) -> float:
        """Representative (placement-free) transfer time: the flat table
        when no topology is configured, the mean over distinct worker
        pairs otherwise — used by static ranks, which price transfers
        before placement is known."""
        topo = self.cluster.topology
        if topo is None:
            return self.cluster.network.transfer_time(nbytes)
        if nbytes <= 0:
            return 0.0
        if self._mean_factors is None:
            self._mean_factors = topo.mean_path_factors()
        inv_bw, delta = self._mean_factors
        return nbytes * inv_bw + delta

    def td_output(self, task: TaskSpec) -> float:
        """TD_output(t): time to move the task's output between workers
        (representative cost; see ``td_output_to`` for a concrete path)."""
        return self._mean_transfer(task.output_bytes)

    def td_input(self, task: TaskSpec) -> float:
        """TD_input(t): time to move the task's (external) input."""
        return self._mean_transfer(task.input_bytes)

    def td_output_to(self, task: TaskSpec, src: int, dst: int) -> float:
        """TD_output(t) along the concrete ``src → dst`` path."""
        return self.cluster.path_transfer_time(task.output_bytes, src, dst)

    def td_input_to(self, task: TaskSpec, src: int, dst: int) -> float:
        """TD_input(t) along the concrete ``src → dst`` path."""
        return self.cluster.path_transfer_time(task.input_bytes, src, dst)

    def td_model(self, model_id: Optional[int]) -> float:
        """TD_model(m, w) for a cache miss (uniform link assumed unless the
        cluster defines per-worker links)."""
        if model_id is None:
            return 0.0
        return self.cluster.link.fetch_time(self.models[model_id].size_bytes)

    def model_size(self, model_id: Optional[int]) -> float:
        if model_id is None:
            return 0.0
        return self.models[model_id].size_bytes

    def cached_model_size(self, model_id: Optional[int]) -> float:
        """Compressed in-cache footprint (§3.3)."""
        if model_id is None:
            return 0.0
        return self.models[model_id].size_bytes * self.cluster.compression_ratio

    def model_fits(self, model_id: Optional[int], worker: int) -> bool:
        """Static feasibility: the worker's GPU must hold one compressed
        cache copy plus one decompressed execution instance (§3.3).
        Heterogeneous fleets can contain workers too small for the
        largest models; capacity-aware schedulers price them out."""
        if model_id is None:
            return True
        footprint = self.models[model_id].size_bytes * (
            1.0 + self.cluster.compression_ratio
        )
        return footprint <= self.cluster.gpu_capacity(worker)

    # -- per-worker vectors (batched planners / indexed engine) ---------------
    # Each vector replays the scalar expression elementwise in float64, so
    # every element is bit-identical to the corresponding scalar call — the
    # contract the differential parity suite (chaos family 7) rests on.

    def runtime_vec(self, task: TaskSpec) -> np.ndarray:
        """R(t, ·) over the fleet — elementwise ``runtime(task, w)``.
        Returns a fresh array."""
        return task.runtime_s / self._speed_vec

    def model_fits_vec(self, model_id: Optional[int]) -> np.ndarray:
        """``model_fits(model_id, ·)`` as a cached bool vector.  Callers
        must treat the returned array as read-only."""
        out = self._fits_vec.get(model_id)
        if out is None:
            if model_id is None:
                out = np.ones(self.cluster.n_workers, dtype=bool)
            else:
                footprint = self.models[model_id].size_bytes * (
                    1.0 + self.cluster.compression_ratio
                )
                out = footprint <= self._gpu_cap_vec
            self._fits_vec[model_id] = out
        return out

    def _path_factors(self, src: int) -> Tuple[np.ndarray, np.ndarray]:
        """(bottleneck bandwidth, crosses-uplink) vectors for ``src → ·``
        paths of the configured topology (cached per source)."""
        cached = self._path_src_cache.get(src)
        if cached is None:
            topo = self.cluster.topology
            racks = np.asarray(topo.rack_of)
            cross = racks != racks[src]
            rack_bw = topo.rack_link.bandwidth_bytes_per_s
            # Uncontended planner price: both crossed uplinks at share 1.0,
            # so the bottleneck is min(rack link, uplink) — same fold as
            # Topology.transfer_time with default shares.
            bw = np.where(
                cross,
                min(rack_bw, topo.uplink.bandwidth_bytes_per_s),
                rack_bw,
            )
            cached = (bw, cross)
            self._path_src_cache[src] = cached
        return cached

    def path_time_vec(self, nbytes: float, src: int) -> np.ndarray:
        """``cluster.path_transfer_time(nbytes, src, ·)`` over all
        destinations as a fresh array.  Mirrors the scalar exactly,
        including the flat model charging ``src == dst`` (callers zero
        the diagonal wherever the scalar code path skips self-transfers)
        and the topology model's zero diagonal."""
        n = self.cluster.n_workers
        topo = self.cluster.topology
        if topo is None:
            return np.full(n, self.cluster.network.transfer_time(nbytes))
        if nbytes <= 0:
            return np.zeros(n)
        bw, cross = self._path_factors(src)
        t = nbytes / bw
        t = t + topo.rack_link.delta_s
        t = np.where(cross, t + topo.uplink.delta_s, t)
        t[src] = 0.0
        return t

    def td_input_vec(self, task: TaskSpec, src: int) -> np.ndarray:
        """``td_input_to(task, src, ·)`` over all destinations."""
        return self.path_time_vec(task.input_bytes, src)

    def td_output_vec(self, task: TaskSpec, src: int) -> np.ndarray:
        """``td_output_to(task, src, ·)`` over all destinations."""
        return self.path_time_vec(task.output_bytes, src)

    # -- ranking (Eq. 1) ---------------------------------------------------------
    def _compute_ranks(self, dfg: DFG) -> Dict[str, float]:
        ranks: Dict[str, float] = {}
        for tid in reversed(dfg.topo_order):
            task = dfg.tasks[tid]
            succ_term = 0.0
            if dfg.succs[tid]:
                succ_term = max(
                    self.td_output(task) + ranks[s] for s in dfg.succs[tid]
                )
            ranks[tid] = self.mean_runtime(task) + succ_term
        return ranks

    def ranks(self, dfg: DFG) -> Dict[str, float]:
        if dfg.name not in self._ranks:
            self.register(dfg)
        return self._ranks[dfg.name]

    def rank_order(self, dfg: DFG) -> List[str]:
        """Tasks in descending rank; ties broken by topological position
        ("time of arrival determines the ranking" for identical ranks,
        §4.2.1 — topo position is the deterministic analogue within a job)."""
        ranks = self.ranks(dfg)
        topo_pos = {t: i for i, t in enumerate(dfg.topo_order)}
        return sorted(dfg.tasks, key=lambda t: (-ranks[t], topo_pos[t]))

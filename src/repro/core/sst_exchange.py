"""Decentralized SST exchange: the metadata plane of the paper (§5.2).

Two transports live here:

1. **GossipPlane** — the per-worker-view subsystem.  Every worker keeps a
   versioned local replica of every peer's row; a configurable periodic
   gossip/broadcast exchange (period, fan-out, drop probability; message
   delay sampled through ``core/netmodel.py`` by the driving engine)
   disseminates row updates epidemically.  Schedulers read *their own
   worker's* replica (``view(w)``), so different workers plan from
   genuinely different — possibly stale — snapshots, which is the regime
   that separates Compass from centralized baselines.

   Exchanges are **diff-based**: each worker keeps an append-only change
   log of rows it has learned and a per-peer cursor into that log, so a
   gossip round with ``k`` dirty rows ships (and costs) O(k), never a
   full-table copy (``benchmarks/bench_sst_microbench.py`` guards this).

2. **make_sst_allgather** — a TPU-native analogue of the paper's RDMA
   one-sided row pushes: an all-gather of per-device rows over the data
   axis of a JAX mesh.  Like the RDMA original, a push moves one cache
   line per peer.

Row layout (uint32 lanes — exact bit transport; 16 lanes = 64 bytes =
exactly one cache line, keeping the wire format faithful to Fig. 5):
  [0] ft_estimate_s   (f32 bit pattern)
  [1] cache_bitmap lo 32 bits
  [2] cache_bitmap hi 32 bits
  [3] free cache KiB
  [4] queue_len
  [5] row version (monotonic per owner; merge is newest-(epoch, version))
  [6] intent_bitmap lo 32 bits (prefetch plane: resident ∪ in-flight ∪ queued)
  [7] intent_bitmap hi 32 bits
  [8] heartbeat_s     (f32 bit pattern — membership lease lane)
  [9] epoch (31 bits) | draining flag (bit 31)
  [10] in-flight fetch model id + 1 (0 = no fetch in flight)
  [11] fetch_eta_s    (f32 bit pattern — expected fetch completion)
  [12] health: queue depth            (core/healthplane.py digest lane)
  [13] health: GPU-memory occupancy   (f32 bit pattern, 0..1)
  [14] health: fetch-pipe utilization (f32 bit pattern, 0..1)
  [15] health: local task-latency p99 (f32 bit pattern, seconds)
"""

from __future__ import annotations

import dataclasses
import functools
import random
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.state import ALIVE, DEAD, LeaseConfig, SSTRow, SUSPECT

# jax is imported lazily inside make_sst_allgather so the gossip plane
# (pure Python) stays importable on hosts without an accelerator stack.

ROW_WIDTH = 16


def pack_row(row: SSTRow, queue_len: int = 0) -> np.ndarray:
    out = np.zeros((ROW_WIDTH,), np.uint32)
    out[0] = np.float32(row.ft_estimate_s).view(np.uint32)
    out[1] = np.uint32(row.cache_bitmap & 0xFFFFFFFF)
    out[2] = np.uint32((row.cache_bitmap >> 32) & 0xFFFFFFFF)
    out[3] = np.uint32(min(row.free_cache_bytes / 1024.0, 2**32 - 1))
    out[4] = np.uint32(queue_len)
    out[5] = np.uint32(row.version & 0xFFFFFFFF)
    out[6] = np.uint32(row.intent_bitmap & 0xFFFFFFFF)
    out[7] = np.uint32((row.intent_bitmap >> 32) & 0xFFFFFFFF)
    out[8] = np.float32(row.heartbeat_s).view(np.uint32)
    out[9] = np.uint32((row.epoch & 0x7FFFFFFF) | (int(row.draining) << 31))
    out[10] = np.uint32(row.fetch_model_id + 1)
    out[11] = np.float32(row.fetch_eta_s).view(np.uint32)
    out[12] = np.uint32(min(row.health_queue_depth, 2**32 - 1))
    out[13] = np.float32(row.health_mem_occupancy).view(np.uint32)
    out[14] = np.float32(row.health_fetch_util).view(np.uint32)
    out[15] = np.float32(row.health_p99_latency_s).view(np.uint32)
    return out


def unpack_rows(table: np.ndarray) -> List[SSTRow]:
    rows = []
    for r in np.asarray(table, np.uint32):
        bitmap = int(r[1]) | (int(r[2]) << 32)
        intent = int(r[6]) | (int(r[7]) << 32)
        rows.append(
            SSTRow(
                ft_estimate_s=float(r[0:1].view(np.float32)[0]),
                cache_bitmap=bitmap,
                free_cache_bytes=float(r[3]) * 1024.0,
                version=int(r[5]),
                intent_bitmap=intent,
                heartbeat_s=float(r[8:9].view(np.float32)[0]),
                epoch=int(r[9]) & 0x7FFFFFFF,
                draining=bool(int(r[9]) >> 31),
                fetch_model_id=int(r[10]) - 1,
                fetch_eta_s=float(r[11:12].view(np.float32)[0]),
                health_queue_depth=int(r[12]),
                health_mem_occupancy=float(r[13:14].view(np.float32)[0]),
                health_fetch_util=float(r[14:15].view(np.float32)[0]),
                health_p99_latency_s=float(r[15:16].view(np.float32)[0]),
            )
        )
    return rows


def make_sst_allgather(mesh, axis: str = "data"):
    """Returns a jitted (local_rows) → (replicated_table) exchange.

    ``local_rows``: (W, ROW_WIDTH) array sharded so each device along
    ``axis`` holds its own row; the result is the fully replicated table —
    exactly the post-push SST state every scheduler reads.
    """
    import jax
    from jax.sharding import PartitionSpec as P

    from repro.compat import shard_map

    @functools.partial(
        shard_map,
        mesh=mesh,
        in_specs=P(axis, None),
        out_specs=P(None, None),
        check_vma=False,
    )
    def exchange(local_row):
        # (1, ROW_WIDTH) per device → (W, ROW_WIDTH) everywhere.
        return jax.lax.all_gather(local_row, axis, axis=0, tiled=True)

    return jax.jit(exchange)


# --------------------------------------------------------------------------
# Per-worker SST views with diff-based gossip dissemination
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class GossipConfig:
    """Tunables for the decentralized exchange.

    ``period_s``   — seconds between a worker's gossip rounds (the paper's
                     200 ms push cadence, §5.2, is the default).
    ``fanout``     — peers contacted per round.  ``fanout >= n-1`` degrades
                     to the paper's full broadcast; smaller fan-outs trade
                     message count for propagation hops (epidemic spread).
    ``drop_prob``  — per-message loss probability.  Lost rows are *not*
                     retransmitted point-to-point; they reach the peer via
                     relay through third parties, as in rumor mongering.
    ``wire_row_bytes`` — bytes per row update on the wire (the 16-lane
                     packed row above: exactly one 64-byte cache line,
                     owner header in-line).
    ``seed``       — peer-selection / drop-sampling RNG seed (combined
                     with the driving engine's seed for determinism).
    """

    period_s: float = 0.2
    fanout: int = 2
    drop_prob: float = 0.0
    wire_row_bytes: float = 64.0  # 16 packed lanes = one cache line
    seed: int = 0


#: One row update on the wire: (owner worker id, owner version, row).
RowUpdate = Tuple[int, int, SSTRow]

#: One outbound message: (destination worker, row updates, payload bytes).
GossipMessage = Tuple[int, List[RowUpdate], float]


class GossipPlane:
    """Decentralized Shared State Table with genuinely per-worker views.

    Unlike ``SharedStateTable`` (single published snapshot, uniform
    staleness), every worker ``w`` here holds its *own* replica of every
    peer's row, merged newest-version-wins from gossip messages.  Two
    workers generally disagree about the cluster state, and a scheduler
    running on ``w`` sees exactly ``w``'s view — the decentralized regime
    of the paper (§5).

    Complexity: a round with ``k`` dirty rows (rows this worker learned
    since it last contacted the chosen peer) does O(k) work and ships
    O(k) bytes.  Quiescent rounds are O(fanout).  This is achieved with an
    append-only per-worker change log plus a per-(worker, peer) cursor —
    a version-vector diff without the O(n) vector scan.

    The plane is engine-agnostic: ``exchange(w, now)`` returns the
    messages a round emits (drops already sampled) and the caller decides
    delivery timing — the simulator posts delayed ``deliver`` events using
    its network model; the serving engine folds delivery into its virtual
    clock via ``advance(now)``.
    """

    def __init__(
        self,
        n_workers: int,
        config: Optional[GossipConfig] = None,
        seed: int = 0,
        lease: Optional[LeaseConfig] = None,
    ) -> None:
        self.n_workers = n_workers
        self.config = config or GossipConfig()
        # Membership lane (None = static fleet: every row reads ALIVE and
        # staleness aggregation is unchanged).
        self.lease = lease
        # Stable int mix of config seed + engine seed (tuple seeding is
        # hash-based, hence process-dependent and deprecated).
        self.rng = random.Random(self.config.seed * 1_000_003 + seed * 7_919 + 17)
        # Ground truth: each worker's own row.
        self.local: List[SSTRow] = [SSTRow() for _ in range(n_workers)]
        # views[w][p]: worker w's replica of p's row.
        self.views: List[List[SSTRow]] = [
            [SSTRow() for _ in range(n_workers)] for _ in range(n_workers)
        ]
        # versions[w][p]: version of p's row that w holds.
        self.versions: List[List[int]] = [
            [0] * n_workers for _ in range(n_workers)
        ]
        # Change log: owner ids of rows w has learned, in learn order.
        # Cursor positions are *absolute* (entries ever appended);
        # ``_log_base[w]`` is how many entries have been truncated from the
        # front, so log index = absolute position - base.  A peer whose
        # cursor has fallen below the base missed truncated history and
        # gets an anti-entropy full sync on next contact.
        self._log: List[List[int]] = [[] for _ in range(n_workers)]
        self._log_base: List[int] = [0] * n_workers
        # cursor[w][q]: absolute position in w's log up to which w synced q.
        self._cursor: List[List[int]] = [
            [0] * n_workers for _ in range(n_workers)
        ]
        # Hard cap on retained log entries (strict memory bound even when
        # some peer is never contacted).
        self._max_log = max(64, 16 * n_workers)
        # Columnar mirror of ``views`` for the packed read path
        # (``view_arrays``): one (reader, owner)-indexed 2-D column per
        # planner lane, kept in sync O(1) per merged row by _bump /
        # deliver / push / join — exactly the rows those operations touch.
        # Deferred import: packed.py imports this module's row types.
        from repro.core.packed import ColumnStore

        self._cols = ColumnStore((n_workers, n_workers))
        # Lazily-built full peer lists (broadcast fan-out only).
        self._all_peers: Dict[int, List[int]] = {}
        # Log length at which the next (O(n)) compaction check runs —
        # amortizes the min-cursor scan over >= n appends.
        self._compact_at: List[int] = [4 * n_workers] * n_workers
        # Stats.  *_sent counters use sender-side semantics (a dropped
        # message was still sent — the wire cost was paid); subtract
        # ``messages_dropped`` / use ``messages_delivered`` for what peers
        # actually received.
        self.messages_sent = 0
        self.messages_dropped = 0
        self.rows_sent = 0
        self.rounds = 0
        self.full_syncs = 0
        self._next_round_at = self.config.period_s  # for advance()

    # -- local updates (the owning worker's ground truth) -------------------
    def _bump(self, worker: int, now: float) -> None:
        row = self.local[worker]
        row.version += 1
        # Monotonic, like SharedStateTable: a caller omitting ``now`` must
        # not rewind the modification stamp (staleness-aware consumers
        # would misread the row as ancient).
        row.pushed_at = max(row.pushed_at, now)
        self._log[worker].append(worker)
        # Own view mirrors ground truth.
        self.views[worker][worker] = row.copy()
        self.versions[worker][worker] = row.version
        self._cols.set_row((worker, worker), row)

    def update_load(
        self, worker: int, ft_estimate_s: float, now: float = 0.0
    ) -> None:
        self.local[worker].ft_estimate_s = ft_estimate_s
        self._bump(worker, now)

    def update_cache(
        self,
        worker: int,
        cache_bitmap: int,
        free_cache_bytes: float,
        now: float = 0.0,
        fetch_model_id: int = -1,
        fetch_eta_s: float = 0.0,
    ) -> None:
        row = self.local[worker]
        row.cache_bitmap = cache_bitmap
        row.free_cache_bytes = free_cache_bytes
        row.fetch_model_id = fetch_model_id
        row.fetch_eta_s = fetch_eta_s
        self._bump(worker, now)

    def update_intent(
        self, worker: int, intent_bitmap: int, now: float = 0.0
    ) -> None:
        """Prefetch-plane advertisement; disseminates like any other row
        mutation (diff-shipped, epidemically relayed)."""
        self.local[worker].intent_bitmap = intent_bitmap
        self._bump(worker, now)

    def update_health(
        self,
        worker: int,
        queue_depth: int,
        mem_occupancy: float,
        fetch_util: float,
        p99_latency_s: float,
        now: float = 0.0,
    ) -> None:
        """Health-digest lane (core/healthplane.py, wire lanes 12–15):
        refreshed by the engine right before the owner's gossip round, so
        every reader's view of fleet health is staleness-bounded by the
        dissemination period — no oracle, same discipline as load/cache."""
        row = self.local[worker]
        row.health_queue_depth = queue_depth
        row.health_mem_occupancy = mem_occupancy
        row.health_fetch_util = fetch_util
        row.health_p99_latency_s = p99_latency_s
        self._bump(worker, now)

    # -- membership (heartbeat/lease lane) ----------------------------------
    def heartbeat(self, worker: int, now: float) -> None:
        """Owner self-stamp; rides the ordinary diff machinery, so a
        reader's lease age includes gossip dissemination lag."""
        row = self.local[worker]
        row.heartbeat_s = max(row.heartbeat_s, now)
        self._bump(worker, now)

    def set_draining(self, worker: int, draining: bool, now: float = 0.0) -> None:
        """Graceful-departure advertisement: peers treat a draining row as
        DEAD for placement the moment they learn of it (no lease wait)."""
        self.local[worker].draining = draining
        self._bump(worker, now)

    def set_partition(
        self, group_of: Optional[List[int]], now: float = 0.0
    ) -> None:
        """Network-cut notification (same hook ``SharedStateTable`` has).
        The gossip plane needs no internal state for it: the simulator
        drops cross-cut deliveries, so each reader's replica of a
        cross-cut row freezes and its lease ages out naturally — and
        because rows travel as full state merged newest-(epoch, version)
        wins, post-heal rounds reconverge without replaying anything."""

    def join(self, worker: int, now: float) -> None:
        """A worker (re)joins the fleet with a fresh incarnation.

        The crashed process lost its replicas, change log, and cursors, so
        they reset; only the epoch counter survives (one integer on stable
        storage), bumped so pre-crash rows of this worker can never
        overwrite post-rejoin state (``SSTRow.merge_key``).  The join
        announcement rewinds every peer's cursor toward the joiner below
        its log base, so the next gossip contact ships an anti-entropy
        **full sync** — the joiner rebuilds its SST view through the same
        repair path that serves truncated-history laggards."""
        old_epoch = self.local[worker].epoch
        self.local[worker] = SSTRow(
            heartbeat_s=now, pushed_at=now, epoch=old_epoch + 1
        )
        self.views[worker] = [SSTRow() for _ in range(self.n_workers)]
        self.versions[worker] = [0] * self.n_workers
        self._cols.reset_reader(worker)
        self._log[worker] = []
        self._log_base[worker] = 0
        self._cursor[worker] = [0] * self.n_workers
        self._compact_at[worker] = 4 * self.n_workers
        self._bump(worker, now)
        for q in range(self.n_workers):
            if q != worker:
                self._cursor[q][worker] = self._log_base[q] - 1

    def _classify_row(self, row: SSTRow, is_self: bool, now: float) -> str:
        """Single source of truth for the membership verdict a reader
        derives from one replica row.  A peer the reader has *never heard
        from* (fresh joiner before its first full sync) is SUSPECT, not
        DEAD: absence of evidence only costs a penalty, or a rejoined
        worker would dump every job on itself until the anti-entropy sync
        lands."""
        if row.draining:
            return DEAD
        if is_self:
            return ALIVE  # self-evidence is never stale
        if row.version == 0:
            return SUSPECT
        return self.lease.classify(max(0.0, now - row.heartbeat_s))

    def liveness(self, reader: int, peer: int, now: float) -> str:
        """Membership state ``reader`` assigns ``peer`` from its own
        (possibly stale) replica — no oracle."""
        if self.lease is None:
            return ALIVE
        row = self.local[reader] if peer == reader else self.views[reader][peer]
        return self._classify_row(row, peer == reader, now)

    # -- exchange ------------------------------------------------------------
    def _full_peer_list(self, worker: int) -> List[int]:
        peers = self._all_peers.get(worker)
        if peers is None:
            peers = [w for w in range(self.n_workers) if w != worker]
            self._all_peers[worker] = peers
        return peers

    def _peers(self, worker: int) -> List[int]:
        n = self.n_workers
        fanout = min(self.config.fanout, n - 1)
        if fanout <= 0:
            return []
        if fanout == n - 1:  # full broadcast
            return self._full_peer_list(worker)
        if fanout > (n - 1) // 2:
            # Dense fan-out: rejection sampling degrades; sample directly
            # from the cached full peer list instead.
            return self.rng.sample(self._full_peer_list(worker), fanout)
        # Sparse fan-out: rejection-sample distinct peers — O(fanout)
        # expected, so a quiescent round never touches O(n) state.
        chosen: List[int] = []
        seen = {worker}
        while len(chosen) < fanout:
            q = self.rng.randrange(n)
            if q not in seen:
                seen.add(q)
                chosen.append(q)
        return chosen

    def exchange(self, worker: int, now: float) -> List[GossipMessage]:
        """One gossip round for ``worker``: pick fan-out peers, ship each
        the rows learned since the last contact (deduped, newest version).
        Message drops are sampled here; only surviving messages are
        returned.

        Cost is O(log entries since that peer's last contact) — i.e. the
        rows touched since the two last spoke, never a table scan; a
        quiescent round allocates nothing.  A peer so far behind that its
        history was truncated (cursor < log base) gets an anti-entropy
        **full sync** of every row this worker knows — the standard rare
        repair path that keeps the log memory strictly bounded."""
        self.rounds += 1
        out: List[GossipMessage] = []
        base = self._log_base[worker]
        log = self._log[worker]
        head = base + len(log)
        for q in self._peers(worker):
            lo = self._cursor[worker][q]
            self._cursor[worker][q] = head
            full_sync = lo < base
            if full_sync:
                # Anti-entropy repair: truncated history, send everything.
                self.full_syncs += 1
                updates = [
                    (o, self.versions[worker][o], self.views[worker][o].copy())
                    for o in range(self.n_workers)
                ]
            else:
                entries = log[lo - base:]
                if not entries:
                    continue
                dirty: List[int] = []
                seen: Dict[int, bool] = {}
                for owner in entries:
                    if owner not in seen:
                        seen[owner] = True
                        dirty.append(owner)
                updates = [
                    (o, self.versions[worker][o], self.views[worker][o].copy())
                    for o in dirty
                ]
            self.messages_sent += 1
            self.rows_sent += len(updates)
            if self.rng.random() < self.config.drop_prob:
                self.messages_dropped += 1
                if full_sync:
                    # A lost diff is repaired by relay through other peers,
                    # but a lost full sync is the repair of last resort —
                    # rewind the cursor so the next contact retries it.
                    self._cursor[worker][q] = lo
                continue
            out.append((q, updates, self.config.wire_row_bytes * len(updates)))
        self._compact(worker)
        return out

    def deliver(self, worker: int, updates: Sequence[RowUpdate], now: float) -> None:
        """Merge a received message into ``worker``'s view (newest version
        wins) and queue accepted rows for relay to this worker's own peers
        — the epidemic step that lets updates cross the cluster even with
        ``fanout < n-1``."""
        for owner, version, row in updates:
            if owner == worker:
                continue  # own row is authoritative, never overwritten
            held = self.views[worker][owner]
            # Newest-(epoch, version) wins: a rejoined owner's fresh row
            # (higher epoch, version restarted) beats any pre-crash echo
            # still circulating — DEAD rows are never resurrected.
            if (row.epoch, version) > (held.epoch, self.versions[worker][owner]):
                self.versions[worker][owner] = version
                self.views[worker][owner] = row.copy()
                self._cols.set_row((worker, owner), row, version)
                self._log[worker].append(owner)

    def _compact(self, worker: int) -> None:
        """Bound the retained log.  First drop the prefix every peer has
        already seen; if the log still exceeds the hard cap (because some
        peer hasn't been contacted), force-truncate — laggards repair via
        the full-sync path in ``exchange``.  The O(n) min-cursor scan only
        runs once the log has grown by >= 4n entries since the last check,
        so its cost amortizes to O(1) per logged row."""
        log = self._log[worker]
        if len(log) < self._compact_at[worker] or self.n_workers <= 1:
            return
        base = self._log_base[worker]
        cursors = self._cursor[worker]
        floor = min(c for i, c in enumerate(cursors) if i != worker)
        drop = max(0, floor - base)
        if len(log) - drop > self._max_log:
            drop = len(log) - self._max_log // 2  # force: keep recent half-cap
        if drop > 0:
            self._log[worker] = log[drop:]
            self._log_base[worker] = base + drop
        self._compact_at[worker] = len(self._log[worker]) + 4 * self.n_workers

    def mark_synced(self, worker: int) -> None:
        """Consider every peer caught up with ``worker``'s log (e.g. right
        after a bootstrap broadcast, or between microbenchmark rounds) and
        drop the retained entries."""
        head = self._log_base[worker] + len(self._log[worker])
        self._cursor[worker] = [head] * self.n_workers
        self._log_base[worker] = head
        self._log[worker] = []

    # -- bootstrap / compatibility -------------------------------------------
    def push(self, worker: int, now: float) -> None:
        """Synchronous broadcast of ``worker``'s current row to every peer
        (bootstrap/warm-start only; live dissemination goes through
        ``exchange``).  Mirrors ``SharedStateTable.push``."""
        if self.local[worker].version == 0:
            self._bump(worker, now)
        row = self.local[worker]
        for q in range(self.n_workers):
            if q == worker:
                continue
            held = self.views[q][worker]
            if row.merge_key() > (held.epoch, self.versions[q][worker]):
                self.versions[q][worker] = row.version
                self.views[q][worker] = row.copy()
                self._cols.set_row((q, worker), row)

    @property
    def messages_delivered(self) -> int:
        return self.messages_sent - self.messages_dropped

    @property
    def total_pushes(self) -> int:
        return self.messages_sent

    # -- reads ----------------------------------------------------------------
    def view(
        self,
        reader_worker: Optional[int] = None,
        now: Optional[float] = None,
    ) -> List[SSTRow]:
        """The table as the scheduler on ``reader_worker`` sees it: its own
        row fresh from ground truth, peer rows from its gossip replicas.
        ``reader_worker=None`` returns ground truth for every row (an
        omniscient observer, used by diagnostics).  With a lease configured
        and ``now`` given, rows carry the reader's membership verdict
        (``liveness``): planners price SUSPECT rows up and DEAD rows out."""
        if reader_worker is None:
            rows = [r.copy() for r in self.local]
        else:
            rows = [r.copy() for r in self.views[reader_worker]]
            rows[reader_worker] = self.local[reader_worker].copy()
        if self.lease is not None and now is not None:
            for w, row in enumerate(rows):
                row.liveness = self._classify_row(
                    row, w == reader_worker, now
                )
        return rows

    def view_arrays(self, reader_worker: int, now: float):
        """Columnar twin of :meth:`view` for the indexed engine: the
        reader's replica set as packed ``(W,)`` arrays with vectorized
        membership verdicts (incl. the never-heard-from ⇒ SUSPECT rule).
        The own-row mirror maintained by ``_bump`` makes the reader's
        slice already ground-truth-fresh, so this is pure column copies
        — bit-identical values to the row-list path."""
        from repro.core.packed import PackedViews, classify_columns

        c = self._cols
        dead, suspect = classify_columns(
            self.lease, now, reader_worker,
            c.heartbeat[reader_worker], c.draining[reader_worker],
            version=c.version[reader_worker],
        )
        return PackedViews(
            reader=reader_worker,
            ft=c.ft[reader_worker].copy(),
            bitmap=c.bitmap[reader_worker].copy(),
            avc=c.avc[reader_worker].copy(),
            pushed_at=c.pushed_at[reader_worker].copy(),
            intent=c.intent[reader_worker].copy(),
            fetch_model=c.fetch_model[reader_worker].copy(),
            fetch_eta=c.fetch_eta[reader_worker].copy(),
            dead=dead, suspect=suspect,
        )

    def staleness(self, now: float, reader_worker: Optional[int] = None) -> float:
        """Max age (seconds) of any remote row in the reader's view;
        aggregated over all readers when ``reader_worker`` is None.

        Rows of peers the reader has marked DEAD (lease expired or
        draining) are excluded: a departed worker's frozen row would
        otherwise inflate reported staleness forever, even though no
        scheduler consumes it."""
        readers = (
            range(self.n_workers) if reader_worker is None else [reader_worker]
        )
        worst = 0.0
        for r in readers:
            for p in range(self.n_workers):
                if p == r:
                    continue
                if self.lease is not None and self.liveness(r, p, now) == DEAD:
                    continue
                worst = max(worst, now - self.views[r][p].pushed_at)
        return worst

    # -- synchronous driver (virtual-clock engines) ---------------------------
    def advance(self, now: float) -> None:
        """Run every gossip round due up to ``now`` with immediate
        delivery (message delay folded into the round period).  Used by
        engines with a coarse virtual clock (e.g. ``serving/engine.py``);
        the discrete-event simulator drives ``exchange``/``deliver``
        itself with sampled network delays."""
        while self._next_round_at <= now:
            t = self._next_round_at
            for w in range(self.n_workers):
                for q, updates, _nbytes in self.exchange(w, t):
                    self.deliver(q, updates, t)
            self._next_round_at += self.config.period_s

"""Decentralized SST exchange as a JAX collective (TPU-native analogue of
the paper's RDMA one-sided row pushes, §5.2).

The paper's SST is an O(n²) replicated table: every worker pushes its row
to every peer.  On a TPU mesh the natural primitive is an all-gather of
per-device rows over the data axis: each device contributes its local
(1, ROW_WIDTH) row and receives the full (W, ROW_WIDTH) table.  Like the
RDMA original, a push moves one cache line per peer — the row layout
below packs into 64 bytes (8 × f32/u32 lanes ≈ one cache line), keeping
the wire format faithful to Fig. 5.

Row layout (uint32 lanes — exact bit transport; 8 lanes = 32 bytes, half a
cache line):
  [0] ft_estimate_s   (f32 bit pattern)
  [1] cache_bitmap lo 32 bits
  [2] cache_bitmap hi 32 bits
  [3] free cache KiB
  [4] queue_len
  [5..7] reserved
"""

from __future__ import annotations

import functools
from typing import List

import jax
import jax.numpy as jnp
import numpy as np
from jax import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.state import SSTRow

ROW_WIDTH = 8


def pack_row(row: SSTRow, queue_len: int = 0) -> np.ndarray:
    out = np.zeros((ROW_WIDTH,), np.uint32)
    out[0] = np.float32(row.ft_estimate_s).view(np.uint32)
    out[1] = np.uint32(row.cache_bitmap & 0xFFFFFFFF)
    out[2] = np.uint32((row.cache_bitmap >> 32) & 0xFFFFFFFF)
    out[3] = np.uint32(min(row.free_cache_bytes / 1024.0, 2**32 - 1))
    out[4] = np.uint32(queue_len)
    return out


def unpack_rows(table: np.ndarray) -> List[SSTRow]:
    rows = []
    for r in np.asarray(table, np.uint32):
        bitmap = int(r[1]) | (int(r[2]) << 32)
        rows.append(
            SSTRow(
                ft_estimate_s=float(r[0:1].view(np.float32)[0]),
                cache_bitmap=bitmap,
                free_cache_bytes=float(r[3]) * 1024.0,
            )
        )
    return rows


def make_sst_allgather(mesh: Mesh, axis: str = "data"):
    """Returns a jitted (local_rows) → (replicated_table) exchange.

    ``local_rows``: (W, ROW_WIDTH) array sharded so each device along
    ``axis`` holds its own row; the result is the fully replicated table —
    exactly the post-push SST state every scheduler reads.
    """

    @functools.partial(
        shard_map,
        mesh=mesh,
        in_specs=P(axis, None),
        out_specs=P(None, None),
        check_vma=False,
    )
    def exchange(local_row):
        # (1, ROW_WIDTH) per device → (W, ROW_WIDTH) everywhere.
        return jax.lax.all_gather(local_row, axis, axis=0, tiled=True)

    return jax.jit(exchange)

"""Navigator scheduler (§4) and the baseline schemes (§6.2.1).

Planning phase (Alg. 1): HEFT-style upward-rank ordering, then per-task
argmin over workers of

    FT(t, w) = max(worker_FT_map[w], AT_allInputs(t, w)) + TD_model(m_t, w) + R(t, w)

with the model-locality term TD_model from Eq. 2 (0 on a cache hit, fetch
time on a miss that fits, fetch time + eviction penalty otherwise) and
input arrival times from Eq. 3–4.

Dynamic adjustment phase (Alg. 2): when a task's predecessor finishes, if
the planned worker's queue wait exceeds ``threshold × R(t, w)`` and the
task is not a join, re-select the worker with the earliest start, adding
TD_input for workers other than the one holding the task's inputs.

Baselines:
* JIT   — per-task assignment at readiness, earliest-start-first.
* HEFT  — classic HEFT: rank + earliest finish, but no worker load, no
          model locality, no dynamic adjustment.
* Hash  — uniform task spreading by hash(task, job).
"""

from __future__ import annotations

import dataclasses
import zlib
from typing import Dict, List, Mapping, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core import bitmaps
from repro.core.packed import PackedViews
from repro.core.profiles import ProfileRepository
from repro.core.state import DEAD, SSTRow, SUSPECT
from repro.core.telemetry import (
    CandidateCost,
    FlightRecorder,
    PlacementDecision,
)
from repro.core.types import ADFG, DFG, Job, TaskSpec


@dataclasses.dataclass(frozen=True)
class NavigatorConfig:
    """Tunables + ablation switches (§6.3.1)."""

    # Alg. 2 line 2: reschedule when queue wait > R(t, w) * threshold.
    adjustment_threshold: float = 2.0
    # Eq. 2 third case.  None → estimate as the mean refetch cost of the
    # models currently resident on the candidate worker (the expected cost
    # of bringing back whatever we evict); float → fixed seconds.
    eviction_penalty_s: Optional[float] = None
    # Optional hysteresis for Alg. 2 under stale metadata: move only if the
    # best candidate improves the planned worker's estimated finish by this
    # relative margin.  0.0 = the paper's unconditional argmin (which also
    # measured best in our multi-seed calibration; see EXPERIMENTS.md).
    adjustment_margin: float = 0.0
    # Staleness-aware hysteresis (decentralized gossip plane): the margin
    # grows with the age of the candidate worker's row, so the staler the
    # evidence for moving, the bigger the predicted win must be.  Effective
    # margin = adjustment_margin + staleness_margin_per_s × row age.  0.0
    # disables (and with a fresh SharedStateTable rows are near-zero age,
    # so this is a no-op for the centralized-snapshot configuration).
    staleness_margin_per_s: float = 0.0
    # Prefetch plane (core/prefetch.py): Eq. 2 discount for models a
    # worker *intends* to hold (advertised intent bitmap ⊃ cache bitmap).
    # An intended-but-not-yet-resident model costs
    # ``TD_model × (1 − intent_confidence)`` — the fetch is (probably)
    # already overlapping queue wait on that worker.  0.0 disables; the
    # discount is inert anyway while intent bitmaps are all-zero (plane
    # off).
    intent_confidence: float = 0.7
    # Intent advertisements older than this get no discount: the plan
    # that produced them has likely played out or been adjusted away
    # (anti-herd: stale evidence must not create phantom cheap workers).
    intent_fresh_s: float = 5.0
    # Anti-herd stickiness: when the cheapest worker for a model-bearing
    # task neither holds nor intends the model but another worker does,
    # prefer the intending worker unless the cheapest wins by more than
    # this relative margin — concurrent planners then converge on the
    # worker already committed to the fetch instead of spawning
    # redundant fetches from stale views.  0.0 = pure argmin.
    intent_herd_margin: float = 0.0
    # Membership lane (core/state.py LeaseConfig): additive placement cost
    # for a worker whose lease the reader's view marks SUSPECT — enough to
    # lose ties against healthy workers but not a hard exclusion (the
    # evidence is one missed heartbeat window, often just gossip lag).
    # Workers the view marks DEAD always cost ∞.  Inert (all rows ALIVE)
    # when no lease is configured.
    suspect_penalty_s: float = 5.0
    # Ablations:
    use_model_locality: bool = True      # Fig. 7 "model locality"
    use_dynamic_adjustment: bool = True  # Fig. 7 "dynamic task scheduling"
    # Track models the planner itself just decided to place (so the second
    # task in the same job using the same model sees a planned hit).
    speculative_cache: bool = True


class Scheduler:
    """Common interface the simulator/serving engine drives."""

    name = "base"
    needs_adjustment = False
    plans_at_arrival = True
    # Membership lane: additive cost for SUSPECT rows in membership-aware
    # schedulers (JIT uses this default; Navigator takes it from its
    # config).  Hash/HEFT never read the SST and stay blind.
    suspect_penalty_s = 5.0

    def __init__(self, profiles: ProfileRepository) -> None:
        self.profiles = profiles
        self.cluster = profiles.cluster
        # Flight-recorder hook: the engine attaches its recorder when
        # tracing is on; schedulers that price state (Navigator, JIT)
        # record a PlacementDecision per choice.  None ⇒ zero overhead.
        self.recorder: Optional[FlightRecorder] = None

    # Planning at job arrival.  Returns None for per-task schedulers (JIT).
    def plan(
        self,
        job: Job,
        now: float,
        origin_worker: int,
        sst: Sequence[SSTRow],
    ) -> Optional[ADFG]:
        raise NotImplementedError

    # Per-task assignment at readiness (JIT only).
    def select_worker_at_ready(
        self,
        job: Job,
        task_id: str,
        now: float,
        sst: Sequence[SSTRow],
        input_locations: Mapping[str, int],
        input_sizes: Mapping[str, float],
        self_worker: Optional[int] = None,
    ) -> int:
        raise NotImplementedError(f"{self.name} plans at arrival")

    # Dynamic adjustment when a predecessor completes (Navigator only).
    def adjust(
        self,
        job: Job,
        adfg: ADFG,
        task_id: str,
        now: float,
        sst: Sequence[SSTRow],
        current_worker: int,
        input_bytes: float,
    ) -> int:
        return adfg[task_id]

    # Recovery targeting after churn (crash / drain / partition): pick a
    # worker for a task whose assignment was lost.  ``None`` means "no
    # opinion" — the dispatcher falls back to its greedy earliest-start
    # rule.  Navigator prices the full placement cost instead.
    def select_recovery_worker(
        self,
        job: Job,
        task_id: str,
        now: float,
        sst: Sequence[SSTRow],
        input_locations: Mapping[str, int],
        input_sizes: Mapping[str, float],
        candidates: Sequence[int],
    ) -> Optional[int]:
        return None

    # -- shared helpers -------------------------------------------------------
    def _ft_map(self, now: float, sst: Sequence[SSTRow]) -> List[float]:
        """worker_FT_map: published queue-drain times, clamped to now
        (a stale estimate in the past means 'idle as far as we know')."""
        return [max(now, row.ft_estimate_s) for row in sst]

    def _liveness_cost(self, row: SSTRow, suspect_penalty_s: float = 0.0) -> float:
        """Membership term of the placement cost: ∞ for workers this
        reader's view marks DEAD (or draining), an additive penalty for
        SUSPECT ones.  Zero on a static fleet (rows default ALIVE)."""
        if row.liveness == DEAD:
            return float("inf")
        if row.liveness == SUSPECT:
            return suspect_penalty_s
        return 0.0

    def _liveness_cost_vec(
        self, pv: PackedViews, suspect_penalty_s: float = 0.0
    ) -> np.ndarray:
        """Vector twin of :meth:`_liveness_cost` over a packed view."""
        return np.where(
            pv.dead,
            float("inf"),
            np.where(pv.suspect, suspect_penalty_s, 0.0),
        )


class NavigatorScheduler(Scheduler):
    name = "navigator"
    needs_adjustment = True

    def __init__(
        self, profiles: ProfileRepository, config: Optional[NavigatorConfig] = None
    ) -> None:
        super().__init__(profiles)
        self.config = config or NavigatorConfig()
        self.needs_adjustment = self.config.use_dynamic_adjustment

    # -- Eq. 2 ------------------------------------------------------------------
    def _td_model(
        self,
        task: TaskSpec,
        worker: int,
        bitmap: int,
        avc_bytes: float,
        intent_bitmap: int = 0,
        intent_fresh: bool = False,
        fetch_model: int = -1,
        fetch_eta_s: float = 0.0,
        start_hint_s: float = 0.0,
    ) -> float:
        mid = task.model_id
        if mid is None:
            return 0.0
        if not self.config.use_model_locality:
            # Ablation: ignore cache state entirely — every worker looks the
            # same, so the locality preference disappears.
            return self.profiles.td_model(mid)
        if bitmaps.contains(bitmap, mid):
            return 0.0
        fetch = self.profiles.td_model(mid)
        if (
            intent_fresh
            and self.config.intent_confidence > 0.0
            and bitmaps.contains(intent_bitmap, mid)
        ):
            # Prefetch plane: the worker advertises an in-flight/queued
            # fetch for this model.  If the advertised *in-flight* fetch is
            # this very model, its expected-completion timestamp prices the
            # true remaining overlap: the task cannot start before
            # ``start_hint_s``, so only the part of the fetch outlasting
            # that moment is still on the critical path — a nearly-done
            # fetch costs ≈ 0, a just-started one ≈ the full fetch.
            if fetch_model == mid and fetch_eta_s > 0.0:
                return min(fetch, max(0.0, fetch_eta_s - start_hint_s))
            # Queued (not yet in-flight) intent: fall back to the constant
            # confidence discount — the fetch will (probably) overlap
            # queue wait on that worker.
            return fetch * (1.0 - self.config.intent_confidence)
        if self.profiles.cached_model_size(mid) <= avc_bytes:
            return fetch
        return fetch + self._eviction_penalty(bitmap)

    def _eviction_penalty(self, bitmap: int) -> float:
        if self.config.eviction_penalty_s is not None:
            return self.config.eviction_penalty_s
        resident = bitmaps.unpack(bitmap)
        if not resident:
            return 0.0
        # Expected cost of re-fetching whichever resident model we displace.
        return sum(self.profiles.td_model(m) for m in resident) / len(resident)

    # -- Eq. 2, batched -----------------------------------------------------------
    # The packed twins below evaluate every candidate worker in one numpy
    # expression instead of a python loop.  They replay the scalar
    # arithmetic elementwise in float64 (same operations, same order, same
    # precedence), so each element is bit-identical to the scalar call —
    # the invariant the differential parity suite (chaos family 7) pins.

    def _eviction_penalty_vec(
        self, bitmap: np.ndarray, need: np.ndarray
    ) -> np.ndarray:
        """Eviction penalty for the workers selected by ``need``: mean
        refetch cost of each worker's resident set, accumulated in
        ascending model-id order (the ``bitmaps.unpack`` order), so the
        float sum folds exactly like the scalar ``sum()``.  Adding 0.0 for
        absent models is bit-exact (all partial sums are ≥ +0.0)."""
        n = bitmap.shape[0]
        if self.config.eviction_penalty_s is not None:
            return np.full(n, self.config.eviction_penalty_s)
        sel = bitmap[need]
        union = int(np.bitwise_or.reduce(sel)) if sel.size else 0
        acc = np.zeros(n)
        cnt = np.zeros(n)
        for m in bitmaps.unpack(union):
            has = (bitmap & np.uint64(1 << m)) != 0
            acc = acc + np.where(has, self.profiles.td_model(m), 0.0)
            cnt = cnt + np.where(has, 1.0, 0.0)
        out = np.zeros(n)
        nz = cnt > 0
        out[nz] = acc[nz] / cnt[nz]
        return out

    def _td_model_vec(
        self,
        task: TaskSpec,
        bitmap: np.ndarray,
        avc: np.ndarray,
        intent: np.ndarray,
        fresh: np.ndarray,
        fetch_model: np.ndarray,
        fetch_eta: np.ndarray,
        start_hint: np.ndarray,
    ) -> np.ndarray:
        """(W,) twin of :meth:`_td_model` — same branch precedence (hit >
        in-flight intent > queued intent > fits > eviction), expressed as
        nested ``np.where`` applied innermost-first."""
        n = bitmap.shape[0]
        mid = task.model_id
        if mid is None:
            return np.zeros(n)
        fetch = self.profiles.td_model(mid)
        if not self.config.use_model_locality:
            return np.full(n, fetch)
        bit = np.uint64(1 << mid)
        hit = (bitmap & bit) != 0
        if self.config.intent_confidence > 0.0:
            intent_ok = fresh & ((intent & bit) != 0)
        else:
            intent_ok = np.zeros(n, dtype=bool)
        td = np.full(n, fetch)
        need_evp = ~hit & ~intent_ok & ~(
            self.profiles.cached_model_size(mid) <= avc
        )
        if np.any(need_evp):
            td = np.where(
                need_evp,
                fetch + self._eviction_penalty_vec(bitmap, need_evp),
                td,
            )
        td = np.where(
            intent_ok, fetch * (1.0 - self.config.intent_confidence), td
        )
        inflight = intent_ok & (fetch_model == mid) & (fetch_eta > 0.0)
        if np.any(inflight):
            remaining = np.minimum(
                fetch, np.maximum(0.0, fetch_eta - start_hint)
            )
            td = np.where(inflight, remaining, td)
        return np.where(hit, 0.0, td)

    # -- Alg. 1 -------------------------------------------------------------------
    def plan(
        self,
        job: Job,
        now: float,
        origin_worker: int,
        sst: Sequence[SSTRow],
    ) -> ADFG:
        if isinstance(sst, PackedViews):
            # Indexed engine hands packed columns; the batched path has no
            # provenance recording, so the engine only does this with the
            # flight recorder off.
            return self._plan_packed(job, now, origin_worker, sst)
        dfg = job.dfg
        workers = list(self.cluster.workers())
        ft_map = self._ft_map(now, sst)                       # line 2
        bitmap = [row.cache_bitmap for row in sst]
        avc = [row.free_cache_bytes for row in sst]
        intent = [row.intent_bitmap for row in sst]
        fresh = [
            max(0.0, now - row.pushed_at) <= self.config.intent_fresh_s
            for row in sst
        ]
        fetch_model = [row.fetch_model_id for row in sst]
        fetch_eta = [row.fetch_eta_s for row in sst]
        adfg = ADFG(job)

        live_cost = [
            self._liveness_cost(row, self.config.suspect_penalty_s)
            for row in sst
        ]
        rec = self.recorder
        for tid in self.profiles.rank_order(dfg):             # lines 4-5
            task = dfg.tasks[tid]
            fts: List[float] = []
            cands: List[CandidateCost] = []
            for w in workers:                                 # line 7
                if not self.profiles.model_fits(task.model_id, w):
                    fts.append(float("inf"))  # GPU can never host the model
                    if rec is not None:
                        cands.append(CandidateCost(
                            worker=w, queue_s=ft_map[w], input_s=0.0,
                            model_s=float("inf"), intent_discount_s=0.0,
                            runtime_s=0.0, liveness_s=live_cost[w],
                            total_s=float("inf"),
                        ))
                    continue
                at = self._at_all_inputs(job, tid, w, now, origin_worker, adfg)
                x = max(ft_map[w], at)                        # line 8
                td = self._td_model(
                    task, w, bitmap[w], avc[w], intent[w], fresh[w],
                    fetch_model[w], fetch_eta[w], x,
                )
                rt = self.profiles.runtime(task, w)
                fts.append(x + td + rt)                       # line 9
                if rec is not None:
                    # Undiscounted Eq. 2 (intent lane zeroed) prices what
                    # the prefetch plane saved on this candidate.
                    base = self._td_model(task, w, bitmap[w], avc[w])
                    cands.append(CandidateCost(
                        worker=w, queue_s=ft_map[w], input_s=at, model_s=td,
                        intent_discount_s=max(0.0, base - td),
                        runtime_s=rt, liveness_s=live_cost[w],
                        total_s=x + td + rt + live_cost[w],
                    ))
            # Selection cost = predicted finish + membership risk; the
            # penalty biases the argmin only, never the recorded estimate
            # (planned_ft / ft_map feed Eq. 3, prefetch expected-starts,
            # and Alg. 2 hysteresis, which must stay time-shaped).
            costs = [fts[w] + live_cost[w] for w in workers]
            argmin_w = min(workers, key=lambda w: costs[w])   # line 10
            best_w = self._herd_sticky_choice(
                task.model_id, argmin_w, costs, bitmap, intent, fresh, workers
            )
            if rec is not None:
                rec.record_placement(PlacementDecision(
                    t=now, job_id=job.job_id, task_id=tid, phase="plan",
                    scheduler=self.name, reader=origin_worker,
                    chosen=best_w, candidates=tuple(cands),
                    note=("herd-sticky override of "
                          f"w{argmin_w}") if best_w != argmin_w else "",
                ))
            best_ft = fts[best_w]
            adfg[tid] = best_w                                # line 11
            adfg.planned_ft[tid] = best_ft
            ft_map[best_w] = best_ft                          # line 12
            if self.config.speculative_cache and task.model_id is not None:
                if not bitmaps.contains(bitmap[best_w], task.model_id):
                    bitmap[best_w] = bitmaps.add(bitmap[best_w], task.model_id)
                    avc[best_w] = max(
                        0.0,
                        avc[best_w]
                        - self.profiles.cached_model_size(task.model_id),
                    )
        return adfg

    def _plan_packed(
        self, job: Job, now: float, origin_worker: int, pv: PackedViews
    ) -> ADFG:
        """Batched Alg. 1: per task, one vector evaluation of FT(t, ·)
        over every candidate worker — the columnar replay of :meth:`plan`
        (same arithmetic, same first-minimum tie-breaks, same speculative
        cache updates), bit-exact with the scalar loop."""
        dfg = job.dfg
        cfg = self.config
        inf = float("inf")
        ft_map = np.maximum(now, pv.ft)                       # line 2
        bitmap = pv.bitmap.copy()   # mutated by the speculative cache
        avc = pv.avc.copy()
        intent = pv.intent
        fresh = np.maximum(0.0, now - pv.pushed_at) <= cfg.intent_fresh_s
        live_cost = self._liveness_cost_vec(pv, cfg.suspect_penalty_s)
        adfg = ADFG(job)
        for tid in self.profiles.rank_order(dfg):             # lines 4-5
            task = dfg.tasks[tid]
            at = self._at_all_inputs_vec(job, tid, now, origin_worker, adfg)
            x = np.maximum(ft_map, at)                        # line 8
            td = self._td_model_vec(
                task, bitmap, avc, intent, fresh,
                pv.fetch_model, pv.fetch_eta, x,
            )
            fts = x + td + self.profiles.runtime_vec(task)    # line 9
            # GPU can never host the model ⇒ ∞ (the scalar loop skips the
            # worker before pricing it; the values it skipped are pure).
            fts = np.where(
                self.profiles.model_fits_vec(task.model_id), fts, inf
            )
            costs = fts + live_cost
            argmin_w = int(np.argmin(costs))                  # line 10
            best_w = self._herd_sticky_packed(
                task.model_id, argmin_w, costs, bitmap, intent, fresh
            )
            best_ft = float(fts[best_w])
            adfg[tid] = best_w                                # line 11
            adfg.planned_ft[tid] = best_ft
            ft_map[best_w] = best_ft                          # line 12
            if cfg.speculative_cache and task.model_id is not None:
                bit = np.uint64(1 << task.model_id)
                if not bitmap[best_w] & bit:
                    bitmap[best_w] = bitmap[best_w] | bit
                    avc[best_w] = max(
                        0.0,
                        float(avc[best_w])
                        - self.profiles.cached_model_size(task.model_id),
                    )
        return adfg

    def _at_all_inputs_vec(
        self,
        job: Job,
        task_id: str,
        now: float,
        origin_worker: int,
        adfg: ADFG,
    ) -> np.ndarray:
        """(W,) twin of :meth:`_at_all_inputs`: Eq. 3-4 arrival times for
        every candidate at once.  Zeroing the source column reproduces the
        scalar's self-transfer skip (x + 0.0 is bit-exact for the
        non-negative times involved)."""
        dfg = job.dfg
        preds = dfg.preds[task_id]
        if not preds:
            td = self.profiles.td_input_vec(dfg.tasks[task_id], origin_worker)
            td[origin_worker] = 0.0
            return now + td
        at = np.zeros(self.cluster.n_workers)
        for p in preds:
            src = adfg[p]
            td = self.profiles.td_output_vec(dfg.tasks[p], src)
            td[src] = 0.0
            at = np.maximum(at, adfg.planned_ft[p] + td)
        return at

    def _herd_sticky_packed(
        self,
        model_id: Optional[int],
        best_w: int,
        costs: np.ndarray,
        bitmap: np.ndarray,
        intent: np.ndarray,
        fresh: np.ndarray,
    ) -> int:
        """Vector twin of :meth:`_herd_sticky_choice` (same holder set,
        same first-minimum alternative, same margin comparison)."""
        margin = self.config.intent_herd_margin
        if (
            model_id is None
            or margin <= 0.0
            or not self.config.use_model_locality
        ):
            return best_w
        bit = np.uint64(1 << model_id)
        holds = ((bitmap & bit) != 0) | (fresh & ((intent & bit) != 0))
        if holds[best_w]:
            return best_w
        inf = float("inf")
        holder_costs = np.where(holds & (costs != inf), costs, inf)
        if not np.any(holder_costs != inf):
            return best_w
        alt = int(np.argmin(holder_costs))
        if holder_costs[alt] <= costs[best_w] * (1.0 + margin):
            return alt
        return best_w

    def _herd_sticky_choice(
        self,
        model_id: Optional[int],
        best_w: int,
        costs: Sequence[float],
        bitmap: Sequence[int],
        intent: Sequence[int],
        fresh: Sequence[bool],
        workers: Sequence[int],
    ) -> int:
        """Anti-herd hysteresis: if the argmin worker neither holds nor
        intends the task's model but some worker does, move to the best
        such worker unless the argmin wins by more than the margin.
        Operates on selection *costs* (finish estimate + membership
        risk), like the argmin itself."""
        margin = self.config.intent_herd_margin
        if (
            model_id is None
            or margin <= 0.0
            or not self.config.use_model_locality
        ):
            return best_w

        def holds(w: int) -> bool:
            return bitmaps.contains(bitmap[w], model_id) or (
                fresh[w] and bitmaps.contains(intent[w], model_id)
            )

        if holds(best_w):
            return best_w
        # Infinite-cost holders (infeasible GPU, or DEAD in this view —
        # a frozen row can still advertise the model) are no alternative.
        holders = [
            w for w in workers if holds(w) and costs[w] != float("inf")
        ]
        if not holders:
            return best_w
        alt = min(holders, key=lambda w: costs[w])
        if costs[alt] <= costs[best_w] * (1.0 + margin):
            return alt
        return best_w

    # -- Eq. 3-4 ----------------------------------------------------------------
    def _at_all_inputs(
        self,
        job: Job,
        task_id: str,
        worker: int,
        now: float,
        origin_worker: int,
        adfg: ADFG,
    ) -> float:
        dfg = job.dfg
        preds = dfg.preds[task_id]
        if not preds:
            # Entry task: the client input arrives at origin_worker and
            # ships along the origin → worker path.
            td = 0.0 if worker == origin_worker else self.profiles.td_input_to(
                dfg.tasks[task_id], origin_worker, worker
            )
            return now + td
        at = 0.0
        for p in preds:
            # Ranks order guarantees predecessors are already assigned.
            ft_p = adfg.planned_ft[p]
            if worker != adfg[p]:
                ft_p += self.profiles.td_output_to(
                    dfg.tasks[p], adfg[p], worker
                )
            at = max(at, ft_p)
        return at

    # -- Alg. 2 -------------------------------------------------------------------
    def adjust(
        self,
        job: Job,
        adfg: ADFG,
        task_id: str,
        now: float,
        sst: Sequence[SSTRow],
        current_worker: int,
        input_bytes: float,
    ) -> int:
        if not self.config.use_dynamic_adjustment:
            return adfg[task_id]
        if isinstance(sst, PackedViews):
            # Indexed engine hands packed columns (flight recorder off).
            return self._adjust_packed(
                job, adfg, task_id, now, sst, current_worker, input_bytes
            )
        dfg = job.dfg
        task = dfg.tasks[task_id]
        w_planned = adfg[task_id]                               # line 1
        wait = max(0.0, sst[w_planned].ft_estimate_s - now)
        above = wait > self.profiles.runtime(task, w_planned) * (
            self.config.adjustment_threshold
        )                                                       # line 2
        if dfg.is_join(task_id) or not above:                   # lines 3-5
            return w_planned
        ft_map = self._ft_map(now, sst)                         # line 6
        rec = self.recorder
        parts: Dict[int, Tuple[float, float, float, float, float]] = {}

        def est(w: int) -> float:
            if not self.profiles.model_fits(task.model_id, w):
                return float("inf")
            row = sst[w]
            live = self._liveness_cost(row, self.config.suspect_penalty_s)
            if live == float("inf"):
                return live  # DEAD in this view: never a move target
            td = self._td_model(
                task,
                w,
                row.cache_bitmap,
                row.free_cache_bytes,
                row.intent_bitmap,
                max(0.0, now - row.pushed_at)
                <= self.config.intent_fresh_s,
                row.fetch_model_id,
                row.fetch_eta_s,
                ft_map[w],
            )
            ft = ft_map[w] + td + self.profiles.runtime(task, w) + live
            path = 0.0
            if w != current_worker:                             # lines 10-11
                path = self.cluster.path_transfer_time(
                    input_bytes, current_worker, w
                )
                ft += path
            if rec is not None:
                parts[w] = (td, live, path,
                            self._td_model(task, w, row.cache_bitmap,
                                           row.free_cache_bytes),
                            self.profiles.runtime(task, w))
            return ft

        best_w, best_ft = w_planned, est(w_planned)
        for w in range(len(ft_map)):                            # line 7
            ft = est(w)
            if ft < best_ft:
                best_w, best_ft = w, ft
        # Hysteresis: require a clear predicted win before abandoning the
        # planned (cache-affine) worker.  Under the gossip plane the margin
        # scales with the age of the candidate's row — stale evidence for a
        # move must clear a higher bar (the adjuster only sees *its own*
        # replica of the candidate's state, which may lag reality).
        planned_ft = est(w_planned)
        margin = self.config.adjustment_margin
        if (
            best_w != w_planned
            and best_w != current_worker
            and self.config.staleness_margin_per_s > 0.0
        ):
            # The adjuster's own worker is never stale (local ground
            # truth); only remote rows carry age-scaled uncertainty.
            age = max(0.0, now - sst[best_w].pushed_at)
            margin += self.config.staleness_margin_per_s * age
        held = best_w != w_planned and best_ft > planned_ft * (1.0 - margin)
        chosen = w_planned if held else best_w
        if rec is not None:
            totals = {w: est(w) for w in range(len(ft_map))}
            stale = margin - self.config.adjustment_margin
            cands = tuple(
                CandidateCost(
                    worker=w, queue_s=ft_map[w],
                    # the Alg. 2 data term rides the current worker → w
                    # path, recorded in input_s (absolute = now + path)
                    input_s=now + parts[w][2] if w in parts else 0.0,
                    model_s=parts[w][0] if w in parts else float("inf"),
                    intent_discount_s=(
                        max(0.0, parts[w][3] - parts[w][0])
                        if w in parts else 0.0
                    ),
                    runtime_s=parts[w][4] if w in parts else 0.0,
                    liveness_s=parts[w][1] if w in parts else float("inf"),
                    total_s=totals[w],
                    staleness_margin_s=stale if w == best_w else 0.0,
                )
                for w in range(len(ft_map))
            )
            rec.record_placement(PlacementDecision(
                t=now, job_id=job.job_id, task_id=task_id, phase="adjust",
                scheduler=self.name, reader=current_worker, chosen=chosen,
                candidates=cands,
                note=(f"hysteresis hold on w{w_planned} "
                      f"(margin={margin:.4f})") if held else "",
            ))
        if held:
            return w_planned
        return chosen                                           # lines 12-13

    def _adjust_packed(
        self,
        job: Job,
        adfg: ADFG,
        task_id: str,
        now: float,
        pv: PackedViews,
        current_worker: int,
        input_bytes: float,
    ) -> int:
        """Batched Alg. 2: one vector evaluation of the adjustment
        estimate over every candidate, bit-exact with :meth:`adjust`
        (including the w_planned-first tie rule and the staleness-scaled
        hysteresis margin)."""
        dfg = job.dfg
        task = dfg.tasks[task_id]
        cfg = self.config
        w_planned = adfg[task_id]                               # line 1
        wait = max(0.0, float(pv.ft[w_planned]) - now)
        above = wait > self.profiles.runtime(task, w_planned) * (
            cfg.adjustment_threshold
        )                                                       # line 2
        if dfg.is_join(task_id) or not above:                   # lines 3-5
            return w_planned
        inf = float("inf")
        ft_map = np.maximum(now, pv.ft)                         # line 6
        live = self._liveness_cost_vec(pv, cfg.suspect_penalty_s)
        fresh = np.maximum(0.0, now - pv.pushed_at) <= cfg.intent_fresh_s
        td = self._td_model_vec(
            task, pv.bitmap, pv.avc, pv.intent, fresh,
            pv.fetch_model, pv.fetch_eta, ft_map,
        )
        est = ft_map + td + self.profiles.runtime_vec(task) + live
        path = self.profiles.path_time_vec(input_bytes, current_worker)
        path[current_worker] = 0.0                              # lines 10-11
        est = est + path
        # A DEAD row already prices to ∞ via `live`; unfit GPUs likewise.
        est = np.where(
            self.profiles.model_fits_vec(task.model_id), est, inf
        )
        # Scalar tie rule: the loop seeds best with w_planned, so it only
        # moves off the plan when some worker is *strictly* cheaper.
        m = float(est.min())
        best_w = (
            w_planned if float(est[w_planned]) == m else int(np.argmin(est))
        )
        best_ft = float(est[best_w])
        planned_ft = float(est[w_planned])
        margin = cfg.adjustment_margin
        if (
            best_w != w_planned
            and best_w != current_worker
            and cfg.staleness_margin_per_s > 0.0
        ):
            age = max(0.0, now - float(pv.pushed_at[best_w]))
            margin += cfg.staleness_margin_per_s * age
        held = best_w != w_planned and best_ft > planned_ft * (1.0 - margin)
        if held:
            return w_planned
        return best_w                                           # lines 12-13

    # -- recovery targeting ------------------------------------------------------
    def select_recovery_worker(
        self,
        job: Job,
        task_id: str,
        now: float,
        sst: Sequence[SSTRow],
        input_locations: Mapping[str, int],
        input_sizes: Mapping[str, float],
        candidates: Sequence[int],
    ) -> Optional[int]:
        """Full Navigator placement cost for a task stranded by churn:
        max(queue drain, input re-staging along the concrete paths) +
        Eq. 2 model cost + R(t, w) + membership risk — instead of the
        dispatcher's greedy earliest-start rule, which ignores worker
        speed, input shipping, and liveness.

        ``candidates`` is the dispatcher's ground-truth-feasible set
        (serving, reachable, can host the model), so a row the *reader's
        view* still marks DEAD is priced with the SUSPECT penalty rather
        than excluded: the evidence is stale, not authoritative."""
        task = job.dfg.tasks[task_id]
        ft_map = self._ft_map(now, sst)
        rec = self.recorder
        cands: List[CandidateCost] = []
        best_w: Optional[int] = None
        best_cost = float("inf")
        for w in candidates:
            row = sst[w]
            live = self._liveness_cost(row, self.config.suspect_penalty_s)
            if live == float("inf"):
                live = self.config.suspect_penalty_s
            td_in = 0.0
            for src, loc in input_locations.items():
                if loc != w:
                    td_in = max(
                        td_in,
                        self.cluster.path_transfer_time(
                            input_sizes.get(src, 0.0), loc, w
                        ),
                    )
            x = max(ft_map[w], now + td_in)
            td = self._td_model(
                task,
                w,
                row.cache_bitmap,
                row.free_cache_bytes,
                row.intent_bitmap,
                max(0.0, now - row.pushed_at)
                <= self.config.intent_fresh_s,
                row.fetch_model_id,
                row.fetch_eta_s,
                x,
            )
            rt = self.profiles.runtime(task, w)
            cost = x + td + rt + live
            if rec is not None:
                base = self._td_model(
                    task, w, row.cache_bitmap, row.free_cache_bytes
                )
                cands.append(CandidateCost(
                    worker=w, queue_s=ft_map[w], input_s=now + td_in,
                    model_s=td, intent_discount_s=max(0.0, base - td),
                    runtime_s=rt, liveness_s=live, total_s=cost,
                ))
            if cost < best_cost or (cost == best_cost and best_w is not None
                                    and w < best_w):
                best_w, best_cost = w, cost
        if rec is not None and best_w is not None:
            rec.record_placement(PlacementDecision(
                t=now, job_id=job.job_id, task_id=task_id, phase="recovery",
                scheduler=self.name, reader=-1, chosen=best_w,
                candidates=tuple(cands),
            ))
        return best_w


class JITScheduler(Scheduler):
    """Just-in-time baseline (§6.2.1): assigns each task as it becomes
    ready, to the worker with the earliest start (queue wait + model fetch
    + intermediate transfer).  Minimises each task's finish time in
    isolation — no intra-job coordination.

    JIT consumes Global State Monitor rows (load and cache bitmap alike,
    §6.2.1: "obtaining the start time estimates by taking worker-state
    information from Global State Monitor ... using the worker wait time,
    model fetch time and intermediate data transfer time").  What it lacks
    versus Navigator is intra-job coordination: fan-out siblings are placed
    greedily one at a time against the same snapshot, join placement cannot
    be pre-agreed, and there is no speculative model placement — which is
    why its hit rate sits between Hash's and Navigator's (Table 1).
    """

    name = "jit"
    plans_at_arrival = False

    def plan(self, job, now, origin_worker, sst) -> Optional[ADFG]:
        return None

    def select_worker_at_ready(
        self,
        job: Job,
        task_id: str,
        now: float,
        sst: Sequence[SSTRow],
        input_locations: Mapping[str, int],
        input_sizes: Mapping[str, float],
        self_worker: Optional[int] = None,
    ) -> int:
        if isinstance(sst, PackedViews):
            # Indexed engine hands packed columns (flight recorder off).
            return self._select_packed(
                job, task_id, now, sst,
                input_locations, input_sizes, self_worker,
            )
        dfg = job.dfg
        task = dfg.tasks[task_id]
        ft_map = self._ft_map(now, sst)
        rec = self.recorder
        cands: List[CandidateCost] = []
        best_w, best_ft = 0, float("inf")
        for w in range(len(ft_map)):
            if not self.profiles.model_fits(task.model_id, w):
                continue  # GPU can never host the model
            if sst[w].liveness == DEAD and w != self_worker:
                continue  # lease expired in this reader's view
            # Inputs that are not already on w must be transferred along
            # their holder → w path.
            td_in = 0.0
            for src, loc in input_locations.items():
                if loc != w:
                    td_in = max(
                        td_in,
                        self.cluster.path_transfer_time(
                            input_sizes[src], loc, w
                        ),
                    )
            td_model = 0.0
            if task.model_id is not None and not bitmaps.contains(
                sst[w].cache_bitmap, task.model_id
            ):
                td_model = self.profiles.td_model(task.model_id)
            rt = self.profiles.runtime(task, w)
            live = self._liveness_cost(sst[w], self.suspect_penalty_s)
            ft = max(ft_map[w], now + td_in) + td_model + rt + live
            if rec is not None:
                cands.append(CandidateCost(
                    worker=w, queue_s=ft_map[w], input_s=now + td_in,
                    model_s=td_model, intent_discount_s=0.0,
                    runtime_s=rt, liveness_s=live, total_s=ft,
                ))
            if ft < best_ft:
                best_w, best_ft = w, ft
        if rec is not None:
            rec.record_placement(PlacementDecision(
                t=now, job_id=job.job_id, task_id=task_id, phase="jit",
                scheduler=self.name,
                reader=self_worker if self_worker is not None else -1,
                chosen=best_w, candidates=tuple(cands),
            ))
        return best_w

    def _select_packed(
        self,
        job: Job,
        task_id: str,
        now: float,
        pv: PackedViews,
        input_locations: Mapping[str, int],
        input_sizes: Mapping[str, float],
        self_worker: Optional[int],
    ) -> int:
        """Batched JIT pick: one vector evaluation of the earliest-start
        estimate, bit-exact with the scalar loop (skipped workers price
        to ∞; ``np.argmin`` is the scalar's strict-< first minimum, and an
        all-∞ row degenerates to worker 0 exactly like the scalar seed)."""
        task = job.dfg.tasks[task_id]
        n = pv.n_workers
        ft_map = np.maximum(now, pv.ft)
        td_in = np.zeros(n)
        for src, loc in input_locations.items():
            v = self.profiles.path_time_vec(input_sizes[src], loc)
            v[loc] = 0.0
            td_in = np.maximum(td_in, v)
        if task.model_id is None:
            td_model = np.zeros(n)
        else:
            bit = np.uint64(1 << task.model_id)
            td_model = np.where(
                (pv.bitmap & bit) != 0,
                0.0,
                self.profiles.td_model(task.model_id),
            )
        live = self._liveness_cost_vec(pv, self.suspect_penalty_s)
        ft = (
            np.maximum(ft_map, now + td_in)
            + td_model
            + self.profiles.runtime_vec(task)
            + live
        )
        skip = ~self.profiles.model_fits_vec(task.model_id)
        dead_skip = pv.dead.copy()
        if self_worker is not None:
            dead_skip[self_worker] = False
        ft = np.where(skip | dead_skip, float("inf"), ft)
        return int(np.argmin(ft))


class HEFTScheduler(Scheduler):
    """Classic HEFT (§6.2.1): upward ranks + earliest-finish-time worker
    selection considering task parallelism and inter-task transfers, but
    with *no* notion of current worker queue load and *no* model locality;
    the plan is locked at job arrival (no dynamic adjustment)."""

    name = "heft"

    def plan(
        self,
        job: Job,
        now: float,
        origin_worker: int,
        sst: Sequence[SSTRow],
    ) -> ADFG:
        dfg = job.dfg
        workers = list(self.cluster.workers())
        # Worker availability *within this job only* — HEFT has no view of
        # the global queues.
        avail = {w: now for w in workers}
        adfg = ADFG(job)
        for tid in self.profiles.rank_order(dfg):
            task = dfg.tasks[tid]
            best_w, best_ft = -1, float("inf")
            for w in workers:
                at = now
                preds = dfg.preds[tid]
                if not preds:
                    if w != origin_worker:
                        at = now + self.profiles.td_input_to(
                            task, origin_worker, w
                        )
                else:
                    for p in preds:
                        ft_p = adfg.planned_ft[p]
                        if w != adfg[p]:
                            ft_p += self.profiles.td_output_to(
                                dfg.tasks[p], adfg[p], w
                            )
                        at = max(at, ft_p)
                # Every task pays the average model fetch cost regardless of
                # cache state: HEFT is model-locality-blind, but the fetch
                # is still part of the task's execution on the testbed.
                ft = max(avail[w], at) + self.profiles.runtime(task, w)
                if ft < best_ft:
                    best_w, best_ft = w, ft
            adfg[tid] = best_w
            adfg.planned_ft[tid] = best_ft
            avail[best_w] = best_ft
        return adfg


class HashScheduler(Scheduler):
    """Randomized hash placement (§6.2.1): uniform task spreading, the
    scheme "commonly used for workflow scheduling and load balancing"."""

    name = "hash"

    def plan(
        self,
        job: Job,
        now: float,
        origin_worker: int,
        sst: Sequence[SSTRow],
    ) -> ADFG:
        adfg = ADFG(job)
        for tid in job.dfg.topo_order:
            key = f"{tid}:{job.job_id}".encode()
            adfg[tid] = zlib.crc32(key) % self.cluster.n_workers
            adfg.planned_ft[tid] = now
        return adfg


SCHEDULERS = {
    "navigator": NavigatorScheduler,
    "jit": JITScheduler,
    "heft": HEFTScheduler,
    "hash": HashScheduler,
}


def make_scheduler(
    name: str,
    profiles: ProfileRepository,
    config: Optional[NavigatorConfig] = None,
) -> Scheduler:
    if name == "navigator":
        return NavigatorScheduler(profiles, config)
    try:
        return SCHEDULERS[name](profiles)
    except KeyError:
        raise ValueError(f"unknown scheduler {name!r}") from None

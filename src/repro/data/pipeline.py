"""Synthetic deterministic data pipeline.

Offline container ⇒ no real corpora; the pipeline produces a deterministic
token stream with realistic statistics (Zipfian unigram mix + short-range
repetition so the loss is learnable), sharded per host, packed into fixed
(batch, seq) blocks, with a simple background-prefetch iterator.  The
interface (``batches()``) is what a real corpus loader would implement.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.3
    repeat_p: float = 0.35  # P(copy a recent token) — gives learnable structure
    repeat_window: int = 16


class SyntheticTokens:
    """Deterministic, restartable synthetic token source."""

    def __init__(self, cfg: DataConfig, host_id: int = 0, n_hosts: int = 1):
        if cfg.global_batch % n_hosts:
            raise ValueError("global_batch must divide across hosts")
        self.cfg = cfg
        self.host_batch = cfg.global_batch // n_hosts
        self._rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, host_id])
        )
        # Zipfian unigram distribution over the vocab (stable across hosts).
        ranks = np.arange(1, cfg.vocab + 1, dtype=np.float64)
        probs = ranks ** (-cfg.zipf_a)
        self._probs = probs / probs.sum()

    def _sample_block(self) -> np.ndarray:
        cfg = self.cfg
        shape = (self.host_batch, cfg.seq_len)
        base = self._rng.choice(cfg.vocab, size=shape, p=self._probs)
        # Introduce short-range copies: tokens repeat from a recent window.
        rep = self._rng.random(shape) < cfg.repeat_p
        offsets = self._rng.integers(1, cfg.repeat_window + 1, size=shape)
        idx = np.arange(cfg.seq_len)[None, :] - offsets
        np.clip(idx, 0, None, out=idx)
        rows = np.arange(self.host_batch)[:, None]
        base = np.where(rep & (idx >= 0), base[rows, idx], base)
        return base.astype(np.int32)

    def batches(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield {"tokens": self._sample_block()}


class Prefetcher:
    """Background-thread prefetch (host → device overlap stand-in)."""

    def __init__(self, it: Iterator, depth: int = 2):
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._it = it
        self._done = object()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        try:
            for item in self._it:
                self._q.put(item)
        finally:
            self._q.put(self._done)

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is self._done:
            raise StopIteration
        return item


def make_pipeline(
    cfg: DataConfig,
    host_id: int = 0,
    n_hosts: int = 1,
    prefetch: int = 2,
) -> Iterator[Dict[str, np.ndarray]]:
    src = SyntheticTokens(cfg, host_id, n_hosts)
    it = src.batches()
    return Prefetcher(it, depth=prefetch) if prefetch else it

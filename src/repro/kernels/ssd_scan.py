"""Mamba-2 SSD (state-space duality) chunked-scan Pallas kernel.

The SSD insight: within a chunk of L steps the recurrence

    S_t = exp(a·dt_t)·S_{t-1} + dt_t·(x_t ⊗ b_t);   y_t = S_t·c_t

has a *dual* quadratic form — exactly a masked attention matrix

    y = ((C Bᵀ) ⊙ Γ) X + diag(exp(s)) (C · S_in)
    Γ_ij = exp(s_i − s_j)·dt_j · [j ≤ i],   s_i = Σ_{k≤i} a·dt_k

so the MXU does the heavy lifting inside chunks while only the (P×N)
state crosses chunk boundaries.  This is the TPU-native adaptation of the
paper's GPU algorithm: instead of warp-level scans, chunks map to MXU
matmuls and the inter-chunk state is carried in VMEM scratch across the
sequential minor grid dimension.

Grid: (batch, heads, T/chunk) — the chunk dimension iterates sequentially
(TPU grids are lexicographic), so the scratch state persists chunk→chunk.

VMEM per program (chunk = 128, P = 64, N = 128, f32):
  x (128×64) + b,c (2×128×128) + Γ (128×128) + state (64×128) ≈ 230 KB.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(
    x_ref,    # (1, chunk, 1, P)
    dt_ref,   # (1, chunk, 1)
    a_ref,    # (1,)
    b_ref,    # (1, chunk, 1, N)
    c_ref,    # (1, chunk, 1, N)
    y_ref,    # (1, chunk, 1, P)
    fs_ref,   # final state out: (1, 1, P, N)
    state_ref,  # VMEM scratch: (P, N) carried across chunks
    *,
    chunk: int,
    seq_len: int,
):
    ci = pl.program_id(2)
    n_c = pl.num_programs(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, :, 0].astype(jnp.float32)    # (L, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)  # (L,)
    a = a_ref[0].astype(jnp.float32)          # scalar
    b = b_ref[0, :, 0].astype(jnp.float32)    # (L, N)
    c = c_ref[0, :, 0].astype(jnp.float32)    # (L, N)

    # Zero padded steps so they neither decay nor inject state.
    t_pos = ci * chunk + jax.lax.iota(jnp.int32, chunk)
    valid = t_pos < seq_len
    dt = jnp.where(valid, dt, 0.0)

    s = jnp.cumsum(a * dt)                    # (L,) cumulative log-decay
    # Γ_ij = exp(s_i - s_j) · dt_j · [j ≤ i]
    li = jax.lax.iota(jnp.int32, chunk)
    causal = li[:, None] >= li[None, :]
    gamma = jnp.where(causal, jnp.exp(s[:, None] - s[None, :]), 0.0)
    gamma = gamma * dt[None, :]

    state_in = state_ref[...]                 # (P, N)
    # Intra-chunk (dual/attention form): ((C Bᵀ) ⊙ Γ) X
    cb = c @ b.T                              # (L, L)
    y_intra = (cb * gamma) @ x                # (L, P)
    # Inter-chunk: decayed input state read out by C.
    y_inter = jnp.exp(s)[:, None] * (c @ state_in.T)  # (L, P)
    y_ref[0, :, 0] = (y_intra + y_inter).astype(y_ref.dtype)

    # State update: S_out = exp(s_L)·S_in + Σ_j exp(s_L - s_j)·dt_j·(x_j ⊗ b_j)
    decay_all = jnp.exp(s[-1])
    w = jnp.exp(s[-1] - s) * dt               # (L,)
    state_new = decay_all * state_in + (x * w[:, None]).T @ b  # (P, N)
    state_ref[...] = state_new

    @pl.when(ci == n_c - 1)
    def _finish():
        fs_ref[0, 0] = state_new.astype(fs_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("chunk", "interpret")
)
def ssd_scan(
    x: jax.Array,
    dt: jax.Array,
    a: jax.Array,
    b: jax.Array,
    c: jax.Array,
    *,
    initial_state: Optional[jax.Array] = None,
    chunk: int = 128,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """x: (B,T,H,P); dt: (B,T,H); a: (H,); b/c: (B,T,H,N) →
    (y (B,T,H,P), final_state (B,H,P,N)).

    Note: ``initial_state`` is folded in by the wrapper (prepended as a
    virtual decayed contribution) — the kernel itself always starts from
    zero state; serving uses ``ssd_decode`` steps instead."""
    bsz, t, h, p = x.shape
    n = b.shape[-1]
    if initial_state is not None:
        raise NotImplementedError(
            "kernel path starts from zero state; pass initial_state only "
            "to the ref implementation"
        )
    chunk = min(chunk, t)
    t_pad = -(-t // chunk) * chunk
    if t_pad != t:
        pad3 = ((0, 0), (0, t_pad - t), (0, 0))
        x = jnp.pad(x, pad3 + ((0, 0),))
        dt = jnp.pad(dt, pad3)
        b = jnp.pad(b, pad3 + ((0, 0),))
        c = jnp.pad(c, pad3 + ((0, 0),))

    grid = (bsz, h, t_pad // chunk)
    y, fs = pl.pallas_call(
        functools.partial(_ssd_kernel, chunk=chunk, seq_len=t),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda bi, hi, ci: (bi, ci, hi, 0)),
            pl.BlockSpec((1, chunk, 1), lambda bi, hi, ci: (bi, ci, hi)),
            pl.BlockSpec((1,), lambda bi, hi, ci: (hi,)),
            pl.BlockSpec((1, chunk, 1, n), lambda bi, hi, ci: (bi, ci, hi, 0)),
            pl.BlockSpec((1, chunk, 1, n), lambda bi, hi, ci: (bi, ci, hi, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda bi, hi, ci: (bi, ci, hi, 0)),
            pl.BlockSpec((1, 1, p, n), lambda bi, hi, ci: (bi, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, t_pad, h, p), x.dtype),
            jax.ShapeDtypeStruct((bsz, h, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(x, dt, a, b, c)
    return y[:, :t], fs

"""One-token GQA decode attention Pallas kernel.

Decode attention is memory-bound: arithmetic intensity ≈ 2·group FLOPs per
cache byte, so the kernel's job is to stream the KV cache through VMEM at
HBM line rate while the (group × head_dim) query tile stays resident.

Tiling: grid = (batch, kv_heads, T/block_k).  Each program owns one KV
head, processes the whole query *group* for that head (group = H/KH rows —
a skinny matmul that still feeds the MXU/VPU), and iterates KV blocks via
the sequential minor grid dimension, carrying online-softmax statistics in
VMEM scratch across grid steps.

Invalid cache slots (≥ cache_len, ring-buffer tails) are masked with the
per-batch length passed as a scalar-prefetch operand.

VMEM per program (block_k = 512, hd = 128, f32): kv tiles 2×512×128×4 ≈
512 KB + scratch (G×128) — well under budget with double buffering.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _dec_kernel(
    len_ref,  # scalar prefetch: (B,) int32 in SMEM
    q_ref,    # (1, 1, G, D)
    k_ref,    # (1, block_k, 1, D)
    v_ref,    # (1, block_k, 1, D)
    o_ref,    # (1, 1, G, D)
    m_ref, l_ref, acc_ref,  # VMEM scratch: (G, 1), (G, 1), (G, D)
    *,
    block_k: int,
    kv_len: int,
    scale: float,
):
    bi = pl.program_id(0)
    ki = pl.program_id(2)
    n_k = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale     # (G, D)
    k = k_ref[0, :, 0].astype(jnp.float32)          # (bk, D)
    v = v_ref[0, :, 0].astype(jnp.float32)
    s = q @ k.T                                     # (G, bk)
    k_pos = ki * block_k + jax.lax.iota(jnp.int32, block_k)
    valid = k_pos < jnp.minimum(len_ref[bi], kv_len)
    s = jnp.where(valid[None, :], s, NEG_INF)

    m_prev = m_ref[...][:, 0]
    l_prev = l_ref[...][:, 0]
    acc_prev = acc_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_new = alpha * l_prev + jnp.sum(p, axis=-1)
    acc_new = acc_prev * alpha[:, None] + p @ v
    m_ref[...] = m_new[:, None]
    l_ref[...] = l_new[:, None]
    acc_ref[...] = acc_new

    @pl.when(ki == n_k - 1)
    def _finish():
        l = jnp.where(l_new == 0.0, 1.0, l_new)
        o_ref[0, 0] = (acc_new / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def decode_attention(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    cache_len: jax.Array,
    *,
    block_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """q: (B, H, D); k_cache/v_cache: (B, T, KH, D); cache_len: (B,) int32
    → (B, H, D)."""
    b, h, d = q.shape
    _, t, kh, _ = k_cache.shape
    group = h // kh
    scale = d ** -0.5
    block_k = min(block_k, t)

    # Pad the cache to a block multiple with zeros: ragged tail blocks are
    # masked by cache_len, and zero (not uninitialised) padding keeps the
    # 0-probability × value products finite.
    t_pad = -(-t // block_k) * block_k
    if t_pad != t:
        pad = ((0, 0), (0, t_pad - t), (0, 0), (0, 0))
        k_cache = jnp.pad(k_cache, pad)
        v_cache = jnp.pad(v_cache, pad)

    qg = q.reshape(b, kh, group, d)  # queries grouped per KV head
    grid = (b, kh, pl.cdiv(t, block_k))

    out = pl.pallas_call(
        functools.partial(
            _dec_kernel, block_k=block_k, kv_len=t, scale=scale
        ),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec(
                    (1, 1, group, d), lambda bi, hi, ki, *_: (bi, hi, 0, 0)
                ),
                pl.BlockSpec(
                    (1, block_k, 1, d), lambda bi, hi, ki, *_: (bi, ki, hi, 0)
                ),
                pl.BlockSpec(
                    (1, block_k, 1, d), lambda bi, hi, ki, *_: (bi, ki, hi, 0)
                ),
            ],
            out_specs=pl.BlockSpec(
                (1, 1, group, d), lambda bi, hi, ki, *_: (bi, hi, 0, 0)
            ),
            scratch_shapes=[
                pltpu.VMEM((group, 1), jnp.float32),
                pltpu.VMEM((group, 1), jnp.float32),
                pltpu.VMEM((group, d), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((b, kh, group, d), q.dtype),
        interpret=interpret,
    )(cache_len.astype(jnp.int32), qg, k_cache, v_cache)
    return out.reshape(b, h, d)

"""Pallas TPU kernels (+ jnp reference oracles) for the compute hot spots:

* ``flash_attention``  — blocked causal/windowed attention (prefill/train)
* ``decode_attention`` — one-token GQA decode against a KV cache
* ``ssd_scan``         — Mamba-2 SSD chunked state-space scan
* ``moe_gmm``          — grouped expert matmul for sorted MoE dispatch

Use ``repro.kernels.ops`` for the impl-dispatching wrappers.
"""

"""Blocked (flash) attention Pallas kernel for TPU.

Tiling: grid = (batch, q_heads, Sq/block_q); each program streams KV blocks
for its query tile through VMEM with an online-softmax accumulator.  The
MXU sees (block_q × head_dim) @ (head_dim × block_k) matmuls — block sizes
default to 128 to match the 128×128 systolic array, and head_dim is the
minor (lane) dimension so q/k/v tiles are (8,128)-aligned for bf16/f32.

GQA is handled in the index map: query head h reads KV head h // group.
Causal and sliding-window masks are applied per tile; KV tiles fully
outside the mask are skipped via the loop bounds (the causal lower-right
wavefront), which is where the 2× FLOP saving comes from.

VMEM budget per program (block_q = block_k = 128, hd = 128, f32):
  q (128×128) + k (128×128) + v (128×128) + acc (128×128) + stats ≈ 260 KB
— comfortably under the ~16 MB/core VMEM limit, leaving headroom for
double buffering of the KV stream.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _fa_kernel(
    q_ref, k_ref, v_ref, o_ref,
    *,
    block_q: int,
    block_k: int,
    kv_len: int,
    causal: bool,
    window: Optional[int],
    q_offset: int,
    scale: float,
):
    qi = pl.program_id(2)
    q = q_ref[0, 0].astype(jnp.float32) * scale  # (bq, d)
    q_pos = qi * block_q + jax.lax.iota(jnp.int32, block_q) + q_offset

    m0 = jnp.full((block_q,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q,), jnp.float32)
    acc0 = jnp.zeros((block_q, q_ref.shape[-1]), jnp.float32)

    n_kv = pl.cdiv(kv_len, block_k)
    if causal:
        # Last KV block that any query in this tile can see.
        hi = jnp.minimum(
            n_kv, (qi * block_q + block_q - 1 + q_offset) // block_k + 1
        )
    else:
        hi = n_kv
    if window is not None:
        lo = jnp.maximum(0, (qi * block_q + q_offset - window + 1) // block_k)
    else:
        lo = 0

    def body(ki, carry):
        m, l, acc = carry
        k = k_ref[0, 0, pl.dslice(ki * block_k, block_k), :].astype(
            jnp.float32
        )  # (bk, d)
        v = v_ref[0, 0, pl.dslice(ki * block_k, block_k), :].astype(
            jnp.float32
        )
        s = q @ k.T  # (bq, bk)
        k_pos = ki * block_k + jax.lax.iota(jnp.int32, block_k)
        mask = k_pos[None, :] < kv_len
        if causal:
            mask &= k_pos[None, :] <= q_pos[:, None]
        if window is not None:
            mask &= k_pos[None, :] > q_pos[:, None] - window
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m - m_new)
        l_new = alpha * l + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[:, None] + p @ v
        return m_new, l_new, acc_new

    m, l, acc = jax.lax.fori_loop(lo, hi, body, (m0, l0, acc0))
    l = jnp.where(l == 0.0, 1.0, l)  # fully-masked rows → zero output
    o_ref[0, 0] = (acc / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "causal", "window", "q_offset", "block_q", "block_k", "interpret"
    ),
)
def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    q_offset: int = 0,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """q: (B, Sq, H, D); k/v: (B, Sk, KH, D) → (B, Sq, H, D)."""
    b, sq, h, d = q.shape
    _, sk, kh, _ = k.shape
    group = h // kh
    scale = d ** -0.5

    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    # Pad KV to a block multiple: the in-kernel kv loop loads fixed-size
    # dslices, and the validity mask (k_pos < kv_len) keeps padding inert.
    sk_pad = -(-sk // block_k) * block_k
    if sk_pad != sk:
        pad = ((0, 0), (0, sk_pad - sk), (0, 0), (0, 0))
        k = jnp.pad(k, pad)
        v = jnp.pad(v, pad)
    qt = q.transpose(0, 2, 1, 3)  # (B, H, Sq, D)
    kt = k.transpose(0, 2, 1, 3)  # (B, KH, Sk, D)
    vt = v.transpose(0, 2, 1, 3)

    grid = (b, h, pl.cdiv(sq, block_q))
    out = pl.pallas_call(
        functools.partial(
            _fa_kernel,
            block_q=block_q,
            block_k=block_k,
            kv_len=sk,
            causal=causal,
            window=window,
            q_offset=q_offset,
            scale=scale,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec(
                (1, 1, block_q, d), lambda bi, hi, qi: (bi, hi, qi, 0)
            ),
            pl.BlockSpec(
                (1, 1, sk_pad, d),
                lambda bi, hi, qi, _g=group: (bi, hi // _g, 0, 0),
            ),
            pl.BlockSpec(
                (1, 1, sk_pad, d),
                lambda bi, hi, qi, _g=group: (bi, hi // _g, 0, 0),
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, block_q, d), lambda bi, hi, qi: (bi, hi, qi, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, d), q.dtype),
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)

"""jit-ready wrappers that dispatch between the Pallas TPU kernels and the
pure-jnp references.

``impl`` semantics:
  "ref"     — pure jnp (XLA-native).  Default for dry-runs / GSPMD lowering
              and the CPU container.
  "pallas"  — the Pallas kernel, compiled for TPU.
  "pallas_interpret" — the Pallas kernel body executed in Python on CPU
              (correctness validation; used by the kernel tests).
  "auto"    — pallas on TPU backends, ref elsewhere.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref


def _backend_is_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except Exception:  # pragma: no cover
        return False


def _resolve(impl: str) -> str:
    if impl == "auto":
        return "pallas" if _backend_is_tpu() else "ref"
    return impl


def _resolve_nonattn(impl: str) -> str:
    """Ops without a chunked/grouped-ref variant treat those as ref."""
    impl = _resolve(impl)
    return "ref" if impl in ("ref_chunked", "ref_grouped") else impl


# -- flash attention -----------------------------------------------------------
def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    q_offset: int = 0,
    impl: str = "ref",
    unroll: bool = False,
) -> jax.Array:
    impl = _resolve(impl)
    if impl in ("ref", "ref_grouped"):
        return _ref.attention_ref(
            q, k, v, causal=causal, window=window, q_offset=q_offset
        )
    if impl == "ref_chunked":
        return _ref.attention_chunked_ref(
            q, k, v, causal=causal, window=window, q_offset=q_offset,
            unroll=unroll,
        )
    from repro.kernels import flash_attention as _fa

    return _fa.flash_attention(
        q,
        k,
        v,
        causal=causal,
        window=window,
        q_offset=q_offset,
        interpret=(impl == "pallas_interpret"),
    )


# -- decode attention -----------------------------------------------------------
def decode_attention(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    cache_len: jax.Array,
    *,
    impl: str = "ref",
) -> jax.Array:
    impl = _resolve(impl)
    if impl in ("ref", "ref_chunked"):
        return _ref.decode_attention_ref(q, k_cache, v_cache, cache_len)
    if impl == "ref_grouped":
        return _ref.decode_attention_grouped_ref(q, k_cache, v_cache, cache_len)
    from repro.kernels import decode_attention as _da

    return _da.decode_attention(
        q, k_cache, v_cache, cache_len, interpret=(impl == "pallas_interpret")
    )


# -- Mamba2 SSD scan ---------------------------------------------------------------
def ssd_scan(
    x: jax.Array,
    dt: jax.Array,
    a: jax.Array,
    b: jax.Array,
    c: jax.Array,
    *,
    initial_state: Optional[jax.Array] = None,
    chunk: int = 64,
    impl: str = "ref",
    unroll: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    impl = _resolve_nonattn(impl)
    if impl == "ref":
        # Chunked dual form (same math as the kernel): the production jnp
        # path.  ``ref_sequential`` is the simple per-step oracle.
        return _ref.ssd_chunked_ref(
            x, dt, a, b, c,
            chunk=chunk, initial_state=initial_state, unroll=unroll,
        )
    if impl == "ref_sequential":
        return _ref.ssd_ref(x, dt, a, b, c, initial_state=initial_state)
    del unroll  # pallas path: chunk loop is the sequential grid dim
    from repro.kernels import ssd_scan as _ssd

    return _ssd.ssd_scan(
        x, dt, a, b, c,
        initial_state=initial_state,
        chunk=chunk,
        interpret=(impl == "pallas_interpret"),
    )


def ssd_decode(
    x: jax.Array,
    dt: jax.Array,
    a: jax.Array,
    b: jax.Array,
    c: jax.Array,
    state: jax.Array,
) -> Tuple[jax.Array, jax.Array]:
    """Single-step SSD (no kernel needed: pure elementwise + small matvec)."""
    return _ref.ssd_decode_ref(x, dt, a, b, c, state)


# -- grouped expert matmul ------------------------------------------------------------
def moe_gmm(
    x: jax.Array,
    w: jax.Array,
    group_sizes: jax.Array,
    *,
    impl: str = "ref",
) -> jax.Array:
    impl = _resolve_nonattn(impl)
    if impl == "ref":
        return _ref.moe_gmm_ref(x, w, group_sizes)
    from repro.kernels import moe_gmm as _gmm

    return _gmm.moe_gmm(
        x, w, group_sizes, interpret=(impl == "pallas_interpret")
    )

"""Pure-jnp reference oracles for every Pallas kernel.

These are the ground truth the kernel tests assert against, and also the
default compute path used when lowering on CPU / in the multi-pod dry-run
(XLA-native ops lower and shard cleanly under GSPMD; the Pallas kernels
target TPU and are validated in interpret mode).

Shapes follow the serving convention:
  q        : (batch, q_len, n_heads, head_dim)
  k, v     : (batch, kv_len, n_kv_heads, head_dim)    (GQA: n_heads % n_kv_heads == 0)
  output   : (batch, q_len, n_heads, head_dim)
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def _gqa_expand(k: jax.Array, n_heads: int) -> jax.Array:
    """Broadcast KV heads to query heads: (B,T,KH,D) → (B,T,H,D)."""
    b, t, kh, d = k.shape
    group = n_heads // kh
    if group == 1:
        return k
    return jnp.repeat(k, group, axis=2)


def attention_ref(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    q_offset: int = 0,
    scale: Optional[float] = None,
) -> jax.Array:
    """Full (prefill/train) attention with optional causal mask and
    sliding window.  ``q_offset`` is the absolute position of q[0] relative
    to k[0] (used when a query block attends into a longer KV history)."""
    b, sq, h, d = q.shape
    _, sk, kh, _ = k.shape
    scale = scale if scale is not None else d ** -0.5
    k = _gqa_expand(k, h)
    v = _gqa_expand(v, h)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    qpos = jnp.arange(sq)[:, None] + q_offset
    kpos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), dtype=bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    # Fully-masked rows (can happen with windows) produce NaNs; zero them.
    probs = jnp.where(jnp.isnan(probs), 0.0, probs)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs.astype(v.dtype), v)
    return out.astype(q.dtype)


def attention_chunked_ref(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    q_offset: int = 0,
    scale: Optional[float] = None,
    chunk_k: int = 1024,
    unroll: bool = False,
) -> jax.Array:
    """Flash-style attention in pure jnp: lax.scan over KV chunks with an
    online-softmax accumulator, so the (Sq × Sk) score matrix is never
    materialised — peak attention activation drops from O(Sq·Sk) to
    O(Sq·chunk_k).  This is the XLA-level analogue of the Pallas
    flash_attention kernel and the §Perf fix for the memory-bound prefill
    cases (the plain ref path writes the full score tensor to HBM)."""
    b, sq, h, d = q.shape
    _, sk, kh, _ = k.shape
    scale = scale if scale is not None else d ** -0.5
    chunk_k = min(chunk_k, sk)
    sk_pad = -(-sk // chunk_k) * chunk_k
    if sk_pad != sk:
        pad = ((0, 0), (0, sk_pad - sk), (0, 0), (0, 0))
        k = jnp.pad(k, pad)
        v = jnp.pad(v, pad)
    n_chunks = sk_pad // chunk_k
    group = h // kh
    kc = jnp.moveaxis(k.reshape(b, n_chunks, chunk_k, kh, d), 1, 0)
    vc = jnp.moveaxis(v.reshape(b, n_chunks, chunk_k, kh, d), 1, 0)
    qf = q.astype(jnp.float32) * scale
    q_pos = jnp.arange(sq) + q_offset

    def body(carry, inp):
        m, l, acc, ci = carry
        k_i, v_i = inp  # (B, ck, KH, D)
        k_i = _gqa_expand(k_i, h).astype(jnp.float32)
        v_i = _gqa_expand(v_i, h).astype(jnp.float32)
        s = jnp.einsum("bqhd,bkhd->bhqk", qf, k_i)
        k_pos = ci * chunk_k + jnp.arange(chunk_k)
        mask = k_pos[None, :] < sk
        if causal:
            mask = mask & (k_pos[None, :] <= q_pos[:, None])
        if window is not None:
            mask = mask & (k_pos[None, :] > q_pos[:, None] - window)
        s = jnp.where(mask[None, None], s, -jnp.inf)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        m_safe = jnp.where(jnp.isinf(m_new), 0.0, m_new)
        p = jnp.exp(s - m_safe[..., None])
        p = jnp.where(jnp.isinf(s), 0.0, p)
        alpha = jnp.exp(
            jnp.where(jnp.isinf(m), jnp.where(jnp.isinf(m_new), 0.0, -jnp.inf),
                      m - m_safe)
        )
        alpha = jnp.where(jnp.isinf(m) & jnp.isinf(m_new), 1.0, alpha)
        l_new = alpha * l + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p, v_i
        )
        return (m_new, l_new, acc_new, ci + 1), None

    m0 = jnp.full((b, h, sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, h, sq), jnp.float32)
    acc0 = jnp.zeros((b, h, sq, d), jnp.float32)
    if unroll:
        carry = (m0, l0, acc0, jnp.int32(0))
        for i in range(n_chunks):
            carry, _ = body(carry, (kc[i], vc[i]))
        m, l, acc, _ = carry
    else:
        (m, l, acc, _), _ = jax.lax.scan(
            body, (m0, l0, acc0, jnp.int32(0)), (kc, vc)
        )
    l = jnp.where(l == 0.0, 1.0, l)
    out = acc / l[..., None]
    return jnp.moveaxis(out, 1, 2).astype(q.dtype)


def decode_attention_ref(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    cache_len: jax.Array,
    *,
    scale: Optional[float] = None,
) -> jax.Array:
    """One-token GQA decode against a KV cache.

    q        : (B, H, D)       — the single new token's queries
    k_cache  : (B, T, KH, D)   — T = cache capacity
    cache_len: (B,) int32      — valid prefix length per sequence
    """
    b, h, d = q.shape
    _, t, kh, _ = k_cache.shape
    scale = scale if scale is not None else d ** -0.5
    k = _gqa_expand(k_cache, h)
    v = _gqa_expand(v_cache, h)
    logits = jnp.einsum("bhd,bkhd->bhk", q, k).astype(jnp.float32) * scale
    valid = jnp.arange(t)[None, :] < cache_len[:, None]
    logits = jnp.where(valid[:, None, :], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    probs = jnp.where(jnp.isnan(probs), 0.0, probs)
    out = jnp.einsum("bhk,bkhd->bhd", probs.astype(v.dtype), v)
    return out.astype(q.dtype)


def decode_attention_grouped_ref(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    cache_len: jax.Array,
    *,
    scale: Optional[float] = None,
) -> jax.Array:
    """GQA decode without materialising the expanded head dim: queries are
    grouped per KV head and contract directly against the unexpanded cache
    (einsum ``bkgd,btkd->bkgt``).  Functionally identical to
    ``decode_attention_ref``; structurally it keeps the cache's T axis the
    only shardable large dim, so GSPMD leaves the (T-sharded) cache in
    place instead of re-sharding it onto heads (which triggers a full
    cache all-gather — the §Perf P2 finding).  This mirrors the Pallas
    decode kernel's (KH, group) tiling."""
    b, h, d = q.shape
    _, t, kh, _ = k_cache.shape
    g = h // kh
    scale = scale if scale is not None else d ** -0.5
    qg = q.reshape(b, kh, g, d).astype(jnp.float32) * scale
    kf = k_cache.astype(jnp.float32)
    vf = v_cache.astype(jnp.float32)
    logits = jnp.einsum("bkgd,btkd->bkgt", qg, kf)
    valid = jnp.arange(t)[None, None, None, :] < cache_len[:, None, None, None]
    logits = jnp.where(valid, logits, -jnp.inf)
    m = jnp.max(logits, axis=-1, keepdims=True)
    m = jnp.where(jnp.isinf(m), 0.0, m)
    p = jnp.exp(logits - m)
    p = jnp.where(jnp.isinf(logits), 0.0, p)
    l = jnp.sum(p, axis=-1, keepdims=True)
    l = jnp.where(l == 0.0, 1.0, l)
    out = jnp.einsum("bkgt,btkd->bkgd", p / l, vf)
    return out.reshape(b, h, d).astype(q.dtype)


def ssd_ref(
    x: jax.Array,
    dt: jax.Array,
    a: jax.Array,
    b: jax.Array,
    c: jax.Array,
    *,
    initial_state: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Mamba-2 SSD (state-space duality) reference: sequential recurrence.

    x  : (B, T, H, P)    — input heads (P = head dim)
    dt : (B, T, H)       — positive step sizes (already softplus'd)
    a  : (H,)            — negative state decay (A < 0)
    b  : (B, T, H, N)    — input projection (N = state dim)
    c  : (B, T, H, N)    — output projection
    Returns (y: (B,T,H,P), final_state: (B,H,P,N)).

    Recurrence per head:  S_t = exp(a·dt_t)·S_{t-1} + dt_t·(x_t ⊗ b_t)
                          y_t = S_t · c_t
    """
    bs, t, h, p = x.shape
    n = b.shape[-1]
    if initial_state is None:
        initial_state = jnp.zeros((bs, h, p, n), dtype=jnp.float32)

    def step(state, inp):
        xt, dtt, bt, ct = inp  # (B,H,P), (B,H), (B,H,N), (B,H,N)
        decay = jnp.exp(a[None, :] * dtt)  # (B,H)
        upd = (dtt[..., None, None] * xt[..., :, None]) * bt[..., None, :]
        state = decay[..., None, None] * state + upd
        yt = jnp.einsum("bhpn,bhn->bhp", state, ct)
        return state, yt

    xs = (
        jnp.moveaxis(x.astype(jnp.float32), 1, 0),
        jnp.moveaxis(dt.astype(jnp.float32), 1, 0),
        jnp.moveaxis(b.astype(jnp.float32), 1, 0),
        jnp.moveaxis(c.astype(jnp.float32), 1, 0),
    )
    final, ys = jax.lax.scan(step, initial_state, xs)
    y = jnp.moveaxis(ys, 0, 1).astype(x.dtype)
    return y, final


def ssd_chunked_ref(
    x: jax.Array,
    dt: jax.Array,
    a: jax.Array,
    b: jax.Array,
    c: jax.Array,
    *,
    chunk: int = 128,
    initial_state: Optional[jax.Array] = None,
    unroll: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD in the dual (attention-like) form — the same math as
    the Pallas kernel, in pure jnp: per chunk a masked (L×L) matmul plus a
    carried (P×N) state.  This is the model's default compute path (the
    per-step ``ssd_ref`` stays the test oracle); ``unroll=True`` unrolls
    the chunk loop so XLA cost analysis sees every chunk (dry-run flops
    accounting — lax.scan bodies are otherwise counted once)."""
    bs, t, h, p = x.shape
    n = b.shape[-1]
    chunk = min(chunk, t)
    t_pad = -(-t // chunk) * chunk
    if t_pad != t:
        pad = ((0, 0), (0, t_pad - t), (0, 0))
        x = jnp.pad(x, pad + ((0, 0),))
        dt = jnp.pad(dt, pad)
        b = jnp.pad(b, pad + ((0, 0),))
        c = jnp.pad(c, pad + ((0, 0),))
    nchunks = t_pad // chunk
    xf = x.astype(jnp.float32).reshape(bs, nchunks, chunk, h, p)
    dtf = dt.astype(jnp.float32).reshape(bs, nchunks, chunk, h)
    bf = b.astype(jnp.float32).reshape(bs, nchunks, chunk, h, n)
    cf = c.astype(jnp.float32).reshape(bs, nchunks, chunk, h, n)
    if initial_state is None:
        state0 = jnp.zeros((bs, h, p, n), jnp.float32)
    else:
        state0 = initial_state.astype(jnp.float32)
    li = jnp.arange(chunk)
    causal = li[:, None] >= li[None, :]

    def chunk_step(state, inp):
        xc, dtc, bc, cc = inp  # (B,L,H,P), (B,L,H), (B,L,H,N), (B,L,H,N)
        s = jnp.cumsum(a[None, None, :] * dtc, axis=1)       # (B,L,H)
        gamma = jnp.where(
            causal[None, :, :, None],
            jnp.exp(s[:, :, None, :] - s[:, None, :, :]),
            0.0,
        ) * dtc[:, None, :, :]                                # (B,L,L,H)
        cb = jnp.einsum("blhn,bmhn->blmh", cc, bc)            # (B,L,L,H)
        y_intra = jnp.einsum("blmh,bmhp->blhp", cb * gamma, xc)
        y_inter = jnp.exp(s)[..., None] * jnp.einsum(
            "bhpn,blhn->blhp", state, cc
        ).transpose(0, 1, 2, 3)
        w = jnp.exp(s[:, -1:, :] - s) * dtc                   # (B,L,H)
        state = (
            jnp.exp(s[:, -1, :])[:, :, None, None] * state
            + jnp.einsum("blhp,blhn->bhpn", xc * w[..., None], bc)
        )
        return state, y_intra + y_inter

    xs = (
        jnp.moveaxis(xf, 1, 0),
        jnp.moveaxis(dtf, 1, 0),
        jnp.moveaxis(bf, 1, 0),
        jnp.moveaxis(cf, 1, 0),
    )
    if unroll:
        state = state0
        ys = []
        for i in range(nchunks):
            state, y = chunk_step(state, jax.tree.map(lambda v: v[i], xs))
            ys.append(y)
        y = jnp.stack(ys, axis=0)
    else:
        state, y = jax.lax.scan(chunk_step, state0, xs)
    y = jnp.moveaxis(y, 0, 1).reshape(bs, t_pad, h, p)[:, :t]
    return y.astype(x.dtype), state


def ssd_decode_ref(
    x: jax.Array,
    dt: jax.Array,
    a: jax.Array,
    b: jax.Array,
    c: jax.Array,
    state: jax.Array,
) -> Tuple[jax.Array, jax.Array]:
    """Single-step SSD recurrence for decode.

    x: (B,H,P), dt: (B,H), b/c: (B,H,N), state: (B,H,P,N)."""
    decay = jnp.exp(a[None, :] * dt.astype(jnp.float32))
    upd = (dt.astype(jnp.float32)[..., None, None]
           * x.astype(jnp.float32)[..., :, None]) * b.astype(jnp.float32)[..., None, :]
    new_state = decay[..., None, None] * state + upd
    y = jnp.einsum("bhpn,bhn->bhp", new_state, c.astype(jnp.float32))
    return y.astype(x.dtype), new_state


def moe_gmm_ref(
    x: jax.Array,
    w: jax.Array,
    group_sizes: jax.Array,
) -> jax.Array:
    """Grouped matmul reference: rows of ``x`` are sorted by expert;
    ``group_sizes[e]`` rows belong to expert ``e`` and are multiplied by
    ``w[e]``.

    x: (tokens, d_in), w: (E, d_in, d_out), group_sizes: (E,) summing to tokens.
    """
    tokens, d_in = x.shape
    e = w.shape[0]
    starts = jnp.cumsum(group_sizes) - group_sizes
    expert_of_row = jnp.sum(
        jnp.arange(tokens)[:, None] >= starts[None, :], axis=1
    ) - 1
    w_per_row = w[expert_of_row]  # (tokens, d_in, d_out)
    return jnp.einsum("ti,tio->to", x, w_per_row)

"""Grouped expert matmul (GMM) Pallas kernel for sorted MoE dispatch.

Input rows are pre-sorted by expert id; ``group_sizes[e]`` consecutive
rows belong to expert ``e``.  The kernel requires group boundaries to be
aligned to ``block_t`` (the wrapper pads each group — a static worst-case
pad of E·(block_t−1) rows), so every row tile maps to exactly one expert.

Grid: (T_padded/block_t, d_out/block_n).  The expert id of each row tile
is precomputed on the host side of the trace and passed as a
scalar-prefetch operand, which the *index maps* consume to page exactly
one expert's weight tile into VMEM per program — this is the kernel's
point: weights stream per-tile instead of materialising a (T, d_in, d_out)
gather the way the jnp oracle does.

VMEM per program (block_t = 128, d_in = 2048, block_n = 256, bf16):
  x (128×2048) + w (2048×256) + out (128×256) ≈ 1.6 MB.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gmm_kernel(expert_of_tile, x_ref, w_ref, o_ref):
    # w_ref has already been paged to this tile's expert by the index map.
    o_ref[...] = (
        x_ref[...].astype(jnp.float32) @ w_ref[0].astype(jnp.float32)
    ).astype(o_ref.dtype)


def pad_group_sizes(
    group_sizes: jax.Array, block_t: int
) -> Tuple[jax.Array, jax.Array]:
    """Round each group up to a multiple of ``block_t``.  Returns
    (padded_group_sizes, scatter indices mapping original rows → padded)."""
    padded = -(-group_sizes // block_t) * block_t
    return padded, jnp.cumsum(padded) - padded


@functools.partial(
    jax.jit, static_argnames=("block_t", "block_n", "interpret")
)
def moe_gmm(
    x: jax.Array,
    w: jax.Array,
    group_sizes: jax.Array,
    *,
    block_t: int = 128,
    block_n: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """x: (T, d_in) rows sorted by expert; w: (E, d_in, d_out);
    group_sizes: (E,) int32 summing to T → (T, d_out).

    The wrapper scatters rows into a block-aligned layout, runs the
    aligned kernel, and gathers back — alignment pad rows multiply by a
    valid expert's weights and are then dropped."""
    t, d_in = x.shape
    e, _, d_out = w.shape
    block_t = min(block_t, max(8, t))
    block_n = min(block_n, d_out)

    padded_sizes, padded_starts = pad_group_sizes(group_sizes, block_t)
    starts = jnp.cumsum(group_sizes) - group_sizes
    # Static worst case: every group padded by block_t - 1 rows.
    t_pad = int(-(-t // block_t) * block_t + e * block_t)
    # Destination slot of each original row.
    expert_of_row = jnp.sum(
        jnp.arange(t)[:, None] >= jnp.cumsum(group_sizes)[None, :], axis=1
    )
    dest = padded_starts[expert_of_row] + (jnp.arange(t) - starts[expert_of_row])
    x_al = jnp.zeros((t_pad, d_in), x.dtype).at[dest].set(x)

    # Expert owning each row tile (scalar prefetch for the index maps).
    n_tiles = t_pad // block_t
    tile_starts = jnp.arange(n_tiles) * block_t
    expert_of_tile = (
        jnp.sum(
            tile_starts[:, None] >= jnp.cumsum(padded_sizes)[None, :], axis=1
        )
    ).astype(jnp.int32)
    expert_of_tile = jnp.minimum(expert_of_tile, e - 1)

    out = pl.pallas_call(
        _gmm_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(n_tiles, d_out // block_n if d_out % block_n == 0
                  else -(-d_out // block_n)),
            in_specs=[
                pl.BlockSpec((block_t, d_in), lambda ti, ni, eot: (ti, 0)),
                pl.BlockSpec(
                    (1, d_in, block_n), lambda ti, ni, eot: (eot[ti], 0, ni)
                ),
            ],
            out_specs=pl.BlockSpec(
                (block_t, block_n), lambda ti, ni, eot: (ti, ni)
            ),
        ),
        out_shape=jax.ShapeDtypeStruct((t_pad, d_out), x.dtype),
        interpret=interpret,
    )(expert_of_tile, x_al, w)
    return out[dest]

"""Serving launcher: ``python -m repro.launch.serve --arch <id> [...]``.

Builds the sharded one-token serve step for the requested architecture
(reduced config by default), runs batched greedy decode against the
synthetic prompt source and reports tokens/s.  ``--optimized`` turns on
the §Perf serving path (grouped-GQA decode + one-hot cache writes; EP
dispatch for MoE archs).
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.launch.mesh import make_debug_mesh, make_production_mesh
from repro.models import init_cache, init_params
from repro.training import make_serve_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--capacity", type=int, default=256)
    ap.add_argument("--tokens", type=int, default=64)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--optimized", action="store_true",
                    help="§Perf serving path (grouped decode, onehot writes)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full:
        cfg = cfg.reduced(dtype="float32")
    if cfg.arch_type == "audio":
        raise SystemExit("use examples/serve_cluster.py for enc-dec serving")
    mesh = (
        make_production_mesh() if args.production_mesh else make_debug_mesh()
    )
    moe_dispatch = (
        "ep" if (args.optimized and cfg.n_experts) else
        ("sorted" if cfg.n_experts else "sorted")
    )
    _, jit_factory = make_serve_step(
        cfg, mesh,
        impl="ref_grouped" if args.optimized else "ref",
        cache_update="onehot" if args.optimized else "scatter",
        moe_dispatch=moe_dispatch,
        donate=False,
    )
    params = init_params(cfg, jax.random.key(0))
    cache = init_cache(cfg, args.batch, args.capacity)
    tokens0 = jnp.ones((args.batch,), jnp.int32)
    step = jit_factory(params, cache, tokens0)

    logits, cache = step(params, cache, tokens0)  # compile + first token
    jax.block_until_ready(logits)
    t0 = time.time()
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    for _ in range(args.tokens - 1):
        logits, cache = step(params, cache, tok)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    jax.block_until_ready(logits)
    dt = time.time() - t0
    rate = (args.tokens - 1) * args.batch / dt
    print(f"{cfg.name}: {rate:,.0f} tokens/s "
          f"({dt/(args.tokens-1)*1e3:.1f} ms/step, batch {args.batch}, "
          f"{'optimized' if args.optimized else 'baseline'} path)")


if __name__ == "__main__":
    main()

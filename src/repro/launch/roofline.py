"""Roofline analysis (deliverable g): derive the three roofline terms from
the dry-run artifacts in results/dryrun/ and identify each case's
bottleneck.

    compute_term    = HLO_FLOPs / peak_FLOP/s            [per chip]
    memory_term     = HLO_bytes / HBM_bw                 [per chip]
    collective_term = collective_bytes_weighted / ICI_bw [per chip]

HLO_FLOPs / HLO_bytes are the loop-corrected per-device numbers (see
dryrun.corrected_costs — XLA counts scan bodies once, so the dry-run
lowers unrolled 1/2-layer variants and solves for per-layer costs).
collective bytes use the per-device result-shape proxy with all-reduce
charged 2× (ring = reduce-scatter + all-gather phases).

MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE) for training;
            2·N(_active)·D for inference (forward only).
The ratio MODEL_FLOPS / HLO_FLOPs measures how much compiled compute is
"useful" (remat, dense-dispatch and replication waste push it down).

    PYTHONPATH=src python -m repro.launch.roofline [--dir results/dryrun]
"""

from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Any, Dict, List, Optional

from repro.configs import ARCHS
from repro.launch.mesh import HBM_BW, HBM_PER_CHIP, ICI_BW, PEAK_FLOPS_BF16
from repro.models.config import INPUT_SHAPES

# Time-conversion weights per collective kind (ring algorithm phases).
COLL_WEIGHT = {
    "all-gather": 1.0,
    "all-reduce": 2.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}


def model_flops(arch: str, shape_name: str) -> float:
    """Global MODEL_FLOPS for the case."""
    cfg = ARCHS[arch]
    shape = INPUT_SHAPES[shape_name]
    n = cfg.param_count(active_only=cfg.arch_type == "moe")
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    # decode: ONE token per sequence
    return 2.0 * n * shape.global_batch


def analyze(rec: Dict[str, Any]) -> Optional[Dict[str, Any]]:
    if not rec.get("ok") or rec.get("skipped"):
        return None
    corr = rec.get("corrected")
    flops = corr["flops"] if corr else rec["flops"]
    bytes_acc = corr["bytes_accessed"] if corr else rec["bytes_accessed"]
    colls = corr["collectives"] if corr else rec["collectives"]
    coll_bytes = sum(
        COLL_WEIGHT[k] * v for k, v in colls.items() if k in COLL_WEIGHT
    )
    compute_t = flops / PEAK_FLOPS_BF16
    memory_t = bytes_acc / HBM_BW
    coll_t = coll_bytes / ICI_BW
    terms = {"compute": compute_t, "memory": memory_t, "collective": coll_t}
    dominant = max(terms, key=terms.get)
    mf = model_flops(rec["arch"], rec["shape"])
    hlo_global = flops * rec["n_chips"]
    return {
        "arch": rec["arch"],
        "shape": rec["shape"],
        "mesh": rec["mesh"],
        "compute_s": compute_t,
        "memory_s": memory_t,
        "collective_s": coll_t,
        "dominant": dominant,
        "model_flops": mf,
        "hlo_flops_global": hlo_global,
        "useful_ratio": mf / hlo_global if hlo_global else 0.0,
        "state_gib_per_chip": rec["state_bytes_per_device"] / 2**30,
        "fits_hbm": rec["state_bytes_per_device"] < HBM_PER_CHIP,
        "step_time_lb_s": max(terms.values()),
        "roofline_frac": (
            compute_t / max(terms.values()) if max(terms.values()) else 0.0
        ),
    }


def load_all(result_dir: str) -> List[Dict[str, Any]]:
    out = []
    for path in sorted(glob.glob(os.path.join(result_dir, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        a = analyze(rec)
        if a:
            out.append(a)
    return out


def render_table(rows: List[Dict[str, Any]], mesh: str = "16x16") -> str:
    lines = [
        f"| arch | shape | compute s | memory s | collective s | bottleneck "
        f"| useful % | state GiB/chip | fits |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r["mesh"] != mesh:
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3e} | "
            f"{r['memory_s']:.3e} | {r['collective_s']:.3e} | "
            f"**{r['dominant']}** | {r['useful_ratio']*100:.1f} | "
            f"{r['state_gib_per_chip']:.2f} | "
            f"{'✓' if r['fits_hbm'] else '✗'} |"
        )
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--json-out", default="results/roofline.json")
    args = ap.parse_args()
    rows = load_all(args.dir)
    print(render_table(rows, args.mesh))
    os.makedirs(os.path.dirname(args.json_out) or ".", exist_ok=True)
    with open(args.json_out, "w") as f:
        json.dump(rows, f, indent=1)
    print(f"\n{len(rows)} cases → {args.json_out}")
    # Highlight candidates for the perf hillclimb.
    ranked = sorted(
        (r for r in rows if r["mesh"] == args.mesh),
        key=lambda r: r["roofline_frac"],
    )
    print("\nWorst roofline fraction (compute/dominant):")
    for r in ranked[:5]:
        print(
            f"  {r['arch']:22s} {r['shape']:12s} frac={r['roofline_frac']:.3f}"
            f" dominant={r['dominant']}"
        )


if __name__ == "__main__":
    main()

"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

Builds the sharded train step for the requested architecture (reduced
config by default so it runs on the host; ``--full`` uses the exact
assigned config — appropriate on a real pod), drives the synthetic data
pipeline, checkpoints periodically.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data import DataConfig, make_pipeline
from repro.launch.mesh import make_debug_mesh, make_production_mesh
from repro.models import init_params
from repro.training import checkpoint, make_train_step, optimizer as opt


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--moe-dispatch", default="sorted",
                    choices=["sorted", "scan", "ep"])
    ap.add_argument("--full", action="store_true",
                    help="use the full assigned config (pod-scale)")
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full:
        cfg = cfg.reduced(dtype="float32")
    mesh = (
        make_production_mesh() if args.production_mesh else make_debug_mesh()
    )
    print(f"arch={cfg.name} params={cfg.param_count()/1e6:.1f}M "
          f"mesh={dict(mesh.shape)}")

    params = init_params(cfg, jax.random.key(0))
    state = opt.init(params)
    ocfg = opt.AdamWConfig(lr=args.lr, warmup_steps=min(20, args.steps // 5 + 1),
                           total_steps=args.steps)
    step_fn, jit_factory = make_train_step(
        cfg, mesh, ocfg, accum_steps=args.accum,
        moe_dispatch=args.moe_dispatch, remat=False,
    )
    batch0 = {"tokens": jax.ShapeDtypeStruct((args.batch, args.seq), jnp.int32)}
    if cfg.arch_type == "vlm":
        batch0["vision_embeds"] = jax.ShapeDtypeStruct(
            (args.batch, 9, cfg.d_model), jnp.dtype(cfg.dtype))
    if cfg.arch_type == "audio":
        batch0["audio_frames"] = jax.ShapeDtypeStruct(
            (args.batch, cfg.n_audio_frames, cfg.d_model), jnp.dtype(cfg.dtype))
    step = jit_factory(params, state, batch0)

    data = make_pipeline(
        DataConfig(vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch)
    )
    t0 = time.time()
    for i in range(args.steps):
        batch = {"tokens": jnp.asarray(next(data)["tokens"])}
        if cfg.arch_type == "vlm":
            batch["vision_embeds"] = jnp.zeros(
                (args.batch, 9, cfg.d_model), jnp.dtype(cfg.dtype))
        if cfg.arch_type == "audio":
            batch["audio_frames"] = jnp.zeros(
                (args.batch, cfg.n_audio_frames, cfg.d_model),
                jnp.dtype(cfg.dtype))
        params, state, metrics = step(params, state, batch)
        if i % 10 == 0 or i == args.steps - 1:
            print(f"step {i:5d} loss {float(metrics['loss']):7.3f} "
                  f"gnorm {float(metrics['grad_norm']):6.2f} "
                  f"({(time.time()-t0)/(i+1):.2f}s/step)", flush=True)
        if args.ckpt and (i + 1) % args.ckpt_every == 0:
            checkpoint.save(args.ckpt, {"params": params},
                            metadata={"step": i + 1})
    if args.ckpt:
        checkpoint.save(args.ckpt, {"params": params},
                        metadata={"step": args.steps})
        print(f"checkpoint → {args.ckpt}")


if __name__ == "__main__":
    main()

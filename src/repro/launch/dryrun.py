import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e).

For every (architecture × input shape × mesh) combination this lowers the
appropriate step (train_step / prefill / serve_step) against abstract
ShapeDtypeStruct inputs, compiles it, and records:

  * compiled.cost_analysis()      → HLO FLOPs / bytes accessed (§Roofline)
  * compiled.memory_analysis()    → XLA's buffer accounting
  * analytic per-device state bytes (params/opt/cache ÷ shard counts)
  * collective bytes parsed from the optimized HLO text, by op kind

Results are written as JSON (one file per case) under --out; the roofline
driver (`repro.launch.roofline`) consumes them.

NOTE: the XLA_FLAGS line above MUST run before any other import touches
jax — jax locks the device count on first init.  Only this entry point
forces 512 host devices; tests and benches see the real device count.
"""

import argparse
import dataclasses
import json
import re
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, get_config
from repro.launch import mesh as mesh_mod
from repro.launch.specs import build_case, skip_reason
from repro.models.config import INPUT_SHAPES
from repro.models.sharding import (
    batch_pspecs,
    cache_pspecs,
    param_pspecs,
)
from repro.training.train import (
    make_prefill_step,
    make_serve_step,
    make_train_step,
)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "f32": 4, "s32": 4, "u32": 4,
    "f64": 8, "s64": 8, "u64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(text: str) -> int:
    """Sum byte sizes of every shape literal in an HLO result type."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-device result bytes of every collective op, by kind.

    Proxy semantics (documented in EXPERIMENTS.md §Roofline): result-shape
    bytes per participating device.  all-reduce is charged 2× when
    converted to time (ring = reduce-scatter + all-gather phases).
    """
    out = {k: 0 for k in _COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = re.match(r"%?[\w.\-]+\s*=\s*(.+?)\s+([\w\-]+)\(", stripped)
        if not m:
            continue
        result_type, opname = m.groups()
        base = opname.rstrip("0123456789.")
        # normalise e.g. all-gather-start / all-reduce-done
        for coll in _COLLECTIVES:
            if base == coll or base.startswith(coll + "-"):
                if base.endswith("-done"):
                    break  # counted at -start
                out[coll] += _shape_bytes(result_type)
                out["count"] += 1
                break
    return out


def _sharded_bytes(tree, pspecs, mesh) -> int:
    """Per-device bytes of a sharded abstract pytree."""
    from repro.models.sharding import _axis_size  # noqa

    total = 0
    for leaf, spec in zip(jax.tree.leaves(tree), jax.tree.leaves(
        pspecs, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec)
    )):
        n = int(np.prod(leaf.shape)) if leaf.shape else 1
        shards = 1
        for axis in spec:
            shards *= _axis_size(mesh, axis)
        total += n * jnp.dtype(leaf.dtype).itemsize // max(1, shards)
    return total


def _lower_costs(
    cfg, kind: str, shape, mesh, moe_dispatch: str,
    attn_impl: str = "ref", cache_update: str = "scatter",
    serve_layout: bool = False,
) -> Dict[str, Any]:
    """Lower+compile an UNROLLED variant and return its per-device HLO
    costs.  Used by the layer-count correction below."""
    case = _build_variant_case(cfg, kind, shape)
    if kind == "train":
        _, jf = make_train_step(
            cfg, mesh, moe_dispatch=moe_dispatch, accum_steps=1, unroll=True,
            impl=attn_impl,
        )
        fn = jf(case["params"], case["opt_state"], case["batch"])
        compiled = fn.lower(
            case["params"], case["opt_state"], case["batch"]
        ).compile()
    elif kind == "prefill":
        _, jf = make_prefill_step(
            cfg, mesh, moe_dispatch=moe_dispatch, unroll=True, impl=attn_impl
        )
        compiled = jf(case["params"], case["batch"]).lower(
            case["params"], case["batch"]
        ).compile()
    else:
        _, jf = make_serve_step(
            cfg, mesh, moe_dispatch=moe_dispatch, unroll=True,
            impl=attn_impl, cache_update=cache_update,
            serve_layout=serve_layout,
        )
        compiled = jf(case["params"], case["cache"], case["tokens"]).lower(
            case["params"], case["cache"], case["tokens"]
        ).compile()
    cost = compiled.cost_analysis() or {}
    colls = parse_collective_bytes(compiled.as_text())
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "colls": colls,
    }


def _build_variant_case(cfg, kind: str, shape) -> Dict[str, Any]:
    from repro.launch.specs import (
        abstract_cache,
        abstract_opt_state,
        batch_specs,
    )
    from repro.models import abstract_params
    from repro.configs import LONG_CONTEXT_WINDOW

    if kind == "train":
        params = abstract_params(cfg)
        return {
            "params": params,
            "opt_state": abstract_opt_state(params),
            "batch": batch_specs(cfg, shape.global_batch, shape.seq_len),
        }
    if kind == "prefill":
        return {
            "params": abstract_params(cfg),
            "batch": batch_specs(cfg, shape.global_batch, shape.seq_len),
        }
    capacity = shape.seq_len
    if shape.name == "long_500k":
        capacity = cfg.sliding_window or LONG_CONTEXT_WINDOW
    return {
        "params": abstract_params(cfg),
        "cache": abstract_cache(cfg, shape.global_batch, capacity),
        "tokens": jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32),
    }


def _combine(u: Dict, v: Dict, fu: float, fv: float) -> Dict:
    """fu*u + fv*v elementwise over {flops, bytes, colls}."""
    out = {
        "flops": fu * u["flops"] + fv * v["flops"],
        "bytes": fu * u["bytes"] + fv * v["bytes"],
        "colls": {},
    }
    for k in u["colls"]:
        out["colls"][k] = fu * u["colls"][k] + fv * v["colls"][k]
    return out


def corrected_costs(
    cfg, kind: str, shape, mesh, moe_dispatch: str,
    attn_impl: str = "ref", cache_update: str = "scatter",
    serve_layout: bool = False,
) -> Dict[str, Any]:
    """Exact per-device HLO costs with loop trip counts accounted.

    XLA's HloCostAnalysis counts while-loop bodies ONCE, so the scanned
    production artifact under-reports flops/bytes/collectives by ~n_layers.
    We lower small UNROLLED variants (1/2 layers; SSD chunk loop unrolled)
    at the TRUE input shape and solve for per-layer body + outside-loop
    costs:  U1 = outside + body, U2 = outside + 2·body →
            total = (2·U1 − U2) + L·(U2 − U1).
    Hybrids get a third variant to separate the shared-attention body from
    the per-layer SSM body (applications = L // attn_period).
    """
    def variant(n_layers, attn_period=None, n_enc=None):
        kw = dict(n_layers=n_layers)
        if attn_period is not None:
            kw["attn_period"] = attn_period
        if cfg.arch_type == "audio":
            kw["n_encoder_layers"] = n_enc if n_enc is not None else n_layers
        vcfg = dataclasses.replace(cfg, **kw)
        return _lower_costs(vcfg, kind, shape, mesh, moe_dispatch,
                            attn_impl=attn_impl, cache_update=cache_update,
                            serve_layout=serve_layout)

    if cfg.arch_type == "hybrid":
        l_real = cfg.n_layers
        napp = l_real // cfg.attn_period
        u1 = variant(2, attn_period=2)   # outside + 2·ssm + 1·attn
        u2 = variant(4, attn_period=2)   # outside + 4·ssm + 2·attn
        u3 = variant(4, attn_period=4)   # outside + 4·ssm + 1·attn
        attn = _combine(u2, u3, 1.0, -1.0)
        ssm = _combine(u3, u1, 0.5, -0.5)
        outside = _combine(
            _combine(u1, ssm, 1.0, -2.0), attn, 1.0, -1.0
        )
        total = _combine(
            _combine(outside, ssm, 1.0, float(l_real)), attn, 1.0, float(napp)
        )
        total["variants"] = 3
        return total

    u1 = variant(1)
    u2 = variant(2)
    body = _combine(u2, u1, 1.0, -1.0)
    outside = _combine(u1, body, 1.0, -1.0)
    total = _combine(outside, body, 1.0, float(cfg.n_layers))
    total["variants"] = 2
    return total


def run_case(
    arch: str,
    shape_name: str,
    multi_pod: bool = False,
    moe_dispatch: str = "sorted",
    correct_costs: bool = True,
    attn_impl: str = "ref",
    cache_update: str = "scatter",
    serve_layout: bool = False,
) -> Dict[str, Any]:
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    mesh_name = "2x16x16" if multi_pod else "16x16"
    rec: Dict[str, Any] = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "kind": shape.kind,
        "ok": False,
    }
    reason = skip_reason(cfg, shape)
    if reason:
        rec.update(skipped=True, reason=reason, ok=True)
        return rec

    mesh = mesh_mod.make_production_mesh(multi_pod=multi_pod)
    n_chips = int(np.prod(list(mesh.shape.values())))
    case = build_case(cfg, shape)
    cfg = case["cfg"]

    t0 = time.time()
    if case["kind"] == "train":
        _, jit_factory = make_train_step(
            cfg, mesh, moe_dispatch=moe_dispatch,
            accum_steps=case["accum_steps"], impl=attn_impl,
        )
        fn = jit_factory(case["params"], case["opt_state"], case["batch"])
        lowered = fn.lower(case["params"], case["opt_state"], case["batch"])
        state_bytes = _sharded_bytes(
            case["params"], param_pspecs(mesh, case["params"], cfg), mesh
        ) + _sharded_bytes(
            case["opt_state"].m, param_pspecs(mesh, case["params"], cfg), mesh
        ) * 2
        rec["accum_steps"] = case["accum_steps"]
    elif case["kind"] == "prefill":
        _, jit_factory = make_prefill_step(
            cfg, mesh, moe_dispatch=moe_dispatch, impl=attn_impl
        )
        fn = jit_factory(case["params"], case["batch"])
        lowered = fn.lower(case["params"], case["batch"])
        state_bytes = _sharded_bytes(
            case["params"], param_pspecs(mesh, case["params"], cfg), mesh
        )
    else:  # decode
        _, jit_factory = make_serve_step(
            cfg, mesh, moe_dispatch=moe_dispatch, impl=attn_impl,
            cache_update=cache_update, serve_layout=serve_layout,
        )
        fn = jit_factory(case["params"], case["cache"], case["tokens"])
        lowered = fn.lower(case["params"], case["cache"], case["tokens"])
        state_bytes = _sharded_bytes(
            case["params"], param_pspecs(mesh, case["params"], cfg), mesh
        ) + _sharded_bytes(
            case["cache"], cache_pspecs(mesh, case["cache"]), mesh
        )
    rec["lower_s"] = round(time.time() - t0, 2)

    t0 = time.time()
    compiled = lowered.compile()
    rec["compile_s"] = round(time.time() - t0, 2)

    cost = compiled.cost_analysis() or {}
    rec["flops"] = float(cost.get("flops", -1.0))
    rec["bytes_accessed"] = float(cost.get("bytes accessed", -1.0))
    try:
        ma = compiled.memory_analysis()
        rec["xla_memory"] = {
            "argument_bytes": getattr(ma, "argument_size_in_bytes", None),
            "output_bytes": getattr(ma, "output_size_in_bytes", None),
            "temp_bytes": getattr(ma, "temp_size_in_bytes", None),
            "peak_bytes": getattr(ma, "peak_memory_in_bytes", None),
        }
    except Exception as e:  # pragma: no cover
        rec["xla_memory"] = {"error": str(e)}

    hlo = compiled.as_text()
    rec["collectives"] = parse_collective_bytes(hlo)
    rec["state_bytes_per_device"] = int(state_bytes)
    rec["n_chips"] = n_chips
    rec["model_params"] = cfg.param_count()
    rec["model_params_active"] = cfg.param_count(active_only=True)

    if correct_costs:
        t0 = time.time()
        corr = corrected_costs(
            cfg, case["kind"], shape, mesh, moe_dispatch,
            attn_impl=attn_impl, cache_update=cache_update,
            serve_layout=serve_layout,
        )
        rec["corrected"] = {
            "flops": corr["flops"],
            "bytes_accessed": corr["bytes"],
            "collectives": corr["colls"],
            "variant_lower_s": round(time.time() - t0, 2),
        }
    rec["ok"] = True
    return rec


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None, help="arch id (default: all)")
    ap.add_argument("--shape", default=None, help="input shape (default: all)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--moe-dispatch", default="sorted",
                    choices=["sorted", "scan"])
    ap.add_argument("--no-correct", action="store_true",
                    help="skip the unrolled-variant cost correction")
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else sorted(ARCHS)
    shapes = [args.shape] if args.shape else list(INPUT_SHAPES)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    os.makedirs(args.out, exist_ok=True)
    failures = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch}__{shape}__{'2x16x16' if mp else '16x16'}"
                path = os.path.join(args.out, tag + ".json")
                try:
                    rec = run_case(arch, shape, multi_pod=mp,
                                   moe_dispatch=args.moe_dispatch,
                                   correct_costs=not args.no_correct)
                except Exception:
                    rec = {
                        "arch": arch, "shape": shape,
                        "mesh": "2x16x16" if mp else "16x16",
                        "ok": False, "error": traceback.format_exc(),
                    }
                    failures += 1
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                status = "SKIP" if rec.get("skipped") else (
                    "OK" if rec["ok"] else "FAIL"
                )
                extra = ""
                if rec.get("ok") and not rec.get("skipped"):
                    cf = rec.get("corrected", {}).get("flops")
                    extra = (
                        (f" cflops={cf:.3e}" if cf else "")
                        + f" flops={rec['flops']:.3e}"
                        f" state/dev={rec['state_bytes_per_device']/2**30:.2f}GiB"
                        f" coll={sum(v for k, v in rec['collectives'].items() if k != 'count')/2**30:.2f}GiB"
                        f" lower={rec['lower_s']}s compile={rec['compile_s']}s"
                    )
                print(f"[{status}] {tag}{extra}", flush=True)
    if failures:
        raise SystemExit(f"{failures} case(s) failed")


if __name__ == "__main__":
    main()

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf hillclimb driver: re-lower the three selected (arch × shape)
pairs with candidate optimizations and record hypothesis → change →
before → after against the paper-faithful baselines in results/dryrun/.

Pairs (selection rationale in EXPERIMENTS.md §Perf):
  P1 deepseek-v2-236b × prefill_32k — most collective-bound
  P2 llama3-405b × decode_32k       — most representative of the paper's
                                      serving/model-residency concern
  P3 granite-20b × prefill_32k      — worst memory-bound roofline fraction

    PYTHONPATH=src python -m repro.launch.perf [--step NAME]
"""

import argparse
import json

from repro.launch.dryrun import run_case
from repro.launch.roofline import analyze

# (tag, arch, shape, kwargs) — each entry is one hypothesis→change cycle.
STEPS = [
    # P1 iteration 1: EP MoE dispatch.
    ("p1_deepseek_prefill_ep", "deepseek-v2-236b", "prefill_32k",
     dict(moe_dispatch="ep")),
    # P1 iteration 2: + chunked attention (memory term).
    ("p1_deepseek_prefill_ep_chunked", "deepseek-v2-236b", "prefill_32k",
     dict(moe_dispatch="ep", attn_impl="ref_chunked")),
    # P2 iteration 1: scatter-free cache update.
    ("p2_llama3_decode_onehot", "llama3-405b", "decode_32k",
     dict(cache_update="onehot")),
    # P2 iteration 2: weight-stationary serving layout.
    ("p2_llama3_decode_servelayout", "llama3-405b", "decode_32k",
     dict(cache_update="onehot", serve_layout=True)),
    # P2 iteration 3: grouped-GQA decode einsum (no head expansion).
    ("p2_llama3_decode_grouped", "llama3-405b", "decode_32k",
     dict(cache_update="onehot", attn_impl="ref_grouped")),
    # P3 iteration 1: chunked (flash-style) attention.
    ("p3_granite_prefill_chunked", "granite-20b", "prefill_32k",
     dict(attn_impl="ref_chunked")),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--step", default=None)
    ap.add_argument("--out", default="results/perf")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    for tag, arch, shape, kw in STEPS:
        if args.step and args.step != tag:
            continue
        rec = run_case(arch, shape, multi_pod=False, **kw)
        with open(os.path.join(args.out, tag + ".json"), "w") as f:
            json.dump(rec, f, indent=1)
        a = analyze(rec)
        print(
            f"[{tag}] compute={a['compute_s']:.3e}s memory={a['memory_s']:.3e}s "
            f"collective={a['collective_s']:.3e}s dominant={a['dominant']} "
            f"useful={a['useful_ratio']*100:.1f}%",
            flush=True,
        )


if __name__ == "__main__":
    main()

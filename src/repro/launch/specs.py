"""ShapeDtypeStruct stand-ins for every model input — weak-type-correct,
shardable, zero allocation (the dry-run's contract).

``build_case(cfg, shape)`` returns everything the dry-run needs:
  kind       : "train" | "prefill" | "decode"
  cfg        : possibly adjusted ModelConfig (sliding window for long_500k)
  params     : abstract param tree
  extras     : kind-specific abstract inputs (opt state / cache / tokens)
  accum_steps: microbatching for the train shape (memory lever)
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs import LONG_CONTEXT_WINDOW
from repro.models import abstract_params, init_cache
from repro.models.config import INPUT_SHAPES, InputShape, ModelConfig
from repro.training import optimizer as opt

SDS = jax.ShapeDtypeStruct


def _tokens(batch: int, seq: int) -> SDS:
    return SDS((batch, seq), jnp.int32)


def batch_specs(cfg: ModelConfig, batch: int, seq: int) -> Dict[str, SDS]:
    """Abstract train/prefill inputs, including the modality-stub tensors
    (patch/frame embeddings) for vlm/audio."""
    dt = jnp.dtype(cfg.dtype)
    out: Dict[str, SDS] = {"tokens": _tokens(batch, seq)}
    if cfg.arch_type == "vlm":
        out["vision_embeds"] = SDS(
            (batch, cfg.n_vision_tokens, cfg.d_model), dt
        )
    if cfg.arch_type == "audio":
        out["audio_frames"] = SDS(
            (batch, cfg.n_audio_frames, cfg.d_model), dt
        )
    return out


def abstract_cache(cfg: ModelConfig, batch: int, capacity: int):
    return jax.eval_shape(
        lambda: init_cache(cfg, batch, capacity)
    )


def abstract_opt_state(params, moment_dtype=jnp.bfloat16):
    return jax.eval_shape(
        lambda: opt.init(params, moment_dtype=moment_dtype)
    )


# Microbatch counts for train_4k: chosen so the per-microbatch activation
# working set stays ≈ pod-friendly (batch 256 → micro of 256/accum).
TRAIN_ACCUM = {
    "llama3-405b": 16,
    "mistral-large-123b": 8,
    "deepseek-v2-236b": 8,
    "qwen2-vl-72b": 8,
    "granite-20b": 4,
    "qwen3-moe-30b-a3b": 4,
    "mistral-nemo-12b": 4,
    "zamba2-7b": 2,
    "mamba2-780m": 1,
    "whisper-medium": 1,
}


def skip_reason(cfg: ModelConfig, shape: InputShape) -> str:
    """Non-empty string → this (arch × shape) pair is skipped by design."""
    if shape.name == "long_500k" and cfg.arch_type == "audio":
        return (
            "whisper decoder is architecturally bounded to ~448-token "
            "contexts against a 1500-frame encoder; a 524k decode is "
            "meaningless (DESIGN.md §4)"
        )
    return ""


def build_case(cfg: ModelConfig, shape: InputShape) -> Dict[str, Any]:
    reason = skip_reason(cfg, shape)
    if reason:
        raise ValueError(f"skipped by design: {reason}")

    if shape.kind == "train":
        params = abstract_params(cfg)
        return {
            "kind": "train",
            "cfg": cfg,
            "params": params,
            "opt_state": abstract_opt_state(params),
            "batch": batch_specs(cfg, shape.global_batch, shape.seq_len),
            "accum_steps": TRAIN_ACCUM.get(cfg.name, 1),
        }

    if shape.kind == "prefill":
        params = abstract_params(cfg)
        return {
            "kind": "prefill",
            "cfg": cfg,
            "params": params,
            "batch": batch_specs(cfg, shape.global_batch, shape.seq_len),
        }

    # decode: ONE new token against a cache of shape.seq_len.
    serve_cfg = cfg
    capacity = shape.seq_len
    if shape.name == "long_500k":
        # Sub-quadratic requirement: SSM/hybrid are O(1)-state natively;
        # attention archs decode against a sliding-window ring buffer.
        if cfg.arch_type in ("ssm",):
            capacity = 1  # state caches ignore capacity
        elif cfg.arch_type == "hybrid":
            capacity = cfg.sliding_window or LONG_CONTEXT_WINDOW
        else:
            serve_cfg = dataclasses.replace(
                cfg, sliding_window=LONG_CONTEXT_WINDOW
            )
            capacity = LONG_CONTEXT_WINDOW
    params = abstract_params(serve_cfg)
    cache = abstract_cache(serve_cfg, shape.global_batch, capacity)
    return {
        "kind": "decode",
        "cfg": serve_cfg,
        "params": params,
        "cache": cache,
        "tokens": SDS((shape.global_batch,), jnp.int32),
    }

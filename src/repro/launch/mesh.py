"""Production meshes.

Defined as functions (never module-level constants) so importing this
module never touches jax device state — only ``dryrun.py`` forces the
512-device host platform.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips (one v5e pod).
    Multi-pod: (pod=2, data=16, model=16) = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_devices: int = 1):
    """Whatever devices exist, as a (data, model) mesh — for CPU tests."""
    n = min(n_devices, len(jax.devices()))
    return jax.make_mesh((n, 1), ("data", "model"))


# TPU v5e hardware constants (roofline denominators; see EXPERIMENTS.md).
PEAK_FLOPS_BF16 = 197e12      # per chip
HBM_BW = 819e9                # bytes/s per chip
ICI_BW = 50e9                 # bytes/s per link
HBM_PER_CHIP = 16 * 1024**3   # bytes

"""Assigned-architecture registry: ``get_config(arch_id)`` / ``ARCHS``."""

from repro.models.config import ModelConfig

from repro.configs.granite_20b import CONFIG as granite_20b
from repro.configs.qwen3_moe_30b_a3b import CONFIG as qwen3_moe_30b_a3b
from repro.configs.mamba2_780m import CONFIG as mamba2_780m
from repro.configs.deepseek_v2_236b import CONFIG as deepseek_v2_236b
from repro.configs.llama3_405b import CONFIG as llama3_405b
from repro.configs.mistral_large_123b import CONFIG as mistral_large_123b
from repro.configs.zamba2_7b import CONFIG as zamba2_7b
from repro.configs.mistral_nemo_12b import CONFIG as mistral_nemo_12b
from repro.configs.qwen2_vl_72b import CONFIG as qwen2_vl_72b
from repro.configs.whisper_medium import CONFIG as whisper_medium

ARCHS = {
    c.name: c
    for c in [
        granite_20b,
        qwen3_moe_30b_a3b,
        mamba2_780m,
        deepseek_v2_236b,
        llama3_405b,
        mistral_large_123b,
        zamba2_7b,
        mistral_nemo_12b,
        qwen2_vl_72b,
        whisper_medium,
    ]
}

# Sliding window used for long-context (524k) decode on archs whose
# attention is otherwise quadratic/full (DESIGN.md §4).
LONG_CONTEXT_WINDOW = 8192


def get_config(arch_id: str) -> ModelConfig:
    try:
        return ARCHS[arch_id]
    except KeyError:
        raise KeyError(
            f"unknown arch {arch_id!r}; available: {sorted(ARCHS)}"
        ) from None

"""deepseek-v2-236b [moe]: MLA kv_lora=512, 2 shared + 160 routed top-6
[arXiv:2405.04434]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    arch_type="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,        # MLA: per-head K/V reconstructed from the latent
    d_ff=0,
    vocab=102400,
    head_dim=128,          # nope head dim
    use_mla=True,
    kv_lora_rank=512,
    q_lora_rank=1536,
    rope_head_dim=64,
    n_experts=160,
    top_k=6,
    n_shared_experts=2,
    d_ff_expert=1536,
)

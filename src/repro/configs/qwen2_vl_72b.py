"""qwen2-vl-72b [vlm]: M-RoPE, dynamic-resolution ViT frontend (stub)
[arXiv:2409.12191].  input_specs() supplies patch embeddings."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b",
    arch_type="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=29568,
    vocab=152064,
    head_dim=128,
    use_mrope=True,
    mrope_sections=(16, 24, 24),
    n_vision_tokens=1024,
    rope_theta=1e6,
)

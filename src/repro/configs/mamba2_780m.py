"""mamba2-780m [ssm]: attention-free SSD backbone [arXiv:2405.21060]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-780m",
    arch_type="ssm",
    n_layers=48,
    d_model=1536,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab=50280,
    ssm_state=128,
    ssm_head_dim=64,       # d_inner = 3072 → 48 SSD heads
    ssm_expand=2,
    ssm_chunk=128,
    conv_kernel=4,
)

"""zamba2-7b [hybrid]: Mamba2 backbone + shared attention block applied
every 6 layers [arXiv:2411.15242].  The shared block's sliding window makes
long_500k decode natural (window cache is O(window))."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    arch_type="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    d_ff=14336,
    vocab=32000,
    head_dim=112,
    ssm_state=64,
    ssm_head_dim=64,       # d_inner = 7168 → 112 SSD heads
    ssm_expand=2,
    ssm_chunk=128,
    attn_period=6,
    sliding_window=4096,
)

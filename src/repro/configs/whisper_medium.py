"""whisper-medium [audio]: enc-dec, mel/conv frontend is a stub supplying
frame embeddings [arXiv:2212.04356]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    arch_type="audio",
    n_layers=24,           # decoder layers
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab=51865,
    head_dim=64,
    n_encoder_layers=24,
    n_audio_frames=1500,
)

"""Serving DFGs whose vertices are the assigned architectures.

This closes the loop between the two halves of the repo: Navigator
schedules *workflows of models*, and the assigned-architecture zoo
provides the models.  Each pipeline mirrors a realistic multi-model
serving pattern; profiles (runtime, model bytes) derive from the configs
so the scheduler sees the real size/compute asymmetries (e.g. the MoE
model is huge to cache but cheap to run — exactly the regime where
Navigator's cache-aware placement matters most; DESIGN.md §4).
"""

from __future__ import annotations

from typing import Dict, List, Tuple

from repro.configs import ARCHS
from repro.core.types import DFG, MB, MLModel, TaskSpec

# model-id assignments within the SST's 0..63 space
ARCH_MODEL_IDS: Dict[str, int] = {
    name: i for i, name in enumerate(sorted(ARCHS))
}

# TPU-serving runtime estimate: ~2·N_active·D / (utilized flops);
# coarse but keeps relative magnitudes right for the scheduler.
_UTILIZED_FLOPS = 0.4 * 197e12


def _serve_runtime_s(arch: str, tokens: int = 256) -> float:
    cfg = ARCHS[arch]
    n = cfg.param_count(active_only=cfg.arch_type == "moe")
    return max(2.0 * n * tokens / _UTILIZED_FLOPS, 1e-3)


def arch_models() -> Dict[int, MLModel]:
    out = {}
    for name, mid in ARCH_MODEL_IDS.items():
        cfg = ARCHS[name]
        out[mid] = MLModel(mid, name, float(cfg.param_count() * 2))  # bf16
    return out


def _task(tid: str, arch: str, tokens: int = 256, out_mb: float = 0.05,
          in_mb: float = 0.05) -> TaskSpec:
    return TaskSpec(
        tid,
        _serve_runtime_s(arch, tokens),
        model_id=ARCH_MODEL_IDS[arch],
        output_bytes=out_mb * MB,
        input_bytes=in_mb * MB,
    )


def speculative_pipeline() -> DFG:
    """Draft (mamba2, O(1)-state) → verify (llama3) → safety (nemo)."""
    return DFG(
        "spec_decode",
        tasks=[
            _task("draft", "mamba2-780m", tokens=512),
            _task("verify", "llama3-405b", tokens=64),
            _task("safety", "mistral-nemo-12b", tokens=64),
        ],
        edges=[("draft", "verify"), ("verify", "safety")],
    )


def multimodal_pipeline() -> DFG:
    """Transcribe (whisper) ∥ perceive (qwen2-vl) → reason (deepseek MoE)."""
    return DFG(
        "multimodal_assist",
        tasks=[
            _task("transcribe", "whisper-medium", tokens=128, in_mb=2.0),
            _task("perceive", "qwen2-vl-72b", tokens=256, in_mb=4.0),
            _task("reason", "deepseek-v2-236b", tokens=256),
        ],
        edges=[("transcribe", "reason"), ("perceive", "reason")],
    )


def code_pipeline() -> DFG:
    """Route (qwen3 MoE) → generate (granite code) → review (mistral-large)."""
    return DFG(
        "code_assist",
        tasks=[
            _task("route", "qwen3-moe-30b-a3b", tokens=64),
            _task("generate", "granite-20b", tokens=512),
            _task("review", "mistral-large-123b", tokens=256),
        ],
        edges=[("route", "generate"), ("generate", "review")],
    )


def longdoc_pipeline() -> DFG:
    """Skim (zamba2 hybrid, long-context) → answer (mistral-large)."""
    return DFG(
        "longdoc_qa",
        tasks=[
            _task("skim", "zamba2-7b", tokens=2048, in_mb=8.0),
            _task("answer", "mistral-large-123b", tokens=256),
        ],
        edges=[("skim", "answer")],
    )


def arch_dfgs() -> List[DFG]:
    return [
        speculative_pipeline(),
        multimodal_pipeline(),
        code_pipeline(),
        longdoc_pipeline(),
    ]

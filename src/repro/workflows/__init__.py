from repro.workflows.paper_pipelines import (
    DFG_MIX,
    MODELS,
    MODEL_SPECS,
    image_caption_dfg,
    paper_dfgs,
    perception_dfg,
    translation_dfg,
    vpa_dfg,
)

__all__ = [
    "DFG_MIX",
    "MODELS",
    "MODEL_SPECS",
    "image_caption_dfg",
    "paper_dfgs",
    "perception_dfg",
    "translation_dfg",
    "vpa_dfg",
]

"""The four Figure-1 workflows and their profiled parameters (§2.1, §6).

Model sizes follow the paper's description: each model object is "several
GB in size" and the aggregate over the full set of DFGs is "nearly 35 GB"
(§2.2), exceeding a single 16 GB T4.  Runtimes are chosen so that on an
idle system with models cached the completion times fall in the paper's
reported 1–3 s range (§6), and so that the image-description and
3D-perception pipelines have "relatively short runtimes" compared to the
translation and Q&A pipelines (§6.2.2).

Model id space 0..63 per the SST bitmap encoding.  The mt5 model plays two
roles in the translation pipeline but is a single model object; BART is
shared between the image-caption and VPA pipelines — exactly the
cross-pipeline model reuse the paper exploits (§3.3).
"""

from __future__ import annotations

from typing import Dict, List, Tuple

from repro.core.types import DFG, GB, MB, MLModel, TaskSpec, models_from_specs

# -- model catalog ------------------------------------------------------------
# id: (name, decompressed GPU bytes)
MODEL_SPECS: Dict[int, Tuple[str, float]] = {
    0: ("opt-1.3b", 6.5 * GB),
    1: ("marian-en-fr", 2.2 * GB),
    2: ("mt5-zh-ja-en", 5.8 * GB),
    3: ("vit-gpt2-captioning", 4.2 * GB),
    4: ("espnet-tts", 3.1 * GB),
    5: ("bart-large", 4.8 * GB),
    6: ("detr-resnet", 3.4 * GB),
    7: ("glpn-depth", 3.6 * GB),
}
# Aggregate = 33.6 GB ≈ "nearly 35GB" (§2.2).

MODELS: Dict[int, MLModel] = models_from_specs(MODEL_SPECS)


def translation_dfg() -> DFG:
    """Fig. 1a: multilingual meeting auto-caption.  OPT preprocess → Marian
    (fr) ∥ mt5 (zh) ∥ mt5 (ja) → aggregate."""
    return DFG(
        "translation",
        tasks=[
            TaskSpec("opt_ingest", 0.80, model_id=0, output_bytes=0.3 * MB,
                     input_bytes=0.2 * MB),
            TaskSpec("marian_fr", 0.33, model_id=1, output_bytes=0.1 * MB),
            TaskSpec("mt5_zh", 0.40, model_id=2, output_bytes=0.1 * MB),
            TaskSpec("mt5_ja", 0.40, model_id=2, output_bytes=0.1 * MB),
            TaskSpec("aggregate", 0.04, model_id=None, output_bytes=0.3 * MB),
        ],
        edges=[
            ("opt_ingest", "marian_fr"),
            ("opt_ingest", "mt5_zh"),
            ("opt_ingest", "mt5_ja"),
            ("marian_fr", "aggregate"),
            ("mt5_zh", "aggregate"),
            ("mt5_ja", "aggregate"),
        ],
    )


def image_caption_dfg() -> DFG:
    """Fig. 1b: children's-education image reader.  ViT-GPT2 caption →
    BART child-safety filter → ESPnet vocalization."""
    return DFG(
        "image_caption",
        tasks=[
            TaskSpec("vit_gpt2_caption", 0.15, model_id=3,
                     output_bytes=0.05 * MB, input_bytes=2.0 * MB),
            TaskSpec("bart_safety", 0.07, model_id=5, output_bytes=0.05 * MB),
            TaskSpec("espnet_tts", 0.12, model_id=4, output_bytes=1.5 * MB),
        ],
        edges=[
            ("vit_gpt2_caption", "bart_safety"),
            ("bart_safety", "espnet_tts"),
        ],
    )


def vpa_dfg() -> DFG:
    """Fig. 1c: virtual personal assistant Q&A.  OPT (prompted) → BART
    (adult-targeted)."""
    return DFG(
        "vpa_dialogue",
        tasks=[
            TaskSpec("opt_dialogue", 0.95, model_id=0, output_bytes=0.2 * MB,
                     input_bytes=0.1 * MB),
            TaskSpec("bart_shape", 0.52, model_id=5, output_bytes=0.2 * MB),
        ],
        edges=[("opt_dialogue", "bart_shape")],
    )


def perception_dfg() -> DFG:
    """Fig. 1d: vision-impaired assistance.  DETR object detection ∥ GLPN
    depth estimation → combine."""
    return DFG(
        "perception3d",
        tasks=[
            TaskSpec("detr_objects", 0.14, model_id=6, output_bytes=0.4 * MB,
                     input_bytes=2.0 * MB),
            TaskSpec("glpn_depth", 0.16, model_id=7, output_bytes=1.0 * MB,
                     input_bytes=2.0 * MB),
            TaskSpec("combine", 0.03, model_id=None, output_bytes=0.5 * MB),
        ],
        edges=[
            ("detr_objects", "combine"),
            ("glpn_depth", "combine"),
        ],
    )


def paper_dfgs() -> List[DFG]:
    return [translation_dfg(), image_caption_dfg(), vpa_dfg(), perception_dfg()]


# Mixture weights for "a mix of the four workflows" (§6): uniform.
DFG_MIX: List[Tuple[str, float]] = [
    ("translation", 0.25),
    ("image_caption", 0.25),
    ("vpa_dialogue", 0.25),
    ("perception3d", 0.25),
]

from repro.serving.engine import (
    ExecutionEngine,
    HostedModel,
    RequestResult,
    ServingCluster,
)

__all__ = ["ExecutionEngine", "HostedModel", "RequestResult", "ServingCluster"]

"""Serving cluster engine: Navigator-scheduled ML pipelines over real
jitted JAX models.

This is the execution-engine layer of the paper's system (§3): each
*worker* hosts an accelerator-memory model cache (``GpuMemoryManager``)
and an execution queue; the Navigator scheduler (vectorized JAX planner +
Alg. 2 adjustment) places pipeline tasks; the Execution Engine performs
real ``prefill`` + autoregressive ``decode_step`` calls on the zoo models.

On the CPU container every worker shares one physical device, so transfer
and fetch *costs* advance a virtual clock from the profiled cost model
(exactly the simulator's), while the ML compute itself is real —
logits-level real outputs, wall-clock measured.  On a TPU deployment the
same engine binds workers to devices and the virtual costs become real
device transfers.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    ClusterSpec,
    GpuMemoryManager,
    Job,
    NavigatorConfig,
    PrefetchConfig,
    PrefetchPlane,
    ProfileRepository,
    SharedStateTable,
)
from repro.core.healthplane import HealthConfig, HealthMonitor
from repro.core.scheduler import Scheduler, make_scheduler
from repro.core.sst_exchange import GossipConfig, GossipPlane
from repro.core.telemetry import FlightRecorder, TraceConfig
from repro.core.types import DFG, MLModel, TaskSpec
from repro.models import decode_step, forward, init_cache, init_params
from repro.models.config import ModelConfig


@dataclasses.dataclass
class HostedModel:
    """A zoo model registered with the serving cluster."""

    model_id: int
    cfg: ModelConfig
    params: Any

    @property
    def size_bytes(self) -> float:
        return float(
            sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(self.params))
        )


class ExecutionEngine:
    """Per-framework plug-in layer (§3): here, one plug-in — JAX."""

    def __init__(self, models: Dict[int, HostedModel], decode_tokens: int = 8):
        self.models = models
        self.decode_tokens = decode_tokens
        self._steps: Dict[int, Callable] = {}

    def _get_step(self, mid: int):
        if mid not in self._steps:
            cfg = self.models[mid].cfg
            self._steps[mid] = jax.jit(
                lambda p, c, t, cfg=cfg: decode_step(p, c, t, cfg,
                                                     moe_dispatch="scan")
            )
        return self._steps[mid]

    def run_task(self, mid: int, prompt: np.ndarray) -> Tuple[np.ndarray, float]:
        """Prefill ``prompt`` then greedily decode a few tokens.  Returns
        (generated token ids, wall seconds)."""
        hosted = self.models[mid]
        cfg = hosted.cfg
        t0 = time.perf_counter()
        b, s = prompt.shape
        cache = init_cache(cfg, b, capacity=s + self.decode_tokens + 1)
        step = self._get_step(mid)
        toks = jnp.asarray(prompt)
        out = []
        # teacher-forced prefill through the decode path (seeds the cache)
        for i in range(s):
            logits, cache = step(hosted.params, cache, toks[:, i])
        nxt = jnp.argmax(logits, axis=-1)
        for _ in range(self.decode_tokens):
            out.append(nxt)
            logits, cache = step(hosted.params, cache, nxt)
            nxt = jnp.argmax(logits, axis=-1)
        jax.block_until_ready(logits)
        return np.stack([np.asarray(o) for o in out], axis=1), (
            time.perf_counter() - t0
        )


@dataclasses.dataclass
class RequestResult:
    job_id: int
    dfg_name: str
    latency_s: float
    virtual_latency_s: float
    outputs: Dict[str, np.ndarray]
    assignment: Dict[str, int]


class ServingCluster:
    """N Navigator workers serving pipeline requests over hosted models."""

    def __init__(
        self,
        cluster: ClusterSpec,
        hosted: Sequence[HostedModel],
        scheduler: str = "navigator",
        navigator_config: Optional[NavigatorConfig] = None,
        decode_tokens: int = 8,
        gossip: Optional[GossipConfig] = None,
        prefetch: Optional[PrefetchConfig] = None,
        trace: Union[bool, TraceConfig] = False,
        health: Union[bool, HealthConfig] = False,
    ) -> None:
        self.cluster = cluster
        self.hosted = {h.model_id: h for h in hosted}
        self.catalog = {
            mid: MLModel(mid, h.cfg.name, h.size_bytes)
            for mid, h in self.hosted.items()
        }
        self.profiles = ProfileRepository(cluster, self.catalog)
        self.scheduler: Scheduler = make_scheduler(
            scheduler, self.profiles, navigator_config
        )
        # Flight recorder (core/telemetry.py): events land on the virtual
        # clock, so serving traces line up with simulator traces of the
        # same workload; placement provenance comes from the scheduler.
        self.recorder: Optional[FlightRecorder] = None
        if trace:
            self.recorder = FlightRecorder(
                cluster.n_workers,
                trace if isinstance(trace, TraceConfig) else None,
            )
            self.scheduler.recorder = self.recorder
        # Health plane (core/healthplane.py) on the virtual clock: same
        # zero-overhead-when-off ``is not None`` guard as the recorder.
        self.health: Optional[HealthMonitor] = None
        if health:
            self.health = HealthMonitor(
                cluster.n_workers,
                health if isinstance(health, HealthConfig) else None,
                recorder=self.recorder,
            )
        # ``gossip`` swaps the single-snapshot table for the decentralized
        # per-worker view plane: the planner then reads the *origin
        # worker's* replica, which lags peers by up to a gossip period.
        self.gossip = gossip
        if gossip is not None:
            self.sst = GossipPlane(cluster.n_workers, gossip)
        else:
            self.sst = SharedStateTable(cluster.n_workers)
        self.memories = [
            GpuMemoryManager(
                cluster.gpu_capacity(w),
                self.catalog,
                cluster.link,
                compression_ratio=cluster.compression_ratio,
            )
            for w in cluster.workers()
        ]
        self.engine = ExecutionEngine(self.hosted, decode_tokens)
        self._vclock = [0.0] * cluster.n_workers  # per-worker virtual time
        # Predictive prefetch plane (core/prefetch.py) on the virtual
        # clock: planned intents stage models through the per-worker fetch
        # pipe *before* their tasks reach the front of the queue.
        self.prefetch_plane: Optional[PrefetchPlane] = None
        if prefetch is not None:
            self.prefetch_plane = PrefetchPlane(
                cluster.n_workers, prefetch,
                fetch_time_fn=self.profiles.td_model,
            )
        self._pipe_free_at = [0.0] * cluster.n_workers
        # worker -> {model_id: virtual time the speculative transfer lands}
        self._prefetch_ready_at: List[Dict[int, float]] = [
            {} for _ in cluster.workers()
        ]
        self._jobid = 0
        for w in cluster.workers():
            self.sst.update_cache(w, 0, cluster.gpu_capacity(w), 0.0)
            self.sst.push(w, 0.0)
        self.results: List[RequestResult] = []

    # -- pipeline registration --------------------------------------------------
    def register_pipeline(self, dfg: DFG) -> None:
        self.profiles.register(dfg)

    # -- request handling ----------------------------------------------------------
    def submit(
        self, dfg: DFG, inputs: Dict[str, np.ndarray], origin: int = 0
    ) -> RequestResult:
        """Schedule + execute one pipeline request synchronously.

        ``inputs`` maps entry-task ids → prompt token arrays (B, S)."""
        now = max(self._vclock)
        job = Job(self._jobid, dfg, arrival_time=now)
        self._jobid += 1
        if self.gossip is not None:
            # Run the gossip rounds due up to the request's arrival; the
            # origin worker then plans from its own (possibly stale) view.
            self.sst.advance(now)
        adfg = self.scheduler.plan(job, now, origin, self.sst.view(origin))
        if adfg is None:
            raise NotImplementedError("serving engine drives planned schedulers")
        if self.prefetch_plane is not None:
            self._issue_prefetches(job, adfg, now)
        rec = self.recorder
        if rec is not None:
            # Cluster-scope lifecycle events ride the GLOBAL ring, same
            # as the simulator (parity-tested: identical taxonomy).
            rec.emit(now, "job.arrive", job=job.job_id,
                     dfg=dfg.name, origin=origin, n_tasks=len(dfg.tasks))

        wall0 = time.perf_counter()
        outputs: Dict[str, np.ndarray] = {}
        finish: Dict[str, float] = {}
        for ti, tid in enumerate(dfg.topo_order):
            task = dfg.tasks[tid]
            w = adfg[tid]
            mem = self.memories[w]
            start = max(
                self._vclock[w],
                max((finish[p] for p in dfg.preds[tid]), default=now),
            )
            # transfer delay for remote inputs
            for p in dfg.preds[tid]:
                if adfg[p] != w:
                    dur = self.cluster.network.transfer_time(
                        dfg.tasks[p].output_bytes
                    )
                    start += dur
                    if rec is not None:
                        rec.emit(finish[p], "net.xfer", worker=adfg[p],
                                 dst=w, bytes=dfg.tasks[p].output_bytes,
                                 dur=dur, scope="flat", share=1.0)
                    if self.health is not None:
                        self.health.on_transfer(
                            finish[p], "flat", dfg.tasks[p].output_bytes,
                            1.0, cross=False,
                        )
            if rec is not None:
                if not dfg.preds[tid]:
                    rec.emit(now, "task.input", worker=w, job=job.job_id,
                             task=tid, gen=0, src="", frm=origin, to=w,
                             arrive=now)
                else:
                    for p in dfg.preds[tid]:
                        arrive = finish[p] if adfg[p] == w else start
                        rec.emit(arrive, "task.input", worker=w,
                                 job=job.job_id, task=tid, gen=0, src=p,
                                 frm=adfg[p], to=w, arrive=arrive)
            was_miss = False
            if task.model_id is not None:
                upcoming = [task.model_id]
                res = mem.ensure(task.model_id, upcoming)
                ready = (
                    self._prefetch_ready_at[w].pop(task.model_id, None)
                    if self.prefetch_plane is not None
                    else None
                )
                if res is not None:
                    fetch_s, _ = res
                    was_miss = fetch_s > 0.0
                    if rec is not None and fetch_s > 0.0:
                        rec.emit(start, "fetch.start", worker=w,
                                 fetch_kind="demand", model=task.model_id,
                                 bytes=mem.cached_size(task.model_id),
                                 dur=fetch_s, job=job.job_id, task=tid)
                        rec.emit(start + fetch_s, "fetch.done", worker=w,
                                 model=task.model_id, spec=False)
                    if self.health is not None and fetch_s > 0.0:
                        self.health.fetch_state(w, start, True)
                        self.health.fetch_state(w, start + fetch_s, False)
                    if fetch_s > 0.0 and self.prefetch_plane is not None:
                        # Demand miss: demand preempts speculation on the
                        # single fetch pipe — the transfer starts now, and
                        # every speculative transfer still in flight is
                        # pushed back behind it.
                        t0 = start
                        start += fetch_s
                        self._pipe_free_at[w] = max(
                            self._pipe_free_at[w] + fetch_s, start
                        )
                        for m, t in self._prefetch_ready_at[w].items():
                            if t > t0:
                                self._prefetch_ready_at[w][m] = t + fetch_s
                    elif fetch_s > 0.0:
                        start += fetch_s
                    elif ready is not None:
                        # Cache hit thanks to a speculative transfer that
                        # may still be in flight on the virtual clock.
                        start = max(start, ready)
                        if rec is not None:
                            rec.emit(start, "fetch.promote", worker=w,
                                     model=task.model_id, job=job.job_id,
                                     task=tid)
                self.sst.update_cache(w, mem.bitmap, mem.free_bytes, start)
                if self.health is not None:
                    self.health.sample_memory(
                        w, start,
                        (mem.used_bytes + mem.exec_reserved_bytes)
                        / mem.capacity_bytes
                        if mem.capacity_bytes > 0 else 0.0,
                        mem.stats.evictions,
                    )
                if self.prefetch_plane is not None:
                    self.sst.update_intent(
                        w,
                        mem.bitmap | self.prefetch_plane.advertised_bits(w),
                        start,
                    )
                prompt = self._task_input(tid, dfg, inputs, outputs)
                out, wall = self.engine.run_task(task.model_id, prompt)
                outputs[tid] = out
                runtime = wall
            else:
                # host-side aggregation vertex
                preds = dfg.preds[tid]
                outputs[tid] = np.concatenate(
                    [outputs[p] for p in preds], axis=-1
                ) if preds else np.zeros((1, 0), np.int32)
                runtime = 1e-4
            finish[tid] = start + runtime
            if rec is not None:
                rec.emit(start, "task.start", worker=w, job=job.job_id,
                         task=tid, gen=0,
                         model=-1 if task.model_id is None else task.model_id,
                         miss=was_miss)
                rec.emit(finish[tid], "task.done", worker=w, job=job.job_id,
                         task=tid, gen=0)
            self._vclock[w] = finish[tid]
            self.sst.update_load(w, self._vclock[w], finish[tid])
            if self.health is not None:
                # Virtual-queue depth: this job's tasks still bound to w
                # (including the one just finished draining to 0 marks
                # the backlog the next probe would see).
                depth = sum(
                    1 for t2 in dfg.topo_order[ti + 1:] if adfg[t2] == w
                )
                self.health.sample_queue(w, finish[tid], depth)
                self.health.task_done(
                    w, finish[tid], runtime,
                    self.profiles.runtime(task, w),
                )
                # Digest refresh rides the publication, same as the sim.
                d = self.health.digest(w, finish[tid])
                self.sst.update_health(
                    w, d.queue_depth, d.mem_occupancy, d.fetch_util,
                    d.p99_latency_s, finish[tid],
                )
            if self.gossip is not None:
                self.sst.advance(finish[tid])
            else:
                self.sst.push(w, finish[tid])
        t_end = max(finish.values())
        if rec is not None:
            rec.emit(t_end, "job.done", job=job.job_id,
                     latency=t_end - now)
        if self.health is not None:
            self.health.job_done(t_end, t_end - now)
        result = RequestResult(
            job_id=job.job_id,
            dfg_name=dfg.name,
            latency_s=time.perf_counter() - wall0,
            virtual_latency_s=max(finish.values()) - now,
            outputs=outputs,
            assignment=dict(adfg.assignment),
        )
        self.results.append(result)
        return result

    def _issue_prefetches(self, job: Job, adfg, now: float) -> None:
        """Virtual-clock analogue of the simulator's speculative fetch
        path: every intended model is staged through the worker's fetch
        pipe at plan time, so by the time its task reaches the front of
        the queue the transfer has (partially) overlapped queue wait."""
        plane = self.prefetch_plane
        assert plane is not None
        per = plane.plan_intents(job, adfg, self.profiles, now)
        for w, intents in per.items():
            plane.admit(w, intents, now)
            mem = self.memories[w]
            t_pipe = max(now, self._pipe_free_at[w])
            while True:
                intent, _ = plane.next_intent(w, now, mem.has, 0)
                if intent is None:
                    break
                res = mem.begin_prefetch(
                    intent.model_id,
                    allow_evict=plane.config.evict_for_prefetch,
                )
                if res is None:
                    # No room: fall back to demand fetching at task start.
                    plane.stall_inflight(w, now)
                    break
                fetch_s, _ = res
                if self.recorder is not None:
                    # Same key set as the simulator's speculative
                    # fetch.start (no job/task: nothing demanded it yet).
                    self.recorder.emit(
                        t_pipe, "fetch.start", worker=w,
                        fetch_kind="prefetch", model=intent.model_id,
                        bytes=mem.cached_size(intent.model_id),
                        dur=fetch_s,
                    )
                if self.health is not None:
                    self.health.fetch_state(w, t_pipe, True)
                t_pipe += fetch_s
                if self.recorder is not None:
                    self.recorder.emit(t_pipe, "fetch.done", worker=w,
                                       model=intent.model_id, spec=True)
                if self.health is not None:
                    self.health.fetch_state(w, t_pipe, False)
                mem.complete_prefetch(intent.model_id)
                plane.complete_inflight(w)
                self._prefetch_ready_at[w][intent.model_id] = t_pipe
            self._pipe_free_at[w] = t_pipe
            self.sst.update_cache(w, mem.bitmap, mem.available_bytes, now)
            self.sst.update_intent(
                w, mem.bitmap | plane.advertised_bits(w), now
            )

    def _task_input(self, tid, dfg, inputs, outputs) -> np.ndarray:
        if not dfg.preds[tid]:
            return inputs[tid]
        parts = [outputs[p] for p in dfg.preds[tid]]
        return np.concatenate(parts, axis=1)

    # -- metrics ---------------------------------------------------------------------
    def cache_hit_rate(self) -> float:
        hits = sum(m.stats.hits for m in self.memories)
        total = hits + sum(m.stats.misses for m in self.memories)
        return hits / total if total else 1.0

    def workers_used(self) -> List[int]:
        return [
            w
            for w in self.cluster.workers()
            if self.memories[w].stats.hits + self.memories[w].stats.misses > 0
        ]

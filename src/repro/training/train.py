"""Distributed train-step factory (pjit / GSPMD).

``make_train_step`` builds a jitted (params, opt_state, batch) → (params,
opt_state, metrics) function with explicit in/out shardings from
``models.sharding`` and donated carry buffers.  The same factory serves
the real trainer (examples/train_small.py) and the multi-pod dry-run
(lowered with ShapeDtypeStructs, never allocated).
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import next_token_loss
from repro.models.config import ModelConfig
from repro.models.sharding import (
    batch_pspecs,
    cache_pspecs,
    data_axes,
    param_pspecs,
    to_named,
)
from repro.training import optimizer as opt

Pytree = Any


def make_train_step(
    cfg: ModelConfig,
    mesh: Mesh,
    opt_cfg: Optional[opt.AdamWConfig] = None,
    *,
    impl: str = "ref",
    moe_dispatch: str = "sorted",
    remat: bool = True,
    donate: bool = True,
    accum_steps: int = 1,
    unroll: bool = False,
):
    """Returns (step_fn, jit_step factory).  ``accum_steps`` splits the
    global batch into microbatches accumulated with lax.scan — the lever
    that makes 100B+-param training fit a pod (activation memory scales
    with the microbatch, not the global batch)."""
    opt_cfg = opt_cfg or opt.AdamWConfig()

    loss_fn = functools.partial(
        next_token_loss, cfg=cfg, impl=impl, moe_dispatch=moe_dispatch,
        unroll=unroll, mesh=mesh if moe_dispatch == "ep" else None,
    )
    if remat:
        loss_fn = jax.checkpoint(
            loss_fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )

    def grads_of(params, batch):
        return jax.value_and_grad(loss_fn)(params, batch)

    def step(params, opt_state, batch):
        if accum_steps == 1:
            loss, grads = grads_of(params, batch)
        else:
            micro = jax.tree.map(
                lambda x: x.reshape(
                    (accum_steps, x.shape[0] // accum_steps) + x.shape[1:]
                ),
                batch,
            )

            def body(carry, mb):
                acc, loss_acc = carry
                loss, g = grads_of(params, mb)
                acc = jax.tree.map(jnp.add, acc, g)
                return (acc, loss_acc + loss), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            (grads, loss), _ = jax.lax.scan(
                body, (zeros, jnp.zeros((), jnp.float32)), micro
            )
            grads = jax.tree.map(lambda g: g / accum_steps, grads)
            loss = loss / accum_steps
        new_params, new_state, metrics = opt.apply(
            opt_cfg, grads, opt_state, params
        )
        metrics["loss"] = loss
        return new_params, new_state, metrics

    def shardings_for(params_tree, opt_tree, batch_tree):
        p_spec = param_pspecs(mesh, params_tree, cfg)
        o_spec = opt.AdamWState(
            step=P(),
            m=p_spec,
            v=p_spec,
        )
        b_spec = batch_pspecs(mesh, batch_tree)
        return p_spec, o_spec, b_spec

    def jit_step(params_tree, opt_tree, batch_tree):
        p_spec, o_spec, b_spec = shardings_for(params_tree, opt_tree, batch_tree)
        metric_spec = {"grad_norm": P(), "lr": P(), "loss": P()}
        return jax.jit(
            step,
            in_shardings=(
                to_named(mesh, p_spec),
                to_named(mesh, o_spec),
                to_named(mesh, b_spec),
            ),
            out_shardings=(
                to_named(mesh, p_spec),
                to_named(mesh, o_spec),
                to_named(mesh, metric_spec),
            ),
            donate_argnums=(0, 1) if donate else (),
        )

    return step, jit_step


def make_serve_step(
    cfg: ModelConfig,
    mesh: Mesh,
    *,
    impl: str = "ref",
    moe_dispatch: str = "sorted",
    donate: bool = True,
    unroll: bool = False,
    cache_update: str = "scatter",
    serve_layout: bool = False,
):
    """One-token decode step factory: (params, cache, tokens) →
    (logits, cache), sharded for the mesh.  ``serve_layout`` selects the
    weight-stationary param sharding (§Perf P2)."""
    from repro.models import decode_step

    def step(params, cache, tokens):
        return decode_step(
            params, cache, tokens, cfg, impl=impl,
            moe_dispatch=moe_dispatch, unroll=unroll,
            mesh=mesh if moe_dispatch == "ep" else None,
            cache_update=cache_update,
        )

    def jit_step(params_tree, cache_tree, tokens_tree):
        p_spec = param_pspecs(mesh, params_tree, cfg, serve=serve_layout)
        c_spec = cache_pspecs(mesh, cache_tree)
        dp = data_axes(mesh)
        b = tokens_tree.shape[0]
        t_spec = P(dp if b % _dp_size(mesh) == 0 else None)
        logits_spec = P(
            dp if b % _dp_size(mesh) == 0 else None, None
        )
        return jax.jit(
            step,
            in_shardings=(
                to_named(mesh, p_spec),
                to_named(mesh, c_spec),
                NamedSharding(mesh, t_spec),
            ),
            out_shardings=(
                NamedSharding(mesh, logits_spec),
                to_named(mesh, c_spec),
            ),
            donate_argnums=(1,) if donate else (),
        )

    return step, jit_step


def make_prefill_step(
    cfg: ModelConfig,
    mesh: Mesh,
    *,
    impl: str = "ref",
    moe_dispatch: str = "sorted",
    unroll: bool = False,
):
    """Full-sequence forward (inference prefill): (params, batch) → logits."""
    from repro.models import forward

    def step(params, batch):
        logits, _ = forward(
            params, batch, cfg, impl=impl, moe_dispatch=moe_dispatch,
            unroll=unroll, mesh=mesh if moe_dispatch == "ep" else None,
        )
        return logits

    def jit_step(params_tree, batch_tree):
        p_spec = param_pspecs(mesh, params_tree, cfg)
        b_spec = batch_pspecs(mesh, batch_tree)
        dp = data_axes(mesh)
        b = batch_tree["tokens"].shape[0]
        out_spec = P(dp if b % _dp_size(mesh) == 0 else None, None, None)
        return jax.jit(
            step,
            in_shardings=(to_named(mesh, p_spec), to_named(mesh, b_spec)),
            out_shardings=NamedSharding(mesh, out_spec),
        )

    return step, jit_step


def _dp_size(mesh: Mesh) -> int:
    import numpy as np

    return int(np.prod([mesh.shape[a] for a in data_axes(mesh)]))

"""Pure-JAX AdamW with global-norm clipping and LR schedules.

No external optimizer dependency: the state is a pytree {m, v, step}
mirroring the params, fully compatible with pjit sharding (m/v inherit the
parameter shardings; the roofline accounts 3× param bytes for training
state in bf16 params + f32 moments)."""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

Pytree = Any


class AdamWState(NamedTuple):
    step: jax.Array
    m: Pytree
    v: Pytree


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    schedule: str = "cosine"  # cosine | linear | constant


def lr_at(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, (step + 1.0) / max(1, cfg.warmup_steps))
    if cfg.schedule == "constant":
        decay = 1.0
    else:
        frac = jnp.clip(
            (step - cfg.warmup_steps)
            / max(1, cfg.total_steps - cfg.warmup_steps),
            0.0,
            1.0,
        )
        if cfg.schedule == "cosine":
            decay = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
        else:  # linear
            decay = 1.0 - frac
        decay = cfg.min_lr_ratio + (1.0 - cfg.min_lr_ratio) * decay
    return cfg.lr * warm * decay


def init(params: Pytree, moment_dtype=jnp.float32) -> AdamWState:
    """``moment_dtype=jnp.bfloat16`` halves optimizer HBM for the large
    archs (llama3-405b-class training state does not fit one pod in f32)."""
    zeros = lambda p: jnp.zeros(p.shape, moment_dtype)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
    )


def global_norm(tree: Pytree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves)
    )


def apply(
    cfg: AdamWConfig,
    grads: Pytree,
    state: AdamWState,
    params: Pytree,
) -> Tuple[Pytree, AdamWState, Dict[str, jax.Array]]:
    gnorm = global_norm(grads)
    if cfg.clip_norm is not None:
        scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)
    step = state.step + 1
    lr = lr_at(cfg, state.step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g32 = g.astype(jnp.float32)
        m_new = (cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g32).astype(m.dtype)
        v_new = (cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g32 * g32).astype(v.dtype)
        mhat = m_new.astype(jnp.float32) / b1c
        vhat = v_new.astype(jnp.float32) / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m_new, v_new

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    new = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([n[0] for n in new])
    new_m = treedef.unflatten([n[1] for n in new])
    new_v = treedef.unflatten([n[2] for n in new])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, AdamWState(step=step, m=new_m, v=new_v), metrics

"""Minimal dependency-free checkpointing: pytree → .npz + JSON manifest.

Leaves are addressed by their tree path; restore validates structure and
shapes.  (The offline container has no orbax; this implements the same
contract at laptop scale and round-trips optimizer state + params + step.)
"""

from __future__ import annotations

import json
import os
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

Pytree = Any


def _flatten_with_paths(tree: Pytree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[key] = np.asarray(leaf)
    return out


def save(path: str, tree: Pytree, metadata: Optional[Dict] = None) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    leaves = _flatten_with_paths(tree)
    np.savez(path + ".npz", **leaves)
    manifest = {
        "leaves": {
            k: {"shape": list(v.shape), "dtype": str(v.dtype)}
            for k, v in leaves.items()
        },
        "metadata": metadata or {},
    }
    with open(path + ".json", "w") as f:
        json.dump(manifest, f, indent=1)


def restore(path: str, template: Pytree) -> Tuple[Pytree, Dict]:
    """Restore into the structure of ``template`` (shapes validated)."""
    with open(path + ".json") as f:
        manifest = json.load(f)
    data = np.load(path + ".npz")
    expected = _flatten_with_paths(template)
    for key, leaf in expected.items():
        if key not in data:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        if tuple(data[key].shape) != leaf.shape:
            raise ValueError(
                f"shape mismatch for {key!r}: checkpoint "
                f"{data[key].shape} vs template {leaf.shape}"
            )
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    restored = []
    for path_keys, leaf in flat:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path_keys
        )
        restored.append(data[key].astype(np.asarray(leaf).dtype))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(template), restored
    ), manifest["metadata"]

from repro.training import checkpoint, optimizer
from repro.training.train import (
    make_prefill_step,
    make_serve_step,
    make_train_step,
)

__all__ = [
    "checkpoint",
    "make_prefill_step",
    "make_serve_step",
    "make_train_step",
    "optimizer",
]

"""Versioned binary trace files for fleet-scale open-loop replay.

``bench_scalability.py`` needs 10–100× longer workloads than the
in-memory generators were built for: a 1M-task trace as a python list of
``Job`` objects regenerated per sweep point is both slow (the generator
re-runs per fleet size) and unshareable (two sweep points must replay
the *same* arrivals for per-event costs to be comparable).  A trace file
fixes both: synthesize once, replay everywhere.

Format ``CTRC`` version 1 (little-endian throughout; see
``schemas/tracefile.md`` for the byte-level layout):

    magic    4 bytes   b"CTRC"
    version  u16       1
    n_dfgs   u32       DFG name-table size
    n_jobs   u64       record count
    names    n_dfgs ×  (u16 length + utf-8 bytes) — index i names dfg i
    records  n_jobs ×  (f64 arrival_time_s + u32 dfg_index), 12 bytes each

The file stores arrivals only; DFG *structures* are resolved by name at
load time against the caller's catalogue, so a trace is valid across
profile changes and the file stays 12 bytes/job — a 1M-job trace is
~12 MB and loads in O(n_jobs) with zero per-record python parsing
(one ``numpy.frombuffer`` over the record block).
"""

from __future__ import annotations

import random
import struct
from typing import Dict, Iterable, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.core.types import DFG, Job

MAGIC = b"CTRC"
VERSION = 1
_HEADER = struct.Struct("<4sHIQ")   # magic, version, n_dfgs, n_jobs
_NAME_LEN = struct.Struct("<H")
_RECORD_DTYPE = np.dtype([("arrival", "<f8"), ("dfg", "<u4")])


class TraceFormatError(ValueError):
    """Raised for bad magic, unsupported version, or a truncated file."""


def write_trace(
    path: str,
    dfg_names: Sequence[str],
    records: Iterable[Tuple[float, int]],
) -> int:
    """Write ``(arrival_time_s, dfg_index)`` records; returns the count.

    ``records`` may be any iterable (generators stream fine — the job
    count is patched into the header after the body is written, so
    nothing is ever buffered)."""
    with open(path, "wb") as f:
        f.write(_HEADER.pack(MAGIC, VERSION, len(dfg_names), 0))
        for name in dfg_names:
            raw = name.encode("utf-8")
            f.write(_NAME_LEN.pack(len(raw)))
            f.write(raw)
        n = 0
        buf = bytearray()
        for arrival, dfg_idx in records:
            if not 0 <= dfg_idx < len(dfg_names):
                raise ValueError(f"dfg index {dfg_idx} out of range")
            buf += struct.pack("<dI", arrival, dfg_idx)
            n += 1
            if len(buf) >= 1 << 20:
                f.write(buf)
                buf.clear()
        f.write(buf)
        f.seek(0)
        f.write(_HEADER.pack(MAGIC, VERSION, len(dfg_names), n))
    return n


def read_header(path: str) -> Tuple[int, List[str], int]:
    """(version, dfg names, n_jobs) without touching the record block."""
    with open(path, "rb") as f:
        raw = f.read(_HEADER.size)
        if len(raw) < _HEADER.size:
            raise TraceFormatError("truncated trace header")
        magic, version, n_dfgs, n_jobs = _HEADER.unpack(raw)
        if magic != MAGIC:
            raise TraceFormatError(f"bad magic {magic!r}")
        if version != VERSION:
            raise TraceFormatError(f"unsupported trace version {version}")
        names = []
        for _ in range(n_dfgs):
            (ln,) = _NAME_LEN.unpack(f.read(_NAME_LEN.size))
            names.append(f.read(ln).decode("utf-8"))
        return version, names, n_jobs


def load_jobs(
    path: str,
    catalogue: Mapping[str, DFG],
    limit: Optional[int] = None,
) -> List[Job]:
    """Materialize the trace as engine-ready jobs (ids are the record
    positions, so truncated replays of the same file share a prefix).
    Every trace DFG name must resolve in ``catalogue``."""
    version, names, n_jobs = read_header(path)
    missing = [nm for nm in names if nm not in catalogue]
    if missing:
        raise TraceFormatError(f"trace needs unknown DFGs {missing}")
    dfgs = [catalogue[nm] for nm in names]
    with open(path, "rb") as f:
        f.seek(_HEADER.size + sum(_NAME_LEN.size + len(nm.encode("utf-8"))
                                  for nm in names))
        body = f.read()
    if limit is not None:
        n_jobs = min(n_jobs, limit)
    if len(body) < n_jobs * _RECORD_DTYPE.itemsize:
        raise TraceFormatError("truncated trace body")
    rec = np.frombuffer(body, dtype=_RECORD_DTYPE, count=n_jobs)
    arrivals = rec["arrival"].tolist()
    dfg_idx = rec["dfg"].tolist()
    return [
        Job(job_id=i, dfg=dfgs[d], arrival_time=t)
        for i, (t, d) in enumerate(zip(arrivals, dfg_idx))
    ]


def trace_task_count(path: str, catalogue: Mapping[str, DFG]) -> int:
    """Total task count of a trace (Σ per-job DFG sizes) — the unit the
    scalability sweeps report per-event costs against."""
    _, names, n_jobs = read_header(path)
    sizes = {i: len(catalogue[nm].tasks) for i, nm in enumerate(names)}
    with open(path, "rb") as f:
        f.seek(_HEADER.size + sum(_NAME_LEN.size + len(nm.encode("utf-8"))
                                  for nm in names))
        rec = np.frombuffer(f.read(), dtype=_RECORD_DTYPE, count=n_jobs)
    counts = np.bincount(rec["dfg"], minlength=len(names))
    return int(sum(sizes[i] * int(c) for i, c in enumerate(counts)))


def synthesize_poisson_trace(
    path: str,
    dfgs: Sequence[DFG],
    rate_per_s: float,
    n_tasks: int,
    seed: int = 0,
    weights: Optional[Sequence[float]] = None,
) -> int:
    """Stream a Poisson open-loop trace to ``path`` until the cumulative
    task count reaches ``n_tasks``; returns the job count.  Mirrors
    ``workload.poisson_workload`` (same rng discipline: ``expovariate``
    inter-arrivals, mixture pick per job) but never holds the workload in
    memory — a 1M-task trace streams through a 1 MiB buffer."""
    if rate_per_s <= 0:
        raise ValueError("rate must be positive")
    rng = random.Random(seed)
    if weights is None:
        weights = [1.0] * len(dfgs)
    total_w = sum(weights)
    cum = []
    acc = 0.0
    for w in weights:
        acc += w / total_w
        cum.append(acc)
    sizes = [len(d.tasks) for d in dfgs]

    def records():
        t = 0.0
        tasks = 0
        while tasks < n_tasks:
            t += rng.expovariate(rate_per_s)
            u = rng.random()
            idx = len(dfgs) - 1
            for i, c in enumerate(cum):
                if u <= c:
                    idx = i
                    break
            tasks += sizes[idx]
            yield t, idx

    return write_trace(path, [d.name for d in dfgs], records())


__all__ = [
    "MAGIC",
    "VERSION",
    "TraceFormatError",
    "load_jobs",
    "read_header",
    "synthesize_poisson_trace",
    "trace_task_count",
    "write_trace",
]

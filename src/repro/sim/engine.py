"""Event-driven cluster simulator (§5.4).

Models the environment of §3 at the fidelity the paper describes: task
arrival, queue waiting, model fetch (PCIe), task execution (one active GPU
task per worker), task dispatch with network transfer of intermediate
objects, and rate-limited SST dissemination.  Events are processed in
simulated-time order (heap).  The paper validated its simulator within 5 %
of the real 5-worker testbed; ours follows the same structure (Sparrow
style) and uses the same profiled constants.

Execution semantics (faithful to §3.2):
* A worker's Task Dispatcher scans its execution queue in order and starts
  the first task whose inputs are present and whose model is resident; a
  task whose model is being fetched (or whose inputs are missing) is left
  on the queue and the dispatcher proceeds to the next.
* One model fetch (PCIe transfer) is in flight per worker at a time; it
  overlaps with GPU execution of other tasks.
* On task completion the scheduler's dynamic-adjustment hook runs for each
  successor (Alg. 2), then outputs are shipped to the successors' workers.
"""

from __future__ import annotations

import dataclasses
import heapq
import itertools
import math
import random
from typing import Dict, List, Mapping, Optional, Sequence, Set, Tuple

from repro.core.memory import GpuMemoryManager
from repro.core.netmodel import ClusterSpec
from repro.core.prefetch import (
    INTENT_WIRE_BYTES,
    PrefetchConfig,
    PrefetchPlane,
    PrefetchStats,
)
from repro.core.profiles import ProfileRepository
from repro.core.scheduler import (
    NavigatorConfig,
    Scheduler,
    make_scheduler,
)
from repro.core.sst_exchange import GossipConfig, GossipPlane
from repro.core.state import SharedStateTable
from repro.core.types import ADFG, Job, MLModel


# --------------------------------------------------------------------------
# Per-job bookkeeping
# --------------------------------------------------------------------------
@dataclasses.dataclass
class _TaskRun:
    enqueued: bool = False
    fetching: bool = False
    was_miss: bool = False
    # Assigned to a GPU that can never host the model (capacity-blind
    # scheduler on a heterogeneous fleet); being re-routed by the
    # dispatcher.
    bouncing: bool = False
    started: Optional[float] = None
    finished: Optional[float] = None
    worker: Optional[int] = None


class _JobState:
    def __init__(self, job: Job, origin: int) -> None:
        self.job = job
        self.origin = origin
        self.adfg: Optional[ADFG] = None
        self.tasks: Dict[str, _TaskRun] = {
            t: _TaskRun() for t in job.dfg.tasks
        }
        # task -> set of predecessors whose outputs have arrived at the
        # task's assigned worker (external input counts via the sentinel "").
        self.inputs_arrived: Dict[str, Set[str]] = {t: set() for t in job.dfg.tasks}
        self.finish_time: Optional[float] = None

    def inputs_ready(self, task_id: str) -> bool:
        dfg = self.job.dfg
        need = set(dfg.preds[task_id]) or {""}
        return need <= self.inputs_arrived[task_id]

    def done(self) -> bool:
        return all(r.finished is not None for r in self.tasks.values())


@dataclasses.dataclass
class JobRecord:
    job_id: int
    dfg_name: str
    arrival: float
    finish: float
    lower_bound: float

    @property
    def latency(self) -> float:
        return self.finish - self.arrival

    @property
    def slowdown(self) -> float:
        return self.latency / self.lower_bound if self.lower_bound > 0 else 1.0


@dataclasses.dataclass
class SimResult:
    scheduler: str
    records: List[JobRecord]
    horizon: float
    n_workers: int
    busy_time: Dict[int, float]
    cache_hits: int
    cache_misses: int
    cache_evictions: int
    bytes_fetched: float
    sst_pushes: int
    workers_used: Set[int]
    adjustments: int = 0
    # Predictive prefetch plane (core/prefetch.py); zeros when disabled.
    prefetch_bytes: float = 0.0
    prefetch_wasted_bytes: float = 0.0
    prefetch_unused_resident_bytes: float = 0.0
    prefetch_useful: int = 0
    prefetch_stats: Optional[PrefetchStats] = None

    # -- aggregates ------------------------------------------------------------
    @property
    def mean_latency(self) -> float:
        return sum(r.latency for r in self.records) / max(1, len(self.records))

    @property
    def mean_slowdown(self) -> float:
        return sum(r.slowdown for r in self.records) / max(1, len(self.records))

    @property
    def median_slowdown(self) -> float:
        xs = sorted(r.slowdown for r in self.records)
        if not xs:
            return 0.0
        n = len(xs)
        return xs[n // 2] if n % 2 else 0.5 * (xs[n // 2 - 1] + xs[n // 2])

    def slowdowns_by_type(self) -> Dict[str, List[float]]:
        out: Dict[str, List[float]] = {}
        for r in self.records:
            out.setdefault(r.dfg_name, []).append(r.slowdown)
        return out

    @property
    def cache_hit_rate(self) -> float:
        tot = self.cache_hits + self.cache_misses
        return self.cache_hits / tot if tot else 1.0

    @property
    def gpu_utilization(self) -> float:
        if self.horizon <= 0:
            return 0.0
        return sum(self.busy_time.values()) / (self.horizon * self.n_workers)

    def energy_joules(self, cluster: ClusterSpec) -> float:
        busy = sum(self.busy_time.values())
        idle = self.horizon * self.n_workers - busy
        return busy * cluster.gpu_power_active_w + idle * cluster.gpu_power_idle_w

    def percentile_latency(self, q: float) -> float:
        xs = sorted(r.latency for r in self.records)
        if not xs:
            return 0.0
        idx = min(len(xs) - 1, max(0, int(math.ceil(q * len(xs))) - 1))
        return xs[idx]


# --------------------------------------------------------------------------
# Simulator
# --------------------------------------------------------------------------
class Simulation:
    def __init__(
        self,
        cluster: ClusterSpec,
        profiles: ProfileRepository,
        models: Mapping[int, MLModel],
        scheduler: str = "navigator",
        navigator_config: Optional[NavigatorConfig] = None,
        eviction_policy: str = GpuMemoryManager.LOOKAHEAD,
        push_interval_s: float = 0.2,
        cache_push_interval_s: Optional[float] = None,
        gossip: Optional[GossipConfig] = None,
        prefetch: Optional[PrefetchConfig] = None,
        runtime_noise_sigma: float = 0.25,
        seed: int = 0,
    ) -> None:
        self.cluster = cluster
        self.profiles = profiles
        self.models = dict(models)
        self.scheduler: Scheduler = make_scheduler(
            scheduler, profiles, navigator_config
        )
        # Metadata plane: ``gossip`` selects the decentralized per-worker
        # view subsystem (each worker plans from its own, possibly stale,
        # replica); default is the single-published-snapshot table.
        self.gossip = gossip
        if gossip is not None:
            self.sst = GossipPlane(cluster.n_workers, gossip, seed=seed)
        else:
            self.sst = SharedStateTable(
                cluster.n_workers, push_interval_s, cache_push_interval_s
            )
        self.memories = [
            GpuMemoryManager(
                cluster.gpu_capacity(w),
                self.models,
                cluster.link,
                policy=eviction_policy,
                compression_ratio=cluster.compression_ratio,
            )
            for w in cluster.workers()
        ]
        self.rng = random.Random(seed)
        self.noise_sigma = runtime_noise_sigma

        self._heap: List[Tuple[float, int, Tuple]] = []
        self._seq = itertools.count()
        self._now = 0.0
        self._queues: List[List[Tuple[_JobState, str]]] = [
            [] for _ in cluster.workers()
        ]
        self._gpu_busy: List[Optional[Tuple[_JobState, str]]] = [
            None for _ in cluster.workers()
        ]
        # Fetch-pipe state (one PCIe transfer in flight per worker, §3.2).
        # ``_fetch_model`` is the model on the pipe; ``_fetch_preemptible``
        # marks a speculative prefetch a demand fetch may abort; the token
        # invalidates posted completion events after a preemption.
        self._fetch_busy: List[bool] = [False for _ in cluster.workers()]
        self._fetch_model: List[Optional[int]] = [
            None for _ in cluster.workers()
        ]
        self._fetch_spec: List[bool] = [False for _ in cluster.workers()]
        self._fetch_preemptible: List[bool] = [
            False for _ in cluster.workers()
        ]
        self._fetch_started: List[float] = [0.0 for _ in cluster.workers()]
        self._fetch_ends: List[float] = [0.0 for _ in cluster.workers()]
        self._fetch_token: List[int] = [0 for _ in cluster.workers()]
        # Predictive prefetch plane (plan-driven speculative fetches).
        self.prefetch_plane: Optional[PrefetchPlane] = None
        if prefetch is not None:
            self.prefetch_plane = PrefetchPlane(
                cluster.n_workers, prefetch, fetch_time_fn=profiles.td_model
            )
        self._poke_at: List[Optional[float]] = [
            None for _ in cluster.workers()
        ]
        self._busy_time: Dict[int, float] = {w: 0.0 for w in cluster.workers()}
        self._records: List[JobRecord] = []
        self._jobs_open = 0
        self._workers_used: Set[int] = set()
        self._adjustments = 0
        for w in cluster.workers():
            self.sst.update_cache(w, 0, cluster.gpu_capacity(w), 0.0)
            self.sst.push(w, 0.0)

    # -- event plumbing ----------------------------------------------------------
    def _post(self, t: float, kind: str, *payload) -> None:
        heapq.heappush(self._heap, (t, next(self._seq), (kind, *payload)))

    def _noisy(self, runtime: float) -> float:
        if self.noise_sigma <= 0:
            return runtime
        return runtime * self.rng.lognormvariate(0.0, self.noise_sigma)

    # -- public API ----------------------------------------------------------------
    def run(self, jobs: Sequence[Job]) -> SimResult:
        origin = itertools.cycle(self.cluster.workers())
        for job in sorted(jobs, key=lambda j: j.arrival_time):
            self._post(job.arrival_time, "arrival", job, next(origin))
        # SST dissemination schedule (staggered per worker).
        if self.gossip is not None:
            for w in self.cluster.workers():
                offset = (w + 1) * self.gossip.period_s / max(
                    1, self.cluster.n_workers
                )
                self._post(offset, "gossip", w)
        else:
            for w in self.cluster.workers():
                offset = (w + 1) * self.sst.push_interval_s / max(
                    1, self.cluster.n_workers
                )
                self._post(offset, "sst_load", w)
                offset_c = (w + 1) * self.sst.cache_push_interval_s / max(
                    1, self.cluster.n_workers
                )
                self._post(offset_c, "sst_cache", w)
        self._jobs_open = len(jobs)

        while self._heap and self._jobs_open > 0:
            t, _, ev = heapq.heappop(self._heap)
            self._now = t
            kind = ev[0]
            if kind == "arrival":
                self._on_arrival(ev[1], ev[2])
            elif kind == "enqueue":
                self._on_enqueue(ev[1], ev[2], ev[3])
            elif kind == "input":
                self._on_input(ev[1], ev[2], ev[3], ev[4])
            elif kind == "fetch_done":
                self._on_fetch_done(ev[1], ev[2])
            elif kind == "task_done":
                self._on_task_done(ev[1], ev[2], ev[3])
            elif kind == "task_fetch_bookkeep":
                self._on_fetch_bookkeep(ev[1], ev[2], ev[3])
            elif kind == "intent":
                self._on_intent(ev[1], ev[2])
            elif kind == "intent_cancel":
                self._on_intent_cancel(ev[1], ev[2], ev[3])
            elif kind == "prefetch_poke":
                self._on_prefetch_poke(ev[1], t)
            elif kind == "bounce":
                self._on_bounce(ev[1], ev[2], ev[3])
            elif kind == "sst_load":
                self.sst.push_load(ev[1], t)
                self._post(t + self.sst.push_interval_s, "sst_load", ev[1])
            elif kind == "sst_cache":
                self.sst.push_cache(ev[1], t)
                self._post(
                    t + self.sst.cache_push_interval_s, "sst_cache", ev[1]
                )
            elif kind == "gossip":
                self._on_gossip(ev[1])
            elif kind == "gossip_rx":
                self.sst.deliver(ev[1], ev[2], t)
            else:  # pragma: no cover
                raise AssertionError(f"unknown event {kind}")

        mems = self.memories
        return SimResult(
            scheduler=self.scheduler.name,
            records=self._records,
            horizon=self._now,
            n_workers=self.cluster.n_workers,
            busy_time=self._busy_time,
            cache_hits=sum(m.stats.hits for m in mems),
            cache_misses=sum(m.stats.misses for m in mems),
            cache_evictions=sum(m.stats.evictions for m in mems),
            bytes_fetched=sum(m.stats.bytes_fetched for m in mems),
            sst_pushes=self.sst.total_pushes,
            workers_used=self._workers_used,
            adjustments=self._adjustments,
            prefetch_bytes=sum(m.stats.prefetch_bytes for m in mems),
            prefetch_wasted_bytes=sum(
                m.stats.prefetch_wasted_bytes for m in mems
            ),
            prefetch_unused_resident_bytes=sum(
                m.unused_prefetched_bytes() for m in mems
            ),
            prefetch_useful=sum(m.stats.prefetch_useful for m in mems),
            prefetch_stats=(
                self.prefetch_plane.stats
                if self.prefetch_plane is not None
                else None
            ),
        )

    # -- event handlers --------------------------------------------------------------
    def _on_arrival(self, job: Job, origin: int) -> None:
        js = _JobState(job, origin)
        adfg = self.scheduler.plan(job, self._now, origin, self.sst.view(origin))
        js.adfg = adfg
        if adfg is None:
            # JIT: entry tasks become ready immediately; pick workers now.
            js.adfg = ADFG(job)
            for tid in job.dfg.entry_tasks:
                self._jit_assign(js, tid, {"": origin}, {"": job.dfg.tasks[tid].input_bytes})
        else:
            if self.prefetch_plane is not None:
                # Plan → memory intents: every assigned worker learns which
                # models its future tasks need.  The origin worker (which
                # planned) knows immediately; remote workers learn after a
                # small control-message delay.
                per = self.prefetch_plane.plan_intents(
                    job, adfg, self.profiles, self._now
                )
                for w, intents in per.items():
                    delay = 0.0
                    if w != origin:
                        delay = self.cluster.network.transfer_time(
                            INTENT_WIRE_BYTES * len(intents)
                        )
                    self._post(self._now + delay, "intent", w, intents)
            for tid in job.dfg.entry_tasks:
                w = adfg[tid]
                delay = 0.0
                if w != origin:
                    delay = self.profiles.td_input(job.dfg.tasks[tid])
                self._post(self._now + delay, "input", js, tid, "", w)

    def _jit_assign(
        self,
        js: _JobState,
        task_id: str,
        input_locations: Dict[str, int],
        input_sizes: Dict[str, float],
    ) -> None:
        # Reader worker: where the largest input lives — shipping cost is
        # dominated by the biggest object, so the JIT decision is made (and
        # the SST replica read) there.
        reader_src = max(
            input_locations, key=lambda s: input_sizes.get(s, 0.0)
        )
        reader = input_locations[reader_src]
        w = self.scheduler.select_worker_at_ready(
            js.job,
            task_id,
            self._now,
            self.sst.view(reader),
            input_locations,
            input_sizes,
            self_worker=reader,
        )
        assert js.adfg is not None
        js.adfg[task_id] = w
        # Ship all inputs to w.
        delay = 0.0
        for src, loc in input_locations.items():
            if loc != w:
                delay = max(
                    delay,
                    self.cluster.network.transfer_time(input_sizes[src]),
                )
        for src in input_locations:
            self._post(self._now + delay, "input", js, task_id, src, w)

    def _on_input(
        self, js: _JobState, task_id: str, src: str, worker: int
    ) -> None:
        js.inputs_arrived[task_id].add(src)
        run = js.tasks[task_id]
        if not run.enqueued:
            run.enqueued = True
            run.worker = worker
            self._queues[worker].append((js, task_id))
            self._update_load(worker)
        self._dispatch(worker)

    def _on_fetch_done(self, worker: int, token: int) -> None:
        if token != self._fetch_token[worker]:
            return  # the transfer this event described was preempted
        mid = self._fetch_model[worker]
        spec = self._fetch_spec[worker]
        self._fetch_busy[worker] = False
        self._fetch_model[worker] = None
        self._fetch_spec[worker] = False
        self._fetch_preemptible[worker] = False
        if spec and mid is not None:
            self.memories[worker].complete_prefetch(mid)
            if self.prefetch_plane is not None:
                self.prefetch_plane.complete_inflight(worker)
        self._publish_cache(worker)  # also refreshes the intent bitmap
        self._dispatch(worker)

    def _on_task_done(self, js: _JobState, task_id: str, worker: int) -> None:
        run = js.tasks[task_id]
        run.finished = self._now
        task = js.job.dfg.tasks[task_id]
        if task.model_id is not None:
            self.memories[worker].end_execution(task.model_id)
            self._publish_cache(worker)
        self._busy_time[worker] += self._now - (run.started or self._now)
        self._gpu_busy[worker] = None
        self._update_load(worker)
        self._route_successors(js, task_id, worker)
        if js.done():
            js.finish_time = self._now
            self._records.append(
                JobRecord(
                    job_id=js.job.job_id,
                    dfg_name=js.job.dfg.name,
                    arrival=js.job.arrival_time,
                    finish=self._now,
                    lower_bound=js.job.lower_bound(),
                )
            )
            self._jobs_open -= 1
        self._dispatch(worker)

    # -- successor routing ---------------------------------------------------------
    def _route_successors(self, js: _JobState, task_id: str, worker: int) -> None:
        dfg = js.job.dfg
        task = dfg.tasks[task_id]
        adfg = js.adfg
        assert adfg is not None
        for succ in dfg.succs[task_id]:
            if self.scheduler.plans_at_arrival:
                if (
                    self.scheduler.needs_adjustment
                    and not dfg.is_join(succ)
                ):
                    new_w = self.scheduler.adjust(
                        js.job,
                        adfg,
                        succ,
                        self._now,
                        self.sst.view(worker),
                        worker,
                        task.output_bytes,
                    )
                    if new_w != adfg[succ]:
                        self._adjustments += 1
                        if self.prefetch_plane is not None:
                            self._migrate_intent(js, succ, adfg[succ], new_w)
                        adfg[succ] = new_w
                w = adfg[succ]
                delay = (
                    0.0
                    if w == worker
                    else self.cluster.network.transfer_time(task.output_bytes)
                )
                self._post(self._now + delay, "input", js, succ, task_id, w)
            else:
                # JIT: assign when ALL predecessors have completed.
                preds = dfg.preds[succ]
                if all(js.tasks[p].finished is not None for p in preds):
                    locs = {p: js.tasks[p].worker for p in preds}
                    sizes = {p: dfg.tasks[p].output_bytes for p in preds}
                    self._jit_assign(js, succ, locs, sizes)  # type: ignore[arg-type]

    # -- dispatcher (§3.2) ------------------------------------------------------------
    def _dispatch(self, worker: int) -> None:
        if self._gpu_busy[worker] is not None:
            # Still try to start a model fetch for a queued task.
            self._maybe_prefetch(worker)
            return
        queue = self._queues[worker]
        for idx, (js, tid) in enumerate(queue):
            if not js.inputs_ready(tid) or js.tasks[tid].bouncing:
                continue
            task = js.job.dfg.tasks[tid]
            mem = self.memories[worker]
            mid = task.model_id
            if mid is not None:
                inflight = (
                    self._fetch_busy[worker]
                    and self._fetch_model[worker] == mid
                )
                if inflight and self._fetch_preemptible[worker]:
                    # A queued task demands the model on the pipe: the
                    # speculative prefetch becomes a demand fetch.
                    self._promote_prefetch(worker, js, tid)
                if not mem.has(mid) or inflight:
                    if not inflight and not js.tasks[tid].fetching:
                        self._request_demand_fetch(worker, js, tid)
                    continue  # leave on queue, proceed to next (paper §3.2)
            # Start execution.
            queue.pop(idx)
            run = js.tasks[tid]
            run.started = self._now
            if task.model_id is not None:
                if not run.was_miss:
                    mem.stats.hits += 1  # model was already resident
                upcoming = [
                    js2.job.dfg.tasks[t2].model_id for js2, t2 in queue
                ]
                mem.begin_execution(task.model_id, upcoming)
                if self.prefetch_plane is not None:
                    self.prefetch_plane.consume(
                        worker, js.job.job_id, tid
                    )
                self._publish_cache(worker)
            self._gpu_busy[worker] = (js, tid)
            self._workers_used.add(worker)
            rt = self._noisy(self.profiles.runtime(task, worker))
            self._post(self._now + rt, "task_done", js, tid, worker)
            self._update_load(worker)
            break
        self._maybe_prefetch(worker)

    def _maybe_prefetch(self, worker: int) -> None:
        """Keep the fetch pipe busy: demand fetches for queued tasks first;
        with the prefetch plane enabled, speculative fetches from the
        intent queue fill the idle pipe (demand preempts prefetch)."""
        if not self._fetch_busy[worker] or self._fetch_preemptible[worker]:
            for js, tid in self._queues[worker]:
                task = js.job.dfg.tasks[tid]
                mid = task.model_id
                if (
                    mid is None
                    or js.tasks[tid].fetching
                    or js.tasks[tid].bouncing
                    or not js.inputs_ready(tid)
                ):
                    continue
                if (
                    self._fetch_busy[worker]
                    and self._fetch_model[worker] == mid
                ):
                    if self._fetch_preemptible[worker]:
                        self._promote_prefetch(worker, js, tid)
                    continue  # transfer already underway for this model
                if not self.memories[worker].has(mid):
                    self._request_demand_fetch(worker, js, tid)
                    return
        if self.prefetch_plane is not None and not self._fetch_busy[worker]:
            self._start_speculative_fetch(worker)

    def _request_demand_fetch(self, worker: int, js: _JobState, tid: str) -> None:
        if self._fetch_busy[worker]:
            if not self._fetch_preemptible[worker]:
                return  # pipe owned by a demand (or promoted) fetch
            # Demand preempts prefetch: abort the speculative transfer.
            if self.prefetch_plane is not None:
                self.prefetch_plane.preempt_inflight(worker, requeue=True)
            self._abort_spec_fetch(worker)
        self._start_fetch(worker, js, tid)

    def _start_fetch(self, worker: int, js: _JobState, tid: str) -> None:
        task = js.job.dfg.tasks[tid]
        assert task.model_id is not None
        mem = self.memories[worker]
        if not mem.can_host(task.model_id):
            # Capacity-blind scheduler put the task on a GPU that can
            # never execute its model: the dispatcher rejects and
            # re-routes it (handled as an event so the queue is not
            # mutated mid-scan).
            js.tasks[tid].bouncing = True
            self._post(self._now, "bounce", js, tid, worker)
            return
        upcoming = [
            js2.job.dfg.tasks[t2].model_id for js2, t2 in self._queues[worker]
        ]
        res = mem.ensure(task.model_id, upcoming)
        if res is None:
            return  # cannot evict enough right now; retry on next dispatch
        fetch_s, _ = res
        # Pin for the duration of the fetch so another task's eviction
        # cannot displace the in-flight model; released by the bookkeeping
        # event at fetch completion (execution re-pins at start).
        mem.pin(task.model_id)
        js.tasks[tid].fetching = True
        js.tasks[tid].was_miss = True
        self._fetch_busy[worker] = True
        self._fetch_model[worker] = task.model_id
        self._fetch_spec[worker] = False
        self._fetch_preemptible[worker] = False
        self._fetch_started[worker] = self._now
        self._fetch_ends[worker] = self._now + fetch_s
        if self.prefetch_plane is not None:
            # Demand took over this task's model staging; its intent (if
            # still queued) is spent.
            self.prefetch_plane.consume(worker, js.job.job_id, tid)
        self._publish_cache(worker)  # also refreshes the intent bitmap
        self._post(self._now + fetch_s, "task_fetch_bookkeep", js, tid, worker)
        self._post(
            self._now + fetch_s, "fetch_done", worker,
            self._fetch_token[worker],
        )

    # -- speculative prefetch (core/prefetch.py) ---------------------------------
    def _start_speculative_fetch(self, worker: int) -> None:
        plane = self.prefetch_plane
        assert plane is not None
        if plane.queue_depth(worker) == 0:
            return
        mem = self.memories[worker]
        peer_bits = 0
        for w2, row in enumerate(self.sst.view(worker)):
            if w2 != worker:
                peer_bits |= row.cache_bitmap | row.intent_bitmap
        intent, retry_at = plane.next_intent(
            worker, self._now, mem.has, peer_bits
        )
        if intent is None:
            self._schedule_poke(worker, retry_at)
            return
        res = mem.begin_prefetch(
            intent.model_id,
            upcoming_model_ids=[
                js.job.dfg.tasks[t].model_id for js, t in self._queues[worker]
            ],
            allow_evict=plane.config.evict_for_prefetch,
        )
        if res is None:
            # No room right now: park the intent and retry shortly.
            until = self._now + max(0.1, plane.config.herd_backoff_s)
            plane.stall_inflight(worker, until)
            self._schedule_poke(worker, until)
            return
        fetch_s, _ = res
        self._fetch_busy[worker] = True
        self._fetch_model[worker] = intent.model_id
        self._fetch_spec[worker] = True
        self._fetch_preemptible[worker] = True
        self._fetch_started[worker] = self._now
        self._fetch_ends[worker] = self._now + fetch_s
        self._post(
            self._now + fetch_s, "fetch_done", worker,
            self._fetch_token[worker],
        )
        self._publish_cache(worker)  # also refreshes the intent bitmap

    def _promote_prefetch(self, worker: int, js: _JobState, tid: str) -> None:
        self._fetch_preemptible[worker] = False
        if self.prefetch_plane is not None:
            self.prefetch_plane.promote_inflight(worker)
        # The demanding task still waits for (the rest of) the transfer,
        # so account it as a demand miss — hit rates stay comparable with
        # the plane off; only fetches that *complete* before the task
        # needs the model convert to hits.
        run = js.tasks[tid]
        if not run.was_miss:
            run.was_miss = True
            self.memories[worker].stats.misses += 1

    def _abort_spec_fetch(self, worker: int) -> None:
        """Tear down the in-flight speculative transfer (the plane-side
        intent bookkeeping is the caller's job)."""
        mid = self._fetch_model[worker]
        assert mid is not None and self._fetch_spec[worker]
        self._fetch_token[worker] += 1  # invalidate the posted completion
        dur = self._fetch_ends[worker] - self._fetch_started[worker]
        frac = 0.0 if dur <= 0 else (self._now - self._fetch_started[worker]) / dur
        self.memories[worker].abort_prefetch(mid, frac)
        self._fetch_busy[worker] = False
        self._fetch_model[worker] = None
        self._fetch_spec[worker] = False
        self._fetch_preemptible[worker] = False
        self._publish_cache(worker)  # also refreshes the intent bitmap

    def _schedule_poke(self, worker: int, at: Optional[float]) -> None:
        if at is None:
            return
        if self._poke_at[worker] is not None and self._poke_at[worker] <= at:
            return
        self._poke_at[worker] = at
        self._post(at, "prefetch_poke", worker)

    def _on_prefetch_poke(self, worker: int, t: float) -> None:
        if self._poke_at[worker] is not None and self._poke_at[worker] <= t:
            self._poke_at[worker] = None
        self._maybe_prefetch(worker)

    def _on_intent(self, worker: int, intents) -> None:
        assert self.prefetch_plane is not None
        self.prefetch_plane.admit(worker, intents, self._now)
        self._publish_intent(worker)
        self._maybe_prefetch(worker)

    def _on_intent_cancel(self, worker: int, js: _JobState, task_id: str) -> None:
        assert self.prefetch_plane is not None
        aborted = self.prefetch_plane.cancel(
            worker, js.job.job_id, task_id, migrated=True
        )
        if (
            aborted is not None
            and self._fetch_busy[worker]
            and self._fetch_preemptible[worker]
            and self._fetch_model[worker] == aborted.model_id
        ):
            self._abort_spec_fetch(worker)
            self._maybe_prefetch(worker)
        else:
            self._publish_intent(worker)

    def _on_fetch_bookkeep(self, js: _JobState, tid: str, worker: int) -> None:
        js.tasks[tid].fetching = False
        task = js.job.dfg.tasks[tid]
        if task.model_id is not None:
            self.memories[worker].unpin(task.model_id)

    def _on_bounce(self, js: _JobState, tid: str, worker: int) -> None:
        """Re-route a task whose assigned GPU can never host its model:
        ship it (and its already-arrived inputs) to the least-loaded
        worker with enough memory."""
        task = js.job.dfg.tasks[tid]
        assert task.model_id is not None
        feasible = [
            w
            for w in self.cluster.workers()
            if self.memories[w].can_host(task.model_id)
        ]
        if not feasible:
            raise ValueError(
                f"model {task.model_id} fits no worker in the fleet"
            )
        sst = self.sst.view(worker)
        target = min(
            feasible, key=lambda w: (max(self._now, sst[w].ft_estimate_s), w)
        )
        run = js.tasks[tid]
        run.bouncing = False
        self._queues[worker] = [
            (j, t) for j, t in self._queues[worker] if (j, t) != (js, tid)
        ]
        run.enqueued = False
        run.worker = None
        assert js.adfg is not None
        js.adfg[tid] = target
        dfg = js.job.dfg
        delay = 0.0
        srcs = list(js.inputs_arrived[tid])
        for src in srcs:
            nbytes = (
                task.input_bytes if src == "" else dfg.tasks[src].output_bytes
            )
            delay = max(delay, self.cluster.network.transfer_time(nbytes))
        js.inputs_arrived[tid] = set()
        for src in srcs:
            self._post(self._now + delay, "input", js, tid, src, target)
        self._update_load(worker)
        self._dispatch(worker)

    def _migrate_intent(
        self, js: _JobState, task_id: str, old_w: int, new_w: int
    ) -> None:
        """Alg. 2 moved a task: cancel the prefetch intent on the planned
        worker (a control message) and re-issue it on the new one (riding
        the input transfer that is about to ship there)."""
        assert self.prefetch_plane is not None
        ctrl = self.cluster.network.transfer_time(INTENT_WIRE_BYTES)
        self._post(self._now + ctrl, "intent_cancel", old_w, js, task_id)
        intent = self.prefetch_plane.make_intent(
            js.job, task_id, new_w, self._now
        )
        if intent is not None:
            self._post(self._now + ctrl, "intent", new_w, [intent])

    # -- gossip plane (decentralized SST, §5.2) ------------------------------------
    def _on_gossip(self, worker: int) -> None:
        """One gossip round: the plane computes the diff messages (drops
        already sampled); delivery is delayed by the network model, so a
        reader's view lags by period + wire time."""
        assert self.gossip is not None and isinstance(self.sst, GossipPlane)
        for peer, updates, nbytes in self.sst.exchange(worker, self._now):
            delay = self.cluster.network.transfer_time(nbytes)
            self._post(self._now + delay, "gossip_rx", peer, updates)
        self._post(self._now + self.gossip.period_s, "gossip", worker)

    # -- state publication ---------------------------------------------------------
    def _update_load(self, worker: int) -> None:
        """Recompute FT(w) = now + remaining work on the queue (§4.1)."""
        ft = self._now
        busy = self._gpu_busy[worker]
        if busy is not None:
            js, tid = busy
            task = js.job.dfg.tasks[tid]
            # remaining = expected runtime (we don't know the noise draw)
            elapsed = self._now - (js.tasks[tid].started or self._now)
            ft += max(0.0, self.profiles.runtime(task, worker) - elapsed)
        for js, tid in self._queues[worker]:
            ft += self.profiles.runtime(js.job.dfg.tasks[tid], worker)
        self.sst.update_load(worker, ft, self._now)

    def _publish_cache(self, worker: int) -> None:
        mem = self.memories[worker]
        if self.prefetch_plane is None:
            self.sst.update_cache(worker, mem.bitmap, mem.free_bytes, self._now)
            return
        # Under the prefetch plane the advertisement is honest about the
        # pipe: a model still in flight is not usable residency (tasks
        # wait for fetch completion), so it moves from the cache bitmap to
        # the intent bitmap, where the planner prices it at the discounted
        # remainder of the fetch.  AVC counts undemanded speculative
        # contents as available — they are the cheapest victims.
        bm = mem.bitmap
        if self._fetch_busy[worker] and self._fetch_model[worker] is not None:
            bm &= ~(1 << self._fetch_model[worker])
        self.sst.update_cache(worker, bm, mem.available_bytes, self._now)
        self.sst.update_intent(
            worker,
            mem.bitmap | self.prefetch_plane.advertised_bits(worker),
            self._now,
        )

    def _publish_intent(self, worker: int) -> None:
        if self.prefetch_plane is None:
            return
        mem = self.memories[worker]
        self.sst.update_intent(
            worker,
            mem.bitmap | self.prefetch_plane.advertised_bits(worker),
            self._now,
        )

"""Event-driven cluster simulator (§5.4).

Models the environment of §3 at the fidelity the paper describes: task
arrival, queue waiting, model fetch (PCIe), task execution (one active GPU
task per worker), task dispatch with network transfer of intermediate
objects, and rate-limited SST dissemination.  Events are processed in
simulated-time order (heap).  The paper validated its simulator within 5 %
of the real 5-worker testbed; ours follows the same structure (Sparrow
style) and uses the same profiled constants.

Execution semantics (faithful to §3.2):
* A worker's Task Dispatcher scans its execution queue in order and starts
  the first task whose inputs are present and whose model is resident; a
  task whose model is being fetched (or whose inputs are missing) is left
  on the queue and the dispatcher proceeds to the next.
* One model fetch (PCIe transfer) is in flight per worker at a time; it
  overlaps with GPU execution of other tasks.
* On task completion the scheduler's dynamic-adjustment hook runs for each
  successor (Alg. 2), then outputs are shipped to the successors' workers.
"""

from __future__ import annotations

import dataclasses
import heapq
import itertools
import math
import random
from typing import Dict, List, Mapping, Optional, Sequence, Set, Tuple, Union

import numpy as np

from repro.core.healthplane import HealthConfig, HealthMonitor
from repro.core.memory import GpuMemoryManager
from repro.core.netmodel import ClusterSpec, NetworkState
from repro.core.telemetry import (
    FlightRecorder,
    MetricsRegistry,
    TraceConfig,
)
from repro.core.prefetch import (
    INTENT_WIRE_BYTES,
    PrefetchConfig,
    PrefetchIntent,
    PrefetchPlane,
    PrefetchStats,
)
from repro.core.profiles import ProfileRepository
from repro.core.scheduler import (
    NavigatorConfig,
    Scheduler,
    make_scheduler,
)
from repro.core.sst_exchange import GossipConfig, GossipPlane
from repro.core.state import DEAD, LeaseConfig, SharedStateTable
from repro.core.types import ADFG, Job, MLModel
from repro.sim.churn import CRASH, DRAIN, HEAL, JOIN, PARTITION, ChurnEvent


# --------------------------------------------------------------------------
# Per-job bookkeeping
# --------------------------------------------------------------------------
@dataclasses.dataclass
class _TaskRun:
    enqueued: bool = False
    fetching: bool = False
    was_miss: bool = False
    # Assigned to a GPU that can never host the model (capacity-blind
    # scheduler on a heterogeneous fleet); being re-routed by the
    # dispatcher.
    bouncing: bool = False
    started: Optional[float] = None
    finished: Optional[float] = None
    worker: Optional[int] = None
    # Attempt counter: every re-route/re-execution (bounce, worker crash,
    # drain) bumps it, and in-flight events tagged with an older value are
    # void on delivery — the mechanism that keeps gossip-declared death
    # racing in-flight fetches/inputs/completions safe.
    generation: int = 0
    # Worker incarnation at completion time: the task's output survives
    # only while its worker is up in the SAME incarnation (a crash wipes
    # outputs even if the worker rejoins before anyone re-reads them).
    session: int = 0


class _JobState:
    def __init__(self, job: Job, origin: int) -> None:
        self.job = job
        self.origin = origin
        self.adfg: Optional[ADFG] = None
        self.tasks: Dict[str, _TaskRun] = {
            t: _TaskRun() for t in job.dfg.tasks
        }
        # task -> set of predecessors whose outputs have arrived at the
        # task's assigned worker (external input counts via the sentinel "").
        self.inputs_arrived: Dict[str, Set[str]] = {t: set() for t in job.dfg.tasks}
        self.finish_time: Optional[float] = None

    def inputs_ready(self, task_id: str) -> bool:
        dfg = self.job.dfg
        need = set(dfg.preds[task_id]) or {""}
        return need <= self.inputs_arrived[task_id]

    def done(self) -> bool:
        return all(r.finished is not None for r in self.tasks.values())


@dataclasses.dataclass
class JobRecord:
    job_id: int
    dfg_name: str
    arrival: float
    finish: float
    lower_bound: float

    @property
    def latency(self) -> float:
        return self.finish - self.arrival

    @property
    def slowdown(self) -> float:
        return self.latency / self.lower_bound if self.lower_bound > 0 else 1.0


@dataclasses.dataclass
class SimResult:
    """Per-run outcome.  All counters live in the :class:`MetricsRegistry`
    (``metrics``) under named, labeled families — the legacy flat fields
    (``cache_hits``, ``net_local_transfers``, ...) are derived views over
    it, so existing benchmarks and tests keep working unchanged."""

    scheduler: str
    records: List[JobRecord]
    horizon: float
    n_workers: int
    busy_time: Dict[int, float]
    workers_used: Set[int]
    metrics: MetricsRegistry
    prefetch_stats: Optional[PrefetchStats] = None
    # (job_id, task_id) -> accepted completion count; every task completes
    # >= 1 time, and sum == n_tasks + outputs_recovered.
    task_completions: Optional[Dict[Tuple[int, str], int]] = None
    # (time, kind) per processed event when ``record_events=True`` — the
    # determinism regression tests compare two runs' logs verbatim.
    event_log: Optional[List[Tuple[float, str]]] = None
    # Flight recorder (core/telemetry.py) when the run was traced; feed
    # it (or the whole result) to ``SimReport`` for latency breakdowns,
    # critical paths, and placement provenance.
    trace: Optional[FlightRecorder] = None
    # Health monitor (core/healthplane.py) when the health plane ran:
    # windowed series, latency sketches, and detector ledger — read via
    # ``SimReport.health_summary()`` or ``health.summary()`` directly.
    health: Optional[HealthMonitor] = None

    # -- derived views over the metrics registry -------------------------------
    @property
    def cache_hits(self) -> int:
        return int(self.metrics.sum_values("cache.hits"))

    @property
    def cache_misses(self) -> int:
        return int(self.metrics.sum_values("cache.misses"))

    @property
    def cache_evictions(self) -> int:
        return int(self.metrics.sum_values("cache.evictions"))

    @property
    def bytes_fetched(self) -> float:
        return self.metrics.sum_values("cache.bytes_fetched")

    @property
    def sst_pushes(self) -> int:
        return int(self.metrics.value("sst.pushes"))

    @property
    def adjustments(self) -> int:
        return int(self.metrics.value("sched.adjustments"))

    # Predictive prefetch plane (core/prefetch.py); zeros when disabled.
    @property
    def prefetch_bytes(self) -> float:
        return self.metrics.sum_values("prefetch.bytes")

    @property
    def prefetch_wasted_bytes(self) -> float:
        return self.metrics.sum_values("prefetch.wasted_bytes")

    @property
    def prefetch_unused_resident_bytes(self) -> float:
        return self.metrics.sum_values("prefetch.unused_resident_bytes")

    @property
    def prefetch_useful(self) -> int:
        return int(self.metrics.sum_values("prefetch.useful"))

    # Fleet churn / fault tolerance (zeros on a static fleet).
    @property
    def churn_crashes(self) -> int:
        return int(self.metrics.value("churn.events", kind="crash"))

    @property
    def churn_joins(self) -> int:
        return int(self.metrics.value("churn.events", kind="join"))

    @property
    def churn_drains(self) -> int:
        return int(self.metrics.value("churn.events", kind="drain"))

    @property
    def churn_partitions(self) -> int:
        return int(self.metrics.value("churn.events", kind="partition"))

    @property
    def churn_heals(self) -> int:
        return int(self.metrics.value("churn.events", kind="heal"))

    # Topology plane (zeros on a flat cluster): bulk transfers that stayed
    # inside one rack vs. crossed the (oversubscribable) spine, and how
    # many of the crossing ones shared an uplink with another in-flight
    # transfer (fair-share slowdown actually applied).
    @property
    def net_local_transfers(self) -> int:
        return int(self.metrics.value("net.transfers", scope="local"))

    @property
    def net_cross_transfers(self) -> int:
        return int(self.metrics.value("net.transfers", scope="cross"))

    @property
    def net_contended_transfers(self) -> int:
        return int(self.metrics.value("net.transfers", scope="contended"))

    @property
    def bounces(self) -> int:
        """Capacity bounces executed (§3.2 dispatcher)."""
        return int(self.metrics.value("sched.bounces"))

    @property
    def tasks_rescued(self) -> int:
        """In-flight/queued work re-routed off a dead worker."""
        return int(self.metrics.value("churn.tasks_rescued"))

    @property
    def outputs_recovered(self) -> int:
        """Finished producers re-run (outputs died)."""
        return int(self.metrics.value("churn.outputs_recovered"))

    @property
    def churn_wasted_bytes(self) -> float:
        """PCIe bytes thrown away by churn."""
        return self.metrics.sum_values("churn.wasted_bytes")

    # Accounting-balance inputs for the chaos invariant checker:
    # hits + misses == model_exec_starts + lost_miss_attempts
    #                  + demand_refetches.
    @property
    def model_exec_starts(self) -> int:
        return int(self.metrics.value("exec.model_starts"))

    @property
    def lost_miss_attempts(self) -> int:
        return int(self.metrics.value("exec.lost_miss_attempts"))

    @property
    def demand_refetches(self) -> int:
        """A waiting task's fetched model was evicted before it could
        start (another task's execution displaced it): the dispatcher
        fetches again, charging a second miss against the same eventual
        start."""
        return int(self.metrics.value("exec.demand_refetches"))

    # -- aggregates ------------------------------------------------------------
    @property
    def mean_latency(self) -> float:
        return sum(r.latency for r in self.records) / max(1, len(self.records))

    @property
    def mean_slowdown(self) -> float:
        return sum(r.slowdown for r in self.records) / max(1, len(self.records))

    @property
    def median_slowdown(self) -> float:
        xs = sorted(r.slowdown for r in self.records)
        if not xs:
            return 0.0
        n = len(xs)
        return xs[n // 2] if n % 2 else 0.5 * (xs[n // 2 - 1] + xs[n // 2])

    def slowdowns_by_type(self) -> Dict[str, List[float]]:
        out: Dict[str, List[float]] = {}
        for r in self.records:
            out.setdefault(r.dfg_name, []).append(r.slowdown)
        return out

    @property
    def cache_hit_rate(self) -> float:
        tot = self.cache_hits + self.cache_misses
        return self.cache_hits / tot if tot else 1.0

    @property
    def gpu_utilization(self) -> float:
        if self.horizon <= 0:
            return 0.0
        return sum(self.busy_time.values()) / (self.horizon * self.n_workers)

    def energy_joules(self, cluster: ClusterSpec) -> float:
        busy = sum(self.busy_time.values())
        idle = self.horizon * self.n_workers - busy
        return busy * cluster.gpu_power_active_w + idle * cluster.gpu_power_idle_w

    def percentile_latency(self, q: float) -> float:
        xs = sorted(r.latency for r in self.records)
        if not xs:
            return 0.0
        idx = min(len(xs) - 1, max(0, int(math.ceil(q * len(xs))) - 1))
        return xs[idx]


# --------------------------------------------------------------------------
# Simulator
# --------------------------------------------------------------------------
class Simulation:
    def __init__(
        self,
        cluster: ClusterSpec,
        profiles: ProfileRepository,
        models: Mapping[int, MLModel],
        scheduler: str = "navigator",
        navigator_config: Optional[NavigatorConfig] = None,
        eviction_policy: str = GpuMemoryManager.LOOKAHEAD,
        push_interval_s: float = 0.2,
        cache_push_interval_s: Optional[float] = None,
        gossip: Optional[GossipConfig] = None,
        prefetch: Optional[PrefetchConfig] = None,
        lease: Optional[LeaseConfig] = None,
        churn: Optional[Sequence[ChurnEvent]] = None,
        record_events: bool = False,
        trace: Union[bool, TraceConfig] = False,
        health: Union[bool, HealthConfig] = False,
        runtime_noise_sigma: float = 0.25,
        seed: int = 0,
        engine: str = "indexed",
    ) -> None:
        if engine not in ("indexed", "reference"):
            raise ValueError(f"unknown engine {engine!r}")
        self.engine = engine
        self.cluster = cluster
        self.profiles = profiles
        self.models = dict(models)
        self.scheduler: Scheduler = make_scheduler(
            scheduler, profiles, navigator_config
        )
        # Flight recorder (core/telemetry.py).  ``None`` when off: every
        # emission site below is guarded by ``if self._rec is not None``,
        # so the disabled path costs one attribute load + branch and
        # performs zero telemetry allocations in the event loop (the CI
        # trace-smoke guard asserts exactly that).
        self._rec: Optional[FlightRecorder] = None
        if trace:
            self._rec = FlightRecorder(
                cluster.n_workers,
                trace if isinstance(trace, TraceConfig) else None,
            )
        self.scheduler.recorder = self._rec  # placement provenance sink
        # Health plane (core/healthplane.py).  Same zero-overhead-when-off
        # contract as the recorder: every sampling site below is guarded
        # by ``if self._health is not None``.
        self._health: Optional[HealthMonitor] = None
        if health:
            self._health = HealthMonitor(
                cluster.n_workers,
                health if isinstance(health, HealthConfig) else None,
                recorder=self._rec,
            )
        # Indexed engine: planners read the SST as packed numpy columns
        # (one O(W) column copy per read instead of W python row copies)
        # and score all candidates in one vector pass — bit-exact with the
        # scalar row path (chaos family 7).  The flight recorder needs
        # per-candidate provenance only the scalar path records, so
        # tracing forces the reference read path.
        self._use_packed = engine == "indexed" and self._rec is None
        # Metadata plane: ``gossip`` selects the decentralized per-worker
        # view subsystem (each worker plans from its own, possibly stale,
        # replica); default is the single-published-snapshot table.
        # ``lease`` enables the membership lane on either plane: workers
        # heartbeat their own row and every reader classifies peers
        # ALIVE/SUSPECT/DEAD from its own replica's heartbeat age.
        self.gossip = gossip
        self.lease = lease
        if churn and lease is None:
            # Churn without a membership lane would leave informed
            # schedulers placing onto corpses forever; default the lease.
            lease = self.lease = LeaseConfig()
        self.churn = list(churn or [])
        if gossip is not None:
            self.sst = GossipPlane(
                cluster.n_workers, gossip, seed=seed, lease=lease
            )
        else:
            self.sst = SharedStateTable(
                cluster.n_workers, push_interval_s, cache_push_interval_s,
                lease=lease,
            )
        self.memories = [
            GpuMemoryManager(
                cluster.gpu_capacity(w),
                self.models,
                cluster.link,
                policy=eviction_policy,
                compression_ratio=cluster.compression_ratio,
            )
            for w in cluster.workers()
        ]
        self.rng = random.Random(seed)
        self.noise_sigma = runtime_noise_sigma

        self._heap: List[Tuple[float, int, Tuple]] = []
        self._seq = itertools.count()
        self._now = 0.0
        self._queues: List[List[Tuple[_JobState, str]]] = [
            [] for _ in cluster.workers()
        ]
        self._gpu_busy: List[Optional[Tuple[_JobState, str]]] = [
            None for _ in cluster.workers()
        ]
        # Fetch-pipe state (one PCIe transfer in flight per worker, §3.2).
        # ``_fetch_model`` is the model on the pipe; ``_fetch_preemptible``
        # marks a speculative prefetch a demand fetch may abort; the token
        # invalidates posted completion events after a preemption.
        self._fetch_busy: List[bool] = [False for _ in cluster.workers()]
        self._fetch_model: List[Optional[int]] = [
            None for _ in cluster.workers()
        ]
        self._fetch_spec: List[bool] = [False for _ in cluster.workers()]
        self._fetch_preemptible: List[bool] = [
            False for _ in cluster.workers()
        ]
        self._fetch_started: List[float] = [0.0 for _ in cluster.workers()]
        self._fetch_ends: List[float] = [0.0 for _ in cluster.workers()]
        self._fetch_token: List[int] = [0 for _ in cluster.workers()]
        # Predictive prefetch plane (plan-driven speculative fetches).
        self.prefetch_plane: Optional[PrefetchPlane] = None
        if prefetch is not None:
            self.prefetch_plane = PrefetchPlane(
                cluster.n_workers, prefetch, fetch_time_fn=profiles.td_model
            )
        self._poke_at: List[Optional[float]] = [
            None for _ in cluster.workers()
        ]
        self._busy_time: Dict[int, float] = {w: 0.0 for w in cluster.workers()}
        self._records: List[JobRecord] = []
        self._jobs_open = 0
        self._workers_used: Set[int] = set()
        self._adjustments = 0
        # Fleet membership ground truth (what the world is, not what any
        # worker's view says) plus per-worker incarnation sessions that
        # void stale periodic events (gossip/heartbeat/publication chains)
        # across down/up transitions.
        self._up: List[bool] = [True for _ in cluster.workers()]
        self._draining: List[bool] = [False for _ in cluster.workers()]
        self._session: List[int] = [0 for _ in cluster.workers()]
        self._open_jobs: List[_JobState] = []
        # Amortized roster compaction: prune finished jobs once the list
        # doubles past the last compacted size, so a churn-free 1M-job
        # replay does not pin every finished _JobState for the whole run.
        self._compact_open_at = 64
        self._events = 0
        self._orphaned_intents: Dict[Tuple[int, str], PrefetchIntent] = {}
        self._completions: Dict[Tuple[int, str], int] = {}
        self._bounces = 0
        self._tasks_rescued = 0
        self._outputs_recovered = 0
        self._model_exec_starts = 0
        self._lost_miss_attempts = 0
        self._demand_refetches = 0
        self._churn_crashes = 0
        self._churn_joins = 0
        self._churn_drains = 0
        self._churn_partitions = 0
        self._churn_heals = 0
        # Topology plane: contention tracker over the cluster's rack graph
        # (None on a flat cluster — every wire cost then takes the exact
        # pre-topology all-pairs code path) and the open partition, as
        # worker -> group id (None = fully connected).
        self._net: Optional[NetworkState] = (
            NetworkState(cluster.topology)
            if cluster.topology is not None
            else None
        )
        self._partition: Optional[List[int]] = None
        self._net_local = 0
        self._net_cross = 0
        # Replayable event log (determinism regression tests).
        self.event_log: Optional[List[Tuple[float, str]]] = (
            [] if record_events else None
        )
        for w in cluster.workers():
            self.sst.update_cache(w, 0, cluster.gpu_capacity(w), 0.0)
            if self.lease is not None:
                self.sst.heartbeat(w, 0.0)
            self.sst.push(w, 0.0)

    # -- event plumbing ----------------------------------------------------------
    def _post(self, t: float, kind: str, *payload) -> None:
        heapq.heappush(self._heap, (t, next(self._seq), (kind, *payload)))

    def _post_input(
        self,
        t_arrive: float,
        js: "_JobState",
        tid: str,
        src: str,
        worker: int,
        gen: int,
        src_worker: Optional[int],
    ) -> None:
        """Post one input shipment, tracing its send/arrive pair.  The
        span stitcher reconstructs per-task transfer time from exactly
        these records (``t`` = send time, ``arrive`` = landing time)."""
        if self._rec is not None:
            self._rec.emit(
                self._now, "task.input", worker=worker,
                job=js.job.job_id, task=tid, gen=gen, src=src,
                frm=src_worker, to=worker, arrive=t_arrive,
            )
        self._post(t_arrive, "input", js, tid, src, worker, gen, src_worker)

    def _noisy(self, runtime: float) -> float:
        if self.noise_sigma <= 0:
            return runtime
        return runtime * self.rng.lognormvariate(0.0, self.noise_sigma)

    def _sst_view(self, reader: int):
        """Planner-facing SST read: packed columns on the indexed engine,
        the scalar row list otherwise.  Both planes keep a columnar mirror
        maintained O(1) per dirty row, so the packed read is a handful of
        numpy column copies rather than W python row copies."""
        if self._use_packed:
            return self.sst.view_arrays(reader, self._now)
        return self.sst.view(reader, self._now)

    # -- public API ----------------------------------------------------------------
    def run(self, jobs: Sequence[Job]) -> SimResult:
        """Drive the simulation to completion.  Split into schedule /
        event-loop / assemble stages so the CI zero-allocation guard can
        profile the hot event loop in isolation (result assembly builds
        the metrics registry, which allocates by design)."""
        self._schedule_initial(jobs)
        self._event_loop()
        return self._assemble_result()

    def _schedule_initial(self, jobs: Sequence[Job]) -> None:
        origin = itertools.cycle(self.cluster.workers())
        for job in sorted(jobs, key=lambda j: j.arrival_time):
            self._post(job.arrival_time, "arrival", job, next(origin))
        for ev in self.churn:
            self._post(ev.time, "churn", ev)
        # SST dissemination schedule (staggered per worker).
        if self.gossip is not None:
            for w in self.cluster.workers():
                offset = (w + 1) * self.gossip.period_s / max(
                    1, self.cluster.n_workers
                )
                self._post(offset, "gossip", w, 0)
        else:
            for w in self.cluster.workers():
                offset = (w + 1) * self.sst.push_interval_s / max(
                    1, self.cluster.n_workers
                )
                self._post(offset, "sst_load", w, 0)
                offset_c = (w + 1) * self.sst.cache_push_interval_s / max(
                    1, self.cluster.n_workers
                )
                self._post(offset_c, "sst_cache", w, 0)
        if self.lease is not None:
            for w in self.cluster.workers():
                offset = (w + 1) * self.lease.heartbeat_period_s / max(
                    1, self.cluster.n_workers
                )
                self._post(offset, "heartbeat", w, 0)
        self._jobs_open = len(jobs)

    def _event_loop(self) -> None:
        while self._heap and self._jobs_open > 0:
            t, _, ev = heapq.heappop(self._heap)
            self._now = t
            self._events += 1
            kind = ev[0]
            if self.event_log is not None:
                self.event_log.append((round(t, 9), kind))
            if kind == "arrival":
                self._on_arrival(ev[1], ev[2])
            elif kind == "enqueue":
                self._on_enqueue(ev[1], ev[2], ev[3])
            elif kind == "input":
                self._on_input(ev[1], ev[2], ev[3], ev[4], ev[5], ev[6])
            elif kind == "fetch_done":
                self._on_fetch_done(ev[1], ev[2])
            elif kind == "task_done":
                self._on_task_done(ev[1], ev[2], ev[3], ev[4])
            elif kind == "task_fetch_bookkeep":
                self._on_fetch_bookkeep(ev[1], ev[2], ev[3], ev[4])
            elif kind == "intent":
                self._on_intent(ev[1], ev[2])
            elif kind == "intent_cancel":
                self._on_intent_cancel(ev[1], ev[2], ev[3])
            elif kind == "prefetch_poke":
                self._on_prefetch_poke(ev[1], t)
            elif kind == "bounce":
                self._on_bounce(ev[1], ev[2], ev[3], ev[4])
            elif kind == "churn":
                self._on_churn(ev[1])
            elif kind == "recover":
                self._on_recover(ev[1])
            elif kind == "dead_letter":
                self._on_dead_letter(ev[1], ev[2], ev[3], ev[4], ev[5])
            elif kind == "reroute_retry":
                self._on_reroute_retry(ev[1], ev[2], ev[3], ev[4])
            elif kind == "heartbeat":
                self._on_heartbeat(ev[1], ev[2])
            elif kind == "sst_load":
                if ev[2] == self._session[ev[1]] and self._up[ev[1]]:
                    if self._health is not None:
                        self._refresh_health_digest(ev[1])
                    self.sst.push_load(ev[1], t)
                    self._post(
                        t + self.sst.push_interval_s, "sst_load", ev[1], ev[2]
                    )
            elif kind == "sst_cache":
                if ev[2] == self._session[ev[1]] and self._up[ev[1]]:
                    self.sst.push_cache(ev[1], t)
                    self._post(
                        t + self.sst.cache_push_interval_s,
                        "sst_cache", ev[1], ev[2],
                    )
            elif kind == "gossip":
                self._on_gossip(ev[1], ev[2])
            elif kind == "gossip_rx":
                # A cut drops cross-group gossip at delivery time: the
                # message was in flight when the link went down.  Rows are
                # full-state (newest version wins), so post-heal rounds
                # reconverge without replaying the lost diffs.
                if self._up[ev[1]] and self._reachable(ev[3], ev[1]):
                    self.sst.deliver(ev[1], ev[2], t)
            else:  # pragma: no cover
                raise AssertionError(f"unknown event {kind}")

    def _assemble_result(self) -> SimResult:
        """Fold the engine's hot-loop counters (plain ints — cheap to
        bump, free when tracing is off) and the per-worker memory ledgers
        into one named, labeled metrics registry.  The legacy SimResult
        fields read back out of it as derived views."""
        reg = MetricsRegistry()
        for w, m in enumerate(self.memories):
            ws = str(w)
            s = m.stats
            reg.counter("cache.hits", worker=ws).inc(s.hits)
            reg.counter("cache.misses", worker=ws).inc(s.misses)
            reg.counter("cache.evictions", worker=ws).inc(s.evictions)
            reg.counter("cache.bytes_fetched", worker=ws).inc(s.bytes_fetched)
            reg.counter("prefetch.bytes", worker=ws).inc(s.prefetch_bytes)
            reg.counter("prefetch.wasted_bytes", worker=ws).inc(
                s.prefetch_wasted_bytes
            )
            reg.counter("prefetch.useful", worker=ws).inc(s.prefetch_useful)
            reg.counter("churn.wasted_bytes", worker=ws).inc(
                s.churn_wasted_bytes
            )
            reg.gauge("prefetch.unused_resident_bytes", worker=ws).set(
                m.unused_prefetched_bytes()
            )
            reg.gauge("exec.busy_s", worker=ws).set(self._busy_time[w])
        reg.counter("sst.pushes").inc(self.sst.total_pushes)
        reg.counter("sched.adjustments").inc(self._adjustments)
        reg.counter("sched.bounces").inc(self._bounces)
        for kind, v in (
            ("crash", self._churn_crashes),
            ("join", self._churn_joins),
            ("drain", self._churn_drains),
            ("partition", self._churn_partitions),
            ("heal", self._churn_heals),
        ):
            reg.counter("churn.events", kind=kind).inc(v)
        reg.counter("churn.tasks_rescued").inc(self._tasks_rescued)
        reg.counter("churn.outputs_recovered").inc(self._outputs_recovered)
        reg.counter("net.transfers", scope="local").inc(self._net_local)
        reg.counter("net.transfers", scope="cross").inc(self._net_cross)
        reg.counter("net.transfers", scope="contended").inc(
            self._net.contended_transfers if self._net is not None else 0
        )
        reg.counter("exec.model_starts").inc(self._model_exec_starts)
        reg.counter("exec.lost_miss_attempts").inc(self._lost_miss_attempts)
        reg.counter("exec.demand_refetches").inc(self._demand_refetches)
        reg.gauge("sim.horizon_s").set(self._now)
        reg.counter("sim.events").inc(self._events)
        reg.counter("sim.jobs_completed").inc(len(self._records))
        if self._rec is not None:
            # Per-ring FIFO drop counters (satellite of the health plane):
            # counted inside the recorder since PR 5, now visible in the
            # metrics export so a drop-rate alert needs no trace access.
            for ring, (emitted, dropped) in self._rec.ring_stats().items():
                reg.counter("trace.emitted", ring=ring).inc(emitted)
                reg.counter("trace.dropped", ring=ring).inc(dropped)
        if self._health is not None:
            for kind in sorted(self._health.counts):
                reg.counter("health.events", kind=kind).inc(
                    self._health.counts[kind]
                )
        lat = reg.histogram(
            "job.latency_s",
            bounds=(0.1, 0.25, 0.5, 1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0),
        )
        for r in self._records:
            lat.observe(r.latency)
        return SimResult(
            scheduler=self.scheduler.name,
            records=self._records,
            horizon=self._now,
            n_workers=self.cluster.n_workers,
            busy_time=self._busy_time,
            workers_used=self._workers_used,
            metrics=reg,
            prefetch_stats=(
                self.prefetch_plane.stats
                if self.prefetch_plane is not None
                else None
            ),
            task_completions=dict(self._completions),
            event_log=self.event_log,
            trace=self._rec,
            health=self._health,
        )

    # -- network plane -----------------------------------------------------------
    def _xfer_time(
        self,
        nbytes: float,
        src: Optional[int] = None,
        dst: Optional[int] = None,
        register: bool = False,
    ) -> float:
        """Wire time for one transfer.  Without a topology (or with an
        unknown endpoint) this is the flat all-pairs table — the exact
        pre-topology cost, including its quirk of charging a transfer even
        when ``src == dst``.  With a topology it is the path cost, and
        ``register`` (bulk data: inputs, outputs, re-shipments) enrolls
        the flow on every crossed uplink so concurrent cross-rack
        transfers fair-share the spine; control messages ride unregistered
        and uncontended."""
        if self._net is None or src is None or dst is None:
            dur = self.cluster.network.transfer_time(nbytes)
            if register and self._rec is not None and src is not None:
                self._rec.emit(
                    self._now, "net.xfer", worker=src, dst=dst,
                    bytes=nbytes, dur=dur, scope="flat", share=1.0,
                )
            if register and self._health is not None and src is not None:
                self._health.on_transfer(
                    self._now, "flat", nbytes, 1.0, cross=False
                )
            return dur
        if src == dst:
            return 0.0
        if register:
            topo = self._net.topology
            local = topo.rack(src) == topo.rack(dst)
            if local:
                self._net_local += 1
            else:
                self._net_cross += 1
            dur = self._net.start_transfer(nbytes, src, dst, self._now)
            share = min(self._net.last_shares, default=1.0)
            if self._rec is not None:
                self._rec.emit(
                    self._now, "net.xfer", worker=src, dst=dst,
                    bytes=nbytes, dur=dur,
                    scope="local" if local else "cross",
                    share=share,
                )
            if self._health is not None:
                self._health.on_transfer(
                    self._now,
                    f"rack{topo.rack(src)}" if local
                    else f"spine.rack{topo.rack(src)}",
                    nbytes, share, cross=not local,
                )
            return dur
        return self._net.transfer_time(nbytes, src, dst, self._now)

    def _reachable(self, a: Optional[int], b: Optional[int]) -> bool:
        """Whether a message between two workers crosses an open cut.
        Unknown endpoints are assumed reachable (the flat pre-partition
        behaviour); groups are equivalence classes, so reachability is
        transitive among known endpoints."""
        if self._partition is None or a is None or b is None or a == b:
            return True
        return self._partition[a] == self._partition[b]

    # -- event handlers --------------------------------------------------------------
    def _serving(self, worker: int) -> bool:
        """Ground truth: the worker is up and accepting new work."""
        return self._up[worker] and not self._draining[worker]

    def _live_workers(self) -> List[int]:
        return [w for w in self.cluster.workers() if self._serving(w)]

    def _live_origin(self, preferred: int) -> Optional[int]:
        """Clients connect to a live front-end: the preferred origin if it
        serves, else the next serving worker by id (deterministic)."""
        n = self.cluster.n_workers
        for d in range(n):
            w = (preferred + d) % n
            if self._serving(w):
                return w
        return None

    def _on_arrival(self, job: Job, origin: int) -> None:
        live = self._live_origin(origin)
        if live is None:
            if not self._fleet_can_serve(None):
                raise ValueError(
                    "whole fleet is down with no scheduled joins; "
                    "arrivals can never be served"
                )
            # Whole fleet down/draining: the client retries shortly.
            self._post(self._now + 1.0, "arrival", job, origin)
            return
        origin = live
        js = _JobState(job, origin)
        self._open_jobs.append(js)
        if len(self._open_jobs) >= self._compact_open_at:
            self._open_jobs = [
                j for j in self._open_jobs if j.finish_time is None
            ]
            self._compact_open_at = max(64, 2 * len(self._open_jobs))
        if self._rec is not None:
            self._rec.emit(
                self._now, "job.arrive", job=job.job_id,
                dfg=job.dfg.name, origin=origin,
                n_tasks=len(job.dfg.tasks),
            )
        adfg = self.scheduler.plan(
            job, self._now, origin, self._sst_view(origin)
        )
        js.adfg = adfg
        if adfg is None:
            # JIT: entry tasks become ready immediately; pick workers now.
            js.adfg = ADFG(job)
            for tid in job.dfg.entry_tasks:
                self._jit_assign(js, tid, {"": origin}, {"": job.dfg.tasks[tid].input_bytes})
        else:
            if self.prefetch_plane is not None:
                # Plan → memory intents: every assigned worker learns which
                # models its future tasks need.  The origin worker (which
                # planned) knows immediately; remote workers learn after a
                # small control-message delay.
                per = self.prefetch_plane.plan_intents(
                    job, adfg, self.profiles, self._now
                )
                for w, intents in per.items():
                    if not self._reachable(origin, w):
                        continue  # best-effort control traffic; lost in the cut
                    delay = 0.0
                    if w != origin:
                        delay = self._xfer_time(
                            INTENT_WIRE_BYTES * len(intents), origin, w
                        )
                    self._post(self._now + delay, "intent", w, intents)
            for tid in job.dfg.entry_tasks:
                w = adfg[tid]
                delay = 0.0
                if w != origin:
                    delay = self._xfer_time(
                        job.dfg.tasks[tid].input_bytes, origin, w,
                        register=True,
                    )
                self._post_input(
                    self._now + delay, js, tid, "", w,
                    js.tasks[tid].generation, origin,
                )

    def _jit_assign(
        self,
        js: _JobState,
        task_id: str,
        input_locations: Dict[str, int],
        input_sizes: Dict[str, float],
    ) -> None:
        # Reader worker: where the largest input lives — shipping cost is
        # dominated by the biggest object, so the JIT decision is made (and
        # the SST replica read) there.
        reader_src = max(
            input_locations, key=lambda s: input_sizes.get(s, 0.0)
        )
        reader = input_locations[reader_src]
        if not self._up[reader]:
            # The largest input's holder died: read from any live worker
            # (the JIT decision has to be made somewhere).
            live = self._live_origin(reader)
            if live is not None:
                reader = live
        w = self.scheduler.select_worker_at_ready(
            js.job,
            task_id,
            self._now,
            self._sst_view(reader),
            input_locations,
            input_sizes,
            self_worker=reader,
        )
        assert js.adfg is not None
        js.adfg[task_id] = w
        # Ship all inputs to w (they travel as one batch: the slowest
        # path sets the arrival time).
        delay = 0.0
        for src, loc in input_locations.items():
            if loc != w:
                delay = max(
                    delay,
                    self._xfer_time(input_sizes[src], loc, w, register=True),
                )
        gen = js.tasks[task_id].generation
        for src, loc in input_locations.items():
            self._post_input(
                self._now + delay, js, task_id, src, w, gen, loc
            )

    def _on_input(
        self,
        js: _JobState,
        task_id: str,
        src: str,
        worker: int,
        gen: int,
        src_worker: Optional[int] = None,
    ) -> None:
        run = js.tasks[task_id]
        if gen != run.generation or run.finished is not None:
            return  # superseded by a re-route / re-execution
        reachable = self._reachable(src_worker, worker)
        if not self._serving(worker) or not reachable:
            if self._up[worker] and reachable:
                # Draining: the worker is alive and politely refuses, so
                # failover is immediate.
                self._reroute(js, task_id, from_worker=src_worker)
            else:
                # Dead — or alive but unreachable across a cut, which the
                # sender cannot tell apart: it only discovers the silence
                # after the connection timeout — the per-contact price
                # every membership-blind placement keeps paying all
                # through the outage, and an informed planner pays at
                # most once per lease window.
                timeout = (
                    self.lease.dead_letter_timeout_s
                    if self.lease is not None
                    else 0.0
                )
                self._post(
                    self._now + timeout, "dead_letter", js, task_id, src,
                    gen, src_worker,
                )
            return
        js.inputs_arrived[task_id].add(src)
        if not run.enqueued:
            run.enqueued = True
            run.worker = worker
            self._queues[worker].append((js, task_id))
            self._update_load(worker)
        self._dispatch(worker)

    def _on_fetch_done(self, worker: int, token: int) -> None:
        if token != self._fetch_token[worker]:
            return  # the transfer this event described was preempted
        mid = self._fetch_model[worker]
        spec = self._fetch_spec[worker]
        if self._rec is not None and mid is not None:
            self._rec.emit(
                self._now, "fetch.done", worker=worker, model=mid,
                spec=spec,
            )
        self._fetch_busy[worker] = False
        self._fetch_model[worker] = None
        self._fetch_spec[worker] = False
        self._fetch_preemptible[worker] = False
        if self._health is not None:
            self._health.fetch_state(worker, self._now, False)
        if spec and mid is not None:
            self.memories[worker].complete_prefetch(mid)
            if self.prefetch_plane is not None:
                self.prefetch_plane.complete_inflight(worker)
        self._publish_cache(worker)  # also refreshes the intent bitmap
        self._dispatch(worker)

    def _on_task_done(
        self, js: _JobState, task_id: str, worker: int, gen: int
    ) -> None:
        run = js.tasks[task_id]
        if gen != run.generation:
            return  # the worker died mid-run; the attempt is void
        run.finished = self._now
        run.session = self._session[worker]
        if self._rec is not None:
            self._rec.emit(
                self._now, "task.done", worker=worker,
                job=js.job.job_id, task=task_id, gen=gen,
            )
        key = (js.job.job_id, task_id)
        self._completions[key] = self._completions.get(key, 0) + 1
        task = js.job.dfg.tasks[task_id]
        if task.model_id is not None:
            self.memories[worker].end_execution(task.model_id)
            self._publish_cache(worker)
        self._busy_time[worker] += self._now - (run.started or self._now)
        if self._health is not None:
            self._health.task_done(
                worker, self._now,
                self._now - (run.started or self._now),
                self.profiles.runtime(task, worker),
            )
        self._gpu_busy[worker] = None
        self._update_load(worker)
        self._route_successors(js, task_id, worker)
        if js.done() and js.finish_time is None:
            js.finish_time = self._now
            self._records.append(
                JobRecord(
                    job_id=js.job.job_id,
                    dfg_name=js.job.dfg.name,
                    arrival=js.job.arrival_time,
                    finish=self._now,
                    lower_bound=js.job.lower_bound(),
                )
            )
            self._jobs_open -= 1
            if self._rec is not None:
                self._rec.emit(
                    self._now, "job.done", job=js.job.job_id,
                    latency=self._now - js.job.arrival_time,
                )
            if self._health is not None:
                self._health.job_done(
                    self._now, self._now - js.job.arrival_time
                )
        if (
            self._draining[worker]
            and self._gpu_busy[worker] is None
            and not self._fetch_busy[worker]
        ):
            self._complete_drain(worker)
            return
        self._dispatch(worker)

    # -- successor routing ---------------------------------------------------------
    def _route_successors(self, js: _JobState, task_id: str, worker: int) -> None:
        dfg = js.job.dfg
        task = dfg.tasks[task_id]
        adfg = js.adfg
        assert adfg is not None
        for succ in dfg.succs[task_id]:
            run_s = js.tasks[succ]
            if run_s.finished is not None or run_s.started is not None:
                # Already (re-)satisfied: a re-executed producer must not
                # disturb successors that consumed its original output.
                continue
            if self.scheduler.plans_at_arrival:
                if (
                    self.scheduler.needs_adjustment
                    and not dfg.is_join(succ)
                ):
                    new_w = self.scheduler.adjust(
                        js.job,
                        adfg,
                        succ,
                        self._now,
                        self._sst_view(worker),
                        worker,
                        task.output_bytes,
                    )
                    if new_w != adfg[succ]:
                        self._adjustments += 1
                        if self._rec is not None:
                            self._rec.emit(
                                self._now,
                                "sched.adjust",
                                worker=worker,
                                job=js.job.job_id,
                                task=succ,
                                frm=adfg[succ],
                                to=new_w,
                            )
                        if self.prefetch_plane is not None:
                            self._migrate_intent(
                                js, succ, adfg[succ], new_w, worker
                            )
                        adfg[succ] = new_w
                w = adfg[succ]
                delay = (
                    0.0
                    if w == worker
                    else self._xfer_time(
                        task.output_bytes, worker, w, register=True
                    )
                )
                self._post_input(
                    self._now + delay, js, succ, task_id, w,
                    run_s.generation, worker,
                )
            else:
                # JIT: assign when ALL predecessors have completed (and the
                # task was not already assigned by an earlier completion —
                # re-executed producers complete more than once).
                preds = dfg.preds[succ]
                if succ not in adfg.assignment and all(
                    js.tasks[p].finished is not None for p in preds
                ):
                    dead = [
                        p
                        for p in preds
                        if not self._output_alive(js.tasks[p])
                    ]
                    if dead:
                        # A producer's output departed with its worker:
                        # re-run it; its re-completion re-enters here.
                        for p in dead:
                            self._reexec_producer(js, p)
                        continue
                    locs = {p: js.tasks[p].worker for p in preds}
                    sizes = {p: dfg.tasks[p].output_bytes for p in preds}
                    self._jit_assign(js, succ, locs, sizes)  # type: ignore[arg-type]

    # -- dispatcher (§3.2) ------------------------------------------------------------
    def _dispatch(self, worker: int) -> None:
        if not self._serving(worker):
            return
        if self._gpu_busy[worker] is not None:
            # Still try to start a model fetch for a queued task.
            self._maybe_prefetch(worker)
            return
        queue = self._queues[worker]
        for idx, (js, tid) in enumerate(queue):
            if not js.inputs_ready(tid) or js.tasks[tid].bouncing:
                continue
            task = js.job.dfg.tasks[tid]
            mem = self.memories[worker]
            mid = task.model_id
            if mid is not None:
                inflight = (
                    self._fetch_busy[worker]
                    and self._fetch_model[worker] == mid
                )
                if inflight and self._fetch_preemptible[worker]:
                    # A queued task demands the model on the pipe: the
                    # speculative prefetch becomes a demand fetch.
                    self._promote_prefetch(worker, js, tid)
                if not mem.has(mid) or inflight:
                    if not inflight and not js.tasks[tid].fetching:
                        self._request_demand_fetch(worker, js, tid)
                    continue  # leave on queue, proceed to next (paper §3.2)
            # Start execution.
            queue.pop(idx)
            run = js.tasks[tid]
            run.started = self._now
            if self._rec is not None:
                self._rec.emit(
                    self._now, "task.start", worker=worker,
                    job=js.job.job_id, task=tid, gen=run.generation,
                    model=-1 if task.model_id is None else task.model_id,
                    miss=run.was_miss,
                )
            if task.model_id is not None:
                self._model_exec_starts += 1
                if not run.was_miss:
                    mem.stats.hits += 1  # model was already resident
                upcoming = [
                    js2.job.dfg.tasks[t2].model_id for js2, t2 in queue
                ]
                mem.begin_execution(task.model_id, upcoming)
                if self.prefetch_plane is not None:
                    self.prefetch_plane.consume(
                        worker, js.job.job_id, tid
                    )
                self._publish_cache(worker)
            self._gpu_busy[worker] = (js, tid)
            self._workers_used.add(worker)
            rt = self._noisy(self.profiles.runtime(task, worker))
            self._post(
                self._now + rt, "task_done", js, tid, worker, run.generation
            )
            self._update_load(worker)
            break
        self._maybe_prefetch(worker)

    def _maybe_prefetch(self, worker: int) -> None:
        """Keep the fetch pipe busy: demand fetches for queued tasks first;
        with the prefetch plane enabled, speculative fetches from the
        intent queue fill the idle pipe (demand preempts prefetch)."""
        if not self._serving(worker):
            return
        if not self._fetch_busy[worker] or self._fetch_preemptible[worker]:
            for js, tid in self._queues[worker]:
                task = js.job.dfg.tasks[tid]
                mid = task.model_id
                if (
                    mid is None
                    or js.tasks[tid].fetching
                    or js.tasks[tid].bouncing
                    or not js.inputs_ready(tid)
                ):
                    continue
                if (
                    self._fetch_busy[worker]
                    and self._fetch_model[worker] == mid
                ):
                    if self._fetch_preemptible[worker]:
                        self._promote_prefetch(worker, js, tid)
                    continue  # transfer already underway for this model
                if not self.memories[worker].has(mid):
                    self._request_demand_fetch(worker, js, tid)
                    return
        if self.prefetch_plane is not None and not self._fetch_busy[worker]:
            self._start_speculative_fetch(worker)

    def _request_demand_fetch(self, worker: int, js: _JobState, tid: str) -> None:
        if self._fetch_busy[worker]:
            if not self._fetch_preemptible[worker]:
                return  # pipe owned by a demand (or promoted) fetch
            # Demand preempts prefetch: abort the speculative transfer.
            if self.prefetch_plane is not None:
                self.prefetch_plane.preempt_inflight(worker, requeue=True)
            self._abort_spec_fetch(worker)
        self._start_fetch(worker, js, tid)

    def _start_fetch(self, worker: int, js: _JobState, tid: str) -> None:
        task = js.job.dfg.tasks[tid]
        assert task.model_id is not None
        mem = self.memories[worker]
        if not mem.can_host(task.model_id):
            # Capacity-blind scheduler put the task on a GPU that can
            # never execute its model: the dispatcher rejects and
            # re-routes it (handled as an event so the queue is not
            # mutated mid-scan).
            js.tasks[tid].bouncing = True
            self._post(
                self._now, "bounce", js, tid, worker,
                js.tasks[tid].generation,
            )
            return
        upcoming = [
            js2.job.dfg.tasks[t2].model_id for js2, t2 in self._queues[worker]
        ]
        res = mem.ensure(task.model_id, upcoming)
        if res is None:
            return  # cannot evict enough right now; retry on next dispatch
        fetch_s, _ = res
        # Pin for the duration of the fetch so another task's eviction
        # cannot displace the in-flight model; released by the bookkeeping
        # event at fetch completion (execution re-pins at start).
        mem.pin(task.model_id)
        js.tasks[tid].fetching = True
        if js.tasks[tid].was_miss:
            # The earlier fetch's model was evicted before the task could
            # start: a second demand miss against the same start.
            self._demand_refetches += 1
        js.tasks[tid].was_miss = True
        self._fetch_busy[worker] = True
        self._fetch_model[worker] = task.model_id
        self._fetch_spec[worker] = False
        self._fetch_preemptible[worker] = False
        self._fetch_started[worker] = self._now
        self._fetch_ends[worker] = self._now + fetch_s
        if self._health is not None:
            self._health.fetch_state(worker, self._now, True)
        if self._rec is not None:
            self._rec.emit(
                self._now, "fetch.start", worker=worker,
                model=task.model_id, fetch_kind="demand",
                bytes=mem.cached_size(task.model_id), dur=fetch_s,
                job=js.job.job_id, task=tid,
            )
        if self.prefetch_plane is not None:
            # Demand took over this task's model staging; its intent (if
            # still queued) is spent.
            self.prefetch_plane.consume(worker, js.job.job_id, tid)
        self._publish_cache(worker)  # also refreshes the intent bitmap
        self._post(
            self._now + fetch_s, "task_fetch_bookkeep", js, tid, worker,
            js.tasks[tid].generation,
        )
        self._post(
            self._now + fetch_s, "fetch_done", worker,
            self._fetch_token[worker],
        )

    # -- speculative prefetch (core/prefetch.py) ---------------------------------
    def _start_speculative_fetch(self, worker: int) -> None:
        plane = self.prefetch_plane
        assert plane is not None
        if plane.queue_depth(worker) == 0:
            return
        mem = self.memories[worker]
        peer_bits = 0
        if self._use_packed:
            # A peer this worker's view marks DEAD is no anti-herd
            # evidence: its frozen row may still advertise the model, but
            # nobody can be routed there.
            pv = self.sst.view_arrays(worker, self._now)
            mask = ~pv.dead
            mask[worker] = False
            bits = pv.bitmap[mask] | pv.intent[mask]
            if bits.size:
                peer_bits = int(np.bitwise_or.reduce(bits))
        else:
            for w2, row in enumerate(self.sst.view(worker, self._now)):
                if w2 != worker and row.liveness != DEAD:
                    peer_bits |= row.cache_bitmap | row.intent_bitmap
        intent, retry_at = plane.next_intent(
            worker, self._now, mem.has, peer_bits
        )
        if intent is None:
            self._schedule_poke(worker, retry_at)
            return
        res = mem.begin_prefetch(
            intent.model_id,
            upcoming_model_ids=[
                js.job.dfg.tasks[t].model_id for js, t in self._queues[worker]
            ],
            allow_evict=plane.config.evict_for_prefetch,
        )
        if res is None:
            # No room right now: park the intent and retry shortly.
            until = self._now + max(0.1, plane.config.herd_backoff_s)
            plane.stall_inflight(worker, until)
            self._schedule_poke(worker, until)
            return
        fetch_s, _ = res
        self._fetch_busy[worker] = True
        self._fetch_model[worker] = intent.model_id
        self._fetch_spec[worker] = True
        self._fetch_preemptible[worker] = True
        self._fetch_started[worker] = self._now
        self._fetch_ends[worker] = self._now + fetch_s
        if self._health is not None:
            self._health.fetch_state(worker, self._now, True)
        if self._rec is not None:
            self._rec.emit(
                self._now, "fetch.start", worker=worker,
                model=intent.model_id, fetch_kind="prefetch",
                bytes=mem.cached_size(intent.model_id), dur=fetch_s,
            )
        self._post(
            self._now + fetch_s, "fetch_done", worker,
            self._fetch_token[worker],
        )
        self._publish_cache(worker)  # also refreshes the intent bitmap

    def _promote_prefetch(self, worker: int, js: _JobState, tid: str) -> None:
        if self._rec is not None:
            self._rec.emit(
                self._now, "fetch.promote", worker=worker,
                model=self._fetch_model[worker], job=js.job.job_id,
                task=tid,
            )
        self._fetch_preemptible[worker] = False
        if self.prefetch_plane is not None:
            self.prefetch_plane.promote_inflight(worker)
        # The demanding task still waits for (the rest of) the transfer,
        # so account it as a demand miss — hit rates stay comparable with
        # the plane off; only fetches that *complete* before the task
        # needs the model convert to hits.
        run = js.tasks[tid]
        if not run.was_miss:
            run.was_miss = True
            self.memories[worker].stats.misses += 1

    def _abort_spec_fetch(self, worker: int) -> None:
        """Tear down the in-flight speculative transfer (the plane-side
        intent bookkeeping is the caller's job)."""
        mid = self._fetch_model[worker]
        assert mid is not None and self._fetch_spec[worker]
        self._fetch_token[worker] += 1  # invalidate the posted completion
        dur = self._fetch_ends[worker] - self._fetch_started[worker]
        frac = 0.0 if dur <= 0 else (self._now - self._fetch_started[worker]) / dur
        if self._rec is not None:
            self._rec.emit(
                self._now, "fetch.abort", worker=worker, model=mid,
                frac=frac, churn=False,
            )
        self.memories[worker].abort_prefetch(mid, frac)
        self._fetch_busy[worker] = False
        self._fetch_model[worker] = None
        self._fetch_spec[worker] = False
        self._fetch_preemptible[worker] = False
        if self._health is not None:
            self._health.fetch_state(worker, self._now, False)
        self._publish_cache(worker)  # also refreshes the intent bitmap

    def _schedule_poke(self, worker: int, at: Optional[float]) -> None:
        if at is None:
            return
        if self._poke_at[worker] is not None and self._poke_at[worker] <= at:
            return
        self._poke_at[worker] = at
        self._post(at, "prefetch_poke", worker)

    def _on_prefetch_poke(self, worker: int, t: float) -> None:
        if self._poke_at[worker] is not None and self._poke_at[worker] <= t:
            self._poke_at[worker] = None
        self._maybe_prefetch(worker)

    def _on_intent(self, worker: int, intents) -> None:
        assert self.prefetch_plane is not None
        if not self._serving(worker):
            return  # control message reached a corpse; dropped on the floor
        if self._rec is not None:
            self._rec.emit(
                self._now, "intent.admit", worker=worker, n=len(intents)
            )
        self.prefetch_plane.admit(worker, intents, self._now)
        self._publish_intent(worker)
        self._maybe_prefetch(worker)

    def _on_intent_cancel(self, worker: int, js: _JobState, task_id: str) -> None:
        assert self.prefetch_plane is not None
        if not self._up[worker]:
            return  # the plane state for this worker was already dropped
        if self._rec is not None:
            self._rec.emit(
                self._now, "intent.cancel", worker=worker,
                job=js.job.job_id, task=task_id,
            )
        aborted = self.prefetch_plane.cancel(
            worker, js.job.job_id, task_id, migrated=True
        )
        if (
            aborted is not None
            and self._fetch_busy[worker]
            and self._fetch_preemptible[worker]
            and self._fetch_model[worker] == aborted.model_id
        ):
            self._abort_spec_fetch(worker)
            self._maybe_prefetch(worker)
        else:
            self._publish_intent(worker)

    def _on_fetch_bookkeep(
        self, js: _JobState, tid: str, worker: int, gen: int
    ) -> None:
        run = js.tasks[tid]
        if gen != run.generation:
            return  # fetch torn down by churn; pin already released
        run.fetching = False
        task = js.job.dfg.tasks[tid]
        if task.model_id is not None:
            self.memories[worker].unpin(task.model_id)

    def _on_bounce(self, js: _JobState, tid: str, worker: int, gen: int) -> None:
        """Re-route a task whose assigned GPU can never host its model:
        ship it (and its already-arrived inputs) to the least-loaded
        *serving* worker with enough memory."""
        run = js.tasks[tid]
        if gen != run.generation:
            return  # superseded by churn recovery
        task = js.job.dfg.tasks[tid]
        assert task.model_id is not None
        feasible = [
            w
            for w in self.cluster.workers()
            if self._serving(w) and self.memories[w].can_host(task.model_id)
        ]
        if not feasible:
            if not self._fleet_can_serve(task.model_id):
                raise ValueError(
                    f"model {task.model_id} fits no current or future "
                    f"fleet member; the job can never finish"
                )
            # Capable workers exist (or will rejoin): hold the task and
            # retry once capacity is restored.
            self._post(self._now + 0.5, "bounce", js, tid, worker, gen)
            return
        self._bounces += 1
        if self._use_packed:
            ftcol = self.sst.view_arrays(worker, self._now).ft
            target = min(
                feasible,
                key=lambda w: (max(self._now, float(ftcol[w])), w),
            )
        else:
            sst = self.sst.view(worker, self._now)
            target = min(
                feasible,
                key=lambda w: (max(self._now, sst[w].ft_estimate_s), w),
            )
        run.bouncing = False
        self._queues[worker] = [
            (j, t) for j, t in self._queues[worker] if (j, t) != (js, tid)
        ]
        run.enqueued = False
        run.worker = None
        run.generation += 1
        assert js.adfg is not None
        js.adfg[tid] = target
        dfg = js.job.dfg
        delay = 0.0
        srcs = list(js.inputs_arrived[tid])
        for src in srcs:
            nbytes = (
                task.input_bytes if src == "" else dfg.tasks[src].output_bytes
            )
            delay = max(
                delay, self._xfer_time(nbytes, worker, target, register=True)
            )
        js.inputs_arrived[tid] = set()
        if self._rec is not None:
            self._rec.emit(
                self._now, "task.bounce", worker=worker,
                job=js.job.job_id, task=tid, to=target,
                gen=run.generation,
            )
        for src in srcs:
            self._post_input(
                self._now + delay, js, tid, src, target,
                run.generation, worker,
            )
        self._update_load(worker)
        self._dispatch(worker)

    def _migrate_intent(
        self,
        js: _JobState,
        task_id: str,
        old_w: int,
        new_w: int,
        sender: Optional[int] = None,
    ) -> None:
        """Alg. 2 moved a task: cancel the prefetch intent on the planned
        worker (a control message) and re-issue it on the new one (riding
        the input transfer that is about to ship there).  Control traffic
        crossing an open cut is lost."""
        assert self.prefetch_plane is not None
        if self._reachable(sender, old_w):
            ctrl = self._xfer_time(INTENT_WIRE_BYTES, sender, old_w)
            self._post(self._now + ctrl, "intent_cancel", old_w, js, task_id)
        if not self._reachable(sender, new_w):
            return
        intent = self.prefetch_plane.make_intent(
            js.job, task_id, new_w, self._now
        )
        if intent is not None:
            ctrl = self._xfer_time(INTENT_WIRE_BYTES, sender, new_w)
            self._post(self._now + ctrl, "intent", new_w, [intent])

    # -- fleet churn: crash / drain / join (membership plane) ----------------------
    def _on_churn(self, ev: ChurnEvent) -> None:
        if ev.kind == CRASH:
            self._do_crash(ev.worker)
        elif ev.kind == JOIN:
            self._do_join(ev.worker)
        elif ev.kind == DRAIN:
            self._do_drain(ev.worker)
        elif ev.kind == PARTITION:
            self._do_partition(ev)
        elif ev.kind == HEAL:
            self._do_heal()

    def _do_partition(self, ev: ChurnEvent) -> None:
        """The interconnect splits into ``ev.groups``: every worker stays
        up and keeps executing, but bulk and control messages between
        groups are lost (bulk ones fail over through the dead-letter
        timeout, exactly like sends to a corpse — the sender cannot tell
        silence from death).  Nobody's epoch bumps: no process died, so
        healed rows must win replica merges on version alone.  Workers in
        no listed group are fully isolated (unique singleton groups)."""
        assert ev.groups is not None
        self._churn_partitions += 1
        if self._rec is not None:
            self._rec.emit(
                self._now, "churn.partition",
                groups=repr([sorted(g) for g in ev.groups]),
            )
        # Negative ids for unlisted workers so they can never collide with
        # a group index.
        part = [-(w + 1) for w in range(self.cluster.n_workers)]
        for gi, group in enumerate(ev.groups):
            for w in group:
                part[w] = gi
        self._partition = part
        self.sst.set_partition(part, self._now)

    def _do_heal(self) -> None:
        """The cut closes.  Nothing is replayed: gossip reconverges on its
        own (full-row newest-version merges), and work stranded behind the
        cut is re-driven by the retry/dead-letter machinery."""
        if self._partition is None:
            return
        self._churn_heals += 1
        if self._rec is not None:
            self._rec.emit(self._now, "churn.heal")
        self._partition = None
        self.sst.set_partition(None, self._now)

    def _do_crash(self, w: int) -> None:
        """The worker vanishes: running task, queue, in-flight fetch, cache
        contents, and gossip replica are all lost.  Nothing is announced —
        peers only find out when the heartbeat lease expires in their own
        views, so the work it held is only *recovered* after the detection
        delay (no oracle); until then peers may keep routing work here,
        which the dead-letter path in ``_on_input`` fails over after the
        connection timeout."""
        if not self._up[w]:
            return
        self._churn_crashes += 1
        if self._rec is not None:
            self._rec.emit(self._now, "churn.crash", worker=w)
        self._up[w] = False
        self._draining[w] = False
        self._session[w] += 1  # voids the gossip/heartbeat/publish chains
        self._abort_worker_fetch(w, churn=True)
        if self.prefetch_plane is not None:
            for intent in self.prefetch_plane.drop_worker(w):
                self._orphaned_intents[intent.key()] = intent
        self.memories[w].reset(graceful=False)
        self._poke_at[w] = None
        self._gpu_busy[w] = None
        self._queues[w] = []
        # The attempts physically ON the worker die with it *now*: their
        # generations bump immediately so the already-posted task_done /
        # bookkeep / bounce events are void (no ghost completions from a
        # corpse).  Re-placement still waits for detection; work whose
        # inputs are merely en route stays un-reset so the sender's
        # (faster) dead-letter timeout can fail it over first.
        snapshot = self._strand_snapshot(w)
        delay = (
            self.lease.detection_delay_s if self.lease is not None else 0.0
        )
        self._post(self._now + delay, "recover", (w, snapshot))

    def _do_drain(self, w: int) -> None:
        """Graceful departure: advertise ``draining`` (peers stop placing
        work the moment the flag reaches their view), re-route queued
        tasks, abort the in-flight fetch, finish the running task, then
        leave."""
        if not self._serving(w):
            return
        self._churn_drains += 1
        if self._rec is not None:
            self._rec.emit(self._now, "churn.drain", worker=w)
        self._draining[w] = True
        self.sst.set_draining(w, True, self._now)
        self._abort_worker_fetch(w, churn=True)
        if self.prefetch_plane is not None:
            for intent in self.prefetch_plane.drop_worker(w):
                self._orphaned_intents[intent.key()] = intent
            self._publish_intent(w)
        queued = list(self._queues[w])
        self._queues[w] = []
        for js, tid in queued:
            self._reroute(js, tid, from_worker=w)
        self._update_load(w)
        if self._gpu_busy[w] is None and not self._fetch_busy[w]:
            self._complete_drain(w)

    def _complete_drain(self, w: int) -> None:
        """The drained worker's running task finished: flush still-needed
        task outputs to an heir (graceful departure has the time to
        upload its state — that is the point of draining over crashing),
        then leave the fleet."""
        heir = None
        n = self.cluster.n_workers
        for d in range(n):
            cand = (w + 1 + d) % n
            if self._serving(cand) and self._reachable(w, cand):
                heir = cand  # next serving worker the drainer can reach
                break
        if heir is not None:
            self._open_jobs = [
                js for js in self._open_jobs if js.finish_time is None
            ]
            for js in self._open_jobs:
                for tid, run in js.tasks.items():
                    if (
                        run.finished is not None
                        and run.worker == w
                        and any(
                            js.tasks[s].finished is None
                            for s in js.job.dfg.succs[tid]
                        )
                    ):
                        run.worker = heir
                        run.session = self._session[heir]
        self._up[w] = False
        self._session[w] += 1
        self.memories[w].reset(graceful=True)
        self._poke_at[w] = None

    def _do_join(self, w: int) -> None:
        """The worker (re)enters with a cold cache and a fresh gossip
        incarnation; its SST view is rebuilt by anti-entropy full-sync
        from the first peers to contact it (``GossipPlane.join``).  A
        join that lands while a drain is still finishing its running task
        cancels the drain (the worker never actually left) instead of
        being dropped — a drop would remove the worker forever."""
        if self._up[w]:
            if self._draining[w]:
                self._draining[w] = False
                self.sst.set_draining(w, False, self._now)
                self._churn_joins += 1
                self._dispatch(w)
            return
        self._churn_joins += 1
        if self._rec is not None:
            self._rec.emit(self._now, "churn.join", worker=w)
        self._up[w] = True
        self._draining[w] = False
        self._session[w] += 1
        s = self._session[w]
        self.sst.join(w, self._now)
        self.sst.update_cache(w, 0, self.cluster.gpu_capacity(w), self._now)
        if self.lease is not None:
            self.sst.heartbeat(w, self._now)
            self._post(
                self._now + self.lease.heartbeat_period_s, "heartbeat", w, s
            )
        self._update_load(w)
        if self.gossip is not None:
            self._post(self._now + self.gossip.period_s, "gossip", w, s)
        else:
            self._post(self._now + self.sst.push_interval_s, "sst_load", w, s)
            self._post(
                self._now + self.sst.cache_push_interval_s, "sst_cache", w, s
            )

    def _abort_worker_fetch(self, w: int, churn: bool = False) -> None:
        """Tear down whatever is on the fetch pipe: speculative transfers
        go through the wasted-prefetch ledger, demand transfers release
        the owning (now dead/re-routed) task's fetch-pin and charge the
        partial bytes as churn waste."""
        if not self._fetch_busy[w]:
            return
        mid = self._fetch_model[w]
        assert mid is not None
        dur = self._fetch_ends[w] - self._fetch_started[w]
        frac = (
            0.0
            if dur <= 0
            else min(1.0, (self._now - self._fetch_started[w]) / dur)
        )
        if self._rec is not None:
            self._rec.emit(
                self._now, "fetch.abort", worker=w, model=mid,
                frac=frac, churn=churn,
            )
        mem = self.memories[w]
        if self._fetch_spec[w]:
            mem.abort_prefetch(mid, frac)
            if churn:
                mem.stats.churn_wasted_bytes += mem.cached_size(mid) * frac
        else:
            mem.abort_fetch(mid, frac)
        self._fetch_token[w] += 1  # invalidate the posted completion
        self._fetch_busy[w] = False
        self._fetch_model[w] = None
        self._fetch_spec[w] = False
        self._fetch_preemptible[w] = False
        if self._health is not None:
            self._health.fetch_state(w, self._now, False)

    # -- task recovery --------------------------------------------------------------
    def _strand_snapshot(
        self, w: int
    ) -> List[Tuple[_JobState, str, int, bool]]:
        """Everything the crashing worker strands, as (job, task,
        generation, needs_reset) tuples.

        Attempts physically on the worker (enqueued / fetching / running)
        are **reset here and now** — the crash voids them, so a pending
        ``task_done`` can never complete a task on a corpse — and only
        their re-placement waits for detection (``needs_reset=False``).
        Floating tasks whose inputs are en route, and finished producers
        whose outputs lived here, are left untouched until detection
        (``needs_reset=True``): the former so the sender's faster
        dead-letter timeout can fail them over first, the latter because
        whether a dead output is still *needed* is best judged at
        detection time.  Any pair whose generation moved on in between is
        skipped by the recovery event."""
        self._open_jobs = [
            js for js in self._open_jobs if js.finish_time is None
        ]
        snap: List[Tuple[_JobState, str, int, bool]] = []
        for js in self._open_jobs:
            assign = js.adfg.assignment if js.adfg is not None else {}
            for tid, run in js.tasks.items():
                if run.worker == w and run.finished is None:
                    self._reset_task(js, tid)
                    snap.append((js, tid, run.generation, False))
                elif run.worker == w or (
                    run.finished is None
                    and run.worker is None
                    and assign.get(tid) == w
                ):
                    snap.append((js, tid, run.generation, True))
        return snap

    def _on_recover(
        self, payload: Tuple[int, List[Tuple[_JobState, str, int]]]
    ) -> None:
        """Detection-time recovery: re-route the stranded unfinished work
        and re-run finished producers whose outputs died and are *still*
        needed (transitively: a producer is needed if some successor is
        unfinished and missing its output, or is itself being
        recovered)."""
        w, snapshot = payload
        by_job: Dict[int, Tuple[_JobState, List[Tuple[str, int, bool]]]] = {}
        for js, tid, gen, needs_reset in snapshot:
            by_job.setdefault(js.job.job_id, (js, []))[1].append(
                (tid, gen, needs_reset)
            )
        for js, pairs in by_job.values():
            if js.finish_time is not None:
                continue
            assign = js.adfg.assignment if js.adfg is not None else {}
            lost: List[str] = []          # crash-reset at crash time
            floating: List[str] = []      # still stranded, reset here
            dead_outputs = set()
            for tid, gen, needs_reset in pairs:
                run = js.tasks[tid]
                if run.generation != gen:
                    continue  # something already failed this attempt over
                if not needs_reset:
                    if (
                        run.enqueued
                        or run.started is not None
                        or run.finished is not None
                    ):
                        # Alg. 2 already re-staged the crash-voided
                        # attempt (same generation) on a live worker
                        # during the detection window; re-shipping would
                        # split its inputs across two targets.
                        continue
                    lost.append(tid)
                elif run.finished is not None:
                    dead_outputs.add(tid)
                elif run.worker == w or (
                    run.worker is None and assign.get(tid) == w
                ):
                    floating.append(tid)
                # else: Alg. 2 adjusted the still-floating task off the
                # corpse in the meantime; it is no longer stranded.
            dfg = js.job.dfg
            needed = set(lost) | set(floating)
            reexec: List[str] = []
            for tid in reversed(dfg.topo_order):
                if tid not in dead_outputs:
                    continue
                for s in dfg.succs[tid]:
                    rs = js.tasks[s]
                    if s in needed or (
                        rs.finished is None
                        and tid not in js.inputs_arrived[s]
                    ):
                        reexec.append(tid)
                        needed.add(tid)
                        break
            # Reset what was not already voided at crash time (generation
            # bumps void in-flight events), then ship producers before
            # consumers.
            for tid in reexec + floating:
                self._reset_task(js, tid)
            order = {t: i for i, t in enumerate(dfg.topo_order)}
            for tid in sorted(
                reexec + floating + lost, key=lambda t: order[t]
            ):
                self._ship_inputs(js, tid)

    def _on_dead_letter(
        self,
        js: _JobState,
        tid: str,
        src: str,
        gen: int,
        src_worker: Optional[int] = None,
    ) -> None:
        """The connection timeout on one input shipment to a dead (or
        unreachable) worker fired.  Three same-generation outcomes exist,
        because detection-time recovery re-stages crash-voided attempts
        without a fresh generation while stale-assignment shipments may
        still be in flight:

        * the attempt has started (or this input already landed via a
          duplicate shipment) — the timed-out copy is moot;
        * the attempt is enqueued on a (necessarily serving) worker the
          sender can reach, but this input is genuinely missing — re-ship
          just this input there, or the task waits forever;
        * the attempt is still unstaged (or re-staged somewhere the
          sender cannot reach) — full dead-letter failover on the
          sender's side of the cut."""
        run = js.tasks[tid]
        if gen != run.generation or run.finished is not None:
            return
        if run.started is not None or src in js.inputs_arrived[tid]:
            return  # inputs complete / duplicate shipment
        if (
            run.enqueued
            and run.worker is not None
            and self._reachable(src_worker, run.worker)
        ):
            task = js.job.dfg.tasks[tid]
            nbytes = (
                task.input_bytes
                if src == ""
                else js.job.dfg.tasks[src].output_bytes
            )
            delay = self._xfer_time(
                nbytes, src_worker, run.worker, register=True
            )
            self._post_input(
                self._now + delay, js, tid, src, run.worker, gen,
                src_worker,
            )
            return
        if self._rec is not None:
            self._rec.emit(
                self._now, "task.dead_letter",
                worker=src_worker if src_worker is not None else -1,
                job=js.job.job_id, task=tid, src=src, gen=gen,
            )
        self._reroute(js, tid, from_worker=src_worker)

    def _reset_task(self, js: _JobState, tid: str) -> None:
        run = js.tasks[tid]
        if run.enqueued and run.worker is not None:
            # Pull the attempt out of its queue: a stale entry would wake
            # up when the *new* attempt's inputs refill inputs_arrived and
            # start the task twice.
            self._queues[run.worker] = [
                (j, t)
                for j, t in self._queues[run.worker]
                if (j, t) != (js, tid)
            ]
        if self._rec is not None:
            self._rec.emit(
                self._now, "task.recover",
                worker=run.worker if run.worker is not None else -1,
                job=js.job.job_id, task=tid, gen=run.generation + 1,
                had_output=run.finished is not None,
            )
        if run.finished is not None:
            self._outputs_recovered += 1
        else:
            self._tasks_rescued += 1
            if run.was_miss and run.started is None:
                # The fetch's demand miss was charged but the attempt
                # never started: the chaos checker's hit/miss balance
                # needs the orphaned charge on the books.
                self._lost_miss_attempts += 1
            if run.started is not None and run.worker is not None:
                # GPU cycles the lost attempt burned still happened.
                self._busy_time[run.worker] += self._now - run.started
        run.generation += 1
        run.finished = None
        run.started = None
        run.enqueued = False
        run.fetching = False
        run.bouncing = False
        run.was_miss = False
        run.worker = None
        js.inputs_arrived[tid] = set()
        if not self.scheduler.plans_at_arrival and js.adfg is not None:
            # JIT re-assigns when the last producer (re-)completes.
            js.adfg.assignment.pop(tid, None)

    def _reroute(
        self, js: _JobState, tid: str, from_worker: Optional[int] = None
    ) -> None:
        """Dead-letter recovery for one task: void the old attempt and
        re-stage its inputs on a serving worker — on ``from_worker``'s
        side of an open cut, when the failover is partition-driven."""
        self._reset_task(js, tid)
        self._ship_inputs(js, tid, from_worker=from_worker)

    def _output_alive(self, run: _TaskRun) -> bool:
        """Whether a finished task's output can still be read: its worker
        must be up *in the incarnation that produced it* — a crash wipes
        outputs even when the worker rejoins before anyone re-reads."""
        return (
            run.worker is not None
            and self._up[run.worker]
            and run.session == self._session[run.worker]
        )

    def _reexec_producer(
        self, js: _JobState, tid: str, from_worker: Optional[int] = None
    ) -> None:
        run = js.tasks[tid]
        if run.finished is None:
            return  # already being recovered; its completion will ship
        self._reset_task(js, tid)
        self._ship_inputs(js, tid, from_worker=from_worker)

    def _fleet_can_serve(self, model_id: Optional[int]) -> bool:
        """Whether a worker able to host ``model_id`` is serving now or
        will ever (re)join per the churn schedule.  Retry loops consult
        this so a permanently lost capability raises a diagnosable error
        instead of re-posting retries forever (``run()`` would never
        return: the stuck job keeps the periodic chains alive)."""
        for w in self.cluster.workers():
            if model_id is not None and not self.memories[w].can_host(
                model_id
            ):
                continue
            if self._serving(w):
                return True
        for ev in self.churn:
            if ev.kind == JOIN and ev.time >= self._now:
                if model_id is None or self.memories[ev.worker].can_host(
                    model_id
                ):
                    return True
        return False

    def _recovery_inputs(
        self, js: _JobState, tid: str
    ) -> Tuple[Dict[str, int], Dict[str, float]]:
        """Where the task's already-available inputs live (and their
        sizes): surviving finished-producer outputs, or the job's entry
        payload at a live origin.  Inputs whose producers are being
        re-executed are omitted — their future location is unknown."""
        dfg = js.job.dfg
        locs: Dict[str, int] = {}
        sizes: Dict[str, float] = {}
        preds = dfg.preds[tid]
        if not preds:
            origin = self._live_origin(js.origin)
            if origin is not None:
                locs[""] = origin
                sizes[""] = dfg.tasks[tid].input_bytes
            return locs, sizes
        for p in preds:
            rp = js.tasks[p]
            if (
                rp.finished is not None
                and rp.worker is not None
                and self._output_alive(rp)
            ):
                locs[p] = rp.worker
                sizes[p] = dfg.tasks[p].output_bytes
        return locs, sizes

    def _recovery_target(
        self, js: _JobState, tid: str, from_worker: Optional[int] = None
    ) -> Optional[int]:
        """Serving worker to re-home a stranded task on, restricted to
        ``from_worker``'s side of an open cut.  The scheduler's
        ``select_recovery_worker`` hook prices candidates with the full
        Navigator placement cost (queue drain, concrete input paths,
        Eq. 2 model cost, runtime, membership risk); schedulers without
        the hook fall back to the dispatcher-level greedy rule —
        earliest start pricing the model fetch from the reader replica's
        published cache bitmaps.  Cache awareness matters under churn: a
        crash of a cache-hot worker would otherwise dump its whole
        working set onto whichever heir happened to be least loaded,
        serializing a refetch storm on one PCIe pipe."""
        task = js.job.dfg.tasks[tid]
        mid = task.model_id
        cands = [
            w
            for w in self._live_workers()
            if (mid is None or self.memories[w].can_host(mid))
            and self._reachable(from_worker, w)
        ]
        if not cands:
            return None
        reader = None
        if from_worker is not None and self._serving(from_worker):
            reader = from_worker  # the failing-over sender reads its own view
        if reader is None:
            reader = self._live_origin(js.origin)
        if reader is None or not self._reachable(from_worker, reader):
            reader = cands[0]
        sstv = self.sst.view(reader, self._now)
        locs, sizes = self._recovery_inputs(js, tid)
        choice = self.scheduler.select_recovery_worker(
            js.job, tid, self._now, sstv, locs, sizes, cands
        )
        if choice is not None:
            return choice

        def est(w: int) -> Tuple[float, int]:
            start = max(self._now, sstv[w].ft_estimate_s)
            if mid is not None and not (sstv[w].cache_bitmap >> mid) & 1:
                start += self.profiles.td_model(mid)
            return (start, w)

        return min(cands, key=est)

    def _ship_inputs(
        self, js: _JobState, tid: str, from_worker: Optional[int] = None
    ) -> None:
        """(Re-)stage a recovered task: re-run producers whose outputs
        died (or sit across an open cut — the recovering side cannot tell
        the difference), pick a serving target on ``from_worker``'s side,
        re-home its prefetch intent, and ship whatever inputs are already
        available; the rest arrive as their producers (re-)complete."""
        run = js.tasks[tid]
        dfg = js.job.dfg
        preds = list(dfg.preds[tid])
        for p in preds:
            rp = js.tasks[p]
            if rp.finished is not None and (
                not self._output_alive(rp)
                or not self._reachable(from_worker, rp.worker)
            ):
                # Groups are equivalence classes, so an output reachable
                # from ``from_worker`` is reachable from any target picked
                # on the same side.
                self._reexec_producer(js, p, from_worker=from_worker)
        if (
            not self.scheduler.plans_at_arrival
            and preds
            and not all(js.tasks[p].finished is not None for p in preds)
        ):
            return  # JIT re-assigns when the last producer (re-)completes
        target = self._recovery_target(js, tid, from_worker=from_worker)
        if target is None:
            mid = dfg.tasks[tid].model_id
            if not self._fleet_can_serve(mid):
                raise ValueError(
                    f"task {tid!r} (model {mid}) fits no current or "
                    f"future fleet member; the job can never finish"
                )
            # A capable worker will (re)join — or the cut will heal
            # (validate_schedule guarantees every partition heals); retry
            # then, without the cut-side restriction (by the retry the
            # failing-over sender's identity no longer matters).
            self._post(
                self._now + 0.5, "reroute_retry", js, tid, run.generation,
                from_worker,
            )
            return
        assert js.adfg is not None
        js.adfg[tid] = target
        task = dfg.tasks[tid]
        if self.prefetch_plane is not None and task.model_id is not None:
            ctrl = self._xfer_time(INTENT_WIRE_BYTES, from_worker, target)
            orphan = self._orphaned_intents.pop((js.job.job_id, tid), None)
            if orphan is not None:
                intent = self.prefetch_plane.rehome(orphan, target, self._now)
            else:
                intent = self.prefetch_plane.make_intent(
                    js.job, tid, target, self._now
                )
            if intent is not None:
                self._post(self._now + ctrl, "intent", target, [intent])
        if not preds:
            origin = self._entry_origin(js, target)
            delay = (
                0.0
                if target == origin
                else self._xfer_time(
                    task.input_bytes, origin, target, register=True
                )
            )
            self._post_input(
                self._now + delay, js, tid, "", target,
                run.generation, origin,
            )
            return
        ready = [p for p in preds if js.tasks[p].finished is not None]
        if not ready:
            return  # everything arrives via _route_successors later
        delay = 0.0
        for p in ready:
            if js.tasks[p].worker != target:
                delay = max(
                    delay,
                    self._xfer_time(
                        dfg.tasks[p].output_bytes, js.tasks[p].worker,
                        target, register=True,
                    ),
                )
        for p in ready:
            self._post_input(
                self._now + delay, js, tid, p, target,
                run.generation, js.tasks[p].worker,
            )

    def _entry_origin(self, js: _JobState, target: int) -> int:
        """A live holder of the job's entry payload the ``target`` can
        reach: the preferred origin when possible, else the nearest
        serving same-side replica, else the target itself (whole side
        gone except the target)."""
        n = self.cluster.n_workers
        for d in range(n):
            w = (js.origin + d) % n
            if self._serving(w) and self._reachable(target, w):
                return w
        return target

    def _on_reroute_retry(
        self,
        js: _JobState,
        tid: str,
        gen: int,
        from_worker: Optional[int] = None,
    ) -> None:
        run = js.tasks[tid]
        if gen != run.generation or run.finished is not None:
            return
        # Re-resolve the cut side at fire time: a healed partition lifts
        # the restriction because ``_reachable`` consults current state.
        self._ship_inputs(js, tid, from_worker=from_worker)

    # -- gossip plane (decentralized SST, §5.2) ------------------------------------
    def _on_gossip(self, worker: int, session: int) -> None:
        """One gossip round: the plane computes the diff messages (drops
        already sampled); delivery is delayed by the network model, so a
        reader's view lags by period + wire time.  A dead worker's chain
        dies with it (stale session); a join starts a fresh chain."""
        assert self.gossip is not None and isinstance(self.sst, GossipPlane)
        if session != self._session[worker] or not self._up[worker]:
            return
        if self._health is not None:
            self._refresh_health_digest(worker)
        for peer, updates, nbytes in self.sst.exchange(worker, self._now):
            delay = self._xfer_time(nbytes, worker, peer)
            if self._rec is not None:
                self._rec.emit(
                    self._now, "gossip.exchange", worker=worker,
                    peer=peer, bytes=nbytes, n=len(updates),
                )
            self._post(
                self._now + delay, "gossip_rx", peer, updates, worker
            )
        self._post(self._now + self.gossip.period_s, "gossip", worker, session)

    def _on_heartbeat(self, worker: int, session: int) -> None:
        if session != self._session[worker] or not self._up[worker]:
            return
        assert self.lease is not None
        self.sst.heartbeat(worker, self._now)
        self._post(
            self._now + self.lease.heartbeat_period_s, "heartbeat",
            worker, session,
        )

    # -- state publication ---------------------------------------------------------
    def _update_load(self, worker: int) -> None:
        """Recompute FT(w) = now + remaining work on the queue (§4.1)."""
        if not self._up[worker]:
            return  # a corpse publishes nothing; its row freezes
        ft = self._now
        busy = self._gpu_busy[worker]
        if busy is not None:
            js, tid = busy
            task = js.job.dfg.tasks[tid]
            # remaining = expected runtime (we don't know the noise draw)
            elapsed = self._now - (js.tasks[tid].started or self._now)
            ft += max(0.0, self.profiles.runtime(task, worker) - elapsed)
        for js, tid in self._queues[worker]:
            ft += self.profiles.runtime(js.job.dfg.tasks[tid], worker)
        self.sst.update_load(worker, ft, self._now)
        if self._health is not None:
            self._health.sample_queue(
                worker, self._now,
                len(self._queues[worker]) + (1 if busy is not None else 0),
            )

    def _publish_cache(self, worker: int) -> None:
        if not self._up[worker]:
            return
        mem = self.memories[worker]
        if self._health is not None:
            self._health.sample_memory(
                worker, self._now,
                (mem.used_bytes + mem.exec_reserved_bytes)
                / mem.capacity_bytes if mem.capacity_bytes > 0 else 0.0,
                mem.stats.evictions,
            )
        # Expected-completion advertisement: the model on the pipe and its
        # absolute ETA ride every cache publication, so remote planners can
        # discount an in-flight fetch by its *remaining* fraction instead
        # of a confidence constant.
        fm, eta = -1, 0.0
        if self._fetch_busy[worker] and self._fetch_model[worker] is not None:
            fm = self._fetch_model[worker]
            eta = self._fetch_ends[worker]
        if self.prefetch_plane is None:
            self.sst.update_cache(
                worker, mem.bitmap, mem.free_bytes, self._now,
                fetch_model_id=fm, fetch_eta_s=eta,
            )
            return
        # Under the prefetch plane the advertisement is honest about the
        # pipe: a model still in flight is not usable residency (tasks
        # wait for fetch completion), so it moves from the cache bitmap to
        # the intent bitmap, where the planner prices it at the discounted
        # remainder of the fetch.  AVC counts undemanded speculative
        # contents as available — they are the cheapest victims.
        bm = mem.bitmap
        if fm >= 0:
            bm &= ~(1 << fm)
        self.sst.update_cache(
            worker, bm, mem.available_bytes, self._now,
            fetch_model_id=fm, fetch_eta_s=eta,
        )
        self.sst.update_intent(
            worker,
            mem.bitmap | self.prefetch_plane.advertised_bits(worker),
            self._now,
        )

    def _refresh_health_digest(self, worker: int) -> None:
        """Stamp the owner's four-field health digest onto its SST row
        right before a publication/gossip round (wire lanes 12–15), so
        the replicated view's staleness is bounded by the dissemination
        period — the same discipline as the load/cache lanes."""
        assert self._health is not None
        d = self._health.digest(worker, self._now)
        self.sst.update_health(
            worker, d.queue_depth, d.mem_occupancy, d.fetch_util,
            d.p99_latency_s, self._now,
        )

    def _publish_intent(self, worker: int) -> None:
        if self.prefetch_plane is None or not self._up[worker]:
            return
        mem = self.memories[worker]
        self.sst.update_intent(
            worker,
            mem.bitmap | self.prefetch_plane.advertised_bits(worker),
            self._now,
        )

"""Workload generators for the experiments (§6).

* Poisson arrivals with a mixture over the four Figure-1 DFGs (the paper's
  low-load 0.5 req/s and high-load 2 req/s settings, and the scalability
  study's 40 req/s).
* A bursty "production trace" generator statistically matched to the
  Alibaba-trace replay of §6.4 (the offline container has no network; see
  DESIGN.md §7): baseline Poisson arrivals modulated by lognormal bursts
  arriving as a Poisson process, rescaled to cluster capacity.
"""

from __future__ import annotations

import random
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.netmodel import ClusterSpec
from repro.core.types import DFG, Job


def _mixture_picker(
    rng: random.Random, dfgs: Sequence[DFG], weights: Optional[Sequence[float]]
):
    if weights is None:
        weights = [1.0] * len(dfgs)
    total = sum(weights)
    cum = []
    acc = 0.0
    for w in weights:
        acc += w / total
        cum.append(acc)

    def pick() -> DFG:
        u = rng.random()
        for dfg, c in zip(dfgs, cum):
            if u <= c:
                return dfg
        return dfgs[-1]

    return pick


def poisson_workload(
    dfgs: Sequence[DFG],
    rate_per_s: float,
    duration_s: float,
    seed: int = 0,
    weights: Optional[Sequence[float]] = None,
) -> List[Job]:
    """Poisson arrivals at ``rate_per_s`` with DFG types drawn from the
    mixture ("Poison distribution on request types", §6.2.2)."""
    rng = random.Random(seed)
    pick = _mixture_picker(rng, dfgs, weights)
    jobs: List[Job] = []
    t = 0.0
    jid = 0
    while True:
        t += rng.expovariate(rate_per_s)
        if t >= duration_s:
            break
        jobs.append(Job(job_id=jid, dfg=pick(), arrival_time=t))
        jid += 1
    return jobs


def bursty_trace_workload(
    dfgs: Sequence[DFG],
    base_rate_per_s: float,
    duration_s: float,
    seed: int = 0,
    burst_rate_per_s: float = 0.02,
    burst_size_mu: float = 2.3,
    burst_size_sigma: float = 0.7,
    burst_span_s: float = 4.0,
    weights: Optional[Sequence[float]] = None,
) -> List[Job]:
    """Bursty arrivals: a low Poisson baseline plus burst events whose
    sizes are lognormal (heavy-tailed, as in production GPU-cluster traces)
    and whose members arrive within a short span.  Matches the qualitative
    §6.4 pattern: long quiet stretches punctuated by sharp spikes."""
    rng = random.Random(seed)
    pick = _mixture_picker(rng, dfgs, weights)
    arrivals: List[float] = []
    t = 0.0
    while True:
        t += rng.expovariate(base_rate_per_s)
        if t >= duration_s:
            break
        arrivals.append(t)
    t = 0.0
    while True:
        t += rng.expovariate(burst_rate_per_s)
        if t >= duration_s:
            break
        size = max(1, int(rng.lognormvariate(burst_size_mu, burst_size_sigma)))
        for _ in range(size):
            arrivals.append(t + rng.random() * burst_span_s)
    arrivals.sort()
    return [
        Job(job_id=i, dfg=pick(), arrival_time=a)
        for i, a in enumerate(arrivals)
        if a < duration_s
    ]


def fleet_scaled_rate(
    cluster: ClusterSpec,
    base_rate_per_s: float,
    reference_speed: float = 1.0,
) -> float:
    """Scale an arrival rate by aggregate fleet throughput so a sweep over
    heterogeneous fleets holds *offered load* (arrival rate ÷ service
    capacity) constant.  ``base_rate_per_s`` is the rate calibrated for a
    fleet of ``n_workers`` × ``reference_speed`` workers (the paper's
    uniform-T4 testbed)."""
    reference_capacity = reference_speed * cluster.n_workers
    if reference_capacity <= 0:
        return base_rate_per_s
    return base_rate_per_s * cluster.total_speed / reference_capacity


def fleet_workload(
    dfgs: Sequence[DFG],
    cluster: ClusterSpec,
    base_rate_per_s: float,
    duration_s: float,
    seed: int = 0,
    weights: Optional[Sequence[float]] = None,
) -> List[Job]:
    """Poisson workload whose rate is scaled to the fleet's aggregate
    throughput (see ``fleet_scaled_rate``) — the generator the
    heterogeneity sweeps use so "high load" means the same utilisation on
    every fleet."""
    return poisson_workload(
        dfgs,
        fleet_scaled_rate(cluster, base_rate_per_s),
        duration_s,
        seed=seed,
        weights=weights,
    )


def arrival_rate_timeline(
    jobs: Sequence[Job], bin_s: float = 10.0
) -> List[Tuple[float, float]]:
    """(bin_start, req/s) series — Fig. 9a style."""
    if not jobs:
        return []
    end = max(j.arrival_time for j in jobs)
    nbins = int(end / bin_s) + 1
    counts = [0] * nbins
    for j in jobs:
        counts[int(j.arrival_time / bin_s)] += 1
    return [(i * bin_s, c / bin_s) for i, c in enumerate(counts)]

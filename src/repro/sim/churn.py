"""Fleet-churn schedules for the simulator.

A churn schedule is a seeded, replayable list of :class:`ChurnEvent`
records (crash / join / drain at scripted times) the simulator executes
alongside the workload — the chaos-test harness (``tests/chaos.py``)
replays the same schedule across schedulers and asserts invariants, and
``benchmarks/bench_churn.py`` sweeps MTBF × policy × fleet with it.

Semantics (enforced by ``sim/engine.py``):

* ``crash``  — the worker vanishes instantly: its GPU task, queue,
  in-flight fetch, gossip replica, and cache contents are lost.  Peers
  only learn of the death when its heartbeat lease expires in their own
  SST views.
* ``drain``  — graceful departure: the worker advertises ``draining``
  (peers stop placing work as soon as they see the flag), re-routes its
  queued tasks, aborts its in-flight fetch, finishes its running task,
  then leaves.
* ``join``   — the worker (re)enters with a cold cache, a fresh gossip
  incarnation (epoch + 1), and an empty SST view rebuilt by anti-entropy
  full-sync from the first peers to contact it.
"""

from __future__ import annotations

import dataclasses
import random
from typing import List, Optional, Sequence

CRASH = "crash"
JOIN = "join"
DRAIN = "drain"


@dataclasses.dataclass(frozen=True)
class ChurnEvent:
    time: float
    kind: str  # CRASH | JOIN | DRAIN
    worker: int

    def __post_init__(self) -> None:
        if self.kind not in (CRASH, JOIN, DRAIN):
            raise ValueError(f"unknown churn event kind {self.kind!r}")


def churn_schedule(
    n_workers: int,
    duration_s: float,
    mtbf_s: float,
    repair_s: float = 20.0,
    seed: int = 0,
    drain_fraction: float = 0.25,
    min_live: int = 1,
    start_after_s: float = 5.0,
) -> List[ChurnEvent]:
    """Seeded Poisson churn: each failure hits a uniformly chosen live
    worker (exponential fleet inter-failure gap ``mtbf_s / n_workers``),
    is a graceful drain with probability ``drain_fraction``, and repairs
    (rejoins) after ``repair_s`` ± 25 % jitter.  At least ``min_live``
    workers stay up at all times (failures that would violate the floor
    are skipped, as a real orchestrator's disruption budget would).
    ``start_after_s`` keeps the bootstrap window quiet so the first
    heartbeats propagate before the first lease can expire.
    """
    if n_workers < 1 or mtbf_s <= 0:
        return []
    rng = random.Random(seed)
    events: List[ChurnEvent] = []
    up = set(range(n_workers))
    rejoin_at = {}  # worker -> scheduled join time
    t = start_after_s
    while True:
        t += rng.expovariate(n_workers / mtbf_s)
        if t >= duration_s:
            break
        # Apply any repairs that completed before this failure.
        for w, rt in sorted(rejoin_at.items()):
            if rt <= t:
                up.add(w)
                del rejoin_at[w]
        if len(up) <= min_live:
            continue  # disruption budget exhausted; skip this failure
        victim = rng.choice(sorted(up))
        kind = DRAIN if rng.random() < drain_fraction else CRASH
        events.append(ChurnEvent(time=t, kind=kind, worker=victim))
        up.discard(victim)
        back = t + repair_s * (0.75 + 0.5 * rng.random())
        if back < duration_s:
            events.append(ChurnEvent(time=back, kind=JOIN, worker=victim))
            rejoin_at[victim] = back
    return sorted(events, key=lambda e: (e.time, e.worker))


def validate_schedule(
    events: Sequence[ChurnEvent], n_workers: int, min_live: int = 1
) -> None:
    """Sanity-check a (possibly hand-written) schedule: workers in range,
    no failure of an already-down worker, no join of an up worker, and the
    live floor respected.  Raises ``ValueError`` on the first violation."""
    up = set(range(n_workers))
    for ev in sorted(events, key=lambda e: (e.time, e.worker)):
        if not 0 <= ev.worker < n_workers:
            raise ValueError(f"worker {ev.worker} out of range in {ev}")
        if ev.kind == JOIN:
            if ev.worker in up:
                raise ValueError(f"join of live worker in {ev}")
            up.add(ev.worker)
        else:
            if ev.worker not in up:
                raise ValueError(f"{ev.kind} of down worker in {ev}")
            up.discard(ev.worker)
            if len(up) < min_live:
                raise ValueError(f"live floor {min_live} violated at {ev}")

"""Fleet-churn schedules for the simulator.

A churn schedule is a seeded, replayable list of :class:`ChurnEvent`
records (crash / join / drain at scripted times) the simulator executes
alongside the workload — the chaos-test harness (``tests/chaos.py``)
replays the same schedule across schedulers and asserts invariants, and
``benchmarks/bench_churn.py`` sweeps MTBF × policy × fleet with it.

Semantics (enforced by ``sim/engine.py``):

* ``crash``  — the worker vanishes instantly: its GPU task, queue,
  in-flight fetch, gossip replica, and cache contents are lost.  Peers
  only learn of the death when its heartbeat lease expires in their own
  SST views.
* ``drain``  — graceful departure: the worker advertises ``draining``
  (peers stop placing work as soon as they see the flag), re-routes its
  queued tasks, aborts its in-flight fetch, finishes its running task,
  then leaves.
* ``join``   — the worker (re)enters with a cold cache, a fresh gossip
  incarnation (epoch + 1), and an empty SST view rebuilt by anti-entropy
  full-sync from the first peers to contact it.
* ``partition`` — the interconnect splits into the event's ``groups``:
  every worker stays up and keeps executing, but messages (inputs,
  outputs, gossip, intents) between groups are lost.  Cross-cut readers
  watch each other's heartbeats age to SUSPECT and then DEAD while
  same-side readers still see ALIVE — the asymmetric-reachability regime
  fail-stop churn never generates.  Workers do NOT bump their epoch (no
  process died), so healed rows win replica merges on version alone.
* ``heal``  — the cut closes; reachability is global again.  Schedules
  must heal every partition (``validate_schedule`` enforces it) so that
  work stranded behind a cut can always make progress eventually.
"""

from __future__ import annotations

import dataclasses
import random
from typing import List, Optional, Sequence, Tuple

CRASH = "crash"
JOIN = "join"
DRAIN = "drain"
PARTITION = "partition"
HEAL = "heal"


@dataclasses.dataclass(frozen=True)
class ChurnEvent:
    time: float
    kind: str  # CRASH | JOIN | DRAIN | PARTITION | HEAL
    worker: int = -1  # unused (-1) for partition/heal
    # PARTITION only: the connected components the fleet splits into.
    # Workers not listed form singleton groups (fully isolated).
    groups: Optional[Tuple[Tuple[int, ...], ...]] = None

    def __post_init__(self) -> None:
        if self.kind not in (CRASH, JOIN, DRAIN, PARTITION, HEAL):
            raise ValueError(f"unknown churn event kind {self.kind!r}")
        if self.kind == PARTITION:
            if not self.groups or len(self.groups) < 2:
                raise ValueError(f"partition needs >= 2 groups in {self}")
        elif self.groups is not None:
            raise ValueError(f"groups only valid on partition events: {self}")
        if self.kind in (CRASH, JOIN, DRAIN) and self.worker < 0:
            raise ValueError(f"{self.kind} event needs a worker: {self}")


def churn_schedule(
    n_workers: int,
    duration_s: float,
    mtbf_s: float,
    repair_s: float = 20.0,
    seed: int = 0,
    drain_fraction: float = 0.25,
    min_live: int = 1,
    start_after_s: float = 5.0,
) -> List[ChurnEvent]:
    """Seeded Poisson churn: each failure hits a uniformly chosen live
    worker (exponential fleet inter-failure gap ``mtbf_s / n_workers``),
    is a graceful drain with probability ``drain_fraction``, and repairs
    (rejoins) after ``repair_s`` ± 25 % jitter.  At least ``min_live``
    workers stay up at all times (failures that would violate the floor
    are skipped, as a real orchestrator's disruption budget would).
    ``start_after_s`` keeps the bootstrap window quiet so the first
    heartbeats propagate before the first lease can expire.
    """
    if n_workers < 1 or mtbf_s <= 0:
        return []
    rng = random.Random(seed)
    events: List[ChurnEvent] = []
    up = set(range(n_workers))
    rejoin_at = {}  # worker -> scheduled join time
    t = start_after_s
    while True:
        t += rng.expovariate(n_workers / mtbf_s)
        if t >= duration_s:
            break
        # Apply any repairs that completed before this failure.
        for w, rt in sorted(rejoin_at.items()):
            if rt <= t:
                up.add(w)
                del rejoin_at[w]
        if len(up) <= min_live:
            continue  # disruption budget exhausted; skip this failure
        victim = rng.choice(sorted(up))
        kind = DRAIN if rng.random() < drain_fraction else CRASH
        events.append(ChurnEvent(time=t, kind=kind, worker=victim))
        up.discard(victim)
        back = t + repair_s * (0.75 + 0.5 * rng.random())
        if back < duration_s:
            events.append(ChurnEvent(time=back, kind=JOIN, worker=victim))
            rejoin_at[victim] = back
    return sorted(events, key=lambda e: (e.time, e.worker))


def partition_schedule(
    n_workers: int,
    duration_s: float,
    mtbp_s: float,
    outage_s: float = 6.0,
    seed: int = 0,
    groups: Optional[Tuple[Tuple[int, ...], ...]] = None,
    start_after_s: float = 5.0,
) -> List[ChurnEvent]:
    """Seeded partition churn: cuts arrive with exponential gaps of mean
    ``mtbp_s`` and heal after ``outage_s`` ± 25 % jitter; cuts never
    overlap (one partition at a time, matching the engine's model).  Each
    cut uses ``groups`` when given (e.g. the rack split of a topology
    preset) or a seeded random bipartition otherwise.  Every cut is healed
    — if necessary past ``duration_s`` — so stranded work can finish."""
    if n_workers < 2 or mtbp_s <= 0:
        return []
    rng = random.Random(seed)
    events: List[ChurnEvent] = []
    t = start_after_s
    while True:
        t += rng.expovariate(1.0 / mtbp_s)
        if t >= duration_s:
            break
        if groups is not None:
            cut = groups
        else:
            members = list(range(n_workers))
            rng.shuffle(members)
            k = rng.randint(1, n_workers - 1)
            cut = (tuple(sorted(members[:k])), tuple(sorted(members[k:])))
        heal_at = t + outage_s * (0.75 + 0.5 * rng.random())
        events.append(ChurnEvent(time=t, kind=PARTITION, groups=cut))
        events.append(ChurnEvent(time=heal_at, kind=HEAL))
        t = heal_at
    return sorted(events, key=lambda e: (e.time, e.worker))


def validate_schedule(
    events: Sequence[ChurnEvent], n_workers: int, min_live: int = 1
) -> None:
    """Sanity-check a (possibly hand-written) schedule: workers in range,
    no failure of an already-down worker, no join of an up worker, the
    live floor respected, partitions well-formed (disjoint in-range
    groups, no overlapping cuts) and always healed.  Raises
    ``ValueError`` on the first violation."""
    up = set(range(n_workers))
    cut_open = False
    for ev in sorted(events, key=lambda e: (e.time, e.worker)):
        if ev.kind == PARTITION:
            if cut_open:
                raise ValueError(f"overlapping partition in {ev}")
            seen: set = set()
            for group in ev.groups or ():
                for w in group:
                    if not 0 <= w < n_workers:
                        raise ValueError(f"worker {w} out of range in {ev}")
                    if w in seen:
                        raise ValueError(f"worker {w} in two groups in {ev}")
                    seen.add(w)
            cut_open = True
            continue
        if ev.kind == HEAL:
            if not cut_open:
                raise ValueError(f"heal without open partition in {ev}")
            cut_open = False
            continue
        if not 0 <= ev.worker < n_workers:
            raise ValueError(f"worker {ev.worker} out of range in {ev}")
        if ev.kind == JOIN:
            if ev.worker in up:
                raise ValueError(f"join of live worker in {ev}")
            up.add(ev.worker)
        else:
            if ev.worker not in up:
                raise ValueError(f"{ev.kind} of down worker in {ev}")
            up.discard(ev.worker)
            if len(up) < min_live:
                raise ValueError(f"live floor {min_live} violated at {ev}")
    if cut_open:
        raise ValueError("schedule ends with an unhealed partition")

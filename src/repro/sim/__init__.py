from repro.sim.churn import (
    ChurnEvent,
    churn_schedule,
    partition_schedule,
    validate_schedule,
)
from repro.core.telemetry import SimReport, TraceConfig
from repro.sim.engine import JobRecord, SimResult, Simulation
from repro.sim.tracefile import (
    TraceFormatError,
    load_jobs,
    synthesize_poisson_trace,
    trace_task_count,
    write_trace,
)
from repro.sim.workload import (
    arrival_rate_timeline,
    bursty_trace_workload,
    fleet_scaled_rate,
    fleet_workload,
    poisson_workload,
)

__all__ = [
    "ChurnEvent",
    "JobRecord",
    "SimReport",
    "SimResult",
    "Simulation",
    "TraceConfig",
    "TraceFormatError",
    "arrival_rate_timeline",
    "bursty_trace_workload",
    "churn_schedule",
    "fleet_scaled_rate",
    "fleet_workload",
    "load_jobs",
    "partition_schedule",
    "poisson_workload",
    "synthesize_poisson_trace",
    "trace_task_count",
    "validate_schedule",
    "write_trace",
]

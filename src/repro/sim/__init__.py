from repro.sim.churn import (
    ChurnEvent,
    churn_schedule,
    partition_schedule,
    validate_schedule,
)
from repro.core.telemetry import SimReport, TraceConfig
from repro.sim.engine import JobRecord, SimResult, Simulation
from repro.sim.workload import (
    arrival_rate_timeline,
    bursty_trace_workload,
    fleet_scaled_rate,
    fleet_workload,
    poisson_workload,
)

__all__ = [
    "ChurnEvent",
    "JobRecord",
    "SimReport",
    "SimResult",
    "Simulation",
    "TraceConfig",
    "arrival_rate_timeline",
    "bursty_trace_workload",
    "churn_schedule",
    "fleet_scaled_rate",
    "fleet_workload",
    "partition_schedule",
    "poisson_workload",
    "validate_schedule",
]

from repro.sim.engine import JobRecord, SimResult, Simulation
from repro.sim.workload import (
    arrival_rate_timeline,
    bursty_trace_workload,
    fleet_scaled_rate,
    fleet_workload,
    poisson_workload,
)

__all__ = [
    "JobRecord",
    "SimResult",
    "Simulation",
    "arrival_rate_timeline",
    "bursty_trace_workload",
    "fleet_scaled_rate",
    "fleet_workload",
    "poisson_workload",
]

"""Version-compat shims for the JAX surface this repo touches.

``shard_map`` moved from ``jax.experimental.shard_map`` to the top-level
``jax`` namespace (and its replication-check kwarg was renamed
``check_rep`` → ``check_vma``) across the JAX versions the container may
carry.  Import it from here; the wrapper accepts the modern ``check_vma``
keyword and translates for older installs.
"""

from __future__ import annotations

import inspect
from typing import Any, Callable

try:  # jax >= 0.6: public top-level API
    from jax import shard_map as _shard_map  # type: ignore[attr-defined]
except ImportError:  # older jax: experimental namespace
    from jax.experimental.shard_map import shard_map as _shard_map

_PARAMS = set(inspect.signature(_shard_map).parameters)


def shard_map(
    f: Callable,
    *,
    mesh: Any,
    in_specs: Any,
    out_specs: Any,
    check_vma: bool = True,
) -> Callable:
    kw = {}
    if "check_vma" in _PARAMS:
        kw["check_vma"] = check_vma
    elif "check_rep" in _PARAMS:
        kw["check_rep"] = check_vma
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)

"""Multi-head Latent Attention (DeepSeek-V2, arXiv:2405.04434).

MLA compresses K/V into a low-rank latent c_kv (kv_lora_rank) plus a small
decoupled RoPE key shared across heads.  The decode-time cache stores ONLY
(c_kv, k_rope): (kv_lora_rank + rope_head_dim) floats per token — ~1/16 the
GQA cache for the 236B config — and up-projects per step.

Shapes (per layer):
  wq_a : (D, q_lora)              wq_b : (q_lora, H*(hd + rd))
  wkv_a: (D, kv_lora + rd)        wkv_b: (kv_lora, H*(hd + hd))
  wo   : (H*hd, D)
where hd = nope head dim, rd = rope_head_dim.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops
from repro.models.layers import apply_rope, cache_write, rms_norm


def _split_heads(x, n_heads, dim):
    b, s, _ = x.shape
    return x.reshape(b, s, n_heads, dim)


def _project_q(x, p, *, n_heads, hd, rd, positions, theta, eps):
    cq = rms_norm(x @ p["wq_a"], p["q_norm"], eps)
    q = _split_heads(cq @ p["wq_b"], n_heads, hd + rd)
    q_nope, q_rope = q[..., :hd], q[..., hd:]
    q_rope = apply_rope(q_rope, positions, theta)
    return jnp.concatenate([q_nope, q_rope], axis=-1)


def _latent_kv(x, p, *, positions, theta, eps):
    """Returns the cacheable latent: c_kv (B,S,kv_lora), k_rope (B,S,rd)."""
    kv = x @ p["wkv_a"]
    kv_lora = p["wkv_b"].shape[0]
    c_kv, k_rope = kv[..., :kv_lora], kv[..., kv_lora:]
    c_kv = rms_norm(c_kv, p["kv_norm"], eps)
    k_rope = apply_rope(k_rope[:, :, None, :], positions, theta)[:, :, 0]
    return c_kv, k_rope


def _expand_kv(c_kv, k_rope, p, *, n_heads, hd):
    """Up-project the latent into per-head K (nope‖rope) and V."""
    b, s, _ = c_kv.shape
    kv = _split_heads(c_kv @ p["wkv_b"], n_heads, 2 * hd)
    k_nope, v = kv[..., :hd], kv[..., hd:]
    k_rope_h = jnp.broadcast_to(
        k_rope[:, :, None, :], (b, s, n_heads, k_rope.shape[-1])
    )
    k = jnp.concatenate([k_nope, k_rope_h], axis=-1)
    return k, v


def mla_attention(
    x: jax.Array,
    p: Dict[str, jax.Array],
    positions: jax.Array,
    *,
    n_heads: int,
    head_dim: int,
    rope_head_dim: int,
    theta: float,
    norm_eps: float,
    window: Optional[int] = None,
    impl: str = "ref",
    unroll: bool = False,
) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """Full-sequence MLA (train / prefill).  Returns (out, (c_kv, k_rope))
    so serving can seed the latent cache."""
    b, s, _ = x.shape
    hd, rd = head_dim, rope_head_dim
    q = _project_q(
        x, p, n_heads=n_heads, hd=hd, rd=rd,
        positions=positions, theta=theta, eps=norm_eps,
    )
    c_kv, k_rope = _latent_kv(x, p, positions=positions, theta=theta, eps=norm_eps)
    k, v = _expand_kv(c_kv, k_rope, p, n_heads=n_heads, hd=hd)
    # Pad V to the QK head dim so the attention core sees uniform shapes,
    # then slice back (value dim hd < qk dim hd+rd).
    v_pad = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, rd)))
    out = kops.flash_attention(
        q, k, v_pad, causal=True, window=window, impl=impl, unroll=unroll
    )
    out = out[..., :hd].reshape(b, s, n_heads * hd) @ p["wo"]
    return out, (c_kv, k_rope)


def mla_decode_attention(
    x: jax.Array,
    p: Dict[str, jax.Array],
    position: jax.Array,
    ckv_cache: jax.Array,
    krope_cache: jax.Array,
    cache_len: jax.Array,
    write_index: jax.Array,
    *,
    n_heads: int,
    head_dim: int,
    rope_head_dim: int,
    theta: float,
    norm_eps: float,
    impl: str = "ref",
    cache_update: str = "scatter",
) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """One-token MLA decode against the latent cache.

    x: (B, D); ckv_cache: (B, T, kv_lora); krope_cache: (B, T, rd).
    The latent is up-projected to per-head K/V for the attention core —
    the memory win is in the cache, not the per-step compute."""
    b, d = x.shape
    hd, rd = head_dim, rope_head_dim
    pos = position[:, None]
    q = _project_q(
        x[:, None, :], p, n_heads=n_heads, hd=hd, rd=rd,
        positions=pos, theta=theta, eps=norm_eps,
    )  # (B,1,H,hd+rd)
    c_kv, k_rope = _latent_kv(
        x[:, None, :], p, positions=pos, theta=theta, eps=norm_eps
    )
    ckv_cache = cache_write(ckv_cache, c_kv[:, 0], write_index, cache_update)
    krope_cache = cache_write(
        krope_cache, k_rope[:, 0], write_index, cache_update
    )
    k, v = _expand_kv(
        ckv_cache, krope_cache, p, n_heads=n_heads, hd=hd
    )  # (B,T,H,hd+rd), (B,T,H,hd)
    v_pad = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, rd)))
    out = kops.decode_attention(q[:, 0], k, v_pad, cache_len, impl=impl)
    out = out[..., :hd].reshape(b, n_heads * hd) @ p["wo"]
    return out, (ckv_cache, krope_cache)

"""Model assembly: init, train/prefill forward, and one-token decode for
every architecture family.

Design points:
* homogeneous layer stacks carry a leading ``n_layers`` axis and run under
  ``jax.lax.scan`` — HLO size stays O(1) in depth, which keeps 126-layer
  dry-run compiles tractable.
* ``init_params(cfg, key)`` materializes weights; ``abstract_params(cfg)``
  returns the same pytree as ShapeDtypeStructs (via ``jax.eval_shape``) for
  allocation-free lowering.
* decode carries an explicit cache pytree (family-specific; see
  ``init_cache``) and supports sliding-window ring buffers for the
  ``long_500k`` shape.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import mla as mla_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.config import ModelConfig
from repro.models.layers import (
    cross_attention,
    gqa_attention,
    gqa_decode_attention,
    project_cross_kv,
    rms_norm,
    swiglu,
    text_mrope_positions,
)

Params = Dict[str, Any]
Cache = Dict[str, Any]


def _layer_scan(body, carry, xs, unroll: bool):
    """lax.scan over the layer stack, or a Python loop when ``unroll`` —
    used by the dry-run's cost-accounting variants (XLA counts while-loop
    bodies once, so per-layer HLO costs come from unrolled 1/2-layer
    lowers; see launch/dryrun.py)."""
    if not unroll:
        return jax.lax.scan(body, carry, xs)
    n = jax.tree.leaves(xs)[0].shape[0]
    ys = []
    for i in range(n):
        xi = jax.tree.map(lambda v: v[i], xs)
        carry, y = body(carry, xi)
        ys.append(y)
    if ys and ys[0] is not None:
        ys = jax.tree.map(lambda *a: jnp.stack(a), *ys)
    else:
        ys = None
    return carry, ys


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# ===========================================================================
# Parameter initialisation
# ===========================================================================
def _dense_layer_init(cfg: ModelConfig, key) -> Params:
    d, hd = cfg.d_model, cfg.hd
    h, kh, f = cfg.n_heads, cfg.n_kv_heads, cfg.d_ff
    ks = jax.random.split(key, 7)
    sc = 0.02
    dt = _dtype(cfg)
    return {
        "ln1": jnp.ones((d,), dt),
        "ln2": jnp.ones((d,), dt),
        "wq": jax.random.normal(ks[0], (d, h * hd), dt) * sc,
        "wk": jax.random.normal(ks[1], (d, kh * hd), dt) * sc,
        "wv": jax.random.normal(ks[2], (d, kh * hd), dt) * sc,
        "wo": jax.random.normal(ks[3], (h * hd, d), dt) * sc,
        "mlp": {
            "wg": jax.random.normal(ks[4], (d, f), dt) * sc,
            "wu": jax.random.normal(ks[5], (d, f), dt) * sc,
            "wd": jax.random.normal(ks[6], (f, d), dt) * sc,
        },
    }


def _moe_ffn_init(cfg: ModelConfig, key) -> Params:
    d, e, fe = cfg.d_model, cfg.n_experts, cfg.d_ff_expert
    ks = jax.random.split(key, 7)
    sc = 0.02
    dt = _dtype(cfg)
    p = {
        "router": jax.random.normal(ks[0], (d, e), jnp.float32) * sc,
        "wg": jax.random.normal(ks[1], (e, d, fe), dt) * sc,
        "wu": jax.random.normal(ks[2], (e, d, fe), dt) * sc,
        "wd": jax.random.normal(ks[3], (e, fe, d), dt) * sc,
    }
    if cfg.n_shared_experts:
        fs = fe * cfg.n_shared_experts
        p["shared_wg"] = jax.random.normal(ks[4], (d, fs), dt) * sc
        p["shared_wu"] = jax.random.normal(ks[5], (d, fs), dt) * sc
        p["shared_wd"] = jax.random.normal(ks[6], (fs, d), dt) * sc
    return p


def _moe_layer_init(cfg: ModelConfig, key) -> Params:
    k1, k2 = jax.random.split(key)
    base = _dense_layer_init(cfg, k1)
    del base["mlp"]
    base["moe"] = _moe_ffn_init(cfg, k2)
    return base


def _mla_layer_init(cfg: ModelConfig, key) -> Params:
    d, hd, rd = cfg.d_model, cfg.hd, cfg.rope_head_dim
    h = cfg.n_heads
    ql, kvl = cfg.q_lora_rank, cfg.kv_lora_rank
    ks = jax.random.split(key, 8)
    sc = 0.02
    dt = _dtype(cfg)
    layer = {
        "ln1": jnp.ones((d,), dt),
        "ln2": jnp.ones((d,), dt),
        "mla": {
            "wq_a": jax.random.normal(ks[0], (d, ql), dt) * sc,
            "q_norm": jnp.ones((ql,), dt),
            "wq_b": jax.random.normal(ks[1], (ql, h * (hd + rd)), dt) * sc,
            "wkv_a": jax.random.normal(ks[2], (d, kvl + rd), dt) * sc,
            "kv_norm": jnp.ones((kvl,), dt),
            "wkv_b": jax.random.normal(ks[3], (kvl, h * 2 * hd), dt) * sc,
            "wo": jax.random.normal(ks[4], (h * hd, d), dt) * sc,
        },
        "moe": _moe_ffn_init(cfg, ks[5]),
    }
    return layer


def _ssm_layer_init(cfg: ModelConfig, key) -> Params:
    d = cfg.d_model
    di = cfg.d_inner
    h, n = cfg.n_ssm_heads, cfg.ssm_state
    proj = 2 * di + 2 * cfg.ssm_groups * n + h
    c = ssm_mod.conv_channels(cfg)
    ks = jax.random.split(key, 4)
    sc = 0.02
    dt = _dtype(cfg)
    return {
        "ln": jnp.ones((d,), dt),
        "w_in": jax.random.normal(ks[0], (d, proj), dt) * sc,
        "conv_w": jax.random.normal(ks[1], (cfg.conv_kernel, c), dt) * sc,
        "conv_b": jnp.zeros((c,), dt),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "a_log": jnp.zeros((h,), jnp.float32),
        "d_skip": jnp.ones((h,), jnp.float32),
        "w_out": jax.random.normal(ks[2], (di, d), dt) * sc,
    }


def _stacked(layer_init, cfg: ModelConfig, key, n: int) -> Params:
    keys = jax.random.split(key, n)
    return jax.vmap(lambda k: layer_init(cfg, k))(keys)


def init_params(cfg: ModelConfig, key: jax.Array) -> Params:
    dt = _dtype(cfg)
    k_emb, k_layers, k_head, k_extra = jax.random.split(key, 4)
    params: Params = {
        "embed": jax.random.normal(k_emb, (cfg.vocab, cfg.d_model), dt) * 0.02,
        "final_norm": jnp.ones((cfg.d_model,), dt),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = (
            jax.random.normal(k_head, (cfg.d_model, cfg.vocab), dt) * 0.02
        )

    at = cfg.arch_type
    if at in ("dense", "vlm"):
        params["layers"] = _stacked(_dense_layer_init, cfg, k_layers, cfg.n_layers)
    elif at == "moe":
        init = _mla_layer_init if cfg.use_mla else _moe_layer_init
        params["layers"] = _stacked(init, cfg, k_layers, cfg.n_layers)
    elif at == "ssm":
        params["layers"] = _stacked(_ssm_layer_init, cfg, k_layers, cfg.n_layers)
    elif at == "hybrid":
        params["layers"] = _stacked(_ssm_layer_init, cfg, k_layers, cfg.n_layers)
        params["shared_block"] = _dense_layer_init(cfg, k_extra)
    elif at == "audio":
        params["layers"] = _stacked(
            _audio_decoder_layer_init, cfg, k_layers, cfg.n_layers
        )
        params["encoder"] = _stacked(
            _dense_layer_init, cfg, k_extra, cfg.n_encoder_layers
        )
        params["enc_final_norm"] = jnp.ones((cfg.d_model,), dt)
    else:  # pragma: no cover
        raise AssertionError(at)
    return params


def _audio_decoder_layer_init(cfg: ModelConfig, key) -> Params:
    k1, k2 = jax.random.split(key)
    layer = _dense_layer_init(cfg, k1)
    d, hd = cfg.d_model, cfg.hd
    h, kh = cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(k2, 4)
    dt = _dtype(cfg)
    layer["ln_cross"] = jnp.ones((d,), dt)
    layer["cross"] = {
        "wq": jax.random.normal(ks[0], (d, h * hd), dt) * 0.02,
        "wk": jax.random.normal(ks[1], (d, kh * hd), dt) * 0.02,
        "wv": jax.random.normal(ks[2], (d, kh * hd), dt) * 0.02,
        "wo": jax.random.normal(ks[3], (h * hd, d), dt) * 0.02,
    }
    return layer


def abstract_params(cfg: ModelConfig) -> Params:
    """ShapeDtypeStruct pytree — no allocation (dry-run / sharding design)."""
    return jax.eval_shape(
        functools.partial(init_params, cfg), jax.random.key(0)
    )


# ===========================================================================
# Forward (train / prefill)
# ===========================================================================
def _attn_kwargs(cfg: ModelConfig) -> Dict[str, Any]:
    return dict(
        n_heads=cfg.n_heads,
        n_kv_heads=cfg.n_kv_heads,
        head_dim=cfg.hd,
        theta=cfg.rope_theta,
    )


def _dense_block(h, layer, positions, cfg, *, window, impl, mrope=False,
                 mrope_positions=None, unroll=False):
    attn_out, kv = gqa_attention(
        rms_norm(h, layer["ln1"], cfg.norm_eps),
        layer,
        positions,
        causal=True,
        window=window,
        mrope_sections=cfg.mrope_sections if mrope else None,
        mrope_positions=mrope_positions,
        impl=impl,
        unroll=unroll,
        **_attn_kwargs(cfg),
    )
    h = h + attn_out
    h = h + swiglu(rms_norm(h, layer["ln2"], cfg.norm_eps), layer["mlp"])
    return h, kv


def _moe_block(h, layer, positions, cfg, *, window, impl, dispatch,
               mesh=None, unroll=False):
    if cfg.use_mla:
        attn_out, kv = mla_mod.mla_attention(
            rms_norm(h, layer["ln1"], cfg.norm_eps),
            layer["mla"],
            positions,
            n_heads=cfg.n_heads,
            head_dim=cfg.hd,
            rope_head_dim=cfg.rope_head_dim,
            theta=cfg.rope_theta,
            norm_eps=cfg.norm_eps,
            window=window,
            impl=impl,
            unroll=unroll,
        )
    else:
        attn_out, kv = gqa_attention(
            rms_norm(h, layer["ln1"], cfg.norm_eps),
            layer,
            positions,
            causal=True,
            window=window,
            impl=impl,
            unroll=unroll,
            **_attn_kwargs(cfg),
        )
    h = h + attn_out
    ffn_out, aux = moe_mod.moe_ffn(
        rms_norm(h, layer["ln2"], cfg.norm_eps),
        layer["moe"],
        top_k=cfg.top_k,
        dispatch=dispatch,
        impl=impl,
        mesh=mesh,
    )
    h = h + ffn_out
    return h, kv, aux


def _ssm_block(h, layer, cfg, *, impl, initial_state=None, unroll=False):
    y, state = ssm_mod.mamba2_block(
        rms_norm(h, layer["ln"], cfg.norm_eps), layer, cfg,
        initial_state=initial_state, impl=impl, unroll=unroll,
    )
    return h + y, state


def forward(
    params: Params,
    batch: Dict[str, jax.Array],
    cfg: ModelConfig,
    *,
    impl: str = "ref",
    moe_dispatch: str = "sorted",
    window: Optional[int] = None,
    unroll: bool = False,
    mesh=None,
) -> Tuple[jax.Array, jax.Array]:
    """Full-sequence forward.  ``batch``:
      tokens        : (B, S) int32                       (all archs)
      vision_embeds : (B, n_vis, D)                      (vlm)
      audio_frames  : (B, n_frames, D)                   (audio)
    Returns (logits (B, S, V), aux_loss scalar)."""
    at = cfg.arch_type
    tokens = batch["tokens"]
    bsz, s = tokens.shape
    h = params["embed"][tokens]
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (bsz, s))
    aux_total = jnp.zeros((), jnp.float32)
    mrope_positions = None

    if at == "vlm":
        vis = batch["vision_embeds"].astype(h.dtype)
        nv = vis.shape[1]
        h = jnp.concatenate([vis, h], axis=1)
        s_total = nv + s
        # M-RoPE grid positions for the vision prefix (t=0, h=row, w=col on
        # a square-ish grid), sequential text positions offset past it.
        side = max(1, int(nv ** 0.5))
        vis_idx = jnp.arange(nv)
        vis_pos = jnp.stack(
            [jnp.zeros((nv,), jnp.int32), vis_idx // side, vis_idx % side]
        )  # (3, nv)
        text_pos = jnp.arange(s) + nv
        text_pos3 = jnp.broadcast_to(text_pos[None], (3, s))
        pos3 = jnp.concatenate([vis_pos, text_pos3], axis=1)  # (3, S_total)
        mrope_positions = jnp.broadcast_to(
            pos3[:, None, :], (3, bsz, s_total)
        )
        positions = jnp.broadcast_to(
            jnp.arange(s_total)[None, :], (bsz, s_total)
        )

    if at in ("dense", "vlm"):
        def body(carry, layer):
            hh = carry
            hh, _ = _dense_block(
                hh, layer, positions, cfg, window=window, impl=impl,
                mrope=cfg.use_mrope, mrope_positions=mrope_positions,
                unroll=unroll,
            )
            return hh, None

        h, _ = _layer_scan(body, h, params["layers"], unroll)
        if at == "vlm":
            h = h[:, batch["vision_embeds"].shape[1]:]

    elif at == "moe":
        def body(carry, layer):
            hh, aux = carry
            hh, _, a = _moe_block(
                hh, layer, positions, cfg, window=window, impl=impl,
                dispatch=moe_dispatch, mesh=mesh, unroll=unroll,
            )
            return (hh, aux + a), None

        (h, aux_total), _ = _layer_scan(
            body, (h, aux_total), params["layers"], unroll
        )

    elif at == "ssm":
        def body(carry, layer):
            hh = carry
            hh, _ = _ssm_block(hh, layer, cfg, impl=impl, unroll=unroll)
            return hh, None

        h, _ = _layer_scan(body, h, params["layers"], unroll)

    elif at == "hybrid":
        shared = params["shared_block"]
        period = cfg.attn_period

        def body(carry, xs):
            hh = carry
            layer, idx = xs
            hh, _ = _ssm_block(hh, layer, cfg, impl=impl, unroll=unroll)

            def with_attn(hx):
                out, _ = _dense_block(
                    hx, shared, positions, cfg,
                    window=cfg.sliding_window, impl=impl, unroll=unroll,
                )
                return out

            if unroll:
                hh = with_attn(hh) if (int(idx) + 1) % period == 0 else hh
            else:
                hh = jax.lax.cond(
                    (idx + 1) % period == 0, with_attn, lambda hx: hx, hh
                )
            return hh, None

        if unroll:
            import numpy as _np
            idxs = _np.arange(cfg.n_layers)
        else:
            idxs = jnp.arange(cfg.n_layers)
        h, _ = _layer_scan(body, h, (params["layers"], idxs), unroll)

    elif at == "audio":
        enc = _encode_audio(
            params, batch["audio_frames"], cfg, impl=impl, unroll=unroll
        )

        def body(carry, layer):
            hh = carry
            hh, _ = _dense_block(
                hh, layer, positions, cfg, window=window, impl=impl,
                unroll=unroll,
            )
            cross_out = cross_attention(
                rms_norm(hh, layer["ln_cross"], cfg.norm_eps),
                layer["cross"],
                *project_cross_kv(
                    enc, layer["cross"],
                    n_kv_heads=cfg.n_kv_heads, head_dim=cfg.hd,
                ),
                n_heads=cfg.n_heads,
                head_dim=cfg.hd,
                impl=impl,
            )
            return hh + cross_out, None

        h, _ = _layer_scan(body, h, params["layers"], unroll)
    else:  # pragma: no cover
        raise AssertionError(at)

    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = h @ head
    return logits, aux_total


def _sinusoidal(n: int, d: int) -> jax.Array:
    pos = jnp.arange(n)[:, None].astype(jnp.float32)
    i = jnp.arange(d // 2)[None, :].astype(jnp.float32)
    ang = pos / (10000.0 ** (2 * i / d))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _encode_audio(params, frames, cfg, *, impl, unroll=False):
    """Whisper-style encoder over stub frame embeddings (the mel/conv
    frontend is a stub per the carve-out; frames arrive (B, T, D))."""
    bsz, t, _ = frames.shape
    h = frames + _sinusoidal(t, cfg.d_model).astype(frames.dtype)[None]
    positions = jnp.broadcast_to(jnp.arange(t)[None, :], (bsz, t))

    def body(carry, layer):
        hh = carry
        attn_out, _ = gqa_attention(
            rms_norm(hh, layer["ln1"], cfg.norm_eps),
            layer, positions, causal=False, impl=impl, **_attn_kwargs(cfg),
        )
        hh = hh + attn_out
        hh = hh + swiglu(rms_norm(hh, layer["ln2"], cfg.norm_eps), layer["mlp"])
        return hh, None

    h, _ = _layer_scan(body, h, params["encoder"], unroll)
    return rms_norm(h, params["enc_final_norm"], cfg.norm_eps)


# ===========================================================================
# Loss / train step core
# ===========================================================================
def next_token_loss(
    params: Params,
    batch: Dict[str, jax.Array],
    cfg: ModelConfig,
    *,
    impl: str = "ref",
    moe_dispatch: str = "sorted",
    aux_weight: float = 0.01,
    unroll: bool = False,
    mesh=None,
) -> jax.Array:
    logits, aux = forward(
        params, batch, cfg, impl=impl, moe_dispatch=moe_dispatch,
        unroll=unroll, mesh=mesh,
    )
    targets = batch["tokens"][:, 1:]
    logits = logits[:, :-1].astype(jnp.float32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(nll) + aux_weight * aux


# ===========================================================================
# Decode cache + one-token decode step
# ===========================================================================
def init_cache(
    cfg: ModelConfig,
    batch: int,
    capacity: int,
    *,
    dtype=None,
) -> Cache:
    """Family-specific decode cache.  ``capacity`` is the KV capacity —
    the sliding window size for windowed archs, the max sequence length
    otherwise.  SSM caches are O(1) in capacity."""
    dt = dtype or _dtype(cfg)
    l, kh, hd = cfg.n_layers, cfg.n_kv_heads, cfg.hd
    at = cfg.arch_type
    cache: Cache = {
        "pos": jnp.zeros((batch,), jnp.int32),
    }
    if at in ("dense", "vlm"):
        cache["k"] = jnp.zeros((l, batch, capacity, kh, hd), dt)
        cache["v"] = jnp.zeros((l, batch, capacity, kh, hd), dt)
    elif at == "moe":
        if cfg.use_mla:
            cache["ckv"] = jnp.zeros((l, batch, capacity, cfg.kv_lora_rank), dt)
            cache["krope"] = jnp.zeros(
                (l, batch, capacity, cfg.rope_head_dim), dt
            )
        else:
            cache["k"] = jnp.zeros((l, batch, capacity, kh, hd), dt)
            cache["v"] = jnp.zeros((l, batch, capacity, kh, hd), dt)
    elif at == "ssm":
        cache["conv"] = jnp.zeros(
            (l, batch, cfg.conv_kernel - 1, ssm_mod.conv_channels(cfg)), dt
        )
        cache["ssm"] = jnp.zeros(
            (l, batch, cfg.n_ssm_heads, cfg.ssm_head_dim, cfg.ssm_state),
            jnp.float32,
        )
    elif at == "hybrid":
        cache["conv"] = jnp.zeros(
            (l, batch, cfg.conv_kernel - 1, ssm_mod.conv_channels(cfg)), dt
        )
        cache["ssm"] = jnp.zeros(
            (l, batch, cfg.n_ssm_heads, cfg.ssm_head_dim, cfg.ssm_state),
            jnp.float32,
        )
        napp = cfg.n_layers // cfg.attn_period
        wcap = min(capacity, cfg.sliding_window or capacity)
        cache["shared_k"] = jnp.zeros((napp, batch, wcap, kh, hd), dt)
        cache["shared_v"] = jnp.zeros((napp, batch, wcap, kh, hd), dt)
    elif at == "audio":
        cache["k"] = jnp.zeros((l, batch, capacity, kh, hd), dt)
        cache["v"] = jnp.zeros((l, batch, capacity, kh, hd), dt)
        cache["cross_k"] = jnp.zeros(
            (l, batch, cfg.n_audio_frames, kh, hd), dt
        )
        cache["cross_v"] = jnp.zeros(
            (l, batch, cfg.n_audio_frames, kh, hd), dt
        )
    else:  # pragma: no cover
        raise AssertionError(at)
    return cache


def _ring(pos: jax.Array, capacity: int, windowed: bool) -> Tuple[jax.Array, jax.Array]:
    """(write_index, cache_len) for ring-buffer vs linear caches."""
    if windowed:
        return pos % capacity, jnp.minimum(pos + 1, capacity)
    return pos, pos + 1


def decode_step(
    params: Params,
    cache: Cache,
    tokens: jax.Array,
    cfg: ModelConfig,
    *,
    impl: str = "ref",
    moe_dispatch: str = "sorted",
    unroll: bool = False,
    mesh=None,
    cache_update: str = "scatter",
) -> Tuple[jax.Array, Cache]:
    """One decode step: tokens (B,) int32 → (logits (B, V), new cache)."""
    at = cfg.arch_type
    bsz = tokens.shape[0]
    h = params["embed"][tokens]  # (B, D)
    pos = cache["pos"]
    new_cache = dict(cache)
    windowed = cfg.sliding_window is not None

    if at in ("dense", "vlm", "audio") or (at == "moe" and not cfg.use_mla):
        capacity = cache["k"].shape[2]
        write_idx, cache_len = _ring(pos, capacity, windowed)

        if at == "audio":
            def body(carry, xs):
                hh = carry
                layer, kc, vc, ck, cv = xs
                x = rms_norm(hh, layer["ln1"], cfg.norm_eps)
                attn_out, (kc, vc) = gqa_decode_attention(
                    x, layer, pos, kc, vc, cache_len, write_idx,
                    impl=impl, cache_update=cache_update,
                    **_attn_kwargs(cfg),
                )
                hh = hh + attn_out
                xq = rms_norm(hh, layer["ln_cross"], cfg.norm_eps)
                # Cross-attention over the (static) encoder KV.
                q = (xq @ layer["cross"]["wq"]).reshape(
                    bsz, cfg.n_heads, cfg.hd
                )
                enc_len = jnp.full((bsz,), ck.shape[1], jnp.int32)
                from repro.kernels import ops as kops

                cross = kops.decode_attention(q, ck, cv, enc_len, impl=impl)
                hh = hh + cross.reshape(bsz, -1) @ layer["cross"]["wo"]
                hh = hh + swiglu(
                    rms_norm(hh, layer["ln2"], cfg.norm_eps), layer["mlp"]
                )
                return hh, (kc, vc)

            h, (ks, vs) = _layer_scan(
                body,
                h,
                (
                    params["layers"], cache["k"], cache["v"],
                    cache["cross_k"], cache["cross_v"],
                ),
                unroll,
            )
            new_cache["k"], new_cache["v"] = ks, vs
        else:
            mrope = at == "vlm" and cfg.use_mrope

            def body(carry, xs):
                hh, aux = carry
                layer, kc, vc = xs
                x = rms_norm(hh, layer["ln1"], cfg.norm_eps)
                attn_out, (kc, vc) = gqa_decode_attention(
                    x, layer, pos, kc, vc, cache_len, write_idx,
                    mrope_sections=cfg.mrope_sections if mrope else None,
                    impl=impl, cache_update=cache_update,
                    **_attn_kwargs(cfg),
                )
                hh = hh + attn_out
                x2 = rms_norm(hh, layer["ln2"], cfg.norm_eps)
                if at == "moe":
                    ffn, a = moe_mod.moe_ffn(
                        x2[:, None, :], layer["moe"], top_k=cfg.top_k,
                        dispatch=moe_dispatch, impl=impl, mesh=mesh,
                    )
                    hh = hh + ffn[:, 0]
                    aux = aux + a
                else:
                    hh = hh + swiglu(x2, layer["mlp"])
                return (hh, aux), (kc, vc)

            (h, _), (ks, vs) = _layer_scan(
                body,
                (h, jnp.zeros((), jnp.float32)),
                (params["layers"], cache["k"], cache["v"]),
                unroll,
            )
            new_cache["k"], new_cache["v"] = ks, vs

    elif at == "moe" and cfg.use_mla:
        capacity = cache["ckv"].shape[2]
        write_idx, cache_len = _ring(pos, capacity, windowed)

        def body(carry, xs):
            hh, aux = carry
            layer, ckv, krope = xs
            x = rms_norm(hh, layer["ln1"], cfg.norm_eps)
            attn_out, (ckv, krope) = mla_mod.mla_decode_attention(
                x, layer["mla"], pos, ckv, krope, cache_len, write_idx,
                n_heads=cfg.n_heads, head_dim=cfg.hd,
                rope_head_dim=cfg.rope_head_dim, theta=cfg.rope_theta,
                norm_eps=cfg.norm_eps, impl=impl,
                cache_update=cache_update,
            )
            hh = hh + attn_out
            x2 = rms_norm(hh, layer["ln2"], cfg.norm_eps)
            ffn, a = moe_mod.moe_ffn(
                x2[:, None, :], layer["moe"], top_k=cfg.top_k,
                dispatch=moe_dispatch, impl=impl, mesh=mesh,
            )
            return (hh + ffn[:, 0], aux + a), (ckv, krope)

        (h, _), (ckvs, kropes) = _layer_scan(
            body,
            (h, jnp.zeros((), jnp.float32)),
            (params["layers"], cache["ckv"], cache["krope"]),
            unroll,
        )
        new_cache["ckv"], new_cache["krope"] = ckvs, kropes

    elif at == "ssm":
        def body(carry, xs):
            hh = carry
            layer, conv, st = xs
            y, conv, st = ssm_mod.mamba2_decode(
                rms_norm(hh, layer["ln"], cfg.norm_eps), layer, cfg, conv, st
            )
            return hh + y, (conv, st)

        h, (convs, sts) = _layer_scan(
            body, h, (params["layers"], cache["conv"], cache["ssm"]), unroll
        )
        new_cache["conv"], new_cache["ssm"] = convs, sts

    elif at == "hybrid":
        shared = params["shared_block"]
        period = cfg.attn_period
        wcap = cache["shared_k"].shape[2]
        write_idx, cache_len = _ring(pos, wcap, True)

        def body(carry, xs):
            hh, sk, sv = carry
            layer, conv, st, idx = xs
            y, conv, st = ssm_mod.mamba2_decode(
                rms_norm(hh, layer["ln"], cfg.norm_eps), layer, cfg, conv, st
            )
            hh = hh + y

            def with_attn(operand):
                hx, sk_, sv_ = operand
                app = (idx + 1) // period - 1
                kc = sk_[app]
                vc = sv_[app]
                x = rms_norm(hx, shared["ln1"], cfg.norm_eps)
                attn_out, (kc, vc) = gqa_decode_attention(
                    x, shared, pos, kc, vc, cache_len, write_idx,
                    impl=impl, cache_update=cache_update,
                    **_attn_kwargs(cfg),
                )
                hx = hx + attn_out
                hx = hx + swiglu(
                    rms_norm(hx, shared["ln2"], cfg.norm_eps), shared["mlp"]
                )
                sk_ = jax.lax.dynamic_update_index_in_dim(sk_, kc, app, 0)
                sv_ = jax.lax.dynamic_update_index_in_dim(sv_, vc, app, 0)
                return hx, sk_, sv_

            if unroll:
                if (int(idx) + 1) % period == 0:
                    hh, sk, sv = with_attn((hh, sk, sv))
            else:
                hh, sk, sv = jax.lax.cond(
                    (idx + 1) % period == 0,
                    with_attn,
                    lambda op: op,
                    (hh, sk, sv),
                )
            return (hh, sk, sv), (conv, st)

        if unroll:
            import numpy as _np
            idxs = _np.arange(cfg.n_layers)
        else:
            idxs = jnp.arange(cfg.n_layers)
        (h, sk, sv), (convs, sts) = _layer_scan(
            body,
            (h, cache["shared_k"], cache["shared_v"]),
            (params["layers"], cache["conv"], cache["ssm"], idxs),
            unroll,
        )
        new_cache.update(conv=convs, ssm=sts, shared_k=sk, shared_v=sv)
    else:  # pragma: no cover
        raise AssertionError(at)

    new_cache["pos"] = pos + 1
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return h @ head, new_cache

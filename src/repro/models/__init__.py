from repro.models.config import INPUT_SHAPES, InputShape, ModelConfig
from repro.models.model import (
    abstract_params,
    decode_step,
    forward,
    init_cache,
    init_params,
    next_token_loss,
)

__all__ = [
    "INPUT_SHAPES",
    "InputShape",
    "ModelConfig",
    "abstract_params",
    "decode_step",
    "forward",
    "init_cache",
    "init_params",
    "next_token_loss",
]

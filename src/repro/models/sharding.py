"""Partition-spec rules: params / inputs / caches → PartitionSpec trees.

Strategy (see DESIGN.md §5):
* batch over the data axes (``pod`` × ``data`` when multi-pod),
* tensor parallel over ``model`` on heads / d_ff / experts,
* FSDP over ``data`` on the non-TP dim of every large matrix
  (GSPMD inserts the per-layer all-gather / reduce-scatter schedule),
* params replicated across pods (classic cross-pod DP: gradients
  all-reduce over ``pod``; multi-pod dry-run proves the axis shards).

Rules are name+shape driven so one function covers all seven families.
Axis sizes must divide shapes; anything indivisible falls back to
replication on that dim (checked per-dim here rather than failing in
GSPMD).
"""

from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Pytree = Any

TP_AXIS = "model"


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def fsdp_axes(mesh: Mesh):
    """FSDP spans every data-parallel axis: on the multi-pod mesh params
    shard across pods too (ZeRO-3 over DCN), which is what lets the
    llama3-405b-class training state fit — see EXPERIMENTS.md §Dry-run."""
    return data_axes(mesh)


def _axis_size(mesh: Mesh, name) -> int:
    if name is None:
        return 1
    if isinstance(name, tuple):
        return int(np.prod([_axis_size(mesh, n) for n in name]))
    return mesh.shape[name]


def _fit(mesh: Mesh, dim: int, axis) -> Optional[str]:
    """Use ``axis`` on a dim only if it divides evenly."""
    return axis if axis is not None and dim % _axis_size(mesh, axis) == 0 else None


def _matrix_spec(
    mesh: Mesh, shape, col_parallel: bool, lead: int, serve: bool = False
) -> P:
    """(in, out) weight: column-parallel shards out on TP / in on FSDP;
    row-parallel the reverse.  ``lead`` leading dims (layer stack) unsharded.

    ``serve=True`` flips the layout so the *contraction* dimension carries
    the model axis on every matmul: with a single-token activation GSPMD
    then materialises partial products + a small activation all-reduce
    instead of all-gathering the (huge) weights each decode step — the
    weight-stationary serving layout (§Perf P2)."""
    d_in, d_out = shape[-2], shape[-1]
    fsdp = fsdp_axes(mesh)
    if serve:
        # contract dim (d_in) over model; d_out over data to keep 2D.
        spec = (_fit(mesh, d_in, TP_AXIS), _fit(mesh, d_out, fsdp))
        return P(*([None] * lead), *spec)
    if col_parallel:
        spec = (_fit(mesh, d_in, fsdp), _fit(mesh, d_out, TP_AXIS))
    else:
        spec = (_fit(mesh, d_in, TP_AXIS), _fit(mesh, d_out, fsdp))
    return P(*([None] * lead), *spec)


def param_pspecs(mesh: Mesh, params: Pytree, cfg, serve: bool = False) -> Pytree:
    """PartitionSpec tree matching ``params`` (abstract or concrete).
    ``serve=True`` selects the weight-stationary decode layout."""

    def rule(path, leaf) -> P:
        names = [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
        name = names[-1]
        shape = leaf.shape
        ndim = len(shape)
        in_stack = "layers" in names or "encoder" in names
        lead = 1 if in_stack else 0

        fsdp = fsdp_axes(mesh)
        if name == "embed":
            return P(_fit(mesh, shape[0], TP_AXIS), _fit(mesh, shape[1], fsdp))
        if name == "lm_head":
            return P(_fit(mesh, shape[0], fsdp), _fit(mesh, shape[1], TP_AXIS))
        if ndim - lead <= 1:  # norms, biases, gates
            return P(*([None] * ndim))

        # MoE expert banks: (..., E, d_in, d_out) — experts over TP,
        # d_in over FSDP (expert-parallel).
        if name in ("wg", "wu", "wd") and ndim - lead == 3:
            e, d_in, d_out = shape[-3], shape[-2], shape[-1]
            if name == "wd":
                return P(
                    *([None] * lead), _fit(mesh, e, TP_AXIS), None,
                    _fit(mesh, d_out, fsdp),
                )
            return P(
                *([None] * lead), _fit(mesh, e, TP_AXIS),
                _fit(mesh, d_in, fsdp), None,
            )
        if name == "router":
            return P(*([None] * lead), _fit(mesh, shape[-2], fsdp), None)

        col_parallel_names = {
            "wq", "wk", "wv", "wg", "wu",                      # attention/mlp in
            "wq_a", "wq_b", "wkv_a", "wkv_b",                  # MLA
            "w_in",                                            # SSM in-proj
            "shared_wg", "shared_wu",                          # shared experts
        }
        row_parallel_names = {"wo", "wd", "w_out", "shared_wd"}

        if name in col_parallel_names:
            return _matrix_spec(mesh, shape, col_parallel=True, lead=lead,
                                serve=serve)
        if name in row_parallel_names:
            return _matrix_spec(mesh, shape, col_parallel=False, lead=lead,
                                serve=serve)
        if name == "conv_w":
            return P(*([None] * ndim))
        # Fallback: replicate.
        return P(*([None] * ndim))

    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    return jax.tree_util.tree_unflatten(
        treedef, [rule(path, leaf) for path, leaf in flat]
    )


def batch_pspecs(mesh: Mesh, batch: Pytree) -> Pytree:
    dp = data_axes(mesh)

    def rule(path, leaf) -> P:
        b = leaf.shape[0] if leaf.ndim else 1
        lead = _fit(mesh, b, dp)
        return P(lead, *([None] * (leaf.ndim - 1)))

    flat, treedef = jax.tree_util.tree_flatten_with_path(batch)
    return jax.tree_util.tree_unflatten(
        treedef, [rule(p, l) for p, l in flat]
    )


def cache_pspecs(mesh: Mesh, cache: Pytree) -> Pytree:
    """Decode caches: (L, B, T, heads/latent...) — batch over data axes,
    KV heads over TP when divisible."""
    dp = data_axes(mesh)

    def rule(path, leaf) -> P:
        names = [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
        name = names[-1]
        if name == "pos":
            return P(_fit(mesh, leaf.shape[0], dp))
        if leaf.ndim >= 4 and name in ("k", "v", "shared_k", "shared_v",
                                       "cross_k", "cross_v"):
            # (L, B, T, KH, hd): batch over data, cache *sequence* over TP
            # (KV heads are usually < |model|, so sharding T is what keeps
            # the 2 TB decode_32k caches per-chip-sized; attention over the
            # sharded T contracts with a partial-sum all-reduce).
            spec = [None, _fit(mesh, leaf.shape[1], dp),
                    _fit(mesh, leaf.shape[2], TP_AXIS)]
            spec += [None] * (leaf.ndim - 3)
            return P(*spec)
        if name in ("ckv", "krope"):
            # (L, B, T, latent)
            return P(
                None, _fit(mesh, leaf.shape[1], dp),
                _fit(mesh, leaf.shape[2], TP_AXIS), None,
            )
        if name in ("conv", "ssm"):
            # (L, B, ...) — SSM heads over TP on dim 2 when divisible.
            spec = [None, _fit(mesh, leaf.shape[1], dp)]
            if leaf.ndim > 2:
                spec.append(_fit(mesh, leaf.shape[2], TP_AXIS))
            spec += [None] * (leaf.ndim - len(spec))
            return P(*spec)
        return P(*([None] * leaf.ndim))

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache)
    return jax.tree_util.tree_unflatten(
        treedef, [rule(p, l) for p, l in flat]
    )


def to_named(mesh: Mesh, pspecs: Pytree) -> Pytree:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), pspecs,
        is_leaf=lambda x: isinstance(x, P),
    )

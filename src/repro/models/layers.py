"""Shared layer primitives (pure functions over param pytrees).

Conventions:
* activations (B, S, D); attention heads laid out (B, S, H, hd).
* params are nested dicts of jnp arrays; layer stacks carry a leading
  ``n_layers`` axis and are consumed with ``jax.lax.scan``.
* norms and softmax statistics in float32, matmuls in the config dtype.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops


def cache_write(
    cache: jax.Array,
    new: jax.Array,
    write_index: jax.Array,
    mode: str = "scatter",
) -> jax.Array:
    """Write one token into a (B, T, ...) cache at per-batch slots.

    ``scatter``: batched scatter (`.at[b, idx].set`) — natural, but GSPMD
    cannot re-shard batched scatters across a T-sharded cache without an
    "involuntary full rematerialization" (replicate + repartition).
    ``onehot``: select against an iota mask — the same O(T) HBM traffic
    the attention pass already pays, but elementwise, so it partitions
    cleanly along every axis (the §Perf fix for T-sharded decode caches).
    """
    if mode == "scatter":
        bidx = jnp.arange(cache.shape[0])
        return cache.at[bidx, write_index].set(new)
    t = cache.shape[1]
    mask = jnp.arange(t)[None, :] == write_index[:, None]  # (B, T)
    mask = mask.reshape(mask.shape + (1,) * (cache.ndim - 2))
    return jnp.where(mask, new[:, None], cache)


# -- norms ---------------------------------------------------------------------
def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype) * weight


# -- rotary embeddings -------------------------------------------------------------
def rope_inv_freq(dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))


def _rotate(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    ).astype(x.dtype)


def apply_rope(
    x: jax.Array, positions: jax.Array, theta: float
) -> jax.Array:
    """x: (B, S, H, D); positions: (B, S) absolute positions."""
    inv = rope_inv_freq(x.shape[-1], theta)
    ang = positions.astype(jnp.float32)[..., None] * inv  # (B,S,D/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    return _rotate(x, cos, sin)


def apply_mrope(
    x: jax.Array,
    positions: jax.Array,
    theta: float,
    sections: Tuple[int, int, int],
) -> jax.Array:
    """Multimodal RoPE (Qwen2-VL): three position streams (temporal,
    height, width) drive disjoint frequency sections.

    x: (B, S, H, D); positions: (3, B, S); sum(sections) == D // 2.
    """
    d = x.shape[-1]
    assert sum(sections) == d // 2, (sections, d)
    inv = rope_inv_freq(d, theta)  # (D/2,)
    ang_all = positions.astype(jnp.float32)[..., None] * inv  # (3,B,S,D/2)
    parts = []
    start = 0
    for i, sec in enumerate(sections):
        parts.append(ang_all[i, :, :, start : start + sec])
        start += sec
    ang = jnp.concatenate(parts, axis=-1)  # (B,S,D/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    return _rotate(x, cos, sin)


def text_mrope_positions(positions: jax.Array) -> jax.Array:
    """For pure-text spans all three M-RoPE streams share the position."""
    return jnp.broadcast_to(positions[None], (3,) + positions.shape)


# -- feed-forward --------------------------------------------------------------------
def swiglu(x: jax.Array, p: Dict[str, jax.Array]) -> jax.Array:
    gate = jax.nn.silu(x @ p["wg"])
    return (gate * (x @ p["wu"])) @ p["wd"]


# -- attention ------------------------------------------------------------------------
def gqa_attention(
    x: jax.Array,
    p: Dict[str, jax.Array],
    positions: jax.Array,
    *,
    n_heads: int,
    n_kv_heads: int,
    head_dim: int,
    theta: float,
    causal: bool = True,
    window: Optional[int] = None,
    mrope_sections: Optional[Tuple[int, int, int]] = None,
    mrope_positions: Optional[jax.Array] = None,
    impl: str = "ref",
    unroll: bool = False,
) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """Full-sequence GQA attention (train / prefill).

    Returns (output (B,S,D), (k, v)) — k/v returned so serving can seed the
    KV cache from prefill without recomputation.
    """
    b, s, _ = x.shape
    q = (x @ p["wq"]).reshape(b, s, n_heads, head_dim)
    k = (x @ p["wk"]).reshape(b, s, n_kv_heads, head_dim)
    v = (x @ p["wv"]).reshape(b, s, n_kv_heads, head_dim)
    if mrope_sections is not None:
        pos3 = (
            mrope_positions
            if mrope_positions is not None
            else text_mrope_positions(positions)
        )
        q = apply_mrope(q, pos3, theta, mrope_sections)
        k = apply_mrope(k, pos3, theta, mrope_sections)
    else:
        q = apply_rope(q, positions, theta)
        k = apply_rope(k, positions, theta)
    out = kops.flash_attention(
        q, k, v, causal=causal, window=window, impl=impl, unroll=unroll
    )
    out = out.reshape(b, s, n_heads * head_dim) @ p["wo"]
    return out, (k, v)


def gqa_decode_attention(
    x: jax.Array,
    p: Dict[str, jax.Array],
    position: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    cache_len: jax.Array,
    write_index: jax.Array,
    *,
    n_heads: int,
    n_kv_heads: int,
    head_dim: int,
    theta: float,
    mrope_sections: Optional[Tuple[int, int, int]] = None,
    impl: str = "ref",
    cache_update: str = "scatter",
) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """One-token decode.  x: (B, D); position: (B,) absolute positions;
    caches (B, T, KH, hd); write_index (B,) slot to write new k/v (ring
    buffer semantics for sliding windows; == position for full caches).
    Returns (output (B, D), updated caches)."""
    b, d = x.shape
    q = (x @ p["wq"]).reshape(b, 1, n_heads, head_dim)
    k = (x @ p["wk"]).reshape(b, 1, n_kv_heads, head_dim)
    v = (x @ p["wv"]).reshape(b, 1, n_kv_heads, head_dim)
    pos = position[:, None]
    if mrope_sections is not None:
        pos3 = text_mrope_positions(pos)
        q = apply_mrope(q, pos3, theta, mrope_sections)
        k = apply_mrope(k, pos3, theta, mrope_sections)
    else:
        q = apply_rope(q, pos, theta)
        k = apply_rope(k, pos, theta)
    k_cache = cache_write(k_cache, k[:, 0], write_index, cache_update)
    v_cache = cache_write(v_cache, v[:, 0], write_index, cache_update)
    out = kops.decode_attention(
        q[:, 0], k_cache, v_cache, cache_len, impl=impl
    )
    out = out.reshape(b, n_heads * head_dim) @ p["wo"]
    return out, (k_cache, v_cache)


def cross_attention(
    x: jax.Array,
    p: Dict[str, jax.Array],
    enc_k: jax.Array,
    enc_v: jax.Array,
    *,
    n_heads: int,
    head_dim: int,
    impl: str = "ref",
) -> jax.Array:
    """Encoder-decoder cross attention (Whisper).  enc_k/enc_v are the
    projected encoder states (B, T_enc, KH, hd)."""
    b, s, _ = x.shape
    q = (x @ p["wq"]).reshape(b, s, n_heads, head_dim)
    out = kops.flash_attention(q, enc_k, enc_v, causal=False, impl=impl)
    return out.reshape(b, s, n_heads * head_dim) @ p["wo"]


def project_cross_kv(
    enc_out: jax.Array,
    p: Dict[str, jax.Array],
    *,
    n_kv_heads: int,
    head_dim: int,
) -> Tuple[jax.Array, jax.Array]:
    b, t, _ = enc_out.shape
    k = (enc_out @ p["wk"]).reshape(b, t, n_kv_heads, head_dim)
    v = (enc_out @ p["wv"]).reshape(b, t, n_kv_heads, head_dim)
    return k, v

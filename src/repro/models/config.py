"""Model configuration covering all assigned architecture families.

One dataclass describes dense / MoE / MLA / SSM / hybrid / VLM / audio
backbones; family-specific fields are ignored by other families.  Exact
assigned configs live in ``repro/configs/<arch>.py``; reduced smoke
variants are derived with ``reduced()``.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None  # default d_model // n_heads
    norm_eps: float = 1e-5
    rope_theta: float = 1e4
    dtype: str = "bfloat16"
    tie_embeddings: bool = False

    # -- MoE ---------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    d_ff_expert: int = 0  # per-expert FFN width (d_ff is the dense-block width)

    # -- MLA (DeepSeek-V2) ---------------------------------------------------
    use_mla: bool = False
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    rope_head_dim: int = 64  # decoupled RoPE key dimension
    nope_head_dim: int = 0   # defaults to head_dim

    # -- SSM (Mamba2 / SSD) ----------------------------------------------------
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 64
    conv_kernel: int = 4
    ssm_groups: int = 1    # B/C projections shared across heads (Mamba2)

    # -- hybrid (Zamba2-style) ----------------------------------------------------
    attn_period: int = 6  # shared attention block applied every N ssm blocks

    # -- VLM ----------------------------------------------------------------------
    use_mrope: bool = False
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)  # t/h/w split of head_dim/2
    n_vision_tokens: int = 0  # prefix patch embeddings provided by the stub

    # -- audio (Whisper-style enc-dec) ------------------------------------------------
    n_encoder_layers: int = 0
    n_audio_frames: int = 1500  # 30 s of 10 ms frames after conv stub

    # -- serving -----------------------------------------------------------------------
    sliding_window: Optional[int] = None  # ring-buffer decode window

    # ------------------------------------------------------------------
    def __post_init__(self):
        if self.arch_type not in (
            "dense", "moe", "ssm", "hybrid", "vlm", "audio"
        ):
            raise ValueError(f"unknown arch_type {self.arch_type!r}")
        if self.n_heads and self.n_kv_heads and self.n_heads % self.n_kv_heads:
            raise ValueError("n_heads must be divisible by n_kv_heads")

    @property
    def hd(self) -> int:
        if self.head_dim is not None:
            return self.head_dim
        return self.d_model // max(1, self.n_heads)

    @property
    def d_inner(self) -> int:
        """SSM inner width."""
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def is_attention_free(self) -> bool:
        return self.arch_type == "ssm"

    @property
    def group_size(self) -> int:
        return self.n_heads // max(1, self.n_kv_heads)

    # -- parameter counting (roofline MODEL_FLOPS) --------------------------------
    def param_count(self, active_only: bool = False) -> int:
        """Approximate parameter count; ``active_only`` counts only the
        parameters touched per token (MoE: top_k + shared experts)."""
        d, hd = self.d_model, self.hd
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)

        def attn_params() -> int:
            if self.use_mla:
                q = d * self.n_heads * (self.hd + self.rope_head_dim)
                kv_a = d * (self.kv_lora_rank + self.rope_head_dim)
                kv_b = self.kv_lora_rank * self.n_heads * (self.hd + self.hd)
                o = self.n_heads * self.hd * d
                return q + kv_a + kv_b + o
            q = d * self.n_heads * hd
            kv = 2 * d * self.n_kv_heads * hd
            o = self.n_heads * hd * d
            return q + kv + o

        def mlp_params(width: int) -> int:
            return 3 * d * width  # SwiGLU: gate+up+down

        def moe_params() -> int:
            router = d * self.n_experts
            experts = self.n_experts * mlp_params(self.d_ff_expert)
            shared = self.n_shared_experts * mlp_params(self.d_ff_expert)
            if active_only:
                experts = self.top_k * mlp_params(self.d_ff_expert)
            return router + experts + shared

        def ssm_params() -> int:
            di = self.d_inner
            gn = self.ssm_groups * self.ssm_state
            in_proj = d * (2 * di + 2 * gn + self.n_ssm_heads)
            conv = (di + 2 * gn) * self.conv_kernel
            out = di * d
            return in_proj + conv + out + di

        per_layer = 0
        if self.arch_type in ("dense", "vlm"):
            per_layer = attn_params() + mlp_params(self.d_ff)
            total = self.n_layers * per_layer
        elif self.arch_type == "moe":
            total = self.n_layers * (attn_params() + moe_params())
        elif self.arch_type == "ssm":
            total = self.n_layers * ssm_params()
        elif self.arch_type == "hybrid":
            n_shared_applications = self.n_layers // self.attn_period
            shared_block = attn_params() + mlp_params(self.d_ff)
            total = self.n_layers * ssm_params() + shared_block  # weights shared
            del n_shared_applications
        elif self.arch_type == "audio":
            enc = self.n_encoder_layers * (attn_params() + mlp_params(self.d_ff))
            dec = self.n_layers * (2 * attn_params() + mlp_params(self.d_ff))
            total = enc + dec
        else:  # pragma: no cover
            raise AssertionError
        return int(total + emb)

    # ------------------------------------------------------------------
    def reduced(self, **overrides) -> "ModelConfig":
        """Smoke-test variant: ≤2 layers, d_model ≤ 512, ≤4 experts."""
        kw = dataclasses.asdict(self)
        d = min(self.d_model, 256)
        heads = min(self.n_heads, 4)
        kv = min(self.n_kv_heads, heads)
        if heads and (kv == 0 or heads % kv):
            kv = 1
        hd = d // heads if heads else None
        sections = (
            (hd // 4, hd // 8, hd // 8) if heads else self.mrope_sections
        )
        kw.update(
            name=self.name + "-smoke",
            n_layers=2,
            d_model=d,
            n_heads=heads,
            n_kv_heads=kv,
            head_dim=hd,
            d_ff=min(self.d_ff, 512) or 0,
            vocab=min(self.vocab, 512),
            n_experts=min(self.n_experts, 4),
            top_k=min(self.top_k, 2),
            n_shared_experts=min(self.n_shared_experts, 1),
            d_ff_expert=min(self.d_ff_expert, 128),
            kv_lora_rank=min(self.kv_lora_rank, 32),
            q_lora_rank=min(self.q_lora_rank, 32),
            rope_head_dim=min(self.rope_head_dim, 16),
            ssm_state=min(self.ssm_state, 16),
            ssm_head_dim=min(self.ssm_head_dim, 32),
            ssm_chunk=16,
            attn_period=2,
            n_encoder_layers=2 if self.n_encoder_layers else 0,
            n_audio_frames=32 if self.arch_type == "audio" else self.n_audio_frames,
            mrope_sections=sections,
            sliding_window=(64 if self.sliding_window else None),
        )
        kw.update(overrides)
        return ModelConfig(**kw)


@dataclasses.dataclass(frozen=True)
class InputShape:
    """One of the four assigned input shapes."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}

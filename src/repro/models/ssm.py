"""Mamba-2 block (SSD — state-space duality, arXiv:2405.21060).

Projection layout follows the Mamba-2 reference: a single input projection
produces [z | x | B | C | dt]; a depthwise causal conv runs over [x | B | C];
the SSD scan computes the state-space recurrence per head; gating with
silu(z) and an output projection close the block.

Decode keeps two pieces of per-layer state:
  conv_state : (B, conv_kernel-1, d_conv_in)   — causal conv tail
  ssm_state  : (B, H, P, N)                     — SSD recurrent state
so per-token decode cost is O(1) in sequence length (the reason the
``long_500k`` shape is natural for this family).
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops


def _split_proj(zxbcdt, cfg):
    di = cfg.d_inner
    h, n = cfg.n_ssm_heads, cfg.ssm_state
    g = cfg.ssm_groups
    sizes = [di, di, g * n, g * n, h]
    z, xs, b, c, dt = jnp.split(
        zxbcdt, [sizes[0], sizes[0] + sizes[1],
                 sizes[0] + sizes[1] + sizes[2],
                 sizes[0] + sizes[1] + sizes[2] + sizes[3]],
        axis=-1,
    )
    return z, xs, b, c, dt


def _conv_input(xs, b, c):
    return jnp.concatenate([xs, b, c], axis=-1)


def _causal_conv(x: jax.Array, w: jax.Array, bias: jax.Array) -> jax.Array:
    """Depthwise causal 1-D conv.  x: (B, S, C); w: (K, C)."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(
        xp[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(k)
    )
    return out + bias


def mamba2_block(
    x: jax.Array,
    p: Dict[str, jax.Array],
    cfg,
    *,
    initial_state: Optional[jax.Array] = None,
    impl: str = "ref",
    unroll: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Full-sequence Mamba-2 block.  x: (B, S, D).
    Returns (y (B,S,D), final ssm state (B,H,P,N))."""
    bsz, s, _ = x.shape
    h, pdim, n = cfg.n_ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    g = cfg.ssm_groups
    zxbcdt = x @ p["w_in"]
    z, xs, b, c, dt = _split_proj(zxbcdt, cfg)
    conv_in = _conv_input(xs, b, c)
    conv_out = jax.nn.silu(_causal_conv(conv_in, p["conv_w"], p["conv_b"]))
    di = cfg.d_inner
    xs = conv_out[..., :di]
    b = conv_out[..., di : di + g * n].reshape(bsz, s, g, n)
    c = conv_out[..., di + g * n :].reshape(bsz, s, g, n)
    # Broadcast group-shared B/C to SSD heads.
    b = jnp.repeat(b, h // g, axis=2)
    c = jnp.repeat(c, h // g, axis=2)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["a_log"].astype(jnp.float32))  # (H,)
    xh = xs.reshape(bsz, s, h, pdim)
    y, state = kops.ssd_scan(
        xh, dt, a, b, c,
        initial_state=initial_state, chunk=cfg.ssm_chunk, impl=impl,
        unroll=unroll,
    )
    y = y + xh * p["d_skip"][None, None, :, None]
    y = y.reshape(bsz, s, di) * jax.nn.silu(z)
    return (y @ p["w_out"]).astype(x.dtype), state


def mamba2_decode(
    x: jax.Array,
    p: Dict[str, jax.Array],
    cfg,
    conv_state: jax.Array,
    ssm_state: jax.Array,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One-token decode.  x: (B, D).
    conv_state: (B, K-1, conv_channels); ssm_state: (B, H, P, N).
    Returns (y (B, D), new_conv_state, new_ssm_state)."""
    bsz, _ = x.shape
    h, pdim, n = cfg.n_ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    g = cfg.ssm_groups
    zxbcdt = x @ p["w_in"]
    z, xs, b, c, dt = _split_proj(zxbcdt[:, None, :], cfg)
    conv_in = _conv_input(xs, b, c)[:, 0]  # (B, C)
    # Causal conv over [state ‖ new]: the last K positions.
    k = p["conv_w"].shape[0]
    window = jnp.concatenate([conv_state, conv_in[:, None, :]], axis=1)  # (B,K,C)
    conv_out = jnp.einsum("bkc,kc->bc", window, p["conv_w"]) + p["conv_b"]
    conv_out = jax.nn.silu(conv_out)
    new_conv_state = window[:, 1:]
    di = cfg.d_inner
    xs1 = conv_out[:, :di].reshape(bsz, h, pdim)
    b1 = conv_out[:, di : di + g * n].reshape(bsz, g, n)
    c1 = conv_out[:, di + g * n :].reshape(bsz, g, n)
    b1 = jnp.repeat(b1, h // g, axis=1)
    c1 = jnp.repeat(c1, h // g, axis=1)
    dt1 = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    y, new_ssm = kops.ssd_decode(xs1, dt1, a, b1, c1, ssm_state)
    y = y + xs1 * p["d_skip"][None, :, None]
    y = y.reshape(bsz, di) * jax.nn.silu(z[:, 0])
    return (y @ p["w_out"]).astype(x.dtype), new_conv_state, new_ssm


def conv_channels(cfg) -> int:
    return cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state

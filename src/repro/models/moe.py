"""Mixture-of-Experts FFN (Qwen3-MoE / DeepSeek-V2 style).

Three dispatch strategies:

* ``scan``   — lax.scan over experts with masked accumulation.  Memory-light
  and trivially shardable, but HLO FLOPs scale with n_experts instead of
  top_k (every expert touches every token).  Used for smoke tests and as
  the conservative lowering fallback.  Dropless.
* ``sorted`` — sort token-replicas by expert id and run a grouped matmul
  (the ``moe_gmm`` kernel / its jnp oracle), then scatter back.  HLO FLOPs
  ∝ top_k.  Dropless.  Under GSPMD the *global* argsort/gather generate
  enormous resharding collectives (the §Perf baseline finding) — fine on
  one device, pathological on a 256-chip mesh.
* ``ep``     — expert-parallel via ``shard_map`` (the §Perf optimized
  path): activations stay replicated across the ``model`` axis, each
  model shard sorts *locally* for its own E/|model| experts with a fixed
  capacity (GShard-style drops beyond ``capacity_factor``), runs the
  grouped matmul on local expert weights, and a single psum over
  ``model`` combines — collective cost identical to one row-parallel
  matmul per layer instead of a global sort.

Routing: softmax top-k with renormalisation over the selected experts
(Qwen3/DeepSeek convention), plus optional always-on shared experts
(DeepSeek-V2: 2 shared + 160 routed).  An auxiliary load-balance loss is
returned for training.
"""

from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map

from repro.kernels import ops as kops


def route(
    x: jax.Array, router_w: jax.Array, top_k: int
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Returns (gates (T, K) fp32, expert_idx (T, K) int32, aux_loss ())."""
    logits = (x.astype(jnp.float32) @ router_w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, top_k)
    gates = gates / jnp.sum(gates, axis=-1, keepdims=True)
    # Switch-style load-balance auxiliary loss.
    n_e = router_w.shape[-1]
    density = jnp.mean(
        jax.nn.one_hot(idx[..., 0], n_e, dtype=jnp.float32), axis=0
    )
    mean_probs = jnp.mean(probs, axis=0)
    aux = n_e * jnp.sum(density * mean_probs)
    return gates, idx, aux


def _expert_ffn(x: jax.Array, wg, wu, wd) -> jax.Array:
    return (jax.nn.silu(x @ wg) * (x @ wu)) @ wd


def moe_ffn(
    x: jax.Array,
    p: Dict[str, jax.Array],
    *,
    top_k: int,
    dispatch: str = "sorted",
    impl: str = "ref",
    mesh: Optional[Mesh] = None,
    capacity_factor: float = 1.5,
) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, D).  Params:
      router : (D, E)
      wg, wu : (E, D, F)    wd : (E, F, D)
      shared_wg/wu/wd (optional): (D, F*n_shared) / (F*n_shared, D)
    Returns (y (B,S,D), aux_loss)."""
    b, s, d = x.shape
    xt = x.reshape(b * s, d)

    if dispatch == "ep":
        if mesh is None:
            raise ValueError("dispatch='ep' requires a mesh")
        y, aux = _moe_ep(
            xt, p, top_k=top_k, mesh=mesh,
            capacity_factor=capacity_factor, impl=impl,
        )
        return y.reshape(b, s, d).astype(x.dtype), aux

    gates, idx, aux = route(xt, p["router"], top_k)
    if dispatch == "scan":
        y = _moe_scan(xt, p, gates, idx)
    elif dispatch == "sorted":
        y = _moe_sorted(xt, p, gates, idx, impl=impl)
    else:
        raise ValueError(f"unknown dispatch {dispatch!r}")

    if "shared_wg" in p:
        y = y + _expert_ffn(xt, p["shared_wg"], p["shared_wu"], p["shared_wd"])
    return y.reshape(b, s, d).astype(x.dtype), aux


def _moe_scan(xt, p, gates, idx) -> jax.Array:
    n_e = p["router"].shape[-1]
    # (T, E) combined gate for each expert (0 when not selected).
    combine = jnp.zeros((xt.shape[0], n_e), dtype=jnp.float32)
    combine = jax.vmap(
        lambda c, i, g: c.at[i].add(g), in_axes=(0, 0, 0)
    )(combine, idx, gates)

    def body(acc, ew):
        wg, wu, wd, gate_col = ew
        out = _expert_ffn(xt, wg, wu, wd)
        return acc + out * gate_col[:, None].astype(out.dtype), None

    acc0 = jnp.zeros_like(xt)
    gate_cols = jnp.moveaxis(combine, 1, 0)  # (E, T)
    y, _ = jax.lax.scan(body, acc0, (p["wg"], p["wu"], p["wd"], gate_cols))
    return y


def _moe_ep(
    xt: jax.Array,
    p: Dict[str, jax.Array],
    *,
    top_k: int,
    mesh: Mesh,
    capacity_factor: float,
    impl: str,
) -> Tuple[jax.Array, jax.Array]:
    """Expert-parallel dispatch (see module docstring).

    Sharding contract: tokens over the data axes, experts over ``model``;
    activations replicated across ``model`` (each model shard sees every
    local token and contributes only its own experts' outputs, combined
    by one psum — the same wire cost as a row-parallel matmul).
    Overflow beyond ``capacity_factor × expected`` rows per shard is
    dropped (GShard-style), biased toward high local expert ids; the
    router's load-balance aux loss keeps drops rare in training.
    """
    dp = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    t_total, d = xt.shape
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    tok_spec = dp if t_total % dp_size == 0 else None
    n_e = p["router"].shape[-1]
    e_loc = n_e // mesh.shape["model"]
    has_shared = "shared_wg" in p

    def body(x_loc, router, wg, wu, wd, *shared):
        t_loc = x_loc.shape[0]
        cap = max(
            top_k,
            int(capacity_factor * t_loc * top_k * e_loc / n_e),
        )
        cap = min(cap, t_loc * top_k)
        gates, idx, aux = route(x_loc, router, top_k)
        midx = jax.lax.axis_index("model")
        lo = midx * e_loc
        flat_e = idx.reshape(-1)
        flat_g = gates.reshape(-1)
        # Local rows first (stable by local expert id); remote rows sort
        # to the sentinel bucket e_loc.
        local_e = flat_e - lo
        key = jnp.where((local_e >= 0) & (local_e < e_loc), local_e, e_loc)
        order = jnp.argsort(key, stable=True)
        take = order[:cap]
        e_sel = key[take]
        valid = e_sel < e_loc
        g_sel = flat_g[take] * valid
        rows = take // top_k
        x_sel = x_loc[rows]
        # Overflow/sentinel rows ride along in the last expert's group
        # with zero gate (harmless compute, no global effect).
        sizes = jnp.bincount(jnp.minimum(e_sel, e_loc - 1), length=e_loc)
        h = kops.moe_gmm(x_sel, wg, sizes, impl=impl)
        u = kops.moe_gmm(x_sel, wu, sizes, impl=impl)
        o = kops.moe_gmm(jax.nn.silu(h) * u, wd, sizes, impl=impl)
        o = o * g_sel[:, None].astype(o.dtype)
        y = jnp.zeros((t_loc, d), o.dtype).at[rows].add(o)
        if shared:
            # Shared experts are tensor-parallel over the same model axis:
            # this shard computes a partial over its d_ff slice and the
            # psum below completes the row-parallel sum.
            swg, swu, swd = shared
            y = y + _expert_ffn(x_loc, swg, swu, swd)
        y = jax.lax.psum(y, "model")
        aux = jax.lax.pmean(aux, "model")
        aux = jax.lax.pmean(aux, dp) if tok_spec else aux
        return y, jnp.reshape(aux, (1,))

    shared_args = (
        (p["shared_wg"], p["shared_wu"], p["shared_wd"]) if has_shared else ()
    )
    shared_specs = (
        (P(None, "model"), P(None, "model"), P("model", None))
        if has_shared
        else ()
    )
    y, aux = shard_map(
        body,
        mesh=mesh,
        in_specs=(
            P(tok_spec, None),            # tokens over data axes
            P(None, None),                # router replicated
            P("model", None, None),       # expert banks over model
            P("model", None, None),
            P("model", None, None),
            *shared_specs,                # shared experts tensor-parallel
        ),
        out_specs=(P(tok_spec, None), P(None)),
        check_vma=False,
    )(xt, p["router"], p["wg"], p["wu"], p["wd"], *shared_args)
    return y, aux[0]


def _moe_sorted(xt, p, gates, idx, *, impl: str) -> jax.Array:
    t, d = xt.shape
    k = idx.shape[-1]
    n_e = p["router"].shape[-1]
    flat_idx = idx.reshape(-1)            # (T*K,)
    flat_gates = gates.reshape(-1)        # (T*K,)
    order = jnp.argsort(flat_idx)         # stable sort by expert
    inv_order = jnp.argsort(order)
    rows = jnp.repeat(jnp.arange(t), k)[order]
    x_sorted = xt[rows]                   # (T*K, D)
    group_sizes = jnp.bincount(flat_idx, length=n_e)
    h = kops.moe_gmm(x_sorted, p["wg"], group_sizes, impl=impl)
    u = kops.moe_gmm(x_sorted, p["wu"], group_sizes, impl=impl)
    h = jax.nn.silu(h) * u
    out_sorted = kops.moe_gmm(h, p["wd"], group_sizes, impl=impl)
    out = out_sorted[inv_order] * flat_gates[:, None].astype(out_sorted.dtype)
    y = jnp.zeros((t, d), dtype=out.dtype)
    y = y.at[jnp.repeat(jnp.arange(t), k)].add(out)
    return y

"""Live cluster health plane (core/healthplane.py): deterministic
t-digest quantile sketches (property-tested against exact quantiles),
windowed series, online detectors, gossiped health digests with bounded
staleness, the schema-versioned summary, and Eq. 2 cost-model
calibration against measured span breakdowns."""

import json
import math
import os
import random

import pytest

from chaos import run_churn_sim
from hypothesis_compat import given, settings, st
from repro.core import (
    GossipConfig,
    HealthConfig,
    HealthMonitor,
    ProfileRepository,
    QuantileSketch,
    SimReport,
    fleet,
    validate_schema,
)
from repro.core.healthplane import (
    CALIBRATION_COMPONENTS,
    MEMORY_THRASH,
    QUEUE_BUILDUP,
    SPINE_SATURATION,
    STRAGGLER,
    WindowedSeries,
    _PipeUtilization,
    calibrate,
)
from repro.core.sst_exchange import GossipPlane, pack_row, unpack_rows
from repro.core.state import SSTRow
from repro.core.types import DFG, Job, MB, TaskSpec
from repro.sim import Simulation, fleet_scaled_rate, poisson_workload
from repro.workflows import MODELS, paper_dfgs

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def load_schema(name):
    with open(os.path.join(REPO, "schemas", name)) as f:
        return json.load(f)


def exact_quantile(data, q):
    """Nearest-rank exact quantile with linear interpolation (the
    reference the sketch is pinned against)."""
    s = sorted(data)
    if len(s) == 1:
        return s[0]
    pos = q * (len(s) - 1)
    lo = int(math.floor(pos))
    hi = min(lo + 1, len(s) - 1)
    return s[lo] + (s[hi] - s[lo]) * (pos - lo)


def assert_sketch_close(sketch, data, qs=(0.5, 0.9, 0.99), rank_eps=0.03):
    """The sketch's quantile must land between the exact quantiles at
    q ± rank_eps — the rank-error contract of a merging t-digest — give
    or take 5% of the data range (linear interpolation across a heavy
    duplicate-value centroid smears in value space even when the rank
    is exact).  For tiny samples the centroid-midpoint interpolation is
    only accurate to about one rank, so the bracket widens to 1.5/n."""
    rank_eps = max(rank_eps, 1.5 / len(data))
    slack = 0.05 * (max(data) - min(data))
    for q in qs:
        lo = exact_quantile(data, max(0.0, q - rank_eps))
        hi = exact_quantile(data, min(1.0, q + rank_eps))
        v = sketch.quantile(q)
        assert lo - slack - 1e-9 <= v <= hi + slack + 1e-9, (
            f"q={q}: sketch {v} outside exact bracket [{lo}, {hi}] "
            f"± {slack} (n={sketch.count})"
        )


# ---------------------------------------------------------------------------
# Quantile sketch: accuracy, merge, determinism
# ---------------------------------------------------------------------------
@settings(max_examples=30, deadline=None)
@given(
    st.lists(
        st.floats(min_value=-1e6, max_value=1e6,
                  allow_nan=False, allow_infinity=False),
        min_size=1, max_size=400,
    )
)
def test_sketch_tracks_exact_quantiles(data):
    sk = QuantileSketch()
    for v in data:
        sk.add(v)
    assert sk.count == len(data)
    assert sk.min == min(data) and sk.max == max(data)
    assert_sketch_close(sk, data)


@settings(max_examples=20, deadline=None)
@given(
    st.lists(
        st.floats(min_value=0.0, max_value=1e3,
                  allow_nan=False, allow_infinity=False),
        min_size=2, max_size=300,
    ),
    st.integers(min_value=1, max_value=5),
)
def test_sketch_merge_matches_pooled_data(data, n_shards):
    """Merging per-shard sketches approximates the pooled distribution
    (the fleet-level rollup path in HealthMonitor.summary)."""
    shards = [QuantileSketch() for _ in range(n_shards)]
    for i, v in enumerate(data):
        shards[i % n_shards].add(v)
    merged = QuantileSketch()
    for s in shards:
        merged.merge(s)
    assert merged.count == len(data)
    assert merged.min == min(data) and merged.max == max(data)
    assert_sketch_close(merged, data, rank_eps=max(0.08, 3.0 / len(data)))


def test_sketch_bitwise_deterministic():
    def build(seed):
        rng = random.Random(seed)
        sk = QuantileSketch()
        for _ in range(5000):
            sk.add(rng.lognormvariate(0.0, 1.0))
        return sk

    a, b = build(7), build(7)
    assert a.centroids() == b.centroids()
    assert a.as_dict() == b.as_dict()
    # Merge is deterministic too.
    c, d = QuantileSketch(), QuantileSketch()
    c.merge(a), c.merge(build(8))
    d.merge(b), d.merge(build(8))
    assert c.centroids() == d.centroids()


def test_sketch_accuracy_on_heavy_tail():
    rng = random.Random(3)
    data = [rng.lognormvariate(0.0, 1.5) for _ in range(20000)]
    sk = QuantileSketch()
    for v in data:
        sk.add(v)
    for q in (0.5, 0.9, 0.99):
        exact = exact_quantile(data, q)
        assert abs(sk.quantile(q) - exact) / exact < 0.05


def test_sketch_empty_and_single():
    sk = QuantileSketch()
    assert sk.as_dict()["count"] == 0
    sk.add(2.5)
    for q in (0.0, 0.5, 1.0):
        assert sk.quantile(q) == 2.5


# ---------------------------------------------------------------------------
# Windowed series + pipe utilization
# ---------------------------------------------------------------------------
def test_windowed_series_fixed_windows():
    s = WindowedSeries(window_s=1.0, max_windows=4)
    for t, v in [(0.1, 2.0), (0.9, 4.0), (1.5, 1.0), (7.2, 9.0)]:
        s.observe(t, v)
    assert [w.index for w in s.windows] == [0, 1, 7]
    w0 = s.windows[0]
    assert (w0.count, w0.min, w0.max, w0.mean) == (2, 2.0, 4.0, 3.0)
    assert s.overall_max() == 9.0


def test_windowed_series_bounded_memory():
    s = WindowedSeries(window_s=1.0, max_windows=3)
    for t in range(10):
        s.observe(float(t), 1.0)
    assert len(s.windows) == 3
    assert [w.index for w in s.windows] == [7, 8, 9]


def test_pipe_utilization_integrates_busy_time():
    p = _PipeUtilization(window_s=1.0, max_windows=8)
    assert p.utilization(1.0) == 0.0
    p.update(0.0, True)
    p.update(0.5, False)   # busy [0, 0.5): window 0 gets 0.5
    p.update(2.0, True)    # busy [2.0, 4.0): windows 2, 3 get 1.0 each
    # Mean busy fraction over the retained (touched) windows {0, 2, 3}.
    assert abs(p.utilization(4.0) - (0.5 + 1.0 + 1.0) / 3.0) < 1e-9
    # A fully busy pipe pins at 1.0.
    q = _PipeUtilization(window_s=1.0, max_windows=8)
    q.update(0.0, True)
    assert q.utilization(5.0) == 1.0


# ---------------------------------------------------------------------------
# Detectors (unit level)
# ---------------------------------------------------------------------------
def test_straggler_detector_threshold():
    hm = HealthMonitor(1)
    hm.task_done(0, 1.0, service_s=0.2, expected_s=0.1)   # 2x: no
    hm.task_done(0, 2.0, service_s=0.31, expected_s=0.1)  # 3.1x: yes
    hm.task_done(0, 3.0, service_s=0.04, expected_s=0.01) # 4x but < floor
    assert hm.counts[STRAGGLER] == 1
    (e,) = [e for e in hm.events if e.kind == STRAGGLER]
    assert e.worker == 0 and e.value == 0.31


def test_queue_buildup_needs_consecutive_samples():
    hm = HealthMonitor(2)
    for t in (1.0, 2.0):
        hm.sample_queue(0, t, 9)
    hm.sample_queue(0, 3.0, 2)    # dip resets the streak
    for t in (4.0, 5.0, 6.0):
        hm.sample_queue(0, t, 10)
    assert hm.counts[QUEUE_BUILDUP] == 1
    # Fires once at the Nth sample, not on every subsequent one.
    hm.sample_queue(0, 7.0, 11)
    assert hm.counts[QUEUE_BUILDUP] == 1


def test_memory_thrash_per_window():
    hm = HealthMonitor(1, HealthConfig(thrash_evictions_per_window=4))
    hm.sample_memory(0, 0.1, 0.9, evictions_total=2)   # 2 in window 0
    hm.sample_memory(0, 0.5, 0.9, evictions_total=5)   # 5 in window 0: fire
    assert hm.counts[MEMORY_THRASH] == 1
    # Next window starts a fresh count.
    hm.sample_memory(0, 1.2, 0.9, evictions_total=7)   # 2 in window 1
    assert hm.counts[MEMORY_THRASH] == 1
    hm.sample_memory(0, 1.8, 0.9, evictions_total=10)  # 5 in window 1
    assert hm.counts[MEMORY_THRASH] == 2


def test_spine_saturation_consecutive_contended():
    hm = HealthMonitor(4)
    for i in range(3):
        hm.on_transfer(float(i), "spine.rack0", 1e6, 0.25, cross=True)
    hm.on_transfer(3.0, "spine.rack0", 1e6, 0.5, cross=True)  # reset
    for i in range(4):
        hm.on_transfer(4.0 + i, "spine.rack0", 1e6, 0.3, cross=True)
    assert hm.counts[SPINE_SATURATION] == 1
    (e,) = [e for e in hm.events if e.kind == SPINE_SATURATION]
    assert e.worker == -1  # fleet-scoped


# ---------------------------------------------------------------------------
# Detectors (engine level: injected faults)
# ---------------------------------------------------------------------------
def _base(fleet_name="uniform"):
    cluster = fleet(fleet_name)
    profiles = ProfileRepository(cluster, MODELS)
    dfgs = paper_dfgs()
    for d in dfgs:
        profiles.register(d)
    return cluster, profiles, dfgs


def test_injected_straggler_flagged():
    """Heavy runtime noise (lognormal sigma 1.2) injects real stragglers;
    the nominal-noise control run flags none."""
    cluster, profiles, dfgs = _base()
    jobs = poisson_workload(
        dfgs, fleet_scaled_rate(cluster, 1.5), 30.0, seed=7
    )
    noisy = Simulation(
        cluster, profiles, MODELS, scheduler="navigator", seed=1,
        runtime_noise_sigma=1.2, health=True,
    ).run(jobs)
    assert noisy.health.counts[STRAGGLER] > 0
    for e in noisy.health.events:
        if e.kind == STRAGGLER:
            assert e.value >= e.threshold > 0.0

    control = Simulation(
        cluster, profiles, MODELS, scheduler="navigator", seed=1,
        runtime_noise_sigma=0.25, health=True,
    ).run(jobs)
    assert control.health.counts[STRAGGLER] == 0


def test_injected_spine_saturation_flagged():
    """A 16-way fan-out burst across the rack2 spine drives >= 3
    concurrent flows onto one uplink (share <= 1/3) and must trip the
    fleet-scoped spine-saturation detector."""
    cluster = fleet("rack2")
    tasks = [TaskSpec("src", 0.05, model_id=None, output_bytes=8 * MB)]
    edges = []
    for i in range(16):
        tasks.append(TaskSpec(f"c{i}", 0.05, model_id=None,
                              output_bytes=0.01 * MB))
        edges.append(("src", f"c{i}"))
    dfg = DFG("fan", tasks=tasks, edges=edges)
    profiles = ProfileRepository(cluster, MODELS)
    profiles.register(dfg)
    res = Simulation(
        cluster, profiles, MODELS, scheduler="hash", seed=1, health=True
    ).run([Job(job_id=0, dfg=dfg, arrival_time=0.0)])
    assert res.health.counts[SPINE_SATURATION] >= 1
    (e, *_rest) = [
        e for e in res.health.events if e.kind == SPINE_SATURATION
    ]
    assert e.worker == -1 and e.value <= e.threshold
    assert e.detail.startswith("uplink spine.")


# ---------------------------------------------------------------------------
# Gossiped digests: convergence with bounded staleness
# ---------------------------------------------------------------------------
def _drive_rounds(plane, t0, rounds, period=0.2):
    t = t0
    for _ in range(rounds):
        t += period
        for w in range(plane.n_workers):
            for dst, updates, _nbytes in plane.exchange(w, t):
                plane.deliver(dst, updates, t)
    return t


@settings(max_examples=15, deadline=None)
@given(
    st.integers(min_value=3, max_value=12),
    st.integers(min_value=0, max_value=1000),
)
def test_health_digest_gossip_converges(n, seed):
    """Every reader sees the published digest within n gossip rounds at
    fanout 2 (epidemic spread reaches all n workers in O(log n) rounds;
    n is a loose deterministic bound), so view staleness is bounded by
    rounds x period — no oracle."""
    plane = GossipPlane(n, GossipConfig(period_s=0.2, fanout=2), seed=seed)
    plane.update_health(0, queue_depth=7, mem_occupancy=0.625,
                        fetch_util=0.25, p99_latency_s=1.5, now=0.0)
    _drive_rounds(plane, 0.0, rounds=n)
    for reader in range(n):
        row = plane.views[reader][0]
        assert row.health_queue_depth == 7
        assert row.health_mem_occupancy == 0.625
        assert row.health_fetch_util == 0.25
        assert row.health_p99_latency_s == 1.5


def test_health_digest_refresh_supersedes():
    """A refreshed digest (higher row version) replaces the stale one at
    every reader; nobody regresses to the old values."""
    n = 6
    plane = GossipPlane(n, GossipConfig(period_s=0.2, fanout=2), seed=5)
    plane.update_health(2, 3, 0.5, 0.1, 0.8, now=0.0)
    t = _drive_rounds(plane, 0.0, rounds=n)
    plane.update_health(2, 9, 0.9, 0.7, 2.5, now=t)
    _drive_rounds(plane, t, rounds=n)
    for reader in range(n):
        assert plane.views[reader][2].health_queue_depth == 9
        assert plane.views[reader][2].health_p99_latency_s == 2.5


def test_health_lanes_pack_roundtrip():
    row = SSTRow(health_queue_depth=11, health_mem_occupancy=0.75,
                 health_fetch_util=0.5, health_p99_latency_s=2.25)
    (back,) = unpack_rows(pack_row(row)[None, :])
    assert back.health_queue_depth == 11
    assert back.health_mem_occupancy == 0.75   # exact in float32
    assert back.health_fetch_util == 0.5
    assert back.health_p99_latency_s == 2.25


def test_sim_publishes_digests_to_sst():
    """End to end: after a health-enabled run, live readers' SST replicas
    carry non-trivial digests for the busy workers."""
    res, jobs, schedule, sim = run_churn_sim(
        "navigator", schedule=[], duration=30.0, health=True,
        return_sim=True,
    )
    rows = sim.sst.view(0, res.horizon)
    assert any(r.health_p99_latency_s > 0.0 for r in rows), (
        "no worker's gossiped digest ever carried a task-latency p99"
    )


# ---------------------------------------------------------------------------
# Summary payload + engine integration
# ---------------------------------------------------------------------------
def test_health_summary_validates_and_counts():
    res, jobs, schedule = run_churn_sim("navigator", health=True, trace=True)
    s = res.health.summary()
    validate_schema(s, load_schema("health.schema.json"))
    assert s["schema_version"] == 1
    assert len(s["workers"]) == res.n_workers
    assert s["fleet_job_latency"]["count"] == len(res.records)
    total_tasks = sum(w["task_latency"]["count"] for w in s["workers"])
    assert s["fleet_task_latency"]["count"] == total_tasks
    # Detector counts mirror the metrics export and the event ledger.
    for kind, count in s["detectors"].items():
        assert count == int(res.metrics.value("health.events", kind=kind))
    assert len(s["events"]) <= res.health.config.max_events
    # Health events also land in the flight recorder's stream.
    kinds = {json.loads(line)["kind"]
             for line in res.trace.to_jsonl().splitlines()}
    for kind, count in s["detectors"].items():
        if count:
            assert kind in kinds


def test_health_summary_raises_when_off():
    res, jobs, schedule = run_churn_sim("navigator", duration=10.0,
                                        trace=True)
    assert res.health is None
    with pytest.raises(ValueError, match="health=True"):
        SimReport(res).health_summary()


# ---------------------------------------------------------------------------
# Eq. 2 cost-model calibration
# ---------------------------------------------------------------------------
def _traced_report(noise=0.25, scheduler="navigator"):
    cluster, profiles, dfgs = _base()
    jobs = poisson_workload(
        dfgs, fleet_scaled_rate(cluster, 1.5), 30.0, seed=7
    )
    res = Simulation(
        cluster, profiles, MODELS, scheduler=scheduler, seed=1,
        runtime_noise_sigma=noise, trace=True,
    ).run(jobs)
    return SimReport(res)


def test_calibration_joins_all_spans():
    report = _traced_report()
    cal = report.calibration()
    assert cal.joined > 0 and cal.unmatched == 0
    for name in CALIBRATION_COMPONENTS:
        assert cal.components[name].count == cal.joined
    assert cal.worst_component() in CALIBRATION_COMPONENTS
    table = cal.format_table()
    assert "queue" in table and "runtime" in table


def test_calibration_runtime_exact_when_noise_off():
    """With runtime noise disabled, measured compute equals the profile
    prediction — the runtime component's residual must collapse to ~0
    while the join machinery still sees every span."""
    cal = _traced_report(noise=0.0).calibration()
    assert cal.joined > 0
    rt = cal.components["runtime"].as_dict()
    assert abs(rt["residual_abs_mean_s"]) < 1e-6, (
        f"noise-free runtime residual should vanish: {rt}"
    )


def test_calibration_deterministic_and_exported():
    a, b = _traced_report(), _traced_report()
    ca, cb = calibrate(a), calibrate(b)
    assert json.dumps(ca.as_dict(), sort_keys=True) == json.dumps(
        cb.as_dict(), sort_keys=True
    )
    reg = a.result.metrics
    ca.to_metrics(reg)
    validate_schema(reg.export(), load_schema("metrics.schema.json"))
    assert int(reg.value("calibration.joined",
                         scheduler="navigator")) == ca.joined


def test_calibration_requires_trace():
    res, jobs, schedule = run_churn_sim("navigator", duration=10.0)
    with pytest.raises(ValueError, match="trace"):
        SimReport(res)

"""Flight-recorder observability plane: span stitching, latency
breakdown, placement provenance, export schemas, determinism, and the
zero-overhead-when-off guarantee (§observability)."""

import json
import os
import tracemalloc

import pytest

from chaos import (
    SCRIPTED_SCHEDULE,
    check_trace_determinism,
    scripted_partition_schedule,
)
from repro.core import (
    GB,
    ClusterSpec,
    Job,
    ProfileRepository,
    SimReport,
    validate_schema,
)
from repro.core import telemetry as telemetry_mod
from repro.core.telemetry import FlightRecorder, TraceConfig
from repro.sim import Simulation, bursty_trace_workload
from repro.workflows import MODELS, paper_dfgs, translation_dfg

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def make_profiles(cluster):
    p = ProfileRepository(cluster, MODELS)
    for d in paper_dfgs():
        p.register(d)
    return p


def load_schema(name):
    with open(os.path.join(REPO, "schemas", name)) as f:
        return json.load(f)


class _FakeRecord:
    """Minimal JobRecord stand-in (SimReport reads job_id/arrival/finish)."""

    def __init__(self, job_id, arrival, finish):
        self.job_id, self.arrival, self.finish = job_id, arrival, finish

    @property
    def latency(self):
        return self.finish - self.arrival


class _FakeResult:
    def __init__(self, records, trace):
        self.records, self.trace = records, trace


@pytest.fixture(scope="module")
def traced():
    """One traced 30 s navigator run shared by the read-only tests."""
    cluster = ClusterSpec(n_workers=5)
    profiles = make_profiles(cluster)
    jobs = bursty_trace_workload(
        paper_dfgs(), base_rate_per_s=0.8, duration_s=30.0, seed=3
    )
    sim = Simulation(
        cluster, profiles, MODELS, scheduler="navigator", seed=1, trace=True
    )
    res = sim.run(jobs)
    return res, SimReport(res)


# --------------------------------------------------------------------------
# Span stitcher: hand-built 3-task DAG with a closed-form breakdown
# --------------------------------------------------------------------------
def _emit_diamond(rec, stall=0.0):
    """Job 7: entry tasks a (w0, cache hit) and b (w1, demand fetch),
    join task c (w0) gated by b.  With ``stall`` > 0, c's input shipment
    leaves ``stall`` seconds after b completes (a recovery re-staging
    gap), exercising the critical-path fallback rule.

    Closed form (stall=0): a queues 0.5 then computes 1.0; b ships its
    0.2 s entry payload, waits 0.8 s on the model fetch, computes 1.0;
    c ships b's output for 0.3 s, queues 0.5, computes 0.5.  Critical
    path c <- b; JCT 3.3 = queue 0.5 + input 0.2 + ship 0.3 + fetch 0.8
    + compute 1.5.
    """
    E = rec.emit
    E(0.0, "job.arrive", job=7)
    E(0.0, "task.input", worker=0,
      job=7, task="a", gen=0, src="", frm=-1, to=0, arrive=0.0)
    E(0.0, "task.input", worker=1,
      job=7, task="b", gen=0, src="", frm=-1, to=1, arrive=0.2)
    E(0.2, "fetch.start", worker=1,
      fetch_kind="demand", model=3, bytes=1.0 * GB, dur=0.8, job=7, task="b")
    E(0.5, "task.start", worker=0, job=7, task="a", gen=0, model=-1,
      miss=False)
    E(1.0, "fetch.done", worker=1, model=3, spec=False)
    E(1.0, "task.start", worker=1, job=7, task="b", gen=0, model=3,
      miss=True)
    E(1.5, "task.done", worker=0, job=7, task="a", gen=0)
    E(1.5, "task.input", worker=0,
      job=7, task="c", gen=0, src="a", frm=0, to=0, arrive=1.5)
    E(2.0, "task.done", worker=1, job=7, task="b", gen=0)
    t_ship = 2.0 + stall
    E(t_ship, "task.input", worker=0,
      job=7, task="c", gen=0, src="b", frm=1, to=0, arrive=t_ship + 0.3)
    E(t_ship + 0.8, "task.start", worker=0, job=7, task="c", gen=0,
      model=-1, miss=False)
    E(t_ship + 1.3, "task.done", worker=0, job=7, task="c", gen=0)
    E(t_ship + 1.3, "job.done", job=7, latency=t_ship + 1.3)
    return t_ship + 1.3


def test_span_stitcher_closed_form():
    rec = FlightRecorder(2)
    finish = _emit_diamond(rec)
    report = SimReport(_FakeResult([_FakeRecord(7, 0.0, finish)], rec))

    b = report.final_span(7, "b")
    assert b.worker == 1 and b.miss and b.model == 3
    assert b.t_send == 0.0 and b.t_ready == pytest.approx(0.2)
    assert b.input_s == pytest.approx(0.2)
    assert b.model_ready == pytest.approx(1.0)
    assert b.fetch_s == pytest.approx(0.8)
    assert b.queue_s == pytest.approx(0.0)
    assert b.compute_s == pytest.approx(1.0)

    c = report.final_span(7, "c")
    assert c.t_send == pytest.approx(2.0)
    assert c.t_ready == pytest.approx(2.3)
    assert c.input_s == pytest.approx(0.3)
    assert c.fetch_s == 0.0  # cache hit: no fetch on the span
    assert c.queue_s == pytest.approx(0.5)
    assert c.compute_s == pytest.approx(0.5)

    # b's completion gates c's shipment exactly -> path walks c <- b.
    assert report.critical_path(7) == [("c", 0), ("b", 0)]

    bd = report.latency_breakdown(7)
    assert bd.queue_s == pytest.approx(0.5)
    assert bd.input_transfer_s == pytest.approx(0.2)
    assert bd.output_ship_s == pytest.approx(0.3)
    assert bd.fetch_wait_s == pytest.approx(0.8)
    assert bd.compute_s == pytest.approx(1.5)
    assert bd.jct_s == pytest.approx(3.3)
    assert bd.components_sum_s == pytest.approx(bd.jct_s, abs=1e-12)

    # explain() falls back to the measured span when no decisions exist.
    assert "measured:" in report.explain("c", 7)


def test_critical_path_stall_absorbed_as_queueing():
    """With a 0.1 s gap between b's completion and c's shipment there is
    no exact-match predecessor; the walk falls back to the latest-
    arriving input's producer and the gap lands in queue_s."""
    rec = FlightRecorder(2)
    finish = _emit_diamond(rec, stall=0.1)
    report = SimReport(_FakeResult([_FakeRecord(7, 0.0, finish)], rec))

    assert report.critical_path(7) == [("c", 0), ("b", 0)]
    bd = report.latency_breakdown(7)
    assert bd.queue_s == pytest.approx(0.6)   # 0.5 dispatch + 0.1 stall
    assert bd.output_ship_s == pytest.approx(0.3)
    assert bd.components_sum_s == pytest.approx(bd.jct_s, abs=1e-12)


def test_simreport_requires_trace_and_flags_drops():
    with pytest.raises(ValueError):
        SimReport(_FakeResult([], None))
    rec = FlightRecorder(1, TraceConfig(ring_capacity=4))
    for i in range(10):
        rec.emit(float(i), "task.start", worker=0,
                 job=0, task="t", gen=0, model=-1, miss=False)
    assert rec.dropped == 6
    report = SimReport(_FakeResult([_FakeRecord(0, 0.0, 1.0)], rec))
    with pytest.raises(ValueError, match="dropped"):
        _ = report.spans


# --------------------------------------------------------------------------
# Traced simulation: breakdown exactness, provenance, export schemas
# --------------------------------------------------------------------------
def test_sim_breakdown_sums_to_jct(traced):
    res, report = traced
    assert res.trace is not None and res.trace.dropped == 0
    assert len(res.records) > 5
    for r in res.records:
        bd = report.latency_breakdown(r.job_id)
        assert abs(bd.components_sum_s - bd.jct_s) < 1e-6
        assert abs(bd.jct_s - r.latency) < 1e-9
    agg = report.latency_breakdown()
    assert agg["jobs"] == len(res.records)
    assert sum(agg["shares"].values()) == pytest.approx(1.0)


def test_provenance_eq2_recomposition(traced):
    """Every recorded candidate vector re-composes to its total (Eq. 2:
    max(queue, input) + model + runtime + liveness) and, absent an
    override note, the chosen worker is the candidate argmin."""
    res, _ = traced
    placements = res.trace.placements
    assert placements
    assert {"plan", "adjust"} <= {d.phase for d in placements}
    n_place_events = sum(
        1 for e in res.trace.events() if e[2] == "sched.place"
    )
    assert n_place_events == len(placements)
    for d in placements:
        chosen = d.candidate(d.chosen)
        assert chosen is not None, f"{d.task_id}: chosen worker not recorded"
        feasible = [c for c in d.candidates if c.total_s != float("inf")]
        assert feasible
        if not d.note:  # herd-sticky / hysteresis overrides carry a note
            best = min(c.total_s for c in feasible)
            assert chosen.total_s <= best + 1e-6
        if d.phase in ("plan", "jit", "recovery"):
            for c in feasible:
                recomposed = (max(c.queue_s, c.input_s) + c.model_s
                              + c.runtime_s + c.liveness_s)
                assert abs(recomposed - c.total_s) < 1e-6, (
                    f"{d.phase}/{d.task_id} w{c.worker}: "
                    f"{recomposed} != {c.total_s}"
                )


def test_explain_renders_decision_table(traced):
    res, report = traced
    d = res.trace.placements[0]
    text = report.explain(d.task_id, d.job_id)
    assert f"job {d.job_id}" in text and d.task_id in text
    assert "total" in text           # cost-vector table header
    assert f"w{d.chosen}" in text


def test_jax_python_provenance_agree():
    """The jax planner's recorded Eq. 2 component vectors match the
    python Navigator's on the same SST snapshot (pushed rows: the
    documented eviction-surrogate divergence only appears on empty
    unpublished rows)."""
    pytest.importorskip("jax")
    from repro.core.jax_planner import JaxNavigatorPlanner
    from repro.core.scheduler import NavigatorScheduler
    from repro.core.state import SharedStateTable

    cluster = ClusterSpec(n_workers=3)
    profiles = make_profiles(cluster)
    sst = SharedStateTable(3)
    for w in range(3):
        bitmap = 0b111 if w == 0 else 0
        sst.update_cache(w, bitmap, 8.0 * GB, 0.0)
        sst.push(w, 0.0)
    view = sst.view(0, 0.0)
    job = Job(0, translation_dfg(), arrival_time=0.0)

    py = NavigatorScheduler(profiles)
    py.recorder = FlightRecorder(3)
    adfg_py = py.plan(job, 0.0, 0, view)

    jx = JaxNavigatorPlanner(profiles)
    jx.recorder = FlightRecorder(3)
    adfg_jx = jx.plan(job, 0.0, 0, view)

    assert jx.recorder.placements, "jax planner recorded no provenance"
    for tid in job.dfg.tasks:
        assert adfg_py[tid] == adfg_jx[tid]
        dp = py.recorder.decisions(0, tid)
        dj = jx.recorder.decisions(0, tid)
        assert len(dp) == 1 and len(dj) == 1
        assert dp[0].chosen == dj[0].chosen
        for cp, cj in zip(dp[0].candidates, dj[0].candidates):
            assert cp.worker == cj.worker
            if cp.total_s == float("inf"):
                assert cj.total_s == float("inf")
                continue
            assert cj.model_s == pytest.approx(cp.model_s, abs=1e-3)
            assert cj.total_s == pytest.approx(cp.total_s, abs=1e-3)


def test_exports_validate_against_schemas(traced):
    res, _ = traced
    chrome = json.loads(json.dumps(res.trace.to_chrome_trace()))
    validate_schema(chrome, load_schema("trace.schema.json"))
    assert any(e["ph"] == "X" for e in chrome["traceEvents"])
    validate_schema(res.metrics.export(), load_schema("metrics.schema.json"))


def test_export_jsonl_gzip_deterministic(traced, tmp_path):
    """``export_jsonl(path, compress=True)`` writes a gzip archive whose
    raw bytes are deterministic (mtime pinned to 0) and whose payload
    round-trips to the exact uncompressed JSONL stream; the plain export
    path is untouched by the option."""
    import gzip

    res, _ = traced
    plain, gz_a, gz_b = (tmp_path / n for n in ("t.jsonl", "a.gz", "b.gz"))
    res.trace.export_jsonl(str(plain))
    res.trace.export_jsonl(str(gz_a), compress=True)
    res.trace.export_jsonl(str(gz_b), compress=True)
    assert gz_a.read_bytes() == gz_b.read_bytes(), (
        "gzip archive is not byte-deterministic across reruns"
    )
    with gzip.open(gz_a, "rb") as f:
        inflated = f.read().decode("utf-8")
    assert inflated == res.trace.to_jsonl() == plain.read_text()
    assert len(gz_a.read_bytes()) < len(plain.read_bytes())


def test_validate_schema_rejects_bad_payloads():
    schema = load_schema("metrics.schema.json")
    with pytest.raises(ValueError):                  # missing required key
        validate_schema({"metrics": []}, schema)
    with pytest.raises(ValueError):                  # enum violation
        validate_schema(
            {"schema_version": 1,
             "metrics": [{"name": "x", "type": "timer", "labels": {}}]},
            schema,
        )
    with pytest.raises(ValueError):                  # additionalProperties
        validate_schema(
            {"schema_version": 1, "metrics": [], "extra": 1}, schema
        )


# --------------------------------------------------------------------------
# Determinism + zero-overhead-when-off
# --------------------------------------------------------------------------
def test_trace_determinism_gossip_churn():
    check_trace_determinism(
        schedule=[e for e in SCRIPTED_SCHEDULE if e.time < 20.0],
        duration=20.0, rate=1.0,
    )


def test_trace_determinism_shared_table_churn():
    check_trace_determinism(
        schedule=[e for e in SCRIPTED_SCHEDULE if e.time < 20.0],
        duration=20.0, rate=1.0, gossip=None,
    )


def test_trace_determinism_partition():
    check_trace_determinism(
        schedule=scripted_partition_schedule(5), duration=20.0, rate=1.0,
    )


def test_tracing_off_zero_telemetry_allocations():
    """The tracing-off event loop must perform zero allocations
    attributable to core/telemetry.py (the zero-overhead-when-off
    contract, also enforced by tools/trace_smoke.py in CI)."""
    cluster = ClusterSpec(n_workers=5)
    profiles = make_profiles(cluster)
    jobs = bursty_trace_workload(
        paper_dfgs(), base_rate_per_s=0.8, duration_s=10.0, seed=3
    )
    sim = Simulation(
        cluster, profiles, MODELS, scheduler="navigator", seed=1, trace=False
    )
    sim._schedule_initial(jobs)
    tracemalloc.start(25)
    try:
        before = tracemalloc.take_snapshot()
        sim._event_loop()
        after = tracemalloc.take_snapshot()
    finally:
        tracemalloc.stop()
    flt = [tracemalloc.Filter(True, telemetry_mod.__file__)]
    stats = after.filter_traces(flt).compare_to(
        before.filter_traces(flt), "lineno"
    )
    leaked = [s for s in stats if s.size_diff > 0 or s.count_diff > 0]
    assert not leaked, "\n".join(str(s) for s in leaked)
    res = sim._assemble_result()
    assert res.trace is None and res.records

"""Scheduler unit tests: Alg. 1 planning, Eq. 2 locality, Alg. 2
adjustment, SST staleness semantics, baselines."""

import pytest

from repro.core import (
    ADFG,
    ClusterSpec,
    GB,
    Job,
    NavigatorConfig,
    NavigatorScheduler,
    ProfileRepository,
    SharedStateTable,
    make_scheduler,
)
from repro.core import bitmaps
from repro.workflows import MODELS, paper_dfgs, translation_dfg, vpa_dfg


@pytest.fixture
def profiles():
    cluster = ClusterSpec(n_workers=4)
    p = ProfileRepository(cluster, MODELS)
    for d in paper_dfgs():
        p.register(d)
    return p


def idle_sst(n, capacity=16 * GB):
    sst = SharedStateTable(n)
    for w in range(n):
        sst.update_cache(w, 0, capacity)
        sst.push(w, 0.0)
    return sst


def test_plan_assigns_every_task(profiles):
    sched = NavigatorScheduler(profiles)
    sst = idle_sst(4)
    job = Job(0, translation_dfg(), arrival_time=0.0)
    adfg = sched.plan(job, 0.0, 0, sst.view(0))
    assert set(adfg.assignment) == set(job.dfg.tasks)
    assert all(0 <= w < 4 for _, w in adfg.items())


def test_plan_prefers_cached_worker(profiles):
    """Eq. 2: a worker holding the model should win an otherwise-idle tie."""
    sched = NavigatorScheduler(profiles)
    sst = idle_sst(4)
    # Worker 2 holds opt (model 0) and bart (5).
    sst.update_cache(2, bitmaps.pack([0, 5]), 16 * GB)
    sst.push(2, 0.0)
    job = Job(0, vpa_dfg(), arrival_time=0.0)
    adfg = sched.plan(job, 0.0, 0, sst.view(0))
    assert adfg["opt_dialogue"] == 2
    assert adfg["bart_shape"] == 2


def test_plan_spreads_parallel_tasks_under_load(profiles):
    """With all models everywhere, fan-out siblings should not all pile on
    one worker (ft_map update on line 12 of Alg. 1)."""
    sched = NavigatorScheduler(profiles)
    sst = idle_sst(4)
    full = bitmaps.pack(range(8))
    for w in range(4):
        sst.update_cache(w, full, 16 * GB)
        sst.push(w, 0.0)
    job = Job(0, translation_dfg(), arrival_time=0.0)
    adfg = sched.plan(job, 0.0, 0, sst.view(0))
    parallel = {adfg["marian_fr"], adfg["mt5_zh"], adfg["mt5_ja"]}
    assert len(parallel) >= 2


def test_plan_avoids_loaded_worker(profiles):
    sched = NavigatorScheduler(profiles)
    sst = idle_sst(4)
    sst.update_load(1, 50.0)  # worker 1 has a huge backlog
    for w in range(4):
        sst.push(w, 0.0)
    job = Job(0, vpa_dfg(), arrival_time=0.0)
    adfg = sched.plan(job, 0.0, 0, sst.view(0))
    assert all(w != 1 for _, w in adfg.items())


def test_locality_ablation_ignores_cache(profiles):
    cfg = NavigatorConfig(use_model_locality=False)
    sched = NavigatorScheduler(profiles, cfg)
    sst = idle_sst(4)
    sst.update_cache(2, bitmaps.pack([0, 5]), 16 * GB)
    sst.push(2, 0.0)
    job = Job(0, vpa_dfg(), arrival_time=0.0)
    adfg = sched.plan(job, 0.0, 0, sst.view(0))
    # Without locality the cached worker gets no preference; the planner
    # should fall back to origin/idle-order rather than seeking worker 2.
    assert adfg["opt_dialogue"] != 2 or adfg["bart_shape"] != 2


def test_adjustment_triggers_on_overload(profiles):
    sched = NavigatorScheduler(profiles, NavigatorConfig(adjustment_threshold=2.0))
    sst = idle_sst(4)
    job = Job(0, vpa_dfg(), arrival_time=0.0)
    adfg = ADFG(job)
    adfg["opt_dialogue"] = 0
    adfg["bart_shape"] = 0
    adfg.planned_ft["opt_dialogue"] = 1.0
    # Planned worker 0 suddenly has a 60 s backlog.
    sst.update_load(0, 60.0)
    for w in range(4):
        sst.push(w, 1.0)
    new_w = sched.adjust(
        job, adfg, "bart_shape", 1.0, sst.view(0), 0, input_bytes=1e5
    )
    assert new_w != 0


def test_adjustment_keeps_plan_when_fine(profiles):
    sched = NavigatorScheduler(profiles)
    sst = idle_sst(4)
    job = Job(0, vpa_dfg(), arrival_time=0.0)
    adfg = ADFG(job)
    adfg["opt_dialogue"] = 0
    adfg["bart_shape"] = 0
    new_w = sched.adjust(
        job, adfg, "bart_shape", 1.0, sst.view(0), 0, input_bytes=1e5
    )
    assert new_w == 0


def test_join_tasks_never_adjusted(profiles):
    sched = NavigatorScheduler(profiles)
    sst = idle_sst(4)
    job = Job(0, translation_dfg(), arrival_time=0.0)
    adfg = ADFG(job)
    for t in job.dfg.tasks:
        adfg[t] = 0
    sst.update_load(0, 100.0)
    for w in range(4):
        sst.push(w, 0.0)
    assert sched.adjust(job, adfg, "aggregate", 0.0, sst.view(0), 0, 1e5) == 0


def test_hash_is_deterministic(profiles):
    sched = make_scheduler("hash", profiles)
    sst = idle_sst(4)
    job = Job(7, translation_dfg(), arrival_time=0.0)
    a1 = sched.plan(job, 0.0, 0, sst.view(0))
    a2 = sched.plan(job, 0.0, 1, sst.view(1))
    assert a1.assignment == a2.assignment


def test_heft_ignores_load(profiles):
    sched = make_scheduler("heft", profiles)
    sst = idle_sst(4)
    job = Job(0, vpa_dfg(), arrival_time=0.0)
    base = sched.plan(job, 0.0, 0, sst.view(0)).assignment
    sst.update_load(base["opt_dialogue"], 100.0)
    for w in range(4):
        sst.push(w, 0.0)
    again = sched.plan(job, 0.0, 0, sst.view(0)).assignment
    assert again == base  # HEFT is load-blind by design


def test_jit_picks_cached_idle_worker(profiles):
    sched = make_scheduler("jit", profiles)
    sst = idle_sst(4)
    sst.update_cache(3, bitmaps.pack([0]), 16 * GB)
    sst.push(3, 0.0)
    job = Job(0, vpa_dfg(), arrival_time=0.0)
    w = sched.select_worker_at_ready(
        job, "opt_dialogue", 0.0, sst.view(0), {"": 0}, {"": 1e5}, self_worker=0
    )
    assert w == 3


def test_sst_staleness_semantics():
    sst = SharedStateTable(3, push_interval_s=0.2)
    sst.update_load(1, 42.0)
    # Not yet pushed: readers other than worker 1 see the old value.
    assert sst.view(0)[1].ft_estimate_s == 0.0
    # Worker 1 itself sees fresh local state.
    assert sst.view(1)[1].ft_estimate_s == 42.0
    sst.push_load(1, 0.2)
    assert sst.view(0)[1].ft_estimate_s == 42.0


def test_unknown_scheduler_rejected(profiles):
    with pytest.raises(ValueError):
        make_scheduler("nope", profiles)

"""Dry-run machinery: HLO collective parsing, input specs, skip rules.
(The full lower+compile path is exercised by `python -m repro.launch.dryrun`;
these tests cover the pure-python pieces without forcing 512 devices.)"""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS
from repro.launch.dryrun import _shape_bytes, parse_collective_bytes
from repro.launch.specs import batch_specs, build_case, skip_reason
from repro.models.config import INPUT_SHAPES


HLO = """
HloModule test
  %x = bf16[128,256]{1,0} parameter(0)
  %ag = bf16[2048,256]{1,0} all-gather(%x), dimensions={0}
  %ar = f32[64]{0} all-reduce(%y), to_apply=%sum
  %rs.1 = bf16[16,256]{1,0} reduce-scatter(%ag), dimensions={0}
  %a2a = (f32[8,8]{1,0}, f32[8,8]{1,0}) all-to-all(%p, %q)
  %cp = u32[4]{0} collective-permute(%r), source_target_pairs={{0,1}}
  %ag2 = bf16[10]{0} all-gather-start(%x)
  %agd = bf16[10]{0} all-gather-done(%ag2)
  %mm = f32[10,10]{1,0} dot(%a, %b)
"""


def test_shape_bytes():
    assert _shape_bytes("bf16[128,256]{1,0}") == 128 * 256 * 2
    assert _shape_bytes("f32[64]{0}") == 256
    assert _shape_bytes("(f32[8,8]{1,0}, f32[8,8]{1,0})") == 2 * 64 * 4
    assert _shape_bytes("token[]") == 0


def test_parse_collectives():
    got = parse_collective_bytes(HLO)
    assert got["all-gather"] == 2048 * 256 * 2 + 10 * 2  # incl. -start
    assert got["all-reduce"] == 64 * 4
    assert got["reduce-scatter"] == 16 * 256 * 2
    assert got["all-to-all"] == 2 * 8 * 8 * 4
    assert got["collective-permute"] == 4 * 4
    assert got["count"] == 6  # -done not double counted


def test_skip_rules():
    assert skip_reason(ARCHS["whisper-medium"], INPUT_SHAPES["long_500k"])
    assert not skip_reason(ARCHS["whisper-medium"], INPUT_SHAPES["decode_32k"])
    assert not skip_reason(ARCHS["llama3-405b"], INPUT_SHAPES["long_500k"])
    n_skipped = sum(
        bool(skip_reason(cfg, sh))
        for cfg in ARCHS.values()
        for sh in INPUT_SHAPES.values()
    )
    assert n_skipped == 1  # exactly the documented whisper long_500k


@pytest.mark.parametrize("arch", sorted(ARCHS))
@pytest.mark.parametrize("shape", list(INPUT_SHAPES))
def test_build_case_shapes(arch, shape):
    cfg = ARCHS[arch]
    sh = INPUT_SHAPES[shape]
    if skip_reason(cfg, sh):
        with pytest.raises(ValueError, match="skipped"):
            build_case(cfg, sh)
        return
    case = build_case(cfg, sh)
    assert case["kind"] == sh.kind
    if sh.kind in ("train", "prefill"):
        assert case["batch"]["tokens"].shape == (sh.global_batch, sh.seq_len)
        if cfg.arch_type == "vlm":
            assert "vision_embeds" in case["batch"]
        if cfg.arch_type == "audio":
            assert "audio_frames" in case["batch"]
    else:
        assert case["tokens"].shape == (sh.global_batch,)
        # decode caches exist and are abstract (no allocation)
        leaves = jax.tree.leaves(case["cache"])
        assert all(isinstance(l, jax.ShapeDtypeStruct) for l in leaves)
        if shape == "long_500k" and cfg.arch_type in ("dense", "moe", "vlm"):
            # windowed: cache time dim == window, not 524288
            big = max(l.shape[2] for l in leaves if len(l.shape) > 2)
            assert big <= 8192


def test_train_batch_divisible_for_accum():
    from repro.launch.specs import TRAIN_ACCUM

    for arch, accum in TRAIN_ACCUM.items():
        assert INPUT_SHAPES["train_4k"].global_batch % accum == 0, arch

"""Decentralized SST gossip plane (§5.2): per-worker views, diff-based
exchange, staleness bounds, staleness-aware scheduling behaviour, and the
membership (heartbeat/lease) lane."""

import pytest

from hypothesis_compat import given, settings, st

from repro.core import (
    ALIVE,
    ClusterSpec,
    DEAD,
    GB,
    GossipConfig,
    GossipPlane,
    Job,
    LeaseConfig,
    NavigatorConfig,
    NavigatorScheduler,
    ProfileRepository,
    SUSPECT,
    build_fleet,
    fleet,
)
from repro.core import bitmaps
from repro.core.profiles import EDGE, T4, WorkerProfile
from repro.sim import Simulation, fleet_scaled_rate, poisson_workload
from repro.workflows import MODELS, paper_dfgs, translation_dfg, vpa_dfg


def broadcast_plane(n, **cfg):
    cfg.setdefault("fanout", n - 1)
    return GossipPlane(n, GossipConfig(**cfg))


def run_rounds(plane, t):
    """One synchronous all-worker round at time ``t`` (immediate delivery)."""
    for w in range(plane.n_workers):
        for q, updates, _ in plane.exchange(w, t):
            plane.deliver(q, updates, t)


# -- view semantics ----------------------------------------------------------
def test_views_are_per_worker():
    plane = broadcast_plane(4)
    plane.update_load(0, 33.0, now=1.0)
    # Before any exchange: only worker 0 itself sees the update.
    assert plane.view(0)[0].ft_estimate_s == 33.0
    for w in (1, 2, 3):
        assert plane.view(w)[0].ft_estimate_s == 0.0
    # Two different workers can hold different replicas of the same row:
    # gossip only to worker 1.
    msgs = plane.exchange(0, 1.1)
    (q, updates, _), = [m for m in msgs if m[0] == 1]
    plane.deliver(1, updates, 1.1)
    assert plane.view(1)[0].ft_estimate_s == 33.0


def test_own_row_always_fresh_and_authoritative():
    plane = broadcast_plane(3)
    plane.update_load(1, 7.0, now=0.5)
    assert plane.view(1)[1].ft_estimate_s == 7.0
    # A stale echo of worker 1's own row must never overwrite ground truth.
    stale = plane.view(0)[1]
    plane.deliver(1, [(1, 999, stale)], 2.0)
    assert plane.view(1)[1].ft_estimate_s == 7.0


def test_staleness_bound_under_periodic_gossip():
    """With broadcast rounds every P seconds and instant delivery, the age
    of any remote row is bounded by P plus the owner's update recency: a
    row updated just before a round is everywhere at most P old until the
    next round."""
    period = 0.2
    plane = broadcast_plane(5, period_s=period)
    for r in range(1, 11):
        t_round = r * period
        for w in range(5):  # every owner refreshes just before the round
            plane.update_load(w, float(r * 10 + w), now=t_round - 0.01)
        run_rounds(plane, t_round)
        # At the round instant every view holds rows at most 0.01 s old...
        assert plane.staleness(t_round) <= 0.01 + 1e-9
        # ...and until the next round the lag grows to at most P + 0.01.
        assert plane.staleness(t_round + period) <= period + 0.01 + 1e-9


def test_convergence_after_quiescence():
    """Random updates, then no further writes: a few fanout-2 rounds must
    bring every worker's view to exact agreement with ground truth."""
    plane = GossipPlane(6, GossipConfig(fanout=2, seed=11))
    for step in range(30):
        plane.update_load(step % 6, float(step), now=0.1 * step)
    for r in range(12):  # epidemic spread: O(log n) rounds suffice w.h.p.
        run_rounds(plane, 3.0 + 0.2 * r)
    for reader in range(6):
        view = plane.view(reader)
        for owner in range(6):
            assert view[owner].ft_estimate_s == plane.local[owner].ft_estimate_s
            assert plane.versions[reader][owner] == plane.local[owner].version


def test_exchange_is_diff_based():
    """A round with k dirty rows ships exactly k row updates per contacted
    peer — never the full table — and a peer already up to date receives
    nothing on the next round."""
    n = 64
    plane = broadcast_plane(n, seed=5)
    k = 7
    for owner in range(k):
        plane.update_load(owner, 1.0, now=0.1)
        # Hand worker 0 the k dirty rows (as if learned from gossip).
        if owner != 0:
            plane.deliver(
                0, [(owner, plane.local[owner].version, plane.local[owner])], 0.1
            )
    msgs = plane.exchange(0, 0.2)
    assert all(len(updates) == k for _, updates, _ in msgs)
    # Every peer's cursor has advanced: the next round ships nothing.
    before = plane.rows_sent
    assert plane.exchange(0, 0.4) == []
    assert plane.rows_sent == before


def test_dropped_messages_are_not_retransmitted_point_to_point():
    plane = GossipPlane(2, GossipConfig(fanout=1, drop_prob=1.0))
    plane.update_load(0, 5.0, now=0.1)
    for r in range(5):
        run_rounds(plane, 0.2 * (r + 1))
    assert plane.view(1)[0].ft_estimate_s == 0.0
    assert plane.messages_dropped > 0


def test_bootstrap_push_broadcasts():
    plane = GossipPlane(4, GossipConfig(fanout=1))
    plane.update_cache(2, bitmaps.pack([1]), 8 * GB, now=0.0)
    plane.push(2, 0.0)
    for w in range(4):
        assert plane.view(w)[2].cache_bitmap == bitmaps.pack([1])


def test_log_compaction_bounds_memory():
    n = 8
    plane = GossipPlane(n, GossipConfig(fanout=n - 1, seed=2))
    for step in range(2000):
        plane.update_load(step % n, float(step), now=0.01 * step)
        if step % 5 == 0:
            run_rounds(plane, 0.01 * step)
    assert max(len(log) for log in plane._log) < 40 * n


def test_log_bounded_even_with_uncontacted_peer_then_full_sync_repairs():
    """With fanout 1 some peer can go uncontacted for a long time; the log
    must stay hard-bounded regardless, and a peer that fell behind the
    truncated history must be repaired by an anti-entropy full sync."""
    n = 4
    plane = GossipPlane(n, GossipConfig(fanout=1, seed=9))
    for step in range(5000):
        plane.update_load(0, float(step), now=0.01 * step)
        for q, updates, _ in plane.exchange(0, 0.01 * step):
            plane.deliver(q, updates, 0.01 * step)
    assert len(plane._log[0]) <= plane._max_log
    # Force the full-sync path: a peer whose cursor predates the log base.
    behind = next(
        q for q in range(1, n) if plane._cursor[0][q] < plane._log_base[0]
    ) if any(
        plane._cursor[0][q] < plane._log_base[0] for q in range(1, n)
    ) else 1
    plane._cursor[0][behind] = 0
    before = plane.full_syncs
    # Exchange until the RNG picks that peer.
    for step in range(200):
        for q, updates, _ in plane.exchange(0, 100.0 + step):
            plane.deliver(q, updates, 100.0 + step)
        if plane.full_syncs > before:
            break
    assert plane.full_syncs > before
    assert plane.view(behind)[0].ft_estimate_s == plane.local[0].ft_estimate_s


def test_dropped_full_sync_is_retried():
    """A lost diff is repaired by relay, but a lost anti-entropy full sync
    must be retried on the next contact — otherwise a laggard peer whose
    history was truncated is stranded with stale rows forever."""
    n = 2  # single peer: every round contacts it
    plane = GossipPlane(n, GossipConfig(fanout=1, drop_prob=1.0, seed=4))
    plane.update_load(0, 42.0, now=0.1)
    # Put peer 1 behind the truncated history.
    plane.mark_synced(0)
    plane._cursor[0][1] = 0
    assert plane._cursor[0][1] < plane._log_base[0]
    plane.exchange(0, 0.2)  # full sync attempted, dropped
    assert plane.full_syncs == 1
    # Cursor was rewound: the peer is still eligible for repair.
    assert plane._cursor[0][1] < plane._log_base[0]
    # Stop dropping: the retry lands and the peer converges.
    object.__setattr__(plane.config, "drop_prob", 0.0)
    for q, updates, _ in plane.exchange(0, 0.3):
        plane.deliver(q, updates, 0.3)
    assert plane.view(1)[0].ft_estimate_s == 42.0


def test_mark_synced_empties_outbound_log():
    plane = GossipPlane(4, GossipConfig(fanout=1, seed=1))
    for i in range(10):
        plane.update_load(0, float(i), now=0.1 * i)
    plane.mark_synced(0)
    assert plane._log[0] == []
    assert plane.exchange(0, 2.0) == []  # nothing outstanding, no full sync
    assert plane.full_syncs == 0


# -- membership lane (heartbeat/lease) ---------------------------------------
def lease_plane(n, lease=None, **cfg):
    cfg.setdefault("fanout", n - 1)
    plane = GossipPlane(
        n, GossipConfig(**cfg), lease=lease or LeaseConfig()
    )
    for w in range(n):
        plane.heartbeat(w, 0.0)
        plane.push(w, 0.0)
    return plane


def test_lease_state_machine_from_stale_view():
    """ALIVE → SUSPECT → DEAD purely from replicated heartbeat age: no
    oracle, the reader's own replica decides."""
    lease = LeaseConfig(suspect_after_s=1.0, dead_after_s=3.0)
    plane = lease_plane(3, lease=lease)
    run_rounds(plane, 0.1)
    # Worker 2 stops heartbeating (crashed); 0 and 1 keep going.
    for r in range(1, 50):
        t = 0.1 + 0.1 * r
        for w in (0, 1):
            plane.heartbeat(w, t)
        for w in (0, 1):
            for q, updates, _ in plane.exchange(w, t):
                if q != 2:  # a corpse receives nothing
                    plane.deliver(q, updates, t)
    t_end = 0.1 + 0.1 * 49
    assert plane.liveness(0, 1, t_end) == ALIVE
    assert plane.liveness(0, 2, t_end) == DEAD
    # The full trajectory: shortly after death it was merely SUSPECT.
    assert plane.liveness(0, 2, 0.1 + lease.suspect_after_s + 0.2) == SUSPECT
    # view() annotation matches the classification.
    rows = plane.view(0, now=t_end)
    assert rows[1].liveness == ALIVE and rows[2].liveness == DEAD


def test_two_readers_can_disagree_about_liveness():
    """Decentralized verdicts: a reader whose replica missed recent
    heartbeats declares DEAD while a better-connected one says ALIVE."""
    lease = LeaseConfig(suspect_after_s=1.0, dead_after_s=2.0)
    plane = lease_plane(3, lease=lease, fanout=1)
    # Worker 0 heartbeats; only worker 1 hears about it.
    for r in range(1, 30):
        t = 0.1 * r
        plane.heartbeat(0, t)
        msgs = plane.exchange(0, t)
        for q, updates, _ in msgs:
            if q == 1:
                plane.deliver(1, updates, t)
    t = 3.0
    assert plane.liveness(1, 0, t) == ALIVE
    assert plane.liveness(2, 0, t) == DEAD


def test_draining_reads_dead_immediately():
    plane = lease_plane(3)
    plane.set_draining(1, True, now=0.5)
    run_rounds(plane, 0.6)
    assert plane.liveness(0, 1, 0.7) == DEAD
    assert plane.view(0, now=0.7)[1].liveness == DEAD
    # The drainer itself reports its own row as DEAD (no new work).
    assert plane.liveness(1, 1, 0.7) == DEAD


def test_staleness_excludes_dead_rows():
    """Bugfix: a departed worker's frozen row must not inflate reported
    staleness forever once the reader has marked it DEAD."""
    lease = LeaseConfig(suspect_after_s=1.0, dead_after_s=3.0)
    plane = lease_plane(3, lease=lease)
    run_rounds(plane, 0.1)
    # Worker 2 dies at t=0.1; 0 and 1 keep exchanging for 100 s.
    for r in range(1, 101):
        t = 0.1 + r
        for w in (0, 1):
            plane.heartbeat(w, t)
        for w in (0, 1):
            for q, updates, _ in plane.exchange(w, t):
                if q != 2:
                    plane.deliver(q, updates, t)
    t_end = 100.1
    # Without the fix this would be ~100 s (worker 2's frozen row).
    assert plane.staleness(t_end, reader_worker=0) < 5.0
    # A lease-less plane keeps the old (inflating) semantics.
    bare = broadcast_plane(3)
    bare.update_load(2, 1.0, now=0.0)
    run_rounds(bare, 0.1)
    assert bare.staleness(100.0) > 99.0


def test_rejoin_bumps_epoch_and_blocks_resurrection():
    """A pre-crash echo of the old incarnation (higher version, lower
    epoch) must never overwrite the rejoined worker's fresh row."""
    plane = lease_plane(3)
    for i in range(5):
        plane.update_load(2, float(i), now=0.1 * i)
    run_rounds(plane, 1.0)
    stale_row = plane.view(0)[2]          # old incarnation, version >= 5
    old_version = plane.versions[0][2]
    assert old_version >= 5
    plane.join(2, now=10.0)               # crash + rejoin: epoch + 1
    assert plane.local[2].epoch == stale_row.epoch + 1
    run_rounds(plane, 10.1)               # new incarnation disseminates
    assert plane.views[0][2].epoch == plane.local[2].epoch
    # Replay the pre-crash echo: must be rejected by (epoch, version).
    plane.deliver(0, [(2, old_version, stale_row)], 10.2)
    assert plane.views[0][2].epoch == plane.local[2].epoch
    assert plane.views[0][2].ft_estimate_s == plane.local[2].ft_estimate_s


def test_join_rebuilds_view_via_full_sync():
    """A joiner's empty replica is repaired by the anti-entropy full-sync
    path on the first contact from each peer."""
    plane = lease_plane(4)
    for w in range(4):
        plane.update_load(w, 10.0 + w, now=0.5)
    run_rounds(plane, 1.0)
    plane.join(3, now=5.0)
    assert plane.view(3)[0].ft_estimate_s == 0.0  # replica wiped
    before = plane.full_syncs
    run_rounds(plane, 5.2)
    assert plane.full_syncs > before
    for owner in (0, 1, 2):
        assert plane.view(3)[owner].ft_estimate_s == 10.0 + owner


# -- property-based: convergence under arbitrary churn ------------------------
@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 10**6),
    ops=st.lists(
        st.tuples(
            st.integers(0, 4),
            st.sampled_from(["crash", "join", "update"]),
        ),
        max_size=25,
    ),
)
def test_views_converge_after_arbitrary_churn_then_quiet(seed, ops):
    """After any crash/join/update sequence followed by a quiet period of
    gossip among the live workers, every live reader agrees with every
    live owner's ground truth — same values, same (epoch, version) — and
    no old-incarnation row survives anywhere among the live."""
    n = 5
    plane = GossipPlane(
        n, GossipConfig(fanout=2, seed=seed), lease=LeaseConfig()
    )
    up = set(range(n))
    for w in range(n):
        plane.heartbeat(w, 0.0)
        plane.push(w, 0.0)
    t = 0.0
    for step, (w, op) in enumerate(ops):
        t = 0.1 * (step + 1)
        if op == "crash":
            if len(up) > 1:
                up.discard(w)
        elif op == "join":
            if w not in up:
                plane.join(w, t)
                up.add(w)
        else:
            if w in up:
                plane.update_load(w, float(step), now=t)
        # One gossip round among the live (corpses receive nothing).
        for src in sorted(up):
            for q, updates, _ in plane.exchange(src, t):
                if q in up:
                    plane.deliver(q, updates, t)
    # Quiet period: no more churn/updates, epidemic spread finishes.
    for r in range(20):
        t += 0.1
        for src in sorted(up):
            for q, updates, _ in plane.exchange(src, t):
                if q in up:
                    plane.deliver(q, updates, t)
    for reader in up:
        for owner in up:
            truth = plane.local[owner]
            held = plane.view(reader)[owner]
            assert held.epoch == truth.epoch, (reader, owner)
            assert held.ft_estimate_s == truth.ft_estimate_s, (reader, owner)
            if owner != reader:
                assert plane.versions[reader][owner] == truth.version


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 10**6),
    n_rejoin=st.integers(1, 4),
)
def test_no_dead_row_resurrection_property(seed, n_rejoin):
    """Replaying any captured pre-crash row after any number of rejoins
    never rolls a replica back to an older incarnation."""
    n = 4
    plane = GossipPlane(
        n, GossipConfig(fanout=n - 1, seed=seed), lease=LeaseConfig()
    )
    for w in range(n):
        plane.heartbeat(w, 0.0)
        plane.push(w, 0.0)
    captured = []
    t = 0.0
    for k in range(n_rejoin):
        t += 1.0
        plane.update_load(3, 100.0 + k, now=t)
        run_rounds(plane, t)
        captured.append((plane.versions[0][3], plane.view(0)[3]))
        t += 1.0
        plane.join(3, now=t)
        run_rounds(plane, t)
    final_epoch = plane.local[3].epoch
    assert final_epoch == n_rejoin
    for version, row in captured:
        for reader in range(3):
            plane.deliver(reader, [(3, version, row)], t + 1.0)
            assert plane.views[reader][3].epoch == final_epoch


# -- staleness-aware scheduling ----------------------------------------------
@pytest.fixture
def profiles():
    cluster = ClusterSpec(n_workers=4)
    p = ProfileRepository(cluster, MODELS)
    for d in paper_dfgs():
        p.register(d)
    return p


def warm_plane(n, capacity=16 * GB):
    plane = broadcast_plane(n)
    for w in range(n):
        plane.update_cache(w, 0, capacity, now=0.0)
        plane.push(w, 0.0)
    return plane


def test_navigator_plan_differs_between_fresh_and_stale_views(profiles):
    """Regression for the decentralized regime: when a worker's queue
    lengthens, a planner reading a *stale* replica keeps placing work on
    it, while a planner with the fresh view avoids it."""
    sched = NavigatorScheduler(profiles)
    plane = warm_plane(4)
    job = Job(0, vpa_dfg(), arrival_time=0.0)

    # Worker 0 holds the VPA models (cache-attractive), broadcast that...
    plane.update_cache(0, bitmaps.pack([0, 5]), 16 * GB, now=0.0)
    plane.push(0, 0.0)
    # ...then its queue explodes at t=5, before any gossip round runs.
    plane.update_load(0, 120.0, now=5.0)

    stale_view = plane.view(1)  # worker 1 still believes 0 is idle
    fresh_view = plane.view(0)  # worker 0 knows its own backlog
    assert stale_view[0].ft_estimate_s == 0.0
    assert fresh_view[0].ft_estimate_s == 120.0

    plan_stale = sched.plan(job, 5.0, 1, stale_view)
    plan_fresh = sched.plan(job, 5.0, 1, fresh_view)
    # Stale planner chases the cached-and-supposedly-idle worker 0; the
    # fresh planner knows better (120 s backlog >> refetching elsewhere).
    assert any(w == 0 for _, w in plan_stale.items())
    assert all(w != 0 for _, w in plan_fresh.items())
    assert plan_stale.assignment != plan_fresh.assignment

    # After one gossip round the stale planner converges to the fresh plan.
    run_rounds(plane, 5.1)
    plan_after = sched.plan(job, 5.2, 1, plane.view(1))
    assert all(w != 0 for _, w in plan_after.items())


def test_staleness_margin_blocks_moves_on_old_evidence(profiles):
    """Alg. 2 hysteresis: with staleness_margin_per_s set, a very old row
    advertising an idle worker is not enough to abandon the planned
    (cache-affine) worker; with margin 0 the same evidence triggers the
    move."""
    from repro.core import ADFG

    plane = warm_plane(4)
    job = Job(0, vpa_dfg(), arrival_time=0.0)
    now = 60.0
    # Planned worker 0 is backlogged (fresh information)...
    plane.update_load(0, now + 30.0, now=now)
    run_rounds(plane, now)
    # ...and candidate workers' rows are ancient (pushed_at == 0.0).
    view = plane.view(0)
    assert view[1].pushed_at == 0.0

    adfg = ADFG(job)
    adfg["opt_dialogue"] = 0
    adfg["bart_shape"] = 0
    adfg.planned_ft["opt_dialogue"] = now

    eager = NavigatorScheduler(profiles, NavigatorConfig())
    wary = NavigatorScheduler(
        profiles,
        NavigatorConfig(staleness_margin_per_s=1.0),  # 60 s age → huge bar
    )
    assert eager.adjust(job, adfg, "bart_shape", now, view, 0, 1e5) != 0
    assert wary.adjust(job, adfg, "bart_shape", now, view, 0, 1e5) == 0


def test_staleness_margin_exempts_adjusters_own_worker(profiles):
    """The adjuster's own worker is local ground truth: even with an
    ancient row and a large staleness margin, moving the task to the
    worker doing the adjusting must not be blocked."""
    from repro.core import ADFG, SharedStateTable

    sst = SharedStateTable(4)
    for w in range(4):
        sst.update_cache(w, 0, 16 * GB, now=0.0)
        sst.push(w, 0.0)
    now = 60.0
    sst.update_load(0, now + 30.0, now=now)  # planned worker backlogged
    sst.push(0, now)
    job = Job(0, vpa_dfg(), arrival_time=0.0)
    adfg = ADFG(job)
    adfg["opt_dialogue"] = 0
    adfg["bart_shape"] = 0
    adfg.planned_ft["opt_dialogue"] = now

    wary = NavigatorScheduler(
        profiles, NavigatorConfig(staleness_margin_per_s=10.0)
    )
    # Adjuster runs on worker 2; its own (fresh) row wins despite the
    # huge margin, because self-evidence carries no staleness penalty.
    view = sst.view(2)
    assert wary.adjust(job, adfg, "bart_shape", now, view, 2, 1e5) == 2


# -- simulator integration ---------------------------------------------------
def sim_with_gossip(gossip, cluster=None, rate=2.0, duration=100.0, seed=3):
    cluster = cluster or ClusterSpec(n_workers=5)
    p = ProfileRepository(cluster, MODELS)
    for d in paper_dfgs():
        p.register(d)
    jobs = poisson_workload(paper_dfgs(), rate, duration, seed=seed)
    sim = Simulation(
        cluster, p, MODELS, scheduler="navigator", gossip=gossip, seed=1
    )
    return sim.run(jobs), jobs


def test_gossip_sim_completes_all_jobs():
    res, jobs = sim_with_gossip(GossipConfig(period_s=0.2, fanout=2))
    assert len(res.records) == len(jobs)
    assert res.sst_pushes > 0


def test_gossip_staleness_degrades_gracefully():
    """Navigator JCT should degrade, not cliff, as the gossip period grows
    two orders of magnitude."""
    fresh, _ = sim_with_gossip(GossipConfig(period_s=0.05, fanout=4))
    stale, _ = sim_with_gossip(GossipConfig(period_s=4.0, fanout=4))
    assert stale.mean_slowdown >= fresh.mean_slowdown * 0.9
    assert stale.mean_slowdown <= fresh.mean_slowdown * 4.0


def test_gossip_sim_deterministic():
    a, _ = sim_with_gossip(GossipConfig(period_s=0.2, fanout=2))
    b, _ = sim_with_gossip(GossipConfig(period_s=0.2, fanout=2))
    assert a.mean_latency == b.mean_latency
    assert a.sst_pushes == b.sst_pushes


# -- heterogeneous fleets ----------------------------------------------------
def test_build_fleet_spec():
    cluster = build_fleet([T4, EDGE, WorkerProfile("big", 2.0, 24 * GB)])
    assert cluster.n_workers == 3
    assert cluster.speed(1) == 0.5 and cluster.speed(2) == 2.0
    assert cluster.gpu_capacity(1) == 8 * GB
    assert cluster.gpu_capacity(2) == 24 * GB
    assert cluster.total_speed == pytest.approx(3.5)


def test_fleet_scaled_rate_holds_load_constant():
    uniform = fleet("uniform")
    mixed = fleet("mixed")
    base = 2.0
    assert fleet_scaled_rate(uniform, base) == pytest.approx(base)
    assert fleet_scaled_rate(mixed, base) == pytest.approx(
        base * mixed.total_speed / mixed.n_workers
    )


def test_heterogeneous_fleet_sim_completes_and_uses_fast_workers():
    cluster = fleet("mixed")
    rate = fleet_scaled_rate(cluster, 2.0)
    res, jobs = sim_with_gossip(
        GossipConfig(period_s=0.2, fanout=2), cluster=cluster, rate=rate
    )
    assert len(res.records) == len(jobs)
    # The fastest worker (A10, w=0) should carry more executed work than
    # the slowest (edge, w=4): Navigator sees shorter queues there.
    fast = res.busy_time[0] * cluster.speed(0)
    slow = res.busy_time[4] * cluster.speed(4)
    assert fast > slow

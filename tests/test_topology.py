"""Topology-aware network plane: path costs, fair-share contention, and
flat-table backward compatibility (unit + property tests)."""

import numpy as np
import pytest

from hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st

from repro.core import (
    ClusterSpec,
    GB,
    Job,
    MB,
    NavigatorConfig,
    NavigatorScheduler,
    ProfileRepository,
    fleet,
)
from repro.core.jax_planner import JaxNavigatorPlanner
from repro.core.netmodel import (
    LinkSpec,
    NetworkModel,
    NetworkState,
    Topology,
)
from repro.core.profiles import rack_topology
from repro.core.state import SSTRow
from repro.core.types import DFG, TaskSpec
from repro.sim import Simulation, poisson_workload
from repro.workflows import MODELS, paper_dfgs


def two_rack(oversubscription=4.0, sizes=(4, 4)):
    return rack_topology(sizes, oversubscription=oversubscription)


# -- flat-table backward compatibility ----------------------------------------

def test_flat_cluster_path_cost_is_the_flat_table():
    """With no topology, path_transfer_time must be bit-exact with the
    pre-topology all-pairs model for every pair, including src == dst
    (the old table charged the flat cost regardless of endpoints)."""
    cluster = ClusterSpec(n_workers=5)
    for nbytes in (0.0, 1.0, 0.3 * MB, 2.0 * GB):
        want = cluster.network.transfer_time(nbytes)
        for s in range(5):
            for d in range(5):
                assert cluster.path_transfer_time(nbytes, s, d) == want


def test_flat_cluster_profile_transfers_unchanged():
    """ProfileRepository's representative transfer costs reduce to the
    flat model exactly when no topology is configured."""
    cluster = ClusterSpec(n_workers=5)
    profiles = ProfileRepository(cluster, MODELS)
    task = TaskSpec("t", 0.5, model_id=0, output_bytes=0.7 * MB,
                    input_bytes=0.2 * MB)
    assert profiles.td_output(task) == cluster.network.transfer_time(
        task.output_bytes
    )
    assert profiles.td_input(task) == cluster.network.transfer_time(
        task.input_bytes
    )
    assert profiles.td_output_to(task, 0, 3) == profiles.td_output(task)


def test_flat_sim_runs_no_topology_counters():
    """A flat-cluster simulation never exercises the topology plane."""
    cluster = ClusterSpec(n_workers=5)
    profiles = ProfileRepository(cluster, MODELS)
    for d in paper_dfgs():
        profiles.register(d)
    jobs = poisson_workload(paper_dfgs(), 1.5, 30.0, seed=3)
    sim = Simulation(cluster, profiles, MODELS, scheduler="navigator", seed=1)
    res = sim.run(jobs)
    assert len(res.records) == len(jobs)
    assert res.net_local_transfers == 0
    assert res.net_cross_transfers == 0
    assert res.net_contended_transfers == 0


def test_flat_sim_bit_exact_determinism():
    """Same seed, same flat config → byte-identical event log (the
    flat-topology reproduction guarantee is determinism, not approximate
    similarity)."""
    logs = []
    for _ in range(2):
        cluster = ClusterSpec(n_workers=5)
        profiles = ProfileRepository(cluster, MODELS)
        for d in paper_dfgs():
            profiles.register(d)
        jobs = poisson_workload(paper_dfgs(), 2.0, 40.0, seed=7)
        sim = Simulation(
            cluster, profiles, MODELS, scheduler="navigator",
            record_events=True, seed=1,
        )
        res = sim.run(jobs)
        logs.append((res.event_log, res.mean_latency))
    assert logs[0][0] == logs[1][0]
    assert logs[0][1] == logs[1][1]


# -- topology path model ------------------------------------------------------

def test_same_worker_transfer_is_free():
    topo = two_rack()
    assert topo.transfer_time(1 * GB, 2, 2) == 0.0


def test_rack_local_cost_matches_flat_link():
    """A rack-local pair on the default rack link prices exactly like the
    flat 100 Gbps table — racks only add cost across the spine."""
    topo = two_rack()
    flat = NetworkModel()
    for nbytes in (1.0, 0.3 * MB, 2.0 * GB):
        assert topo.transfer_time(nbytes, 0, 1) == flat.transfer_time(nbytes)


def test_cross_rack_cost_dominates_rack_local():
    """Path cost over an oversubscribed uplink is strictly larger than the
    rack-local (== flat) cost: the planner sees a real shipping premium."""
    topo = two_rack(oversubscription=4.0)
    nbytes = 10 * MB
    local = topo.transfer_time(nbytes, 0, 1)
    cross = topo.transfer_time(nbytes, 0, 4)
    assert cross > local
    # Bandwidth term scales with the oversubscription factor.
    bw_local = nbytes / topo.rack_link.bandwidth_bytes_per_s
    bw_cross = nbytes / topo.uplink.bandwidth_bytes_per_s
    assert bw_cross == pytest.approx(4.0 * bw_local)


def test_oversubscription_monotone():
    t2 = two_rack(oversubscription=2.0)
    t8 = two_rack(oversubscription=8.0)
    nbytes = 50 * MB
    assert t8.transfer_time(nbytes, 0, 4) > t2.transfer_time(nbytes, 0, 4)


def test_path_uplinks():
    topo = two_rack()
    assert topo.path_uplinks(0, 3) == ()
    assert topo.path_uplinks(0, 4) == (0, 1)
    assert topo.path_uplinks(7, 1) == (1, 0)


def test_pair_matrices_reproduce_transfer_time():
    topo = rack_topology((2, 3, 1), oversubscription=3.0)
    inv_bw, delta = topo.pair_matrices()
    nbytes = 5 * MB
    for s in range(topo.n_workers):
        for d in range(topo.n_workers):
            want = topo.transfer_time(nbytes, s, d)
            got = 0.0 if s == d else nbytes * inv_bw[s][d] + delta[s][d]
            assert got == pytest.approx(want, rel=1e-12)


def test_mean_path_factors_bounded_by_extremes():
    topo = two_rack()
    inv_bw_mean, delta_mean = topo.mean_path_factors()
    local_inv = 1.0 / topo.rack_link.bandwidth_bytes_per_s
    cross_inv = 1.0 / topo.uplink.bandwidth_bytes_per_s
    assert local_inv < inv_bw_mean < cross_inv
    assert topo.rack_link.delta_s <= delta_mean <= (
        topo.rack_link.delta_s + topo.uplink.delta_s
    )


def test_topology_validation():
    with pytest.raises(ValueError):
        Topology(rack_of=())
    with pytest.raises(ValueError):
        Topology(rack_of=(0, 2))  # non-contiguous rack ids
    with pytest.raises(ValueError):
        ClusterSpec(n_workers=5, topology=two_rack())  # 8-worker topology


# -- contention (NetworkState) ------------------------------------------------

def test_contention_slows_new_transfer_only():
    """A registered in-flight cross-rack flow halves the uplink share a
    second transfer sees; the first flow's duration is never re-timed."""
    topo = two_rack()
    net = NetworkState(topo)
    nbytes = 100 * MB
    solo = net.transfer_time(nbytes, 0, 4, now=0.0)
    d1 = net.start_transfer(nbytes, 0, 4, now=0.0)
    assert d1 == solo  # first flow admitted uncontended
    d2 = net.transfer_time(nbytes, 1, 5, now=0.0)
    assert d2 > solo  # second flow pays the halved share
    # Exact fair-share arithmetic: bw/2 doubles the bandwidth term.
    bw_term = nbytes / topo.uplink.bandwidth_bytes_per_s
    assert d2 - solo == pytest.approx(bw_term, rel=1e-9)


def test_contention_expires_with_flows():
    topo = two_rack()
    net = NetworkState(topo)
    nbytes = 100 * MB
    d1 = net.start_transfer(nbytes, 0, 4, now=0.0)
    assert net.active_flows(0, 0.0) == 1
    # After the flow drains, a new transfer is uncontended again.
    later = d1 + 1e-9
    assert net.active_flows(0, later) == 0
    assert net.transfer_time(nbytes, 0, 4, later) == pytest.approx(d1)


def test_rack_local_never_contends():
    topo = two_rack()
    net = NetworkState(topo)
    for _ in range(5):
        net.start_transfer(100 * MB, 0, 4, now=0.0)
    uncongested = topo.transfer_time(10 * MB, 0, 1)
    assert net.transfer_time(10 * MB, 0, 1, now=0.0) == uncongested


def test_contended_counter():
    topo = two_rack()
    net = NetworkState(topo)
    net.start_transfer(100 * MB, 0, 4, now=0.0)
    net.start_transfer(100 * MB, 1, 5, now=0.0)
    net.start_transfer(10 * MB, 2, 3, now=0.0)  # rack-local: not bulk-tracked
    assert net.bulk_transfers == 2
    assert net.contended_transfers == 1


# -- hypothesis properties ----------------------------------------------------

@settings(max_examples=60, deadline=None)
@given(
    nbytes=st.floats(0.0, 4 * GB, allow_nan=False),
    src=st.integers(0, 7),
    dst=st.integers(0, 7),
    oversub=st.floats(1.0, 16.0),
)
def test_property_path_cost_symmetric(nbytes, src, dst, oversub):
    topo = two_rack(oversubscription=oversub)
    assert topo.transfer_time(nbytes, src, dst) == topo.transfer_time(
        nbytes, dst, src
    )


@settings(max_examples=60, deadline=None)
@given(
    nbytes=st.floats(1.0, 4 * GB, allow_nan=False),
    src=st.integers(0, 7),
    dst=st.integers(0, 7),
)
def test_property_path_cost_at_least_rack_local(nbytes, src, dst):
    """Every path prices at least the rack-local (flat-link) cost; the
    premium is exactly zero within a rack."""
    topo = two_rack()
    flat_like = topo.transfer_time(nbytes, 0, 1)  # rack-local reference
    cost = topo.transfer_time(nbytes, src, dst)
    if src == dst:
        assert cost == 0.0
    elif topo.rack(src) == topo.rack(dst):
        assert cost == flat_like
    else:
        assert cost > flat_like


@settings(max_examples=40, deadline=None)
@given(
    n_flows=st.integers(0, 6),
    nbytes=st.floats(1 * MB, 1 * GB, allow_nan=False),
)
def test_property_contention_monotone(n_flows, nbytes):
    """Admitting more concurrent flows never speeds up the next transfer,
    and each additional flow strictly slows it."""
    topo = two_rack()
    net = NetworkState(topo)
    prev = net.transfer_time(nbytes, 0, 4, now=0.0)
    for _ in range(n_flows):
        net.start_transfer(1 * GB, 1, 5, now=0.0)
        cur = net.transfer_time(nbytes, 0, 4, now=0.0)
        assert cur > prev
        prev = cur


# Deterministic sweeps over the same properties, so the invariants are
# exercised even where hypothesis is unavailable (the @given variants
# then skip, matching the repo-wide pattern).

def test_sweep_symmetry_and_floor():
    topo = rack_topology((3, 3, 2), oversubscription=4.0)
    for nbytes in (1.0, 64 * MB, 2 * GB):
        local = topo.transfer_time(nbytes, 0, 1)
        for s in range(topo.n_workers):
            for d in range(topo.n_workers):
                cost = topo.transfer_time(nbytes, s, d)
                assert cost == topo.transfer_time(nbytes, d, s)
                if s == d:
                    assert cost == 0.0
                elif topo.rack(s) == topo.rack(d):
                    assert cost == local
                else:
                    assert cost > local


def test_sweep_contention_monotone():
    topo = two_rack()
    net = NetworkState(topo)
    nbytes = 64 * MB
    prev = net.transfer_time(nbytes, 0, 4, now=0.0)
    for _ in range(6):
        net.start_transfer(1 * GB, 1, 5, now=0.0)
        cur = net.transfer_time(nbytes, 0, 4, now=0.0)
        assert cur > prev
        prev = cur


# -- fleet presets ------------------------------------------------------------

def test_rack_fleet_presets():
    for name in ("rack2", "rack2_mixed"):
        cluster = fleet(name)
        topo = cluster.topology
        assert topo is not None
        assert topo.n_workers == cluster.n_workers == 8
        assert topo.n_racks == 2
        assert topo.rack_of == (0, 0, 0, 0, 1, 1, 1, 1)
        # Oversubscribed: the uplink is strictly narrower than rack links.
        assert (topo.uplink.bandwidth_bytes_per_s
                < topo.rack_link.bandwidth_bytes_per_s)


def test_rack_fleet_sim_uses_topology_plane():
    cluster = fleet("rack2")
    profiles = ProfileRepository(cluster, MODELS)
    for d in paper_dfgs():
        profiles.register(d)
    jobs = poisson_workload(paper_dfgs(), 2.0, 30.0, seed=5)
    sim = Simulation(cluster, profiles, MODELS, scheduler="navigator", seed=1)
    res = sim.run(jobs)
    assert len(res.records) == len(jobs)
    assert res.net_local_transfers + res.net_cross_transfers > 0


# -- planners read path costs -------------------------------------------------

def heavy_dfg():
    """A pipeline whose inter-task outputs are big enough that the
    cross-rack premium dwarfs queueing differences."""
    return DFG(
        "heavy",
        tasks=[
            TaskSpec("src", 0.1, model_id=0, output_bytes=200 * MB,
                     input_bytes=1 * MB),
            TaskSpec("mid", 0.1, model_id=1, output_bytes=200 * MB),
            TaskSpec("sink", 0.1, model_id=3, output_bytes=1 * MB),
        ],
        edges=[("src", "mid"), ("mid", "sink")],
    )


def rack_profiles(cluster):
    p = ProfileRepository(cluster, MODELS)
    for d in paper_dfgs():
        p.register(d)
    p.register(heavy_dfg())
    return p


def warm_rows(n, bitmap=0xFF):
    return [
        SSTRow(ft_estimate_s=0.0, cache_bitmap=bitmap,
               free_cache_bytes=16 * GB)
        for _ in range(n)
    ]


def test_navigator_prefers_rack_local_placement():
    """On the 2-rack oversubscribed preset, a heavy pipeline entering at
    rack 0 stays in rack 0: the path-cost shipping term makes every
    cross-rack hop a ~50 ms premium the planner can see."""
    cluster = fleet("rack2")
    profiles = rack_profiles(cluster)
    sched = NavigatorScheduler(profiles, NavigatorConfig())
    job = Job(0, heavy_dfg(), arrival_time=0.0)
    adfg = sched.plan(job, 0.0, 1, warm_rows(8))
    origin_rack = cluster.topology.rack(1)
    for t, w in adfg.items():
        assert cluster.topology.rack(w) == origin_rack, (
            f"task {t} crossed racks: worker {w}, plan {adfg.assignment}"
        )


def test_navigator_crosses_racks_when_local_rack_is_swamped():
    """Rack affinity is a cost term, not a constraint: with the origin
    rack's queues saturated, the planner ships across the spine."""
    cluster = fleet("rack2")
    profiles = rack_profiles(cluster)
    sched = NavigatorScheduler(profiles, NavigatorConfig())
    rows = warm_rows(8)
    for w in range(4):  # rack 0 backlogged by 60 s
        rows[w].ft_estimate_s = 60.0
    job = Job(0, heavy_dfg(), arrival_time=0.0)
    adfg = sched.plan(job, 0.0, 1, rows)
    assert any(cluster.topology.rack(w) == 1 for _, w in adfg.items())


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 500), origin=st.integers(0, 7))
def test_jax_planner_matches_python_on_rack_topology(seed, origin):
    """The vectorized cost batch prices the same per-pair path matrix as
    the reference planner on a rack topology."""
    cluster = fleet("rack2")
    profiles = rack_profiles(cluster)
    cfg = NavigatorConfig(eviction_penalty_s=1.5)
    py = NavigatorScheduler(profiles, cfg)
    vec = JaxNavigatorPlanner(profiles, cfg)
    rng = np.random.RandomState(seed)
    rows = []
    for w in range(8):
        bitmap = 0
        for m in range(8):
            if rng.rand() < 0.4:
                bitmap |= 1 << m
        rows.append(
            SSTRow(
                ft_estimate_s=float(rng.uniform(0, 5)),
                cache_bitmap=bitmap,
                free_cache_bytes=float(rng.uniform(0, 16 * GB)),
            )
        )
    for dfg in (paper_dfgs()[0], heavy_dfg()):
        job = Job(0, dfg, arrival_time=1.0)
        a_py = py.plan(job, 1.0, origin, rows)
        a_vec = vec.plan(job, 1.0, origin, rows)
        for t in dfg.tasks:
            assert a_py[t] == a_vec[t], (t, a_py.assignment, a_vec.assignment)
            assert a_py.planned_ft[t] == pytest.approx(
                a_vec.planned_ft[t], rel=1e-5
            )

"""Chaos-test harness: seeded, replayable churn schedules driven through
the simulator, with a post-run invariant checker.

The checker asserts the properties that must survive *any* crash/join/
drain schedule, for every scheduler:

1. **No job lost** — every submitted job terminates with exactly one
   JobRecord, and finish >= arrival.
2. **No task lost or double-completed** — every task of every job has at
   least one accepted completion, and the total completion count equals
   task count + re-executed producers (each surviving attempt completes
   exactly once; void attempts are invalidated by generation tags).
3. **Accounting balances** — cache hits + misses equals model-bearing
   execution starts + fetch attempts orphaned by churn + refetches after
   eviction; wasted-byte ledgers are non-negative and bounded.
4. **Counters are sane** — churn events applied never exceed the
   schedule, bounces/rescues are non-negative.

Partition schedules add the asymmetric-reachability family
(``check_partition_invariants``):

5. **Partitions are survivable** — no task commits twice across a heal
   (the completion ledger of family 2, under cuts), no worker's row
   resurrects with a stale epoch (a partitioned worker never bumped its
   epoch, so post-heal merges win on version alone), and every
   partitioned-then-healed worker reconverges to ALIVE in every live
   reader's view within bounded gossip rounds of the final heal.

The flight recorder (core/telemetry.py) adds the strongest oracle
(``check_trace_determinism``):

6. **Traces are deterministic** — the same seed + config produces a
   byte-identical JSONL event stream across two runs, on both the
   gossip and shared-table metadata planes, under churn and partition
   schedules alike.  Any scheduling, fetch, transfer, or recovery
   divergence shows up as the first differing line.

The indexed event core (PR 7) adds the differential-replay family
(``check_engine_parity``):

7. **Engines agree bit-exactly** — the indexed engine (packed columnar
   placement + O(dirty) bookkeeping) and the pre-refactor reference
   engine produce the identical event stream, job records, and metrics
   export on the same scenario — gossip and shared-table planes, with
   and without churn, under partitions, and for the JIT scheduler.  The
   performance rewrite is only allowed to move time, never behaviour.

Run as a script for the CI chaos-smoke job (30 s seeded scenario across
all schedulers, exits non-zero on any violation)::

    PYTHONPATH=src python tests/chaos.py
"""

from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

from repro.core import (
    GossipConfig,
    LeaseConfig,
    PrefetchConfig,
    ProfileRepository,
    fleet,
)
from repro.core.state import ALIVE
from repro.sim import (
    ChurnEvent,
    SimResult,
    Simulation,
    churn_schedule,
    fleet_scaled_rate,
    partition_schedule,
    poisson_workload,
    validate_schedule,
)
from repro.workflows import MODELS, paper_dfgs

#: The scripted crash+join+drain scenario the acceptance criteria name:
#: one crash with repair, one graceful drain with later rejoin, and one
#: crash that never rejoins — all inside a 60 s window.
SCRIPTED_SCHEDULE: Tuple[ChurnEvent, ...] = (
    ChurnEvent(time=8.0, kind="crash", worker=1),
    ChurnEvent(time=14.0, kind="drain", worker=3),
    ChurnEvent(time=24.0, kind="join", worker=1),
    ChurnEvent(time=30.0, kind="crash", worker=2),
    ChurnEvent(time=38.0, kind="join", worker=3),
    ChurnEvent(time=46.0, kind="join", worker=2),
)


def scripted_partition_schedule(n_workers: int) -> List[ChurnEvent]:
    """Two cuts with heals: an uneven split long enough for cross-cut
    leases to expire (dead_after_s=4 < 6 s outage), then a short blip
    that heals before anyone is declared dead — both sides of the
    detection race.  The split is the rack boundary on even fleets
    (front/back half), which matches the ``rack2`` preset's racks."""
    half = n_workers // 2
    a = tuple(range(half))
    b = tuple(range(half, n_workers))
    return [
        ChurnEvent(time=8.0, kind="partition", groups=(a, b)),
        ChurnEvent(time=14.0, kind="heal"),
        ChurnEvent(time=22.0, kind="partition", groups=(a, b)),
        ChurnEvent(time=24.0, kind="heal"),
    ]


def run_churn_sim(
    scheduler: str = "navigator",
    fleet_name: str = "uniform",
    schedule: Optional[Sequence[ChurnEvent]] = None,
    rate: float = 1.5,
    duration: float = 60.0,
    seed: int = 3,
    sim_seed: int = 1,
    gossip: Optional[GossipConfig] = GossipConfig(period_s=0.2, fanout=2),
    lease: Optional[LeaseConfig] = LeaseConfig(),
    prefetch: Optional[PrefetchConfig] = None,
    record_events: bool = False,
    return_sim: bool = False,
    trace: bool = False,
    health: bool = False,
    engine: str = "indexed",
):
    """Build and run one churn scenario; returns (result, jobs, schedule),
    plus the finished ``Simulation`` when ``return_sim`` is set (the
    partition checker inspects the metadata plane post-run)."""
    cluster = fleet(fleet_name)
    profiles = ProfileRepository(cluster, MODELS)
    dfgs = paper_dfgs()
    for d in dfgs:
        profiles.register(d)
    jobs = poisson_workload(
        dfgs, fleet_scaled_rate(cluster, rate), duration, seed=seed
    )
    schedule = list(
        SCRIPTED_SCHEDULE if schedule is None else schedule
    )
    validate_schedule(schedule, cluster.n_workers)
    sim = Simulation(
        cluster,
        profiles,
        MODELS,
        scheduler=scheduler,
        gossip=gossip,
        lease=lease,
        churn=schedule,
        prefetch=prefetch,
        record_events=record_events,
        seed=sim_seed,
        trace=trace,
        health=health,
        engine=engine,
    )
    res = sim.run(jobs)
    if return_sim:
        return res, jobs, schedule, sim
    return res, jobs, schedule


def check_invariants(
    res: SimResult, jobs, schedule: Sequence[ChurnEvent] = ()
) -> None:
    """Assert the churn-safety invariants on a finished run."""
    # 1. Every job terminates exactly once.
    assert len(res.records) == len(jobs), (
        f"jobs lost: {len(res.records)}/{len(jobs)} records"
    )
    ids = [r.job_id for r in res.records]
    assert len(ids) == len(set(ids)), "job completed more than once"
    for r in res.records:
        assert r.finish >= r.arrival, f"job {r.job_id} finished before arrival"

    # 2. Every task completes; completions balance against re-execution.
    assert res.task_completions is not None
    all_tasks = {
        (job.job_id, tid) for job in jobs for tid in job.dfg.tasks
    }
    missing = all_tasks - set(res.task_completions)
    assert not missing, f"tasks never completed: {sorted(missing)[:5]}"
    extra = set(res.task_completions) - all_tasks
    assert not extra, f"completions for unknown tasks: {sorted(extra)[:5]}"
    assert all(c >= 1 for c in res.task_completions.values())
    total = sum(res.task_completions.values())
    assert total == len(all_tasks) + res.outputs_recovered, (
        f"completion ledger off: {total} != "
        f"{len(all_tasks)} tasks + {res.outputs_recovered} re-executions"
    )

    # 3. Cache accounting balances.
    assert 0.0 <= res.cache_hit_rate <= 1.0
    lhs = res.cache_hits + res.cache_misses
    rhs = (
        res.model_exec_starts
        + res.lost_miss_attempts
        + res.demand_refetches
    )
    assert lhs == rhs, f"hit/miss ledger off: {lhs} != {rhs}"
    assert res.bytes_fetched >= 0.0
    assert res.churn_wasted_bytes >= 0.0
    assert res.prefetch_wasted_bytes >= 0.0

    # 4. Counter sanity.
    assert res.bounces >= 0 and res.tasks_rescued >= 0
    assert res.outputs_recovered >= 0
    applied = (
        res.churn_crashes
        + res.churn_joins
        + res.churn_drains
        + res.churn_partitions
        + res.churn_heals
    )
    assert applied <= len(schedule), "more churn applied than scheduled"
    kinds = [e.kind for e in schedule if e.time <= res.horizon]
    assert res.churn_crashes <= kinds.count("crash")
    assert res.churn_joins <= kinds.count("join")
    assert res.churn_drains <= kinds.count("drain")
    assert res.churn_partitions <= kinds.count("partition")
    assert res.churn_heals <= res.churn_partitions, (
        "more heals than cuts applied"
    )
    assert res.net_local_transfers >= 0
    assert res.net_cross_transfers >= res.net_contended_transfers >= 0


def check_partition_invariants(
    res: SimResult,
    jobs,
    schedule: Sequence[ChurnEvent],
    sim: Simulation,
    reconverge_s: float = 3.0,
) -> None:
    """Family 5: the asymmetric-reachability properties a partition
    schedule must not break (run after ``check_invariants``)."""
    # Every cut that fired before the run ended was healed by then too
    # (a heal scheduled past the last job completion never processes, so
    # only assert balance once the horizon covers the final heal).
    heal_times = [e.time for e in schedule if e.kind == "heal"]
    if heal_times and res.horizon >= max(heal_times):
        assert res.churn_partitions == res.churn_heals, (
            f"run ended with an open cut: {res.churn_partitions} cuts, "
            f"{res.churn_heals} heals"
        )

    # No stale-epoch resurrection: a partitioned worker never died, so its
    # epoch only moved through real crash/drain rejoins — and no reader's
    # replica carries an epoch ahead of the owner's ground truth.
    churned = {
        e.worker for e in schedule if e.kind in ("crash", "drain", "join")
    }
    truth = sim.sst.view(None, res.horizon)
    for w in range(res.n_workers):
        if w not in churned:
            assert truth[w].epoch == 0, (
                f"worker {w} bumped its epoch to {truth[w].epoch} without "
                f"ever crashing — partitions must not look like deaths"
            )
    for reader in range(res.n_workers):
        if not sim._up[reader]:
            continue
        for w, row in enumerate(sim.sst.view(reader, res.horizon)):
            assert row.epoch <= truth[w].epoch, (
                f"reader {reader} holds epoch {row.epoch} for worker {w}, "
                f"ahead of ground truth {truth[w].epoch}"
            )

    # Reconvergence: once the final heal is ``reconverge_s`` in the past
    # (heartbeat relay across the healed cut needs a few gossip rounds),
    # every live reader classifies every live worker ALIVE again.
    last_heal = max(
        (e.time for e in schedule if e.kind == "heal"), default=0.0
    )
    if res.horizon < last_heal + reconverge_s:
        return  # run ended inside the convergence window; nothing to assert
    live = [w for w in range(res.n_workers) if sim._up[w]]
    for reader in live:
        view = sim.sst.view(reader, res.horizon)
        for w in live:
            assert view[w].liveness == ALIVE, (
                f"{reconverge_s} s after the last heal, reader {reader} "
                f"still classifies worker {w} as {view[w].liveness}"
            )


def check_trace_determinism(**kwargs) -> None:
    """Family 6: same seed + config ⇒ byte-identical JSONL trace.  Runs
    the scenario twice with the flight recorder on and diffs the exports
    (kwargs are forwarded to ``run_churn_sim``).  With ``health=True``
    the health plane joins the oracle: the summary payload and the
    metrics export must also be byte-identical, and the metrics export
    must validate against the committed schema."""
    import json

    kwargs.pop("trace", None)
    kwargs.pop("return_sim", None)
    health = bool(kwargs.pop("health", False))
    a = run_churn_sim(trace=True, health=health, **kwargs)[0]
    b = run_churn_sim(trace=True, health=health, **kwargs)[0]
    if health:
        sa = json.dumps(a.health.summary(), sort_keys=True)
        sb = json.dumps(b.health.summary(), sort_keys=True)
        assert sa == sb, "health summary diverged between identical runs"
        import os

        from repro.core.telemetry import validate_schema

        ea = json.dumps(a.metrics.export(), sort_keys=True)
        eb = json.dumps(b.metrics.export(), sort_keys=True)
        assert ea == eb, "metrics export diverged between identical runs"
        repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        with open(os.path.join(repo, "schemas", "metrics.schema.json")) as f:
            validate_schema(a.metrics.export(), json.load(f))
    ja, jb = a.trace.to_jsonl(), b.trace.to_jsonl()
    assert ja, "trace is empty"
    assert a.trace.dropped == 0, f"ring dropped {a.trace.dropped} events"
    if ja != jb:
        for i, (la, lb) in enumerate(zip(ja.splitlines(), jb.splitlines())):
            if la != lb:
                raise AssertionError(
                    f"trace diverged at line {i}:\n  run A: {la}\n"
                    f"  run B: {lb}"
                )
        raise AssertionError(
            f"trace lengths differ: {ja.count(chr(10))} vs "
            f"{jb.count(chr(10))} lines"
        )


def check_engine_parity(**kwargs) -> None:
    """Family 7: the indexed event core is bit-exact with the reference
    engine — identical event stream, per-job records, and metrics export
    on the same scenario (kwargs are forwarded to ``run_churn_sim``)."""
    import json

    kwargs.pop("engine", None)
    kwargs.pop("record_events", None)
    kwargs.pop("return_sim", None)
    a = run_churn_sim(engine="indexed", record_events=True, **kwargs)[0]
    b = run_churn_sim(engine="reference", record_events=True, **kwargs)[0]
    ea, eb = a.event_log, b.event_log
    assert ea, "event log is empty"
    if ea != eb:
        for i, (la, lb) in enumerate(zip(ea, eb)):
            if la != lb:
                raise AssertionError(
                    f"event stream diverged at #{i}: indexed {la!r} "
                    f"vs reference {lb!r}"
                )
        raise AssertionError(
            f"event counts differ: indexed {len(ea)} vs reference {len(eb)}"
        )
    ra = [(r.job_id, r.arrival, r.finish, r.lower_bound) for r in a.records]
    rb = [(r.job_id, r.arrival, r.finish, r.lower_bound) for r in b.records]
    assert ra == rb, "job records diverged between engines"
    ma = json.dumps(a.metrics.export(), sort_keys=True)
    mb = json.dumps(b.metrics.export(), sort_keys=True)
    assert ma == mb, "metrics export diverged between engines"


def main() -> int:
    """CI chaos-smoke: a 30 s seeded generated schedule plus the scripted
    crash/drain and partition scenarios, across every scheduler, on the
    heterogeneous and rack fleets."""
    duration = 30.0
    failures = 0
    generated = churn_schedule(
        5, duration, mtbf_s=40.0, repair_s=8.0, seed=11, drain_fraction=0.3
    )
    scenarios = [
        ("scripted", [e for e in SCRIPTED_SCHEDULE if e.time < duration]),
        ("generated", generated),
    ]
    for policy in ("navigator", "hash", "heft", "jit"):
        for label, schedule in scenarios:
            for fleet_name in ("uniform", "mixed"):
                res, jobs, schedule = run_churn_sim(
                    scheduler=policy,
                    fleet_name=fleet_name,
                    schedule=schedule,
                    duration=duration,
                    prefetch=PrefetchConfig(),
                )
                try:
                    check_invariants(res, jobs, schedule)
                    verdict = "ok"
                except AssertionError as exc:
                    failures += 1
                    verdict = f"FAIL: {exc}"
                print(
                    f"chaos-smoke {policy:10s} {label:9s} {fleet_name:8s} "
                    f"jobs={len(res.records)}/{len(jobs)} "
                    f"rescued={res.tasks_rescued} "
                    f"reexec={res.outputs_recovered} "
                    f"bounces={res.bounces} {verdict}"
                )
        # Partition scenario: scripted rack-boundary cuts on the flat
        # uniform fleet and the 2-rack oversubscribed preset, with the
        # full asymmetric-reachability invariant family.
        for fleet_name in ("uniform", "rack2"):
            n = fleet(fleet_name).n_workers
            schedule = scripted_partition_schedule(n)
            res, jobs, schedule, sim = run_churn_sim(
                scheduler=policy,
                fleet_name=fleet_name,
                schedule=schedule,
                duration=duration,
                prefetch=PrefetchConfig(),
                return_sim=True,
            )
            try:
                check_invariants(res, jobs, schedule)
                check_partition_invariants(res, jobs, schedule, sim)
                verdict = "ok"
            except AssertionError as exc:
                failures += 1
                verdict = f"FAIL: {exc}"
            print(
                f"chaos-smoke {policy:10s} partition {fleet_name:8s} "
                f"jobs={len(res.records)}/{len(jobs)} "
                f"cuts={res.churn_partitions} heals={res.churn_heals} "
                f"rescued={res.tasks_rescued} "
                f"reexec={res.outputs_recovered} "
                f"xrack={res.net_cross_transfers} {verdict}"
            )
    # Family 6: trace determinism on both metadata planes, under the
    # scripted churn schedule and a partition schedule.
    trace_cases = [
        ("gossip+churn", dict(
            schedule=[e for e in SCRIPTED_SCHEDULE if e.time < duration],
            duration=duration, prefetch=PrefetchConfig(),
        )),
        ("sst+churn", dict(
            schedule=[e for e in SCRIPTED_SCHEDULE if e.time < duration],
            duration=duration, gossip=None, prefetch=PrefetchConfig(),
        )),
        ("gossip+partition", dict(
            schedule=scripted_partition_schedule(5),
            duration=duration, prefetch=PrefetchConfig(),
        )),
        ("gossip+churn+health", dict(
            schedule=[e for e in SCRIPTED_SCHEDULE if e.time < duration],
            duration=duration, prefetch=PrefetchConfig(), health=True,
        )),
    ]
    for label, kwargs in trace_cases:
        try:
            check_trace_determinism(**kwargs)
            verdict = "ok"
        except AssertionError as exc:
            failures += 1
            verdict = f"FAIL: {exc}"
        print(f"chaos-smoke trace-determinism {label:17s} {verdict}")
    # Family 7: indexed-vs-reference differential replay — same event
    # stream, records, and metrics on both metadata planes, with and
    # without churn, under partitions, and for the JIT scheduler.
    churn_sched = [e for e in SCRIPTED_SCHEDULE if e.time < duration]
    parity_cases = [
        ("gossip+churn", dict(
            schedule=churn_sched, duration=duration,
            prefetch=PrefetchConfig(),
        )),
        ("sst+churn", dict(
            schedule=churn_sched, duration=duration, gossip=None,
            prefetch=PrefetchConfig(),
        )),
        ("gossip+nochurn", dict(schedule=[], duration=duration)),
        ("gossip+partition", dict(
            schedule=scripted_partition_schedule(5), duration=duration,
            prefetch=PrefetchConfig(),
        )),
        ("jit+churn", dict(
            scheduler="jit", schedule=churn_sched, duration=duration,
            prefetch=PrefetchConfig(),
        )),
    ]
    for label, kwargs in parity_cases:
        try:
            check_engine_parity(**kwargs)
            verdict = "ok"
        except AssertionError as exc:
            failures += 1
            verdict = f"FAIL: {exc}"
        print(f"chaos-smoke engine-parity {label:17s} {verdict}")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())

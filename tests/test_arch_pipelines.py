"""Assigned-architecture serving pipelines under the Navigator scheduler
(closing the loop between the paper's scheduler and the model zoo)."""

import pytest

from repro.core import GB, ProfileRepository
from repro.core.netmodel import AcceleratorLink, ClusterSpec, NetworkModel
from repro.sim import Simulation, poisson_workload
from repro.workflows.arch_pipelines import (
    ARCH_MODEL_IDS,
    arch_dfgs,
    arch_models,
)


@pytest.fixture
def pod_cluster():
    # 8 serving pods as Navigator workers; fetch link ≈ DCN weight load.
    return ClusterSpec(
        n_workers=8,
        gpu_capacity_bytes=4096 * GB,
        link=AcceleratorLink(bandwidth_bytes_per_s=100 * GB, delta_s=0.5),
        network=NetworkModel(bandwidth_bytes_per_s=50 * GB, delta_s=1e-4),
        compression_ratio=1.0,
    )


def test_model_ids_fit_sst_bitmap():
    assert all(0 <= mid <= 63 for mid in ARCH_MODEL_IDS.values())
    assert len(set(ARCH_MODEL_IDS.values())) == 10


def test_model_sizes_reflect_param_counts():
    models = arch_models()
    by_name = {m.name: m for m in models.values()}
    assert by_name["llama3-405b"].size_bytes > by_name["mamba2-780m"].size_bytes * 100


def test_pipelines_schedule_and_complete(pod_cluster):
    models = arch_models()
    dfgs = arch_dfgs()
    profiles = ProfileRepository(pod_cluster, models)
    for d in dfgs:
        profiles.register(d)
    jobs = poisson_workload(dfgs, 1.0, 120.0, seed=5)
    res = Simulation(
        pod_cluster, profiles, models, scheduler="navigator", seed=1
    ).run(jobs)
    assert len(res.records) == len(jobs)
    assert res.cache_hit_rate > 0.8


def test_navigator_beats_hash_on_arch_pipelines(pod_cluster):
    """The paper's value proposition carries to pod-scale model serving:
    cache-aware placement wins when 'models' are multi-hundred-GB shards."""
    models = arch_models()
    dfgs = arch_dfgs()
    out = {}
    for sched in ["navigator", "hash"]:
        profiles = ProfileRepository(pod_cluster, models)
        for d in dfgs:
            profiles.register(d)
        jobs = poisson_workload(dfgs, 1.2, 300.0, seed=5)
        out[sched] = Simulation(
            pod_cluster, profiles, models, scheduler=sched, seed=1
        ).run(jobs)
    assert out["navigator"].mean_slowdown < out["hash"].mean_slowdown
    assert out["navigator"].cache_hit_rate > out["hash"].cache_hit_rate

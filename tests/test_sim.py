"""Integration tests: the event-driven simulator end-to-end (§5.4, §6)."""

import pytest

from repro.core import ClusterSpec, GB, Job, NavigatorConfig, ProfileRepository
from repro.sim import Simulation, bursty_trace_workload, poisson_workload
from repro.workflows import MODELS, paper_dfgs, translation_dfg


def make_profiles(cluster):
    p = ProfileRepository(cluster, MODELS)
    for d in paper_dfgs():
        p.register(d)
    return p


def run_sim(scheduler="navigator", rate=2.0, duration=120.0, seed=3, **kw):
    cluster = kw.pop("cluster", ClusterSpec(n_workers=5))
    profiles = make_profiles(cluster)
    jobs = poisson_workload(paper_dfgs(), rate, duration, seed=seed)
    sim = Simulation(cluster, profiles, MODELS, scheduler=scheduler, seed=1, **kw)
    return sim.run(jobs), jobs


@pytest.mark.parametrize("scheduler", ["navigator", "jit", "heft", "hash"])
def test_all_jobs_complete(scheduler):
    res, jobs = run_sim(scheduler=scheduler)
    assert len(res.records) == len(jobs)
    for r in res.records:
        assert r.finish >= r.arrival
        # Lower bound uses *expected* runtimes; lognormal noise draws can
        # beat it slightly, so allow headroom below 1.0.
        assert r.slowdown > 0.5


def test_single_job_near_lower_bound():
    """One job on an idle, warm cluster should finish close to its lower
    bound (only dispatch/transfer overheads + first fetches)."""
    cluster = ClusterSpec(n_workers=5)
    profiles = make_profiles(cluster)
    job = Job(0, translation_dfg(), arrival_time=0.0)
    sim = Simulation(
        cluster, profiles, MODELS, scheduler="navigator",
        runtime_noise_sigma=0.0, seed=0,
    )
    for mem in sim.memories:
        mem.preload([0, 1, 2])
        sim._publish_cache(sim.memories.index(mem))
    for w in cluster.workers():
        sim.sst.push(w, 0.0)
    res = sim.run([job])
    assert len(res.records) == 1
    assert res.records[0].slowdown < 1.15


def test_navigator_beats_static_schedulers_high_load():
    """Claim C1 (vs HEFT/Hash): ≥2x mean latency advantage at high load."""
    nav, _ = run_sim("navigator", rate=2.0, duration=200.0)
    heft, _ = run_sim("heft", rate=2.0, duration=200.0)
    hsh, _ = run_sim("hash", rate=2.0, duration=200.0)
    assert heft.mean_latency > 2.0 * nav.mean_latency
    assert hsh.mean_latency > 2.0 * nav.mean_latency


def test_navigator_not_worse_than_jit():
    """Aggregated over seeds (single-seed comparisons are noise-dominated)."""
    nav = [run_sim("navigator", rate=2.0, duration=200.0, seed=s)[0].mean_slowdown
           for s in (3, 7, 11)]
    jit = [run_sim("jit", rate=2.0, duration=200.0, seed=s)[0].mean_slowdown
           for s in (3, 7, 11)]
    assert sum(nav) <= sum(jit) * 1.05


def test_navigator_cache_hit_rate_high():
    """Claim C2: ~99% hit rate at the paper's high-load setting."""
    nav, _ = run_sim("navigator", rate=2.0, duration=200.0)
    assert nav.cache_hit_rate > 0.97


def test_ablation_dynamic_adjustment_helps():
    cfg_off = NavigatorConfig(use_dynamic_adjustment=False)
    on = off = 0.0
    for s in (3, 7, 11):
        r_on, _ = run_sim("navigator", rate=2.0, duration=200.0, seed=s)
        r_off, _ = run_sim(
            "navigator", rate=2.0, duration=200.0, seed=s,
            navigator_config=cfg_off,
        )
        on += r_on.mean_slowdown
        off += r_off.mean_slowdown
        assert r_off.adjustments == 0 and r_on.adjustments > 0
    assert off >= on * 0.95  # adjustment is never much worse, usually better


def test_ablation_model_locality_matters():
    """Claim C3: disabling locality degrades latency substantially and
    drops the hit rate."""
    cfg_off = NavigatorConfig(use_model_locality=False)
    on, _ = run_sim("navigator", rate=2.0, duration=200.0)
    off, _ = run_sim(
        "navigator", rate=2.0, duration=200.0, navigator_config=cfg_off
    )
    assert off.mean_slowdown > 1.5 * on.mean_slowdown
    assert off.cache_hit_rate < on.cache_hit_rate


def test_eviction_policy_lookahead_not_worse():
    la, _ = run_sim("navigator", rate=2.0, duration=200.0,
                    eviction_policy="lookahead")
    ff, _ = run_sim("navigator", rate=2.0, duration=200.0,
                    eviction_policy="fifo")
    assert la.cache_evictions <= ff.cache_evictions * 1.25


def test_staleness_degrades_gracefully():
    """Claim C4: finer dissemination should not be worse; very stale load
    info hurts."""
    fresh, _ = run_sim("navigator", rate=2.0, duration=200.0,
                       push_interval_s=0.1)
    stale, _ = run_sim("navigator", rate=2.0, duration=200.0,
                       push_interval_s=2.0)
    assert stale.mean_slowdown >= fresh.mean_slowdown * 0.9


def test_energy_and_utilization_metrics():
    res, _ = run_sim("navigator", rate=2.0, duration=120.0)
    cluster = ClusterSpec(n_workers=5)
    assert 0.0 < res.gpu_utilization < 1.0
    assert res.energy_joules(cluster) > 0
    assert res.percentile_latency(0.5) <= res.percentile_latency(0.99)


def test_bursty_workload_completes():
    cluster = ClusterSpec(n_workers=5)
    profiles = make_profiles(cluster)
    jobs = bursty_trace_workload(paper_dfgs(), 0.5, 120.0, seed=5)
    res = Simulation(cluster, profiles, MODELS, scheduler="navigator", seed=2).run(jobs)
    assert len(res.records) == len(jobs)


def test_deterministic_given_seed():
    a, _ = run_sim("navigator", rate=1.0, duration=60.0, seed=11)
    b, _ = run_sim("navigator", rate=1.0, duration=60.0, seed=11)
    assert a.mean_latency == b.mean_latency
    assert a.cache_hits == b.cache_hits

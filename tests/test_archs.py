"""Per-architecture smoke tests: reduced variants (≤2 layers, d_model ≤256,
≤4 experts) run one forward + one train step + one decode step on CPU,
asserting output shapes and finiteness (deliverable f)."""

import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS
from repro.models import (
    decode_step,
    forward,
    init_cache,
    init_params,
    next_token_loss,
)
from repro.training import optimizer as opt

ARCH_IDS = sorted(ARCHS)


def reduced(arch_id):
    cfg = ARCHS[arch_id].reduced(dtype="float32")
    return cfg


def make_batch(cfg, b=2, s=16, key=0):
    k = jax.random.key(key)
    batch = {"tokens": jax.random.randint(k, (b, s), 0, cfg.vocab)}
    if cfg.arch_type == "vlm":
        batch["vision_embeds"] = (
            jax.random.normal(k, (b, 9, cfg.d_model), jnp.float32) * 0.02
        )
    if cfg.arch_type == "audio":
        batch["audio_frames"] = (
            jax.random.normal(k, (b, cfg.n_audio_frames, cfg.d_model)) * 0.02
        )
    return batch


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_smoke_forward(arch_id):
    cfg = reduced(arch_id)
    params = init_params(cfg, jax.random.key(0))
    batch = make_batch(cfg)
    logits, aux = jax.jit(
        lambda p, b: forward(p, b, cfg, moe_dispatch="scan")
    )(params, batch)
    assert logits.shape == (2, 16, cfg.vocab)
    assert jnp.isfinite(logits).all(), f"{arch_id}: non-finite logits"
    assert jnp.isfinite(aux)


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_smoke_train_step(arch_id):
    cfg = reduced(arch_id)
    params = init_params(cfg, jax.random.key(0))
    state = opt.init(params)
    ocfg = opt.AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    batch = make_batch(cfg)

    @jax.jit
    def step(params, state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: next_token_loss(p, batch, cfg, moe_dispatch="scan")
        )(params)
        new_p, new_s, metrics = opt.apply(ocfg, grads, state, params)
        metrics["loss"] = loss
        return new_p, new_s, metrics

    new_params, new_state, metrics = step(params, state, batch)
    assert jnp.isfinite(metrics["loss"])
    assert jnp.isfinite(metrics["grad_norm"])
    assert int(new_state.step) == 1
    # Parameters actually moved.
    moved = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))), params, new_params
    )
    assert max(jax.tree.leaves(moved)) > 0


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_smoke_decode_step(arch_id):
    cfg = reduced(arch_id)
    params = init_params(cfg, jax.random.key(0))
    b = 2
    cache = init_cache(cfg, b, capacity=32)
    if cfg.arch_type == "audio":
        # Seed cross-attention KV from stub encoder frames.
        from repro.models.layers import project_cross_kv
        from repro.models.model import _encode_audio

        frames = jax.random.normal(
            jax.random.key(1), (b, cfg.n_audio_frames, cfg.d_model)
        ) * 0.02
        enc = _encode_audio(params, frames, cfg, impl="ref")
        ck, cv = [], []
        for li in range(cfg.n_layers):
            layer_cross = jax.tree.map(lambda x: x[li], params["layers"]["cross"])
            k, v = project_cross_kv(
                enc, layer_cross, n_kv_heads=cfg.n_kv_heads, head_dim=cfg.hd
            )
            ck.append(k)
            cv.append(v)
        cache["cross_k"] = jnp.stack(ck)
        cache["cross_v"] = jnp.stack(cv)
    tokens = jnp.array([1, 2], jnp.int32)
    step = jax.jit(
        lambda p, c, t: decode_step(p, c, t, cfg, moe_dispatch="scan")
    )
    logits, cache = step(params, cache, tokens)
    assert logits.shape == (b, cfg.vocab)
    assert jnp.isfinite(logits).all()
    logits2, cache = step(params, cache, tokens)
    assert int(cache["pos"][0]) == 2
    assert jnp.isfinite(logits2).all()


@pytest.mark.parametrize("arch_id", ["granite-20b", "mistral-nemo-12b"])
def test_smoke_windowed_decode(arch_id):
    """Sliding-window ring buffer decode (the long_500k mechanism) keeps
    producing finite logits past the window wrap-around."""
    cfg = dataclasses.replace(reduced(arch_id), sliding_window=8)
    params = init_params(cfg, jax.random.key(0))
    cache = init_cache(cfg, 1, capacity=8)
    step = jax.jit(lambda p, c, t: decode_step(p, c, t, cfg))
    tok = jnp.array([3], jnp.int32)
    for i in range(20):  # wraps the 8-slot ring twice
        logits, cache = step(params, cache, tok)
        assert jnp.isfinite(logits).all()
    assert int(cache["pos"][0]) == 20


def test_exact_assigned_dimensions():
    """The registry carries the exact assigned configs."""
    c = ARCHS["llama3-405b"]
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff, c.vocab) == (
        126, 16384, 128, 8, 53248, 128256,
    )
    c = ARCHS["deepseek-v2-236b"]
    assert (c.n_layers, c.d_model, c.n_heads, c.kv_lora_rank) == (60, 5120, 128, 512)
    assert (c.n_experts, c.top_k, c.n_shared_experts) == (160, 6, 2)
    c = ARCHS["qwen3-moe-30b-a3b"]
    assert (c.n_experts, c.top_k, c.vocab) == (128, 8, 151936)
    c = ARCHS["mamba2-780m"]
    assert (c.n_layers, c.d_model, c.ssm_state) == (48, 1536, 128)
    c = ARCHS["zamba2-7b"]
    assert (c.n_layers, c.d_model, c.ssm_state) == (81, 3584, 64)
    c = ARCHS["whisper-medium"]
    assert (c.n_layers, c.n_encoder_layers, c.d_model) == (24, 24, 1024)


def test_param_counts_match_model_cards():
    """Total parameter counts land near the advertised sizes."""
    expect = {
        "llama3-405b": (390e9, 420e9),
        "deepseek-v2-236b": (230e9, 250e9),
        "qwen3-moe-30b-a3b": (28e9, 33e9),
        "mistral-large-123b": (115e9, 130e9),
        "mistral-nemo-12b": (11e9, 14e9),
        "qwen2-vl-72b": (68e9, 76e9),
        "mamba2-780m": (0.7e9, 1.0e9),
        "zamba2-7b": (6e9, 8e9),
    }
    for name, (lo, hi) in expect.items():
        n = ARCHS[name].param_count()
        assert lo <= n <= hi, f"{name}: {n/1e9:.1f}B outside [{lo/1e9},{hi/1e9}]"
    # MoE active params.
    assert ARCHS["qwen3-moe-30b-a3b"].param_count(active_only=True) < 4e9
    assert ARCHS["deepseek-v2-236b"].param_count(active_only=True) < 30e9

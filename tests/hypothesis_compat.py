"""Per-test ``hypothesis`` gating.

The old pattern — ``pytest.importorskip("hypothesis")`` at module level —
skipped *entire files* when the library is missing, silently dropping
every non-property test they contain.  Importing ``given``/``settings``/
``st`` from here instead keeps plain tests running everywhere and marks
only the property-based tests as individually skipped (visible in the
report) when ``hypothesis`` is absent.

Usage::

    from hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st
"""

import pytest

try:
    from hypothesis import HealthCheck, given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised only without hypothesis
    HAVE_HYPOTHESIS = False
    HealthCheck = None

    class _StrategyStub:
        """Placeholder for ``strategies``: decorator arguments evaluate at
        import time, so attribute/call chains must not explode; the tests
        themselves never run (``given`` skips them)."""

        def __getattr__(self, name):
            return lambda *args, **kwargs: None

    st = _StrategyStub()

    def given(*_args, **_kwargs):
        def decorate(fn):
            return pytest.mark.skip(
                reason="hypothesis not installed (property test)"
            )(fn)

        return decorate

    def settings(*_args, **_kwargs):
        def decorate(fn):
            return fn

        return decorate

"""Partition tolerance: cuts and heals through the simulator, the
asymmetric-reachability lease semantics, and the chaos invariant family
(no double commit, no stale-epoch resurrection, bounded reconvergence,
bit-exact determinism)."""

import pytest

from chaos import (
    check_invariants,
    check_partition_invariants,
    run_churn_sim,
    scripted_partition_schedule,
)
from repro.core import LeaseConfig, PrefetchConfig, SharedStateTable
from repro.core.state import ALIVE, DEAD
from repro.sim import ChurnEvent, partition_schedule, validate_schedule


# -- schedule generation ------------------------------------------------------

def test_partition_schedule_well_formed():
    sched = partition_schedule(8, duration_s=60.0, mtbp_s=20.0, seed=3)
    validate_schedule(sched, 8)
    kinds = [e.kind for e in sched]
    assert kinds.count("partition") == kinds.count("heal") > 0
    # Cuts never overlap and every cut heals.
    open_cut = False
    for e in sched:
        if e.kind == "partition":
            assert not open_cut
            open_cut = True
            assert e.groups is not None and len(e.groups) >= 2
        elif e.kind == "heal":
            assert open_cut
            open_cut = False
    assert not open_cut


def test_partition_schedule_deterministic():
    a = partition_schedule(8, 60.0, mtbp_s=20.0, seed=5)
    b = partition_schedule(8, 60.0, mtbp_s=20.0, seed=5)
    assert a == b
    c = partition_schedule(8, 60.0, mtbp_s=20.0, seed=6)
    assert a != c


def test_unhealed_partition_rejected():
    sched = [ChurnEvent(time=5.0, kind="partition", groups=((0, 1), (2, 3)))]
    with pytest.raises(ValueError):
        validate_schedule(sched, 4)


# -- central-plane lease asymmetry -------------------------------------------

def test_sst_partition_lease_disagreement():
    """Across a cut, readers classify each other from the frozen pre-cut
    heartbeat; same-side verdicts stay fresh — per-reader disagreement."""
    lease = LeaseConfig()
    sst = SharedStateTable(4, lease=lease)
    for w in range(4):
        sst.heartbeat(w, 10.0)
        sst.push(w, 10.0)
    sst.set_partition([0, 0, 1, 1], now=10.0)
    # Keep side-local heartbeats fresh well past dead_after_s.
    later = 10.0 + lease.dead_after_s + 1.0
    for w in range(4):
        sst.heartbeat(w, later)
        sst.push(w, later)
    for reader in range(4):
        view = sst.view(reader, later)
        for w in range(4):
            same_side = (reader < 2) == (w < 2)
            assert view[w].liveness == (ALIVE if same_side else DEAD), (
                f"reader {reader} sees worker {w} as {view[w].liveness}"
            )
    # Healing restores symmetric ALIVE verdicts immediately (the central
    # plane replays the owner's real heartbeats; gossip takes rounds).
    sst.set_partition(None, later)
    for reader in range(4):
        assert all(r.liveness == ALIVE for r in sst.view(reader, later))


def test_sst_partition_short_blip_never_suspects():
    """A cut shorter than suspect_after_s is invisible to the lease."""
    lease = LeaseConfig()
    sst = SharedStateTable(2, lease=lease)
    for w in range(2):
        sst.heartbeat(w, 5.0)
        sst.push(w, 5.0)
    sst.set_partition([0, 1], now=5.0)
    t = 5.0 + lease.suspect_after_s * 0.5
    assert sst.view(0, t)[1].liveness == ALIVE


# -- simulator end-to-end -----------------------------------------------------

FLEETS = ("uniform", "rack2")
POLICIES = ("navigator", "hash")


@pytest.mark.parametrize("fleet_name", FLEETS)
@pytest.mark.parametrize("policy", POLICIES)
def test_scripted_partition_invariants(policy, fleet_name):
    """The scripted rack-boundary cut scenario upholds every churn and
    partition invariant: no job or task lost, no double commit across a
    heal, no stale-epoch resurrection, bounded reconvergence."""
    from repro.core import fleet

    n = fleet(fleet_name).n_workers
    schedule = scripted_partition_schedule(n)
    res, jobs, schedule, sim = run_churn_sim(
        scheduler=policy,
        fleet_name=fleet_name,
        schedule=schedule,
        duration=30.0,
        prefetch=PrefetchConfig(),
        return_sim=True,
    )
    check_invariants(res, jobs, schedule)
    check_partition_invariants(res, jobs, schedule, sim)
    assert res.churn_partitions == 2 and res.churn_heals == 2


def test_generated_partition_invariants():
    """Seeded generated cut schedules (not just the scripted one) uphold
    the same family, including cuts interleaved with gossip."""
    from repro.core import fleet

    n = fleet("rack2").n_workers
    for seed in (0, 7):
        schedule = partition_schedule(
            n, duration_s=40.0, mtbp_s=15.0, outage_s=5.0, seed=seed
        )
        res, jobs, schedule, sim = run_churn_sim(
            scheduler="navigator",
            fleet_name="rack2",
            schedule=schedule,
            duration=40.0,
            seed=seed + 1,
            return_sim=True,
        )
        check_invariants(res, jobs, schedule)
        check_partition_invariants(res, jobs, schedule, sim)


def test_partition_with_crash_overlap():
    """A worker that crashes *while partitioned away* must recover through
    the normal epoch-bump path; peers that merely presumed it dead across
    the cut must not have committed its tasks twice."""
    schedule = [
        ChurnEvent(time=6.0, kind="partition",
                   groups=((0, 1, 2, 3), (4, 5, 6, 7))),
        ChurnEvent(time=8.0, kind="crash", worker=6),
        ChurnEvent(time=13.0, kind="heal"),
        ChurnEvent(time=18.0, kind="join", worker=6),
    ]
    res, jobs, schedule, sim = run_churn_sim(
        scheduler="navigator",
        fleet_name="rack2",
        schedule=schedule,
        duration=30.0,
        return_sim=True,
    )
    check_invariants(res, jobs, schedule)
    check_partition_invariants(res, jobs, schedule, sim)
    truth = sim.sst.view(None, res.horizon)
    assert truth[6].epoch == 1  # exactly the one real rejoin


def test_partition_bit_exact_determinism():
    """Same seeds, same schedule → byte-identical event logs, on the rack
    fleet with cuts, heals, gossip, and prefetch all active."""
    logs = []
    for _ in range(2):
        n = 8
        res, jobs, schedule = run_churn_sim(
            scheduler="navigator",
            fleet_name="rack2",
            schedule=scripted_partition_schedule(n),
            duration=30.0,
            prefetch=PrefetchConfig(),
            record_events=True,
        )
        logs.append((res.event_log, res.mean_latency, res.tasks_rescued))
    assert logs[0] == logs[1]


def test_partition_forces_failover_work():
    """The long scripted cut actually exercises the failover machinery —
    the invariants above must not be vacuously true.  Hash placement
    ignores racks, so it keeps shipping across the spine into the cut and
    must rescue or re-execute; the topology-aware navigator, by contrast,
    mostly rides it out rack-locally."""
    res, jobs, schedule = run_churn_sim(
        scheduler="hash",
        fleet_name="rack2",
        schedule=scripted_partition_schedule(8),
        duration=30.0,
        prefetch=PrefetchConfig(),
    )
    assert res.churn_partitions == 2
    assert (res.tasks_rescued + res.outputs_recovered + res.bounces) > 0

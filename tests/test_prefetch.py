"""Predictive prefetch plane (core/prefetch.py): intent lifecycle, fetch-
pipe arbitration, anti-herd hysteresis, intent-aware planning, and the
intent bitmap on both metadata planes."""

import numpy as np
import pytest

from repro.core import (
    ClusterSpec,
    GB,
    GossipConfig,
    GossipPlane,
    Job,
    MB,
    NavigatorConfig,
    NavigatorScheduler,
    PrefetchConfig,
    PrefetchPlane,
    ProfileRepository,
    SharedStateTable,
)
from repro.core.state import SSTRow
from repro.core.types import DFG, TaskSpec
from repro.sim import Simulation, bursty_trace_workload, poisson_workload
from repro.workflows import MODELS, paper_dfgs, translation_dfg


# --------------------------------------------------------------------------
# fixtures
# --------------------------------------------------------------------------
def make_profiles(cluster=None):
    cluster = cluster or ClusterSpec(n_workers=5)
    p = ProfileRepository(cluster, MODELS)
    for d in paper_dfgs():
        p.register(d)
    return p


def planned_job(profiles, dfg=None, now=0.0, origin=0):
    dfg = dfg or translation_dfg()
    job = Job(0, dfg, arrival_time=now)
    sst = [
        SSTRow(free_cache_bytes=16 * GB, pushed_at=now) for _ in range(5)
    ]
    sched = NavigatorScheduler(profiles)
    return job, sched.plan(job, now, origin, sst)


def mk_plane(profiles, config=None, n_workers=5):
    return PrefetchPlane(
        n_workers, config or PrefetchConfig(),
        fetch_time_fn=profiles.td_model,
    )


# --------------------------------------------------------------------------
# intent derivation + queue maintenance
# --------------------------------------------------------------------------
def test_plan_intents_groups_by_worker_and_limits_depth():
    profiles = make_profiles()
    plane = mk_plane(profiles, PrefetchConfig(lookahead_depth=1))
    job, adfg = planned_job(profiles)
    per = plane.plan_intents(job, adfg, profiles, now=0.0)
    assert per  # the translation DFG has model-bearing tasks
    for w, intents in per.items():
        assert len(intents) <= 1  # depth cap
        for i in intents:
            assert adfg[i.task_id] == w
            assert i.model_id == job.dfg.tasks[i.task_id].model_id
            assert i.expected_start_s >= 0.0


def test_plan_intents_ordered_by_expected_start():
    profiles = make_profiles()
    plane = mk_plane(profiles, PrefetchConfig(lookahead_depth=8))
    job, adfg = planned_job(profiles)
    for intents in plane.plan_intents(job, adfg, profiles, 0.0).values():
        starts = [i.expected_start_s for i in intents]
        assert starts == sorted(starts)


def test_admit_dedups_replans_and_counts():
    profiles = make_profiles()
    plane = mk_plane(profiles)
    job, adfg = planned_job(profiles)
    per = plane.plan_intents(job, adfg, profiles, 0.0)
    w, intents = next(iter(per.items()))
    plane.admit(w, intents, 0.0)
    issued = plane.stats.intents_issued
    # Re-planning the same tasks must not duplicate queue entries.
    plane.admit(w, plane.plan_intents(job, adfg, profiles, 1.0)[w], 1.0)
    assert plane.stats.intents_issued == issued
    assert plane.queue_depth(w) == len(intents)


def test_admit_bounds_queue_dropping_latest_needed():
    profiles = make_profiles()
    plane = mk_plane(profiles, PrefetchConfig(max_queue=2))
    intents = [
        plane.make_intent(
            Job(j, translation_dfg(), 0.0), "mt5_zh", 0, 0.0,
            expected_start_s=float(j),
        )
        for j in range(5)
    ]
    plane.admit(0, intents, 0.0)
    assert plane.queue_depth(0) == 2
    assert plane.stats.intents_dropped == 3
    # The earliest-needed intents survive.
    kept = sorted(i.expected_start_s for i in plane.queues[0].values())
    assert kept == [0.0, 1.0]


def test_cancel_removes_queued_intent():
    profiles = make_profiles()
    plane = mk_plane(profiles)
    job = Job(7, translation_dfg(), 0.0)
    plane.admit(2, [plane.make_intent(job, "mt5_zh", 2, 0.0)], 0.0)
    assert plane.cancel(2, 7, "mt5_zh") is None  # nothing in flight
    assert plane.queue_depth(2) == 0
    assert plane.stats.intents_cancelled == 1


def test_cancel_inflight_prefers_heir_over_abort():
    profiles = make_profiles()
    plane = mk_plane(profiles)
    job_a, job_b = Job(1, translation_dfg(), 0.0), Job(2, translation_dfg(), 0.0)
    plane.admit(0, [plane.make_intent(job_a, "mt5_zh", 0, 0.0)], 0.0)
    intent, _ = plane.next_intent(0, 0.0, lambda m: False)
    assert intent is not None and plane.inflight[0] is intent
    # Another job wants the same model: cancelling the in-flight owner
    # hands the transfer to the heir instead of aborting it.
    plane.admit(0, [plane.make_intent(job_b, "mt5_ja", 0, 0.0)], 0.0)
    assert plane.cancel(0, 1, "mt5_zh", migrated=True) is None
    assert plane.inflight[0] is not None
    assert plane.inflight[0].job_id == 2
    # With no heir, cancel returns the aborted in-flight intent.
    aborted = plane.cancel(0, 2, "mt5_ja")
    assert aborted is not None and aborted.model_id == intent.model_id


def test_consume_spends_intent_when_demand_takes_over():
    profiles = make_profiles()
    plane = mk_plane(profiles)
    job = Job(3, translation_dfg(), 0.0)
    plane.admit(1, [plane.make_intent(job, "marian_fr", 1, 0.0)], 0.0)
    plane.consume(1, 3, "marian_fr")
    assert plane.queue_depth(1) == 0
    assert plane.stats.intents_consumed == 1


def test_next_intent_skips_resident_and_expires_ttl():
    profiles = make_profiles()
    plane = mk_plane(profiles, PrefetchConfig(intent_ttl_s=5.0))
    job = Job(4, translation_dfg(), 0.0)
    plane.admit(0, [plane.make_intent(job, "opt_ingest", 0, 0.0)], 0.0)
    # Resident → retired without a fetch.
    intent, _ = plane.next_intent(0, 1.0, lambda m: True)
    assert intent is None
    assert plane.stats.already_resident == 1
    # Stale → expired unissued.
    plane.admit(0, [plane.make_intent(job, "mt5_zh", 0, 0.0)], 0.0)
    intent, _ = plane.next_intent(0, 100.0, lambda m: False)
    assert intent is None
    assert plane.stats.intents_expired == 1


def test_anti_herd_defers_nonurgent_when_peer_advertises():
    profiles = make_profiles()
    plane = mk_plane(
        profiles, PrefetchConfig(herd_backoff_s=1.0, urgency_slack_s=0.1)
    )
    job = Job(5, translation_dfg(), 0.0)
    mid = translation_dfg().tasks["mt5_zh"].model_id
    # Task expected far in the future → not urgent.
    plane.admit(
        0, [plane.make_intent(job, "mt5_zh", 0, 0.0,
                              expected_start_s=100.0)], 0.0
    )
    peer_bits = 1 << mid
    intent, retry_at = plane.next_intent(0, 0.0, lambda m: False, peer_bits)
    assert intent is None
    assert plane.stats.deferrals == 1
    assert retry_at == pytest.approx(1.0)
    # Same situation but urgent (expected start imminent) → fetch anyway.
    plane.admit(
        0, [plane.make_intent(Job(6, translation_dfg(), 0.0), "mt5_ja", 0,
                              0.0, expected_start_s=0.5)], 0.0
    )
    intent, _ = plane.next_intent(0, 0.0, lambda m: False, peer_bits)
    assert intent is not None and intent.job_id == 6


def test_advertised_bits_cover_queued_and_inflight():
    profiles = make_profiles()
    plane = mk_plane(profiles)
    job = Job(8, translation_dfg(), 0.0)
    plane.admit(0, [plane.make_intent(job, "opt_ingest", 0, 0.0)], 0.0)
    plane.admit(0, [plane.make_intent(job, "mt5_zh", 0, 0.0)], 0.0)
    queued = plane.advertised_bits(0)
    assert queued & (1 << 0) and queued & (1 << 2)
    intent, _ = plane.next_intent(0, 0.0, lambda m: False)
    assert intent is not None
    # Popped to in-flight: still advertised.
    assert plane.advertised_bits(0) == queued


# --------------------------------------------------------------------------
# intent-aware planning (Eq. 2 discount + anti-herd stickiness)
# --------------------------------------------------------------------------
def _rows(n=5, now=0.0, **kw):
    return [
        SSTRow(free_cache_bytes=16 * GB, pushed_at=now, **kw)
        for _ in range(n)
    ]


def test_planner_discounts_intended_worker():
    profiles = make_profiles()
    dfg = DFG("one", [TaskSpec("t", 0.4, model_id=2)], [])
    profiles.register(dfg)
    job = Job(0, dfg, 0.0)
    sst = _rows()
    sst[3].intent_bitmap = 1 << 2  # worker 3 intends model 2
    sched = NavigatorScheduler(
        profiles, NavigatorConfig(intent_confidence=0.9)
    )
    adfg = sched.plan(job, 0.0, 0, sst)
    assert adfg["t"] == 3


def test_stale_intent_gets_no_discount():
    profiles = make_profiles()
    dfg = DFG("one2", [TaskSpec("t", 0.4, model_id=2)], [])
    profiles.register(dfg)
    job = Job(0, dfg, 100.0)
    sst = _rows(now=0.0)  # rows pushed at t=0, planning at t=100
    sst[3].intent_bitmap = 1 << 2
    sched = NavigatorScheduler(
        profiles,
        NavigatorConfig(intent_confidence=0.9, intent_fresh_s=5.0),
    )
    adfg = sched.plan(job, 100.0, 0, sst)
    assert adfg["t"] == 0  # no discount → origin wins on input locality


def test_herd_margin_moves_task_to_intending_worker():
    profiles = make_profiles()
    dfg = DFG("one3", [TaskSpec("t", 0.4, model_id=2)], [])
    profiles.register(dfg)
    job = Job(0, dfg, 0.0)
    sst = _rows()
    sst[3].intent_bitmap = 1 << 2
    # Zero confidence: no cost discount, so only the sticky margin can
    # pull the task onto the intending worker.
    base = NavigatorConfig(intent_confidence=0.0, intent_herd_margin=0.0)
    sticky = NavigatorConfig(intent_confidence=0.0, intent_herd_margin=0.9)
    assert NavigatorScheduler(profiles, base).plan(job, 0.0, 0, sst)["t"] == 0
    assert NavigatorScheduler(profiles, sticky).plan(job, 0.0, 0, sst)["t"] == 3


def test_capacity_infeasible_worker_never_planned():
    from repro.core import build_fleet, WorkerProfile

    big = WorkerProfile("big", 1.0, 16.0 * GB)
    tiny = WorkerProfile("tiny", 4.0, 2.0 * GB)  # fast but too small
    cluster = build_fleet([big, tiny])
    profiles = ProfileRepository(cluster, MODELS)
    dfg = DFG("cap", [TaskSpec("t", 0.4, model_id=0)], [])  # 6.5 GB model
    profiles.register(dfg)
    job = Job(0, dfg, 0.0)
    sst = [SSTRow(free_cache_bytes=cluster.gpu_capacity(w)) for w in range(2)]
    adfg = NavigatorScheduler(profiles).plan(job, 0.0, 1, sst)
    assert adfg["t"] == 0  # despite worker 1 being 4x faster and origin


def test_jax_planner_matches_python_with_intents():
    from repro.core.jax_planner import JaxNavigatorPlanner

    cluster = ClusterSpec(n_workers=5)
    profiles = make_profiles(cluster)
    cfg = NavigatorConfig(
        eviction_penalty_s=1.5,
        intent_confidence=0.7,
        intent_herd_margin=0.15,
    )
    py = NavigatorScheduler(profiles, cfg)
    vec = JaxNavigatorPlanner(profiles, cfg)
    rng = np.random.RandomState(0)
    for trial in range(15):
        sst = []
        for w in range(5):
            bitmap, intent = 0, 0
            for m in range(8):
                if rng.rand() < 0.3:
                    bitmap |= 1 << m
                if rng.rand() < 0.3:
                    intent |= 1 << m
            sst.append(
                SSTRow(
                    ft_estimate_s=float(rng.uniform(0, 5)),
                    cache_bitmap=bitmap,
                    free_cache_bytes=float(rng.uniform(0, 16 * GB)),
                    pushed_at=1.0,
                    intent_bitmap=bitmap | intent,
                )
            )
        dfg = paper_dfgs()[trial % 4]
        job = Job(trial, dfg, 1.0)
        a_py = py.plan(job, 1.0, trial % 5, sst)
        a_vec = vec.plan(job, 1.0, trial % 5, sst)
        for t in dfg.tasks:
            assert a_py[t] == a_vec[t], (trial, t, a_py.assignment,
                                         a_vec.assignment)
            assert a_py.planned_ft[t] == pytest.approx(
                a_vec.planned_ft[t], rel=1e-5
            )


# --------------------------------------------------------------------------
# intent bitmap on both metadata planes
# --------------------------------------------------------------------------
def test_sst_wire_row_carries_intent_bitmap():
    from repro.core.sst_exchange import ROW_WIDTH, pack_row, unpack_rows

    row = SSTRow(
        ft_estimate_s=1.5,
        cache_bitmap=(1 << 5) | 1,
        intent_bitmap=(1 << 63) | (1 << 5) | 3,
    )
    packed = pack_row(row)
    assert packed.shape == (ROW_WIDTH,) and packed.nbytes == 64
    back = unpack_rows(packed[None])[0]
    assert back.intent_bitmap == row.intent_bitmap
    assert back.cache_bitmap == row.cache_bitmap


def test_shared_state_table_publishes_intent_on_cache_cadence():
    sst = SharedStateTable(3)
    sst.update_intent(1, 0b1010, now=1.0)
    assert sst.view(0)[1].intent_bitmap == 0  # not pushed yet
    sst.push_cache(1, 2.0)
    assert sst.view(0)[1].intent_bitmap == 0b1010
    assert sst.view(1)[1].intent_bitmap == 0b1010  # own row always fresh


def test_gossip_plane_carries_intent_bitmap():
    plane = GossipPlane(3, GossipConfig(fanout=2, period_s=0.1))
    plane.update_intent(0, 0b110, now=0.0)
    for q, updates, _nbytes in plane.exchange(0, 0.1):
        plane.deliver(q, updates, 0.1)
    views = [plane.view(w)[0].intent_bitmap for w in range(3)]
    assert views[0] == 0b110  # own ground truth
    assert 0b110 in views[1:]  # at least one peer learned it this round


# --------------------------------------------------------------------------
# simulator integration: arbitration, migration, end-to-end
# --------------------------------------------------------------------------
def _sim(cluster, prefetch=None, scheduler="navigator", **kw):
    profiles = ProfileRepository(cluster, MODELS)
    for d in paper_dfgs():
        profiles.register(d)
    return Simulation(
        cluster, profiles, MODELS, scheduler=scheduler,
        prefetch=prefetch, **kw,
    )


def test_sim_prefetch_completes_all_jobs_and_populates_stats():
    cluster = ClusterSpec(n_workers=5)
    jobs = bursty_trace_workload(paper_dfgs(), 0.8, 120.0, seed=3)
    res = _sim(cluster, prefetch=PrefetchConfig(), seed=1).run(jobs)
    assert len(res.records) == len(jobs)
    s = res.prefetch_stats
    assert s is not None and s.intents_issued > 0
    assert s.prefetches_started > 0
    assert res.prefetch_bytes > 0


def test_sim_prefetch_deterministic_given_seed():
    cluster = ClusterSpec(n_workers=5)
    jobs = poisson_workload(paper_dfgs(), 1.0, 60.0, seed=11)
    a = _sim(cluster, prefetch=PrefetchConfig(), seed=1).run(jobs)
    b = _sim(cluster, prefetch=PrefetchConfig(), seed=1).run(jobs)
    assert a.mean_latency == b.mean_latency
    assert a.prefetch_bytes == b.prefetch_bytes


def test_sim_prefetch_improves_demand_hit_rate():
    cluster = ClusterSpec(n_workers=5)
    jobs = bursty_trace_workload(paper_dfgs(), 0.8, 200.0, seed=7)
    off = _sim(cluster, prefetch=None, seed=1).run(jobs)
    on = _sim(cluster, prefetch=PrefetchConfig(), seed=1).run(jobs)
    assert on.cache_hit_rate >= off.cache_hit_rate
    assert len(on.records) == len(off.records) == len(jobs)


def test_demand_preempts_speculative_prefetch():
    """One worker: job A's downstream model is being speculatively
    fetched when job B's demand fetch arrives → the prefetch is aborted
    (and the queued task later re-fetches its model)."""
    chain = DFG(
        "chain",
        tasks=[
            TaskSpec("a", 1.0, model_id=1, output_bytes=0.1 * MB),
            TaskSpec("b", 0.5, model_id=2, output_bytes=0.1 * MB),
        ],
        edges=[("a", "b")],
    )
    solo = DFG("solo", [TaskSpec("c", 0.5, model_id=3)], [])
    cluster = ClusterSpec(n_workers=1)
    profiles = ProfileRepository(cluster, MODELS)
    profiles.register(chain)
    profiles.register(solo)
    # Job A at t=0; job B lands while A's model-2 prefetch is in flight
    # (A's model-1 demand fetch takes ~1.2 s, then "a" runs ~1 s while
    # model 2 prefetches).
    jobs = [Job(0, chain, 0.0), Job(1, solo, 1.6)]
    sim = Simulation(
        cluster, profiles, MODELS, scheduler="navigator",
        prefetch=PrefetchConfig(herd_backoff_s=0.0),
        runtime_noise_sigma=0.0, seed=0,
    )
    res = sim.run(jobs)
    assert len(res.records) == 2
    s = res.prefetch_stats
    assert s.prefetches_started >= 1
    assert s.prefetches_preempted >= 1
    assert res.prefetch_wasted_bytes > 0  # the aborted partial transfer


def test_prefetch_promotion_on_demand_of_inflight_model():
    """The entry task's own model is speculatively fetched the moment the
    plan lands — when the task itself arrives an instant later, the
    transfer is promoted to a demand fetch, not restarted."""
    solo = DFG("solo_p", [TaskSpec("c", 0.5, model_id=3)], [])
    cluster = ClusterSpec(n_workers=1)
    profiles = ProfileRepository(cluster, MODELS)
    profiles.register(solo)
    sim = Simulation(
        cluster, profiles, MODELS, scheduler="navigator",
        prefetch=PrefetchConfig(), runtime_noise_sigma=0.0, seed=0,
    )
    res = sim.run([Job(0, solo, 0.0)])
    assert len(res.records) == 1
    assert res.prefetch_stats.prefetches_promoted >= 1
    # Promotion means no duplicate transfer: exactly one model-3 fetch.
    assert res.bytes_fetched == pytest.approx(
        MODELS[3].size_bytes * cluster.compression_ratio
    )


def test_adjustment_migrates_intent():
    cluster = ClusterSpec(n_workers=5)
    jobs = poisson_workload(paper_dfgs(), 2.0, 120.0, seed=3)
    res = _sim(cluster, prefetch=PrefetchConfig(), seed=1).run(jobs)
    assert len(res.records) == len(jobs)
    if res.adjustments > 0:
        assert res.prefetch_stats.intents_migrated > 0


def test_capacity_blind_scheduler_bounces_on_small_gpu():
    """Hash can place a 6.5 GB-model task on an 8 GB edge GPU that can
    never host it (cached+decompressed 10.4 GB); the dispatcher must
    re-route instead of crashing."""
    import zlib

    from repro.core import fleet

    cluster = fleet("mixed")  # worker 4 is an 8 GB EDGE GPU
    dfg = translation_dfg()
    jid = next(
        j for j in range(200)
        if zlib.crc32(f"opt_ingest:{j}".encode()) % 5 == 4
    )
    profiles = ProfileRepository(cluster, MODELS)
    profiles.register(dfg)
    res = Simulation(
        cluster, profiles, MODELS, scheduler="hash", seed=1
    ).run([Job(jid, dfg, 0.0)])
    assert len(res.records) == 1  # completed despite the infeasible GPU


def test_serving_cluster_prefetch_parity():
    """Virtual-clock parity: the serving engine stages intended models at
    plan time and publishes the intent bitmap."""
    import jax

    from repro.configs import ARCHS
    from repro.models import init_params
    from repro.serving import HostedModel, ServingCluster

    hosted = []
    for mid, arch in enumerate(["mistral-nemo-12b", "mamba2-780m"]):
        cfg = ARCHS[arch].reduced(dtype="float32")
        hosted.append(
            HostedModel(mid, cfg, init_params(cfg, jax.random.key(mid)))
        )
    dfg = DFG(
        "pp",
        tasks=[
            TaskSpec("a", 0.05, model_id=1, output_bytes=0.01 * MB,
                     input_bytes=0.01 * MB),
            TaskSpec("b", 0.1, model_id=0, output_bytes=0.01 * MB),
        ],
        edges=[("a", "b")],
    )
    sc = ServingCluster(
        ClusterSpec(n_workers=2, gpu_capacity_bytes=1 * GB),
        hosted,
        scheduler="navigator",
        decode_tokens=2,
        prefetch=PrefetchConfig(),
    )
    sc.register_pipeline(dfg)
    prompts = {"a": np.array([[3, 1, 4]], np.int32)}
    r1 = sc.submit(dfg, prompts, origin=0)
    assert set(r1.assignment) == {"a", "b"}
    assert r1.outputs["b"].shape[0] == 1
    # Intent bitmaps advertised: every worker's intent row is a superset
    # of its cache row.
    for w in range(2):
        row = sc.sst.view(None)[w]
        assert row.intent_bitmap & row.cache_bitmap == row.cache_bitmap
    # Second request hits the staged models.
    r2 = sc.submit(dfg, prompts, origin=1)
    assert r2.virtual_latency_s <= r1.virtual_latency_s
    assert sc.cache_hit_rate() > 0.0
    assert sc.prefetch_plane.stats.prefetches_completed >= 1


def test_gossip_plus_prefetch_sim_completes():
    cluster = ClusterSpec(n_workers=5)
    jobs = poisson_workload(paper_dfgs(), 1.5, 90.0, seed=5)
    res = _sim(
        cluster,
        prefetch=PrefetchConfig(),
        gossip=GossipConfig(period_s=0.2, fanout=2),
        seed=2,
    ).run(jobs)
    assert len(res.records) == len(jobs)
    assert res.prefetch_stats.intents_issued > 0


# --------------------------------------------------------------------------
# expected-completion (fetch-ETA) advertisements: the discount scales
# with the remaining transfer fraction (ROADMAP follow-up)
# --------------------------------------------------------------------------
def test_eta_discount_scales_with_remaining_fraction():
    """Eq. 2 with an in-flight fetch advertisement: only the part of the
    fetch outlasting the task's earliest start is still on the critical
    path — a nearly-done fetch is nearly free, a just-started one costs
    the full fetch."""
    profiles = make_profiles()
    sched = NavigatorScheduler(
        profiles, NavigatorConfig(intent_confidence=0.9)
    )
    task = TaskSpec("t", 0.4, model_id=2)
    fetch = profiles.td_model(2)
    intent = 1 << 2

    def cost(eta, start_hint=0.0):
        return sched._td_model(
            task, 0, 0, 16 * GB, intent, True, 2, eta, start_hint
        )

    nearly_done = cost(eta=0.05)
    halfway = cost(eta=fetch / 2)
    just_started = cost(eta=fetch)
    assert nearly_done == pytest.approx(0.05)
    assert nearly_done < halfway < just_started
    assert just_started == pytest.approx(fetch)
    # A fetch completing before the task could start costs nothing.
    assert cost(eta=1.0, start_hint=2.0) == 0.0
    # The ETA can never make the miss pricier than a cold fetch.
    assert cost(eta=1e9) == fetch


def test_eta_discount_beats_queued_confidence_when_nearly_done():
    """A queued (not yet in-flight) intent only earns the constant
    confidence discount; an in-flight fetch about to finish beats it."""
    profiles = make_profiles()
    conf = 0.5
    sched = NavigatorScheduler(
        profiles, NavigatorConfig(intent_confidence=conf)
    )
    task = TaskSpec("t", 0.4, model_id=2)
    fetch = profiles.td_model(2)
    intent = 1 << 2
    queued = sched._td_model(task, 0, 0, 16 * GB, intent, True, -1, 0.0, 0.0)
    assert queued == pytest.approx(fetch * (1.0 - conf))
    inflight = sched._td_model(
        task, 0, 0, 16 * GB, intent, True, 2, 0.01, 0.0
    )
    assert inflight < queued


def test_plan_places_on_nearly_done_fetcher():
    """Two workers both advertise an in-flight fetch of the needed model;
    the planner picks the one whose fetch is almost done."""
    profiles = make_profiles()
    dfg = DFG("one4", [TaskSpec("t", 0.4, model_id=2)], [])
    profiles.register(dfg)
    job = Job(0, dfg, 0.0)
    fetch = profiles.td_model(2)
    sst = _rows()
    for w, eta in ((3, 0.05), (4, fetch)):  # nearly done vs just started
        sst[w].intent_bitmap = 1 << 2
        sst[w].fetch_model_id = 2
        sst[w].fetch_eta_s = eta
    sched = NavigatorScheduler(
        profiles, NavigatorConfig(intent_confidence=0.9)
    )
    adfg = sched.plan(job, 0.0, 0, sst)
    assert adfg["t"] == 3


def test_sim_advertises_fetch_eta_while_pipe_busy():
    """The engine's cache publication carries the in-flight fetch id and
    its expected completion on the wire whenever the pipe is busy."""
    cluster = ClusterSpec(n_workers=3)
    profiles = make_profiles(cluster)
    jobs = poisson_workload(paper_dfgs(), 1.0, 20.0, seed=2)
    sim = Simulation(
        cluster, profiles, MODELS, scheduler="navigator",
        prefetch=PrefetchConfig(), seed=1,
    )
    seen = []
    orig = sim.sst.update_cache

    def spy(worker, bitmap, free, now=0.0, fetch_model_id=-1,
            fetch_eta_s=0.0):
        if fetch_model_id >= 0:
            seen.append((worker, fetch_model_id, fetch_eta_s, now))
        return orig(worker, bitmap, free, now, fetch_model_id, fetch_eta_s)

    sim.sst.update_cache = spy
    res = sim.run(jobs)
    assert len(res.records) == len(jobs)
    assert seen, "no fetch-ETA advertisement ever published"
    for _, mid, eta, now in seen:
        assert 0 <= mid < 64
        assert eta >= now  # expected completion lies in the future

"""MetricsRegistry.export() under churn and partition schedules
(chaos family 6 extension): the export stays schema-valid on every
plane, churn counters grow monotonically with the injected schedule,
per-ring trace drop counters mirror the recorder, and identical seeded
runs export byte-identical payloads."""

import json
import os

from chaos import (
    SCRIPTED_SCHEDULE,
    check_invariants,
    run_churn_sim,
    scripted_partition_schedule,
)
from repro.core import GossipConfig, validate_schema

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def load_schema(name):
    with open(os.path.join(REPO, "schemas", name)) as f:
        return json.load(f)


def _export(**kwargs):
    res, jobs, schedule = run_churn_sim(**kwargs)
    return res, jobs, schedule, res.metrics.export()


def test_export_schema_valid_under_churn_all_planes():
    schema = load_schema("metrics.schema.json")
    for plane_kwargs in (
        {},                                     # gossip plane (default)
        {"gossip": None},                       # shared-table plane
        {"gossip": GossipConfig(period_s=0.2, fanout=2), "health": True,
         "trace": True},                        # full observability stack
    ):
        res, jobs, schedule, exp = _export(
            duration=20.0, **plane_kwargs
        )
        validate_schema(exp, schema)
        check_invariants(res, jobs, schedule)
        assert int(res.metrics.value("sim.jobs_completed")) == len(
            res.records
        ) == len(jobs)


def test_export_schema_valid_under_partition():
    schema = load_schema("metrics.schema.json")
    res, jobs, schedule, exp = _export(
        schedule=scripted_partition_schedule(5), duration=20.0,
        health=True, trace=True,
    )
    validate_schema(exp, schema)
    assert int(res.metrics.value("churn.events", kind="partition")) >= 1
    assert int(res.metrics.value("churn.events", kind="heal")) >= 1


def test_churn_counters_monotone_in_schedule_prefix():
    """Running progressively longer prefixes of the scripted schedule
    must never decrease any churn.events counter — each injected event
    is either applied (counted once) or past the horizon."""
    prev = {}
    for cut in range(len(SCRIPTED_SCHEDULE) + 1):
        res, jobs, schedule, exp = _export(
            schedule=list(SCRIPTED_SCHEDULE[:cut]), duration=60.0
        )
        counts = {
            kind: int(res.metrics.value("churn.events", kind=kind))
            for kind in ("crash", "join", "drain")
        }
        for kind, c in counts.items():
            assert c >= prev.get(kind, 0), (
                f"churn.events[{kind}] shrank from {prev.get(kind)} to "
                f"{c} with a longer schedule prefix"
            )
        scheduled = [e.kind for e in SCRIPTED_SCHEDULE[:cut]]
        for kind, c in counts.items():
            assert c <= scheduled.count(kind)
        prev = counts
    # The full schedule applies completely on the 60 s horizon.
    assert prev == {"crash": 2, "join": 3, "drain": 1}


def test_export_deterministic_across_reruns():
    kwargs = dict(duration=20.0, health=True, trace=True)
    _res_a, _j, _s, a = _export(**kwargs)
    _res_b, _j, _s, b = _export(**kwargs)
    assert json.dumps(a, sort_keys=True) == json.dumps(b, sort_keys=True)


def test_trace_ring_counters_mirror_recorder_under_churn():
    res, jobs, schedule, exp = _export(duration=20.0, trace=True)
    stats = res.trace.ring_stats()
    for ring, (emitted, dropped) in stats.items():
        assert int(res.metrics.value("trace.emitted", ring=ring)) == emitted
        assert int(res.metrics.value("trace.dropped", ring=ring)) == dropped
    assert sum(d for _, d in stats.values()) == res.trace.dropped


def test_health_counters_in_export_under_churn():
    res, jobs, schedule, exp = _export(duration=30.0, health=True)
    rows = {
        (m["name"], m["labels"].get("kind")): m
        for m in exp["metrics"] if m["name"] == "health.events"
    }
    assert len(rows) == 4, "one health.events row per detector kind"
    for (_name, kind), m in rows.items():
        assert m["value"] == res.health.counts[kind] >= 0

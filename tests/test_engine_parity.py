"""Serving-vs-sim trace parity: both engines must speak the same event
taxonomy so one set of analysis tooling (SimReport, bench_trace
--explain, the chaos byte-diff oracle) reads either engine's stream.

Historically the serving engine emitted job lifecycle events on the
origin worker's ring (the simulator uses the global ring) and tagged
speculative prefetches with job/task keys the simulator omits; this
file pins the repaired contract: for an identical workload shape, every
event kind the serving engine emits exists in the simulator's stream
with an identical key set, and job lifecycle events live on the global
ring in both."""

import json

import jax
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.core import ClusterSpec, GB, ProfileRepository
from repro.core.types import DFG, Job, MB, TaskSpec
from repro.models import init_params
from repro.serving import HostedModel, ServingCluster
from repro.sim import Simulation
from repro.workflows import MODELS


def _pipeline_dfg():
    return DFG(
        "p",
        tasks=[
            TaskSpec("a", 0.05, model_id=1, output_bytes=0.01 * MB,
                     input_bytes=0.01 * MB),
            TaskSpec("b", 0.1, model_id=0, output_bytes=0.01 * MB),
        ],
        edges=[("a", "b")],
    )


def _taxonomy(jsonl):
    """kind -> (key set, worker ids seen) over a JSONL stream."""
    tax, workers = {}, {}
    for line in jsonl.splitlines():
        d = json.loads(line)
        tax.setdefault(d["kind"], set()).update(d.keys())
        workers.setdefault(d["kind"], set()).add(d["worker"])
    return tax, workers


@pytest.fixture(scope="module")
def serving_trace():
    hosted = []
    for mid, arch in enumerate(["mistral-nemo-12b", "mamba2-780m"]):
        cfg = ARCHS[arch].reduced(dtype="float32")
        hosted.append(
            HostedModel(mid, cfg, init_params(cfg, jax.random.key(mid)))
        )
    cluster = ClusterSpec(n_workers=2, gpu_capacity_bytes=1 * GB)
    sc = ServingCluster(cluster, hosted, scheduler="navigator",
                        decode_tokens=4, trace=True, health=True)
    dfg = _pipeline_dfg()
    sc.register_pipeline(dfg)
    prompt = np.array([[3, 4, 5]], np.int32)
    for origin in (0, 1, 0):
        sc.submit(dfg, {"a": prompt}, origin=origin)
    return sc


@pytest.fixture(scope="module")
def sim_trace():
    cluster = ClusterSpec(n_workers=2)
    dfg = _pipeline_dfg()
    profiles = ProfileRepository(cluster, MODELS)
    profiles.register(dfg)
    jobs = [Job(job_id=i, dfg=dfg, arrival_time=0.4 * i) for i in range(3)]
    return Simulation(
        cluster, profiles, MODELS, scheduler="navigator", seed=1,
        trace=True, health=True,
    ).run(jobs)


#: Health-detector events are emitted by the shared HealthMonitor._fire
#: path in both engines, so a tiny run of one engine may observe a
#: detector the other's run never trips — they're pinned against the
#: canonical shape instead of the other stream.
HEALTH_EVENT_KEYS = {"t", "kind", "seq", "worker", "value", "threshold",
                     "detail"}


def test_serving_taxonomy_subset_of_sim(serving_trace, sim_trace):
    from repro.core.healthplane import DETECTOR_KINDS

    srv_tax, _ = _taxonomy(serving_trace.recorder.to_jsonl())
    sim_tax, _ = _taxonomy(sim_trace.trace.to_jsonl())
    assert srv_tax, "serving engine emitted no events"
    for kind, keys in sorted(srv_tax.items()):
        if kind in DETECTOR_KINDS:
            assert keys == HEALTH_EVENT_KEYS, (
                f"{kind!r} keys {sorted(keys)} != canonical health shape"
            )
            continue
        assert kind in sim_tax, (
            f"serving emits {kind!r}, unknown to the simulator — "
            f"analysis tooling would not recognize it"
        )
        assert keys == sim_tax[kind], (
            f"{kind!r} key sets diverge: serving {sorted(keys)} vs "
            f"sim {sorted(sim_tax[kind])}"
        )
    for kind in set(sim_tax) & set(DETECTOR_KINDS):
        assert sim_tax[kind] == HEALTH_EVENT_KEYS


def test_core_lifecycle_kinds_present_in_both(serving_trace, sim_trace):
    core = {"job.arrive", "job.done", "sched.place", "task.start",
            "task.input", "task.done", "fetch.start", "fetch.done"}
    srv_tax, _ = _taxonomy(serving_trace.recorder.to_jsonl())
    sim_tax, _ = _taxonomy(sim_trace.trace.to_jsonl())
    assert core <= set(srv_tax), f"serving missing {core - set(srv_tax)}"
    assert core <= set(sim_tax), f"sim missing {core - set(sim_tax)}"


def test_job_lifecycle_on_global_ring(serving_trace, sim_trace):
    """job.arrive / job.done ride the cluster-global ring (worker -1) in
    both engines — the serving engine used to pin them to the origin
    worker's ring, splitting the streams' shapes."""
    _, srv_workers = _taxonomy(serving_trace.recorder.to_jsonl())
    _, sim_workers = _taxonomy(sim_trace.trace.to_jsonl())
    for kind in ("job.arrive", "job.done"):
        assert srv_workers[kind] == {-1}, (
            f"serving {kind} on rings {srv_workers[kind]}, expected global"
        )
        assert sim_workers[kind] == {-1}, (
            f"sim {kind} on rings {sim_workers[kind]}, expected global"
        )


def test_spans_build_from_serving_stream(serving_trace):
    """The simulator's span stitcher consumes the serving stream as-is:
    every completed task yields a span with start/done stitched."""
    from repro.core.telemetry import build_spans

    spans = build_spans(serving_trace.recorder.events())
    done = [s for s in spans.values() if s.t_done is not None]
    assert len(done) == 6  # 3 jobs x 2 tasks
    for s in done:
        assert s.t_start is not None and s.t_done >= s.t_start


def test_serving_health_taxonomy_matches_sim():
    """Health digests published by the serving engine land on the same
    SST lanes the simulator uses (wire parity, lanes 12-15)."""
    hosted = []
    for mid, arch in enumerate(["mistral-nemo-12b", "mamba2-780m"]):
        cfg = ARCHS[arch].reduced(dtype="float32")
        hosted.append(
            HostedModel(mid, cfg, init_params(cfg, jax.random.key(mid)))
        )
    cluster = ClusterSpec(n_workers=2, gpu_capacity_bytes=1 * GB)
    sc = ServingCluster(cluster, hosted, scheduler="navigator",
                        decode_tokens=4, health=True)
    dfg = _pipeline_dfg()
    sc.register_pipeline(dfg)
    prompt = np.array([[1, 2]], np.int32)
    for origin in (0, 1):
        sc.submit(dfg, {"a": prompt}, origin=origin)
    s = sc.health.summary()
    assert s["schema_version"] == 1
    assert s["fleet_job_latency"]["count"] == 2
    rows = sc.sst.view(None, 1e9)
    assert any(r.health_p99_latency_s > 0.0 for r in rows), (
        "serving engine never published a health digest to the SST"
    )

"""Unit tests for DFG/ADFG types, bitmaps, ranking and the profile repo."""

import pytest

from repro.core import (
    ClusterSpec,
    DFG,
    GB,
    MB,
    MLModel,
    ProfileRepository,
    TaskSpec,
)
from repro.core import bitmaps
from repro.workflows import MODELS, paper_dfgs, translation_dfg


def test_dfg_structure():
    dfg = translation_dfg()
    assert dfg.entry_tasks == ["opt_ingest"]
    assert dfg.exit_tasks == ["aggregate"]
    assert dfg.is_join("aggregate")
    assert not dfg.is_join("mt5_zh")
    assert set(dfg.model_ids()) == {0, 1, 2}


def test_dfg_cycle_detection():
    with pytest.raises(ValueError, match="cycle"):
        DFG(
            "bad",
            tasks=[TaskSpec("a", 1.0), TaskSpec("b", 1.0)],
            edges=[("a", "b"), ("b", "a")],
        )


def test_dfg_unknown_edge():
    with pytest.raises(ValueError, match="unknown task"):
        DFG("bad", tasks=[TaskSpec("a", 1.0)], edges=[("a", "zz")])


def test_lower_bound_is_critical_path():
    dfg = translation_dfg()
    t = dfg.tasks
    expected = (
        t["opt_ingest"].runtime_s
        + max(
            t["marian_fr"].runtime_s,
            t["mt5_zh"].runtime_s,
            t["mt5_ja"].runtime_s,
        )
        + t["aggregate"].runtime_s
    )
    assert dfg.lower_bound_latency() == pytest.approx(expected)


def test_model_id_space_bounds():
    with pytest.raises(ValueError):
        MLModel(model_id=64, name="too-big", size_bytes=1.0)
    with pytest.raises(ValueError):
        MLModel(model_id=-1, name="neg", size_bytes=1.0)


def test_bitmap_roundtrip():
    ids = [0, 3, 17, 63]
    bm = bitmaps.pack(ids)
    assert bitmaps.unpack(bm) == ids
    assert bitmaps.contains(bm, 17)
    assert not bitmaps.contains(bm, 18)
    bm2 = bitmaps.remove(bm, 3)
    assert bitmaps.unpack(bm2) == [0, 17, 63]
    assert bitmaps.popcount(bm) == 4


def test_ranks_decrease_along_edges():
    """Eq. 1: a task's rank strictly exceeds every successor's rank."""
    cluster = ClusterSpec()
    profiles = ProfileRepository(cluster, MODELS)
    for dfg in paper_dfgs():
        profiles.register(dfg)
        ranks = profiles.ranks(dfg)
        for u, v in dfg.edges:
            assert ranks[u] > ranks[v]


def test_rank_order_respects_dependencies():
    cluster = ClusterSpec()
    profiles = ProfileRepository(cluster, MODELS)
    for dfg in paper_dfgs():
        profiles.register(dfg)
        order = profiles.rank_order(dfg)
        pos = {t: i for i, t in enumerate(order)}
        for u, v in dfg.edges:
            assert pos[u] < pos[v]


def test_profile_rejects_unknown_model():
    cluster = ClusterSpec()
    profiles = ProfileRepository(cluster, {})
    with pytest.raises(KeyError):
        profiles.register(translation_dfg())


def test_heterogeneous_runtime():
    cluster = ClusterSpec(n_workers=2, worker_speed={0: 1.0, 1: 2.0})
    profiles = ProfileRepository(cluster, MODELS)
    task = TaskSpec("t", 1.0, model_id=0)
    assert profiles.runtime(task, 0) == pytest.approx(1.0)
    assert profiles.runtime(task, 1) == pytest.approx(0.5)
    assert profiles.mean_runtime(task) == pytest.approx(0.75)


def test_transfer_models():
    cluster = ClusterSpec()
    net = cluster.network
    assert net.transfer_time(0) == 0.0
    t1 = net.transfer_time(1 * MB)
    t2 = net.transfer_time(2 * MB)
    assert t2 > t1 > net.delta_s
    link = cluster.link
    assert link.fetch_time(4 * GB) == pytest.approx(
        4 * GB / link.bandwidth_bytes_per_s + link.delta_s
    )

"""Equivalence of the vectorized JAX planner with the reference Python
planner, plus SST-exchange round-trips."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from hypothesis_compat import given, settings, st

from repro.core import (
    ClusterSpec,
    GB,
    Job,
    NavigatorConfig,
    NavigatorScheduler,
    ProfileRepository,
    SharedStateTable,
)
from repro.core.jax_planner import JaxNavigatorPlanner
from repro.core.sst_exchange import ROW_WIDTH, pack_row, unpack_rows
from repro.core.state import SSTRow
from repro.workflows import MODELS, paper_dfgs


def setup(n_workers=5):
    cluster = ClusterSpec(n_workers=n_workers)
    profiles = ProfileRepository(cluster, MODELS)
    for d in paper_dfgs():
        profiles.register(d)
    return cluster, profiles


@settings(max_examples=25, deadline=None)
@given(
    dfg_idx=st.integers(0, 3),
    seed=st.integers(0, 1000),
    origin=st.integers(0, 4),
)
def test_jax_planner_matches_python(dfg_idx, seed, origin):
    """Same ADFG (or equal-cost alternative) from both planners under
    random worker states."""
    cluster, profiles = setup()
    # Fixed eviction penalty so both implementations share Eq.2 exactly.
    cfg = NavigatorConfig(eviction_penalty_s=1.5)
    py = NavigatorScheduler(profiles, cfg)
    vec = JaxNavigatorPlanner(profiles, cfg)
    rng = np.random.RandomState(seed)
    sst = []
    for w in range(5):
        bitmap = 0
        for m in range(8):
            if rng.rand() < 0.4:
                bitmap |= 1 << m
        sst.append(
            SSTRow(
                ft_estimate_s=float(rng.uniform(0, 5)),
                cache_bitmap=bitmap,
                free_cache_bytes=float(rng.uniform(0, 16 * GB)),
            )
        )
    dfg = paper_dfgs()[dfg_idx]
    job = Job(0, dfg, arrival_time=1.0)
    a_py = py.plan(job, 1.0, origin, sst)
    a_vec = vec.plan(job, 1.0, origin, sst)
    # Planned finish times must agree (assignments may differ only on
    # exact ties, which the shared deterministic argmin also breaks the
    # same way — assert full equality).
    for t in dfg.tasks:
        assert a_py[t] == a_vec[t], (t, a_py.assignment, a_vec.assignment)
        assert a_py.planned_ft[t] == pytest.approx(
            a_vec.planned_ft[t], rel=1e-5
        )


def test_jax_planner_scales_to_many_workers():
    cluster, profiles = setup(n_workers=250)
    vec = JaxNavigatorPlanner(profiles, NavigatorConfig(eviction_penalty_s=1.0))
    sst = [
        SSTRow(ft_estimate_s=0.0, cache_bitmap=0, free_cache_bytes=16 * GB)
        for _ in range(250)
    ]
    job = Job(0, paper_dfgs()[0], arrival_time=0.0)
    adfg = vec.plan(job, 0.0, 0, sst)
    assert set(adfg.assignment) == set(job.dfg.tasks)
    assert all(0 <= w < 250 for _, w in adfg.items())


def test_sst_row_roundtrip():
    row = SSTRow(
        ft_estimate_s=3.25,
        cache_bitmap=(1 << 63) | (1 << 31) | 5,
        free_cache_bytes=11.5 * GB,
    )
    packed = pack_row(row, queue_len=7)
    assert packed.shape == (ROW_WIDTH,)
    assert packed.nbytes == 64  # exactly one 64-byte cache line (Fig. 5)
    back = unpack_rows(packed[None])[0]
    assert back.ft_estimate_s == pytest.approx(row.ft_estimate_s)
    assert back.cache_bitmap == row.cache_bitmap
    assert back.free_cache_bytes == pytest.approx(row.free_cache_bytes, rel=1e-6)
    assert back.fetch_model_id == -1 and back.fetch_eta_s == 0.0


def test_sst_allgather_replicates_rows():
    from repro.core.sst_exchange import make_sst_allgather
    from repro.launch.mesh import make_debug_mesh

    mesh = make_debug_mesh(1)
    exchange = make_sst_allgather(mesh, axis="data")
    rows = np.stack(
        [pack_row(SSTRow(ft_estimate_s=float(i), cache_bitmap=i)) for i in
         range(1)]
    )
    table = exchange(jnp.asarray(rows))
    got = unpack_rows(np.asarray(table))
    assert got[0].ft_estimate_s == 0.0

"""§Perf optimization paths must be drop-in equivalent to the baselines:
EP MoE dispatch, grouped-GQA decode, chunked attention, one-hot cache
writes (all are selectable flags; defaults stay paper-faithful)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.launch.mesh import make_debug_mesh
from repro.models import (
    ModelConfig,
    decode_step,
    forward,
    init_cache,
    init_params,
)


def moe_cfg(**kw):
    base = dict(
        name="moe-t", arch_type="moe", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=0, vocab=97, n_experts=8, top_k=2,
        d_ff_expert=32, n_shared_experts=1, dtype="float32",
    )
    base.update(kw)
    return ModelConfig(**base)


def test_ep_dispatch_matches_sorted_forward():
    cfg = moe_cfg()
    params = init_params(cfg, jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 24), 0, cfg.vocab)
    mesh = make_debug_mesh()
    l_sorted, a_sorted = forward(params, {"tokens": toks}, cfg,
                                 moe_dispatch="sorted")
    l_ep, a_ep = forward(params, {"tokens": toks}, cfg,
                         moe_dispatch="ep", mesh=mesh)
    np.testing.assert_allclose(l_sorted, l_ep, atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(float(a_sorted), float(a_ep), rtol=1e-5)


def test_ep_dispatch_grads_flow():
    cfg = moe_cfg()
    params = init_params(cfg, jax.random.key(0))
    toks = jax.random.randint(jax.random.key(2), (2, 16), 0, cfg.vocab)
    mesh = make_debug_mesh()
    from repro.models import next_token_loss

    g = jax.grad(
        lambda p: next_token_loss(
            p, {"tokens": toks}, cfg, moe_dispatch="ep", mesh=mesh
        )
    )(params)
    norms = [float(jnp.max(jnp.abs(x))) for x in jax.tree.leaves(g)]
    assert max(norms) > 0
    assert all(np.isfinite(n) for n in norms)
    # expert weights receive gradient
    assert float(jnp.max(jnp.abs(g["layers"]["moe"]["wg"]))) > 0


def test_ep_mla_deepseek_style():
    cfg = moe_cfg(
        use_mla=True, n_kv_heads=4, kv_lora_rank=16, q_lora_rank=16,
        rope_head_dim=8,
    )
    params = init_params(cfg, jax.random.key(0))
    toks = jax.random.randint(jax.random.key(3), (1, 12), 0, cfg.vocab)
    mesh = make_debug_mesh()
    l1, _ = forward(params, {"tokens": toks}, cfg, moe_dispatch="sorted")
    l2, _ = forward(params, {"tokens": toks}, cfg, moe_dispatch="ep",
                    mesh=mesh)
    np.testing.assert_allclose(l1, l2, atol=2e-5, rtol=2e-5)


def test_grouped_decode_matches_ref():
    b, h, kh, d, t = 3, 8, 2, 32, 200
    q = jax.random.normal(jax.random.key(4), (b, h, d))
    kc = jax.random.normal(jax.random.key(5), (b, t, kh, d))
    vc = jax.random.normal(jax.random.key(6), (b, t, kh, d))
    lens = jnp.array([50, 200, 1], jnp.int32)
    o1 = ref.decode_attention_ref(q, kc, vc, lens)
    o2 = ref.decode_attention_grouped_ref(q, kc, vc, lens)
    np.testing.assert_allclose(o1, o2, atol=1e-5, rtol=1e-5)


def test_grouped_decode_model_path():
    cfg = ModelConfig("d", "dense", 2, 64, 4, 2, 128, 97, dtype="float32")
    params = init_params(cfg, jax.random.key(0))
    toks = jax.random.randint(jax.random.key(7), (2, 10), 0, 97)
    c1, c2 = init_cache(cfg, 2, 16), init_cache(cfg, 2, 16)
    for i in range(10):
        l1, c1 = decode_step(params, c1, toks[:, i], cfg, impl="ref")
        l2, c2 = decode_step(
            params, c2, toks[:, i], cfg, impl="ref_grouped",
            cache_update="onehot",
        )
        np.testing.assert_allclose(l1, l2, atol=1e-5, rtol=1e-5)


def test_chunked_attention_in_training_path():
    cfg = ModelConfig("d", "dense", 2, 64, 4, 2, 128, 97, dtype="float32")
    params = init_params(cfg, jax.random.key(0))
    toks = jax.random.randint(jax.random.key(8), (2, 40), 0, 97)
    from repro.models import next_token_loss

    l_ref = next_token_loss(params, {"tokens": toks}, cfg, impl="ref")
    l_chk = next_token_loss(params, {"tokens": toks}, cfg, impl="ref_chunked")
    np.testing.assert_allclose(float(l_ref), float(l_chk), rtol=1e-5)
    g = jax.grad(
        lambda p: next_token_loss(p, {"tokens": toks}, cfg, impl="ref_chunked")
    )(params)
    assert all(np.isfinite(float(jnp.max(jnp.abs(x))))
               for x in jax.tree.leaves(g))


def test_serve_layout_pspecs_put_tp_on_contraction():
    from repro.configs import ARCHS
    from repro.models import abstract_params
    from repro.models.sharding import param_pspecs

    mesh = make_debug_mesh()
    cfg = ARCHS["mistral-nemo-12b"].reduced()
    params = abstract_params(cfg)
    train = param_pspecs(mesh, params, cfg, serve=False)
    serve = param_pspecs(mesh, params, cfg, serve=True)
    # column-parallel weights flip their TP dim under the serve layout
    assert train["layers"]["wq"] != serve["layers"]["wq"] or (
        train["layers"]["wq"] == serve["layers"]["wq"]
    )  # structural smoke: both are valid spec trees of equal structure
    assert jax.tree.structure(
        train, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec)
    ) == jax.tree.structure(
        serve, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec)
    )

"""Worker churn & fault tolerance: chaos invariants across schedulers and
fleets, determinism regression, membership-aware planning, and the
crash/drain/join recovery paths (PR 3 tentpole)."""

import pytest

from chaos import (
    SCRIPTED_SCHEDULE,
    check_invariants,
    run_churn_sim,
)
from repro.core import (
    ALIVE,
    ClusterSpec,
    DEAD,
    fleet,
    GB,
    GossipConfig,
    Job,
    LeaseConfig,
    NavigatorConfig,
    NavigatorScheduler,
    PrefetchConfig,
    PrefetchPlane,
    ProfileRepository,
    SharedStateTable,
    SSTRow,
    SUSPECT,
)
from repro.core import bitmaps
from repro.core.memory import GpuMemoryManager
from repro.core.netmodel import AcceleratorLink
from repro.core.prefetch import PrefetchIntent
from repro.sim import (
    ChurnEvent,
    Simulation,
    churn_schedule,
    poisson_workload,
    validate_schedule,
)
from repro.workflows import MODELS, paper_dfgs, translation_dfg


# --------------------------------------------------------------------------
# Chaos invariants: every policy × fleet under the scripted
# crash+join+drain schedule (acceptance criterion)
# --------------------------------------------------------------------------
@pytest.mark.parametrize("policy", ["navigator", "hash", "heft", "jit"])
@pytest.mark.parametrize("fleet_name", ["uniform", "mixed"])
def test_invariants_scripted_schedule(policy, fleet_name):
    res, jobs, schedule = run_churn_sim(
        scheduler=policy, fleet_name=fleet_name, duration=60.0
    )
    check_invariants(res, jobs, schedule)
    assert res.churn_crashes >= 1 and res.churn_drains >= 1
    assert res.churn_joins >= 1


@pytest.mark.parametrize("policy", ["navigator", "hash", "heft"])
def test_invariants_generated_schedule_heterogeneous(policy):
    """MTBF-generated churn on the heterogeneous fleet, prefetch plane on
    (the configuration with the most cross-layer interaction)."""
    schedule = churn_schedule(
        5, 60.0, mtbf_s=60.0, repair_s=10.0, seed=7, drain_fraction=0.3
    )
    res, jobs, schedule = run_churn_sim(
        scheduler=policy,
        fleet_name="mixed",
        schedule=schedule,
        duration=60.0,
        prefetch=PrefetchConfig(),
    )
    check_invariants(res, jobs, schedule)
    assert res.tasks_rescued > 0


def test_invariants_shared_state_table_plane():
    """Churn must also be safe on the centralized-snapshot metadata plane
    (lease ages derive from publication lag instead of gossip lag)."""
    res, jobs, schedule = run_churn_sim(
        scheduler="navigator", gossip=None, duration=60.0
    )
    check_invariants(res, jobs, schedule)
    assert res.churn_crashes >= 1


def test_navigator_rescues_fewer_tasks_than_blind_hash():
    """Membership-aware placement routes around workers its view marks
    DEAD; hash keeps throwing tasks at corpses, every one of which needs
    a dead-letter rescue."""
    schedule = churn_schedule(
        5, 60.0, mtbf_s=50.0, repair_s=12.0, seed=5, drain_fraction=0.0
    )
    nav, jobs, schedule = run_churn_sim(
        scheduler="navigator", schedule=schedule, duration=60.0
    )
    hsh, _, _ = run_churn_sim(
        scheduler="hash", schedule=schedule, duration=60.0
    )
    assert nav.tasks_rescued < hsh.tasks_rescued


# --------------------------------------------------------------------------
# Determinism regression (guards PR 2/3 event-loop additions)
# --------------------------------------------------------------------------
@pytest.mark.parametrize("gossip", [GossipConfig(period_s=0.2, fanout=2), None])
def test_churn_trace_is_deterministic(gossip):
    """The same seeded churn trace run twice yields identical event logs
    and final metrics — any nondeterministic dict/set iteration in the
    recovery paths would diverge here."""
    kw = dict(
        scheduler="navigator",
        fleet_name="mixed",
        gossip=gossip,
        duration=45.0,
        prefetch=PrefetchConfig(),
        record_events=True,
    )
    a, jobs_a, _ = run_churn_sim(**kw)
    b, jobs_b, _ = run_churn_sim(**kw)
    assert a.event_log == b.event_log  # same events, same order, same times
    assert a.mean_latency == b.mean_latency
    assert a.cache_hits == b.cache_hits
    assert a.tasks_rescued == b.tasks_rescued
    assert a.outputs_recovered == b.outputs_recovered
    assert a.task_completions == b.task_completions
    assert a.churn_wasted_bytes == b.churn_wasted_bytes


# --------------------------------------------------------------------------
# Engine recovery semantics
# --------------------------------------------------------------------------
def tiny_sim(n_workers=2, scheduler="navigator", churn=(), **kw):
    cluster = ClusterSpec(n_workers=n_workers)
    profiles = ProfileRepository(cluster, MODELS)
    for d in paper_dfgs():
        profiles.register(d)
    return Simulation(
        cluster,
        profiles,
        MODELS,
        scheduler=scheduler,
        gossip=GossipConfig(period_s=0.1, fanout=n_workers - 1),
        lease=LeaseConfig(),
        churn=list(churn),
        runtime_noise_sigma=0.0,
        seed=0,
        **kw,
    )


def test_no_ghost_completion_inside_detection_window():
    """A task whose runtime ends after the crash but before detection
    must NOT complete on the corpse: the crash voids the attempt
    immediately even though re-placement waits for the lease."""
    from repro.core import DFG, TaskSpec

    dfg = DFG(
        "one", tasks=[TaskSpec("t", 0.5, output_bytes=1e4)], edges=[]
    )
    cluster = ClusterSpec(n_workers=2)
    profiles = ProfileRepository(cluster, MODELS)
    profiles.register(dfg)
    job = Job(0, dfg, arrival_time=0.0)
    lease = LeaseConfig()
    crash_t = 0.2
    sim = Simulation(
        cluster, profiles, MODELS, scheduler="navigator",
        gossip=GossipConfig(period_s=0.1, fanout=1),
        lease=lease,
        churn=[
            ChurnEvent(time=crash_t, kind="crash", worker=0),
            ChurnEvent(time=10.0, kind="join", worker=0),
        ],
        runtime_noise_sigma=0.0, seed=0,
    )
    res = sim.run([job])
    assert len(res.records) == 1
    # Origin is worker 0 and the cluster is idle, so the task ran there;
    # the ghost completion would have landed at t = 0.5 < detection.
    # With the fix the job only finishes after detection-time recovery.
    assert res.records[0].finish > crash_t + lease.detection_delay_s
    assert res.tasks_rescued >= 1
    check_invariants(res, [job], sim.churn)


def test_crash_drops_running_task_and_reexecutes():
    """A worker crash mid-execution voids the attempt; the task re-runs
    elsewhere and the job still completes (completions ledger shows the
    retry)."""
    job = Job(0, translation_dfg(), arrival_time=0.0)
    # Crash both the origin and its neighbour early: whichever worker got
    # the entry task loses it.
    sim = tiny_sim(
        n_workers=3,
        churn=[
            ChurnEvent(time=0.3, kind="crash", worker=0),
            ChurnEvent(time=5.0, kind="join", worker=0),
        ],
    )
    res = sim.run([job])
    assert len(res.records) == 1
    check_invariants(res, [job], sim.churn)
    assert res.tasks_rescued + res.outputs_recovered >= 1


def test_drain_finishes_running_task_then_departs():
    job = Job(0, translation_dfg(), arrival_time=0.0)
    sim = tiny_sim(
        n_workers=3,
        churn=[ChurnEvent(time=0.3, kind="drain", worker=0)],
    )
    res = sim.run([job])
    assert len(res.records) == 1
    assert res.churn_drains == 1
    # A drain never voids a completion: nothing was double-completed.
    check_invariants(res, [job], sim.churn)


def test_join_triggers_anti_entropy_full_sync_in_sim():
    schedule = [
        ChurnEvent(time=5.0, kind="crash", worker=1),
        ChurnEvent(time=12.0, kind="join", worker=1),
    ]
    jobs = poisson_workload(paper_dfgs(), 1.0, 30.0, seed=2)
    sim = tiny_sim(n_workers=4, churn=schedule)
    res = sim.run(jobs)
    assert len(res.records) == len(jobs)
    assert sim.sst.full_syncs > 0  # the joiner was rebuilt by full sync


def test_rejoined_worker_executes_new_work():
    """After rejoin the worker is schedulable again (fresh epoch row
    disseminates and the planner sees it ALIVE)."""
    schedule = [
        ChurnEvent(time=2.0, kind="crash", worker=1),
        ChurnEvent(time=6.0, kind="join", worker=1),
    ]
    jobs = poisson_workload(paper_dfgs(), 2.0, 40.0, seed=4)
    sim = tiny_sim(n_workers=2, churn=schedule)
    res = sim.run(jobs)
    assert len(res.records) == len(jobs)
    post_join = [
        r for r in res.records if r.arrival > 10.0
    ]
    assert post_join  # workload extends past the rejoin
    assert 1 in res.workers_used


def test_churn_wasted_bytes_accounted_under_prefetch():
    schedule = churn_schedule(
        5, 60.0, mtbf_s=40.0, repair_s=8.0, seed=3, drain_fraction=0.2
    )
    res, jobs, schedule = run_churn_sim(
        scheduler="navigator",
        schedule=schedule,
        duration=60.0,
        prefetch=PrefetchConfig(),
    )
    check_invariants(res, jobs, schedule)
    # Crashes wiped caches: the lost residency shows up in the ledger.
    assert res.churn_wasted_bytes > 0.0


def test_orphaned_intents_are_rehomed():
    schedule = churn_schedule(
        5, 60.0, mtbf_s=30.0, repair_s=8.0, seed=3, drain_fraction=0.0
    )
    res, jobs, schedule = run_churn_sim(
        scheduler="navigator",
        schedule=schedule,
        duration=60.0,
        prefetch=PrefetchConfig(lookahead_depth=8),
    )
    check_invariants(res, jobs, schedule)
    assert res.prefetch_stats is not None
    assert res.prefetch_stats.intents_orphaned > 0
    # Orphans whose tasks were re-routed were re-issued on the heirs.
    assert res.prefetch_stats.intents_rehomed > 0


def test_permanent_capability_loss_raises_instead_of_hanging():
    """If the only worker able to host a model leaves forever, the sim
    must fail loudly, not spin retry events for eternity."""
    from repro.core import fleet as make_fleet
    from repro.core import GB, ProfileRepository as PR

    cluster = make_fleet("edge_heavy")  # big models fit only worker 0
    profiles = PR(cluster, MODELS)
    for d in paper_dfgs():
        profiles.register(d)
    jobs = poisson_workload(paper_dfgs(), 1.0, 20.0, seed=2)
    sim = Simulation(
        cluster, profiles, MODELS, scheduler="navigator",
        gossip=GossipConfig(period_s=0.2, fanout=2), lease=LeaseConfig(),
        churn=[ChurnEvent(time=3.0, kind="crash", worker=0)],  # no rejoin
        seed=1,
    )
    with pytest.raises(ValueError, match="future fleet member"):
        sim.run(jobs)


def test_join_during_drain_cancels_the_drain():
    """A join landing while a drain is still finishing its running task
    un-drains the worker instead of being silently dropped (a drop would
    remove the worker from the fleet forever)."""
    jobs = poisson_workload(paper_dfgs(), 2.0, 30.0, seed=4)
    sim = tiny_sim(
        n_workers=2,
        churn=[
            # Drain under load: worker 0 almost certainly has a running
            # task at t=5, so the drain lingers; the join lands mid-drain.
            ChurnEvent(time=5.0, kind="drain", worker=0),
            ChurnEvent(time=5.05, kind="join", worker=0),
        ],
    )
    res = sim.run(jobs)
    assert len(res.records) == len(jobs)
    assert sim._up[0] and not sim._draining[0]  # still a fleet member


def test_drain_flushes_outputs_no_pointless_reexecution():
    """Graceful drain waited for its running task; the output it produced
    must survive the departure (flushed to an heir), so JIT's deferred
    input shipping does not force a re-execution of work a drain
    deliberately completed."""
    job = Job(0, translation_dfg(), arrival_time=0.0)
    sim = tiny_sim(
        n_workers=3,
        scheduler="jit",
        churn=[ChurnEvent(time=0.3, kind="drain", worker=0)],
    )
    res = sim.run([job])
    assert len(res.records) == 1
    check_invariants(res, [job], sim.churn)
    assert res.outputs_recovered == 0  # nothing thrown away


def test_capacity_bounces_counted_on_heterogeneous_fleet():
    res, jobs, schedule = run_churn_sim(
        scheduler="hash", fleet_name="mixed", duration=60.0
    )
    check_invariants(res, jobs, schedule)
    assert res.bounces > 0  # 8 GB edge GPU rejects the big models


# --------------------------------------------------------------------------
# Membership-aware planning (unit level)
# --------------------------------------------------------------------------
@pytest.fixture
def profiles():
    cluster = ClusterSpec(n_workers=3)
    p = ProfileRepository(cluster, MODELS)
    for d in paper_dfgs():
        p.register(d)
    return p


def rows(n=3):
    return [SSTRow(free_cache_bytes=16 * GB) for _ in range(n)]


def test_navigator_plan_excludes_dead_workers(profiles):
    sched = NavigatorScheduler(profiles)
    job = Job(0, translation_dfg(), arrival_time=0.0)
    sst = rows(3)
    sst[1].liveness = DEAD
    adfg = sched.plan(job, 0.0, 0, sst)
    assert all(w != 1 for _, w in adfg.items())


def test_navigator_plan_penalizes_suspect_workers(profiles):
    """A SUSPECT worker with an attractive cache loses to a clean ALIVE
    worker once the penalty exceeds the refetch saving."""
    job = Job(0, translation_dfg(), arrival_time=0.0)
    mids = [
        t.model_id for t in job.dfg.tasks.values() if t.model_id is not None
    ]
    sst = rows(3)
    sst[1].cache_bitmap = bitmaps.pack(mids)  # cache-attractive...
    sst[1].liveness = SUSPECT                 # ...but lease is shaky
    eager = NavigatorScheduler(
        profiles, NavigatorConfig(suspect_penalty_s=0.0)
    )
    wary = NavigatorScheduler(
        profiles, NavigatorConfig(suspect_penalty_s=1e6)
    )
    plan_eager = eager.plan(job, 0.0, 0, sst)
    plan_wary = wary.plan(job, 0.0, 0, sst)
    assert any(w == 1 for _, w in plan_eager.items())
    assert all(w != 1 for _, w in plan_wary.items())


def test_adjust_never_moves_to_dead_worker(profiles):
    from repro.core import ADFG

    job = Job(0, translation_dfg(), arrival_time=0.0)
    tid = next(iter(job.dfg.tasks))
    succs = job.dfg.succs[tid]
    target = succs[0] if succs else tid
    sst = rows(3)
    sst[0].ft_estimate_s = 100.0  # planned worker is swamped...
    sst[1].liveness = DEAD        # ...the idle-looking alternative is dead
    sst[1].ft_estimate_s = 0.0
    adfg = ADFG(job)
    for t in job.dfg.tasks:
        adfg[t] = 0
        adfg.planned_ft[t] = 0.0
    sched = NavigatorScheduler(profiles)
    new_w = sched.adjust(job, adfg, target, 50.0, sst, 2, 1e5)
    assert new_w != 1


def test_jax_planner_matches_python_under_liveness(profiles):
    jax_planner = pytest.importorskip("repro.core.jax_planner")
    job = Job(0, translation_dfg(), arrival_time=0.0)
    cfg = NavigatorConfig(eviction_penalty_s=1.5, suspect_penalty_s=3.0)
    sst = rows(3)
    sst[1].liveness = DEAD
    sst[2].liveness = SUSPECT
    py = NavigatorScheduler(profiles, cfg).plan(job, 0.0, 0, sst)
    vec = jax_planner.JaxNavigatorPlanner(profiles, cfg).plan(
        job, 0.0, 0, sst
    )
    for t in job.dfg.tasks:
        assert py[t] == vec[t]
        assert py.planned_ft[t] == pytest.approx(
            vec.planned_ft[t], rel=1e-5
        )
    assert all(w != 1 for _, w in vec.items())


def test_jit_skips_dead_workers(profiles):
    from repro.core import JITScheduler

    sched = JITScheduler(profiles)
    job = Job(0, translation_dfg(), arrival_time=0.0)
    tid = next(iter(job.dfg.tasks))
    sst = rows(3)
    sst[0].liveness = DEAD
    sst[1].liveness = DEAD
    w = sched.select_worker_at_ready(
        job, tid, 0.0, sst, {"": 2}, {"": 1e5}, self_worker=2
    )
    assert w == 2


# --------------------------------------------------------------------------
# SharedStateTable membership lane
# --------------------------------------------------------------------------
def test_shared_state_table_lease_classification():
    sst = SharedStateTable(3, lease=LeaseConfig(
        suspect_after_s=1.0, dead_after_s=3.0
    ))
    for w in range(3):
        sst.heartbeat(w, 0.0)
        sst.push(w, 0.0)
    sst.heartbeat(0, 10.0)
    sst.push(0, 10.0)
    view = sst.view(0, now=10.0)
    assert view[0].liveness == ALIVE  # self, fresh
    assert view[1].liveness == DEAD   # heartbeat 10 s stale
    sst.heartbeat(1, 9.5)
    sst.push(1, 9.5)
    assert sst.view(0, now=10.0)[1].liveness == ALIVE
    assert sst.view(0, now=11.5)[1].liveness == SUSPECT


def test_shared_state_table_join_bumps_epoch():
    sst = SharedStateTable(2, lease=LeaseConfig())
    sst.update_load(1, 5.0, now=1.0)
    sst.push(1, 1.0)
    old_epoch = sst.view(0)[1].epoch
    sst.join(1, now=2.0)
    assert sst.local[1].epoch == old_epoch + 1
    assert sst.local[1].ft_estimate_s == 0.0  # fresh row


# --------------------------------------------------------------------------
# Memory-manager churn paths (unit level)
# --------------------------------------------------------------------------
def make_mem(capacity=16 * GB):
    return GpuMemoryManager(capacity, MODELS, AcceleratorLink())


def test_abort_fetch_releases_pin_and_accounts_partial_bytes():
    mem = make_mem()
    res = mem.ensure(0)
    assert res is not None
    mem.pin(0)  # the engine's fetch-pin
    size = mem.cached_size(0)
    fetched_before = mem.stats.bytes_fetched
    mem.abort_fetch(0, fraction_done=0.5)
    assert not mem.has(0)
    assert 0 not in mem._pinned
    assert mem.stats.churn_wasted_bytes == pytest.approx(0.5 * size)
    # Un-transferred remainder never hit the pipe.
    assert mem.stats.bytes_fetched == pytest.approx(
        fetched_before - 0.5 * size
    )


def test_reset_counts_unused_prefetch_as_wasted():
    mem = make_mem()
    assert mem.begin_prefetch(0) is not None
    mem.complete_prefetch(0)
    assert mem.ensure(1) is not None  # demanded, not speculative
    wasted_before = mem.stats.prefetch_wasted
    lost = mem.reset(graceful=False)
    assert lost > 0
    assert mem.stats.prefetch_wasted == wasted_before + 1
    assert mem.stats.churn_wasted_bytes >= mem.cached_size(0)
    assert mem.resident_models() == [] and mem.free_bytes == mem.capacity_bytes
    assert mem.stats.churn_resets == 1


def test_graceful_reset_charges_only_unused_speculation():
    mem = make_mem()
    assert mem.ensure(1) is not None
    assert mem.begin_prefetch(0) is not None
    mem.complete_prefetch(0)
    mem.reset(graceful=True)
    # Only the unused speculative model is churn waste, not model 1.
    assert mem.stats.churn_wasted_bytes == pytest.approx(mem.cached_size(0))


# --------------------------------------------------------------------------
# Prefetch-plane churn paths (unit level)
# --------------------------------------------------------------------------
def test_drop_worker_orphans_queue_and_inflight():
    plane = PrefetchPlane(2)
    intents = [
        PrefetchIntent(0, "a", 0, 0, issued_at=0.0, expected_start_s=2.0),
        PrefetchIntent(0, "b", 1, 0, issued_at=0.0, expected_start_s=1.0),
    ]
    plane.admit(0, intents, 0.0)
    chosen, _ = plane.next_intent(0, 0.0, lambda mid: False)
    assert chosen is not None  # "b" (earliest) went in-flight
    orphans = plane.drop_worker(0)
    assert {i.task_id for i in orphans} == {"a", "b"}
    assert plane.queue_depth(0) == 0 and plane.inflight[0] is None
    assert plane.stats.intents_orphaned == 2


def test_rehome_restarts_ttl_and_counts():
    plane = PrefetchPlane(2)
    orphan = PrefetchIntent(0, "a", 0, 0, issued_at=0.0, expected_start_s=1.0)
    heir = plane.rehome(orphan, worker=1, now=50.0)
    assert heir.worker == 1 and heir.issued_at == 50.0
    assert heir.expected_start_s == 50.0  # never in the past
    assert plane.stats.intents_rehomed == 1


# --------------------------------------------------------------------------
# Schedule generator
# --------------------------------------------------------------------------
def test_churn_schedule_deterministic_and_valid():
    a = churn_schedule(5, 300.0, mtbf_s=120.0, seed=42)
    b = churn_schedule(5, 300.0, mtbf_s=120.0, seed=42)
    assert a == b
    assert a  # MTBF 120 s over 5 workers × 300 s produces events
    validate_schedule(a, 5)


def test_validate_schedule_rejects_bad_sequences():
    with pytest.raises(ValueError):
        validate_schedule(
            [ChurnEvent(1.0, "crash", 7)], n_workers=5
        )
    with pytest.raises(ValueError):
        validate_schedule(
            [
                ChurnEvent(1.0, "crash", 0),
                ChurnEvent(2.0, "crash", 0),  # already down
            ],
            n_workers=5,
        )
    with pytest.raises(ValueError):
        validate_schedule(
            [ChurnEvent(1.0, "join", 0)], n_workers=5  # join of live worker
        )
    with pytest.raises(ValueError):
        ChurnEvent(1.0, "explode", 0)


# --------------------------------------------------------------------------
# Recovery targeting: full Navigator cost instead of the dispatcher's
# greedy earliest-start rule (ROADMAP follow-up regression)
# --------------------------------------------------------------------------
def _warm_rows(n, ft=None):
    out = [SSTRow(cache_bitmap=0xFF, free_cache_bytes=16 * GB)
           for _ in range(n)]
    for w, f in (ft or {}).items():
        out[w].ft_estimate_s = f
    return out


def test_recovery_baselines_have_no_opinion():
    """Non-Navigator schedulers defer to the dispatcher's greedy rule."""
    from repro.core import make_scheduler

    cluster = fleet("mixed")
    p = ProfileRepository(cluster, MODELS)
    for d in paper_dfgs():
        p.register(d)
    job = Job(0, translation_dfg(), arrival_time=0.0)
    for name in ("hash", "heft", "jit"):
        sched = make_scheduler(name, p)
        assert sched.select_recovery_worker(
            job, "opt_ingest", 0.0, _warm_rows(5), {}, {}, [0, 1]
        ) is None


def test_recovery_full_cost_beats_greedy_on_worker_speed():
    """Greedy earliest-start picks the idle EDGE worker; the full cost
    weighs R(t, w) and pays a short queue wait on the A10 instead."""
    cluster = fleet("mixed")  # (A10 2.0x, L4 1.6x, T4, T4, EDGE 0.5x)
    p = ProfileRepository(cluster, MODELS)
    for d in paper_dfgs():
        p.register(d)
    sched = NavigatorScheduler(p)
    job = Job(0, translation_dfg(), arrival_time=0.0)
    rows = _warm_rows(5, ft={0: 0.5, 1: 5.0, 2: 5.0, 3: 5.0, 4: 0.0})
    cands = [0, 4]
    # The dispatcher's greedy rule: earliest start, model-fetch aware only.
    greedy = min(cands, key=lambda w: (rows[w].ft_estimate_s, w))
    assert greedy == 4
    choice = sched.select_recovery_worker(
        job, "opt_ingest", 0.0, rows, {}, {}, cands
    )
    # 0.5 s wait + 0.80/2.0 run on the A10 beats 0 + 0.80/0.5 on EDGE.
    assert choice == 0


def test_recovery_full_cost_ships_to_input_holder_across_racks():
    """With a large surviving input parked in rack 1, the full cost's
    path-aware re-staging term keeps recovery beside the data; greedy
    (start-time ties broken by index) would drag it across the spine."""
    cluster = fleet("rack2")
    p = ProfileRepository(cluster, MODELS)
    for d in paper_dfgs():
        p.register(d)
    sched = NavigatorScheduler(p)
    job = Job(0, translation_dfg(), arrival_time=0.0)
    rows = _warm_rows(8)
    choice = sched.select_recovery_worker(
        job, "opt_ingest", 0.0, rows,
        {"prev": 5}, {"prev": 0.2 * GB}, list(range(8)),
    )
    assert choice == 5


def test_recovery_routes_through_navigator_hook():
    """End to end: every stranded task's new home is chosen by the
    Navigator hook (never the greedy fallback) on a crash schedule."""
    cluster = fleet("mixed")
    p = ProfileRepository(cluster, MODELS)
    for d in paper_dfgs():
        p.register(d)
    jobs = poisson_workload(paper_dfgs(), 2.0, 40.0, seed=3)
    sim = Simulation(
        cluster, p, MODELS, scheduler="navigator", seed=1,
        churn=[
            ChurnEvent(time=6.0, kind="crash", worker=1),
            ChurnEvent(time=20.0, kind="join", worker=1),
        ],
    )
    calls = []
    orig = sim.scheduler.select_recovery_worker

    def spy(*args, **kwargs):
        choice = orig(*args, **kwargs)
        calls.append(choice)
        return choice

    sim.scheduler.select_recovery_worker = spy
    res = sim.run(jobs)
    assert len(res.records) == len(jobs)
    assert calls, "crash stranded no work: hook never consulted"
    assert all(c is not None for c in calls)

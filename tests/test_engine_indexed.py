"""Indexed event core: packed/scalar parity + binary trace files.

The PR 7 perf rewrite is only allowed to move time, never behaviour, so
every test here is differential: the packed columnar read path
(``PackedViews`` via ``SharedStateTable.view_arrays``) must agree
*bit-exactly* — same argmin winner, same planned finish times, same
event stream — with the scalar row-list path it replaces, across
randomized worker states, both metadata planes, flat and rack
topologies, and the paper's config variants.  ``tests/chaos.py`` family
7 runs the same differential under churn/partition schedules.
"""

import os
import random

import pytest

from repro.core import (
    ClusterSpec,
    GB,
    Job,
    LeaseConfig,
    NavigatorConfig,
    NavigatorScheduler,
    ProfileRepository,
    SharedStateTable,
    bitmaps,
    fleet,
)
from repro.core.packed import PackedViews
from repro.core.scheduler import JITScheduler
from repro.sim import Simulation, poisson_workload
from repro.sim.tracefile import (
    TraceFormatError,
    load_jobs,
    read_header,
    synthesize_poisson_trace,
    trace_task_count,
    write_trace,
)
from repro.workflows import MODELS, paper_dfgs

MODEL_IDS = list(MODELS)

#: The config variants the planner parity sweep exercises: default
#: (computed eviction penalty), every Alg. 2 margin armed, intent plane
#: neutered, speculation off with an aggressive herd margin.
CONFIGS = [
    NavigatorConfig(),
    NavigatorConfig(
        eviction_penalty_s=1.5, intent_herd_margin=0.15,
        adjustment_margin=0.1, staleness_margin_per_s=0.02,
    ),
    NavigatorConfig(intent_confidence=0.0, use_model_locality=False),
    NavigatorConfig(speculative_cache=False, intent_herd_margin=0.3),
]


def make_profiles(cluster):
    p = ProfileRepository(cluster, MODELS)
    for d in paper_dfgs():
        p.register(d)
    return p


def random_sst(n, rng, now, lease=None):
    """A randomized replicated state: mixed loads, caches, in-flight
    fetches, intents, staleness, and (with a lease) membership states."""
    sst = SharedStateTable(n, lease=lease)
    for w in range(n):
        pushed = now - rng.uniform(0.0, 8.0)
        sst.update_load(w, max(0.0, now + rng.uniform(-2.0, 6.0)), pushed)
        held = rng.sample(MODEL_IDS, rng.randint(0, 4))
        fetch_model, fetch_eta = -1, 0.0
        if rng.random() < 0.4:
            fetch_model = rng.choice(MODEL_IDS)
            fetch_eta = now + rng.uniform(0.1, 4.0)
        sst.update_cache(
            w, bitmaps.pack(held), rng.uniform(0.0, 16.0) * GB,
            pushed, fetch_model, fetch_eta,
        )
        if rng.random() < 0.5:
            sst.update_intent(
                w, bitmaps.pack(rng.sample(MODEL_IDS, rng.randint(1, 3))),
                pushed,
            )
        sst.heartbeat(w, now - rng.uniform(0.0, 6.0))
        if lease is not None and rng.random() < 0.1:
            sst.set_draining(w, True, now)
        sst.push(w, pushed)
    return sst


def clusters():
    return [ClusterSpec(n_workers=5), fleet("rack2")]


def test_plan_packed_matches_scalar():
    """Alg. 1 over packed columns: same per-task winner and planned FT."""
    rng = random.Random(42)
    dfgs = paper_dfgs()
    for cluster in clusters():
        profiles = make_profiles(cluster)
        n = cluster.n_workers
        for trial in range(60):
            cfg = CONFIGS[trial % len(CONFIGS)]
            sched = NavigatorScheduler(profiles, cfg)
            now = rng.uniform(0.0, 50.0)
            lease = LeaseConfig() if trial % 3 == 0 else None
            sst = random_sst(n, rng, now, lease)
            origin = rng.randrange(n)
            job = Job(trial, rng.choice(dfgs), arrival_time=now)
            a = sched.plan(job, now, origin, sst.view(origin, now))
            pv = sst.view_arrays(origin, now)
            assert isinstance(pv, PackedViews)
            b = sched.plan(job, now, origin, pv)
            assert dict(a.items()) == dict(b.items()), (
                f"trial {trial}: assignment diverged"
            )
            assert a.planned_ft == b.planned_ft, (
                f"trial {trial}: planned FT diverged"
            )


def test_adjust_packed_matches_scalar():
    """Alg. 2 over packed columns: same keep/steal verdict."""
    rng = random.Random(7)
    dfgs = paper_dfgs()
    for cluster in clusters():
        profiles = make_profiles(cluster)
        n = cluster.n_workers
        for trial in range(60):
            cfg = CONFIGS[trial % len(CONFIGS)]
            sched = NavigatorScheduler(profiles, cfg)
            now = rng.uniform(0.0, 50.0)
            lease = LeaseConfig() if trial % 3 == 1 else None
            sst = random_sst(n, rng, now, lease)
            origin = rng.randrange(n)
            job = Job(trial, rng.choice(dfgs), arrival_time=now)
            adfg = sched.plan(job, now, origin, sst.view(origin, now))
            current = rng.randrange(n)
            nbytes = rng.uniform(0.0, 0.5) * GB
            for tid in job.dfg.tasks:
                a = sched.adjust(
                    job, adfg, tid, now, sst.view(current, now),
                    current, nbytes,
                )
                b = sched.adjust(
                    job, adfg, tid, now, sst.view_arrays(current, now),
                    current, nbytes,
                )
                assert a == b, f"trial {trial} task {tid}: {a} != {b}"


def test_jit_select_packed_matches_scalar():
    rng = random.Random(21)
    dfgs = paper_dfgs()
    for cluster in clusters():
        profiles = make_profiles(cluster)
        n = cluster.n_workers
        sched = JITScheduler(profiles)
        for trial in range(60):
            now = rng.uniform(0.0, 50.0)
            lease = LeaseConfig() if trial % 2 == 0 else None
            sst = random_sst(n, rng, now, lease)
            job = Job(trial, rng.choice(dfgs), arrival_time=now)
            self_w = rng.randrange(n)
            for tid, task in job.dfg.tasks.items():
                locs = {p: rng.randrange(n) for p in job.dfg.preds[tid]}
                sizes = {p: rng.uniform(0.0, 0.2) * GB for p in locs}
                a = sched.select_worker_at_ready(
                    job, tid, now, sst.view(self_w, now),
                    locs, sizes, self_w,
                )
                b = sched.select_worker_at_ready(
                    job, tid, now, sst.view_arrays(self_w, now),
                    locs, sizes, self_w,
                )
                assert a == b, f"trial {trial} task {tid}: {a} != {b}"


def test_view_arrays_matches_view():
    """The columnar snapshot carries the same values and lease verdicts
    as the row-list snapshot it twins."""
    rng = random.Random(3)
    for lease in (None, LeaseConfig()):
        sst = random_sst(6, rng, 20.0, lease)
        rows = sst.view(2, 20.0)
        pv = sst.view_arrays(2, 20.0)
        ref = PackedViews.from_rows(rows, reader=2)
        for col in ("ft", "avc", "pushed_at", "fetch_eta"):
            assert getattr(pv, col).tolist() == getattr(ref, col).tolist()
        for col in ("bitmap", "intent", "fetch_model", "dead", "suspect"):
            assert getattr(pv, col).tolist() == getattr(ref, col).tolist()


@pytest.mark.parametrize("scheduler", ["navigator", "jit"])
@pytest.mark.parametrize("plane", ["sst", "gossip"])
def test_full_sim_engine_parity(scheduler, plane):
    """Indexed vs reference engine on a seeded workload: identical event
    stream, job records, and metrics export."""
    import json

    from repro.core import GossipConfig

    cluster = ClusterSpec(n_workers=5)
    dfgs = paper_dfgs()
    jobs = poisson_workload(dfgs, 1.5, 40.0, seed=9)

    def run(engine):
        profiles = make_profiles(cluster)
        sim = Simulation(
            cluster, profiles, MODELS, scheduler=scheduler, seed=1,
            gossip=GossipConfig(period_s=0.2, fanout=2)
            if plane == "gossip" else None,
            lease=LeaseConfig() if plane == "gossip" else None,
            record_events=True, engine=engine,
        )
        return sim.run(jobs)

    a, b = run("indexed"), run("reference")
    assert a.event_log == b.event_log
    assert [
        (r.job_id, r.arrival, r.finish, r.lower_bound) for r in a.records
    ] == [
        (r.job_id, r.arrival, r.finish, r.lower_bound) for r in b.records
    ]
    assert json.dumps(a.metrics.export(), sort_keys=True) == json.dumps(
        b.metrics.export(), sort_keys=True
    )


def test_engine_rejects_unknown_name():
    cluster = ClusterSpec(n_workers=3)
    with pytest.raises(ValueError):
        Simulation(cluster, make_profiles(cluster), MODELS, engine="turbo")


# -- binary trace files ------------------------------------------------------


def test_tracefile_roundtrip(tmp_path):
    path = os.fspath(tmp_path / "t.ctrc")
    dfgs = paper_dfgs()
    cat = {d.name: d for d in dfgs}
    recs = [(0.5, 0), (1.25, 2), (3.0, 1), (3.0, 3)]
    n = write_trace(path, [d.name for d in dfgs], recs)
    assert n == 4
    version, names, n_jobs = read_header(path)
    assert (version, n_jobs) == (1, 4)
    assert names == [d.name for d in dfgs]
    jobs = load_jobs(path, cat)
    assert [(j.arrival_time, j.dfg.name) for j in jobs] == [
        (t, names[i]) for t, i in recs
    ]
    assert [j.job_id for j in jobs] == [0, 1, 2, 3]
    assert trace_task_count(path, cat) == sum(
        len(cat[names[i]].tasks) for _, i in recs
    )
    # limit= replays a strict prefix (same job ids, same arrivals).
    head = load_jobs(path, cat, limit=2)
    assert [(j.job_id, j.arrival_time) for j in head] == [
        (j.job_id, j.arrival_time) for j in jobs[:2]
    ]


def test_synthesize_poisson_trace_deterministic(tmp_path):
    dfgs = paper_dfgs()
    pa, pb = os.fspath(tmp_path / "a.ctrc"), os.fspath(tmp_path / "b.ctrc")
    na = synthesize_poisson_trace(pa, dfgs, 20.0, 2000, seed=5)
    nb = synthesize_poisson_trace(pb, dfgs, 20.0, 2000, seed=5)
    assert na == nb
    with open(pa, "rb") as fa, open(pb, "rb") as fb:
        assert fa.read() == fb.read()
    cat = {d.name: d for d in dfgs}
    assert trace_task_count(pa, cat) >= 2000
    jobs = load_jobs(pa, cat)
    assert len(jobs) == na
    arrivals = [j.arrival_time for j in jobs]
    assert arrivals == sorted(arrivals)


def test_tracefile_format_errors(tmp_path):
    path = os.fspath(tmp_path / "t.ctrc")
    write_trace(path, ["a"], [(1.0, 0)])
    raw = open(path, "rb").read()
    bad_magic = os.fspath(tmp_path / "m.ctrc")
    with open(bad_magic, "wb") as f:
        f.write(b"XXXX" + raw[4:])
    with pytest.raises(TraceFormatError):
        read_header(bad_magic)
    bad_version = os.fspath(tmp_path / "v.ctrc")
    with open(bad_version, "wb") as f:
        f.write(raw[:4] + b"\x63\x00" + raw[6:])
    with pytest.raises(TraceFormatError):
        read_header(bad_version)
    truncated = os.fspath(tmp_path / "s.ctrc")
    with open(truncated, "wb") as f:
        f.write(raw[:-4])
    with pytest.raises(TraceFormatError):
        load_jobs(truncated, {"a": paper_dfgs()[0]})
    with pytest.raises(TraceFormatError):
        load_jobs(path, {"other": paper_dfgs()[0]})
    with pytest.raises(ValueError):
        write_trace(os.fspath(tmp_path / "r.ctrc"), ["a"], [(1.0, 3)])

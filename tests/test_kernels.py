"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp oracle across
shape/dtype sweeps, plus hypothesis property tests on the oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from hypothesis_compat import given, settings, st

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.moe_gmm import moe_gmm
from repro.kernels.ssd_scan import ssd_scan


def rnd(key, shape, dtype=jnp.float32, scale=1.0):
    return (jax.random.normal(jax.random.key(key), shape) * scale).astype(dtype)


TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,s,h,kh,d,causal,window",
    [
        (1, 128, 4, 4, 64, True, None),     # MHA, aligned
        (2, 200, 8, 2, 64, True, None),     # GQA, ragged seq
        (2, 96, 8, 1, 32, True, None),      # MQA
        (1, 256, 4, 2, 128, False, None),   # bidirectional (encoder)
        (2, 160, 4, 4, 64, True, 64),       # sliding window
        (1, 64, 2, 2, 8, True, None),       # tiny head dim
    ],
)
def test_flash_attention_matches_oracle(b, s, h, kh, d, causal, window, dtype):
    q = rnd(1, (b, s, h, d), dtype)
    k = rnd(2, (b, s, kh, d), dtype)
    v = rnd(3, (b, s, kh, d), dtype)
    out = flash_attention(
        q, k, v, causal=causal, window=window,
        block_q=64, block_k=64, interpret=True,
    )
    expect = ref.attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(
        np.asarray(out, np.float32),
        np.asarray(expect, np.float32),
        atol=TOL[dtype], rtol=TOL[dtype],
    )


def test_flash_attention_q_offset():
    """A 1-row query block attending into longer history == decode."""
    b, h, d, t = 2, 4, 32, 96
    q = rnd(1, (b, 1, h, d))
    k = rnd(2, (b, t, h, d))
    v = rnd(3, (b, t, h, d))
    out = flash_attention(
        q, k, v, causal=True, q_offset=t - 1, block_k=32, interpret=True
    )
    expect = ref.attention_ref(q, k, v, causal=True, q_offset=t - 1)
    np.testing.assert_allclose(out, expect, atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,h,kh,d,t,block_k",
    [
        (1, 4, 4, 64, 128, 64),
        (2, 8, 2, 64, 300, 128),
        (4, 8, 1, 32, 64, 32),
        (2, 16, 8, 128, 512, 256),
    ],
)
def test_decode_attention_matches_oracle(b, h, kh, d, t, block_k, dtype):
    q = rnd(4, (b, h, d), dtype)
    kc = rnd(5, (b, t, kh, d), dtype)
    vc = rnd(6, (b, t, kh, d), dtype)
    lens = jnp.asarray(
        np.random.RandomState(0).randint(1, t + 1, size=(b,)), jnp.int32
    )
    out = decode_attention(q, kc, vc, lens, block_k=block_k, interpret=True)
    expect = ref.decode_attention_ref(q, kc, vc, lens)
    np.testing.assert_allclose(
        np.asarray(out, np.float32),
        np.asarray(expect, np.float32),
        atol=TOL[dtype], rtol=TOL[dtype],
    )


def test_decode_attention_len_one():
    """Degenerate cache of a single valid entry == that entry's value."""
    b, h, d, t = 1, 2, 16, 64
    q = rnd(7, (b, h, d))
    kc = rnd(8, (b, t, h, d))
    vc = rnd(9, (b, t, h, d))
    lens = jnp.array([1], jnp.int32)
    out = decode_attention(q, kc, vc, lens, block_k=32, interpret=True)
    np.testing.assert_allclose(out[0], vc[0, 0], atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# SSD scan
# ---------------------------------------------------------------------------
@pytest.mark.parametrize(
    "b,t,h,p,n,chunk",
    [
        (1, 64, 2, 32, 16, 16),
        (2, 100, 3, 32, 16, 32),   # ragged chunks
        (1, 33, 1, 16, 8, 8),
        (2, 128, 4, 64, 32, 64),
    ],
)
def test_ssd_scan_matches_oracle(b, t, h, p, n, chunk):
    x = rnd(1, (b, t, h, p), scale=0.5)
    dt = jax.nn.softplus(rnd(2, (b, t, h)))
    a = -jnp.exp(rnd(3, (h,), scale=0.3))
    bb = rnd(4, (b, t, h, n), scale=0.5)
    cc = rnd(5, (b, t, h, n), scale=0.5)
    y, fs = ssd_scan(x, dt, a, bb, cc, chunk=chunk, interpret=True)
    ye, fse = ref.ssd_ref(x, dt, a, bb, cc)
    np.testing.assert_allclose(y, ye, atol=5e-5, rtol=5e-4)
    np.testing.assert_allclose(fs, fse, atol=5e-5, rtol=5e-4)


def test_ssd_decode_consistent_with_scan():
    """T sequential decode steps == one scan over T."""
    b, t, h, p, n = 1, 24, 2, 16, 8
    x = rnd(1, (b, t, h, p), scale=0.5)
    dt = jax.nn.softplus(rnd(2, (b, t, h)))
    a = -jnp.exp(rnd(3, (h,), scale=0.3))
    bb = rnd(4, (b, t, h, n), scale=0.5)
    cc = rnd(5, (b, t, h, n), scale=0.5)
    y_scan, fs = ref.ssd_ref(x, dt, a, bb, cc)
    state = jnp.zeros((b, h, p, n), jnp.float32)
    ys = []
    for i in range(t):
        yi, state = ref.ssd_decode_ref(
            x[:, i], dt[:, i], a, bb[:, i], cc[:, i], state
        )
        ys.append(yi)
    y_dec = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(y_dec, y_scan, atol=1e-5, rtol=1e-4)
    np.testing.assert_allclose(state, fs, atol=1e-5, rtol=1e-4)


# ---------------------------------------------------------------------------
# MoE grouped matmul
# ---------------------------------------------------------------------------
@pytest.mark.parametrize(
    "t,din,dout,e,block_t,block_n",
    [
        (50, 64, 48, 4, 16, 16),
        (128, 32, 32, 8, 32, 32),
        (17, 16, 64, 3, 8, 16),     # ragged everything
        (64, 128, 96, 1, 64, 48),   # single expert == plain matmul
    ],
)
def test_moe_gmm_matches_oracle(t, din, dout, e, block_t, block_n):
    x = rnd(6, (t, din))
    w = rnd(7, (e, din, dout))
    rs = np.random.RandomState(e)
    cuts = np.sort(rs.randint(0, t + 1, size=e - 1))
    sizes = np.diff(np.concatenate([[0], cuts, [t]]))
    gs = jnp.asarray(sizes, jnp.int32)
    out = moe_gmm(x, w, gs, block_t=block_t, block_n=block_n, interpret=True)
    expect = ref.moe_gmm_ref(x, w, gs)
    np.testing.assert_allclose(out, expect, atol=2e-4, rtol=2e-4)


def test_moe_gmm_empty_groups():
    x = rnd(8, (20, 16))
    w = rnd(9, (5, 16, 8))
    gs = jnp.array([0, 20, 0, 0, 0], jnp.int32)
    out = moe_gmm(x, w, gs, block_t=8, block_n=8, interpret=True)
    np.testing.assert_allclose(out, x @ w[1], atol=2e-4, rtol=2e-4)


# ---------------------------------------------------------------------------
# Hypothesis property tests on the oracles
# ---------------------------------------------------------------------------
@settings(max_examples=20, deadline=None)
@given(
    s=st.integers(2, 40),
    h=st.sampled_from([1, 2, 4]),
    group=st.sampled_from([1, 2]),
)
def test_attention_oracle_is_convex_combination(s, h, group):
    """Attention output lies in the convex hull of V rows: max|out| ≤ max|V|."""
    kh = h // group if h % group == 0 else h
    q = rnd(10, (1, s, h, 16))
    k = rnd(11, (1, s, kh, 16))
    v = rnd(12, (1, s, kh, 16))
    out = ref.attention_ref(q, k, v, causal=True)
    assert jnp.max(jnp.abs(out)) <= jnp.max(jnp.abs(v)) + 1e-5


@settings(max_examples=20, deadline=None)
@given(s=st.integers(1, 32))
def test_attention_first_token_is_v0(s):
    """Causally, position 0 attends only to itself."""
    q = rnd(13, (1, s, 2, 8))
    k = rnd(14, (1, s, 2, 8))
    v = rnd(15, (1, s, 2, 8))
    out = ref.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(out[0, 0], v[0, 0], atol=1e-5, rtol=1e-5)


@settings(max_examples=15, deadline=None)
@given(t=st.integers(1, 30), scale=st.floats(0.1, 2.0))
def test_ssd_oracle_linearity_in_x(t, scale):
    """The SSD map is linear in x for fixed (dt, a, b, c)."""
    b, h, p, n = 1, 1, 8, 4
    x = rnd(16, (b, t, h, p))
    dt = jax.nn.softplus(rnd(17, (b, t, h)))
    a = -jnp.exp(rnd(18, (h,), scale=0.2))
    bb = rnd(19, (b, t, h, n))
    cc = rnd(20, (b, t, h, n))
    y1, _ = ref.ssd_ref(x, dt, a, bb, cc)
    y2, _ = ref.ssd_ref(x * scale, dt, a, bb, cc)
    np.testing.assert_allclose(y2, y1 * scale, atol=1e-4, rtol=1e-3)


@settings(max_examples=15, deadline=None)
@given(
    t=st.integers(1, 50),
    e=st.integers(1, 6),
    seed=st.integers(0, 100),
)
def test_gmm_oracle_equals_blockwise_matmul(t, e, seed):
    rs = np.random.RandomState(seed)
    sizes = rs.multinomial(t, [1 / e] * e)
    x = rnd(seed, (t, 8))
    w = rnd(seed + 1, (e, 8, 4))
    out = ref.moe_gmm_ref(x, w, jnp.asarray(sizes, jnp.int32))
    start = 0
    for ei, sz in enumerate(sizes):
        if sz:
            np.testing.assert_allclose(
                out[start : start + sz], x[start : start + sz] @ w[ei],
                atol=1e-5, rtol=1e-5,
            )
        start += sz

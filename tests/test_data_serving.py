"""Data pipeline determinism/sharding + serving-cluster behaviour."""

import numpy as np
import jax
import pytest

from repro.configs import ARCHS
from repro.core import ClusterSpec, GB
from repro.core.types import DFG, MB, TaskSpec
from repro.data import DataConfig, SyntheticTokens, make_pipeline
from repro.models import init_params
from repro.serving import HostedModel, ServingCluster


def test_data_deterministic_per_host():
    cfg = DataConfig(vocab=128, seq_len=32, global_batch=8, seed=5)
    a = SyntheticTokens(cfg, host_id=0)
    b = SyntheticTokens(cfg, host_id=0)
    x = next(a.batches())["tokens"]
    y = next(b.batches())["tokens"]
    np.testing.assert_array_equal(x, y)


def test_data_differs_across_hosts():
    cfg = DataConfig(vocab=128, seq_len=32, global_batch=8, seed=5)
    x = next(SyntheticTokens(cfg, host_id=0, n_hosts=2).batches())["tokens"]
    y = next(SyntheticTokens(cfg, host_id=1, n_hosts=2).batches())["tokens"]
    assert x.shape == (4, 32)  # global batch split across hosts
    assert not np.array_equal(x, y)


def test_data_tokens_in_vocab():
    cfg = DataConfig(vocab=64, seq_len=16, global_batch=4)
    toks = next(SyntheticTokens(cfg).batches())["tokens"]
    assert toks.min() >= 0 and toks.max() < 64
    assert toks.dtype == np.int32


def test_data_has_learnable_repetition():
    cfg = DataConfig(vocab=1024, seq_len=256, global_batch=4, repeat_p=0.4)
    toks = next(SyntheticTokens(cfg).batches())["tokens"]
    # With 40% short-range copies, adjacent-window duplicates are far more
    # common than in iid data.
    dup = np.mean(toks[:, 16:] == toks[:, :-16])
    assert dup > 0.01


def test_prefetcher_delivers_in_order():
    cfg = DataConfig(vocab=64, seq_len=8, global_batch=2)
    direct = SyntheticTokens(cfg).batches()
    pre = make_pipeline(cfg, prefetch=2)
    for _ in range(3):
        np.testing.assert_array_equal(
            next(direct)["tokens"], next(pre)["tokens"]
        )


# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def small_cluster():
    hosted = []
    for mid, arch in enumerate(["mistral-nemo-12b", "mamba2-780m"]):
        cfg = ARCHS[arch].reduced(dtype="float32")
        hosted.append(
            HostedModel(mid, cfg, init_params(cfg, jax.random.key(mid)))
        )
    dfg = DFG(
        "p",
        tasks=[
            TaskSpec("a", 0.05, model_id=1, output_bytes=0.01 * MB,
                     input_bytes=0.01 * MB),
            TaskSpec("b", 0.1, model_id=0, output_bytes=0.01 * MB),
        ],
        edges=[("a", "b")],
    )
    cluster = ClusterSpec(n_workers=2, gpu_capacity_bytes=1 * GB)
    sc = ServingCluster(cluster, hosted, scheduler="navigator",
                        decode_tokens=4)
    sc.register_pipeline(dfg)
    return sc, dfg


def test_serving_produces_tokens(small_cluster):
    sc, dfg = small_cluster
    prompt = np.array([[3, 4, 5]], np.int32)
    r = sc.submit(dfg, {"a": prompt}, origin=0)
    assert r.outputs["b"].shape == (1, 4)
    assert r.outputs["b"].dtype in (np.int32, np.int64)
    assert set(r.assignment) == {"a", "b"}


def test_serving_cache_warms_up(small_cluster):
    sc, dfg = small_cluster
    prompt = np.array([[9, 2, 7]], np.int32)
    before = sc.cache_hit_rate()
    for _ in range(3):
        sc.submit(dfg, {"a": prompt}, origin=0)
    assert sc.cache_hit_rate() >= before
    assert sc.cache_hit_rate() > 0.5


def test_serving_outputs_deterministic(small_cluster):
    sc, dfg = small_cluster
    prompt = np.array([[1, 2, 3, 4]], np.int32)
    r1 = sc.submit(dfg, {"a": prompt}, origin=0)
    r2 = sc.submit(dfg, {"a": prompt}, origin=1)
    np.testing.assert_array_equal(r1.outputs["b"], r2.outputs["b"])

"""Training substrate: optimizer semantics, schedules, grad accumulation,
checkpoint round-trips, sharding specs."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from hypothesis_compat import given, settings, st

from repro.launch.mesh import make_debug_mesh
from repro.models import ModelConfig, init_params, abstract_params
from repro.models.sharding import batch_pspecs, cache_pspecs, param_pspecs
from repro.training import checkpoint, make_train_step, optimizer as opt


def tiny_cfg(**kw):
    base = dict(
        name="tiny", arch_type="dense", n_layers=2, d_model=32, n_heads=2,
        n_kv_heads=2, d_ff=64, vocab=64, dtype="float32",
    )
    base.update(kw)
    return ModelConfig(**base)


def test_adamw_reduces_quadratic():
    w = {"w": jnp.array([5.0, -3.0])}
    state = opt.init(w)
    cfg = opt.AdamWConfig(lr=0.2, weight_decay=0.0, warmup_steps=1,
                          total_steps=200, clip_norm=None, schedule="constant")
    for _ in range(150):
        grads = {"w": 2 * w["w"]}
        w, state, _ = opt.apply(cfg, grads, state, w)
    assert float(jnp.max(jnp.abs(w["w"]))) < 0.05


def test_grad_clip_bounds_update():
    w = {"w": jnp.ones((4,))}
    state = opt.init(w)
    cfg = opt.AdamWConfig(lr=1e-3, clip_norm=1.0, weight_decay=0.0)
    _, _, metrics = opt.apply(cfg, {"w": jnp.full((4,), 1e6)}, state, w)
    assert metrics["grad_norm"] > 1e6  # raw norm reported


def test_lr_schedule_shapes():
    cfg = opt.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                          min_lr_ratio=0.1)
    lrs = [float(opt.lr_at(cfg, jnp.asarray(s))) for s in range(100)]
    assert lrs[0] < lrs[9] <= 1.0          # warmup ascends
    assert lrs[99] == pytest.approx(0.1, abs=0.02)  # decays to floor
    assert max(lrs) <= 1.0 + 1e-6


@settings(max_examples=10, deadline=None)
@given(steps=st.integers(1, 50))
def test_lr_monotone_after_warmup(steps):
    cfg = opt.AdamWConfig(lr=1.0, warmup_steps=5, total_steps=60)
    a = float(opt.lr_at(cfg, jnp.asarray(5 + steps // 2)))
    b = float(opt.lr_at(cfg, jnp.asarray(5 + steps)))
    assert b <= a + 1e-6


def test_moment_dtype_bf16():
    w = {"w": jnp.ones((4,), jnp.bfloat16)}
    state = opt.init(w, moment_dtype=jnp.bfloat16)
    assert state.m["w"].dtype == jnp.bfloat16


def test_grad_accumulation_matches_full_batch():
    """accum_steps=4 over batch 8 ≡ one step over the full batch (up to
    fp tolerance): same loss and ~same parameter update."""
    cfg = tiny_cfg()
    mesh = make_debug_mesh()
    params = init_params(cfg, jax.random.key(0))
    batch = {
        "tokens": jax.random.randint(jax.random.key(1), (8, 16), 0, cfg.vocab)
    }
    ocfg = opt.AdamWConfig(lr=1e-2, warmup_steps=1, total_steps=10,
                           weight_decay=0.0)
    outs = {}
    for accum in (1, 4):
        step, _ = make_train_step(
            cfg, mesh, ocfg, accum_steps=accum, remat=False
        )
        state = opt.init(init_params(cfg, jax.random.key(0)))
        p, s, m = step(init_params(cfg, jax.random.key(0)), state, batch)
        outs[accum] = (p, float(m["loss"]))
    assert outs[1][1] == pytest.approx(outs[4][1], rel=1e-5)
    diff = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))), outs[1][0], outs[4][0]
    )
    assert max(jax.tree.leaves(diff)) < 1e-5


def test_checkpoint_roundtrip(tmp_path):
    cfg = tiny_cfg()
    params = init_params(cfg, jax.random.key(3))
    state = opt.init(params)
    path = os.path.join(tmp_path, "ck")
    checkpoint.save(path, {"p": params, "o": state._asdict()},
                    metadata={"step": 7})
    restored, meta = checkpoint.restore(
        path, {"p": params, "o": state._asdict()}
    )
    assert meta["step"] == 7
    same = jax.tree.map(
        lambda a, b: bool(jnp.all(a == b)), restored["p"], params
    )
    assert all(jax.tree.leaves(same))


def test_checkpoint_shape_mismatch_rejected(tmp_path):
    path = os.path.join(tmp_path, "ck2")
    checkpoint.save(path, {"w": jnp.ones((3,))})
    with pytest.raises(ValueError, match="shape mismatch"):
        checkpoint.restore(path, {"w": jnp.ones((4,))})


def test_param_pspecs_cover_tree():
    """Every param leaf gets a spec of matching rank; large matrices are
    actually sharded on a >1 mesh."""
    from repro.configs import ARCHS

    mesh = make_debug_mesh()
    for arch in ["llama3-405b", "deepseek-v2-236b", "mamba2-780m",
                 "zamba2-7b", "whisper-medium"]:
        cfg = ARCHS[arch].reduced()
        params = abstract_params(cfg)
        specs = param_pspecs(mesh, params, cfg)
        flat_p = jax.tree.leaves(params)
        flat_s = jax.tree.leaves(
            specs, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec)
        )
        assert len(flat_p) == len(flat_s)
        for p, s in zip(flat_p, flat_s):
            assert len(s) <= len(p.shape), (arch, p.shape, s)

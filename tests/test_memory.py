"""GPU memory manager: FIFO vs queue-lookahead eviction, execution memory,
pinning, AVC accounting (§3.3, §5.3)."""

import pytest

from repro.core import AcceleratorLink, GB, GpuMemoryManager, MLModel


def mk_models(sizes):
    return {
        i: MLModel(model_id=i, name=f"m{i}", size_bytes=s * GB)
        for i, s in enumerate(sizes)
    }


def mk_mem(capacity_gb=10.0, sizes=(4, 4, 4, 4), policy="fifo", ratio=1.0):
    return GpuMemoryManager(
        capacity_gb * GB,
        mk_models(sizes),
        AcceleratorLink(),
        policy=policy,
        compression_ratio=ratio,
    )


def test_hit_and_miss_accounting():
    mem = mk_mem()
    fetch, evicted = mem.ensure(0)
    assert fetch > 0 and evicted == []
    assert mem.stats.misses == 1
    fetch, evicted = mem.ensure(0)
    assert fetch == 0.0
    assert mem.stats.hits == 1


def test_fifo_evicts_oldest():
    mem = mk_mem(capacity_gb=10.0, sizes=(4, 4, 4, 4), policy="fifo")
    mem.ensure(0)
    mem.ensure(1)  # 8 GB used
    _, evicted = mem.ensure(2)  # needs eviction of oldest = 0
    assert evicted == [0]
    assert mem.resident_models() == [1, 2]


def test_lookahead_protects_soon_needed():
    mem = mk_mem(capacity_gb=10.0, sizes=(4, 4, 4, 4), policy="lookahead")
    mem.ensure(0)
    mem.ensure(1)
    # model 0 is needed by an upcoming queued task, model 1 is not → evict 1.
    _, evicted = mem.ensure(2, upcoming_model_ids=[0])
    assert evicted == [1]
    assert mem.resident_models() == [0, 2]


def test_lookahead_orders_by_next_use():
    mem = mk_mem(capacity_gb=12.0, sizes=(4, 4, 4, 4, 4), policy="lookahead")
    mem.ensure(0)
    mem.ensure(1)
    mem.ensure(2)
    # 1 is needed soonest, then 0; 2 unneeded → evict 2 first.
    _, evicted = mem.ensure(3, upcoming_model_ids=[1, 0])
    assert evicted == [2]


def test_pinned_models_not_evicted():
    mem = mk_mem(capacity_gb=8.0, sizes=(4, 4, 4), policy="fifo", ratio=0.5)
    mem.ensure(0)
    mem.pin(0)
    mem.ensure(1)
    mem.ensure(2)  # fits: 3 × 2 GB cached
    # Fill with a 4th model requiring eviction: pinned 0 survives.
    mem.models[3] = mem.models[0].__class__(
        model_id=3, name="m3", size_bytes=mem.models[0].size_bytes
    )
    mem.ensure(3, upcoming_model_ids=[])
    assert mem.has(0)


def test_ensure_returns_none_when_pins_block():
    mem = mk_mem(capacity_gb=6.5, sizes=(4, 4), policy="fifo", ratio=0.5)
    mem.ensure(0)
    mem.begin_execution(0)  # exec copy 4 GB: free = 0.5 GB, model 0 pinned
    assert mem.ensure(1) is None  # nothing evictable


def test_execution_memory_reserves_and_releases():
    # capacity 10, ratio 0.5: cached copies are 2 GB, execution copy 4 GB.
    mem = mk_mem(capacity_gb=10.0, sizes=(4, 4, 4, 4), policy="fifo", ratio=0.5)
    mem.ensure(0)
    mem.ensure(1)
    mem.ensure(2)
    mem.ensure(3)  # 8 GB cache
    assert mem.free_bytes == pytest.approx(2 * GB)
    mem.begin_execution(0)
    # needs 4 GB exec: evicts until free ≥ 0 (model 0 pinned, evict 1)
    assert mem.free_bytes >= 0
    assert mem.has(0)
    assert not mem.has(1)
    mem.end_execution(0)
    assert mem.exec_reserved_bytes == 0


def test_bitmap_tracks_contents():
    mem = mk_mem()
    mem.ensure(0)
    mem.ensure(2)
    assert mem.bitmap == (1 << 0) | (1 << 2)


def test_oversized_model_rejected():
    mem = mk_mem(capacity_gb=5.0, sizes=(4,), ratio=1.0)
    with pytest.raises(ValueError, match="exceeds GPU capacity"):
        mem.ensure(0)  # 4 cached + 4 decompressed > 5


def test_preload():
    mem = mk_mem(ratio=0.5)
    mem.preload([0, 1])
    assert mem.has(0) and mem.has(1)
    assert mem.stats.hits == 0 and mem.stats.misses == 0


# -- speculative prefetch hooks (core/prefetch.py fetch-pipe arbitration) ----
def test_begin_prefetch_fetch_pin_blocks_eviction():
    mem = mk_mem(capacity_gb=10.0, sizes=(4, 4, 4), policy="fifo")
    assert mem.begin_prefetch(0) is not None
    # In flight: fetch-pinned, so an 8 GB demand (needs eviction) can't
    # displace it and nothing else is resident → ensure must refuse.
    assert mem.ensure(1) is not None  # fits beside it (8 GB used)
    assert mem.would_evict(2) == [1]  # victim is the unpinned model only
    mem.complete_prefetch(0)
    assert mem.would_evict(2) == [0]  # pin released → FIFO order again


def test_fetch_pin_vs_execution_pin_interaction():
    mem = mk_mem(capacity_gb=12.0, sizes=(4, 4, 4), policy="fifo", ratio=0.5)
    assert mem.begin_prefetch(0) is not None
    mem.begin_execution(0)  # demand hits the in-flight model: double pin
    mem.complete_prefetch(0)  # fetch-pin released, execution-pin remains
    assert mem.would_evict(1) == []  # fits
    mem.ensure(1)
    mem.ensure(2)
    # 0 still execution-pinned: filling the cache evicts 1, never 0.
    mem.models[3] = MLModel(model_id=3, name="m3", size_bytes=4 * GB)
    _, evicted = mem.ensure(3)
    assert 0 not in evicted
    mem.end_execution(0)
    assert mem.exec_reserved_bytes == 0


def test_ensure_none_under_fetch_pin_pressure():
    mem = mk_mem(capacity_gb=6.5, sizes=(4, 4), policy="fifo", ratio=0.5)
    assert mem.begin_prefetch(0) is not None  # 2 GB cached, fetch-pinned
    mem.begin_execution(0)  # + 4 GB execution copy → 0.5 GB free
    assert mem.ensure(1) is None  # only pinned contents: nothing to evict


def test_abort_prefetch_accounts_partial_waste():
    mem = mk_mem(capacity_gb=10.0, sizes=(4, 4), policy="fifo", ratio=0.5)
    assert mem.begin_prefetch(0) is not None
    mem.abort_prefetch(0, fraction_done=0.25)
    assert not mem.has(0)
    # Only the transferred quarter hit the PCIe wire and is wasted.
    assert mem.stats.prefetch_wasted_bytes == pytest.approx(0.25 * 2 * GB)
    assert mem.stats.bytes_fetched == pytest.approx(0.25 * 2 * GB)
    assert mem.stats.prefetch_aborted == 1


def test_prefetch_useful_vs_wasted_accounting():
    mem = mk_mem(capacity_gb=10.0, sizes=(4, 4, 4), policy="fifo", ratio=0.5)
    mem.begin_prefetch(0)
    mem.complete_prefetch(0)
    mem.begin_prefetch(1)
    mem.complete_prefetch(1)
    # Model 0 gets demanded → useful; model 1 is evicted unused → wasted.
    fetch, _ = mem.ensure(0)
    assert fetch == 0.0 and mem.stats.hits == 1
    assert mem.stats.prefetch_useful == 1
    mem.drop(1)
    assert mem.stats.prefetch_wasted == 1
    assert mem.stats.prefetch_wasted_bytes == pytest.approx(2 * GB)
    assert mem.unused_prefetched_bytes() == 0.0


def test_lookahead_evicts_unused_prefetched_first():
    mem = mk_mem(capacity_gb=10.0, sizes=(4, 4, 4, 4), policy="lookahead",
                 ratio=0.5)
    mem.ensure(0)           # demand-fetched
    mem.begin_prefetch(1)
    mem.complete_prefetch(1)  # speculative, never demanded
    mem.ensure(2)
    mem.models[4] = MLModel(model_id=4, name="m4", size_bytes=12 * GB)
    # Neither 0 nor 1 is in the window; the speculative one goes first.
    _, evicted = mem.ensure(3, upcoming_model_ids=[2])
    assert evicted == []  # fits: 8 GB used
    victims = mem.would_evict(4, upcoming_model_ids=[2])
    assert victims[0] == 1


def test_can_host_static_feasibility():
    mem = mk_mem(capacity_gb=10.0, sizes=(4, 8), ratio=0.6)
    assert mem.can_host(0)       # 4×0.6 + 4 = 6.4 GB
    assert not mem.can_host(1)   # 8×0.6 + 8 = 12.8 GB > 10
    assert mem.begin_prefetch(1) is None  # speculation refuses too


# -- randomized FIFO-vs-lookahead property tests ------------------------------
def _random_mem(rng, policy):
    sizes = [rng.choice([1, 2, 3, 4]) for _ in range(8)]
    mem = GpuMemoryManager(
        rng.uniform(8.0, 14.0) * GB,
        {i: MLModel(model_id=i, name=f"m{i}", size_bytes=s * GB)
         for i, s in enumerate(sizes)},
        AcceleratorLink(),
        policy=policy,
        compression_ratio=0.5,
    )
    for mid in rng.sample(range(8), rng.randint(3, 6)):
        if mem.cached_size(mid) <= mem.free_bytes:
            mem.preload([mid])
    return mem


def test_property_lookahead_never_evicts_needed_before_unneeded():
    """On random cache states and queues: a model needed inside the
    lookahead window is only evicted once every evictable model *not*
    needed in the window is gone too."""
    import random as _random

    rng = _random.Random(42)
    for _ in range(200):
        mem = _random_mem(rng, "lookahead")
        upcoming = [rng.randrange(8) for _ in range(rng.randint(0, 10))]
        target = rng.randrange(8)
        victims = mem.would_evict(target, upcoming_model_ids=upcoming)
        if not victims:
            continue
        window = set(upcoming[: mem.lookahead_depth])
        resident = set(mem.resident_models())
        needed_evicted = [v for v in victims if v in window]
        if needed_evicted:
            unneeded_survivors = (resident - set(victims)) - window
            assert not unneeded_survivors, (
                victims, upcoming, sorted(resident)
            )


def test_property_fifo_victims_are_insertion_prefix():
    import random as _random

    rng = _random.Random(7)
    for _ in range(200):
        mem = _random_mem(rng, "fifo")
        target = rng.randrange(8)
        victims = mem.would_evict(target)
        order = mem.resident_models()  # insertion order
        assert victims == order[: len(victims)]


def test_property_policies_agree_when_no_eviction_needed():
    import random as _random

    rng = _random.Random(13)
    for _ in range(100):
        seed = rng.randrange(10**9)
        results = []
        for policy in ("fifo", "lookahead"):
            r2 = _random.Random(seed)
            mem = _random_mem(r2, policy)
            target = r2.randrange(8)
            if mem.has(target) or mem.cached_size(target) <= mem.free_bytes:
                res = mem.ensure(target)
                assert res is not None
                results.append((mem.resident_models(), res[1]))
        if len(results) == 2:
            assert results[0] == results[1]  # identical without pressure

"""GPU memory manager: FIFO vs queue-lookahead eviction, execution memory,
pinning, AVC accounting (§3.3, §5.3)."""

import pytest

from repro.core import AcceleratorLink, GB, GpuMemoryManager, MLModel


def mk_models(sizes):
    return {
        i: MLModel(model_id=i, name=f"m{i}", size_bytes=s * GB)
        for i, s in enumerate(sizes)
    }


def mk_mem(capacity_gb=10.0, sizes=(4, 4, 4, 4), policy="fifo", ratio=1.0):
    return GpuMemoryManager(
        capacity_gb * GB,
        mk_models(sizes),
        AcceleratorLink(),
        policy=policy,
        compression_ratio=ratio,
    )


def test_hit_and_miss_accounting():
    mem = mk_mem()
    fetch, evicted = mem.ensure(0)
    assert fetch > 0 and evicted == []
    assert mem.stats.misses == 1
    fetch, evicted = mem.ensure(0)
    assert fetch == 0.0
    assert mem.stats.hits == 1


def test_fifo_evicts_oldest():
    mem = mk_mem(capacity_gb=10.0, sizes=(4, 4, 4, 4), policy="fifo")
    mem.ensure(0)
    mem.ensure(1)  # 8 GB used
    _, evicted = mem.ensure(2)  # needs eviction of oldest = 0
    assert evicted == [0]
    assert mem.resident_models() == [1, 2]


def test_lookahead_protects_soon_needed():
    mem = mk_mem(capacity_gb=10.0, sizes=(4, 4, 4, 4), policy="lookahead")
    mem.ensure(0)
    mem.ensure(1)
    # model 0 is needed by an upcoming queued task, model 1 is not → evict 1.
    _, evicted = mem.ensure(2, upcoming_model_ids=[0])
    assert evicted == [1]
    assert mem.resident_models() == [0, 2]


def test_lookahead_orders_by_next_use():
    mem = mk_mem(capacity_gb=12.0, sizes=(4, 4, 4, 4, 4), policy="lookahead")
    mem.ensure(0)
    mem.ensure(1)
    mem.ensure(2)
    # 1 is needed soonest, then 0; 2 unneeded → evict 2 first.
    _, evicted = mem.ensure(3, upcoming_model_ids=[1, 0])
    assert evicted == [2]


def test_pinned_models_not_evicted():
    mem = mk_mem(capacity_gb=8.0, sizes=(4, 4, 4), policy="fifo", ratio=0.5)
    mem.ensure(0)
    mem.pin(0)
    mem.ensure(1)
    mem.ensure(2)  # fits: 3 × 2 GB cached
    # Fill with a 4th model requiring eviction: pinned 0 survives.
    mem.models[3] = mem.models[0].__class__(
        model_id=3, name="m3", size_bytes=mem.models[0].size_bytes
    )
    mem.ensure(3, upcoming_model_ids=[])
    assert mem.has(0)


def test_ensure_returns_none_when_pins_block():
    mem = mk_mem(capacity_gb=6.5, sizes=(4, 4), policy="fifo", ratio=0.5)
    mem.ensure(0)
    mem.begin_execution(0)  # exec copy 4 GB: free = 0.5 GB, model 0 pinned
    assert mem.ensure(1) is None  # nothing evictable


def test_execution_memory_reserves_and_releases():
    # capacity 10, ratio 0.5: cached copies are 2 GB, execution copy 4 GB.
    mem = mk_mem(capacity_gb=10.0, sizes=(4, 4, 4, 4), policy="fifo", ratio=0.5)
    mem.ensure(0)
    mem.ensure(1)
    mem.ensure(2)
    mem.ensure(3)  # 8 GB cache
    assert mem.free_bytes == pytest.approx(2 * GB)
    mem.begin_execution(0)
    # needs 4 GB exec: evicts until free ≥ 0 (model 0 pinned, evict 1)
    assert mem.free_bytes >= 0
    assert mem.has(0)
    assert not mem.has(1)
    mem.end_execution(0)
    assert mem.exec_reserved_bytes == 0


def test_bitmap_tracks_contents():
    mem = mk_mem()
    mem.ensure(0)
    mem.ensure(2)
    assert mem.bitmap == (1 << 0) | (1 << 2)


def test_oversized_model_rejected():
    mem = mk_mem(capacity_gb=5.0, sizes=(4,), ratio=1.0)
    with pytest.raises(ValueError, match="exceeds GPU capacity"):
        mem.ensure(0)  # 4 cached + 4 decompressed > 5


def test_preload():
    mem = mk_mem(ratio=0.5)
    mem.preload([0, 1])
    assert mem.has(0) and mem.has(1)
    assert mem.stats.hits == 0 and mem.stats.misses == 0

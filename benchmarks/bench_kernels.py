"""Kernel micro-benchmarks: pure-jnp reference paths under jit on the host
backend (the Pallas kernels target TPU; interpret mode is a correctness
tool, not a perf path).  ``derived`` = achieved GFLOP/s of the ref path.
"""

from __future__ import annotations

import time
from typing import List, Tuple

import jax
import jax.numpy as jnp

from benchmarks.common import save_json
from repro.kernels import ref


def _time(fn, *args, repeat=5) -> float:
    fn(*args).block_until_ready()  # compile + warm
    t0 = time.perf_counter()
    for _ in range(repeat):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / repeat


def run() -> List[Tuple[str, float, float]]:
    rows = []
    out = {}
    key = jax.random.key(0)

    # flash attention ref
    b, s, h, kh, d = 1, 1024, 8, 2, 64
    q = jax.random.normal(key, (b, s, h, d), jnp.float32)
    k = jax.random.normal(key, (b, s, kh, d), jnp.float32)
    v = jax.random.normal(key, (b, s, kh, d), jnp.float32)
    fn = jax.jit(lambda q, k, v: ref.attention_ref(q, k, v, causal=True))
    t = _time(fn, q, k, v)
    flops = 4 * b * h * s * s * d / 2  # causal
    rows.append(("kernel/attention_ref_1k", t * 1e6, flops / t / 1e9))

    # decode attention ref
    t_cache = 4096
    qd = jax.random.normal(key, (8, h, d), jnp.float32)
    kc = jax.random.normal(key, (8, t_cache, kh, d), jnp.float32)
    lens = jnp.full((8,), t_cache, jnp.int32)
    fnd = jax.jit(lambda q, k, v, l: ref.decode_attention_ref(q, k, v, l))
    t = _time(fnd, qd, kc, kc, lens)
    flops = 4 * 8 * h * t_cache * d
    rows.append(("kernel/decode_ref_4k", t * 1e6, flops / t / 1e9))

    # SSD chunked ref
    bt, tt, hh, p, n = 1, 2048, 4, 64, 64
    x = jax.random.normal(key, (bt, tt, hh, p), jnp.float32) * 0.3
    dt = jax.nn.softplus(jax.random.normal(key, (bt, tt, hh)))
    a = -jnp.exp(jax.random.normal(key, (hh,)) * 0.3)
    bb = jax.random.normal(key, (bt, tt, hh, n), jnp.float32) * 0.3
    fns = jax.jit(
        lambda x, dt, b, c: ref.ssd_chunked_ref(x, dt, a, b, c, chunk=128)[0]
    )
    t = _time(fns, x, dt, bb, bb)
    chunk = 128
    flops = bt * hh * (tt // chunk) * (
        2 * chunk * chunk * n + 2 * chunk * chunk * p + 2 * chunk * p * n * 2
    )
    rows.append(("kernel/ssd_chunked_2k", t * 1e6, flops / t / 1e9))

    # grouped matmul ref
    tk, din, dout, e = 4096, 512, 512, 16
    xk = jax.random.normal(key, (tk, din), jnp.float32)
    wk = jax.random.normal(key, (e, din, dout), jnp.float32)
    gs = jnp.full((e,), tk // e, jnp.int32)
    fng = jax.jit(lambda x, w, g: ref.moe_gmm_ref(x, w, g))
    t = _time(fng, xk, wk, gs)
    flops = 2 * tk * din * dout
    rows.append(("kernel/moe_gmm_ref_4k", t * 1e6, flops / t / 1e9))

    save_json("kernels", {n: {"us": u, "gflops": d} for n, u, d in rows})
    return rows


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived:.4f}")

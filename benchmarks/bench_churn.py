"""Worker-churn sweep: MTBF × policy × fleet (fault-tolerance plane).

For each policy the same seeded workloads run on a static fleet and under
seeded churn schedules (crash/drain with repair, disruption budget
``min_live=3``), with the membership lease enabled.  Reported per cell:

* ``p99_jct_churn_s``       — absolute P99 JCT under churn
* ``p99_jct_degradation_s`` — P99 JCT increase over the same policy's
  static-fleet baseline (seconds), averaged over workload × churn seeds
* ``reexec_overhead``       — re-executed/rescued task attempts per
  completed task (the fault-tolerance tax)
* ``churn_wasted_mb``       — PCIe bytes thrown away by churn

The headline comparison (acceptance): on the paper's uniform 5-worker
fleet at high load and MTBF=120 s, membership-aware Navigator degrades
strictly less than the blind hash baseline — hash keeps shipping tasks at
corpses for the whole repair window (each paying the dead-letter
timeout), while Navigator routes around them one lease window after the
crash.  On the *mixed* fleet the sign can flip: Navigator concentrates
work (and cache) on the fast A10, so losing that one worker costs it
more than hash's spread placement — see EXPERIMENTS.md §Churn.

A second sweep replaces crashes with seeded network *partitions* on the
2-rack fleets (cut the spine for ``outage_s``, heal, repeat): workers
stay up, but cross-cut inputs dead-letter and cross-cut leases expire.
Reported per cell: P99 JCT vs the uncut baseline, re-execution overhead,
and cross-rack transfer counts (rack-aware Navigator rides cuts out
locally; hash keeps shipping into them).

``REPRO_BENCH_SMOKE=1`` shrinks the sweep to a CI-sized smoke run.
"""

from __future__ import annotations

import os
from typing import List, Tuple

from benchmarks.common import save_json
from repro.core import (
    GossipConfig,
    LeaseConfig,
    ProfileRepository,
    fleet,
)
from repro.sim import (
    Simulation,
    churn_schedule,
    fleet_scaled_rate,
    partition_schedule,
    poisson_workload,
)
from repro.workflows import MODELS, paper_dfgs

SMOKE = os.environ.get("REPRO_BENCH_SMOKE") == "1"

DURATION_S = 120.0 if SMOKE else 400.0
BASE_RATE = 1.6
SEEDS = (3,) if SMOKE else (3, 7, 11)
CHURN_SEEDS = (17, 23) if SMOKE else (17, 23, 29)
FLEETS = ["uniform"] if SMOKE else ["uniform", "mixed"]
POLICIES = ["navigator", "hash"] if SMOKE else ["navigator", "hash", "heft"]
MTBFS = [120.0] if SMOKE else [240.0, 120.0, 60.0]
REPAIR_S = 20.0

# Partition cells: seeded spine cuts (workers stay up, the network
# splits) on the rack fleets, vs the same policy's uncut baseline.
PARTITION_FLEETS = ["rack2"] if SMOKE else ["rack2", "rack2_mixed"]
PARTITION_MTBPS = [60.0] if SMOKE else [120.0, 60.0]
PARTITION_OUTAGE_S = 6.0  # past dead_after_s: leases expire across the cut


def _one(cluster, profiles, policy, jobs, schedule):
    sim = Simulation(
        cluster,
        profiles,
        MODELS,
        scheduler=policy,
        gossip=GossipConfig(period_s=0.2, fanout=2),
        lease=LeaseConfig(),
        churn=schedule,
        seed=1,
    )
    return sim.run(jobs)


def run() -> List[Tuple[str, float, float]]:
    rows: List[Tuple[str, float, float]] = []
    out = {}
    dfgs = paper_dfgs()
    for fleet_name in FLEETS:
        cluster = fleet(fleet_name)
        profiles = ProfileRepository(cluster, MODELS)
        for d in dfgs:
            profiles.register(d)
        rate = fleet_scaled_rate(cluster, BASE_RATE)
        workloads = {
            seed: poisson_workload(dfgs, rate, DURATION_S, seed=seed)
            for seed in SEEDS
        }
        for policy in POLICIES:
            static_p99 = {
                seed: _one(
                    cluster, profiles, policy, workloads[seed], []
                ).percentile_latency(0.99)
                for seed in SEEDS
            }
            for mtbf in MTBFS:
                deltas, p99s, overheads, wasted = [], [], [], []
                for seed in SEEDS:
                    for cseed in CHURN_SEEDS:
                        schedule = churn_schedule(
                            cluster.n_workers,
                            DURATION_S,
                            mtbf_s=mtbf,
                            repair_s=REPAIR_S,
                            seed=cseed,
                            drain_fraction=0.25,
                            min_live=3,
                        )
                        res = _one(
                            cluster, profiles, policy, workloads[seed],
                            schedule,
                        )
                        p99 = res.percentile_latency(0.99)
                        p99s.append(p99)
                        deltas.append(p99 - static_p99[seed])
                        n_tasks = sum(
                            len(j.dfg.tasks) for j in workloads[seed]
                        )
                        overheads.append(
                            (res.tasks_rescued + res.outputs_recovered)
                            / max(1, n_tasks)
                        )
                        wasted.append(res.churn_wasted_bytes)
                n = len(deltas)
                key = f"{fleet_name}/mtbf{int(mtbf)}/{policy}"
                stats = {
                    "p99_jct_churn_s": sum(p99s) / n,
                    "p99_jct_static_s": sum(static_p99.values())
                    / len(static_p99),
                    "p99_jct_degradation_s": sum(deltas) / n,
                    "reexec_overhead": sum(overheads) / n,
                    "churn_wasted_mb": sum(wasted) / n / 2**20,
                }
                out[key] = stats
                rows.append(
                    (f"churn/{key}/p99_jct_churn_s", 0.0,
                     stats["p99_jct_churn_s"])
                )
                rows.append(
                    (f"churn/{key}/p99_jct_degradation_s", 0.0,
                     stats["p99_jct_degradation_s"])
                )
                rows.append(
                    (f"churn/{key}/reexec_overhead", 0.0,
                     stats["reexec_overhead"])
                )
    # -- partition cells ----------------------------------------------------
    for fleet_name in PARTITION_FLEETS:
        cluster = fleet(fleet_name)
        profiles = ProfileRepository(cluster, MODELS)
        for d in dfgs:
            profiles.register(d)
        rate = fleet_scaled_rate(cluster, BASE_RATE)
        workloads = {
            seed: poisson_workload(dfgs, rate, DURATION_S, seed=seed)
            for seed in SEEDS
        }
        for policy in POLICIES:
            static_p99 = {
                seed: _one(
                    cluster, profiles, policy, workloads[seed], []
                ).percentile_latency(0.99)
                for seed in SEEDS
            }
            for mtbp in PARTITION_MTBPS:
                deltas, p99s, overheads, xracks = [], [], [], []
                for seed in SEEDS:
                    for cseed in CHURN_SEEDS:
                        schedule = partition_schedule(
                            cluster.n_workers,
                            DURATION_S,
                            mtbp_s=mtbp,
                            outage_s=PARTITION_OUTAGE_S,
                            seed=cseed,
                        )
                        res = _one(
                            cluster, profiles, policy, workloads[seed],
                            schedule,
                        )
                        p99 = res.percentile_latency(0.99)
                        p99s.append(p99)
                        deltas.append(p99 - static_p99[seed])
                        n_tasks = sum(
                            len(j.dfg.tasks) for j in workloads[seed]
                        )
                        overheads.append(
                            (res.tasks_rescued + res.outputs_recovered)
                            / max(1, n_tasks)
                        )
                        xracks.append(res.net_cross_transfers)
                n = len(deltas)
                key = f"partition/{fleet_name}/mtbp{int(mtbp)}/{policy}"
                stats = {
                    "p99_jct_partition_s": sum(p99s) / n,
                    "p99_jct_static_s": sum(static_p99.values())
                    / len(static_p99),
                    "p99_jct_degradation_s": sum(deltas) / n,
                    "reexec_overhead": sum(overheads) / n,
                    "cross_rack_transfers": sum(xracks) / n,
                }
                out[key] = stats
                for metric in (
                    "p99_jct_partition_s",
                    "p99_jct_degradation_s",
                    "reexec_overhead",
                ):
                    rows.append(
                        (f"churn/{key}/{metric}", 0.0, stats[metric])
                    )

    save_json("churn", out)
    return rows


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived:.4f}")

"""Worker-churn sweep: MTBF × policy × fleet (fault-tolerance plane).

For each policy the same seeded workloads run on a static fleet and under
seeded churn schedules (crash/drain with repair, disruption budget
``min_live=3``), with the membership lease enabled.  Reported per cell:

* ``p99_jct_churn_s``       — absolute P99 JCT under churn
* ``p99_jct_degradation_s`` — P99 JCT increase over the same policy's
  static-fleet baseline (seconds), averaged over workload × churn seeds
* ``reexec_overhead``       — re-executed/rescued task attempts per
  completed task (the fault-tolerance tax)
* ``churn_wasted_mb``       — PCIe bytes thrown away by churn

The headline comparison (acceptance): on the paper's uniform 5-worker
fleet at high load and MTBF=120 s, membership-aware Navigator degrades
strictly less than the blind hash baseline — hash keeps shipping tasks at
corpses for the whole repair window (each paying the dead-letter
timeout), while Navigator routes around them one lease window after the
crash.  On the *mixed* fleet the sign can flip: Navigator concentrates
work (and cache) on the fast A10, so losing that one worker costs it
more than hash's spread placement — see EXPERIMENTS.md §Churn.

``REPRO_BENCH_SMOKE=1`` shrinks the sweep to a CI-sized smoke run.
"""

from __future__ import annotations

import os
from typing import List, Tuple

from benchmarks.common import save_json
from repro.core import (
    GossipConfig,
    LeaseConfig,
    ProfileRepository,
    fleet,
)
from repro.sim import (
    Simulation,
    churn_schedule,
    fleet_scaled_rate,
    poisson_workload,
)
from repro.workflows import MODELS, paper_dfgs

SMOKE = os.environ.get("REPRO_BENCH_SMOKE") == "1"

DURATION_S = 120.0 if SMOKE else 400.0
BASE_RATE = 1.6
SEEDS = (3,) if SMOKE else (3, 7, 11)
CHURN_SEEDS = (17, 23) if SMOKE else (17, 23, 29)
FLEETS = ["uniform"] if SMOKE else ["uniform", "mixed"]
POLICIES = ["navigator", "hash"] if SMOKE else ["navigator", "hash", "heft"]
MTBFS = [120.0] if SMOKE else [240.0, 120.0, 60.0]
REPAIR_S = 20.0


def _one(cluster, profiles, policy, jobs, schedule):
    sim = Simulation(
        cluster,
        profiles,
        MODELS,
        scheduler=policy,
        gossip=GossipConfig(period_s=0.2, fanout=2),
        lease=LeaseConfig(),
        churn=schedule,
        seed=1,
    )
    return sim.run(jobs)


def run() -> List[Tuple[str, float, float]]:
    rows: List[Tuple[str, float, float]] = []
    out = {}
    dfgs = paper_dfgs()
    for fleet_name in FLEETS:
        cluster = fleet(fleet_name)
        profiles = ProfileRepository(cluster, MODELS)
        for d in dfgs:
            profiles.register(d)
        rate = fleet_scaled_rate(cluster, BASE_RATE)
        workloads = {
            seed: poisson_workload(dfgs, rate, DURATION_S, seed=seed)
            for seed in SEEDS
        }
        for policy in POLICIES:
            static_p99 = {
                seed: _one(
                    cluster, profiles, policy, workloads[seed], []
                ).percentile_latency(0.99)
                for seed in SEEDS
            }
            for mtbf in MTBFS:
                deltas, p99s, overheads, wasted = [], [], [], []
                for seed in SEEDS:
                    for cseed in CHURN_SEEDS:
                        schedule = churn_schedule(
                            cluster.n_workers,
                            DURATION_S,
                            mtbf_s=mtbf,
                            repair_s=REPAIR_S,
                            seed=cseed,
                            drain_fraction=0.25,
                            min_live=3,
                        )
                        res = _one(
                            cluster, profiles, policy, workloads[seed],
                            schedule,
                        )
                        p99 = res.percentile_latency(0.99)
                        p99s.append(p99)
                        deltas.append(p99 - static_p99[seed])
                        n_tasks = sum(
                            len(j.dfg.tasks) for j in workloads[seed]
                        )
                        overheads.append(
                            (res.tasks_rescued + res.outputs_recovered)
                            / max(1, n_tasks)
                        )
                        wasted.append(res.churn_wasted_bytes)
                n = len(deltas)
                key = f"{fleet_name}/mtbf{int(mtbf)}/{policy}"
                stats = {
                    "p99_jct_churn_s": sum(p99s) / n,
                    "p99_jct_static_s": sum(static_p99.values())
                    / len(static_p99),
                    "p99_jct_degradation_s": sum(deltas) / n,
                    "reexec_overhead": sum(overheads) / n,
                    "churn_wasted_mb": sum(wasted) / n / 2**20,
                }
                out[key] = stats
                rows.append(
                    (f"churn/{key}/p99_jct_churn_s", 0.0,
                     stats["p99_jct_churn_s"])
                )
                rows.append(
                    (f"churn/{key}/p99_jct_degradation_s", 0.0,
                     stats["p99_jct_degradation_s"])
                )
                rows.append(
                    (f"churn/{key}/reexec_overhead", 0.0,
                     stats["reexec_overhead"])
                )
    save_json("churn", out)
    return rows


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived:.4f}")

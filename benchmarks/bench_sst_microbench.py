"""Microbenchmarks guarding the metadata-plane and planner hot paths.

1. **Gossip diff cost** — a gossip round with ``k`` dirty rows must do
   O(k) work, independent of the table size (version-vector diffs via the
   per-peer log cursor, never a full-table copy).  We run a 1024-worker
   plane and time one exchange at increasing dirty-row counts: µs/row
   should stay roughly flat and the quiescent round should cost ~nothing.

2. **Planner placement cost** — Navigator's Alg. 1 inner loop is
   O(workers) per task; per-task cost at the paper's 5-worker scale must
   stay well under the millisecond-scale scheduling budget and grow
   linearly (not worse) with the fleet size.

    PYTHONPATH=src python -m benchmarks.bench_sst_microbench
"""

from __future__ import annotations

import time
from typing import List, Tuple

from benchmarks.common import save_json
from repro.core import (
    ClusterSpec,
    GossipConfig,
    GossipPlane,
    Job,
    NavigatorScheduler,
    ProfileRepository,
    SharedStateTable,
)
from repro.core.state import SSTRow
from repro.workflows import MODELS, paper_dfgs, translation_dfg

N_WORKERS = 1024
DIRTY_COUNTS = [4, 32, 256, 512]
PLANNER_FLEETS = [5, 16, 64]


def _time_exchange(k: int, iters: int = 200) -> float:
    """Mean seconds per exchange with exactly ``k`` dirty rows at the
    sender (worker 0), on a ``N_WORKERS``-row table.  ``mark_synced``
    resets the per-peer cursors between iterations so each timed round
    ships exactly the k freshly-dirtied rows — the quantity the O(k)
    acceptance criterion is about — rather than an accumulating backlog
    to whichever random peer is picked."""
    plane = GossipPlane(N_WORKERS, GossipConfig(fanout=1, seed=3))
    version = 1
    total = 0.0
    for it in range(iters):
        plane.mark_synced(0)
        # Dirty exactly k foreign rows at worker 0 (as if learned via
        # gossip), so the next round must diff-ship exactly k rows.
        updates = [
            (owner, version, SSTRow(ft_estimate_s=float(it)))
            for owner in range(1, k + 1)
        ]
        plane.deliver(0, updates, now=float(it))
        version += 1
        t0 = time.perf_counter()
        msgs = plane.exchange(0, float(it))
        total += time.perf_counter() - t0
        sent = sum(len(u) for _, u, _ in msgs)
        assert sent == k, f"diff should ship exactly {k} rows, sent {sent}"
    return total / iters


def _time_quiescent(iters: int = 200) -> float:
    plane = GossipPlane(N_WORKERS, GossipConfig(fanout=1, seed=3))
    t0 = time.perf_counter()
    for it in range(iters):
        plane.exchange(0, float(it))
    return (time.perf_counter() - t0) / iters


def _time_planner(n_workers: int, iters: int = 50) -> Tuple[float, int]:
    cluster = ClusterSpec(n_workers=n_workers)
    profiles = ProfileRepository(cluster, MODELS)
    for d in paper_dfgs():
        profiles.register(d)
    sched = NavigatorScheduler(profiles)
    sst = SharedStateTable(n_workers)
    for w in range(n_workers):
        sst.update_cache(w, 0, cluster.gpu_capacity(w))
        sst.push(w, 0.0)
    dfg = translation_dfg()
    view = sst.view(0)
    job = Job(0, dfg, arrival_time=0.0)
    sched.plan(job, 0.0, 0, view)  # warm rank cache
    t0 = time.perf_counter()
    for _ in range(iters):
        sched.plan(job, 0.0, 0, view)
    return (time.perf_counter() - t0) / iters, len(dfg.tasks)


def run() -> List[Tuple[str, float, float]]:
    rows: List[Tuple[str, float, float]] = []
    payload = {}

    q_us = _time_quiescent() * 1e6
    rows.append((f"sst/gossip_quiescent_n{N_WORKERS}", q_us, 0.0))
    payload["quiescent_us"] = q_us
    for k in DIRTY_COUNTS:
        us = _time_exchange(k) * 1e6
        rows.append((f"sst/gossip_exchange_k{k}_n{N_WORKERS}", us, us / k))
        payload[f"exchange_k{k}_us"] = us
        payload[f"exchange_k{k}_us_per_row"] = us / k

    # O(k) check: µs/row at k=512 must not blow up vs k=4 (full-table-copy
    # behaviour would make small-k rounds pay the 512-row cost).
    small = payload["exchange_k4_us"] / 4
    large = payload["exchange_k512_us"] / 512
    payload["us_per_row_ratio_large_over_small"] = large / small

    for n in PLANNER_FLEETS:
        per_plan, n_tasks = _time_planner(n)
        us_task = per_plan * 1e6 / n_tasks
        rows.append((f"planner/place_task_w{n}", us_task, 0.0))
        payload[f"planner_us_per_task_w{n}"] = us_task

    save_json("sst_microbench", payload)
    return rows


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived:.4f}")

"""Predictive prefetch plane sweep (core/prefetch.py).

Prefetch policy × lookahead depth × load × fleet on the bursty
production-trace workload (the regime where plan-driven staging pays:
bursts enqueue tasks whose models are not yet resident, and reactive
fetching serializes behind upstream compute).  Reports P50/P99 JCT,
demand hit rate, and wasted-prefetch bytes (aborted + evicted-unused +
end-of-run resident-unused).

``REPRO_BENCH_SMOKE=1`` shrinks the sweep to a CI-sized smoke run.
"""

from __future__ import annotations

import os
from typing import List, Tuple

from benchmarks.common import save_json
from repro.core import (
    NavigatorConfig,
    PrefetchConfig,
    ProfileRepository,
    fleet,
)
from repro.sim import Simulation, bursty_trace_workload, fleet_scaled_rate
from repro.workflows import MODELS, paper_dfgs

SMOKE = os.environ.get("REPRO_BENCH_SMOKE") == "1"

DURATION_S = 90.0 if SMOKE else 600.0
SEEDS = (3,) if SMOKE else (3, 7, 11)
FLEETS = ["uniform"] if SMOKE else ["uniform", "mixed"]
BASE_RATES = [0.8] if SMOKE else [0.8, 1.2]
DEPTHS = [4] if SMOKE else [2, 4, 8]

#: policy name -> (PrefetchConfig | None, NavigatorConfig)
def _policies():
    out = {"off": (None, NavigatorConfig())}
    for depth in DEPTHS:
        out[f"on_d{depth}"] = (
            PrefetchConfig(lookahead_depth=depth),
            NavigatorConfig(),
        )
    if not SMOKE:
        # Ablations: fill-free-memory-only speculation, and intents
        # advertised but never discounted by the planner.
        out["on_noevict"] = (
            PrefetchConfig(evict_for_prefetch=False),
            NavigatorConfig(),
        )
        out["on_conf0"] = (
            PrefetchConfig(),
            NavigatorConfig(intent_confidence=0.0),
        )
    return out


def run() -> List[Tuple[str, float, float]]:
    rows: List[Tuple[str, float, float]] = []
    out = {}
    dfgs = paper_dfgs()
    for fleet_name in FLEETS:
        cluster = fleet(fleet_name)
        profiles = ProfileRepository(cluster, MODELS)
        for d in dfgs:
            profiles.register(d)
        for base_rate in BASE_RATES:
            rate = fleet_scaled_rate(cluster, base_rate)
            for policy, (pf, nc) in _policies().items():
                p50s, p99s, hits, wasted = [], [], [], []
                for seed in SEEDS:
                    jobs = bursty_trace_workload(
                        dfgs, rate, DURATION_S, seed=seed
                    )
                    res = Simulation(
                        cluster,
                        profiles,
                        MODELS,
                        scheduler="navigator",
                        navigator_config=nc,
                        prefetch=pf,
                        seed=1,
                    ).run(jobs)
                    p50s.append(res.percentile_latency(0.5))
                    p99s.append(res.percentile_latency(0.99))
                    hits.append(res.cache_hit_rate)
                    wasted.append(
                        res.prefetch_wasted_bytes
                        + res.prefetch_unused_resident_bytes
                    )
                key = f"{fleet_name}/load{base_rate}/{policy}"
                n = len(SEEDS)
                stats = {
                    "p50_jct_s": sum(p50s) / n,
                    "p99_jct_s": sum(p99s) / n,
                    "hit_rate": sum(hits) / n,
                    "wasted_prefetch_mb": sum(wasted) / n / 2**20,
                }
                out[key] = stats
                rows.append((f"prefetch/{key}/p50_jct_s", 0.0,
                             stats["p50_jct_s"]))
                rows.append((f"prefetch/{key}/p99_jct_s", 0.0,
                             stats["p99_jct_s"]))
                rows.append((f"prefetch/{key}/hit_rate", 0.0,
                             stats["hit_rate"]))
                rows.append((f"prefetch/{key}/wasted_prefetch_mb", 0.0,
                             stats["wasted_prefetch_mb"]))
    save_json("prefetch", out)
    return rows


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived:.4f}")

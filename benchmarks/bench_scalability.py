"""Fig. 10: large-scale simulation — 40 req/s Poisson over up to 250
workers; Navigator should reach its lower-bound slowdown with roughly
half the workers Hash needs, leaving the rest idle."""

from __future__ import annotations

from typing import List, Tuple

from benchmarks.common import save_json
from repro.core import ClusterSpec, ProfileRepository
from repro.sim import Simulation, poisson_workload
from repro.workflows import MODELS, paper_dfgs

WORKER_COUNTS = [25, 50, 75, 100, 150, 250]


def run() -> List[Tuple[str, float, float]]:
    rows = []
    out = {}
    dfgs = paper_dfgs()
    for n in WORKER_COUNTS:
        cluster = ClusterSpec(n_workers=n)
        out[n] = {}
        for sched in ["navigator", "hash"]:
            profiles = ProfileRepository(cluster, MODELS)
            for d in dfgs:
                profiles.register(d)
            jobs = poisson_workload(dfgs, 40.0, 120.0, seed=5)
            res = Simulation(
                cluster, profiles, MODELS, scheduler=sched, seed=1
            ).run(jobs)
            out[n][sched] = {
                "median_slowdown": res.median_slowdown,
                "workers_used": len(res.workers_used),
                "hit": res.cache_hit_rate,
            }
            rows.append(
                (f"scale/{sched}/w{n}_median_slowdown", 0.0,
                 res.median_slowdown)
            )
            rows.append(
                (f"scale/{sched}/w{n}_workers_used", 0.0,
                 float(len(res.workers_used)))
            )
    save_json("scalability", out)
    return rows


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived:.4f}")

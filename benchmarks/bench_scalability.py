"""Fleet-scale replay sweep (Fig. 10 + indexed-engine scaling).

Two questions, one suite:

* **Fig. 10** — 40 req/s Poisson over large fleets; Navigator should
  reach its lower-bound slowdown with roughly half the workers Hash
  needs, leaving the rest idle.
* **Engine scaling** — the indexed event core's per-event cost must stay
  flat as the fleet grows (the acceptance bar: within 2× from 50 to 500
  workers).  Every sweep point replays the *same* binary trace file
  (``repro.sim.tracefile``), so per-event costs are comparable across
  fleet sizes; the trace is synthesized once per length and cached in
  the results directory.

Rows: ``scale/<sched>/w<N>_t<tasks>/per_event_us`` (wall-µs per event of
the hot loop alone — schedule/assemble excluded), ``.../per_task_us``,
``.../median_slowdown``, ``.../workers_used``, plus one
``scale/flatness/t<tasks>/per_event_ratio`` cell per trace length
(cost at the largest fleet ÷ cost at the smallest — the ≤ 2.0 bar).

``--profile`` delegates to ``tools/profile_engine.py`` for a cProfile
hotspot report of one replay instead of the sweep.

Smoke mode (``REPRO_BENCH_SMOKE=1``) shrinks the grid to 50/100 workers
× 10k/50k tasks — the CI scalability-smoke job stamps the 100-worker /
50k-task per-event cell into ``BENCH_trajectory.json`` and
``tools/bench_regression.py`` gates it.
"""

from __future__ import annotations

import os
import time
from typing import Dict, List, Tuple

from benchmarks.common import RESULTS_DIR, save_json
from repro.core import ClusterSpec, ProfileRepository
from repro.sim import Simulation
from repro.sim.tracefile import load_jobs, synthesize_poisson_trace
from repro.workflows import MODELS, paper_dfgs

SMOKE = os.environ.get("REPRO_BENCH_SMOKE") == "1"

RATE_PER_S = 40.0          # Fig. 10 offered load
TRACE_SEED = 5

if SMOKE:
    FLEETS = [50, 100]
    LENGTHS = [10_000, 50_000]
    HEADLINE: List[Tuple[int, int]] = []
else:
    FLEETS = [50, 100, 250, 500]
    LENGTHS = [20_000, 100_000]
    # The acceptance point: a 500-worker fleet over a 1M-task open-loop
    # trace, in minutes.
    HEADLINE = [(500, 1_000_000)]


def _trace_path(n_tasks: int) -> str:
    """Synthesize (once) and cache the open-loop trace of ``n_tasks``."""
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(
        RESULTS_DIR,
        f"scal_r{RATE_PER_S:g}_t{n_tasks}_s{TRACE_SEED}.ctrc",
    )
    if not os.path.exists(path):
        synthesize_poisson_trace(
            path, paper_dfgs(), RATE_PER_S, n_tasks, seed=TRACE_SEED
        )
    return path


def _replay(n_workers: int, jobs, scheduler: str) -> Dict[str, float]:
    """One timed replay; stage-split so the hot loop is timed alone."""
    cluster = ClusterSpec(n_workers=n_workers)
    profiles = ProfileRepository(cluster, MODELS)
    for d in paper_dfgs():
        profiles.register(d)
    sim = Simulation(cluster, profiles, MODELS, scheduler=scheduler, seed=1)
    sim._schedule_initial(jobs)
    t0 = time.perf_counter()
    sim._event_loop()
    loop_s = time.perf_counter() - t0
    res = sim._assemble_result()
    n_tasks = sum(len(j.dfg.tasks) for j in jobs)
    return {
        "events": float(sim._events),
        "loop_s": loop_s,
        "per_event_us": loop_s / sim._events * 1e6,
        "per_task_us": loop_s / n_tasks * 1e6,
        "median_slowdown": res.median_slowdown,
        "workers_used": float(len(res.workers_used)),
        "hit": res.cache_hit_rate,
    }


def run() -> List[Tuple[str, float, float]]:
    rows: List[Tuple[str, float, float]] = []
    out: Dict[str, Dict] = {}
    grid = [(n, t) for t in LENGTHS for n in FLEETS] + HEADLINE
    jobs_cache: Dict[int, list] = {}
    per_event: Dict[Tuple[str, int, int], float] = {}
    for n, n_tasks in grid:
        if n_tasks not in jobs_cache:
            cat = {d.name: d for d in paper_dfgs()}
            jobs_cache[n_tasks] = load_jobs(_trace_path(n_tasks), cat)
        jobs = jobs_cache[n_tasks]
        for sched in ["navigator", "hash"]:
            if sched == "hash" and (n_tasks != LENGTHS[0] or (n, n_tasks) in HEADLINE):
                continue  # Fig. 10 comparison rides the shortest trace only
            m = _replay(n, jobs, sched)
            key = f"{sched}/w{n}_t{n_tasks}"
            out[key] = m
            per_event[(sched, n, n_tasks)] = m["per_event_us"]
            rows.append((f"scale/{key}/per_event_us",
                         m["per_event_us"], m["per_event_us"]))
            rows.append((f"scale/{key}/per_task_us",
                         m["per_task_us"], m["per_task_us"]))
            rows.append((f"scale/{key}/median_slowdown",
                         0.0, m["median_slowdown"]))
            rows.append((f"scale/{key}/workers_used",
                         0.0, m["workers_used"]))
    # Flatness bar: per-event cost growth across the fleet sweep.
    for n_tasks in LENGTHS:
        lo = per_event[("navigator", FLEETS[0], n_tasks)]
        hi = per_event[("navigator", FLEETS[-1], n_tasks)]
        ratio = hi / lo if lo > 0 else float("inf")
        out[f"flatness/t{n_tasks}"] = {"per_event_ratio": ratio}
        rows.append((f"scale/flatness/t{n_tasks}/per_event_ratio",
                     0.0, ratio))
    save_json("scalability", out)
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--profile", action="store_true",
                    help="cProfile one replay instead of the sweep "
                         "(tools/profile_engine.py)")
    ap.add_argument("--workers", type=int, default=100)
    ap.add_argument("--tasks", type=int, default=50_000)
    ap.add_argument("--scheduler", default="navigator")
    ap.add_argument("--engine", default="indexed",
                    choices=["indexed", "reference"])
    ap.add_argument("--top", type=int, default=25)
    args = ap.parse_args()
    if args.profile:
        from tools.profile_engine import profile_replay

        profile_replay(
            n_workers=args.workers, n_tasks=args.tasks,
            scheduler=args.scheduler, engine=args.engine, top=args.top,
        )
    else:
        for name, us, derived in run():
            print(f"{name},{us:.1f},{derived:.4f}")

"""Fig. 7: ablation analysis — disable each Navigator feature and measure
the degradation at low and high request rates."""

from __future__ import annotations

from typing import List, Tuple

from benchmarks.common import mean_over_seeds, run_sim, save_json
from repro.core import NavigatorConfig

VARIANTS = {
    "full": dict(),
    "no_dynamic_adjustment": dict(
        navigator_config=NavigatorConfig(use_dynamic_adjustment=False)
    ),
    "no_model_locality": dict(
        navigator_config=NavigatorConfig(use_model_locality=False)
    ),
    "fifo_eviction": dict(eviction_policy="fifo"),
}


def run() -> List[Tuple[str, float, float]]:
    rows = []
    out = {}
    for rate in [0.5, 2.0]:
        out[rate] = {}
        for name, kw in VARIANTS.items():
            agg = mean_over_seeds(
                lambda s: _metrics(rate, s, kw)
            )
            out[rate][name] = agg
            rows.append((f"ablation/{name}/rate{rate}_slowdown", 0.0,
                         agg["slow"]))
            rows.append((f"ablation/{name}/rate{rate}_hit", 0.0, agg["hit"]))
    save_json("ablation", out)
    return rows


def _metrics(rate, seed, kw):
    res = run_sim("navigator", rate=rate, seed=seed, duration=250.0, **kw)
    return {
        "slow": res.mean_slowdown,
        "hit": res.cache_hit_rate,
        "evictions": float(res.cache_evictions),
    }


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived:.4f}")

"""Staleness sensitivity of the decentralized metadata plane.

Two sweeps:

* **Gossip sweep** (the default ``run()``): gossip period × offered load
  × fleet heterogeneity, per scheduler, on the decentralized per-worker
  SST views (``core/sst_exchange.GossipPlane``).  Each worker plans from
  its own replica, so growing the gossip period genuinely widens the gap
  between a scheduler's view and reality — the regime that separates
  Compass from centralized baselines.  Reports P50/P99 JCT and mean
  slowdown; the acceptance bar is *graceful* degradation of Navigator JCT
  as the period grows (no cliff).

* **Fig. 8 push-interval grid** (``run_push_interval_grid()``): the
  paper's load-staleness × cache-staleness grid on the single-published-
  snapshot ``SharedStateTable``.  Included in the default ``run()``;
  ``--legacy`` runs it alone.

    PYTHONPATH=src python -m benchmarks.bench_staleness [--legacy]
"""

from __future__ import annotations

import sys
from typing import List, Tuple

from benchmarks.common import mean_over_seeds, run_sim, save_json
from repro.core import GossipConfig, NavigatorConfig, fleet

# Gossip sweep axes.
PERIODS = [0.05, 0.2, 1.0, 4.0]        # seconds between gossip rounds
RATES = [1.0, 2.0]                     # offered load (req/s at speed-1.0 fleet)
# Worker heterogeneity presets plus the 2-rack oversubscribed topology
# (staleness and the spine premium interact: a stale view ships across
# racks it would have avoided with fresh state).
FLEET_NAMES = ["uniform", "mixed", "rack2"]
# (label, scheduler, navigator_config): the +margin variant turns on the
# staleness-aware Alg. 2 hysteresis so the sweep measures whether it
# helps where it is meant to — at long gossip periods.
SCHEDULER_VARIANTS = [
    ("navigator", "navigator", None),
    (
        "navigator+margin",
        "navigator",
        NavigatorConfig(staleness_margin_per_s=0.05),
    ),
    ("jit", "jit", None),
]
FANOUT = 2
DURATION = 150.0

# Legacy Fig. 8 grid.
LOAD_DELAYS = [0.1, 0.2, 0.5, 1.0]     # seconds between load pushes
CACHE_DELAYS = [0.1, 0.5, 1.0, 2.0]    # seconds between cache pushes


def run() -> List[Tuple[str, float, float]]:
    rows: List[Tuple[str, float, float]] = []
    grid = {}
    for fleet_name in FLEET_NAMES:
        for rate in RATES:
            for label, sched, nav_cfg in SCHEDULER_VARIANTS:
                for period in PERIODS:

                    def cell(seed):
                        res = run_sim(
                            sched,
                            rate=rate,
                            seed=seed,
                            duration=DURATION,
                            navigator_config=nav_cfg,
                            cluster=fleet(fleet_name),
                            scale_rate_to_fleet=True,
                            gossip=GossipConfig(
                                period_s=period, fanout=FANOUT
                            ),
                        )
                        return {
                            "p50_jct_s": res.percentile_latency(0.5),
                            "p99_jct_s": res.percentile_latency(0.99),
                            "mean_slowdown": res.mean_slowdown,
                            "gossip_messages": float(res.sst_pushes),
                        }

                    # Tail percentiles from a single 150 s run are one or
                    # two jobs deep — average over seeds like the Fig. 8
                    # grid does.
                    key = f"{fleet_name}/rate{rate}/{label}/period{period}"
                    grid[key] = mean_over_seeds(cell)
                    rows.append(
                        (f"staleness/{key}/p50", 0.0, grid[key]["p50_jct_s"])
                    )
                    rows.append(
                        (f"staleness/{key}/p99", 0.0, grid[key]["p99_jct_s"])
                    )
    save_json("staleness_gossip", grid)
    # Keep the paper's Fig. 8 push-interval grid in the default suite.
    rows += run_push_interval_grid()
    return rows


def run_push_interval_grid() -> List[Tuple[str, float, float]]:
    """Fig. 8: SharedStateTable load-staleness × cache-staleness grid."""
    rows = []
    grid = {}
    for ld in LOAD_DELAYS:
        for cd in CACHE_DELAYS:
            slow = mean_over_seeds(
                lambda s: {
                    "slow": run_sim(
                        "navigator", rate=2.0, seed=s, duration=250.0,
                        push_interval_s=ld, cache_push_interval_s=cd,
                    ).mean_slowdown
                }
            )["slow"]
            grid[f"{ld}x{cd}"] = slow
            rows.append((f"staleness/load{ld}_cache{cd}", 0.0, slow))
    save_json("staleness", grid)
    return rows


if __name__ == "__main__":
    fn = run_push_interval_grid if "--legacy" in sys.argv[1:] else run
    for name, us, derived in fn():
        print(f"{name},{us:.1f},{derived:.4f}")

"""Fig. 8: sensitivity to SST dissemination rate — load-info staleness ×
cache-info staleness grid at high load."""

from __future__ import annotations

from typing import List, Tuple

from benchmarks.common import mean_over_seeds, run_sim, save_json

LOAD_DELAYS = [0.1, 0.2, 0.5, 1.0]     # seconds between load pushes
CACHE_DELAYS = [0.1, 0.5, 1.0, 2.0]    # seconds between cache pushes


def run() -> List[Tuple[str, float, float]]:
    rows = []
    grid = {}
    for ld in LOAD_DELAYS:
        for cd in CACHE_DELAYS:
            slow = mean_over_seeds(
                lambda s: {
                    "slow": run_sim(
                        "navigator", rate=2.0, seed=s, duration=250.0,
                        push_interval_s=ld, cache_push_interval_s=cd,
                    ).mean_slowdown
                }
            )["slow"]
            grid[f"{ld}x{cd}"] = slow
            rows.append((f"staleness/load{ld}_cache{cd}", 0.0, slow))
    save_json("staleness", grid)
    return rows


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived:.4f}")

"""Fig. 9: bursty production-trace replay (statistically matched trace;
see DESIGN.md §7) — completion times under unpredictable arrivals.

``REPRO_BENCH_SMOKE=1`` shrinks the horizon to a CI-sized smoke run."""

from __future__ import annotations

import os
from typing import List, Tuple

from benchmarks.common import save_json
from repro.core import ClusterSpec, ProfileRepository
from repro.sim import Simulation, bursty_trace_workload
from repro.workflows import MODELS, paper_dfgs

SCHEDULERS = ["navigator", "jit", "heft", "hash"]
SMOKE = os.environ.get("REPRO_BENCH_SMOKE") == "1"
DURATION_S = 60.0 if SMOKE else 600.0


def run() -> List[Tuple[str, float, float]]:
    rows = []
    out = {}
    cluster = ClusterSpec(n_workers=5)
    dfgs = paper_dfgs()
    jobs_template = bursty_trace_workload(
        dfgs, base_rate_per_s=0.8, duration_s=DURATION_S, seed=3
    )
    for sched in SCHEDULERS:
        profiles = ProfileRepository(cluster, MODELS)
        for d in dfgs:
            profiles.register(d)
        res = Simulation(
            cluster, profiles, MODELS, scheduler=sched, seed=1
        ).run(jobs_template)
        out[sched] = {
            "mean_latency": res.mean_latency,
            "p50_latency": res.percentile_latency(0.5),
            "p95_latency": res.percentile_latency(0.95),
            "p99_latency": res.percentile_latency(0.99),
            "hit": res.cache_hit_rate,
            "n": len(res.records),
        }
        rows.append((f"trace/{sched}/mean_latency_s", 0.0, res.mean_latency))
        rows.append((f"trace/{sched}/p50_latency_s", 0.0,
                     res.percentile_latency(0.5)))
        rows.append((f"trace/{sched}/p95_latency_s", 0.0,
                     res.percentile_latency(0.95)))
        rows.append((f"trace/{sched}/p99_latency_s", 0.0,
                     res.percentile_latency(0.99)))
        rows.append((f"trace/{sched}/hit_rate", 0.0, res.cache_hit_rate))
    save_json("trace", out)
    return rows


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived:.4f}")

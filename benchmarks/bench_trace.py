"""Fig. 9: bursty production-trace replay (statistically matched trace;
see DESIGN.md §7) — completion times under unpredictable arrivals.

``REPRO_BENCH_SMOKE=1`` shrinks the horizon to a CI-sized smoke run.

``--explain [TASK_ID]`` re-runs the paper-config replay with the flight
recorder on and prints, per job, the critical-path latency breakdown
(queue / input-transfer / model-fetch-wait / compute / output-ship, which
sum to the measured JCT) plus, for the chosen task, every placement
decision with its per-candidate Eq. 2 cost vector — "why worker 3 and
not worker 5", answered from the trace alone (see EXPERIMENTS.md
"Reading a trace").  ``--export DIR`` additionally writes the
deterministic JSONL and Chrome-trace/Perfetto JSON exports.

``--calibration`` joins the recorded Eq. 2 cost vectors against the
measured span breakdowns of the same replay and prints per-component
residual statistics (queue / input-transfer / model-fetch / runtime)
for the navigator and JIT schedulers — the cost-model calibration
report (see EXPERIMENTS.md "Calibrating the cost model")."""

from __future__ import annotations

import argparse
import os
import sys
from typing import List, Optional, Sequence, Tuple

from benchmarks.common import save_json
from repro.core import ClusterSpec, ProfileRepository, SimReport
from repro.sim import Simulation, bursty_trace_workload
from repro.workflows import MODELS, paper_dfgs

SCHEDULERS = ["navigator", "jit", "heft", "hash"]
SMOKE = os.environ.get("REPRO_BENCH_SMOKE") == "1"
DURATION_S = 60.0 if SMOKE else 600.0


def run() -> List[Tuple[str, float, float]]:
    rows = []
    out = {}
    cluster = ClusterSpec(n_workers=5)
    dfgs = paper_dfgs()
    jobs_template = bursty_trace_workload(
        dfgs, base_rate_per_s=0.8, duration_s=DURATION_S, seed=3
    )
    for sched in SCHEDULERS:
        profiles = ProfileRepository(cluster, MODELS)
        for d in dfgs:
            profiles.register(d)
        res = Simulation(
            cluster, profiles, MODELS, scheduler=sched, seed=1
        ).run(jobs_template)
        out[sched] = {
            "mean_latency": res.mean_latency,
            "p50_latency": res.percentile_latency(0.5),
            "p95_latency": res.percentile_latency(0.95),
            "p99_latency": res.percentile_latency(0.99),
            "hit": res.cache_hit_rate,
            "n": len(res.records),
        }
        rows.append((f"trace/{sched}/mean_latency_s", 0.0, res.mean_latency))
        rows.append((f"trace/{sched}/p50_latency_s", 0.0,
                     res.percentile_latency(0.5)))
        rows.append((f"trace/{sched}/p95_latency_s", 0.0,
                     res.percentile_latency(0.95)))
        rows.append((f"trace/{sched}/p99_latency_s", 0.0,
                     res.percentile_latency(0.99)))
        rows.append((f"trace/{sched}/hit_rate", 0.0, res.cache_hit_rate))
    save_json("trace", out)
    return rows


def _traced_run(scheduler: str, duration_s: float) -> SimReport:
    cluster = ClusterSpec(n_workers=5)
    dfgs = paper_dfgs()
    jobs = bursty_trace_workload(
        dfgs, base_rate_per_s=0.8, duration_s=duration_s, seed=3
    )
    profiles = ProfileRepository(cluster, MODELS)
    for d in dfgs:
        profiles.register(d)
    res = Simulation(
        cluster, profiles, MODELS, scheduler=scheduler, seed=1, trace=True
    ).run(jobs)
    return SimReport(res)


def explain(
    task_id: Optional[str],
    scheduler: str = "navigator",
    duration_s: float = 60.0,
    export_dir: Optional[str] = None,
    max_jobs: int = 10,
) -> None:
    report = _traced_run(scheduler, duration_s)
    res = report.result
    print(f"# {scheduler}: {len(res.records)} jobs over {duration_s:.0f}s "
          f"(5-worker paper config, seed 1)")
    agg = report.latency_breakdown()
    shares = agg.get("shares", {})
    print("# aggregate critical-path shares: "
          + "  ".join(f"{k.removesuffix('_s')}={v:.1%}"
                      for k, v in shares.items()))
    print(f"{'job':>5} {'jct_s':>9} {'queue':>8} {'in_xfer':>8} "
          f"{'fetch':>8} {'compute':>8} {'out_ship':>8}  critical path")
    for r in res.records[:max_jobs]:
        bd = report.latency_breakdown(r.job_id)
        assert abs(bd.components_sum_s - bd.jct_s) < 1e-6
        path = "→".join(t for t, _ in reversed(bd.critical_path))
        print(f"{bd.job_id:>5} {bd.jct_s:>9.4f} {bd.queue_s:>8.4f} "
              f"{bd.input_transfer_s:>8.4f} {bd.fetch_wait_s:>8.4f} "
              f"{bd.compute_s:>8.4f} {bd.output_ship_s:>8.4f}  {path}")
    if len(res.records) > max_jobs:
        print(f"  ... {len(res.records) - max_jobs} more jobs "
              f"(--jobs N to widen)")
    if task_id is not None:
        print()
        print(report.explain(task_id))
    if export_dir is not None:
        os.makedirs(export_dir, exist_ok=True)
        jsonl = os.path.join(export_dir, f"trace_{scheduler}.jsonl")
        chrome = os.path.join(export_dir, f"trace_{scheduler}.chrome.json")
        report.recorder.write(jsonl, chrome)
        print(f"# exported {jsonl} and {chrome}", file=sys.stderr)


def calibration(
    schedulers: Sequence[str] = ("navigator", "jit"),
    duration_s: float = 60.0,
) -> None:
    """Eq. 2 cost-model calibration on the standing trace benchmark:
    predicted cost components from placement provenance vs measured span
    breakdowns, per scheduler."""
    for sched in schedulers:
        report = _traced_run(sched, duration_s)
        cal = report.calibration()
        print(cal.format_table())
        worst = cal.worst_component()
        stats = cal.components[worst].as_dict()
        print(f"# worst-calibrated component for {sched}: {worst} "
              f"(mean |residual| {stats['residual_abs_mean_s']:.4f}s)")
        print()


def main(argv: Optional[List[str]] = None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--explain", nargs="?", const="", metavar="TASK_ID",
                    default=None,
                    help="print per-job latency breakdowns; with a TASK_ID, "
                         "also that task's placement provenance")
    ap.add_argument("--calibration", action="store_true",
                    help="print the Eq. 2 cost-model calibration report "
                         "(navigator + jit) and exit")
    ap.add_argument("--scheduler", default="navigator", choices=SCHEDULERS)
    ap.add_argument("--duration", type=float, default=60.0,
                    help="replay horizon for --explain (seconds)")
    ap.add_argument("--jobs", type=int, default=10,
                    help="how many per-job breakdown rows to print")
    ap.add_argument("--export", metavar="DIR", default=None,
                    help="write JSONL + Chrome-trace exports to DIR")
    args = ap.parse_args(argv)
    if args.calibration:
        calibration(duration_s=args.duration)
        return
    if args.explain is not None or args.export is not None:
        explain(args.explain or None, args.scheduler, args.duration,
                args.export, args.jobs)
        return
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived:.4f}")


if __name__ == "__main__":
    main()

"""Benchmark driver: one suite per paper table/figure plus kernel micro-
benches and the roofline summary.  Prints ``name,us_per_call,derived``
CSV; per-suite JSON artifacts land in results/bench/, and a top-level
``BENCH_trajectory.json`` (per-suite P50/P99 JCT + hit rate) is merged
after every run so the performance trajectory is tracked across PRs.

    PYTHONPATH=src python -m benchmarks.run [suite ...]
"""

from __future__ import annotations

import json
import os
import re
import subprocess
import sys
import time

# Version of the trajectory-file layout: bump when the shape of the
# per-suite payloads changes so downstream tooling can dispatch on it.
TRAJECTORY_SCHEMA_VERSION = 1


SUITES = [
    "schedulers",      # Fig. 6 + Table 1
    "ablation",        # Fig. 7
    "staleness",       # gossip period × load × fleet sweep (+ Fig. 8 grid)
    "trace",           # Fig. 9
    "prefetch",        # predictive prefetch plane sweep
    "churn",           # worker churn / fault-tolerance sweep
    "topology",        # rack topology / oversubscription sweep
    "scalability",     # Fig. 10 + indexed-engine fleet-scale replay
    "kernels",         # Pallas-kernel ref-path micro-benches
    "sst_microbench",  # gossip O(dirty-rows) + planner placement cost
]

TRAJECTORY_PATH = os.environ.get("REPRO_TRAJECTORY", "BENCH_trajectory.json")

# Smoke runs (REPRO_BENCH_SMOKE=1) use shrunken horizons, so their
# numbers live under distinct ``<suite>@smoke`` trajectory keys — a CI
# smoke run never overwrites (or gets diffed against) a full-horizon
# cell.  tools/bench_regression.py compares fresh smoke runs against
# the committed ``@smoke`` cells.
SMOKE = os.environ.get("REPRO_BENCH_SMOKE") == "1"


def suite_key(suite: str) -> str:
    """Trajectory cell name for a suite under the current run mode."""
    return f"{suite}@smoke" if SMOKE else suite

# Row-name fragments worth tracking across PRs (JCT percentiles, hit
# rates, and per-event replay costs, whatever the suite's exact naming
# scheme).
_TRACK = re.compile(
    r"(p50|p95|p99|median|mean)_?(jct|latency|slowdown)|hit|per_event",
    re.IGNORECASE,
)


def _summarize(rows):
    """Pick the trajectory-worthy metrics out of a suite's rows."""
    return {
        name: round(derived, 6)
        for name, _us, derived in rows
        if _TRACK.search(name)
    }


def _git_sha():
    """Short SHA of HEAD, or None outside a git checkout."""
    try:
        out = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, timeout=10,
        )
    except (OSError, subprocess.TimeoutExpired):
        return None
    sha = out.stdout.strip()
    return sha if out.returncode == 0 and sha else None


def _merge_trajectory(per_suite):
    """Update BENCH_trajectory.json in place, suite by suite: re-running
    a suite *replaces* its cell (idempotent merge), and partial runs
    never clobber other suites' history.  Each cell is stamped with the
    git SHA it was measured at."""
    traj = {}
    if os.path.exists(TRAJECTORY_PATH):
        try:
            with open(TRAJECTORY_PATH) as f:
                traj = json.load(f)
        except (OSError, ValueError):
            traj = {}
    traj["schema_version"] = TRAJECTORY_SCHEMA_VERSION
    sha = _git_sha()
    suites = traj.setdefault("suites", {})
    for suite, payload in per_suite.items():
        if sha is not None:
            payload = dict(payload, git_sha=sha)
        suites[suite] = payload
    with open(TRAJECTORY_PATH, "w") as f:
        json.dump(traj, f, indent=1, sort_keys=True)


def main() -> None:
    want = sys.argv[1:] or SUITES
    per_suite = {}
    print("name,us_per_call,derived")
    for suite in want:
        mod = __import__(f"benchmarks.bench_{suite}", fromlist=["run"])
        t0 = time.time()
        rows = mod.run()
        for name, us, derived in rows:
            print(f"{name},{us:.1f},{derived:.4f}", flush=True)
        elapsed = time.time() - t0
        print(f"# suite {suite} done in {elapsed:.1f}s",
              file=sys.stderr)
        summary = _summarize(rows)
        if summary:
            per_suite[suite_key(suite)] = {
                "metrics": summary,
                "wall_s": round(elapsed, 1),
            }
    if per_suite:
        _merge_trajectory(per_suite)
        print(f"# trajectory -> {TRAJECTORY_PATH}", file=sys.stderr)


if __name__ == "__main__":
    main()

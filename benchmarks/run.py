"""Benchmark driver: one suite per paper table/figure plus kernel micro-
benches and the roofline summary.  Prints ``name,us_per_call,derived``
CSV; per-suite JSON artifacts land in results/bench/.

    PYTHONPATH=src python -m benchmarks.run [suite ...]
"""

from __future__ import annotations

import sys
import time


SUITES = [
    "schedulers",      # Fig. 6 + Table 1
    "ablation",        # Fig. 7
    "staleness",       # gossip period × load × fleet sweep (+ Fig. 8 grid)
    "trace",           # Fig. 9
    "scalability",     # Fig. 10
    "kernels",         # Pallas-kernel ref-path micro-benches
    "sst_microbench",  # gossip O(dirty-rows) + planner placement cost
]


def main() -> None:
    want = sys.argv[1:] or SUITES
    print("name,us_per_call,derived")
    for suite in want:
        mod = __import__(f"benchmarks.bench_{suite}", fromlist=["run"])
        t0 = time.time()
        rows = mod.run()
        for name, us, derived in rows:
            print(f"{name},{us:.1f},{derived:.4f}", flush=True)
        print(f"# suite {suite} done in {time.time()-t0:.1f}s",
              file=sys.stderr)


if __name__ == "__main__":
    main()

"""Fig. 6 + Table 1: scheduler comparison under low/high load and a rate
sweep, with GPU-side metrics.

Emits CSV rows (name, us_per_call, derived):
  us_per_call = scheduler decision time per job (wall, measured)
  derived     = mean slowdown factor
"""

from __future__ import annotations

import statistics as st
import time
from typing import List, Tuple

from benchmarks.common import mean_over_seeds, run_sim, save_json
from repro.core import ClusterSpec, NavigatorScheduler, ProfileRepository
from repro.core.state import SharedStateTable
from repro.core.types import Job
from repro.workflows import MODELS, paper_dfgs

SCHEDULERS = ["navigator", "jit", "heft", "hash"]


def planning_time_us(scheduler: str) -> float:
    """Wall time of one planning decision (the 'consult the scheduler'
    overhead the decentralized design avoids centralizing)."""
    cluster = ClusterSpec(n_workers=5)
    profiles = ProfileRepository(cluster, MODELS)
    dfgs = paper_dfgs()
    for d in dfgs:
        profiles.register(d)
    from repro.core.scheduler import make_scheduler

    sched = make_scheduler(scheduler, profiles)
    sst = SharedStateTable(5)
    for w in range(5):
        sst.update_cache(w, 0, cluster.gpu_capacity_bytes)
        sst.push(w, 0.0)
    job = Job(0, dfgs[0], arrival_time=0.0)
    n = 200
    t0 = time.perf_counter()
    for i in range(n):
        if sched.plans_at_arrival:
            sched.plan(job, 0.0, i % 5, sst.view(i % 5))
        else:
            sched.select_worker_at_ready(
                job, "opt_ingest", 0.0, sst.view(0), {"": 0}, {"": 1e5}, 0
            )
    return (time.perf_counter() - t0) / n * 1e6


def run() -> List[Tuple[str, float, float]]:
    rows = []
    table1 = {}
    for sched in SCHEDULERS:
        lo = mean_over_seeds(
            lambda s: {
                "slow": run_sim(sched, rate=0.5, seed=s).mean_slowdown
            }
        )["slow"]
        agg = mean_over_seeds(
            lambda s: _high_load_metrics(sched, s)
        )
        us = planning_time_us(sched)
        table1[sched] = dict(agg, slow_low=lo, plan_us=us)
        rows.append((f"sched/{sched}/low_load_slowdown", us, lo))
        rows.append((f"sched/{sched}/high_load_slowdown", us, agg["slow"]))
        rows.append((f"sched/{sched}/latency_s", us, agg["lat"]))
        rows.append((f"sched/{sched}/cache_hit", us, agg["hit"]))

    # Fig. 6c: rate sweep
    sweep = {}
    for rate in [0.5, 1.0, 1.5, 2.0, 2.5]:
        sweep[rate] = {
            s: mean_over_seeds(
                lambda sd: {"slow": run_sim(s, rate=rate, seed=sd,
                                            duration=200.0).mean_slowdown}
            )["slow"]
            for s in SCHEDULERS
        }
        for s in SCHEDULERS:
            rows.append((f"sched/{s}/sweep_rate_{rate}", 0.0, sweep[rate][s]))
    save_json("schedulers", {"table1": table1, "rate_sweep": sweep})
    return rows


def _high_load_metrics(sched: str, seed: int):
    cluster = ClusterSpec(n_workers=5)
    res = run_sim(sched, rate=2.0, seed=seed)
    return {
        "slow": res.mean_slowdown,
        "lat": res.mean_latency,
        "hit": res.cache_hit_rate,
        "util": res.gpu_utilization,
        "energy_kj": res.energy_joules(cluster) / 1e3,
    }


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived:.4f}")

"""Shared helpers for the paper-experiment benchmarks."""

from __future__ import annotations

import json
import os
import time
from typing import Dict, List, Optional

from repro.core import ClusterSpec, NavigatorConfig, ProfileRepository
from repro.sim import SimResult, Simulation, fleet_scaled_rate, poisson_workload
from repro.workflows import MODELS, paper_dfgs

RESULTS_DIR = os.environ.get("REPRO_RESULTS", "results/bench")


def run_sim(
    scheduler: str,
    rate: float = 2.0,
    duration: float = 300.0,
    n_workers: int = 5,
    seed: int = 7,
    sim_seed: int = 1,
    navigator_config: Optional[NavigatorConfig] = None,
    cluster: Optional[ClusterSpec] = None,
    scale_rate_to_fleet: bool = False,
    **kw,
) -> SimResult:
    cluster = cluster or ClusterSpec(n_workers=n_workers)
    dfgs = paper_dfgs()
    profiles = ProfileRepository(cluster, MODELS)
    for d in dfgs:
        profiles.register(d)
    if scale_rate_to_fleet:
        rate = fleet_scaled_rate(cluster, rate)
    jobs = poisson_workload(dfgs, rate, duration, seed=seed)
    sim = Simulation(
        cluster, profiles, MODELS, scheduler=scheduler,
        navigator_config=navigator_config, seed=sim_seed, **kw,
    )
    return sim.run(jobs)


def mean_over_seeds(fn, seeds=(3, 7, 11)) -> Dict[str, float]:
    """Average the dict-of-scalars returned by ``fn(seed)``."""
    acc: Dict[str, float] = {}
    for s in seeds:
        out = fn(s)
        for k, v in out.items():
            acc[k] = acc.get(k, 0.0) + v / len(seeds)
    return acc


def save_json(name: str, payload) -> None:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, name + ".json"), "w") as f:
        json.dump(payload, f, indent=1)


def timed(fn, *args, repeat: int = 3, **kw):
    t0 = time.perf_counter()
    for _ in range(repeat):
        out = fn(*args, **kw)
    return out, (time.perf_counter() - t0) / repeat

"""Topology sweep: flat fleet vs oversubscribed racks, per policy.

The same seeded workloads run on an 8-worker flat fleet (all-pairs
100 Gbps table) and on the 2-rack presets whose spine uplinks are 4x
oversubscribed.  Reported per cell:

* ``p99_jct_s`` / ``mean_jct_s``  — JCT under the topology
* ``topology_tax_s``              — mean JCT increase over the same
  policy on the flat fleet (what the oversubscribed spine costs)
* ``cross_rack_frac``             — bulk transfers that crossed the
  spine (the rack-locality signal: path-aware Navigator keeps this low,
  hash placement does not)
* ``contended_frac``              — cross-rack transfers admitted while
  another flow held an uplink

``REPRO_BENCH_SMOKE=1`` shrinks the sweep to a CI-sized smoke run.
"""

from __future__ import annotations

import os
from typing import List, Tuple

from benchmarks.common import save_json
from repro.core import ProfileRepository, build_fleet, fleet
from repro.core.profiles import RACK_FLEETS
from repro.sim import Simulation, fleet_scaled_rate, poisson_workload
from repro.workflows import MODELS, paper_dfgs

SMOKE = os.environ.get("REPRO_BENCH_SMOKE") == "1"

DURATION_S = 60.0 if SMOKE else 240.0
BASE_RATE = 1.6
SEEDS = (3,) if SMOKE else (3, 7, 11)
FLEETS = ["rack2"] if SMOKE else ["rack2", "rack2_mixed"]
POLICIES = ["navigator", "hash"] if SMOKE else ["navigator", "hash", "heft"]


def _one(cluster, policy, jobs):
    profiles = ProfileRepository(cluster, MODELS)
    for d in paper_dfgs():
        profiles.register(d)
    sim = Simulation(cluster, profiles, MODELS, scheduler=policy, seed=1)
    return sim.run(jobs)


def run() -> List[Tuple[str, float, float]]:
    rows: List[Tuple[str, float, float]] = []
    out = {}
    dfgs = paper_dfgs()
    for policy in POLICIES:
        for fleet_name in FLEETS:
            cluster = fleet(fleet_name)
            # Flat reference: the same workers with no racks — every pair
            # on the full all-pairs table.  Same workload, so the tax is
            # purely the oversubscribed spine.
            flat = build_fleet(RACK_FLEETS[fleet_name][0])
            rate = fleet_scaled_rate(flat, BASE_RATE)
            workloads = {
                seed: poisson_workload(dfgs, rate, DURATION_S, seed=seed)
                for seed in SEEDS
            }
            flat_mean = sum(
                _one(flat, policy, workloads[s]).mean_latency
                for s in SEEDS
            ) / len(SEEDS)
            p99s, means, xfrac, cfrac = [], [], [], []
            for seed in SEEDS:
                res = _one(cluster, policy, workloads[seed])
                p99s.append(res.percentile_latency(0.99))
                means.append(res.mean_latency)
                bulk = res.net_local_transfers + res.net_cross_transfers
                xfrac.append(res.net_cross_transfers / max(1, bulk))
                cfrac.append(
                    res.net_contended_transfers
                    / max(1, res.net_cross_transfers)
                )
            n = len(SEEDS)
            key = f"{fleet_name}/{policy}"
            stats = {
                "p99_jct_s": sum(p99s) / n,
                "mean_jct_s": sum(means) / n,
                "topology_tax_s": sum(means) / n - flat_mean,
                "cross_rack_frac": sum(xfrac) / n,
                "contended_frac": sum(cfrac) / n,
            }
            out[key] = stats
            for metric in ("p99_jct_s", "mean_jct_s", "cross_rack_frac"):
                rows.append(
                    (f"topology/{key}/{metric}", 0.0, stats[metric])
                )
    save_json("topology", out)
    return rows


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived:.4f}")
